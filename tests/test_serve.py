"""Serving-path parity: the kernelized prefill/decode subsystem against the
sequential decode oracles and the full-sequence forward.

* prefill-kernel state == decode-replay state (fp32 tight, bf16 loose);
* ``lln_decode_chunk(T)`` == T sequential ``decode_step``s (state + outputs),
  including chunks that straddle a diag-block boundary and T > block;
* end-to-end greedy prefill + decode logits == the full-sequence forward for
  softmax / lln / lln_diag × GQA r ∈ {1, 4};
* the scanned generation segment == the per-token dispatch loop.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, ShapeSpec
from repro.core import attention as ca
from repro.core import lln as core_lln
from repro.kernels import ops as kops
from repro.models import build_model, synthetic_batch


def _qkv(seed, b, n, h, g, d, dtype=jnp.float32):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(kq, (b, n, h, d)).astype(dtype),
            jax.random.normal(kk, (b, n, g, d)).astype(dtype),
            jax.random.normal(kv, (b, n, g, d)).astype(dtype))


def _replay_state(q, k, v, alpha, beta_h, h):
    """Sequential decode_step replay over the prompt (the state oracle)."""
    b, n, _, d = q.shape
    kf = k if k.shape[2] == h else jnp.repeat(k, h // k.shape[2], axis=2)
    vf = v if v.shape[2] == h else jnp.repeat(v, h // v.shape[2], axis=2)
    st = core_lln.LLNState.init(b, h, d, vf.shape[-1])
    for t in range(n):
        _, st = core_lln.decode_step(st, q[:, t:t + 1], kf[:, t:t + 1],
                                     vf[:, t:t + 1], alpha, beta_h)
    return st


class TestPrefillState:
    @pytest.mark.parametrize("r", [1, 4])
    @pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 2e-4),
                                            (jnp.bfloat16, 5e-2)])
    def test_prefill_state_matches_decode_replay(self, r, dtype, rtol):
        b, n, g, d = 2, 48, 2, 16
        h = g * r
        q, k, v = _qkv(r, b, n, h, g, d, dtype)
        alpha = jnp.full((h,), 1.3)
        beta = jnp.full((g,), 1.1)
        out, s, z, c_k = kops.lln_prefill(q, k, v, alpha, beta, chunk=16)
        st = _replay_state(q, k, v, alpha, jnp.repeat(beta, r), h)
        # The reference constants may differ by a bf16 ulp (fp32 vs bf16
        # beta*k product); the states are equivalent after rescaling both
        # to a common constant.
        np.testing.assert_allclose(np.asarray(c_k), np.asarray(st.c_k),
                                   atol=1e-5 if dtype == jnp.float32
                                   else 2e-2)
        c_ref = jnp.maximum(c_k, st.c_k)
        fa = jnp.exp(c_k - c_ref)[:, 0, :, 0]
        fb = jnp.exp(st.c_k - c_ref)[:, 0, :, 0]
        s_a, s_b = s * fa[..., None, None], st.s * fb[..., None, None]
        z_a, z_b = z * fa[..., None], st.z * fb[..., None]
        scale = float(np.abs(np.asarray(s_b)).max())
        np.testing.assert_allclose(np.asarray(s_a), np.asarray(s_b),
                                   atol=rtol * scale)
        scale = float(np.abs(np.asarray(z_b)).max())
        np.testing.assert_allclose(np.asarray(z_a), np.asarray(z_b),
                                   atol=rtol * scale)
        assert out.dtype == dtype

    @pytest.mark.parametrize("n", [30, 48])
    def test_prefill_out_matches_core(self, n):
        """Aligned (scan twin) and ragged (jnp fallback) dispatch both match
        the core causal reference."""
        b, g, r, d = 1, 2, 2, 8
        h = g * r
        q, k, v = _qkv(3, b, n, h, g, d)
        alpha = jnp.full((h,), 1.2)
        beta = jnp.full((g,), 1.0)
        out, s, z, c_k = kops.lln_prefill(q, k, v, alpha, beta, chunk=16)
        kf, vf = jnp.repeat(k, r, 2), jnp.repeat(v, r, 2)
        ref, st_ref = core_lln.prefill(q, kf, vf, alpha,
                                       jnp.repeat(beta, r), chunk=16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-4)
        np.testing.assert_allclose(np.asarray(s), np.asarray(st_ref.s),
                                   rtol=1e-4, atol=1e-4)

    def test_prefill_kernel_path_matches_scan_twin(self, monkeypatch):
        """Interpret-mode Pallas state-emitting kernel == the scan twin the
        CPU container dispatches to."""
        b, n, g, r, d = 1, 32, 2, 2, 8
        h = g * r
        q, k, v = _qkv(5, b, n, h, g, d)
        alpha, beta = jnp.full((h,), 1.2), jnp.full((g,), 1.0)
        twin = kops.lln_prefill(q, k, v, alpha, beta, chunk=16)
        from repro.kernels.lln_attention import lln_causal_pallas
        monkeypatch.setattr(kops, "_interpret", lambda flag: False)
        monkeypatch.setattr(
            kops, "lln_causal_pallas",
            lambda *a, **kw: lln_causal_pallas(*a, **{**kw,
                                                      "interpret": True}))
        pallas = kops.lln_prefill(q, k, v, alpha, beta, chunk=16)
        for a, b_ in zip(pallas, twin):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=2e-5, atol=2e-5)


class TestDecodeChunk:
    def _state(self, b, h, g, d, n0, seed=0):
        q, k, v = _qkv(seed, b, n0, h, g, d)
        alpha = jnp.full((h,), 1.3)
        beta = jnp.full((g,), 1.1)
        _, s, z, c_k = kops.lln_prefill(q, k, v, alpha, beta, chunk=8)
        return core_lln.LLNState(s=s, z=z, c_k=c_k), alpha, \
            jnp.repeat(beta, h // g)

    @pytest.mark.parametrize("r", [1, 2])
    @pytest.mark.parametrize("t", [1, 7])
    def test_chunk_matches_sequential_steps(self, r, t):
        b, g, d, n0 = 2, 2, 8, 24
        h = g * r
        st, alpha, beta_h = self._state(b, h, g, d, n0)
        qn, kn, vn = _qkv(9, b, t, h, g, d)
        knh, vnh = jnp.repeat(kn, r, 2), jnp.repeat(vn, r, 2)
        oc, stc = kops.lln_decode_chunk(st, qn, kn, vn, alpha, beta_h)
        sts, outs = st, []
        for i in range(t):
            o, sts = core_lln.decode_step(sts, qn[:, i:i + 1],
                                          knh[:, i:i + 1], vnh[:, i:i + 1],
                                          alpha, beta_h)
            outs.append(o)
        oseq = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(oc), np.asarray(oseq),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(stc.s), np.asarray(sts.s),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(stc.z), np.asarray(sts.z),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(stc.c_k), np.asarray(sts.c_k),
                                   atol=1e-6)

    def test_chunk_kernel_matches_twin(self, monkeypatch):
        """Interpret-mode decode-chunk Pallas kernel (padded T path) == the
        jnp twin."""
        b, g, r, d, t = 2, 2, 2, 8, 7
        h = g * r
        st, alpha, beta_h = self._state(b, h, g, d, 24, seed=2)
        qn, kn, vn = _qkv(11, b, t, h, g, d)
        o_twin, st_twin = kops.lln_decode_chunk(st, qn, kn, vn, alpha,
                                                beta_h)
        real = kops.lln_decode_pallas
        monkeypatch.setattr(kops, "_interpret", lambda flag: False)
        monkeypatch.setattr(
            kops, "lln_decode_pallas",
            lambda *a, **kw: real(*a, **{**kw, "interpret": True}))
        o_pal, st_pal = kops.lln_decode_chunk(st, qn, kn, vn, alpha, beta_h)
        np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_twin),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(st_pal.s),
                                   np.asarray(st_twin.s), rtol=2e-5,
                                   atol=2e-5)
        np.testing.assert_allclose(np.asarray(st_pal.c_k),
                                   np.asarray(st_twin.c_k), atol=1e-6)

    @pytest.mark.parametrize("t", [1, 3, 5, 7, 12])
    def test_chunk_backends_agree_non_sublane_t(self, t):
        """lln_decode_chunk parity across explicit pallas/scan/ref backends
        for T that is NOT a sublane multiple (the Pallas path pads T with
        NEG_INF keys => Phi(k) = 0).  The speculative verify pass calls
        T = k+1 with arbitrary k, so odd chunk lengths are routine."""
        b, g, r, d = 2, 2, 2, 8
        h = g * r
        st, alpha, beta_h = self._state(b, h, g, d, 24, seed=t)
        qn, kn, vn = _qkv(17 + t, b, t, h, g, d)
        results = {}
        for backend in ("pallas", "scan", "ref"):
            results[backend] = kops.lln_decode_chunk(
                st, qn, kn, vn, alpha, beta_h, backend=backend)
        o_ref, st_ref = results["ref"]
        for backend in ("pallas", "scan"):
            o, stb = results[backend]
            np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                       rtol=2e-5, atol=2e-5,
                                       err_msg=backend)
            np.testing.assert_allclose(np.asarray(stb.s),
                                       np.asarray(st_ref.s),
                                       rtol=2e-5, atol=2e-5,
                                       err_msg=backend)
            np.testing.assert_allclose(np.asarray(stb.z),
                                       np.asarray(st_ref.z),
                                       rtol=2e-5, atol=2e-5,
                                       err_msg=backend)
            np.testing.assert_allclose(np.asarray(stb.c_k),
                                       np.asarray(st_ref.c_k),
                                       atol=1e-6, err_msg=backend)

    @pytest.mark.parametrize("t", [7, 19])
    def test_full_decode_chunk_crosses_block_boundary(self, t):
        """decode_lln_chunk (LLN + tail-softmax diag) over a chunk straddling
        a diag-block boundary == T sequential single-token decodes; G-head
        tail == the repeated H-head (seed-layout) tail."""
        b, g, r, d, block, n0 = 2, 2, 2, 8, 8, 21
        h = g * r
        st_lln, alpha, beta_h = self._state(b, h, g, d, n0, seed=3)
        _, k0, v0 = _qkv(3, b, n0, h, g, d)
        nb = -(-n0 // block)
        pad = nb * block - n0
        tg_k = jnp.pad(k0, ((0, 0), (0, pad), (0, 0), (0, 0)))[:, (nb - 1) * block:]
        tg_v = jnp.pad(v0, ((0, 0), (0, pad), (0, 0), (0, 0)))[:, (nb - 1) * block:]
        pos = jnp.asarray(n0, jnp.int32)
        st_g = ca.LLNDecodeState(lln=st_lln, tail_k=tg_k, tail_v=tg_v,
                                 pos=pos)
        st_h = ca.LLNDecodeState(lln=st_lln, tail_k=jnp.repeat(tg_k, r, 2),
                                 tail_v=jnp.repeat(tg_v, r, 2), pos=pos)
        qn, kn, vn = _qkv(13, b, t, h, g, d)
        for impl in ("lln", "lln_diag"):
            oc, stc = ca.decode_lln_chunk(st_g, qn, kn, vn, alpha, beta_h,
                                          impl=impl)
            sts, outs = st_h, []
            for i in range(t):
                o, sts = ca.decode_lln_chunk(
                    sts, qn[:, i:i + 1], kn[:, i:i + 1], vn[:, i:i + 1],
                    alpha, beta_h, impl=impl)
                outs.append(o)
            oseq = jnp.concatenate(outs, axis=1)
            np.testing.assert_allclose(np.asarray(oc), np.asarray(oseq),
                                       rtol=3e-5, atol=3e-5, err_msg=impl)
            np.testing.assert_allclose(
                np.asarray(jnp.repeat(stc.tail_k, r, 2)),
                np.asarray(sts.tail_k), atol=1e-6)
            assert int(stc.pos) == int(sts.pos)


def _tiny_cfg(impl, r, **kw):
    h = 4
    return ArchConfig(
        name=f"serve-test-r{r}", family="dense", n_layers=2, d_model=64,
        n_heads=h, n_kv_heads=h // r, d_ff=128, vocab=128, head_dim=16,
        attn_impl=impl, diag_block=8, lln_chunk=8, softmax_chunk=16,
        lln_fixed_ab=2.1 if impl != "softmax" else 0.0,
        compute_dtype="float32", param_dtype="float32", remat="none",
        tie_embeddings=True, **kw)


class TestEndToEnd:
    def test_greedy_decode_matches_full_forward(self, impl_gqa_cell):
        """Greedy prefill + decode logits == teacher-forced full-sequence
        forward logits (fixed alpha/beta so prompt-time stats match)."""
        from repro.models.layers import logits_from_hidden
        impl, r = impl_gqa_cell
        cfg = _tiny_cfg(impl, r)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        n_prompt, n_gen = 16, 5
        total = n_prompt + n_gen
        batch = synthetic_batch(cfg, batch=2, seq=total)
        full_h, _ = model.hidden(params, batch)
        head = params["embed"]["table"].T
        ref_logits = logits_from_hidden(head, full_h, cfg.cdtype, 0.0)

        prompt_batch = dict(batch)
        prompt_batch["inputs"] = batch["inputs"][:, :n_prompt]
        logits, caches = model.prefill(params, prompt_batch, total)
        last = logits[:, -1] if logits.ndim == 3 else logits
        np.testing.assert_allclose(
            np.asarray(last), np.asarray(ref_logits[:, n_prompt - 1]),
            atol=2e-3)
        for t in range(n_gen - 1):
            tok = batch["inputs"][:, n_prompt + t]
            logits, caches = model.decode(params, caches, tok,
                                          jnp.asarray(n_prompt + t,
                                                      jnp.int32))
            np.testing.assert_allclose(
                np.asarray(logits),
                np.asarray(ref_logits[:, n_prompt + t]), atol=2e-3,
                err_msg=f"step {t}")

    @pytest.mark.parametrize("impl", ["softmax", "lln_diag"])
    def test_chunked_model_decode_matches_sequential(self, impl):
        """model.decode over a (B, T) token chunk == T single-token calls."""
        cfg = _tiny_cfg(impl, 2)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(1))
        n_prompt, t = 16, 6
        batch = synthetic_batch(cfg, batch=2, seq=n_prompt + t)
        prompt_batch = dict(batch)
        prompt_batch["inputs"] = batch["inputs"][:, :n_prompt]
        draft = batch["inputs"][:, n_prompt:n_prompt + t]

        _, caches = model.prefill(params, prompt_batch, n_prompt + t)
        lg_chunk, _ = model.decode(params, caches, draft,
                                   jnp.asarray(n_prompt, jnp.int32))
        _, caches = model.prefill(params, prompt_batch, n_prompt + t)
        for i in range(t):
            lg, caches = model.decode(params, caches, draft[:, i],
                                      jnp.asarray(n_prompt + i, jnp.int32))
            np.testing.assert_allclose(np.asarray(lg_chunk[:, i]),
                                       np.asarray(lg), rtol=2e-4, atol=2e-4,
                                       err_msg=f"token {i}")

    def test_scanned_generate_matches_loop(self):
        """ServeSetup.make_generate (one lax.scan dispatch) produces the
        same greedy tokens as the per-token decode_fn loop."""
        from repro.launch.mesh import compat_mesh
        from repro.launch.steps import make_serve_setup
        cfg = _tiny_cfg("lln_diag", 2)
        model = build_model(cfg)
        n_prompt, steps = 16, 6
        mesh = compat_mesh((1, 1), ("data", "model"))
        shape = ShapeSpec("t", n_prompt + steps + 1, 2, "decode")
        with mesh:
            setup = make_serve_setup(cfg, shape, mesh, multi_pod=False)
            params = model.init(jax.random.PRNGKey(2))
            batch = synthetic_batch(cfg, 2, n_prompt + steps + 1,
                                    text_seq=n_prompt)
            pos0 = jnp.asarray(n_prompt, jnp.int32)

            logits, caches = setup.prefill_fn(params, batch)
            tok = jnp.argmax(logits[:, -1] if logits.ndim == 3 else logits,
                             -1).astype(jnp.int32)
            tok0 = tok
            loop_toks = []
            for i in range(steps):
                logits, caches = setup.decode_fn(params, caches, tok,
                                                 pos0 + i)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
                loop_toks.append(np.asarray(tok))

            _, caches = setup.prefill_fn(params, batch)
            gen_fn = setup.make_generate(steps, 0.0)
            toks, _ = gen_fn(params, caches, tok0, pos0,
                             jax.random.PRNGKey(0))
            np.testing.assert_array_equal(np.asarray(toks),
                                          np.stack(loop_toks, 1))
