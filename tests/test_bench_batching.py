"""Smoke test: the batching benchmark runs end-to-end (interpret mode)."""
import json

from benchmarks.bench_batching import run


def test_bench_batching_smoke(tmp_path):
    out = tmp_path / "BENCH_batching.json"
    report = run(str(out), smoke=True, repeats=1, verbose=False)
    assert out.exists()
    on_disk = json.loads(out.read_text())
    assert on_disk["modes"].keys() == {"static", "continuous",
                                       "continuous_spec"}
    assert len(on_disk["results"]) == len(report["results"]) == 1
    for row in on_disk["results"]:
        assert row["goodput_tok_s"]["static"] > 0
        assert row["goodput_tok_s"]["continuous"] > 0
        assert row["speedup"] > 0
        assert 0 < row["slot_utilization"]["continuous"] <= 1
        assert row["traffic"]["useful_tokens"] == sum(
            [3, 3, 9, 3, 3][:row["traffic"]["requests"]])
        # Pooled-speculative cell: same stream through the spec pool.
        assert row["goodput_tok_s"]["continuous_spec"] > 0
        sp = row["continuous_spec"]
        assert sp["spec_k"] >= 1
        assert 0.0 <= sp["acceptance_rate"] <= 1.0
        assert sp["verify_iters"] > 0
        # Every verify iteration commits in [1, spec_k + 1] tokens.
        assert 1.0 <= sp["goodput_tokens_per_iter"] <= sp["spec_k"] + 1
