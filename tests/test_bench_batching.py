"""Smoke test: the batching benchmark runs end-to-end (interpret mode)."""
import json

from benchmarks.bench_batching import run


def test_bench_batching_smoke(tmp_path):
    out = tmp_path / "BENCH_batching.json"
    report = run(str(out), smoke=True, repeats=1, verbose=False)
    assert out.exists()
    on_disk = json.loads(out.read_text())
    assert on_disk["modes"].keys() == {"static", "continuous"}
    assert len(on_disk["results"]) == len(report["results"]) == 1
    for row in on_disk["results"]:
        assert row["goodput_tok_s"]["static"] > 0
        assert row["goodput_tok_s"]["continuous"] > 0
        assert row["speedup"] > 0
        assert 0 < row["slot_utilization"]["continuous"] <= 1
        assert row["traffic"]["useful_tokens"] == sum(
            [3, 3, 9, 3, 3][:row["traffic"]["requests"]])
