"""Property tests for the flash softmax path (flat-head rewrite) and the
unified attention dispatcher — hypothesis sweeps over shapes, GQA ratios,
chunk sizes, masks and dtypes against the quadratic reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # container has no
    from _hypothesis_shim import given, settings       # hypothesis; use the
    from _hypothesis_shim import strategies as st      # deterministic shim

from repro.core import AttnConfig, flash_softmax, multi_head_attention, \
    naive_softmax


def _qkv(seed, b, n, h, g, d, dtype=jnp.float32):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(kq, (b, n, h, d), dtype),
            jax.random.normal(kk, (b, n, g, d), dtype),
            jax.random.normal(kv, (b, n, g, d), dtype))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(3, 90),
       hg=st.sampled_from([(1, 1), (4, 4), (4, 2), (8, 1), (6, 3)]),
       chunk=st.sampled_from([8, 16, 64]),
       causal=st.booleans(),
       seed=st.integers(0, 2**16))
def test_flash_matches_naive(n, hg, chunk, causal, seed):
    h, g = hg
    q, k, v = _qkv(seed, 2, n, h, g, 16)
    out = flash_softmax(q, k, v, causal=causal, chunk=chunk)
    ref = naive_softmax(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(16, 64), prefix=st.integers(1, 15),
       seed=st.integers(0, 2**16))
def test_flash_prefix_lm(n, prefix, seed):
    q, k, v = _qkv(seed, 1, n, 4, 2, 8)
    out = flash_softmax(q, k, v, causal=True, chunk=16, prefix_len=prefix)
    ref = naive_softmax(q, k, v, causal=True, prefix_len=prefix)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(8, 64), valid=st.integers(1, 8),
       seed=st.integers(0, 2**16))
def test_flash_key_mask(n, valid, seed):
    q, k, v = _qkv(seed, 2, n, 4, 4, 8)
    m = (jnp.arange(n)[None] < min(valid, n)).repeat(2, 0)
    out = flash_softmax(q, k, v, causal=False, chunk=16, mask=m)
    ref = naive_softmax(q, k, v, causal=False, mask=m)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


@settings(max_examples=8, deadline=None)
@given(nq=st.integers(1, 8), nk=st.integers(16, 64),
       seed=st.integers(0, 2**16))
def test_flash_decode_shapes(nq, nk, seed):
    """queries are the last nq positions of an nk-long context."""
    q, k, v = _qkv(seed, 2, nk, 4, 2, 8)
    out = flash_softmax(q[:, -nq:], k, v, causal=True, chunk=16)
    ref = naive_softmax(q[:, -nq:], k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_flash_bf16_close_to_f32():
    q, k, v = _qkv(0, 2, 64, 4, 2, 16)
    ref = flash_softmax(q, k, v, causal=True, chunk=16)
    out = flash_softmax(q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                        v.astype(jnp.bfloat16), causal=True, chunk=16)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=5e-2)


def test_flash_grads_match_naive():
    q, k, v = _qkv(1, 2, 48, 4, 2, 8)

    def lf(q, k, v):
        return jnp.sum(flash_softmax(q, k, v, causal=True, chunk=16) ** 2)

    def ln(q, k, v):
        return jnp.sum(naive_softmax(q, k, v, causal=True) ** 2)

    gf = jax.grad(lf, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(ln, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gn):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(impl=st.sampled_from(["softmax", "lln", "lln_diag"]),
       causal=st.booleans(), seed=st.integers(0, 2**16))
def test_dispatcher_finite_and_shaped(impl, causal, seed):
    q, k, v = _qkv(seed, 2, 32, 4, 2, 16)
    cfg = AttnConfig(impl=impl, causal=causal, diag_block=16, lln_chunk=16,
                     softmax_chunk=16)
    out = multi_head_attention(q, k, v, cfg)
    assert out.shape == q.shape
    assert np.all(np.isfinite(np.asarray(out, np.float32)))


def test_dispatcher_rejects_unknown_impl():
    q, k, v = _qkv(0, 1, 16, 2, 2, 8)
    with pytest.raises(ValueError):
        multi_head_attention(q, k, v, AttnConfig(impl="bogus"))
