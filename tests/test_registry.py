"""Backend registry + AttentionEngine lifecycle.

* explicit ``backend=pallas(interpret)|scan|ref`` parity at small shapes,
  impl × causal × r ∈ {1, 4} — the scan twins and jnp references are
  first-class testable targets, not accidents of the CPU dispatch;
* ``AttnSpec`` validation errors and ``resolve`` policy;
* ``AttentionState`` lifecycle round-trip (init → prefill → decode →
  evict) matching the legacy ``attn_prefill``/``attn_decode`` composition
  bitwise;
* MLA chunked multi-token decode through the engine.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attention as ca
from repro.core import lln as core_lln
from repro.core.engine import AttentionEngine, AttentionState
from repro.kernels import ops as kops
from repro.kernels.registry import AttnSpec, Resolution, resolve


def _qkv(seed, b, n, h, g, d, dtype=jnp.float32):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(kq, (b, n, h, d)).astype(dtype),
            jax.random.normal(kk, (b, n, g, d)).astype(dtype),
            jax.random.normal(kv, (b, n, g, d)).astype(dtype))


# ---------------------------------------------------------------------------
# AttnSpec validation + resolve policy.
# ---------------------------------------------------------------------------

class TestSpecValidation:
    def test_defaults_valid(self):
        AttnSpec()

    @pytest.mark.parametrize("kw", [
        {"impl": "bogus"},
        {"backend": "cuda"},
        {"impl": "softmax", "backend": "pallas"},
        {"r": 0},
        {"calibration": "global"},
        {"precision": "int8"},
        {"lln_chunk": 0},
        {"diag_block": -1},
        {"fixed_ab": -2.0},
    ])
    def test_invalid_specs_raise(self, kw):
        with pytest.raises(ValueError):
            AttnSpec(**kw)

    def test_from_cfg_maps_serve_kernel_escape(self):
        from repro.configs.base import ArchConfig
        cfg = ArchConfig(name="t", family="dense", n_layers=1, d_model=32,
                         n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                         head_dim=8, attn_impl="lln_diag",
                         use_serve_kernel=False)
        spec = AttnSpec.from_cfg(cfg)
        assert spec.backend == "ref"      # the seed jnp serving path
        assert spec.r == 2
        spec2 = AttnSpec.from_cfg(cfg.replace(use_serve_kernel=True))
        assert spec2.backend == "auto"

    def test_resolve_policy(self):
        assert resolve("auto", ragged=True) == Resolution("ref", False)
        assert resolve("ref", ragged=True) == Resolution("ref", False)
        assert resolve("scan") == Resolution("scan", False)
        for backend in ("pallas", "scan"):
            with pytest.raises(ValueError):
                resolve(backend, ragged=True)
        with pytest.raises(ValueError):
            resolve("tpu")


# ---------------------------------------------------------------------------
# Explicit-backend parity at the ops level.
# ---------------------------------------------------------------------------

class TestBackendParity:
    @pytest.mark.parametrize("causal", [True, False])
    def test_attention_backends_agree(self, lln_parity_cell, causal):
        backend, impl, r = lln_parity_cell
        b, n, g, d = 2, 32, 2, 8
        h = g * r
        q, k, v = _qkv(r, b, n, h, g, d)
        alpha = jnp.full((h,), 1.2)
        beta = jnp.full((g,), 1.0)
        if impl == "log_linear" and not causal:
            pytest.skip("log_linear is causal-only")
        fn = {"lln": kops.lln_attention,
              "lln_diag": kops.lln_diag_attention,
              "log_linear": kops.loglin_attention}[impl]
        ref = fn(q, k, v, alpha, beta, causal, 16, backend="auto")
        out = fn(q, k, v, alpha, beta, causal, 16, backend=backend)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-4, atol=3e-4,
                                   err_msg=f"{impl} {backend}")

    def test_prefill_backends_agree(self, backend_gqa_cell):
        backend, r = backend_gqa_cell
        b, n, g, d = 2, 32, 2, 8
        h = g * r
        q, k, v = _qkv(10 + r, b, n, h, g, d)
        alpha = jnp.full((h,), 1.3)
        beta = jnp.full((g,), 1.1)
        ref = kops.lln_prefill(q, k, v, alpha, beta, chunk=16,
                               backend="auto")
        got = kops.lln_prefill(q, k, v, alpha, beta, chunk=16,
                               backend=backend)
        for name, a, b_ in zip(("out", "s", "z", "c_k"), got, ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=3e-4, atol=3e-4,
                                       err_msg=f"{backend}:{name}")

    def test_decode_chunk_backends_agree(self, backend_gqa_cell):
        backend, r = backend_gqa_cell
        b, g, d, t = 2, 2, 8, 5
        h = g * r
        q0, k0, v0 = _qkv(20 + r, b, 24, h, g, d)
        alpha = jnp.full((h,), 1.3)
        beta = jnp.full((g,), 1.1)
        _, s, z, c_k = kops.lln_prefill(q0, k0, v0, alpha, beta, chunk=8)
        st = core_lln.LLNState(s=s, z=z, c_k=c_k)
        qn, kn, vn = _qkv(30 + r, b, t, h, g, d)
        ref = kops.lln_decode_chunk(st, qn, kn, vn, alpha, beta,
                                    backend="auto")
        o, st2 = kops.lln_decode_chunk(st, qn, kn, vn, alpha, beta,
                                       backend=backend)
        np.testing.assert_allclose(np.asarray(o), np.asarray(ref[0]),
                                   rtol=3e-4, atol=3e-4,
                                   err_msg=backend)
        np.testing.assert_allclose(np.asarray(st2.s),
                                   np.asarray(ref[1].s), rtol=3e-4,
                                   atol=3e-4, err_msg=backend)

    def test_diag_fwd_backends_agree(self):
        b, n, g, r, d = 2, 32, 2, 2, 8
        h = g * r
        q, k, v = _qkv(40, b, n, h, g, d)
        ref = kops.block_diag_fwd(q, k, v, 8, True, backend="auto")
        for backend in ("pallas", "scan", "ref"):
            out = kops.block_diag_fwd(q, k, v, 8, True, backend=backend)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=3e-4, atol=3e-4,
                                       err_msg=backend)

    def test_explicit_pallas_rejects_ragged(self):
        q, k, v = _qkv(50, 1, 30, 2, 2, 8)     # 30 % 16 != 0
        with pytest.raises(ValueError):
            kops.lln_prefill(q, k, v, 1.0, 1.0, chunk=16, backend="pallas")


# ---------------------------------------------------------------------------
# Engine-level backend parity (softmax included) + state lifecycle.
# ---------------------------------------------------------------------------

def _engine(impl, r, backend="auto", calibration="batch"):
    g = 2
    spec = AttnSpec(impl=impl, causal=True, r=r, backend=backend,
                    lln_chunk=8, diag_block=8, softmax_chunk=16,
                    fixed_ab=0.0 if impl == "softmax" else 2.1,
                    calibration=calibration)
    return AttentionEngine(spec=spec, heads=g * r, kv_heads=g, head_dim=8,
                           v_dim=8, cache_dtype=jnp.float32)


class TestEngineLifecycle:
    def test_engine_backends_agree_end_to_end(self, engine_parity_cell):
        """prefill + decode outputs agree across every legal backend
        (each cell checks one backend against the auto resolution)."""
        backend, impl, r = engine_parity_cell
        b, n, g, d, t = 2, 16, 2, 8, 3
        h = g * r
        q, k, v = _qkv(60 + r, b, n, h, g, d)
        qn, kn, vn = _qkv(70 + r, b, t, h, g, d)
        ref_eng = _engine(impl, r, "auto")
        ref_out, ref_st = ref_eng.prefill(q, k, v, max_len=n + t)
        ref_out2, _ = ref_eng.decode(ref_st, qn, kn, vn)
        eng = _engine(impl, r, backend)
        out, st = eng.prefill(q, k, v, max_len=n + t)
        out2, _ = eng.decode(st, qn, kn, vn)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                                   rtol=3e-4, atol=3e-4, err_msg=backend)
        np.testing.assert_allclose(np.asarray(out2), np.asarray(ref_out2),
                                   rtol=3e-4, atol=3e-4, err_msg=backend)

    @pytest.mark.parametrize("impl", ["softmax", "lln_diag"])
    def test_lifecycle_roundtrip_matches_legacy_bitwise(self, impl):
        """init -> prefill -> decode -> evict; every step bitwise-equal to
        the legacy composition (KVCache/LLNDecodeState + decode_softmax /
        decode_lln_chunk — the pre-engine ``attn_decode`` body)."""
        b, n, g, r, d, t = 2, 16, 2, 2, 8, 2
        h = g * r
        eng = _engine(impl, r)
        q, k, v = _qkv(80, b, n, h, g, d)
        qn, kn, vn = _qkv(81, b, t, h, g, d)

        st0 = eng.init_state(b, n + t)
        assert st0.pos is None or st0.pos.shape == (b,)
        out, st = eng.prefill(q, k, v, max_len=n + t)
        out2, st2 = eng.decode(st, qn, kn, vn)

        if impl == "softmax":
            legacy = ca.KVCache(k=st.k, v=st.v, length=st.len)
            ref2, kv2 = ca.decode_softmax(legacy, qn, kn, vn,
                                          chunk=eng.spec.softmax_chunk)
            np.testing.assert_array_equal(np.asarray(out2),
                                          np.asarray(ref2))
            np.testing.assert_array_equal(np.asarray(st2.k),
                                          np.asarray(kv2.k))
            np.testing.assert_array_equal(np.asarray(st2.len),
                                          np.asarray(kv2.length))
        else:
            legacy = ca.LLNDecodeState(
                lln=core_lln.LLNState(s=st.s, z=st.z, c_k=st.c_k),
                tail_k=st.tail_k, tail_v=st.tail_v, pos=st.pos)
            ref2, lst = ca.decode_lln_chunk(legacy, qn, kn, vn, st.alpha,
                                            st.beta, impl=impl)
            np.testing.assert_array_equal(np.asarray(out2),
                                          np.asarray(ref2))
            np.testing.assert_array_equal(np.asarray(st2.s),
                                          np.asarray(lst.lln.s))
            np.testing.assert_array_equal(np.asarray(st2.tail_k),
                                          np.asarray(lst.tail_k))
            np.testing.assert_array_equal(np.asarray(st2.pos),
                                          np.asarray(lst.pos))

        # evict resets exactly the named rows to their init values (zeros;
        # calibration alpha/beta back to ONES), others intact.
        st3 = eng.evict(st2, jnp.asarray([0], jnp.int32))
        for kp, leaf in jax.tree_util.tree_leaves_with_path(st3):
            path = jax.tree_util.keystr(kp)
            fill = 1.0 if ("alpha" in path or "beta" in path) else 0.0
            np.testing.assert_array_equal(
                np.asarray(leaf)[0],
                np.full_like(np.asarray(leaf)[0], fill),
                err_msg=f"evicted row not reset to init: {path}")
        for kp, leaf in jax.tree_util.tree_leaves_with_path(st2):
            after = st3
            for kk in kp:
                after = after[kk.key]
            np.testing.assert_array_equal(
                np.asarray(after)[1], np.asarray(leaf)[1],
                err_msg=f"evict leaked into live row: {jax.tree_util.keystr(kp)}")

    def test_legacy_shims_delegate_bitwise(self):
        """attn_prefill/attn_decode (deprecation shims) return exactly what
        serve_prefill/serve_decode return."""
        from repro.models import attention_block as ab
        from repro.configs.base import ArchConfig
        cfg = ArchConfig(name="t", family="dense", n_layers=1, d_model=32,
                         n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                         head_dim=8, attn_impl="lln_diag", diag_block=8,
                         lln_chunk=8, softmax_chunk=16, lln_fixed_ab=2.1,
                         compute_dtype="float32", param_dtype="float32")
        p = ab.attn_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
        positions = jnp.arange(16)
        out_new, st_new = ab.serve_prefill(p, x, cfg, positions, max_len=20)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            out_old, st_old = ab.attn_prefill(p, x, cfg, positions,
                                              max_len=20)
        np.testing.assert_array_equal(np.asarray(out_new),
                                      np.asarray(out_old))
        x1 = jax.random.normal(jax.random.PRNGKey(2), (2, 1, 32))
        d_new, s2_new = ab.serve_decode(p, x1, st_new, cfg,
                                        jnp.asarray(16, jnp.int32))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            d_old, _ = ab.attn_decode(p, x1, st_old, cfg,
                                      jnp.asarray(16, jnp.int32))
        np.testing.assert_array_equal(np.asarray(d_new), np.asarray(d_old))

    def test_state_is_a_pytree_with_dict_paths(self):
        st = _engine("lln_diag", 2).init_state(2, 16)
        leaves = jax.tree_util.tree_leaves_with_path(st)
        names = {kp[-1].key for kp, _ in leaves}
        assert {"s", "z", "c_k", "log_scale", "tail_k", "tail_v", "pos",
                "alpha", "beta"} == names
        assert st["pos"].shape == (2,)
        with pytest.raises(KeyError):
            st["nope"]


# ---------------------------------------------------------------------------
# Per-row calibration (batched-prefill admission).
# ---------------------------------------------------------------------------

class TestPerRowCalibration:
    def test_per_row_matches_solo_rows(self):
        """(B, H) per-row alpha/beta == each row calibrated alone."""
        b, n, g, r, d = 3, 16, 2, 2, 8
        h = g * r
        q, k, _ = _qkv(90, b, n, h, g, d)
        cfg = ca.AttnConfig(impl="lln", fixed_ab=0.0)
        a_rows, b_rows = ca.batch_alpha_beta(q, k, cfg, per_row=True)
        assert a_rows.shape == (b, h) and b_rows.shape == (b, g)
        for i in range(b):
            a1, b1 = ca.batch_alpha_beta(q[i:i + 1], k[i:i + 1], cfg)
            np.testing.assert_allclose(np.asarray(a_rows[i]),
                                       np.asarray(a1), rtol=1e-6)
            np.testing.assert_allclose(np.asarray(b_rows[i]),
                                       np.asarray(b1), rtol=1e-6)

    @pytest.mark.parametrize("backend", ["pallas", "scan", "ref"])
    def test_per_row_calibration_works_on_every_backend(self, backend):
        """(B, H)/(B, G) calibration must flow through every backend's
        full-sequence forward — including the jnp core path (which pools
        per-q-head beta to groups and repeats it per row)."""
        b, n, g, r, d = 2, 16, 2, 2, 8
        h = g * r
        eng = _engine("lln", r, backend, calibration="per_row")
        q, k, v = _qkv(92, b, n, h, g, d)
        alpha, beta = eng.calibrate(q, k)
        assert alpha.shape == (b, h) and beta.shape == (b, g)
        out = eng.attention(q, k, v, alpha=alpha, beta=beta)
        assert out.shape == (b, n, h, d)
        # And with calibration computed inside attention(): same result —
        # engine.attention must honour spec.calibration, not silently
        # fall back to batch pooling.
        out2 = eng.attention(q, k, v)
        np.testing.assert_allclose(np.asarray(out2), np.asarray(out),
                                   rtol=2e-5, atol=2e-5)

    def test_per_row_calibration_grads(self):
        """jax.grad through lln_attention with per-row (B, H)/(B, G)
        alpha/beta — the custom_vjp chain rule must broadcast per row."""
        b, n, g, r, d = 2, 16, 2, 2, 8
        h = g * r
        q, k, v = _qkv(93, b, n, h, g, d)
        alpha = jnp.abs(jax.random.normal(jax.random.PRNGKey(7), (b, h))) + 1
        beta = jnp.abs(jax.random.normal(jax.random.PRNGKey(8), (b, g))) + 1
        for fn in (kops.lln_attention, kops.lln_diag_attention):
            grads = jax.grad(
                lambda q_, k_, v_: fn(q_, k_, v_, alpha, beta, True,
                                      8).sum(), argnums=(0, 1, 2))(q, k, v)
            for gr in grads:
                assert bool(jnp.isfinite(gr).all()), fn.__name__

    def test_engine_per_row_prefill_matches_solo(self):
        """A batched per-row-calibrated prefill carries exactly the state
        each row would get prefilled alone."""
        b, n, g, r, d = 2, 16, 2, 2, 8
        h = g * r
        eng = _engine("lln_diag", r, calibration="per_row")
        q, k, v = _qkv(91, b, n, h, g, d)
        out, st = eng.prefill(q, k, v, max_len=24)
        for i in range(b):
            _, sti = eng.prefill(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                                 max_len=24)
            np.testing.assert_allclose(np.asarray(st.alpha[i]),
                                       np.asarray(sti.alpha[0]), rtol=1e-6)
            np.testing.assert_allclose(np.asarray(st.s[i]),
                                       np.asarray(sti.s[0]), rtol=2e-5,
                                       atol=2e-5)


# ---------------------------------------------------------------------------
# MLA through the engine: chunked multi-token decode.
# ---------------------------------------------------------------------------

def _mla_cfg(impl):
    # Dense FFN so chunk-vs-sequential isolates the attention path (MoE
    # capacity routing is per-dispatch and would differ legitimately).
    from repro.configs.base import ArchConfig
    return ArchConfig(
        name=f"mla-test-{impl}", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=128, attn_impl=impl,
        diag_block=8, lln_chunk=8, softmax_chunk=16,
        lln_fixed_ab=2.1 if impl != "softmax" else 0.0,
        kv_lora=32, q_lora=24, rope_head_dim=8, nope_head_dim=16,
        v_head_dim=16, compute_dtype="float32", param_dtype="float32",
        remat="none", tie_embeddings=True)


class TestMLAChunkedDecode:
    @pytest.mark.parametrize("impl", ["softmax", "lln_diag"])
    def test_mla_chunked_decode_matches_sequential(self, impl):
        """model.decode over a (B, T) chunk == T single-token calls for
        MLA — chunked decode now reaches the latent-attention family."""
        from repro.models import build_model, synthetic_batch
        cfg = _mla_cfg(impl)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(4))
        n_prompt, t = 16, 4
        batch = synthetic_batch(cfg, batch=2, seq=n_prompt + t)
        prompt_batch = dict(batch)
        prompt_batch["inputs"] = batch["inputs"][:, :n_prompt]
        draft = batch["inputs"][:, n_prompt:n_prompt + t]

        _, caches = model.prefill(params, prompt_batch, n_prompt + t)
        lg_chunk, _ = model.decode(params, caches, draft,
                                   jnp.asarray(n_prompt, jnp.int32))
        _, caches = model.prefill(params, prompt_batch, n_prompt + t)
        for i in range(t):
            lg, caches = model.decode(params, caches, draft[:, i],
                                      jnp.asarray(n_prompt + i, jnp.int32))
            np.testing.assert_allclose(np.asarray(lg_chunk[:, i]),
                                       np.asarray(lg), rtol=3e-4,
                                       atol=3e-4, err_msg=f"token {i}")

    def test_mla_state_has_g_head_tails(self):
        """The MLA LLN state is the same AttentionState pytree, tails at
        the (here G == H) kv heads."""
        from repro.models.mla import mla_state_init
        cfg = _mla_cfg("lln_diag")
        st = mla_state_init(cfg, 2, 32)
        assert isinstance(st, AttentionState)
        assert st.tail_k.shape == (2, cfg.diag_block, 4,
                                   cfg.nope_head_dim + cfg.rope_head_dim)
        assert st.pos.shape == (2,)
        assert st.alpha.shape == (2, 4)
