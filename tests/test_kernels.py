"""Pallas kernels vs pure-jnp oracles (interpret mode), incl. hypothesis
shape/dtype sweeps and gradient checks through the custom_vjp wrappers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import (block_diag_attention, lln_attention,
                           lln_diag_attention)
from repro.kernels import ref as kref
from repro.kernels.block_diag import block_diag_pallas
from repro.kernels.lln_attention import (lln_bidir_pallas, lln_causal_pallas,
                                         lln_diag_fused_pallas)


def _inputs(key, bh, bg, n, d, dv, dtype=jnp.float32, shift=-0.5):
    kq, kk, kv = jax.random.split(key, 3)
    qs = (jax.random.normal(kq, (bh, n, d)) + shift).astype(dtype)
    ks = (jax.random.normal(kk, (bg, n, d)) + shift).astype(dtype)
    v = jax.random.normal(kv, (bg, n, dv)).astype(dtype)
    return qs, ks, v


@settings(max_examples=12, deadline=None)
@given(r=st.sampled_from([1, 2, 4]),
       nblk=st.integers(1, 4),
       blk=st.sampled_from([8, 16]),
       d=st.sampled_from([8, 16]),
       seed=st.integers(0, 2**16))
def test_lln_causal_kernel_sweep(r, nblk, blk, d, seed):
    bg, n = 2, nblk * blk
    qs, ks, v = _inputs(jax.random.PRNGKey(seed), bg * r, bg, n, d, d)
    out = lln_causal_pallas(qs, ks, v, r=r, blk=blk, interpret=True)
    ref = kref.lln_causal_ref(qs, ks, v, r=r)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


@settings(max_examples=12, deadline=None)
@given(r=st.sampled_from([1, 2]),
       nblk=st.integers(1, 4),
       blk=st.sampled_from([8, 16]),
       dv=st.sampled_from([8, 16]),
       seed=st.integers(0, 2**16))
def test_lln_bidir_kernel_sweep(r, nblk, blk, dv, seed):
    bg, n, d = 2, nblk * blk, 16
    qs, ks, v = _inputs(jax.random.PRNGKey(seed), bg * r, bg, n, d, dv)
    out = lln_bidir_pallas(qs, ks, v, r=r, blk=blk, interpret=True)
    ref = kref.lln_bidir_ref(qs, ks, v, r=r)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


@settings(max_examples=12, deadline=None)
@given(r=st.sampled_from([1, 4]),
       causal=st.booleans(),
       blk=st.sampled_from([8, 16]),
       seed=st.integers(0, 2**16))
def test_block_diag_kernel_sweep(r, causal, blk, seed):
    bg, n, d = 2, 3 * blk, 16
    q, k, v = _inputs(jax.random.PRNGKey(seed), bg * r, bg, n, d, d, shift=0)
    out = block_diag_pallas(q, k, v, r=r, blk=blk, causal=causal,
                            interpret=True)
    ref = kref.block_diag_ref(q, k, v, block=blk, causal=causal, r=r)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


def test_fused_lln_diag_kernel():
    key = jax.random.PRNGKey(0)
    qs, ks, v = _inputs(key, 4, 2, 48, 16, 16)
    q, k, _ = _inputs(jax.random.PRNGKey(1), 4, 2, 48, 16, 16, shift=0)
    out = lln_diag_fused_pallas(qs, ks, q, k, v, r=2, blk=16, causal=True,
                                interpret=True)
    ref = kref.lln_diag_fused_ref(qs, ks, q, k, v, block=16, causal=True, r=2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


def test_fused_kernel_rejects_bidir():
    with pytest.raises(ValueError):
        lln_diag_fused_pallas(jnp.zeros((1, 16, 8)), jnp.zeros((1, 16, 8)),
                              jnp.zeros((1, 16, 8)), jnp.zeros((1, 16, 8)),
                              jnp.zeros((1, 16, 8)), causal=False)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernels_dtype(dtype):
    qs, ks, v = _inputs(jax.random.PRNGKey(2), 4, 2, 32, 16, 16, dtype=dtype)
    out = lln_causal_pallas(qs, ks, v, r=2, blk=16, interpret=True)
    ref = kref.lln_causal_ref(qs, ks, v, r=2)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=3e-2 if dtype == jnp.bfloat16 else 2e-4)


class TestPublicOps:
    def _model_inputs(self, key, b=2, n=32, h=4, g=2, d=16):
        kq, kk, kv = jax.random.split(key, 3)
        return (jax.random.normal(kq, (b, n, h, d)),
                jax.random.normal(kk, (b, n, g, d)),
                jax.random.normal(kv, (b, n, g, d)))

    def test_lln_attention_grads_match_ref(self):
        q, k, v = self._model_inputs(jax.random.PRNGKey(0))
        alpha = jnp.full((4,), 1.5)
        beta = jnp.full((2,), 1.2)
        from repro.core import lln_causal

        def loss_kernel(q, k, v):
            return jnp.sum(lln_attention(q, k, v, alpha, beta, True, 16) ** 2)

        def loss_ref(q, k, v):
            return jnp.sum(lln_causal(q, jnp.repeat(k, 2, 2),
                                      jnp.repeat(v, 2, 2), alpha,
                                      jnp.repeat(beta, 2), chunk=16) ** 2)

        gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=2e-3)

    def test_block_diag_attention_grad_finite(self):
        q, k, v = self._model_inputs(jax.random.PRNGKey(1))
        g = jax.grad(lambda q: jnp.sum(
            block_diag_attention(q, k, v, 16, True) ** 2))(q)
        assert np.all(np.isfinite(np.asarray(g)))

    def test_lln_diag_attention_matches_unfused(self):
        q, k, v = self._model_inputs(jax.random.PRNGKey(2))
        alpha = jnp.full((4,), 1.5)
        beta = jnp.full((2,), 1.2)
        fused = lln_diag_attention(q, k, v, alpha, beta, True, 16)
        lln = lln_attention(q, k, v, alpha, beta, True, 16)
        diag = block_diag_attention(q, k, v, 16, True)
        np.testing.assert_allclose(np.asarray(fused),
                                   np.asarray(0.5 * (lln + diag)), atol=1e-4)

    def test_unaligned_seq_falls_back(self):
        q, k, v = self._model_inputs(jax.random.PRNGKey(3), n=30)
        out = lln_attention(q, k, v, 1.0, 1.0, True, 16)
        assert out.shape == q.shape
        assert np.all(np.isfinite(np.asarray(out, np.float32)))


class TestSSDKernel:
    def _inputs(self, key, b=2, l=48, h=4, g=2, p=8, s=4):
        ks = jax.random.split(key, 4)
        xbar = jax.random.normal(ks[0], (b, l, h, p))
        b_in = jax.random.normal(ks[1], (b, l, g, s))
        c_in = jax.random.normal(ks[2], (b, l, g, s))
        log_a = -jax.nn.softplus(jax.random.normal(ks[3], (b, l, h)))
        return xbar, b_in, c_in, log_a

    @settings(max_examples=8, deadline=None)
    @given(g=st.sampled_from([1, 2, 4]), nblk=st.integers(1, 3),
           seed=st.integers(0, 2**16))
    def test_ssd_kernel_sweep(self, g, nblk, seed):
        from repro.kernels import ssd_scan
        from repro.models.ssm import ssd_chunked
        xbar, b_in, c_in, log_a = self._inputs(
            jax.random.PRNGKey(seed), l=nblk * 16, g=g)
        y = ssd_scan(xbar, b_in, c_in, log_a, 16)
        rep = 4 // g
        bf = jnp.repeat(b_in, rep, 2)
        cf = jnp.repeat(c_in, rep, 2)
        y_ref, _ = ssd_chunked(xbar, bf, cf, log_a, chunk=16)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=3e-4)

    def test_ssd_kernel_grads(self):
        from repro.kernels import ssd_scan
        from repro.models.ssm import ssd_chunked
        xbar, b_in, c_in, log_a = self._inputs(jax.random.PRNGKey(0))
        bf = jnp.repeat(b_in, 2, 2)
        cf = jnp.repeat(c_in, 2, 2)
        gk = jax.grad(lambda x, a: jnp.sum(
            ssd_scan(x, b_in, c_in, a, 16) ** 2), argnums=(0, 1))(
                xbar, log_a)
        gr = jax.grad(lambda x, a: jnp.sum(
            ssd_chunked(x, bf, cf, a, chunk=16)[0] ** 2), argnums=(0, 1))(
                xbar, log_a)
        for a, b_ in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=3e-3)

    def test_mamba_block_with_kernel_matches_jnp(self):
        from repro.configs import get_config
        from repro.models.ssm import ssm_apply, ssm_init
        cfg = get_config("mamba2-130m", smoke=True).replace(
            compute_dtype="float32", ssm_chunk=16)
        p = ssm_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
        y_jnp = ssm_apply(p, x, cfg)
        y_k = ssm_apply(p, x, cfg.replace(use_kernel=True))
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_jnp),
                                   atol=1e-4)
