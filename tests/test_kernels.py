"""Pallas kernels vs pure-jnp oracles (interpret mode), incl. hypothesis
shape/dtype sweeps and gradient checks through the custom_vjp wrappers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # container has no
    from _hypothesis_shim import given, settings       # hypothesis; use the
    from _hypothesis_shim import strategies as st      # deterministic shim

from repro.kernels import (block_diag_attention, lln_attention,
                           lln_diag_attention)
from repro.kernels import ref as kref
from repro.kernels.block_diag import block_diag_bwd_pallas, block_diag_pallas
from repro.kernels.lln_attention import (lln_bidir_pallas, lln_causal_pallas,
                                         lln_diag_fused_pallas)
from repro.kernels.lln_backward import (lln_bidir_bwd_pallas,
                                        lln_bidir_bwd_scan,
                                        lln_causal_bwd_pallas,
                                        lln_causal_bwd_scan,
                                        lln_diag_fused_bwd_pallas,
                                        lln_diag_fused_bwd_scan,
                                        block_diag_bwd_scan)


def _inputs(key, bh, bg, n, d, dv, dtype=jnp.float32, shift=-0.5):
    kq, kk, kv = jax.random.split(key, 3)
    qs = (jax.random.normal(kq, (bh, n, d)) + shift).astype(dtype)
    ks = (jax.random.normal(kk, (bg, n, d)) + shift).astype(dtype)
    v = jax.random.normal(kv, (bg, n, dv)).astype(dtype)
    return qs, ks, v


@settings(max_examples=12, deadline=None)
@given(r=st.sampled_from([1, 2, 4]),
       nblk=st.integers(1, 4),
       blk=st.sampled_from([8, 16]),
       d=st.sampled_from([8, 16]),
       seed=st.integers(0, 2**16))
def test_lln_causal_kernel_sweep(r, nblk, blk, d, seed):
    bg, n = 2, nblk * blk
    qs, ks, v = _inputs(jax.random.PRNGKey(seed), bg * r, bg, n, d, d)
    out = lln_causal_pallas(qs, ks, v, r=r, blk=blk, interpret=True)
    ref = kref.lln_causal_ref(qs, ks, v, r=r)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


@settings(max_examples=12, deadline=None)
@given(r=st.sampled_from([1, 2]),
       nblk=st.integers(1, 4),
       blk=st.sampled_from([8, 16]),
       dv=st.sampled_from([8, 16]),
       seed=st.integers(0, 2**16))
def test_lln_bidir_kernel_sweep(r, nblk, blk, dv, seed):
    bg, n, d = 2, nblk * blk, 16
    qs, ks, v = _inputs(jax.random.PRNGKey(seed), bg * r, bg, n, d, dv)
    out = lln_bidir_pallas(qs, ks, v, r=r, blk=blk, interpret=True)
    ref = kref.lln_bidir_ref(qs, ks, v, r=r)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


@settings(max_examples=12, deadline=None)
@given(r=st.sampled_from([1, 4]),
       causal=st.booleans(),
       blk=st.sampled_from([8, 16]),
       seed=st.integers(0, 2**16))
def test_block_diag_kernel_sweep(r, causal, blk, seed):
    bg, n, d = 2, 3 * blk, 16
    q, k, v = _inputs(jax.random.PRNGKey(seed), bg * r, bg, n, d, d, shift=0)
    out = block_diag_pallas(q, k, v, r=r, blk=blk, causal=causal,
                            interpret=True)
    ref = kref.block_diag_ref(q, k, v, block=blk, causal=causal, r=r)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


def test_fused_lln_diag_kernel():
    key = jax.random.PRNGKey(0)
    qs, ks, v = _inputs(key, 4, 2, 48, 16, 16)
    q, k, _ = _inputs(jax.random.PRNGKey(1), 4, 2, 48, 16, 16, shift=0)
    out = lln_diag_fused_pallas(qs, ks, q, k, v, r=2, blk=16, causal=True,
                                interpret=True)
    ref = kref.lln_diag_fused_ref(qs, ks, q, k, v, block=16, causal=True, r=2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


def test_fused_kernel_rejects_bidir():
    with pytest.raises(ValueError):
        lln_diag_fused_pallas(jnp.zeros((1, 16, 8)), jnp.zeros((1, 16, 8)),
                              jnp.zeros((1, 16, 8)), jnp.zeros((1, 16, 8)),
                              jnp.zeros((1, 16, 8)), causal=False)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernels_dtype(dtype):
    qs, ks, v = _inputs(jax.random.PRNGKey(2), 4, 2, 32, 16, 16, dtype=dtype)
    out = lln_causal_pallas(qs, ks, v, r=2, blk=16, interpret=True)
    ref = kref.lln_causal_ref(qs, ks, v, r=2)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=3e-2 if dtype == jnp.bfloat16 else 2e-4)


class TestPublicOps:
    def _model_inputs(self, key, b=2, n=32, h=4, g=2, d=16):
        kq, kk, kv = jax.random.split(key, 3)
        return (jax.random.normal(kq, (b, n, h, d)),
                jax.random.normal(kk, (b, n, g, d)),
                jax.random.normal(kv, (b, n, g, d)))

    def test_lln_attention_grads_match_ref(self):
        q, k, v = self._model_inputs(jax.random.PRNGKey(0))
        alpha = jnp.full((4,), 1.5)
        beta = jnp.full((2,), 1.2)
        from repro.core import lln_causal

        def loss_kernel(q, k, v):
            return jnp.sum(lln_attention(q, k, v, alpha, beta, True, 16) ** 2)

        def loss_ref(q, k, v):
            return jnp.sum(lln_causal(q, jnp.repeat(k, 2, 2),
                                      jnp.repeat(v, 2, 2), alpha,
                                      jnp.repeat(beta, 2), chunk=16) ** 2)

        gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=2e-3)

    def test_block_diag_attention_grad_finite(self):
        q, k, v = self._model_inputs(jax.random.PRNGKey(1))
        g = jax.grad(lambda q: jnp.sum(
            block_diag_attention(q, k, v, 16, True) ** 2))(q)
        assert np.all(np.isfinite(np.asarray(g)))

    def test_lln_diag_attention_matches_unfused(self):
        q, k, v = self._model_inputs(jax.random.PRNGKey(2))
        alpha = jnp.full((4,), 1.5)
        beta = jnp.full((2,), 1.2)
        fused = lln_diag_attention(q, k, v, alpha, beta, True, 16)
        lln = lln_attention(q, k, v, alpha, beta, True, 16)
        diag = block_diag_attention(q, k, v, 16, True)
        np.testing.assert_allclose(np.asarray(fused),
                                   np.asarray(0.5 * (lln + diag)), atol=1e-4)

    def test_unaligned_seq_falls_back(self):
        q, k, v = self._model_inputs(jax.random.PRNGKey(3), n=30)
        out = lln_attention(q, k, v, 1.0, 1.0, True, 16)
        assert out.shape == q.shape
        assert np.all(np.isfinite(np.asarray(out, np.float32)))


class TestPallasBackwardKernels:
    """Interpret-mode parity of the backward kernels vs the ref.py oracles
    (kernel layout, small blocks — fast unit coverage of the kernel math)."""

    def _inputs(self, seed, r, bg=2, nblk=3, blk=16, d=8, dv=8):
        n = nblk * blk
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        qs = jax.random.normal(ks[0], (bg * r, n, d)) - 0.5
        kk = jax.random.normal(ks[1], (bg, n, d)) - 0.5
        v = jax.random.normal(ks[2], (bg, n, dv))
        g = jax.random.normal(ks[3], (bg * r, n, dv))
        return qs, kk, v, g, blk

    @pytest.mark.parametrize("r", [1, 2, 4])
    def test_causal_bwd_kernel(self, r):
        qs, ks, v, g, blk = self._inputs(0, r)
        o, den = lln_causal_pallas(qs, ks, v, r=r, blk=blk, interpret=True,
                                   return_res=True)
        outs = lln_causal_bwd_pallas(qs, ks, v, g, o, den, r=r, blk=blk,
                                     interpret=True)
        refs = kref.lln_bwd_ref(qs, ks, v, g, o, den, causal=True, r=r)
        for a, b_ in zip(outs, refs):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=2e-4)

    @pytest.mark.parametrize("r", [1, 2])
    def test_bidir_bwd_kernel(self, r):
        qs, ks, v, g, blk = self._inputs(1, r)
        o, s, z, den = lln_bidir_pallas(qs, ks, v, r=r, blk=blk,
                                        interpret=True, return_res=True)
        outs = lln_bidir_bwd_pallas(qs, ks, v, g, o, den, s, z, r=r, blk=blk,
                                    interpret=True)
        refs = kref.lln_bwd_ref(qs, ks, v, g, o, den, causal=False, r=r)
        for a, b_ in zip(outs, refs):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=2e-4)

    @pytest.mark.parametrize("r", [1, 2])
    def test_fused_bwd_kernel(self, r):
        qs, ks, v, g, blk = self._inputs(2, r)
        q, k, _, _, _ = self._inputs(3, r)
        o, den = lln_diag_fused_pallas(qs, ks, q, k, v, r=r, blk=blk,
                                       causal=True, interpret=True,
                                       return_res=True)
        outs = lln_diag_fused_bwd_pallas(qs, ks, q, k, v, g, o, den, r=r,
                                         blk=blk, interpret=True)
        refs = kref.lln_diag_fused_bwd_ref(qs, ks, q, k, v, g, o, den,
                                           block=blk, r=r)
        for a, b_ in zip(outs, refs):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=2e-4)

    @pytest.mark.parametrize("causal", [False, True])
    def test_block_diag_bwd_kernel(self, causal):
        q, k, v, g, blk = self._inputs(4, 2)
        outs = block_diag_bwd_pallas(q, k, v, g, r=2, blk=blk, causal=causal,
                                     interpret=True)
        refs = kref.block_diag_bwd_ref(q, k, v, g, block=blk, causal=causal,
                                       r=2)
        for a, b_ in zip(outs, refs):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=2e-4)

    @pytest.mark.parametrize("r", [1, 2])
    def test_scan_twins_match_kernels(self, r):
        """The lax.scan twins (interpret-mode dispatch) produce the same
        gradients as the Pallas kernels for all four entry points."""
        qs, ks, v, g, blk = self._inputs(5, r)
        q, k, _, _, _ = self._inputs(6, r)
        o, den = lln_causal_pallas(qs, ks, v, r=r, blk=blk, interpret=True,
                                   return_res=True)
        for a, b_ in zip(
                lln_causal_bwd_scan(qs, ks, v, g, o, den, r=r, blk=blk),
                lln_causal_bwd_pallas(qs, ks, v, g, o, den, r=r, blk=blk,
                                      interpret=True)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=2e-5)
        o, s, z, den = lln_bidir_pallas(qs, ks, v, r=r, blk=blk,
                                        interpret=True, return_res=True)
        for a, b_ in zip(
                lln_bidir_bwd_scan(qs, ks, v, g, o, den, s, z, r=r, blk=blk),
                lln_bidir_bwd_pallas(qs, ks, v, g, o, den, s, z, r=r,
                                     blk=blk, interpret=True)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=2e-5)
        o, den = lln_diag_fused_pallas(qs, ks, q, k, v, r=r, blk=blk,
                                       causal=True, interpret=True,
                                       return_res=True)
        for a, b_ in zip(
                lln_diag_fused_bwd_scan(qs, ks, q, k, v, g, o, den, r=r,
                                        blk=blk),
                lln_diag_fused_bwd_pallas(qs, ks, q, k, v, g, o, den, r=r,
                                          blk=blk, interpret=True)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=2e-5)
        for a, b_ in zip(
                block_diag_bwd_scan(q, k, v, g, r=r, blk=blk, causal=True),
                block_diag_bwd_pallas(q, k, v, g, r=r, blk=blk, causal=True,
                                      interpret=True)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=2e-5)


class TestPallasVJPGradParity:
    """End-to-end gradients through the custom_vjp wrappers vs jax.vjp of
    the core/lln.py reference: causal/bidir/fused x GQA r in {1, 4} x
    N in {256, 512}, interpret mode, per-dtype tolerances."""

    CHUNK = 128

    def _model_inputs(self, seed, n, r, dtype=jnp.float32, b=1, g=1, d=16):
        h = g * r
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        return (jax.random.normal(ks[0], (b, n, h, d)).astype(dtype),
                jax.random.normal(ks[1], (b, n, g, d)).astype(dtype),
                jax.random.normal(ks[2], (b, n, g, d)).astype(dtype))

    def _ref_loss(self, mode, q, k, v, alpha, beta):
        from repro.core import lln_bidir, lln_causal
        from repro.core.diag import block_diag_attn
        h, g = q.shape[2], k.shape[2]
        kf = jnp.repeat(k, h // g, 2) if g != h else k
        vf = jnp.repeat(v, h // g, 2) if g != h else v
        beta_h = jnp.repeat(beta, h // g) if g != h else beta
        causal = mode in ("causal", "fused")
        if causal:
            out = lln_causal(q, kf, vf, alpha, beta_h, chunk=self.CHUNK)
        else:
            out = lln_bidir(q, kf, vf, alpha, beta_h)
        if mode in ("fused", "fused_bidir"):
            diag = block_diag_attn(q, kf, vf, block=self.CHUNK,
                                   causal=causal)
            out = 0.5 * (out.astype(jnp.float32) + diag.astype(jnp.float32))
        return jnp.sum(out.astype(jnp.float32) ** 2)

    def _kernel_loss(self, mode, q, k, v, alpha, beta):
        if mode in ("fused", "fused_bidir"):
            out = lln_diag_attention(q, k, v, alpha, beta, mode == "fused",
                                     self.CHUNK)
        else:
            out = lln_attention(q, k, v, alpha, beta, mode == "causal",
                                self.CHUNK)
        return jnp.sum(out.astype(jnp.float32) ** 2)

    @pytest.mark.parametrize("r", [1, 4])
    def test_noncausal_hybrid_grads_match_core_vjp(self, r):
        """The non-causal lln_diag backward branch (bidir LLN bwd + diag
        bwd on the halved cotangent, dv summed) in both dispatch variants."""
        from repro.kernels import ops as kops
        q, k, v = self._model_inputs(17, 256, r)
        alpha = jnp.full((q.shape[2],), 1.4)
        beta = jnp.full((k.shape[2],), 1.1)
        gr = jax.grad(lambda *a: self._ref_loss("fused_bidir", *a, alpha,
                                                beta),
                      argnums=(0, 1, 2))(q, k, v)
        for force in (False, True):
            kops.FORCE_KERNEL_BWD = force
            try:
                gk = jax.grad(lambda *a: self._kernel_loss(
                    "fused_bidir", *a, alpha, beta),
                    argnums=(0, 1, 2))(q, k, v)
            finally:
                kops.FORCE_KERNEL_BWD = False
            for a, b_, nm in zip(gk, gr, "qkv"):
                scale = max(1.0, float(jnp.max(jnp.abs(b_))))
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b_), atol=2e-3 * scale,
                    err_msg=f"d{nm} force_kernel={force}")

    @pytest.mark.parametrize("causal", [False, True])
    def test_block_diag_grads_match_ref_vjp(self, causal):
        """End-to-end dq/dk/dv value parity of block_diag_attention's
        Pallas backward wiring vs jax.vjp of the reference path."""
        q, k, v = self._model_inputs(19, 256, 2)
        gk = jax.grad(lambda q_, k_, v_: jnp.sum(block_diag_attention(
            q_, k_, v_, self.CHUNK, causal) ** 2),
            argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(lambda q_, k_, v_: jnp.sum(block_diag_attention(
            q_, k_, v_, self.CHUNK, causal, None, False) ** 2),
            argnums=(0, 1, 2))(q, k, v)
        for a, b_, nm in zip(gk, gr, "qkv"):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=2e-3, err_msg=f"d{nm}")

    @pytest.mark.parametrize("n", [256, 512])
    @pytest.mark.parametrize("r", [1, 4])
    @pytest.mark.parametrize("mode", ["causal", "bidir", "fused"])
    def test_grads_match_core_vjp_fp32(self, mode, r, n):
        q, k, v = self._model_inputs(7, n, r)
        alpha = jnp.full((q.shape[2],), 1.4)
        beta = jnp.full((k.shape[2],), 1.1)
        gk = jax.grad(lambda *a: self._kernel_loss(mode, *a, alpha, beta),
                      argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(lambda *a: self._ref_loss(mode, *a, alpha, beta),
                      argnums=(0, 1, 2))(q, k, v)
        for a, b_, nm in zip(gk, gr, "qkv"):
            scale = max(1.0, float(jnp.max(jnp.abs(b_))))
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=2e-3 * scale, err_msg=f"d{nm}")

    @pytest.mark.parametrize("mode", ["causal", "bidir", "fused"])
    def test_grads_match_core_vjp_bf16(self, mode):
        q, k, v = self._model_inputs(9, 256, 4, dtype=jnp.bfloat16)
        alpha = jnp.full((q.shape[2],), 1.4)
        beta = jnp.full((k.shape[2],), 1.1)
        gk = jax.grad(lambda *a: self._kernel_loss(mode, *a, alpha, beta),
                      argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(lambda *a: self._ref_loss(mode, *a, alpha, beta),
                      argnums=(0, 1, 2))(q, k, v)
        for a, b_, nm in zip(gk, gr, "qkv"):
            assert a.dtype == jnp.bfloat16
            af = np.asarray(a, np.float32)
            bf = np.asarray(b_, np.float32)
            scale = max(1.0, float(np.abs(bf).max()))
            np.testing.assert_allclose(af, bf, atol=8e-2 * scale,
                                       err_msg=f"d{nm}")

    @pytest.mark.parametrize("mode", ["causal", "bidir", "fused"])
    def test_kernel_bwd_path_matches_core_vjp(self, mode, monkeypatch):
        """Force the Pallas kernel backward (instead of the scan twins the
        CPU container dispatches to) through the full custom_vjp chain."""
        from repro.kernels import ops as kops
        monkeypatch.setattr(kops, "FORCE_KERNEL_BWD", True)
        q, k, v = self._model_inputs(15, 256, 4)
        alpha = jnp.full((q.shape[2],), 1.4)
        beta = jnp.full((k.shape[2],), 1.1)
        gk = jax.grad(lambda *a: self._kernel_loss(mode, *a, alpha, beta),
                      argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(lambda *a: self._ref_loss(mode, *a, alpha, beta),
                      argnums=(0, 1, 2))(q, k, v)
        for a, b_, nm in zip(gk, gr, "qkv"):
            scale = max(1.0, float(jnp.max(jnp.abs(b_))))
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=2e-3 * scale, err_msg=f"d{nm}")

    def test_grads_match_analytic_oracle(self):
        from repro.core.lln import lln_grads
        q, k, v = self._model_inputs(11, 256, 1, g=2)
        alpha = jnp.full((2,), 1.4)
        beta = jnp.full((2,), 1.1)
        out, vjp = jax.vjp(
            lambda q_, k_, v_: lln_attention(q_, k_, v_, alpha, beta, True,
                                             self.CHUNK), q, k, v)
        g = jnp.ones_like(out)
        dq, dk, dv = vjp(g)
        aq, ak, av = lln_grads(q, k, v, alpha, beta, g, causal=True)
        for a, b_ in ((dq, aq), (dk, ak), (dv, av)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=2e-3)

    def test_ragged_fallback_keeps_v_dtype(self):
        # Regression: the n % chunk fallback used to return fp32 while the
        # Pallas path returned v.dtype, recompiling jit'd callers per length.
        q, k, v = self._model_inputs(13, 48, 2, dtype=jnp.bfloat16)
        alpha, beta = 1.0, 1.0
        for n in (48, 30):   # aligned (pallas) and ragged (jnp fallback)
            out = lln_attention(q[:, :n], k[:, :n], v[:, :n], alpha, beta,
                                True, 16)
            assert out.dtype == jnp.bfloat16, n
            fused = lln_diag_attention(q[:, :n], k[:, :n], v[:, :n], alpha,
                                       beta, True, 16)
            assert fused.dtype == jnp.bfloat16, n
            diag = block_diag_attention(q[:, :n], k[:, :n], v[:, :n], 16,
                                        True)
            assert diag.dtype == jnp.bfloat16, n


class TestSSDKernel:
    def _inputs(self, key, b=2, l=48, h=4, g=2, p=8, s=4):
        ks = jax.random.split(key, 4)
        xbar = jax.random.normal(ks[0], (b, l, h, p))
        b_in = jax.random.normal(ks[1], (b, l, g, s))
        c_in = jax.random.normal(ks[2], (b, l, g, s))
        log_a = -jax.nn.softplus(jax.random.normal(ks[3], (b, l, h)))
        return xbar, b_in, c_in, log_a

    @settings(max_examples=8, deadline=None)
    @given(g=st.sampled_from([1, 2, 4]), nblk=st.integers(1, 3),
           seed=st.integers(0, 2**16))
    def test_ssd_kernel_sweep(self, g, nblk, seed):
        from repro.kernels import ssd_scan
        from repro.models.ssm import ssd_chunked
        xbar, b_in, c_in, log_a = self._inputs(
            jax.random.PRNGKey(seed), l=nblk * 16, g=g)
        y = ssd_scan(xbar, b_in, c_in, log_a, 16)
        rep = 4 // g
        bf = jnp.repeat(b_in, rep, 2)
        cf = jnp.repeat(c_in, rep, 2)
        y_ref, _ = ssd_chunked(xbar, bf, cf, log_a, chunk=16)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=3e-4)

    def test_ssd_kernel_grads(self):
        from repro.kernels import ssd_scan
        from repro.models.ssm import ssd_chunked
        xbar, b_in, c_in, log_a = self._inputs(jax.random.PRNGKey(0))
        bf = jnp.repeat(b_in, 2, 2)
        cf = jnp.repeat(c_in, 2, 2)
        gk = jax.grad(lambda x, a: jnp.sum(
            ssd_scan(x, b_in, c_in, a, 16) ** 2), argnums=(0, 1))(
                xbar, log_a)
        gr = jax.grad(lambda x, a: jnp.sum(
            ssd_chunked(x, bf, cf, a, chunk=16)[0] ** 2), argnums=(0, 1))(
                xbar, log_a)
        for a, b_ in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=3e-3)

    def test_mamba_block_with_kernel_matches_jnp(self):
        from repro.configs import get_config
        from repro.models.ssm import ssm_apply, ssm_init
        cfg = get_config("mamba2-130m", smoke=True).replace(
            compute_dtype="float32", ssm_chunk=16)
        p = ssm_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
        y_jnp = ssm_apply(p, x, cfg)
        y_k = ssm_apply(p, x, cfg.replace(use_kernel=True))
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_jnp),
                                   atol=1e-4)
