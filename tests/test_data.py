"""Data pipeline: determinism, host disjointness, prefetch, learnability."""
import numpy as np

from repro.data.pipeline import HostShardedSource, Prefetcher
from repro.data.synthetic import MarkovCorpus, lm_batches, mlm_batches


def test_markov_determinism():
    c = MarkovCorpus(vocab=64, seed=3)
    rng1 = np.random.default_rng(0)
    rng2 = np.random.default_rng(0)
    a = c.sample(rng1, 4, 32)
    b = c.sample(rng2, 4, 32)
    np.testing.assert_array_equal(a, b)


def test_markov_is_learnable():
    """Bigram conditional entropy well below log2(V): a model CAN learn it
    (the Fig-8a convergence benchmark depends on this)."""
    c = MarkovCorpus(vocab=64, seed=0, branching=8)
    toks = c.sample(np.random.default_rng(0), 64, 256).reshape(-1)
    joint = np.zeros((64, 64))
    for a, b in zip(toks[:-1], toks[1:]):
        joint[a, b] += 1
    pj = joint / joint.sum()
    pa = pj.sum(1, keepdims=True)
    cond = pj / np.maximum(pa, 1e-12)
    h = -np.sum(pj * np.log2(np.maximum(cond, 1e-12)))
    assert h < 0.7 * np.log2(64)


def test_lm_batches_shift():
    b = next(lm_batches(64, 2, 16, seed=1))
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["targets"][:, :-1])
    assert b["mask"].shape == (2, 16)


def test_mlm_batches():
    b = next(mlm_batches(64, 4, 64, seed=1))
    masked = b["mask"] > 0
    assert 0.05 < masked.mean() < 0.3
    # unmasked positions keep original tokens
    keep = ~masked
    np.testing.assert_array_equal(b["inputs"][keep], b["targets"][keep])


def test_host_sharding_disjoint():
    def gen(batch, seed):
        return lm_batches(64, batch, 8, seed=seed)
    s0 = HostShardedSource(gen, 8, process_index=0, process_count=2)
    s1 = HostShardedSource(gen, 8, process_index=1, process_count=2)
    b0, b1 = next(s0), next(s1)
    assert b0["inputs"].shape[0] == 4
    assert not np.array_equal(b0["inputs"], b1["inputs"])


def test_prefetcher_order_and_close():
    src = iter([{"x": np.full((2,), i)} for i in range(5)])
    pf = Prefetcher(src, depth=2)
    got = [next(pf)["x"][0] for _ in range(5)]
    assert got == [0, 1, 2, 3, 4]
    pf.close()


def test_prefetcher_propagates_errors():
    def bad():
        yield {"x": np.zeros(1)}
        raise RuntimeError("boom")
    pf = Prefetcher(bad(), depth=1)
    next(pf)
    try:
        next(pf)
        assert False, "should raise"
    except RuntimeError:
        pass
