"""Smoke test: the train-step benchmark runs end-to-end (interpret mode)."""
import json

from benchmarks.bench_train_step import IMPLS, run


def test_bench_train_step_smoke(tmp_path):
    out = tmp_path / "BENCH_train_step.json"
    report = run(str(out), smoke=True, repeats=1, verbose=False)
    assert out.exists()
    on_disk = json.loads(out.read_text())
    assert on_disk["modes"].keys() == {"jnp_fallback", "pallas_vjp"}
    assert len(on_disk["results"]) == len(report["results"]) == 1
    row = on_disk["results"][0]
    for impl in IMPLS:
        entry = row[impl]
        assert entry["fwd_us"] > 0
        assert entry["fwd_bwd_us"]["jnp_fallback"] > 0
        assert entry["fwd_bwd_us"]["pallas_vjp"] > 0
        assert entry["bwd_speedup"] > 0
