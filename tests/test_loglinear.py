"""Log-linear (Fenwick multi-scale) attention state: core + ops + engine.

Covers the ``log_linear`` impl end to end against its quadratic oracle
(:func:`repro.core.loglinear.loglin_attention_ref`):

* layout unit tests — ``occupancy`` is a saturating binary counter,
  ``level_matrix`` matches a python Fenwick walk;
* exact reductions — ``scale_decay=1`` and ``num_scales=1`` reproduce
  plain LLN attention bit-for-tolerance;
* backend parity (pallas / scan / ref × GQA, fp32 tight + bf16 loose);
* the serving lifecycle: prefill+decode == oracle, chunked == sequential
  decode, ``commit_chunk`` bitwise == ``verify``'s fold, ``row_mask``
  rows bitwise inert, per-bucket ``renorm`` semantics-preserving, and
  ``evict`` resetting the bucket pyramid;
* the hybrid satellite regression: masked hybrid-model rows leave every
  cache leaf (SSM state, conv window, attention pyramid) bitwise
  unchanged.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import loglinear as core_loglin
from repro.core.engine import AttentionEngine, AttnSpec
from repro.kernels import ops as kops

B, H, G, D = 2, 4, 2, 8
CH, L = 8, 3          # granule, num_scales
DECAY = 0.5


def _qkv(n, seed=0, t_heads=H, kv=G, d=D, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, n, t_heads, d)), dtype) * 0.5
    k = jnp.asarray(rng.normal(size=(B, n, kv, d)), dtype) * 0.5
    v = jnp.asarray(rng.normal(size=(B, n, kv, d)), dtype)
    alpha = jnp.asarray(rng.uniform(0.8, 1.2, size=(t_heads,)), jnp.float32)
    beta = jnp.asarray(rng.uniform(0.8, 1.2, size=(kv,)), jnp.float32)
    return q, k, v, alpha, beta


def _rep(x, r):
    return x if r == 1 else jnp.repeat(x, r, axis=2)


def _spec(backend, r, **kw):
    kw.setdefault("lln_chunk", CH)
    kw.setdefault("num_scales", L)
    kw.setdefault("scale_decay", DECAY)
    return AttnSpec(impl="log_linear", causal=True, r=r, backend=backend,
                    **kw)


class TestLayout:
    def test_occupancy_binary_counter(self):
        """occupancy(n) is n in binary with a saturating top level."""
        for n in range(0, 40):
            occ = np.asarray(core_loglin.occupancy(jnp.int32(n), L))
            top = 2 ** (L - 1)
            want = [float((n >> l) & 1) for l in range(L - 1)]
            want.append(float(n >= top))
            assert occ.tolist() == want, (n, occ)

    def test_occupancy_single_scale(self):
        occ = np.asarray(core_loglin.occupancy(jnp.asarray([0, 1, 7]), 1))
        assert occ.tolist() == [[0.0], [1.0], [1.0]]

    def test_level_matrix_fenwick_walk(self):
        """Each key granule's level matches a python binary-counter walk."""
        n, g, ls = 64, 8, 3
        lev = np.asarray(core_loglin.level_matrix(n, granule=g,
                                                  num_scales=ls))
        for t in range(n):
            nq = t // g
            # walk: which level does closed granule j live at, given nq?
            top_count = nq - (nq & ((1 << (ls - 1)) - 1))
            for j in range(t + 1):
                gj = j // g
                if gj == nq:
                    want = 0                       # intra / open bucket
                elif gj < top_count:
                    want = ls - 1
                else:
                    want = None
                    for l in range(ls - 1):
                        hi = (nq >> (l + 1)) << (l + 1)
                        if ((nq >> l) & 1) and hi <= gj < hi + (1 << l):
                            want = l
                            break
                    assert want is not None, (t, j)
                assert lev[t, j] == want, (t, j, lev[t, j], want)

    def test_level_weights(self):
        w = np.asarray(core_loglin.level_weights(4, 0.5))
        np.testing.assert_allclose(w, [1.0, 0.5, 0.25, 0.125])


class TestReductions:
    """scale_decay=1 / num_scales=1 reduce EXACTLY to plain LLN."""

    @pytest.mark.parametrize("ls,decay", [(L, 1.0), (1, DECAY)],
                             ids=["decay1", "scales1"])
    def test_reduces_to_lln(self, ls, decay):
        q, k, v, alpha, beta = _qkv(48, seed=3)
        kf, vf = _rep(k, H // G), _rep(v, H // G)
        beta_h = jnp.repeat(beta, H // G)
        ref = kops.lln_attention(q, kf, vf, alpha, beta_h, True, CH,
                                 backend="ref")
        got = core_loglin.loglin_attention_ref(
            q, kf, vf, alpha, beta_h, granule=CH, num_scales=ls,
            scale_decay=decay)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)


class TestBackendParity:
    def test_attention_matches_oracle(self, lln_parity_cell):
        backend, impl, r = lln_parity_cell
        if impl != "log_linear":
            pytest.skip("log_linear-only module")
        n = 48
        q, k, v, alpha, beta = _qkv(n, seed=1, kv=H // r)
        kf, vf = _rep(k, r), _rep(v, r)
        beta_h = jnp.repeat(beta, r) if r > 1 else beta
        want = core_loglin.loglin_attention_ref(
            q, kf, vf, alpha, beta_h, granule=CH, num_scales=L,
            scale_decay=DECAY)
        got = kops.loglin_attention(q, k, v, alpha, beta, True, CH,
                                    num_scales=L, scale_decay=DECAY,
                                    backend=backend)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=5e-5, rtol=5e-5)

    def test_attention_bf16(self):
        n = 32
        q, k, v, alpha, beta = _qkv(n, seed=2, dtype=jnp.bfloat16)
        kf, vf = _rep(k, H // G), _rep(v, H // G)
        beta_h = jnp.repeat(beta, H // G)
        want = core_loglin.loglin_attention_ref(
            q.astype(jnp.float32), kf.astype(jnp.float32),
            vf.astype(jnp.float32), alpha, beta_h, granule=CH,
            num_scales=L, scale_decay=DECAY)
        got = kops.loglin_attention(q, k, v, alpha, beta, True, CH,
                                    num_scales=L, scale_decay=DECAY,
                                    backend="pallas")
        assert got.dtype == v.dtype
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want), atol=5e-2, rtol=5e-2)

    def test_non_causal_raises(self):
        q, k, v, alpha, beta = _qkv(16)
        with pytest.raises(ValueError, match="causal"):
            kops.loglin_attention(q, k, v, alpha, beta, False, CH)


class TestLifecycle:
    """Engine-level serving lifecycle on every backend × GQA cell."""

    def _engine(self, backend, r):
        spec = _spec(backend, r)
        return AttentionEngine(spec=spec, heads=H, kv_heads=H // r,
                               head_dim=D, v_dim=D), spec

    def test_prefill_decode_matches_oracle(self, backend_gqa_cell):
        backend, r = backend_gqa_cell
        eng, _ = self._engine(backend, r)
        n, t = 32, 5
        q, k, v, alpha, beta = _qkv(n + t, seed=4, kv=H // r)
        out_p, st = eng.prefill(q[:, :n], k[:, :n], v[:, :n],
                                max_len=4096, alpha=alpha, beta=beta)
        out_d, st2 = eng.decode(st, q[:, n:], k[:, n:], v[:, n:])
        kf, vf = _rep(k, r), _rep(v, r)
        beta_h = jnp.repeat(beta, r) if r > 1 else beta
        want = core_loglin.loglin_attention_ref(
            q, kf, vf, alpha, beta_h, granule=CH, num_scales=L,
            scale_decay=DECAY)
        np.testing.assert_allclose(np.asarray(out_p),
                                   np.asarray(want[:, :n]),
                                   atol=5e-5, rtol=5e-5)
        np.testing.assert_allclose(np.asarray(out_d),
                                   np.asarray(want[:, n:]),
                                   atol=5e-5, rtol=5e-5)
        assert np.asarray(st2.pos).tolist() == [n + t] * B

    def test_chunked_equals_sequential(self, backend_gqa_cell):
        backend, r = backend_gqa_cell
        eng, _ = self._engine(backend, r)
        n, t = 16, 8     # chunk crosses a granule boundary mid-stream
        q, k, v, alpha, beta = _qkv(n + t, seed=5, kv=H // r)
        _, st = eng.prefill(q[:, :n], k[:, :n], v[:, :n], max_len=4096,
                            alpha=alpha, beta=beta)
        out_c, st_c = eng.decode(st, q[:, n:], k[:, n:], v[:, n:])
        outs, s = [], st
        for i in range(n, n + t):
            o, s = eng.decode(s, q[:, i:i + 1], k[:, i:i + 1],
                              v[:, i:i + 1])
            outs.append(o)
        np.testing.assert_allclose(np.asarray(out_c),
                                   np.asarray(jnp.concatenate(outs, 1)),
                                   atol=5e-5, rtol=5e-5)
        for f in ("s", "z", "sl", "zl", "pos"):
            np.testing.assert_allclose(np.asarray(getattr(st_c, f)),
                                       np.asarray(getattr(s, f)),
                                       atol=5e-5, rtol=5e-5, err_msg=f)

    def test_commit_bitwise_equals_verify(self, backend_gqa_cell):
        backend, r = backend_gqa_cell
        eng, _ = self._engine(backend, r)
        n, t = 24, 6
        q, k, v, alpha, beta = _qkv(n + t, seed=6, kv=H // r)
        _, st = eng.prefill(q[:, :n], k[:, :n], v[:, :n], max_len=4096,
                            alpha=alpha, beta=beta)
        cl = jnp.asarray([2, 6], jnp.int32)
        # verify with commit_len=0 must be a bitwise no-op on the state
        _, st0, res = eng.verify(st, q[:, n:], k[:, n:], v[:, n:],
                                 commit_len=jnp.zeros((B,), jnp.int32),
                                 return_residuals=True)
        for f in ("s", "z", "c_k", "sl", "zl", "cl", "pos"):
            assert (np.asarray(getattr(st0, f))
                    == np.asarray(getattr(st, f))).all(), f
        _, st_v = eng.verify(st, q[:, n:], k[:, n:], v[:, n:],
                             commit_len=cl)
        st_c = eng.commit(st, res, commit_len=cl)
        for f in ("s", "z", "c_k", "sl", "zl", "cl", "pos"):
            assert (np.asarray(getattr(st_c, f))
                    == np.asarray(getattr(st_v, f))).all(), f
        assert np.asarray(st_c.pos).tolist() == [n + 2, n + 6]

    def test_row_mask_bitwise_inert(self, backend_gqa_cell):
        backend, r = backend_gqa_cell
        eng, _ = self._engine(backend, r)
        n, t = 24, 4
        q, k, v, alpha, beta = _qkv(n + t, seed=7, kv=H // r)
        _, st = eng.prefill(q[:, :n], k[:, :n], v[:, :n], max_len=4096,
                            alpha=alpha, beta=beta)
        rm = jnp.asarray([True, False])
        _, st_m = eng.decode(st, q[:, n:], k[:, n:], v[:, n:],
                             row_mask=rm)
        for f in ("s", "z", "c_k", "sl", "zl", "cl", "pos", "log_scale"):
            a = np.asarray(getattr(st_m, f))
            b = np.asarray(getattr(st, f))
            assert (a[1] == b[1]).all(), f"masked row moved {f}"

    def test_evict_resets_pyramid(self):
        eng, _ = self._engine("scan", 2)
        n = 24
        q, k, v, alpha, beta = _qkv(n, seed=8, kv=H // 2)
        _, st = eng.prefill(q, k, v, max_len=4096, alpha=alpha, beta=beta)
        assert float(np.abs(np.asarray(st.sl)).max()) > 0
        st_e = eng.evict(st, jnp.asarray([0], jnp.int32))
        for f in ("s", "z", "c_k", "sl", "zl", "cl", "log_scale"):
            assert float(np.abs(np.asarray(getattr(st_e, f))[0]).max()) \
                == 0.0, f
        assert int(st_e.pos[0]) == 0
        # the untouched row keeps its pyramid bitwise
        assert (np.asarray(st_e.sl[1]) == np.asarray(st.sl[1])).all()

    def test_per_row_positions(self):
        """Rows at different depths use different bucket layouts; a pooled
        decode step must match each row's solo decode."""
        eng, _ = self._engine("scan", 2)
        n0, n1, t = 16, 24, 4
        q, k, v, alpha, beta = _qkv(n1 + t, seed=9, kv=H // 2)
        _, st_a = eng.prefill(q[:, :n0], k[:, :n0], v[:, :n0],
                              max_len=4096, alpha=alpha, beta=beta)
        _, st_b = eng.prefill(q[:, :n1], k[:, :n1], v[:, :n1],
                              max_len=4096, alpha=alpha, beta=beta)
        # pooled state: row 0 at depth n0, row 1 at depth n1
        st = st_a.replace(
            **{f: jnp.concatenate([getattr(st_a, f)[:1],
                                   getattr(st_b, f)[1:]], 0)
               for f in ("s", "z", "c_k", "sl", "zl", "cl", "pos",
                         "alpha", "beta", "log_scale")})
        q2 = jnp.concatenate([q[:1, n0:n0 + t], q[1:, n1:n1 + t]], 0)
        k2 = jnp.concatenate([k[:1, n0:n0 + t], k[1:, n1:n1 + t]], 0)
        v2 = jnp.concatenate([v[:1, n0:n0 + t], v[1:, n1:n1 + t]], 0)
        out, st2 = eng.decode(st, q2, k2, v2)
        o_a, _ = eng.decode(st_a, q[:, n0:n0 + t], k[:, n0:n0 + t],
                            v[:, n0:n0 + t])
        o_b, _ = eng.decode(st_b, q[:, n1:n1 + t], k[:, n1:n1 + t],
                            v[:, n1:n1 + t])
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(o_a[0]),
                                   atol=5e-5, rtol=5e-5)
        np.testing.assert_allclose(np.asarray(out[1]), np.asarray(o_b[1]),
                                   atol=5e-5, rtol=5e-5)
        assert np.asarray(st2.pos).tolist() == [n0 + t, n1 + t]

    def test_renorm_semantics_preserving(self):
        """The per-bucket drift guard changes carried magnitudes, not
        outputs."""
        n, t = 24, 4
        q, k, v, alpha, beta = _qkv(n + t, seed=10, kv=H // 2)
        outs = {}
        for renorm in (0.0, 1.0):
            spec = _spec("scan", 2, renorm=renorm)
            eng = AttentionEngine(spec=spec, heads=H, kv_heads=G,
                                  head_dim=D, v_dim=D)
            _, st = eng.prefill(q[:, :n], k[:, :n], v[:, :n],
                                max_len=4096, alpha=alpha, beta=beta)
            o1, st = eng.decode(st, q[:, n:], k[:, n:], v[:, n:])
            outs[renorm] = o1
        np.testing.assert_allclose(np.asarray(outs[0.0]),
                                   np.asarray(outs[1.0]),
                                   atol=5e-5, rtol=5e-5)


class TestHybridMaskedRows:
    """ISSUE regression: masked hybrid rows are bitwise-unchanged across
    EVERY cache leaf — SSM recurrent state, conv windows, and the shared
    block's log_linear pyramid."""

    def _cfg(self):
        from repro.configs.base import ArchConfig
        return ArchConfig(
            name="hybrid-mask", family="hybrid", n_layers=4, d_model=32,
            n_heads=2, n_kv_heads=2, d_ff=64, vocab=64, head_dim=16,
            attn_impl="log_linear", lln_chunk=8, lln_fixed_ab=2.1,
            lln_num_scales=3, ssm_state=8, ssm_expand=2, ssm_head_dim=16,
            ssm_groups=1, conv_width=4, shared_attn_period=2,
            compute_dtype="float32", param_dtype="float32", remat="none",
            tie_embeddings=True)

    def test_masked_rows_bitwise_unchanged(self):
        from repro.models import hybrid as hy
        cfg = self._cfg()
        p = hy.hybrid_init(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (3, 6), 0,
                                  cfg.vocab)
        _, caches = hy.hybrid_prefill(p, toks, cfg, 32)
        nxt = jax.random.randint(jax.random.PRNGKey(2), (3,), 0, cfg.vocab)
        rm = jnp.asarray([True, False, True])
        _, cm = hy.hybrid_decode(p, caches, nxt, cfg,
                                 jnp.asarray(6, jnp.int32), row_mask=rm)
        old = jax.tree_util.tree_leaves(caches)
        new = jax.tree_util.tree_leaves(cm)
        assert len(old) == len(new)
        for a, b in zip(old, new):
            aa, bb = np.asarray(a), np.asarray(b)
            assert aa.shape == bb.shape
            # batch is axis 1 on every hybrid cache leaf (layer/group
            # stacking is axis 0)
            assert (aa[:, 1] == bb[:, 1]).all(), aa.shape

    def test_ssm_chunked_decode_partial_commit(self):
        """ssm_decode_chunk folds exactly the accepted prefix."""
        from repro.configs.base import ArchConfig
        from repro.models.ssm import (ssm_cache_init, ssm_decode,
                                      ssm_decode_chunk, ssm_init)
        cfg = self._cfg()
        p = ssm_init(jax.random.PRNGKey(3), cfg)
        bsz, t = 3, 5
        x = jax.random.normal(jax.random.PRNGKey(4),
                              (bsz, t, cfg.d_model)) * 0.5
        cache = ssm_cache_init(cfg, bsz)
        for i in range(2):       # warm with non-trivial state
            w = jax.random.normal(jax.random.PRNGKey(5 + i),
                                  (bsz, 1, cfg.d_model)) * 0.5
            _, cache = ssm_decode(p, w, cache, cfg)
        cl = jnp.asarray([2, 0, 5], jnp.int32)
        _, cp = ssm_decode_chunk(p, x, cache, cfg, commit_len=cl)
        for b, nacc in enumerate([2, 0, 5]):
            cb = jax.tree_util.tree_map(lambda a: a[b:b + 1], cache)
            for i in range(nacc):
                _, cb = ssm_decode(p, x[b:b + 1, i:i + 1], cb, cfg)
            np.testing.assert_allclose(np.asarray(cp["state"][b]),
                                       np.asarray(cb["state"][0]),
                                       atol=5e-5, rtol=5e-5)
            np.testing.assert_allclose(
                np.asarray(cp["conv"][b], np.float32),
                np.asarray(cb["conv"][0], np.float32),
                atol=5e-5, rtol=5e-5)
