"""Example scripts run end-to-end at smoke sizes.

The examples are the repo's runnable documentation — they rot the same
way docs do.  Each test loads the script as a module (no subprocess: the
failure shows a real traceback) and drives ``main`` at the smallest
parameterization that still exercises the full pipeline.
"""
import importlib.util
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def _load(name: str):
    path = ROOT / "examples" / f"{name}.py"
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_long_context_serving_smoke(capsys):
    rows = _load("long_context_serving").main(prompts=(32, 64), steps=2)
    out = capsys.readouterr().out
    assert "cache growth" in out
    # Both impls ran both prompt lengths; the LLN state did not grow
    # with context while the softmax cache did.
    sm = [r for r in rows if r[0] == "softmax"]
    ln = [r for r in rows if r[0] == "lln_diag"]
    assert len(sm) == len(ln) == 2
    assert sm[-1][2] > sm[0][2]
    assert abs(ln[-1][2] - ln[0][2]) / ln[0][2] < 0.05


def test_concentration_analysis_smoke(capsys):
    _load("concentration_analysis").main(steps=2)
    out = capsys.readouterr().out
    assert "spec_gap" in out
    assert "moment match" in out
    assert "log-normality" in out
