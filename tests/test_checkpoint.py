"""Checkpointing: atomic roundtrip, CRC corruption detection, keep-N GC,
async writer, resume semantics, elastic resharding."""
import glob
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import (AsyncCheckpointer,
                                           committed_steps, restore, save)
from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros((8,))},
            "opt": {"m": {"w": jnp.ones((8, 8)), "b": jnp.ones((8,))},
                    "step": jnp.asarray(7, jnp.int32)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 5, t)
    template = jax.tree_util.tree_map(jnp.zeros_like, t)
    out = restore(str(tmp_path), 5, template)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(t)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_atomicity_ignores_uncommitted(tmp_path):
    t = _tree()
    save(str(tmp_path), 1, t)
    # simulate a crashed write: committed sentinel missing
    os.makedirs(tmp_path / "step_00000002")
    assert committed_steps(str(tmp_path)) == [1]


def test_crc_corruption_detection(tmp_path):
    t = _tree()
    save(str(tmp_path), 3, t)
    idx = tmp_path / "step_00000003" / "index.json"
    meta = json.loads(idx.read_text())
    first = next(iter(meta["leaves"]))
    meta["leaves"][first]["crc"] ^= 0xFF
    idx.write_text(json.dumps(meta))
    with pytest.raises(IOError):
        restore(str(tmp_path), 3, jax.tree_util.tree_map(jnp.zeros_like, t))


def test_async_and_gc(tmp_path):
    ckpt = AsyncCheckpointer(str(tmp_path), keep_n=2)
    for s in (10, 20, 30, 40):
        ckpt.save_async(s, _tree(s))
    ckpt.wait()
    assert committed_steps(str(tmp_path)) == [30, 40]


def test_manager_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path), interval=2, keep_n=3)
    state, start = mgr.restore_or_init(lambda: _tree(1))
    assert start == 0
    mgr.maybe_save(2, state)
    mgr.async_ckpt.wait()
    mgr2 = CheckpointManager(str(tmp_path), interval=2)
    state2, start2 = mgr2.restore_or_init(lambda: _tree(99))
    assert start2 == 2
    for a, b in zip(jax.tree_util.tree_leaves(state2),
                    jax.tree_util.tree_leaves(state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_shape_mismatch_raises(tmp_path):
    save(str(tmp_path), 1, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        restore(str(tmp_path), 1, {"w": jnp.zeros((8, 8))})


def test_missing_leaf_raises(tmp_path):
    save(str(tmp_path), 1, {"w": jnp.zeros((4,))})
    with pytest.raises(KeyError):
        restore(str(tmp_path), 1, {"w": jnp.zeros((4,)),
                                   "extra": jnp.zeros((2,))})
