"""Checkpointing: atomic roundtrip, CRC corruption detection, keep-N GC,
async writer, resume semantics, elastic resharding, truncation manifests."""
import glob
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import (AsyncCheckpointer,
                                           committed_steps, is_valid,
                                           read_extra, restore, save,
                                           valid_steps)
from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros((8,))},
            "opt": {"m": {"w": jnp.ones((8, 8)), "b": jnp.ones((8,))},
                    "step": jnp.asarray(7, jnp.int32)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 5, t)
    template = jax.tree_util.tree_map(jnp.zeros_like, t)
    out = restore(str(tmp_path), 5, template)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(t)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_atomicity_ignores_uncommitted(tmp_path):
    t = _tree()
    save(str(tmp_path), 1, t)
    # simulate a crashed write: committed sentinel missing
    os.makedirs(tmp_path / "step_00000002")
    assert committed_steps(str(tmp_path)) == [1]


def test_crc_corruption_detection(tmp_path):
    t = _tree()
    save(str(tmp_path), 3, t)
    idx = tmp_path / "step_00000003" / "index.json"
    meta = json.loads(idx.read_text())
    first = next(iter(meta["leaves"]))
    meta["leaves"][first]["crc"] ^= 0xFF
    idx.write_text(json.dumps(meta))
    with pytest.raises(IOError):
        restore(str(tmp_path), 3, jax.tree_util.tree_map(jnp.zeros_like, t))


def test_async_and_gc(tmp_path):
    ckpt = AsyncCheckpointer(str(tmp_path), keep_n=2)
    for s in (10, 20, 30, 40):
        ckpt.save_async(s, _tree(s))
    ckpt.wait()
    assert committed_steps(str(tmp_path)) == [30, 40]


def test_manager_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path), interval=2, keep_n=3)
    state, start = mgr.restore_or_init(lambda: _tree(1))
    assert start == 0
    mgr.maybe_save(2, state)
    mgr.async_ckpt.wait()
    mgr2 = CheckpointManager(str(tmp_path), interval=2)
    state2, start2 = mgr2.restore_or_init(lambda: _tree(99))
    assert start2 == 2
    for a, b in zip(jax.tree_util.tree_leaves(state2),
                    jax.tree_util.tree_leaves(state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_shape_mismatch_raises(tmp_path):
    save(str(tmp_path), 1, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        restore(str(tmp_path), 1, {"w": jnp.zeros((8, 8))})


def test_missing_leaf_raises(tmp_path):
    save(str(tmp_path), 1, {"w": jnp.zeros((4,))})
    with pytest.raises(KeyError):
        restore(str(tmp_path), 1, {"w": jnp.zeros((4,)),
                                   "extra": jnp.zeros((2,))})


def test_truncated_checkpoint_skipped_and_gced(tmp_path):
    """Regression: a committed-but-truncated step (crash between the shard
    write and the sentinel landing on old kernels, or disk-full
    truncation) must never become ``latest_step`` — the size manifest in
    ``_COMMITTED`` catches it, and the corrupt dir is GC'd so it cannot
    shadow the older restorable step."""
    mgr = CheckpointManager(str(tmp_path), interval=1)
    save(str(tmp_path), 1, _tree(1))
    save(str(tmp_path), 2, _tree(2))
    shard = tmp_path / "step_00000002" / "shard_0.npz"
    data = shard.read_bytes()
    shard.write_bytes(data[: len(data) // 2])
    assert committed_steps(str(tmp_path)) == [1, 2]  # sentinel-only view
    assert valid_steps(str(tmp_path)) == [1]         # manifest view
    assert mgr.latest_step() == 1
    assert not (tmp_path / "step_00000002").exists()  # corrupt dir GC'd
    state, start = mgr.restore_or_init(lambda: _tree(0))
    assert start == 1
    np.testing.assert_allclose(np.asarray(state["params"]["w"]),
                               np.asarray(_tree(1)["params"]["w"]))


def test_legacy_ok_sentinel_still_restorable(tmp_path):
    """Pre-manifest checkpoints (sentinel == "ok") stay restorable via the
    existence-only fallback."""
    save(str(tmp_path), 4, _tree(4))
    (tmp_path / "step_00000004" / "_COMMITTED").write_text("ok")
    assert is_valid(str(tmp_path), 4)
    assert CheckpointManager(str(tmp_path), interval=1).latest_step() == 4


def test_extra_sidecar_roundtrip_and_manifest(tmp_path):
    """``extra`` sidecar files land in the same atomic commit, read back
    via ``read_extra``, and are covered by the truncation manifest."""
    save(str(tmp_path), 1, _tree(),
         extra={"meta.json": json.dumps({"queue": [3, 4]})})
    back = json.loads(read_extra(str(tmp_path), 1, "meta.json"))
    assert back == {"queue": [3, 4]}
    (tmp_path / "step_00000001" / "meta.json").write_text("x")
    assert not is_valid(str(tmp_path), 1)


def test_non_native_dtype_roundtrip(tmp_path):
    """bfloat16 leaves (npz stores them as raw void bytes) round-trip —
    the serving-pool snapshot path saves bf16 caches."""
    t = {"x": jnp.arange(8, dtype=jnp.float32).astype(jnp.bfloat16)}
    save(str(tmp_path), 1, t)
    out = restore(str(tmp_path), 1,
                  {"x": jnp.zeros((8,), jnp.bfloat16)})
    assert out["x"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out["x"], np.float32),
                               np.arange(8, dtype=np.float32))
