"""Smoke test: the dispatch-overhead microbench runs end-to-end."""
import json

from benchmarks.bench_dispatch import run


def test_bench_dispatch_smoke(tmp_path):
    out = tmp_path / "BENCH_dispatch.json"
    rows = run(str(out), smoke=True, repeats=2, verbose=False)
    assert out.exists()
    on_disk = json.loads(out.read_text())
    names = [r["name"] for r in on_disk["rows"]]
    assert names == ["dispatch_prefill_direct", "dispatch_prefill_engine",
                     "dispatch_decode_direct", "dispatch_decode_engine"]
    for row in on_disk["rows"]:
        assert row["us_per_call"] > 0
        assert row["ratio_vs_direct"] > 0
    assert len(rows) == 4
