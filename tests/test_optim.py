"""Optimizer substrate: AdamW convergence, clipping, schedules, gradient
compression (error feedback preserves convergence)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         bf16_allreduce_cast, clip_by_global_norm,
                         ef_compress, ef_decompress, ef_init, global_norm,
                         warmup_cosine, warmup_linear)


def _quadratic_problem(key, dim=16):
    a = jax.random.normal(key, (dim, dim))
    target = jax.random.normal(jax.random.fold_in(key, 1), (dim,))

    def loss(p):
        return 0.5 * jnp.sum((a @ (p["x"] - target)) ** 2)
    return loss, {"x": jnp.zeros((dim,))}, target


def test_adamw_converges_on_quadratic():
    loss, params, target = _quadratic_problem(jax.random.PRNGKey(0))
    state = adamw_init(params)
    cfg = AdamWConfig(weight_decay=0.0)
    for _ in range(400):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, 0.05, cfg)
    assert float(loss(params)) < 1e-2


def test_weight_decay_shrinks_params():
    params = {"x": jnp.ones((4,))}
    state = adamw_init(params)
    cfg = AdamWConfig(weight_decay=0.5)
    zero_g = {"x": jnp.zeros((4,))}
    params2, _, _ = adamw_update(zero_g, state, params, 0.1, cfg)
    assert float(jnp.max(params2["x"])) < 1.0


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == 10.0
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    # below threshold -> untouched
    same, _ = clip_by_global_norm(g, 100.0)
    np.testing.assert_allclose(np.asarray(same["a"]), 3.0)


def test_schedules():
    assert float(warmup_cosine(0, peak_lr=1.0, warmup_steps=10,
                               total_steps=100)) == 0.0
    assert float(warmup_cosine(10, peak_lr=1.0, warmup_steps=10,
                               total_steps=100)) == 1.0
    end = float(warmup_cosine(100, peak_lr=1.0, warmup_steps=10,
                              total_steps=100))
    assert abs(end - 0.1) < 1e-6
    assert float(warmup_linear(100, peak_lr=1.0, warmup_steps=10,
                               total_steps=100)) == 0.0


def test_ef_compression_roundtrip_small_error():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64))}
    res = ef_init(g)
    q, res2 = ef_compress(g, res)
    deq = ef_decompress(q)
    err = float(jnp.max(jnp.abs(deq["w"] - g["w"])))
    assert err < float(jnp.max(jnp.abs(g["w"]))) / 100
    # residual equals the quantization error exactly
    np.testing.assert_allclose(np.asarray(res2["w"]),
                               np.asarray(g["w"] - deq["w"]), atol=1e-6)


def test_ef_compression_preserves_convergence():
    """SGD with int8 error-feedback compressed grads still converges —
    the distributed-optimization trick validated numerically."""
    loss, params, target = _quadratic_problem(jax.random.PRNGKey(1), dim=8)
    res = ef_init(params)
    p_plain = params
    for _ in range(300):
        g = jax.grad(loss)(params)
        q, res = ef_compress(g, res)
        g_hat = ef_decompress(q)
        params = jax.tree_util.tree_map(lambda p, gg: p - 0.01 * gg,
                                        params, g_hat)
        g2 = jax.grad(loss)(p_plain)
        p_plain = jax.tree_util.tree_map(lambda p, gg: p - 0.01 * gg,
                                         p_plain, g2)
    assert float(loss(params)) < 1.5 * max(float(loss(p_plain)), 1e-3)


def test_bf16_cast():
    g = {"w": jnp.ones((4,), jnp.float32)}
    out = bf16_allreduce_cast(g)
    assert out["w"].dtype == jnp.bfloat16
