"""Smoke test: the long-context soak benchmark runs end-to-end.

Runs the 8k-token smoke horizon.  The deterministic soak gates
(z growth/pinning, fp32 safety, renorm invariance, telemetry flatness)
must PASS even at smoke scale — they measure math, not wall clock.  The
telemetry-overhead cell is wall-clock and too noisy to hard-gate here;
only its shape is checked.
"""
import json

from benchmarks.bench_longctx import run


def test_bench_longctx_smoke(tmp_path):
    out = tmp_path / "BENCH_longctx.json"
    report = run(str(out), smoke=True, verbose=False)
    assert out.exists()
    on_disk = json.loads(out.read_text())
    assert on_disk["modes"].keys() == {"baseline", "renorm", "robust"}
    names = [r["name"] for r in on_disk["results"]]
    assert names == ["z_growth", "fp32_safe", "renorm_invariance",
                     "telemetry_flat", "telemetry_overhead"]
    assert len(report["results"]) == len(on_disk["results"])

    rows = {r["name"]: r for r in on_disk["results"]}
    # Deterministic soak gates hold at any horizon.
    for name in ("z_growth", "fp32_safe", "renorm_invariance",
                 "telemetry_flat"):
        assert rows[name]["pass"], rows[name]
    assert rows["z_growth"]["baseline_ratio"] >= rows["z_growth"][
        "baseline_min"]
    assert rows["z_growth"]["renorm_z_max"] <= on_disk["soak"]["renorm"] * (
        1 + 1e-3)
    assert rows["renorm_invariance"]["final_out_err"] <= 1e-3

    # Smoke overhead cells are too noisy to hard-gate, but the
    # measurement itself must be well-formed.
    over = rows["telemetry_overhead"]
    assert over["tok_s"]["telemetry_off"] > 0
    assert over["tok_s"]["telemetry_on"] > 0
    assert over["gate_pct"] == 2.0
    assert isinstance(over["overhead_pct"], float)
