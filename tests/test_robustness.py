"""Fault-tolerant serving: every recovery path proven end to end.

* state-health sentinel (``core/health.py`` + ``AttentionEngine.
  check_health``) flags exactly the poisoned rows;
* under an injected per-row NaN, healthy pool rows are token-for-token
  identical to the fault-free run, and the quarantined row recovers —
  re-prefill + partial-commit replay — to the SAME final tokens (which
  equal its fresh solo run, by the pool-parity suite) with status
  ``retried``;
* poisoned FREE slots reset silently without touching live rows;
* typed admission rejection (bad rid/prompt/vocab/budget, duplicate,
  queue cap) never crashes the loop and always yields status
  ``rejected`` with a reason;
* deadlines fire at segment boundaries (status ``timeout``, partial
  output kept), and an injected ``delay`` trips the straggler watchdog;
* retry exhaustion under repeated poison yields status ``failed``;
* a ``kill`` fault mid-run + ``run(resume=True)`` restores the pool from
  the latest snapshot and finishes every in-flight request with the same
  final tokens as the crash-free run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.engine import AttentionEngine
from repro.core.health import HealthConfig, row_health, unhealthy_rows
from repro.checkpoint.manager import CheckpointManager
from repro.kernels.registry import AttnSpec
from repro.launch.batcher import (AdmissionError, ContinuousBatcher,
                                  QueueFullError, Request, synthetic_traffic)
from repro.launch.faults import (FaultEvent, FaultPlan, SimulatedCrash,
                                 poison_rows)
from repro.launch.mesh import compat_mesh
from repro.launch.steps import make_pool_setup
from repro.models import build_model


def _tiny_cfg(impl="lln_diag", r=2, fixed_ab=False):
    h = 4
    return ArchConfig(
        name=f"robust-test-{impl}-r{r}", family="dense", n_layers=2,
        d_model=64, n_heads=h, n_kv_heads=h // r, d_ff=128, vocab=128,
        head_dim=16, attn_impl=impl, diag_block=8, lln_chunk=8,
        softmax_chunk=16,
        lln_fixed_ab=2.1 if fixed_ab and impl != "softmax" else 0.0,
        compute_dtype="float32", param_dtype="float32", remat="none",
        tie_embeddings=True)


@dataclasses.dataclass
class _Pool:
    cfg: object
    model: object
    params: object
    mesh: object
    setup: object


@pytest.fixture(scope="module")
def pool():
    """One shared 2-slot pool (dynamic per-row calibration — the hardest
    recovery mode: alpha/beta must survive re-prefill bitwise)."""
    cfg = _tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = compat_mesh((1, 1), ("data", "model"))
    with mesh:
        setup = make_pool_setup(cfg, mesh, slots=2, max_len=48, segment=3)
        yield _Pool(cfg=cfg, model=model, params=params, mesh=mesh,
                    setup=setup)


def _run(pool, reqs, **kw):
    eng = ContinuousBatcher(pool.setup, pool.params)
    with pool.mesh:
        return eng.run(reqs, key=jax.random.PRNGKey(42), **kw)


# ---------------------------------------------------------------------------
# Sentinel unit level.
# ---------------------------------------------------------------------------

class TestSentinel:
    def test_row_health_flags_each_failure_mode(self):
        s = np.zeros((4, 2, 3), np.float32)
        s[1, 0, 2] = np.nan
        s[2, 1, 1] = 1e9                      # magnitude explosion
        alpha = np.ones((4, 2), np.float32)
        alpha[3, 0] = -0.5                    # calibration drift
        tree = {"s": jnp.asarray(s), "alpha": jnp.asarray(alpha),
                "len": jnp.zeros((4,), jnp.int32)}   # int leaf skipped
        flags = row_health(tree, row_axis=0)
        np.testing.assert_array_equal(
            np.asarray(flags["nonfinite"]), [False, True, False, False])
        np.testing.assert_array_equal(
            np.asarray(flags["magnitude"]), [False, False, True, False])
        np.testing.assert_array_equal(
            np.asarray(flags["calib"]), [False, False, False, True])
        np.testing.assert_array_equal(
            np.asarray(flags["unhealthy"]), [False, True, True, True])

    def test_config_disables_checks(self):
        s = np.zeros((2, 3), np.float32)
        s[1] = 1e9
        cfg = HealthConfig(check_magnitude=False)
        got = unhealthy_rows({"s": jnp.asarray(s)}, config=cfg)
        assert not np.asarray(got).any()

    def test_no_float_leaves_raises(self):
        with pytest.raises(ValueError):
            row_health({"len": jnp.zeros((2,), jnp.int32)})

    def test_engine_check_health_hook(self):
        g, r, d = 2, 2, 8
        spec = AttnSpec(impl="lln_diag", causal=True, r=r, lln_chunk=8,
                        diag_block=8, fixed_ab=2.1)
        eng = AttentionEngine(spec=spec, heads=g * r, kv_heads=g,
                              head_dim=d, v_dim=d,
                              cache_dtype=jnp.float32)
        kq, kk, kv = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(kq, (2, 16, g * r, d))
        k = jax.random.normal(kk, (2, 16, g, d))
        v = jax.random.normal(kv, (2, 16, g, d))
        _, state = eng.prefill(q, k, v, max_len=24)
        healthy = eng.check_health(state)
        assert not np.asarray(healthy["unhealthy"]).any()
        bad = jax.tree_util.tree_map(
            lambda a: a.at[0].set(jnp.nan)
            if jnp.issubdtype(a.dtype, jnp.floating) else a, state)
        flags = eng.check_health(bad)
        np.testing.assert_array_equal(np.asarray(flags["unhealthy"]),
                                      [True, False])

    def test_free_pool_slot_is_healthy_by_construction(self, pool):
        caches = pool.setup.cache_init()
        got = unhealthy_rows(caches, row_axis=1)
        assert not np.asarray(got).any()


# ---------------------------------------------------------------------------
# Quarantine -> re-prefill recovery (the tentpole parity test).
# ---------------------------------------------------------------------------

class TestQuarantineRecovery:
    def test_nan_row_recovers_and_healthy_rows_unaffected(self, pool):
        """Poison slot 0 mid-run.  Healthy rows must be token-for-token
        identical to the fault-free run; the quarantined request must
        recover (re-prefill + replay) to the SAME final tokens with
        status ``retried``."""
        reqs = synthetic_traffic(3, pool.cfg.vocab, prompt_lens=[8, 11],
                                 gen_lens=[14, 9], seed=3)
        clean = _run(pool, reqs)
        assert all(v == "done" for v in clean.statuses.values())

        plan = FaultPlan(events=[FaultEvent(kind="nan", segment=2, row=0)])
        faulty = _run(pool, reqs, fault_plan=plan)

        assert faulty.recoveries == 1
        assert len(faulty.health_events) == 1
        hurt_rid = faulty.health_events[0]["rid"]
        assert hurt_rid >= 0
        for req in reqs:
            np.testing.assert_array_equal(
                faulty.outputs[req.rid], clean.outputs[req.rid],
                err_msg=f"rid {req.rid}")
            want = "retried" if req.rid == hurt_rid else "done"
            assert faulty.statuses[req.rid] == want
        assert faulty.completed_tokens == clean.completed_tokens

    def test_poisoned_free_slot_resets_silently(self, pool):
        """NaN in a FREE slot (rid -1) must reset the row without touching
        the live request — and must not count as a recovery."""
        reqs = synthetic_traffic(1, pool.cfg.vocab, prompt_lens=[8],
                                 gen_lens=[10], seed=5)
        clean = _run(pool, reqs)
        plan = FaultPlan(events=[FaultEvent(kind="nan", segment=1, row=1)])
        faulty = _run(pool, reqs, fault_plan=plan)
        np.testing.assert_array_equal(faulty.outputs[0], clean.outputs[0])
        assert faulty.statuses[0] == "done"
        assert faulty.recoveries == 0
        assert faulty.health_events and faulty.health_events[0]["rid"] == -1

    def test_retry_exhaustion_fails_request(self, pool):
        """Repeated poison on the same request: retries back off, then
        exhaust -> status ``failed`` with a typed reason."""
        reqs = synthetic_traffic(1, pool.cfg.vocab, prompt_lens=[8],
                                 gen_lens=[30], seed=9)
        plan = FaultPlan(events=[
            FaultEvent(kind="nan", segment=1, row=0),
            FaultEvent(kind="nan", segment=4, row=0),
            FaultEvent(kind="nan", segment=8, row=0)])
        eng = ContinuousBatcher(pool.setup, pool.params, max_retries=2)
        with pool.mesh:
            stats = eng.run(reqs, key=jax.random.PRNGKey(42),
                            fault_plan=plan)
        assert stats.statuses[0] == "failed"
        assert "retries exhausted" in stats.reject_reasons[0]
        assert stats.failed == 1

    def test_drop_fault_cancels_request(self, pool):
        reqs = synthetic_traffic(2, pool.cfg.vocab, prompt_lens=[8],
                                 gen_lens=[12], seed=11)
        clean = _run(pool, reqs)
        plan = FaultPlan(events=[FaultEvent(kind="drop", segment=1,
                                            rid=0)])
        faulty = _run(pool, reqs, fault_plan=plan)
        assert faulty.statuses[0] == "failed"
        assert "dropped" in faulty.reject_reasons[0]
        assert faulty.statuses[1] == "done"
        np.testing.assert_array_equal(faulty.outputs[1], clean.outputs[1])


# ---------------------------------------------------------------------------
# Admission validation + queue bounds (typed rejection, no crashes).
# ---------------------------------------------------------------------------

class TestAdmissionGuards:
    def test_typed_validation_errors(self, pool):
        eng = ContinuousBatcher(pool.setup, pool.params)
        ok = np.zeros((8,), np.int32)
        cases = [
            Request(rid=-2, prompt=ok, gen_len=4),
            Request(rid=1, prompt=np.zeros((0,), np.int32), gen_len=4),
            Request(rid=2, prompt=np.zeros((8,), np.float32), gen_len=4),
            Request(rid=3, prompt=ok + pool.cfg.vocab, gen_len=4),
            Request(rid=4, prompt=ok, gen_len=0),
            Request(rid=5, prompt=ok, gen_len=1000),   # exceeds max_len
            Request(rid=6, prompt=ok, gen_len=4, deadline_s=-1.0),
            Request(rid=7, prompt=ok, gen_len=4, max_tokens=0),
        ]
        for req in cases:
            with pytest.raises(AdmissionError):
                eng.check_request(req)

    def test_rejected_requests_get_status_and_survivors_complete(self, pool):
        good = synthetic_traffic(2, pool.cfg.vocab, prompt_lens=[8],
                                 gen_lens=[6], seed=13)
        bad = [Request(rid=10, prompt=np.zeros((8,), np.int32),
                       gen_len=1000),
               Request(rid=11,
                       prompt=np.full((8,), pool.cfg.vocab, np.int32),
                       gen_len=4)]
        clean = _run(pool, good)
        stats = _run(pool, good + bad)
        assert stats.statuses[10] == "rejected"
        assert "max_len" in stats.reject_reasons[10]
        assert stats.statuses[11] == "rejected"
        assert stats.rejected == 2
        for req in good:
            assert stats.statuses[req.rid] == "done"
            np.testing.assert_array_equal(stats.outputs[req.rid],
                                          clean.outputs[req.rid])

    def test_duplicate_rid_rejected(self, pool):
        reqs = synthetic_traffic(1, pool.cfg.vocab, prompt_lens=[8],
                                 gen_lens=[4], seed=15)
        dup = Request(rid=0, prompt=reqs[0].prompt, gen_len=4)
        stats = _run(pool, reqs + [dup])
        assert stats.statuses[0] == "done"
        assert stats.rejected == 1

    def test_queue_cap_rejects_overflow(self, pool):
        reqs = synthetic_traffic(4, pool.cfg.vocab, prompt_lens=[8],
                                 gen_lens=[4], seed=17)
        eng = ContinuousBatcher(pool.setup, pool.params, queue_cap=2)
        with pool.mesh:
            stats = eng.run(reqs, key=jax.random.PRNGKey(42))
        served = [r for r, v in stats.statuses.items() if v == "done"]
        capped = [r for r, v in stats.statuses.items() if v == "rejected"]
        assert len(served) == 2 and len(capped) == 2
        for rid in capped:
            assert "queue" in stats.reject_reasons[rid]

    def test_max_tokens_bounds_output_buffer(self, pool):
        req = Request(rid=0,
                      prompt=np.zeros((8,), np.int32), gen_len=20,
                      max_tokens=5)
        stats = _run(pool, [req])
        assert stats.statuses[0] == "done"
        assert len(stats.outputs[0]) == 5


# ---------------------------------------------------------------------------
# Deadlines + straggler watchdog.
# ---------------------------------------------------------------------------

class TestDeadlines:
    def test_deadline_times_out_with_partial_output(self, pool):
        reqs = [Request(rid=0, prompt=np.zeros((8,), np.int32),
                        gen_len=30, deadline_s=1e-4),
                Request(rid=1, prompt=np.ones((8,), np.int32),
                        gen_len=6)]
        stats = _run(pool, reqs)
        assert stats.statuses[0] == "timeout"
        assert stats.timeouts == 1
        assert 1 <= len(stats.outputs[0]) < 30   # partial kept
        assert stats.statuses[1] == "done"
        assert len(stats.outputs[1]) == 6

    def test_delay_fault_trips_watchdog(self, pool):
        reqs = synthetic_traffic(1, pool.cfg.vocab, prompt_lens=[8],
                                 gen_lens=[36], seed=21)
        plan = FaultPlan(events=[FaultEvent(kind="delay", segment=8,
                                            seconds=1.0)])
        stats = _run(pool, reqs, fault_plan=plan)
        assert stats.segment_ewma_s > 0
        # The EWMA threshold is ~1ms here, so an OS scheduling blip on a
        # loaded host can also register — require the injected delay to
        # be AMONG the stragglers, not necessarily the first.
        delayed = [r for r in stats.stragglers if r.duration >= 1.0]
        assert delayed, "1s delay must register as a straggler"


# ---------------------------------------------------------------------------
# Snapshot / kill / restore.
# ---------------------------------------------------------------------------

class TestKillRestore:
    def test_kill_and_restore_resumes_identically(self, pool, tmp_path):
        """Crash (kill fault) after segment 3 with per-segment snapshots;
        ``run(resume=True)`` must finish every in-flight request with the
        same final tokens as the crash-free run."""
        reqs = synthetic_traffic(3, pool.cfg.vocab, prompt_lens=[8, 11],
                                 gen_lens=[16, 9], seed=23)
        clean = _run(pool, reqs)

        mgr = CheckpointManager(str(tmp_path), keep_n=2, interval=1)
        eng = ContinuousBatcher(pool.setup, pool.params, snapshot_mgr=mgr,
                                snapshot_every=1)
        plan = FaultPlan(events=[FaultEvent(kind="kill", segment=3)])
        with pool.mesh:
            with pytest.raises(SimulatedCrash):
                eng.run(reqs, key=jax.random.PRNGKey(42), fault_plan=plan)
            assert mgr.latest_step() == 3
            stats = eng.run([], resume=True)
        assert stats.restored_step == 3
        assert stats.snapshots > 0
        for req in reqs:
            np.testing.assert_array_equal(
                stats.outputs[req.rid], clean.outputs[req.rid],
                err_msg=f"rid {req.rid}")
            assert stats.statuses[req.rid] == "done"

    def test_resume_without_snapshot_raises(self, pool, tmp_path):
        mgr = CheckpointManager(str(tmp_path), interval=1)
        eng = ContinuousBatcher(pool.setup, pool.params, snapshot_mgr=mgr,
                                snapshot_every=1)
        with pytest.raises(RuntimeError):
            eng.run([], resume=True)


# ---------------------------------------------------------------------------
# Fault-plan plumbing.
# ---------------------------------------------------------------------------

class TestFaultPlan:
    def test_json_roundtrip_and_inline_load(self):
        plan = FaultPlan(events=[
            FaultEvent(kind="nan", segment=2, row=1),
            FaultEvent(kind="kill", segment=4)], seed=7)
        back = FaultPlan.load(plan.to_json())
        assert back.seed == 7
        assert [e.kind for e in back.events] == ["nan", "kill"]
        assert back.at(4)[0].kind == "kill"

    def test_bad_kind_rejected(self):
        with pytest.raises(ValueError):
            FaultEvent(kind="meteor", segment=0)

    def test_seeded_row_pick_is_deterministic(self):
        ev = FaultEvent(kind="nan", segment=0, row=-1)
        rows1 = [FaultPlan(events=[ev], seed=3).pick_row(ev, 8)
                 for _ in range(3)]
        rows2 = [FaultPlan(events=[ev], seed=3).pick_row(ev, 8)
                 for _ in range(3)]
        assert rows1 == rows2

    def test_poison_rows_hits_only_target_rows(self, pool):
        caches = pool.setup.cache_init()
        bad = poison_rows(caches, [1])
        flags = np.asarray(unhealthy_rows(bad, row_axis=1))
        np.testing.assert_array_equal(flags, [False, True])
