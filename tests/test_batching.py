"""Continuous-batching engine: parity with solo serving + masked-row
state-isolation.

* the pool (staggered admits/evicts, per-row positions, row masks) emits
  token-for-token the SAME sequence per request as running that request
  alone through ``ServeSetup.make_generate`` — softmax/lln/lln_diag ×
  GQA r ∈ {1, 4};
* masked rows provably do not mutate state: every cache leaf of a
  masked-off row is bitwise unchanged through ``model.decode``, at both
  the model level and the ``lln_decode_chunk``/``decode_lln_chunk`` level;
* per-row positions degenerate to the scalar path when all rows agree;
* ``admit_fn`` writes exactly one pool row.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, ShapeSpec
from repro.core import attention as ca
from repro.core import lln as core_lln
from repro.kernels import ops as kops
from repro.launch.batcher import (ContinuousBatcher, Request,
                                  synthetic_traffic)
from repro.launch.mesh import compat_mesh
from repro.launch.steps import make_pool_setup, make_serve_setup
from repro.models import build_model


def _tiny_cfg(impl, r, fixed_ab=True):
    h = 4
    return ArchConfig(
        name=f"pool-test-{impl}-r{r}", family="dense", n_layers=2,
        d_model=64, n_heads=h, n_kv_heads=h // r, d_ff=128, vocab=128,
        head_dim=16, attn_impl=impl, diag_block=8, lln_chunk=8,
        softmax_chunk=16,
        lln_fixed_ab=2.1 if fixed_ab and impl != "softmax" else 0.0,
        compute_dtype="float32", param_dtype="float32", remat="none",
        tie_embeddings=True)


def _solo_tokens(cfg, model, params, mesh, req, max_len, gen_cache):
    """The request served alone: B=1 prefill + ``make_generate``."""
    plen = len(req.prompt)
    if ("setup", plen) not in gen_cache:
        shape = ShapeSpec("solo", max_len, 1, "decode")
        gen_cache[("setup", plen)] = make_serve_setup(cfg, shape, mesh,
                                                      multi_pod=False)
    setup = gen_cache[("setup", plen)]
    batch = {"inputs": jnp.asarray(req.prompt)[None, :],
             "targets": jnp.asarray(req.prompt)[None, :],
             "mask": jnp.ones((1, plen), jnp.float32)}
    logits, caches = setup.prefill_fn(params, batch)
    last = logits[:, -1] if logits.ndim == 3 else logits
    tok0 = jnp.argmax(last, -1).astype(jnp.int32)
    toks = [int(tok0[0])]
    if req.gen_len > 1:
        key = ("gen", plen, req.gen_len)
        if key not in gen_cache:
            gen_cache[key] = setup.make_generate(req.gen_len - 1, 0.0)
        out, _ = gen_cache[key](params, caches, tok0,
                                jnp.asarray(plen, jnp.int32),
                                jax.random.PRNGKey(0))
        toks.extend(int(t) for t in np.asarray(out)[0])
    return np.asarray(toks, np.int32)


class TestPoolParity:
    @pytest.mark.parametrize("r", [1, 4])
    @pytest.mark.parametrize("impl", ["softmax", "lln", "lln_diag",
                                      "log_linear"])
    def test_pool_matches_solo_generate(self, impl, r):
        """2 slots, 4 mixed-length requests: admits/evicts stagger (short
        requests retire and refill their slot while a long one is still
        mid-flight), yet every request's tokens equal its solo run."""
        cfg = _tiny_cfg(impl, r)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        max_len = 32
        # Two leading same-length prompts exercise grouped admission (one
        # batched prefill admitting both slots); the 11-prompt exercises
        # the per-length compile path.
        reqs = synthetic_traffic(4, cfg.vocab, prompt_lens=[8, 8, 11],
                                 gen_lens=[2, 7, 4], seed=r)
        mesh = compat_mesh((1, 1), ("data", "model"))
        with mesh:
            setup = make_pool_setup(cfg, mesh, slots=2, max_len=max_len,
                                    segment=3)
            stats = ContinuousBatcher(setup, params).run(reqs)
            assert stats.admitted == len(reqs)
            gen_cache: dict = {}
            for req in reqs:
                ref = _solo_tokens(cfg, model, params, mesh, req, max_len,
                                   gen_cache)
                got = stats.outputs[req.rid]
                assert len(got) == req.gen_len
                np.testing.assert_array_equal(got, ref,
                                              err_msg=f"rid {req.rid}")

    def test_pool_matches_solo_dynamic_calibration(self):
        """Dynamic moment matching (no fixed alpha/beta): every slot
        carries genuinely different per-row (B, H) alpha/beta from its own
        prompt statistics.  Per-row calibration (``lln_per_row_calib``,
        the pool default) makes a batched slot prefill exact per request,
        so admission is GROUPED even here — and pooled rows still decode
        token-for-token like solo runs."""
        cfg = _tiny_cfg("lln_diag", 2, fixed_ab=False)
        assert cfg.lln_fixed_ab == 0
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(3))
        max_len = 32
        reqs = synthetic_traffic(3, cfg.vocab, prompt_lens=[8],
                                 gen_lens=[3, 6], seed=7)
        mesh = compat_mesh((1, 1), ("data", "model"))
        with mesh:
            setup = make_pool_setup(cfg, mesh, slots=2, max_len=max_len,
                                    segment=3)
            eng = ContinuousBatcher(setup, params)
            assert eng.group_admits     # batched-prefill admission
            stats = eng.run(reqs)
            gen_cache: dict = {}
            for req in reqs:
                ref = _solo_tokens(cfg, model, params, mesh, req, max_len,
                                   gen_cache)
                np.testing.assert_array_equal(stats.outputs[req.rid], ref,
                                              err_msg=f"rid {req.rid}")


class TestMaskedRows:
    @pytest.mark.parametrize("impl", ["softmax", "lln_diag"])
    def test_masked_rows_do_not_mutate_model_caches(self, impl):
        """model.decode with a row mask leaves every cache leaf of the
        masked rows bitwise unchanged (and matches the unmasked decode on
        active rows)."""
        cfg = _tiny_cfg(impl, 2)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(1))
        b, plen, max_len = 3, 8, 24
        toks = jax.random.randint(jax.random.PRNGKey(2), (b, plen), 0,
                                  cfg.vocab, jnp.int32)
        # Per-row pooled caches at a common depth (prefill each row solo
        # would also work; a shared prefill keeps the test fast).
        _, caches = model.prefill(params, {"inputs": toks}, max_len)

        def per_rowify(leaf):
            if leaf.ndim == 1 and leaf.shape[0] == cfg.n_layers:  # len/pos
                return jnp.broadcast_to(leaf[:, None],
                                        (cfg.n_layers, b)).astype(leaf.dtype)
            if leaf.ndim == 2 and leaf.shape == (cfg.n_layers, cfg.n_heads):
                return jnp.broadcast_to(leaf[:, None, :],
                                        (cfg.n_layers, b, cfg.n_heads))
            return leaf
        caches = jax.tree_util.tree_map(per_rowify, caches)

        mask = jnp.asarray([True, False, True])
        tok = jnp.asarray([3, 5, 7], jnp.int32)
        pos = jnp.full((b,), plen, jnp.int32)
        _, c_masked = model.decode(params, caches, tok, pos, row_mask=mask)
        _, c_all = model.decode(params, caches, tok, pos,
                                row_mask=jnp.ones((b,), jnp.bool_))

        def rows(leaf, i):
            # Every cache leaf carries the batch axis at position 1
            # (stacked layers first); counters/calibration are (L, B[, H]).
            return np.asarray(leaf)[:, i]
        for kp, before in jax.tree_util.tree_leaves_with_path(caches):
            after = c_masked
            for k in kp:
                after = after[k.key] if hasattr(k, "key") else after[k.idx]
            path = jax.tree_util.keystr(kp)
            np.testing.assert_array_equal(
                rows(after, 1), rows(before, 1),
                err_msg=f"masked row mutated: {path}")
            got = c_all
            for k in kp:
                got = got[k.key] if hasattr(k, "key") else got[k.idx]
            np.testing.assert_array_equal(
                rows(after, 0), rows(got, 0),
                err_msg=f"active row diverged under masking: {path}")

    @pytest.mark.parametrize("use_kernel", [True, False])
    def test_masked_rows_lln_decode_chunk(self, use_kernel):
        """decode_lln_chunk row mask: masked rows keep (s, z, c_k), tails
        and pos exactly."""
        b, t, g, r, d, block = 3, 2, 2, 2, 8, 8
        h = g * r
        kq, kk, kv = jax.random.split(jax.random.PRNGKey(3), 3)
        q0 = jax.random.normal(kq, (b, 24, h, d))
        k0 = jax.random.normal(kk, (b, 24, g, d))
        v0 = jax.random.normal(kv, (b, 24, g, d))
        alpha = jnp.full((h,), 1.2)
        beta = jnp.full((g,), 1.0)
        _, s, z, c_k = kops.lln_prefill(q0, k0, v0, alpha, beta, chunk=8)
        st = ca.LLNDecodeState(
            lln=core_lln.LLNState(s=s, z=z, c_k=c_k),
            tail_k=k0[:, -block:], tail_v=v0[:, -block:],
            pos=jnp.full((b,), 24, jnp.int32))
        qn, kn, vn = (jax.random.normal(k_, (b, t, hh, d)) for k_, hh in
                      zip(jax.random.split(jax.random.PRNGKey(4), 3),
                          (h, g, g)))
        mask = jnp.asarray([False, True, False])
        _, st2 = ca.decode_lln_chunk(st, qn, kn, vn, alpha,
                                     jnp.repeat(beta, r),
                                     use_kernel=use_kernel, row_mask=mask)
        for name in ("tail_k", "tail_v", "pos"):
            a, bfr = getattr(st2, name), getattr(st, name)
            for i in (0, 2):
                np.testing.assert_array_equal(np.asarray(a)[i],
                                              np.asarray(bfr)[i],
                                              err_msg=name)
        for name in ("s", "z", "c_k"):
            a, bfr = getattr(st2.lln, name), getattr(st.lln, name)
            for i in (0, 2):
                np.testing.assert_array_equal(np.asarray(a)[i],
                                              np.asarray(bfr)[i],
                                              err_msg=name)
        # The active row advanced.
        assert int(np.asarray(st2.pos)[1]) == 24 + t
        assert not np.array_equal(np.asarray(st2.lln.s)[1],
                                  np.asarray(st.lln.s)[1])


class TestMaskedLogits:
    @pytest.mark.parametrize("impl", ["softmax", "lln_diag"])
    def test_masked_row_logits_never_reach_sampling(self, impl):
        """The masked-row contract says an inactive slot's logits are
        garbage — segment_fn must neutralize them before sample_token.
        Regression: poison a free slot's cache state with NaN (the worst
        legal garbage) and assert the active rows' harvested tokens are
        bitwise identical to a clean-pool run, with no NaN anywhere in
        the emitted stream."""
        cfg = _tiny_cfg(impl, 2)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(4))
        mesh = compat_mesh((1, 1), ("data", "model"))
        with mesh:
            setup = make_pool_setup(cfg, mesh, slots=2, max_len=32,
                                    segment=4, temperature=0.7)
            prompt = jax.random.randint(jax.random.PRNGKey(5), (1, 8), 0,
                                        cfg.vocab, jnp.int32)
            _, slot_caches = setup.prefill_fn(8)(params, prompt)

            def run_segment(pool):
                tok = jnp.zeros((2,), jnp.int32).at[0].set(7)
                pos = jnp.zeros((2,), jnp.int32).at[0].set(8)
                remaining = jnp.zeros((2,), jnp.int32).at[0].set(4)
                active = jnp.asarray([True, False])
                out = setup.segment_fn(params, pool, tok, pos, remaining,
                                       active, jax.random.PRNGKey(6))
                _, tok2, _, _, _, toks, emitted, _, _ = out
                return np.asarray(toks), np.asarray(emitted), \
                    np.asarray(tok2)

            clean = setup.admit_fn(setup.cache_init(), slot_caches,
                                   jnp.asarray([0], jnp.int32))
            toks_clean, em_clean, tok_clean = run_segment(clean)

            poisoned = setup.admit_fn(setup.cache_init(), slot_caches,
                                      jnp.asarray([0], jnp.int32))
            poisoned = jax.tree_util.tree_map(
                lambda a: a.at[:, 1].set(jnp.nan)
                if jnp.issubdtype(a.dtype, jnp.floating) else a, poisoned)
            toks_poi, em_poi, tok_poi = run_segment(poisoned)

        np.testing.assert_array_equal(em_clean, em_poi)
        np.testing.assert_array_equal(toks_clean[:, 0], toks_poi[:, 0])
        assert tok_clean[0] == tok_poi[0]
        # Nothing NaN-shaped leaked into the emitted token stream.
        assert (toks_poi[em_poi] >= 0).all()


class TestEvictCalibration:
    def test_evict_resets_alpha_beta_to_init(self):
        """evict_fn resets a freed slot to its init_state values: zeros
        everywhere EXCEPT alpha/beta, which reset to ONES — a previous
        request's moment-matching constants must not survive in the
        pool."""
        cfg = _tiny_cfg("lln_diag", 2, fixed_ab=False)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(7))
        mesh = compat_mesh((1, 1), ("data", "model"))
        with mesh:
            setup = make_pool_setup(cfg, mesh, slots=2, max_len=32,
                                    segment=2)
            pooled = setup.cache_init()
            prompt = jax.random.randint(jax.random.PRNGKey(8), (1, 8), 0,
                                        cfg.vocab, jnp.int32)
            _, sc = setup.prefill_fn(8)(params, prompt)
            pooled = setup.admit_fn(pooled, sc,
                                    jnp.asarray([1], jnp.int32))
            # The admitted row carries genuine prompt calibration != 1.
            a1 = np.asarray(pooled["layers"]["alpha"])[:, 1]
            assert not np.allclose(a1, 1.0)
            mask = np.zeros((2,), np.bool_)
            mask[1] = True
            pooled = setup.evict_fn(pooled, jnp.asarray(mask))
        for kp, leaf in jax.tree_util.tree_leaves_with_path(pooled):
            name = jax.tree_util.keystr(kp)
            row = np.asarray(leaf)[:, 1]
            want = 1.0 if ("alpha" in name or "beta" in name) else 0.0
            np.testing.assert_array_equal(
                row, np.full_like(row, want),
                err_msg=f"evict left {name} at non-init values")

    def test_readmit_into_evicted_slot_matches_solo(self):
        """Re-admission regression: serve request A in a slot, evict it,
        then admit request B — whose prompt statistics (and therefore
        per-row dynamic alpha/beta) genuinely differ — into the SAME
        slot.  B must decode token-for-token like a solo run; any stale
        calibration or state surviving eviction would break this."""
        cfg = _tiny_cfg("lln_diag", 2, fixed_ab=False)
        assert cfg.lln_fixed_ab == 0     # dynamic moment matching
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(9))
        max_len = 32
        # Different prompt lengths => different lengths AND statistics.
        reqs = synthetic_traffic(2, cfg.vocab, prompt_lens=[8, 11],
                                 gen_lens=[3, 5], seed=11)
        mesh = compat_mesh((1, 1), ("data", "model"))
        with mesh:
            setup = make_pool_setup(cfg, mesh, slots=1, max_len=max_len,
                                    segment=2)
            eng = ContinuousBatcher(setup, params)
            # ONE slot: request B can only run through the evicted slot A
            # used, so stale-state leakage would be on the critical path.
            stats = eng.run(reqs)
            gen_cache: dict = {}
            for req in reqs:
                ref = _solo_tokens(cfg, model, params, mesh, req, max_len,
                                   gen_cache)
                np.testing.assert_array_equal(
                    stats.outputs[req.rid], ref,
                    err_msg=f"rid {req.rid} diverged after re-admission")


class TestPerRowPositions:
    def test_vector_pos_matches_scalar_pos(self):
        """All rows at the same depth: the per-row (B,) position path and
        the scalar path produce identical outputs and states."""
        b, t, g, r, d, block, n0 = 2, 3, 2, 2, 8, 8, 21
        h = g * r
        keys = jax.random.split(jax.random.PRNGKey(5), 6)
        q0 = jax.random.normal(keys[0], (b, n0, h, d))
        k0 = jax.random.normal(keys[1], (b, n0, g, d))
        v0 = jax.random.normal(keys[2], (b, n0, g, d))
        alpha = jnp.full((h,), 1.3)
        beta_h = jnp.full((h,), 1.1)
        _, s, z, c_k = kops.lln_prefill(q0, k0, v0, alpha,
                                        jnp.full((g,), 1.1), chunk=7)
        nb = -(-n0 // block)
        pad = nb * block - n0
        tail_k = jnp.pad(k0, ((0, 0), (0, pad), (0, 0), (0, 0)))[:,
                                                                 -block:]
        tail_v = jnp.pad(v0, ((0, 0), (0, pad), (0, 0), (0, 0)))[:,
                                                                 -block:]
        qn = jax.random.normal(keys[3], (b, t, h, d))
        kn = jax.random.normal(keys[4], (b, t, g, d))
        vn = jax.random.normal(keys[5], (b, t, g, d))
        lln = core_lln.LLNState(s=s, z=z, c_k=c_k)
        st_scalar = ca.LLNDecodeState(lln=lln, tail_k=tail_k, tail_v=tail_v,
                                      pos=jnp.asarray(n0, jnp.int32))
        st_vec = ca.LLNDecodeState(lln=lln, tail_k=tail_k, tail_v=tail_v,
                                   pos=jnp.full((b,), n0, jnp.int32))
        o1, s1 = ca.decode_lln_chunk(st_scalar, qn, kn, vn, alpha, beta_h)
        o2, s2 = ca.decode_lln_chunk(st_vec, qn, kn, vn, alpha, beta_h)
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
        np.testing.assert_array_equal(np.asarray(s1.tail_k),
                                      np.asarray(s2.tail_k))
        assert np.asarray(s2.pos).shape == (b,)


class TestAdmit:
    def test_admit_writes_exactly_one_row(self):
        cfg = _tiny_cfg("lln_diag", 2)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(6))
        mesh = compat_mesh((1, 1), ("data", "model"))
        with mesh:
            setup = make_pool_setup(cfg, mesh, slots=3, max_len=32,
                                    segment=2)
            pooled = setup.cache_init()
            ref = jax.tree_util.tree_map(jnp.copy, pooled)
            prompt = jnp.ones((1, 8), jnp.int32)
            _, slot_caches = setup.prefill_fn(8)(params, prompt)
            new = setup.admit_fn(pooled, slot_caches,
                                 jnp.asarray([1], jnp.int32))
        for kp, leaf in jax.tree_util.tree_leaves_with_path(new):
            before = ref
            for k in kp:
                before = before[k.key] if hasattr(k, "key") else \
                    before[k.idx]
            path = jax.tree_util.keystr(kp)
            for row in (0, 2):
                np.testing.assert_array_equal(
                    np.asarray(leaf)[:, row], np.asarray(before)[:, row],
                    err_msg=f"admit leaked into row {row}: {path}")
        # And the admitted row is the slot prefill's state.
        tgt = np.asarray(new["layers"]["pos"])[:, 1]
        np.testing.assert_array_equal(tgt, np.full((cfg.n_layers,), 8))


# ---------------------------------------------------------------------------
# Speculative continuous batching (PoolSetup.spec_k >= 1).
# ---------------------------------------------------------------------------

def _solo_spec_tokens(cfg, params, mesh, req, max_len, spec_k,
                      draft_layers, cache):
    """The request served alone through the solo ``SpecSetup`` loop —
    the speculative oracle pooled rows must reproduce token-for-token."""
    from repro.launch.steps import flatten_spec_tokens, make_spec_setup
    plen = len(req.prompt)
    if ("setup", plen) not in cache:
        shape = ShapeSpec("solo-spec", max_len, 1, "decode")
        cache[("setup", plen)] = make_spec_setup(
            cfg, shape, mesh, spec_k=spec_k, draft_layers=draft_layers)
    ss = cache[("setup", plen)]
    logits, tgt, dr = ss.prefill_fn(
        params, {"inputs": jnp.asarray(req.prompt)[None, :]})
    last = logits[:, -1] if logits.ndim == 3 else logits
    tok0 = jnp.argmax(last, -1).astype(jnp.int32)
    toks = [int(tok0[0])]
    steps = req.budget - 1
    if steps > 0:
        gkey = ("gen", plen, steps)
        if gkey not in cache:
            cache[gkey] = ss.make_generate(steps, 0.0)
        t, n_emit, *_ = cache[gkey](params, tgt, dr, tok0,
                                    jnp.asarray([plen], jnp.int32),
                                    jax.random.PRNGKey(0))
        flat = flatten_spec_tokens(np.asarray(t), np.asarray(n_emit),
                                   steps)
        toks.extend(int(x) for x in flat[0])
    return np.asarray(toks, np.int32)


class TestSpeculativePool:
    SPEC_K, DRAFT_LAYERS = 2, 1

    def test_pool_matches_solo_spec(self, impl_gqa_cell):
        """Pooled speculative greedy decode (staggered admits/evicts over
        2 slots, per-row commit_len) is token-for-token the solo
        ``SpecSetup`` run per request — softmax/lln/lln_diag × r."""
        impl, r = impl_gqa_cell
        cfg = _tiny_cfg(impl, r)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        max_len = 48
        reqs = synthetic_traffic(4, cfg.vocab, prompt_lens=[8, 8, 11],
                                 gen_lens=[2, 7, 4], seed=r)
        mesh = compat_mesh((1, 1), ("data", "model"))
        with mesh:
            setup = make_pool_setup(cfg, mesh, slots=2, max_len=max_len,
                                    segment=3, spec_k=self.SPEC_K,
                                    draft_layers=self.DRAFT_LAYERS)
            stats = ContinuousBatcher(setup, params).run(reqs)
            assert stats.admitted == len(reqs)
            assert stats.spec_k == self.SPEC_K
            assert stats.verify_iters > 0
            assert 1.0 <= stats.goodput_tokens_per_iter <= self.SPEC_K + 1
            cache: dict = {}
            for req in reqs:
                ref = _solo_spec_tokens(cfg, params, mesh, req, max_len,
                                        self.SPEC_K, self.DRAFT_LAYERS,
                                        cache)
                got = stats.outputs[req.rid]
                assert len(got) == req.gen_len
                np.testing.assert_array_equal(got, ref,
                                              err_msg=f"rid {req.rid}")

    def test_quarantine_recovery_replays_both_states(self):
        """NaN-poisoning a speculative row mid-stream quarantines it; the
        re-prefill + paired replay rebuilds BOTH states and the request
        still finishes with its exact solo-spec tokens."""
        from repro.launch.faults import FaultPlan
        cfg = _tiny_cfg("lln_diag", 2)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        max_len = 48
        reqs = synthetic_traffic(2, cfg.vocab, prompt_lens=[8],
                                 gen_lens=[9], seed=5)
        plan = FaultPlan(events=[{"kind": "nan", "segment": 1, "row": 0}])
        mesh = compat_mesh((1, 1), ("data", "model"))
        with mesh:
            setup = make_pool_setup(cfg, mesh, slots=2, max_len=max_len,
                                    segment=2, spec_k=self.SPEC_K,
                                    draft_layers=self.DRAFT_LAYERS)
            stats = ContinuousBatcher(setup, params).run(
                reqs, key=jax.random.PRNGKey(1), fault_plan=plan)
            assert stats.recoveries >= 1
            cache: dict = {}
            for req in reqs:
                ref = _solo_spec_tokens(cfg, params, mesh, req, max_len,
                                        self.SPEC_K, self.DRAFT_LAYERS,
                                        cache)
                np.testing.assert_array_equal(stats.outputs[req.rid], ref,
                                              err_msg=f"rid {req.rid}")

    def test_budget_expiry_caps_multi_token_harvest(self):
        """Regression (multi-token emission bugfix): a speculative row's
        final verify iteration may emit up to spec_k + 1 tokens past its
        budget — the harvest must cap the stored output at EXACTLY
        ``Request.budget`` (including the ``max_tokens`` form), and the
        kept prefix must still match the oracle."""
        cfg = _tiny_cfg("lln", 4)    # r=4 tends to accept multi-token runs
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        max_len = 48
        mesh = compat_mesh((1, 1), ("data", "model"))
        # gen_len chosen NOT ≡ 1 (mod spec_k+1) so expiry can land
        # mid-iteration; max_tokens on rid 1 exercises the min() budget.
        reqs = [Request(rid=0, prompt=np.arange(2, 10, dtype=np.int32),
                        gen_len=6),
                Request(rid=1, prompt=np.arange(3, 11, dtype=np.int32),
                        gen_len=7, max_tokens=5)]
        with mesh:
            setup = make_pool_setup(cfg, mesh, slots=2, max_len=max_len,
                                    segment=3, spec_k=self.SPEC_K,
                                    draft_layers=cfg.n_layers)  # accept=1
            stats = ContinuousBatcher(setup, params).run(reqs)
            cache: dict = {}
            for req in reqs:
                got = stats.outputs[req.rid]
                assert len(got) == req.budget, \
                    f"rid {req.rid}: {len(got)} != budget {req.budget}"
                ref = _solo_spec_tokens(cfg, params, mesh, req, max_len,
                                        self.SPEC_K, cfg.n_layers, cache)
                np.testing.assert_array_equal(got, ref,
                                              err_msg=f"rid {req.rid}")

    def test_check_request_reserves_spec_slack(self):
        """Admission rejects a request whose prompt + budget would fit a
        plain pool but not the speculative overshoot slack."""
        cfg = _tiny_cfg("lln_diag", 2)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        mesh = compat_mesh((1, 1), ("data", "model"))
        with mesh:
            setup = make_pool_setup(cfg, mesh, slots=2, max_len=24,
                                    segment=2, spec_k=self.SPEC_K,
                                    draft_layers=self.DRAFT_LAYERS)
            eng = ContinuousBatcher(setup, params)
            fits = Request(rid=0, prompt=np.zeros((8,), np.int32),
                           gen_len=24 - 8 - self.SPEC_K)
            eng.check_request(fits)
            from repro.launch.batcher import AdmissionError
            with pytest.raises(AdmissionError, match="spec slack"):
                eng.check_request(
                    Request(rid=1, prompt=np.zeros((8,), np.int32),
                            gen_len=24 - 8 - self.SPEC_K + 1))
