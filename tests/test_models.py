"""Per-architecture smoke tests (reduced configs): one train step on CPU,
output shapes + finite values; serve-path consistency (teacher-forced
forward == prefill+decode logits) per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import build_model, synthetic_batch

ARCHS = list(list_archs())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = synthetic_batch(cfg, batch=2, seq=32)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    assert 3.0 < float(loss) < 12.0, "initial loss should be ~ln(vocab)"
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32)))
               for g in leaves)


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "mamba2-130m"])
def test_smoke_train_step_lln_diag(arch):
    """The paper's technique as a drop-in on every attention-bearing arch."""
    if arch == "roberta-lln":
        pytest.skip("already lln_diag by default")
    cfg = get_config(arch, smoke=True, attn_impl="lln_diag")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = synthetic_batch(cfg, batch=2, seq=32)
    loss = model.loss(params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_hidden_shapes(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = synthetic_batch(cfg, batch=2, seq=32)
    h, aux = model.hidden(params, batch)
    assert h.shape[0] == 2 and h.shape[-1] == cfg.d_model
    assert h.shape[1] == batch["inputs"].shape[1]
    assert np.all(np.isfinite(np.asarray(h, np.float32)))


@pytest.mark.parametrize("arch,impl", [
    ("yi-9b", "softmax"), ("yi-9b", "lln_diag"),
    ("qwen3-14b", "softmax"), ("chatglm3-6b", "lln"),
    ("deepseek-v2-236b", "softmax"), ("deepseek-v2-236b", "lln_diag"),
    ("mamba2-130m", "softmax"), ("zamba2-7b", "softmax"),
    ("seamless-m4t-medium", "softmax"), ("paligemma-3b", "softmax"),
    ("qwen3-moe-235b-a22b", "softmax"),
])
def test_decode_consistency(arch, impl):
    """Greedy decode logits == teacher-forced forward logits at the same
    positions (the end-to-end correctness test for every cache type)."""
    cfg = get_config(arch, smoke=True, attn_impl=impl)
    # deterministic ffn path for exact comparisons: drop dropped tokens
    if cfg.n_experts:
        cfg = cfg.replace(capacity_factor=8.0)
    if impl in ("lln", "lln_diag"):
        # dynamic moment matching re-estimates (alpha, beta) from whatever
        # batch it sees, so prompt-time and full-sequence stats differ by
        # construction; the paper's fixed-alpha/beta mode (§A.8.4) makes the
        # serve path exactly comparable.
        cfg = cfg.replace(lln_fixed_ab=2.1)
    # bf16 noise scales with logit magnitude (embed_scale multiplies by
    # sqrt(d)) and with matmul-chain depth (MLA's low-rank decompositions;
    # hybrid stacks bf16 SSM recurrences on top of the attention path).
    tol = 0.3 if cfg.embed_scale else (
        0.15 if cfg.kv_lora else (0.1 if cfg.family == "hybrid" else 0.05))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_prompt, n_gen = 24, 6
    total = n_prompt + n_gen
    batch = synthetic_batch(cfg, batch=2, seq=total + cfg.num_prefix_tokens
                            if cfg.family == "vlm" else total)
    if cfg.family == "vlm":
        batch["inputs"] = batch["inputs"][:, :total]
    full_h, _ = model.hidden(params, batch)
    # teacher-forced logits at positions n_prompt-1 .. total-2
    from repro.models.transformer import lm_head_of
    head = params.get("lm_head") if isinstance(params, dict) else None
    if head is None:
        head = (params["lm_head"] if "lm_head" in params
                else params["embed"]["table"].T)
    from repro.models.layers import logits_from_hidden
    ref_logits = logits_from_hidden(head, full_h, cfg.cdtype,
                                    cfg.logit_softcap)

    prompt_batch = dict(batch)
    prompt_batch["inputs"] = batch["inputs"][:, :n_prompt]
    capacity = total + (cfg.num_prefix_tokens if cfg.family == "vlm" else 0)
    logits, caches = model.prefill(params, prompt_batch, capacity)
    last = logits[:, -1] if logits.ndim == 3 else logits
    np.testing.assert_allclose(np.asarray(last, np.float32),
                               np.asarray(ref_logits[:, n_prompt - 1],
                                          np.float32), atol=tol)
    pos = n_prompt + (cfg.num_prefix_tokens if cfg.family == "vlm" else 0)
    for t in range(n_gen - 1):
        tok = batch["inputs"][:, n_prompt + t]
        logits, caches = model.decode(params, caches, tok,
                                      jnp.asarray(pos + t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(ref_logits[:, n_prompt + t], np.float32), atol=tol)


def test_param_counts_full_configs():
    """Full (paper-exact) configs match the published parameter scales."""
    import math

    def count(cfg):
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        return sum(int(np.prod(s.shape))
                   for s in jax.tree_util.tree_leaves(shapes))

    expected = {"deepseek-v2-236b": 236e9, "qwen3-moe-235b-a22b": 235e9,
                "yi-9b": 8.8e9, "stablelm-1.6b": 1.6e9, "qwen3-14b": 14e9,
                "chatglm3-6b": 6.2e9, "mamba2-130m": 0.13e9,
                "zamba2-7b": 7e9, "paligemma-3b": 2.5e9}
    for arch, target in expected.items():
        n = count(get_config(arch))
        assert 0.7 * target < n < 1.45 * target, \
            f"{arch}: {n / 1e9:.2f}B vs expected {target / 1e9:.1f}B"
