"""Core LLN attention: the paper's math (Props 3.1/4.1, Thms 3.2-3.4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AttnConfig, LLNDecodeState, block_diag_attn,
                        decode_lln, lln_bidir, lln_causal,
                        multi_head_attention, naive_softmax)
from repro.core import metrics as M
from repro.core import moment_matching as mm
from repro.core.lln import prefill as lln_prefill


def _qkv(key, b=2, n=64, h=4, d=16, g=None):
    g = h if g is None else g
    kq, kk, kv = jax.random.split(key, 3)
    return (jax.random.normal(kq, (b, n, h, d)),
            jax.random.normal(kk, (b, n, g, d)),
            jax.random.normal(kv, (b, n, g, d)))


def _direct_lln(q, k, v, alpha, beta, causal):
    """Quadratic-form oracle straight from eq. 9."""
    fq = jnp.exp(alpha * q - jnp.max(alpha * q, axis=(1, 3), keepdims=True))
    fk = jnp.exp(beta * k - jnp.max(beta * k, axis=(1, 3), keepdims=True))
    s = jnp.einsum("bihd,bjhd->bhij", fq, fk)
    if causal:
        s = s * jnp.tril(jnp.ones(s.shape[-2:]))
    return jnp.einsum("bhij,bjhv->bihv",
                      s / (s.sum(-1, keepdims=True) + 1e-6), v)


class TestLLNForms:
    def test_causal_chunked_equals_quadratic(self):
        q, k, v = _qkv(jax.random.PRNGKey(0))
        out = lln_causal(q, k, v, 1.4, 1.1, chunk=16)
        ref = _direct_lln(q, k, v, 1.4, 1.1, True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-4)

    def test_bidir_equals_quadratic(self):
        q, k, v = _qkv(jax.random.PRNGKey(1))
        out = lln_bidir(q, k, v, 1.4, 1.1)
        ref = _direct_lln(q, k, v, 1.4, 1.1, False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-4)

    @pytest.mark.parametrize("chunk", [8, 16, 64])
    def test_chunk_invariance(self, chunk):
        q, k, v = _qkv(jax.random.PRNGKey(2))
        a = lln_causal(q, k, v, 1.0, 1.0, chunk=chunk)
        b = lln_causal(q, k, v, 1.0, 1.0, chunk=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)

    def test_stabilization_exactness(self):
        """Subtracting global constants must not change the output — the
        exact invariance used for bf16 safety (core/lln.py docstring)."""
        q, k, v = _qkv(jax.random.PRNGKey(3))
        big = lln_causal(q + 10.0, k + 10.0, v, 1.0, 1.0, chunk=16)
        # reference without shift applied to inputs shifted the same way
        ref = _direct_lln(q + 10.0, k + 10.0, v, 1.0, 1.0, True)
        np.testing.assert_allclose(np.asarray(big), np.asarray(ref),
                                   atol=2e-3)
        assert np.all(np.isfinite(np.asarray(big)))

    def test_decode_matches_full_forward(self):
        q, k, v = _qkv(jax.random.PRNGKey(4), n=48)
        alpha = jnp.full((4,), 1.3)
        beta = jnp.full((4,), 0.9)
        full = lln_causal(q, k, v, alpha, beta, chunk=16)
        out_pre, st = lln_prefill(q[:, :40], k[:, :40], v[:, :40], alpha,
                                  beta, chunk=16)
        np.testing.assert_allclose(np.asarray(out_pre),
                                   np.asarray(full[:, :40]), atol=2e-4)
        from repro.core.lln import decode_step
        for t in range(40, 48):
            out, st = decode_step(st, q[:, t:t + 1], k[:, t:t + 1],
                                  v[:, t:t + 1], alpha, beta)
            np.testing.assert_allclose(np.asarray(out[:, 0]),
                                       np.asarray(full[:, t]), atol=3e-4)

    def test_lln_diag_decode_matches_full(self):
        q, k, v = _qkv(jax.random.PRNGKey(5), g=2)
        cfg = AttnConfig(impl="lln_diag", causal=True, diag_block=16,
                         lln_chunk=16)
        alpha = jnp.full((4,), 1.2)
        beta = jnp.full((2,), 1.2)
        full = multi_head_attention(q, k, v, cfg, alpha=alpha, beta=beta)
        st = LLNDecodeState.init(2, 4, 16, 16, 16, jnp.float32)
        beta_h = jnp.repeat(beta, 2)
        outs = []
        for t in range(q.shape[1]):
            o, st = decode_lln(st, q[:, t:t + 1], k[:, t:t + 1],
                               v[:, t:t + 1], alpha, beta_h)
            outs.append(o)
        np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                                   np.asarray(full), atol=3e-4)


class TestPaperTheory:
    """Empirical checks of the paper's propositions and theorems."""

    def test_prop31_lognormality_of_softmax_attention(self):
        """Prop 3.1: P^(SM) is approximately log-normal."""
        key = jax.random.PRNGKey(0)
        kq, kk = jax.random.split(key)
        q = 1.2 * jax.random.normal(kq, (512, 64))
        k = 1.2 * jax.random.normal(kk, (512, 64))
        p = mm.softmax_attn_matrix(q, k)
        assert M.lognormality_score(p) > 0.99

    def test_prop31_variance_prediction(self):
        """Var[ln P^(SM)] ~= sigma_q^2 sigma_k^2 (d-scaled inputs)."""
        key = jax.random.PRNGKey(1)
        for sig in (1.0, 1.3):
            kq, kk = jax.random.split(jax.random.fold_in(key, int(sig * 10)))
            d = 64
            # a_ij = q.k/sqrt(d) has std sig^2 when q,k entries ~ N(0, sig^2)
            q = sig * jax.random.normal(kq, (1024, d))
            k = sig * jax.random.normal(kk, (1024, d))
            p = mm.softmax_attn_matrix(q, k)
            _, var = M.attention_log_moments(p)
            assert abs(float(var) - sig ** 4) / sig ** 4 < 0.15

    def test_prop41_lognormality_of_lln_attention(self):
        key = jax.random.PRNGKey(2)
        kq, kk = jax.random.split(key)
        q = jax.random.normal(kq, (512, 64))
        k = jax.random.normal(kk, (512, 64))
        p = mm.lln_attn_matrix(q, k, 2.1, 2.1)
        assert M.lognormality_score(p) > 0.98

    def test_moment_matching_matches_variance(self):
        """After eq. 10, Var[ln P^(LLN)] ~= Var[ln P^(SM)] (Fig. 5b)."""
        key = jax.random.PRNGKey(3)
        kq, kk = jax.random.split(key)
        d, sig = 64, 1.2
        q = sig * jax.random.normal(kq, (1024, d))
        k = sig * jax.random.normal(kk, (1024, d))
        a, b = mm.constants_for_dim(d)
        alpha, beta = mm.solve_alpha_beta(sig, sig, a, b)
        p_lln = mm.lln_attn_matrix(q, k, float(alpha), float(beta))
        p_sm = mm.softmax_attn_matrix(q, k)
        v_lln = float(M.attention_log_moments(p_lln)[1])
        v_sm = float(M.attention_log_moments(p_sm)[1])
        assert abs(v_lln - v_sm) / v_sm < 0.3
        # without matching (alpha=beta=1) the variance is far too small
        p_raw = mm.lln_attn_matrix(q, k, 1.0, 1.0)
        assert float(M.attention_log_moments(p_raw)[1]) < 0.3 * v_sm

    def test_alpha_beta_in_paper_range(self):
        """Fig. 9: moment matching lands alpha, beta in (2, 2.2) for unit-
        variance inputs (we allow a small tolerance around it)."""
        alpha, beta = mm.solve_alpha_beta(1.0, 1.0)
        assert 1.8 < float(alpha) < 2.6
        assert 1.8 < float(beta) < 2.6

    def test_thm32_entropy_monotone_in_temperature(self):
        key = jax.random.PRNGKey(4)
        scores = jax.random.normal(key, (64, 64))
        ents = []
        for tau in (0.25, 0.5, 1.0, 2.0, 4.0):
            p = jax.nn.softmax(scores / tau, axis=-1)
            ents.append(float(M.row_entropy(p)))
        assert all(a < b for a, b in zip(ents, ents[1:]))

    def test_thm34_variance_decreasing_in_temperature(self):
        key = jax.random.PRNGKey(5)
        scores = jax.random.normal(key, (64, 64))
        vs = []
        for tau in (0.25, 0.5, 1.0, 2.0, 4.0):
            p = jax.nn.softmax(scores / tau, axis=-1)
            vs.append(float(jnp.var(p)))
        assert all(a > b for a, b in zip(vs, vs[1:]))

    def test_thm33_spectral_identity(self):
        """Thm 3.3 building blocks:
        (a) Wielandt deflation: eigs(P - 1 mu^T) = {0} + {lambda_2..};
        (b) variance along the deflated matrix's top eigenvector direction
            equals lambda_2^2.
        NOTE (recorded in DESIGN.md): the paper's stronger phrasing — that
        lambda_2^2 equals the variance along the *major principal
        component* — holds exactly only for normal matrices; for a general
        stochastic matrix the major-PC variance upper-bounds lambda_2^2.
        We verify the provable identities and the symmetric-case equality.
        """
        rng = np.random.default_rng(0)
        logits = rng.normal(size=(48, 48))
        p = np.exp(logits)
        p /= p.sum(axis=1, keepdims=True)
        mu = p.mean(axis=0)
        pbar = p - np.ones((48, 1)) @ mu[None, :]
        ev_p = np.sort(np.abs(np.linalg.eigvals(p)))[::-1]
        ev_bar = np.sort(np.abs(np.linalg.eigvals(pbar)))[::-1]
        # (a) deflation removed lambda_1 = 1, kept the rest
        np.testing.assert_allclose(ev_bar[:5], ev_p[1:6], atol=1e-8)
        # (b) ||Pbar v2||^2 / ||v2||^2 == |lambda_2|^2
        w, vecs = np.linalg.eig(pbar)
        i2 = int(np.argmax(np.abs(w)))
        v2 = vecs[:, i2]
        var_dir = np.linalg.norm(pbar @ v2) ** 2 / np.linalg.norm(v2) ** 2
        np.testing.assert_allclose(var_dir, np.abs(w[i2]) ** 2, rtol=1e-8)
        # general case: major-PC variance >= lambda_2^2
        assert M.variance_along_pc(p) >= ev_p[1] ** 2 - 1e-9
        # symmetric (doubly-stochastic, via Sinkhorn) case: equality
        a = np.exp(0.3 * (logits + logits.T))
        for _ in range(200):
            d = 1.0 / np.sqrt(a.sum(axis=1))
            a = d[:, None] * a * d[None, :]
        ev_s = np.sort(np.abs(np.linalg.eigvalsh(a)))[::-1]
        np.testing.assert_allclose(M.variance_along_pc(a), ev_s[1] ** 2,
                                   rtol=1e-3)

    def test_spectral_gap_increases_with_temperature(self):
        rng = np.random.default_rng(1)
        logits = rng.normal(size=(48, 48))
        gaps = []
        for tau in (0.5, 1.0, 2.0, 4.0):
            p = np.exp(logits / tau)
            p /= p.sum(axis=1, keepdims=True)
            gaps.append(M.spectral_gap(p))
        assert gaps[-1] > gaps[0]

    def test_temperature_formulas(self):
        assert M.temperature_sm(1.0, 1.0) == 1.0
        assert M.temperature_sm(2.0, 1.0) == 0.5
        t = M.temperature_lln(2.0, 2.0, 1.0, 1.0, a=0.2, b=-0.7)
        assert t == pytest.approx(1.0 / np.sqrt(0.2 * 8 - 0.7))


class TestHybridLayer:
    def test_lln_diag_is_average(self):
        q, k, v = _qkv(jax.random.PRNGKey(6))
        cfg = dict(diag_block=16, lln_chunk=16)
        alpha = beta = jnp.full((4,), 1.3)
        h = multi_head_attention(q, k, v,
                                 AttnConfig(impl="lln_diag", causal=True,
                                            **cfg), alpha=alpha, beta=beta)
        l = multi_head_attention(q, k, v,
                                 AttnConfig(impl="lln", causal=True, **cfg),
                                 alpha=alpha, beta=beta)
        d = block_diag_attn(q, k, v, block=16, causal=True)
        np.testing.assert_allclose(np.asarray(h),
                                   np.asarray(0.5 * (l + d)), atol=1e-5)

    def test_block_diag_matches_naive_within_block(self):
        q, k, v = _qkv(jax.random.PRNGKey(7), n=32)
        out = block_diag_attn(q, k, v, block=16, causal=True)
        for blk in range(2):
            sl = slice(16 * blk, 16 * (blk + 1))
            ref = naive_softmax(q[:, sl], k[:, sl], v[:, sl], causal=True)
            np.testing.assert_allclose(np.asarray(out[:, sl]),
                                       np.asarray(ref), atol=2e-5)
