"""Property-based serving fuzz suite for the continuous-batching pool.

Random traffic schedules — prompt/generation lengths, admit/evict order
(driven by mixed budgets over few slots), deadlines, scripted fault
events, speculative on/off — run through :class:`ContinuousBatcher`, and
every harvested request is checked token-for-token against its solo
oracle (``make_serve_setup.make_generate`` for plain pools, the solo
``SpecSetup`` loop for speculative pools):

* status ``done``/``retried``  -> output EXACTLY equals the oracle, at
  exactly the request's budget;
* status ``timeout``/``failed`` -> the partial output is a PREFIX of the
  oracle (a harvested token is never wrong, only missing);
* every output is hard-capped at the budget (a speculative row may emit
  up to ``spec_k + 1`` tokens in its budget-expiry iteration — the
  overshoot must never surface).

A failing schedule prints a replayable FaultPlan-style JSON seed; feed it
back through :func:`run_schedule` to reproduce.  The tier-1 sweep is
small; the ``slow``-marked sweep runs 200+ schedules (``-m slow``).

The sweeps are deterministic: the hypothesis shim draws from a fixed
seed, prompts/budgets derive from the drawn schedule seed, and fault
plans are seeded scripts.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # container has no
    from _hypothesis_shim import given, settings       # hypothesis; use the
    from _hypothesis_shim import strategies as st      # deterministic shim

from repro.configs.base import ArchConfig, ShapeSpec
from repro.launch.batcher import ContinuousBatcher, Request
from repro.launch.faults import FaultPlan
from repro.launch.mesh import compat_mesh
from repro.launch.steps import (flatten_spec_tokens, make_pool_setup,
                                make_serve_setup, make_spec_setup)
from repro.models import build_model

SLOTS, SEGMENT, MAX_LEN = 2, 3, 48
SPEC_K, DRAFT_LAYERS = 2, 1
PROMPT_MENU = (6, 9)          # small menus bound the compile count
GEN_MENU = (1, 2, 4, 7)
#: The fuzzed impl axis: each schedule draws the attention state family —
#: lln_diag (O(d^2) state + diag tails) or log_linear (Fenwick bucket
#: pyramid).  Oracle parity over random admit/evict/quarantine+replay
#: schedules is exactly the "lifecycle preserves the bucket pyramid
#: bitwise" property: any merge/occupancy corruption changes tokens.
IMPL_MENU = ("lln_diag", "log_linear")


def _cfg(impl: str = "lln_diag"):
    h = 4
    return ArchConfig(
        name=f"pool-fuzz-{impl}", family="dense", n_layers=2, d_model=64,
        n_heads=h, n_kv_heads=h // 2, d_ff=128, vocab=128, head_dim=16,
        attn_impl=impl, diag_block=8, lln_chunk=8, softmax_chunk=16,
        lln_fixed_ab=2.1, lln_num_scales=3, compute_dtype="float32",
        param_dtype="float32", remat="none", tie_embeddings=True)


_STATE: dict = {}


def _pool(spec: bool, impl: str = "lln_diag"):
    """Module-cached pool (cfg, model, params, mesh, setup): every
    schedule reuses the same jitted executables."""
    key = ("pool", spec, impl)
    if key not in _STATE:
        cfg = _cfg(impl)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        mesh = compat_mesh((1, 1), ("data", "model"))
        with mesh:
            setup = make_pool_setup(
                cfg, mesh, slots=SLOTS, max_len=MAX_LEN, segment=SEGMENT,
                spec_k=SPEC_K if spec else 0,
                draft_layers=DRAFT_LAYERS if spec else 0)
        _STATE[key] = (cfg, model, params, mesh, setup)
    return _STATE[key]


def _oracle(spec: bool, impl: str, prompt: tuple,
            gen_len: int) -> np.ndarray:
    """Solo greedy reference for one request, cached per (prompt, len)."""
    key = ("oracle", spec, impl, prompt, gen_len)
    if key in _STATE:
        return _STATE[key]
    cfg, model, params, mesh, _ = _pool(spec, impl)
    plen = len(prompt)
    with mesh:
        if not spec:
            skey = ("serve", spec, impl, plen)
            if skey not in _STATE:
                shape = ShapeSpec("fuzz-solo", MAX_LEN, 1, "decode")
                _STATE[skey] = make_serve_setup(cfg, shape, mesh,
                                                multi_pod=False)
            ss = _STATE[skey]
            batch = {"inputs": jnp.asarray(prompt, jnp.int32)[None, :],
                     "targets": jnp.asarray(prompt, jnp.int32)[None, :],
                     "mask": jnp.ones((1, plen), jnp.float32)}
            logits, caches = ss.prefill_fn(params, batch)
            last = logits[:, -1] if logits.ndim == 3 else logits
            tok0 = jnp.argmax(last, -1).astype(jnp.int32)
            toks = [int(tok0[0])]
            if gen_len > 1:
                gkey = ("gen", spec, impl, plen, gen_len)
                if gkey not in _STATE:
                    _STATE[gkey] = ss.make_generate(gen_len - 1, 0.0)
                out, _ = _STATE[gkey](params, caches, tok0,
                                      jnp.asarray(plen, jnp.int32),
                                      jax.random.PRNGKey(0))
                toks.extend(int(t) for t in np.asarray(out)[0])
        else:
            skey = ("spec-solo", impl, plen)
            if skey not in _STATE:
                shape = ShapeSpec("fuzz-spec", MAX_LEN, 1, "decode")
                _STATE[skey] = make_spec_setup(cfg, shape, mesh,
                                               spec_k=SPEC_K,
                                               draft_layers=DRAFT_LAYERS)
            ss = _STATE[skey]
            logits, tgt, dr = ss.prefill_fn(
                params, {"inputs": jnp.asarray(prompt, jnp.int32)[None, :]})
            last = logits[:, -1] if logits.ndim == 3 else logits
            tok0 = jnp.argmax(last, -1).astype(jnp.int32)
            toks = [int(tok0[0])]
            steps = gen_len - 1
            if steps > 0:
                gkey = ("gen", spec, impl, plen, steps)
                if gkey not in _STATE:
                    _STATE[gkey] = ss.make_generate(steps, 0.0)
                t, n_emit, *_ = _STATE[gkey](
                    params, tgt, dr, tok0, jnp.asarray([plen], jnp.int32),
                    jax.random.PRNGKey(0))
                flat = flatten_spec_tokens(np.asarray(t),
                                           np.asarray(n_emit), steps)
                toks.extend(int(x) for x in flat[0])
    _STATE[key] = np.asarray(toks, np.int32)
    return _STATE[key]


def make_schedule(seed: int, spec: bool, n_req: int,
                  fault_mode: int, deadline_mode: int,
                  impl_idx: int = 0) -> dict:
    """Expand drawn knobs into a fully explicit, replayable schedule."""
    rng = np.random.RandomState(seed)
    vocab = 128
    reqs = []
    for rid in range(n_req):
        plen = int(PROMPT_MENU[rng.randint(len(PROMPT_MENU))])
        glen = int(GEN_MENU[rng.randint(len(GEN_MENU))])
        req = {"rid": rid, "gen_len": glen,
               "prompt": rng.randint(0, vocab, size=(plen,)).tolist()}
        if deadline_mode == 1 and rid == 0:
            req["deadline_s"] = 1e-6       # expires at the first boundary
        elif deadline_mode == 2:
            req["deadline_s"] = 300.0      # never fires
        if rng.rand() < 0.25:
            req["max_tokens"] = max(1, glen - 1)
        reqs.append(req)
    faults = []
    if fault_mode == 1:
        faults = [{"kind": "nan", "segment": 1}]
    elif fault_mode == 2:
        faults = [{"kind": "drop", "segment": 1, "rid": 0}]
    elif fault_mode == 3:
        faults = [{"kind": "delay", "segment": 1, "seconds": 0.002},
                  {"kind": "nan", "segment": 2}]
    return {"seed": seed, "spec": bool(spec),
            "impl": IMPL_MENU[impl_idx % len(IMPL_MENU)], "requests": reqs,
            "faults": {"seed": seed, "events": faults}}


def run_schedule(schedule: dict) -> None:
    """Run one schedule and assert the oracle-parity properties.  Feed a
    printed failure seed straight back in to reproduce."""
    spec = schedule["spec"]
    impl = schedule.get("impl", "lln_diag")
    cfg, model, params, mesh, setup = _pool(spec, impl)
    reqs = [Request(rid=r["rid"],
                    prompt=np.asarray(r["prompt"], np.int32),
                    gen_len=r["gen_len"],
                    deadline_s=r.get("deadline_s"),
                    max_tokens=r.get("max_tokens"))
            for r in schedule["requests"]]
    plan = (FaultPlan(**schedule["faults"])
            if schedule["faults"]["events"] else None)
    with mesh:
        eng = ContinuousBatcher(setup, params)
        stats = eng.run(reqs, key=jax.random.PRNGKey(schedule["seed"]),
                        fault_plan=plan)
    for req in reqs:
        status = stats.statuses.get(req.rid)
        assert status is not None, f"rid {req.rid} has no terminal status"
        got = np.asarray(stats.outputs[req.rid], np.int32)
        assert len(got) <= req.budget, \
            f"rid {req.rid}: harvested {len(got)} > budget {req.budget}"
        ref = _oracle(spec, impl, tuple(int(t) for t in req.prompt),
                      req.budget)
        if status in ("done", "retried"):
            assert len(got) == req.budget, \
                f"rid {req.rid}: {status} with {len(got)}/{req.budget}"
            np.testing.assert_array_equal(got, ref,
                                          err_msg=f"rid {req.rid}")
        elif status in ("timeout", "failed"):
            np.testing.assert_array_equal(
                got, ref[:len(got)],
                err_msg=f"rid {req.rid} (prefix, status={status})")


def _fuzz_one(seed, spec, n_req, fault_mode, deadline_mode, impl_idx=0):
    schedule = make_schedule(seed, spec, n_req, fault_mode, deadline_mode,
                             impl_idx)
    try:
        run_schedule(schedule)
    except AssertionError:
        print("\nreplayable schedule seed:\n"
              + json.dumps(schedule, indent=None))
        raise


class TestPoolFuzz:
    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 10**6), spec=st.booleans(),
           n_req=st.integers(1, 5), fault_mode=st.integers(0, 3),
           deadline_mode=st.integers(0, 2),
           impl_idx=st.integers(0, len(IMPL_MENU) - 1))
    def test_fuzz_quick(self, seed, spec, n_req, fault_mode,
                        deadline_mode, impl_idx):
        """Tier-1 smoke sweep (12 random schedules)."""
        _fuzz_one(seed, spec, n_req, fault_mode, deadline_mode, impl_idx)

    @pytest.mark.slow
    @settings(max_examples=200, deadline=None)
    @given(seed=st.integers(0, 10**6), spec=st.booleans(),
           n_req=st.integers(1, 5), fault_mode=st.integers(0, 3),
           deadline_mode=st.integers(0, 2),
           impl_idx=st.integers(0, len(IMPL_MENU) - 1))
    def test_fuzz_deep(self, seed, spec, n_req, fault_mode,
                       deadline_mode, impl_idx):
        """The deep sweep: 200 schedules, zero parity violations
        (``pytest -m slow tests/test_pool_fuzz.py``)."""
        _fuzz_one(seed, spec, n_req, fault_mode, deadline_mode, impl_idx)

    def test_replay_seed_roundtrip(self):
        """A printed failure seed replays: make_schedule -> JSON ->
        run_schedule is the documented reproduction loop."""
        schedule = make_schedule(1234, True, 3, 1, 0, impl_idx=1)
        run_schedule(json.loads(json.dumps(schedule)))
