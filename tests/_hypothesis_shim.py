"""Deterministic stand-in for `hypothesis` when it isn't installed.

The container image does not ship hypothesis and nothing may be pip
installed, so the property tests fall back to this shim: each strategy
draws from a seeded `random.Random`, and ``@given`` re-runs the test body
``max_examples`` times with fresh draws.  Shrinking, the example database
and `@example` are not emulated — the sweep is a plain randomized grid,
reproducible across runs because the seed is fixed.
"""
from __future__ import annotations

import functools
import inspect
import random


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


class strategies:
    integers = staticmethod(integers)
    sampled_from = staticmethod(sampled_from)
    booleans = staticmethod(booleans)
    floats = staticmethod(floats)


def settings(max_examples: int = 10, deadline=None, **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # ``@settings`` is applied above ``@given`` in this repo, so the
            # example count lands on the wrapper after decoration.
            n = getattr(wrapper, "_shim_max_examples", 10)
            rng = random.Random(0xC0FFEE)
            for _ in range(n):
                drawn = {name: s.draw(rng) for name, s in strats.items()}
                fn(*args, **kwargs, **drawn)

        # Hide the drawn parameters from pytest's fixture resolution (the
        # real hypothesis wrapper does the same): only e.g. ``self`` stays.
        sig = inspect.signature(fn)
        kept = [p for name, p in sig.parameters.items() if name not in strats]
        wrapper.__signature__ = sig.replace(parameters=kept)
        del wrapper.__wrapped__
        return wrapper
    return deco
