"""Smoke test: the log-linear state benchmark runs end-to-end.

Runs the smoke-scale cells.  The state-bytes and recall gates are
deterministic — they measure math and layout, not wall clock — and must
PASS even at smoke scale.  The decode-cost cell is wall-clock and too
noisy to hard-gate here; only its shape is checked (same policy as
``test_bench_longctx``).
"""
import json

from benchmarks.bench_loglinear import run
from benchmarks.ci_check import _loglinear_gates


def test_bench_loglinear_smoke(tmp_path):
    out = tmp_path / "BENCH_loglinear.json"
    report = run(str(out), smoke=True, verbose=False)
    assert out.exists()
    on_disk = json.loads(out.read_text())
    names = [r["name"] for r in on_disk["results"]]
    assert names == ["state_bytes", "recall", "decode_cost"]
    assert len(report["results"]) == len(on_disk["results"])

    rows = {r["name"]: r for r in on_disk["results"]}
    # Deterministic gates hold at any scale.
    sb = rows["state_bytes"]
    assert sb["pass"], sb
    assert sb["ratio_vs_ideal"] <= sb["gate_ratio"]
    assert sb["compression_vs_kv"] > 10.0       # logN*d^2 beats N*d by far
    rc = rows["recall"]
    assert rc["pass"], rc
    assert rc["log_linear"]["top1_acc"] >= rc["gate_acc"]
    assert rc["log_linear"]["top1_acc"] >= rc["lln"]["top1_acc"]
    assert rc["log_linear"]["cos_margin"] > rc["lln"]["cos_margin"]

    # Smoke wall clocks are too noisy to hard-gate; shape only.
    dc = rows["decode_cost"]
    assert dc["tok_s"]["lln"] > 0 and dc["tok_s"]["log_linear"] > 0
    assert isinstance(dc["overhead_ratio"], float)
    assert dc["gate_ratio"] == 3.0


def test_ci_check_gates_on_committed_report():
    """The committed repo-root BENCH_loglinear.json passes the ci_check
    gate validator (the same one CI applies)."""
    import os
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, "BENCH_loglinear.json")) as f:
        committed = json.load(f)
    assert _loglinear_gates(committed) == []
