"""End-to-end behaviour: the paper's system actually trains and serves.

* RoBERTa-style encoder with LLN+Diag attention learns the synthetic MLM
  task (loss decreases) — the §5 setting at smoke scale.
* LLN+Diag loss closely tracks softmax-attention loss over training — the
  paper's central convergence claim (Fig. 8a) at smoke scale.
* train driver + checkpoint restart round-trip through the CLI path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import lm_batches, mlm_batches
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update


def _train(cfg, batches, steps, lr=3e-3, seed=0):
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    state = adamw_init(params)
    opt_cfg = AdamWConfig(weight_decay=0.01)

    @jax.jit
    def step_fn(params, state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, state, _ = adamw_update(grads, state, params, lr, opt_cfg)
        return params, state, loss

    losses = []
    for _ in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        params, state, loss = step_fn(params, state, batch)
        losses.append(float(loss))
    return losses


def test_encoder_mlm_learns_with_lln_diag():
    cfg = get_config("roberta-lln", smoke=True)   # lln_diag by default
    assert cfg.attn_impl == "lln_diag"
    gen = mlm_batches(cfg.vocab, 8, 64, seed=0)
    losses = np.asarray(_train(cfg, gen, steps=60))
    # Variance-robust learning assertion.  The seed asserted a fixed 0.3
    # drop between 5-step endpoint means, which wobbled around its margin
    # with the step count (missed by ~0.01 on some hosts).  Learning ==
    # (a) the smoothed curve still trends DOWN over the latter 2/3 of
    # training (slope of a linear fit, robust to per-step noise), and
    # (b) the median loss dropped by a margin well above batch noise.
    w = 9
    smooth = np.convolve(losses, np.ones(w) / w, mode="valid")
    tail = smooth[smooth.size // 3:]
    slope = np.polyfit(np.arange(tail.size), tail, 1)[0]
    assert slope < 0, (slope, tail[:3], tail[-3:])
    drop = float(np.median(losses[:10]) - np.median(losses[-10:]))
    assert drop > 0.15, (drop, losses[:3], losses[-3:])


def test_lln_tracks_softmax_convergence():
    """Fig. 8a analog: |loss_lln - loss_sa| small throughout training."""
    steps = 30
    curves = {}
    for impl in ("softmax", "lln_diag"):
        cfg = get_config("roberta-lln", smoke=True, attn_impl=impl)
        gen = mlm_batches(cfg.vocab, 8, 64, seed=0)
        curves[impl] = np.asarray(_train(cfg, gen, steps=steps))
    gap = np.abs(curves["softmax"][-10:] - curves["lln_diag"][-10:]).mean()
    assert gap < 0.5, gap
    # both actually learned
    assert curves["lln_diag"][-5:].mean() < curves["lln_diag"][:5].mean()


def test_causal_lm_learns_markov():
    cfg = get_config("yi-9b", smoke=True, attn_impl="lln_diag")
    gen = lm_batches(cfg.vocab, 8, 64, seed=0)
    losses = _train(cfg, gen, steps=40)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


def test_train_cli_with_restart(tmp_path):
    """Driver-level fault-tolerance: run 6 steps, 'crash', resume to 10."""
    from repro.launch.train import main as train_main
    ckpt = str(tmp_path / "ckpt")
    h1 = train_main(["--arch", "stablelm-1.6b", "--smoke", "--steps", "6",
                     "--batch", "4", "--seq", "32", "--ckpt-dir", ckpt,
                     "--ckpt-interval", "2", "--log-every", "100"])
    h2 = train_main(["--arch", "stablelm-1.6b", "--smoke", "--steps", "10",
                     "--batch", "4", "--seq", "32", "--ckpt-dir", ckpt,
                     "--ckpt-interval", "2", "--log-every", "100"])
    assert h1[-1]["step"] == 5
    assert h2[0]["step"] >= 6, "resume must continue, not restart"
    assert h2[-1]["step"] == 9


def test_serve_cli_lln_state_decode():
    """O(d^2) LLN-state cache regime through the scanned generation loop."""
    from repro.launch.serve import main as serve_main
    toks = serve_main(["--arch", "chatglm3-6b", "--smoke", "--attn-impl",
                       "lln_diag", "--batch", "2", "--prompt-len", "24",
                       "--gen", "6"])
    assert toks.shape == (2, 6)


def test_serve_cli_softmax_kv_decode():
    """KV-cache regime end-to-end; --no-scan exercises the seed-style
    per-token dispatch loop kept as the benchmark baseline."""
    from repro.launch.serve import main as serve_main
    toks = serve_main(["--arch", "chatglm3-6b", "--smoke", "--attn-impl",
                       "softmax", "--batch", "2", "--prompt-len", "24",
                       "--gen", "6"])
    assert toks.shape == (2, 6)
    toks = serve_main(["--arch", "chatglm3-6b", "--smoke", "--attn-impl",
                       "softmax", "--batch", "2", "--prompt-len", "16",
                       "--gen", "4", "--no-scan", "--no-serve-kernel"])
    assert toks.shape == (2, 4)
