"""Mamba2 SSD: chunked dual form vs naive recurrence oracle; decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.ssm import (ssd_chunked, ssm_apply, ssm_cache_init,
                              ssm_decode, ssm_init)


def _naive_ssd(xbar, b_in, c_in, log_a):
    """Direct recurrence: h_t = a_t h_{t-1} + B_t xbar_t^T; y = C_t^T h_t."""
    bsz, l, h, p = xbar.shape
    s = b_in.shape[-1]
    state = np.zeros((bsz, h, s, p), np.float64)
    y = np.zeros((bsz, l, h, p), np.float64)
    xb = np.asarray(xbar, np.float64)
    bb = np.asarray(b_in, np.float64)
    cc = np.asarray(c_in, np.float64)
    la = np.asarray(log_a, np.float64)
    for t in range(l):
        a = np.exp(la[:, t])[:, :, None, None]
        state = a * state + np.einsum("bhs,bhp->bhsp", bb[:, t], xb[:, t])
        y[:, t] = np.einsum("bhs,bhsp->bhp", cc[:, t], state)
    return y, state


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_ssd_chunked_matches_recurrence(chunk):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    bsz, l, h, p, s = 2, 24, 3, 8, 4
    xbar = jax.random.normal(ks[0], (bsz, l, h, p))
    b_in = jax.random.normal(ks[1], (bsz, l, h, s))
    c_in = jax.random.normal(ks[2], (bsz, l, h, s))
    log_a = -jax.nn.softplus(jax.random.normal(ks[3], (bsz, l, h)))
    y, state = ssd_chunked(xbar, b_in, c_in, log_a, chunk=chunk)
    y_ref, state_ref = _naive_ssd(xbar, b_in, c_in, log_a)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state), state_ref, atol=1e-4)


def test_ssd_state0_continuation():
    """Splitting a sequence in two with state passing == one pass."""
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 4)
    bsz, l, h, p, s = 1, 32, 2, 8, 4
    xbar = jax.random.normal(ks[0], (bsz, l, h, p))
    b_in = jax.random.normal(ks[1], (bsz, l, h, s))
    c_in = jax.random.normal(ks[2], (bsz, l, h, s))
    log_a = -jax.nn.softplus(jax.random.normal(ks[3], (bsz, l, h)))
    y_full, st_full = ssd_chunked(xbar, b_in, c_in, log_a, chunk=8)
    y1, st1 = ssd_chunked(xbar[:, :16], b_in[:, :16], c_in[:, :16],
                          log_a[:, :16], chunk=8)
    y2, st2 = ssd_chunked(xbar[:, 16:], b_in[:, 16:], c_in[:, 16:],
                          log_a[:, 16:], chunk=8, state0=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               atol=1e-4)


def test_mamba_block_decode_matches_full():
    cfg = get_config("mamba2-130m", smoke=True).replace(
        compute_dtype="float32")
    p = ssm_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 20, cfg.d_model))
    full, cache_after = ssm_apply(p, x, cfg, return_state=True)
    cache = ssm_cache_init(cfg, 2)
    outs = []
    for t in range(20):
        o, cache = ssm_decode(p, x[:, t:t + 1], cache, cfg)
        outs.append(o)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-3)
    np.testing.assert_allclose(np.asarray(cache["state"]),
                               np.asarray(cache_after["state"]), atol=2e-3)


def test_ssd_long_decay_stability():
    """Large negative decay over a long chunk must not NaN (log-space)."""
    bsz, l, h, p, s = 1, 64, 2, 4, 4
    key = jax.random.PRNGKey(2)
    xbar = jax.random.normal(key, (bsz, l, h, p))
    b_in = jnp.ones((bsz, l, h, s))
    c_in = jnp.ones((bsz, l, h, s))
    log_a = jnp.full((bsz, l, h), -5.0)
    y, state = ssd_chunked(xbar, b_in, c_in, log_a, chunk=64)
    assert np.all(np.isfinite(np.asarray(y)))
    assert np.all(np.isfinite(np.asarray(state)))
