"""Smoke test: the speculative-decode bench runs end-to-end."""
import json

from benchmarks.bench_spec import run


def test_bench_spec_smoke(tmp_path):
    out = tmp_path / "BENCH_spec.json"
    rows = run(str(out), smoke=True, verbose=False)
    assert out.exists()
    on_disk = json.loads(out.read_text())
    assert len(on_disk["rows"]) == len(rows) == 2
    for row in on_disk["rows"]:
        assert row["us_per_call"] > 0
        assert 0.0 <= row["acceptance_rate"] <= 1.0
        # >= 1 by construction (every verify step commits at least one
        # token); > 1 whenever any draft survives.
        assert row["tokens_per_step"] >= 1.0
        # Single-pass verify: the score pass returns residuals and the
        # commit is an O(T d^2) fold, so each verify iteration dispatches
        # exactly ONE full target-transformer pass (gate <= 1.25 leaves
        # room for a fractional amortized extra, never a second pass).
        assert 1.0 <= row["target_passes_per_iter"] <= 1.25
        assert row["greedy_parity"] is True
    # The gated claim: the bench demonstrates tokens/step > 1 somewhere.
    assert any(r["tokens_per_step"] > 1.0 for r in on_disk["rows"])
