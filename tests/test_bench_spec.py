"""Smoke test: the speculative-decode bench runs end-to-end."""
import json

from benchmarks.bench_spec import run


def test_bench_spec_smoke(tmp_path):
    out = tmp_path / "BENCH_spec.json"
    rows = run(str(out), smoke=True, verbose=False)
    assert out.exists()
    on_disk = json.loads(out.read_text())
    assert len(on_disk["rows"]) == len(rows) == 2
    for row in on_disk["rows"]:
        assert row["us_per_call"] > 0
        assert 0.0 <= row["acceptance_rate"] <= 1.0
        # >= 1 by construction (every verify step commits at least one
        # token); > 1 whenever any draft survives.
        assert row["tokens_per_step"] >= 1.0
        assert row["greedy_parity"] is True
    # The gated claim: the bench demonstrates tokens/step > 1 somewhere.
    assert any(r["tokens_per_step"] > 1.0 for r in on_disk["rows"])
