"""Speculative decoding: the partial-commit contract + the accept/reject
loop.

* ``commit_len`` partial commit: scoring covers all T positions while the
  state (LLN ``(s, z, c_k)``, diag tails, softmax KV rows, ``pos``/``len``)
  folds exactly the accepted prefix — pinned against prefix-only decodes
  across the pallas/scan/ref backends, with ``commit_len=0`` bitwise equal
  to a masked row;
* acceptance rules (``core/speculative.py``): greedy longest-prefix match
  and residual resampling;
* the headline gate: greedy speculative decode
  (``launch/steps.py:make_spec_setup``) is token-for-token identical to
  the non-speculative scanned loop for softmax / lln / lln_diag ×
  GQA r ∈ {1, 4}, including runs where rows of one batch accept
  different numbers of draft tokens.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, ShapeSpec
from repro.core import attention as ca
from repro.core import lln as core_lln
from repro.core import speculative as spec
from repro.core.engine import AttentionEngine
from repro.kernels import ops as kops
from repro.kernels.registry import AttnSpec
from repro.launch.mesh import compat_mesh
from repro.launch.steps import (flatten_spec_tokens, make_serve_setup,
                                make_spec_setup)
from repro.models import build_model, draft_config, draft_params, \
    synthetic_batch


def _qkv(seed, b, n, h, g, d):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(kq, (b, n, h, d)),
            jax.random.normal(kk, (b, n, g, d)),
            jax.random.normal(kv, (b, n, g, d)))


def _tiny_cfg(impl, r, **kw):
    h = 4
    base = dict(
        name=f"spec-test-{impl}-r{r}", family="dense", n_layers=2,
        d_model=64, n_heads=h, n_kv_heads=h // r, d_ff=128, vocab=128,
        head_dim=16, attn_impl=impl, diag_block=8, lln_chunk=8,
        softmax_chunk=16,
        lln_fixed_ab=2.1 if impl != "softmax" else 0.0,
        compute_dtype="float32", param_dtype="float32", remat="none",
        tie_embeddings=True)
    base.update(kw)
    return ArchConfig(**base)


# ---------------------------------------------------------------------------
# Acceptance rules.
# ---------------------------------------------------------------------------

class TestAcceptRules:
    def test_greedy_verify_prefix_and_correction(self):
        v = 8
        # Row 0: target argmax agrees with drafts [3, 5] then extends 6.
        # Row 1: first draft rejected -> correction is argmax at pos 0.
        logits = np.full((2, 3, v), -5.0, np.float32)
        logits[0, 0, 3] = 5.0
        logits[0, 1, 5] = 5.0
        logits[0, 2, 6] = 5.0
        logits[1, 0, 7] = 5.0
        logits[1, 1, 1] = 5.0
        logits[1, 2, 2] = 5.0
        drafts = jnp.asarray([[3, 5], [4, 1]], jnp.int32)
        n_acc, nxt, commit = spec.greedy_verify(drafts,
                                                jnp.asarray(logits))
        assert np.asarray(n_acc).tolist() == [2, 0]
        assert np.asarray(nxt).tolist() == [6, 7]
        assert np.asarray(commit).tolist() == [3, 1]

    def test_greedy_no_acceptance_after_first_mismatch(self):
        """A later match behind a mismatch must NOT count."""
        v = 8
        logits = np.full((1, 4, v), -5.0, np.float32)
        for i, tok in enumerate([2, 9 % v, 4, 5]):
            logits[0, i, tok] = 5.0
        drafts = jnp.asarray([[2, 3, 4]], jnp.int32)   # pos 1 mismatches
        n_acc, nxt, commit = spec.greedy_verify(drafts,
                                                jnp.asarray(logits))
        assert int(n_acc[0]) == 1
        assert int(nxt[0]) == 9 % v
        assert int(commit[0]) == 2

    def test_emit_tokens_packing(self):
        drafts = jnp.asarray([[10, 11, 12], [20, 21, 22]], jnp.int32)
        n_acc = jnp.asarray([2, 0], jnp.int32)
        nxt = jnp.asarray([77, 88], jnp.int32)
        out = np.asarray(spec.emit_tokens(drafts, n_acc, nxt))
        assert out[0, :3].tolist() == [10, 11, 77]
        assert out[1, 0] == 88

    def test_residual_verify_identical_dists_accept_all(self):
        """draft dist == target dist => accept probability 1 everywhere,
        next token is the bonus sample."""
        b, k, v = 2, 3, 16
        logits = jax.random.normal(jax.random.PRNGKey(0), (b, k + 1, v))
        drafts = jnp.argmax(logits[:, :k], -1).astype(jnp.int32)
        n_acc, nxt, commit = spec.residual_verify(
            drafts, logits[:, :k], logits, jax.random.PRNGKey(1), 1.0)
        assert np.asarray(n_acc).tolist() == [k, k]
        assert np.asarray(commit).tolist() == [k + 1, k + 1]

    def test_residual_verify_rejects_zero_prob_draft(self):
        """A draft token the target gives ~zero probability is rejected,
        and the resample never returns it (zero residual mass there)."""
        b, k, v = 1, 1, 8
        tgt = np.full((b, 2, v), 0.0, np.float32)
        tgt[0, 0, 3] = 50.0            # target: all mass on 3
        tgt[0, 1, 4] = 50.0
        dr = np.full((b, 1, v), 0.0, np.float32)
        dr[0, 0, 6] = 50.0             # draft: all mass on 6
        drafts = jnp.asarray([[6]], jnp.int32)
        for seed in range(5):
            n_acc, nxt, _ = spec.residual_verify(
                drafts, jnp.asarray(dr), jnp.asarray(tgt),
                jax.random.PRNGKey(seed), 1.0)
            assert int(n_acc[0]) == 0
            assert int(nxt[0]) == 3

    def test_verify_tokens_dispatch(self):
        drafts = jnp.zeros((1, 2), jnp.int32)
        logits = jnp.zeros((1, 3, 8))
        n_acc, _, _ = spec.verify_tokens(drafts, logits, 0.0)
        assert n_acc.shape == (1,)
        with pytest.raises(ValueError, match="requires draft_logits"):
            spec.verify_tokens(drafts, logits, 1.0)
        with pytest.raises(ValueError, match="temperature > 0"):
            spec.residual_verify(drafts, logits[:, :2], logits,
                                 jax.random.PRNGKey(0), 0.0)


# ---------------------------------------------------------------------------
# The partial-commit contract.
# ---------------------------------------------------------------------------

class TestPartialCommit:
    def _lln_state(self, b, h, g, d, n0, seed=0):
        q, k, v = _qkv(seed, b, n0, h, g, d)
        alpha = jnp.full((h,), 1.3)
        beta = jnp.full((g,), 1.1)
        _, s, z, c_k = kops.lln_prefill(q, k, v, alpha, beta, chunk=8)
        return core_lln.LLNState(s=s, z=z, c_k=c_k), alpha, beta

    @pytest.mark.parametrize("backend", ["pallas", "scan", "ref"])
    @pytest.mark.parametrize("t", [3, 5])
    def test_commit_equals_prefix_decode(self, backend, t):
        """lln_decode_chunk(commit_len=c): outputs == full-chunk scoring,
        state == plain decode of the first c tokens — per row, on every
        backend, at odd T (the verify pass calls T = k+1)."""
        b, g, r, d = 3, 2, 2, 8
        h = g * r
        st, alpha, beta = self._lln_state(b, h, g, d, 24)
        qn, kn, vn = _qkv(7, b, t, h, g, d)
        cl = jnp.asarray([0, t // 2 + 1, t], jnp.int32)
        o_c, st_c = kops.lln_decode_chunk(st, qn, kn, vn, alpha, beta,
                                          backend=backend, commit_len=cl)
        o_f, st_f = kops.lln_decode_chunk(st, qn, kn, vn, alpha, beta,
                                          backend=backend)
        np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_f),
                                   rtol=2e-5, atol=2e-5)
        # Row 0 (commit 0): state bitwise preserved.
        for name in ("s", "z", "c_k"):
            np.testing.assert_array_equal(
                np.asarray(getattr(st_c, name))[0],
                np.asarray(getattr(st, name))[0], err_msg=name)
        # Row 2 (commit T): the plain full decode.
        for name in ("s", "z", "c_k"):
            np.testing.assert_allclose(
                np.asarray(getattr(st_c, name))[2],
                np.asarray(getattr(st_f, name))[2],
                rtol=2e-5, atol=2e-5, err_msg=name)
        # Row 1 (partial): decode of only the accepted prefix.
        c = t // 2 + 1
        _, st_p = kops.lln_decode_chunk(st, qn[:, :c], kn[:, :c],
                                        vn[:, :c], alpha, beta,
                                        backend=backend)
        for name in ("s", "z", "c_k"):
            np.testing.assert_allclose(
                np.asarray(getattr(st_c, name))[1],
                np.asarray(getattr(st_p, name))[1],
                rtol=2e-5, atol=2e-5, err_msg=name)

    @pytest.mark.parametrize("impl", ["softmax", "lln_diag"])
    def test_engine_verify_commit_zero_is_masked_row(self, impl):
        """engine.verify(commit_len=0) == decode(row_mask=False) on every
        state leaf, bitwise — and verify raises without commit_len."""
        b, t, g, r, d = 2, 3, 2, 2, 8
        h = g * r
        espec = AttnSpec(impl=impl, causal=True, r=r, lln_chunk=8,
                         diag_block=8, fixed_ab=2.1)
        eng = AttentionEngine(spec=espec, heads=h, kv_heads=g, head_dim=d,
                              v_dim=d, cache_dtype=jnp.float32)
        q0, k0, v0 = _qkv(0, b, 16, h, g, d)
        _, state = eng.prefill(q0, k0, v0, max_len=32)
        qn, kn, vn = _qkv(1, b, t, h, g, d)
        mask = jnp.zeros((b,), jnp.bool_)
        _, st_mask = eng.decode(state, qn, kn, vn, row_mask=mask)
        out, st_zero = eng.verify(state, qn, kn, vn,
                                  commit_len=jnp.zeros((b,), jnp.int32))
        for (kp, a), (_, bb) in zip(
                jax.tree_util.tree_leaves_with_path(st_zero),
                jax.tree_util.tree_leaves_with_path(st_mask)):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(bb),
                err_msg=f"{impl} {jax.tree_util.keystr(kp)}")
        # verify still scored every position (outputs are NOT garbage).
        out_ref, _ = eng.decode(state, qn, kn, vn)
        np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                                   rtol=2e-5, atol=2e-5)
        with pytest.raises(ValueError, match="commit_len"):
            eng.verify(state, qn, kn, vn, commit_len=None)

    @pytest.mark.parametrize("impl", ["softmax", "lln", "lln_diag"])
    def test_model_score_pass_touches_nothing(self, impl):
        """lm_decode(commit_len=0 everywhere) returns the chunk's logits
        AND leaves every cache leaf bitwise untouched — the verify score
        pass."""
        cfg = _tiny_cfg(impl, 2)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(1))
        b, plen, t = 2, 10, 4
        batch = synthetic_batch(cfg, batch=b, seq=plen + t)
        chunk = batch["inputs"][:, plen:plen + t]
        _, caches = model.prefill(
            params, {"inputs": batch["inputs"][:, :plen]}, plen + t + 4)
        pos = jnp.full((b,), plen, jnp.int32)
        lg_score, c_after = model.decode(
            params, caches, chunk, pos,
            commit_len=jnp.zeros((b,), jnp.int32))
        lg_plain, _ = model.decode(params, caches, chunk, pos)
        np.testing.assert_allclose(np.asarray(lg_score),
                                   np.asarray(lg_plain),
                                   rtol=2e-5, atol=2e-5)
        for (kp, a), (_, bb) in zip(
                jax.tree_util.tree_leaves_with_path(c_after),
                jax.tree_util.tree_leaves_with_path(caches)):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(bb),
                err_msg=f"{impl} {jax.tree_util.keystr(kp)}")

    def test_softmax_commit_rolls_back_length_not_scoring(self):
        """Softmax verify: all T draft keys are visible to scoring, but
        ``len`` advances only by the accepted prefix and a commit_len=0
        row's buffer is bitwise restored."""
        b, t, g, h, d, mx = 3, 4, 2, 4, 8, 32
        keys = jax.random.split(jax.random.PRNGKey(3), 5)
        k0 = jax.random.normal(keys[0], (b, 6, g, d))
        v0 = jax.random.normal(keys[1], (b, 6, g, d))
        cache = ca.KVCache(
            k=jnp.zeros((b, mx, g, d)).at[:, :6].set(k0),
            v=jnp.zeros((b, mx, g, d)).at[:, :6].set(v0),
            length=jnp.full((b,), 6, jnp.int32))
        q = jax.random.normal(keys[2], (b, t, h, d))
        kn = jax.random.normal(keys[3], (b, t, g, d))
        vn = jax.random.normal(keys[4], (b, t, g, d))
        cl = jnp.asarray([0, 2, 4], jnp.int32)
        out_c, cc = ca.decode_softmax(cache, q, kn, vn, commit_len=cl)
        out_f, _ = ca.decode_softmax(cache, q, kn, vn)
        np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_f),
                                   rtol=1e-5, atol=1e-5)
        assert np.asarray(cc.length).tolist() == [6, 8, 10]
        np.testing.assert_array_equal(np.asarray(cc.k)[0],
                                      np.asarray(cache.k)[0])
        with pytest.raises(ValueError, match="per-row"):
            ca.decode_softmax(
                ca.KVCache(k=cache.k, v=cache.v,
                           length=jnp.asarray(6, jnp.int32)),
                q, kn, vn, commit_len=cl)


# ---------------------------------------------------------------------------
# The tied first-k-layers draft.
# ---------------------------------------------------------------------------

class TestDraftModel:
    def test_draft_config_validates(self):
        cfg = _tiny_cfg("lln_diag", 2)
        assert draft_config(cfg, 1).n_layers == 1
        with pytest.raises(ValueError, match="draft_layers"):
            draft_config(cfg, 3)
        with pytest.raises(ValueError, match="draft_layers"):
            draft_config(cfg, 0)       # cfg.draft_layers defaults to 0

    def test_full_depth_draft_is_the_target(self):
        """draft_layers == n_layers: the sliced params ARE the target's
        (stacked leaves equal), so the draft's logits match the target's."""
        cfg = _tiny_cfg("lln_diag", 2)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        dp = draft_params(params, cfg, cfg.n_layers)
        for a, b in zip(jax.tree_util.tree_leaves(dp["layers"]),
                        jax.tree_util.tree_leaves(params["layers"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert dp["embed"] is params["embed"]

    def test_first_k_draft_params_slice(self):
        cfg = _tiny_cfg("lln_diag", 1)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(2))
        dp = draft_params(params, cfg, 1)
        lead = jax.tree_util.tree_leaves(dp["layers"])[0]
        full = jax.tree_util.tree_leaves(params["layers"])[0]
        assert lead.shape[0] == 1 and full.shape[0] == cfg.n_layers
        np.testing.assert_array_equal(np.asarray(lead),
                                      np.asarray(full[:1]))


# ---------------------------------------------------------------------------
# The headline gate: spec greedy == non-spec greedy, token for token.
# ---------------------------------------------------------------------------

def _run_pair(cfg, draft_layers, spec_k, steps, bsz=2, plen=12, seed=0):
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    max_len = plen + steps + spec_k + 2
    mesh = compat_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("spec", max_len, bsz, "decode")
    batch = synthetic_batch(cfg, bsz, max_len, text_seq=plen)
    with mesh:
        serve = make_serve_setup(cfg, shape, mesh, multi_pod=False)
        logits, caches = serve.prefill_fn(params, batch)
        tok0 = jnp.argmax(logits[:, -1] if logits.ndim == 3 else logits,
                          -1).astype(jnp.int32)
        gen = serve.make_generate(steps, 0.0)
        ref, _ = gen(params, caches, tok0, jnp.asarray(plen, jnp.int32),
                     jax.random.PRNGKey(0))

        sp = make_spec_setup(cfg, shape, mesh, spec_k=spec_k,
                             draft_layers=draft_layers)
        lg, tc, dc = sp.prefill_fn(params, batch)
        tok0s = jnp.argmax(lg[:, -1] if lg.ndim == 3 else lg,
                           -1).astype(jnp.int32)
        sgen = sp.make_generate(steps, 0.0)
        toks, n_emit, n_acc, live, *_ = sgen(
            params, tc, dc, tok0s, jnp.asarray(plen, jnp.int32),
            jax.random.PRNGKey(0))
    got = flatten_spec_tokens(toks, n_emit, steps)
    return got, np.asarray(ref), np.asarray(n_acc), np.asarray(live)


class TestSpecParity:
    @pytest.mark.parametrize("r", [1, 4])
    @pytest.mark.parametrize("impl", ["softmax", "lln", "lln_diag"])
    def test_spec_greedy_matches_scanned_loop(self, impl, r):
        """Greedy draft-then-verify (imperfect first-1-layer draft, so
        accept/reject genuinely fires) emits token-for-token the
        non-speculative scanned loop's sequence."""
        cfg = _tiny_cfg(impl, r)
        got, ref, n_acc, live = _run_pair(cfg, draft_layers=1, spec_k=3,
                                          steps=9, seed=r)
        np.testing.assert_array_equal(got, ref)
        # The draft is imperfect: BOTH branches of accept/reject must have
        # fired — some drafts accepted, some rejected (the chosen seeds
        # guarantee it; all-accept or all-reject would leave half the
        # partial-commit machinery unexercised).
        drafted = live.sum() * 3
        assert 0 < n_acc.sum() < drafted, (
            f"acceptance degenerate: {n_acc.sum()}/{drafted}")

    def test_rows_accept_different_counts(self):
        """Rows of one batch accept different numbers of draft tokens in
        the same verify step — positions, commits and emits diverge per
        row — and parity still holds."""
        cfg = _tiny_cfg("lln_diag", 2)
        got, ref, n_acc, live = _run_pair(cfg, draft_layers=1, spec_k=3,
                                          steps=9, seed=0)
        np.testing.assert_array_equal(got, ref)
        both_live = live.all(axis=0)
        diff = (n_acc[0] != n_acc[1]) & both_live
        assert diff.any(), (
            "expected at least one verify step where the two rows accept "
            f"different draft counts; got n_acc={n_acc.tolist()}")

    def test_tied_full_draft_accepts_everything(self):
        """draft_layers == n_layers: the draft IS the target, so greedy
        acceptance is ~total and tokens/step approaches k+1."""
        cfg = _tiny_cfg("lln_diag", 2)
        k, steps = 3, 8
        got, ref, n_acc, live = _run_pair(cfg, draft_layers=cfg.n_layers,
                                          spec_k=k, steps=steps)
        np.testing.assert_array_equal(got, ref)
        acc = n_acc.sum() / max(live.sum() * k, 1)
        assert acc > 0.9, f"tied draft acceptance {acc:.2f}"

    def test_spec_temperature_sampling_runs(self):
        """Residual-resampling path: the loop runs, emits the requested
        token budget, and positions stay consistent (distribution-level
        correctness is pinned at the rule level)."""
        cfg = _tiny_cfg("lln_diag", 2)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        bsz, plen, steps, k = 2, 12, 6, 2
        max_len = plen + steps + k + 2
        mesh = compat_mesh((1, 1), ("data", "model"))
        shape = ShapeSpec("spec", max_len, bsz, "decode")
        batch = synthetic_batch(cfg, bsz, max_len, text_seq=plen)
        with mesh:
            sp = make_spec_setup(cfg, shape, mesh, spec_k=k,
                                 draft_layers=1)
            lg, tc, dc = sp.prefill_fn(params, batch)
            tok0 = jnp.argmax(lg[:, -1] if lg.ndim == 3 else lg,
                              -1).astype(jnp.int32)
            sgen = sp.make_generate(steps, temperature=0.8)
            toks, n_emit, n_acc, live, *_ = sgen(
                params, tc, dc, tok0, jnp.asarray(plen, jnp.int32),
                jax.random.PRNGKey(3))
        flat = flatten_spec_tokens(toks, n_emit, steps)
        assert flat.shape == (bsz, steps)
        # sample_token draws over the padded head (as everywhere else).
        assert (flat >= 0).all() and (flat < cfg.padded_vocab).all()
