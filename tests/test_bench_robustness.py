"""Smoke test: the robustness benchmark runs end-to-end (interpret mode)."""
import json

from benchmarks.bench_robustness import run


def test_bench_robustness_smoke(tmp_path):
    out = tmp_path / "BENCH_robustness.json"
    report = run(str(out), smoke=True, repeats=1, verbose=False)
    assert out.exists()
    on_disk = json.loads(out.read_text())
    assert on_disk["modes"].keys() == {"sentinel_off", "sentinel_on"}
    assert len(on_disk["results"]) == len(report["results"]) == 1
    for row in on_disk["results"]:
        assert row["tok_s"]["sentinel_off"] > 0
        assert row["tok_s"]["sentinel_on"] > 0
        assert row["gate_pct"] == 2.0
        # Smoke cells are too noisy to hard-gate, but the measurement
        # itself must be well-formed.
        assert isinstance(row["overhead_pct"], float)
        assert row["traffic"]["useful_tokens"] == sum(
            [3, 3, 9, 3][:row["traffic"]["requests"]])
