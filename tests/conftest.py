import os
import sys

# Tests run single-device (the 512-device override belongs ONLY to the
# dry-run, which always runs in its own subprocess).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
