import os
import sys

import pytest

# Tests run single-device (the 512-device override belongs ONLY to the
# dry-run, which always runs in its own subprocess).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

#: Markers deselected from the default tier-1 run (``pytest -x -q``).
#: Passing any ``-m`` expression takes over selection entirely, so
#: ``-m slow`` / ``-m soak`` / ``-m "slow or not slow"`` opt back in.
_DEFAULT_DESELECT = ("slow", "soak")


@pytest.fixture(autouse=True, scope="module")
def _drop_compiled_programs_between_modules():
    """Free each module's compiled executables when it finishes.  The
    full suite compiles thousands of tiny programs; letting them pile up
    in one process has produced native crashes in XLA:CPU's JIT late in
    the run.  Shapes barely repeat across modules, so the lost cache
    reuse is negligible."""
    yield
    jax.clear_caches()


def pytest_collection_modifyitems(config, items):
    if config.getoption("-m"):
        return
    skip = {name: pytest.mark.skip(
        reason=f"tier-2 ({name}): run with -m {name}")
        for name in _DEFAULT_DESELECT}
    for item in items:
        for name in _DEFAULT_DESELECT:
            if name in item.keywords:
                item.add_marker(skip[name])


# ---------------------------------------------------------------------------
# The canonical backend-parity sweep: ONE source of truth for the
# pallas/scan/ref × impl × GQA grids that test_registry.py, test_serve.py
# and test_longctx.py used to copy-paste.  softmax × pallas is excluded
# (an invalid AttnSpec — there is no softmax pallas kernel).
# ---------------------------------------------------------------------------

PARITY_BACKENDS = ("pallas", "scan", "ref")
PARITY_IMPLS = ("softmax", "lln", "lln_diag", "log_linear")
PARITY_GQA = (1, 4)


def _cells(impls):
    return [pytest.param((b, i, r), id=f"{b}-{i}-r{r}")
            for i in impls for b in PARITY_BACKENDS for r in PARITY_GQA
            if not (i == "softmax" and b == "pallas")]


@pytest.fixture(params=_cells(("lln", "lln_diag", "log_linear")))
def lln_parity_cell(request):
    """(backend, impl, r) over the LLN attention ops (kernels/ops.py).
    ``log_linear`` is causal-only — tests sweeping a causal axis skip the
    non-causal cells for it."""
    return request.param


@pytest.fixture(params=_cells(PARITY_IMPLS))
def engine_parity_cell(request):
    """(backend, impl, r) over the AttentionEngine (softmax included)."""
    return request.param


@pytest.fixture(params=[pytest.param((b, r), id=f"{b}-r{r}")
                        for b in PARITY_BACKENDS for r in PARITY_GQA])
def backend_gqa_cell(request):
    """(backend, r) for impl-agnostic LLN state ops (prefill / decode
    chunk / renorm), where the impl axis does not exist."""
    return request.param


@pytest.fixture(params=[pytest.param((i, r), id=f"{i}-r{r}")
                        for i in PARITY_IMPLS for r in PARITY_GQA])
def impl_gqa_cell(request):
    """(impl, r) for model-level parity sweeps that dispatch backend=auto
    (end-to-end serve / pool tests)."""
    return request.param
