"""Deprecation-shim guard.

Every legacy entry point superseded by the AttentionEngine must (a) emit a
``DeprecationWarning`` exactly once per process, and (b) delegate to the
engine-era replacement (same returns, no forked math).  If a shim grows its
own logic again, or the warning disappears, this file fails.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import attention as ca
from repro.core import lln as core_lln
from repro.kernels import registry
from repro.models import attention_block as ab
from repro.models import mla as mla_mod

SHIMS = [
    (ab, "attn_cache_init"),
    (ab, "attn_prefill"),
    (ab, "attn_decode"),
    (mla_mod, "mla_cache_init"),
]


def _cfg(**kw):
    base = dict(name="shim-test", family="dense", n_layers=1, d_model=32,
                n_heads=4, n_kv_heads=2, d_ff=64, vocab=64, head_dim=8,
                attn_impl="lln_diag", diag_block=8, lln_chunk=8,
                softmax_chunk=16, lln_fixed_ab=2.1, compute_dtype="float32",
                param_dtype="float32", remat="none", tie_embeddings=True)
    base.update(kw)
    return ArchConfig(**base)


def _mla_cfg():
    return _cfg(kv_lora=32, q_lora=24, rope_head_dim=8, nope_head_dim=16,
                v_head_dim=16, n_kv_heads=4, head_dim=None)


@pytest.fixture(autouse=True)
def _fresh_deprecations():
    registry.reset_deprecations()
    yield
    registry.reset_deprecations()


def _call(mod, name):
    cfg = _mla_cfg() if mod is mla_mod else _cfg()
    if name.endswith("cache_init"):
        return getattr(mod, name)(cfg, 2, 16)
    p = ab.attn_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    if name == "attn_prefill":
        return mod.attn_prefill(p, x, cfg, jnp.arange(8), max_len=16)
    _, st = ab.serve_prefill(p, x, cfg, jnp.arange(8), max_len=16)
    x1 = x[:, :1]
    return mod.attn_decode(p, x1, st, cfg, jnp.asarray(8, jnp.int32))


class TestWarnOnce:
    @pytest.mark.parametrize("mod,name", SHIMS,
                             ids=[n for _, n in SHIMS])
    def test_shim_warns_exactly_once(self, mod, name):
        fn = getattr(mod, name)
        assert getattr(fn, "__deprecated_shim__", None), \
            f"{name} is not marked as a deprecation shim"
        with pytest.warns(DeprecationWarning, match="deprecated"):
            _call(mod, name)
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            _call(mod, name)        # second call: no warning

    def test_decode_lln_warns_exactly_once(self):
        b, h, d = 1, 2, 4
        st = core_lln.LLNState.init(b, h, d, d)
        dst = ca.LLNDecodeState(lln=st,
                                tail_k=jnp.zeros((b, 4, h, d)),
                                tail_v=jnp.zeros((b, 4, h, d)),
                                pos=jnp.zeros((b,), jnp.int32))
        q = jnp.ones((b, 1, h, d))
        with pytest.warns(DeprecationWarning, match="decode_lln"):
            ca.decode_lln(dst, q, q, q, 1.0, 1.0, impl="lln")
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            ca.decode_lln(dst, q, q, q, 1.0, 1.0, impl="lln")


class TestShimsUnderJit:
    """Warn-once bookkeeping must survive ``jax.jit``: the warning fires
    at trace time (once per process), cached executions must not re-fire,
    and a re-trace at a new shape must not re-fire either — and the shim
    must keep delegating correctly from inside a traced context."""

    def test_shim_warns_once_across_traced_calls(self):
        cfg = _cfg()
        p = ab.attn_init(jax.random.PRNGKey(0), cfg)

        @jax.jit
        def decode_via_shim(x, st, x1):
            return ab.attn_decode(p, x1, st, cfg,
                                  jnp.full((x.shape[0],), x.shape[1],
                                           jnp.int32))

        def args(b):
            x = jax.random.normal(jax.random.PRNGKey(1), (b, 8,
                                                          cfg.d_model))
            _, st = ab.serve_prefill(p, x, cfg, jnp.arange(8), max_len=16)
            return x, st, x[:, :1]

        with pytest.warns(DeprecationWarning, match="attn_decode"):
            out1, _ = decode_via_shim(*args(2))     # first trace: warns
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            decode_via_shim(*args(2))               # cached: no trace
            decode_via_shim(*args(3))               # re-trace: no re-fire

        # The traced shim delegates: same numbers as the canonical path.
        x, st, x1 = args(2)
        ref, _ = ab.serve_decode(p, x1, st, cfg,
                                 jnp.full((2,), 8, jnp.int32))
        got, _ = decode_via_shim(x, st, x1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)

    def test_shim_inside_jit_after_eager_warmup(self):
        """An eager shim call burns the once-per-process warning; tracing
        the same shim under jit afterwards must stay silent (the
        bookkeeping is shared, not per-context)."""
        cfg = _cfg()
        with pytest.warns(DeprecationWarning):
            ab.attn_cache_init(cfg, 2, 16)
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            jitted = jax.jit(lambda: ab.attn_cache_init(cfg, 2, 16))
            jitted()


class TestDelegation:
    def test_attn_cache_init_delegates(self, monkeypatch):
        sentinel = object()
        monkeypatch.setattr(ab, "serve_state_init",
                            lambda *a, **k: sentinel)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            assert ab.attn_cache_init(_cfg(), 2, 16) is sentinel

    def test_attn_prefill_decode_delegate(self, monkeypatch):
        calls = []
        monkeypatch.setattr(ab, "serve_prefill",
                            lambda *a, **k: calls.append("prefill"))
        monkeypatch.setattr(ab, "serve_decode",
                            lambda *a, **k: calls.append("decode"))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            ab.attn_prefill(None, None, _cfg(), None)
            ab.attn_decode(None, None, None, _cfg(), None)
        assert calls == ["prefill", "decode"]

    def test_mla_cache_init_delegates(self, monkeypatch):
        sentinel = object()
        monkeypatch.setattr(mla_mod, "mla_state_init",
                            lambda *a, **k: sentinel)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            assert mla_mod.mla_cache_init(_mla_cfg(), 2, 16) is sentinel

    def test_shim_outputs_match_canonical(self):
        """The shim returns the canonical function's exact pytree."""
        cfg = _cfg()
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            old = ab.attn_cache_init(cfg, 2, 16)
        new = ab.serve_state_init(cfg, 2, 16)
        for a, b in zip(jax.tree_util.tree_leaves(old),
                        jax.tree_util.tree_leaves(new)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
