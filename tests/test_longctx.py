"""Length-robustness contracts (PR 7).

Four promises, each with its own class:

* **renorm is semantics-preserving and backend-uniform** — the drift
  renormalization (``core/lln.py:decode_chunk(renorm=...)``) changes no
  output on any backend, never touches masked / ``commit_len=0`` rows
  (bitwise), and a continuation from a renormalized state matches one
  from the raw state;
* **beta(n) reduces to the fixed calibration** at ``n <= calib_len`` —
  the length schedule is exactly inert where the shipped constants were
  fit, and the length-aware constant table returns the legacy entries
  there;
* **serving parity survives the robustness layer** — a mixed-depth pool
  with renorm + beta(n) on matches solo runs token-for-token, drifting
  rows quarantine through the sentinel path, and the fused telemetry is
  produced inside ``segment_fn``'s jit;
* **estimators** — the power-iteration spectral gap matches the dense
  eigendecomposition, the seeded fit reproduces the shipped constants,
  and masked ``update_stats`` ignores padding.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import lln
from repro.core import moment_matching as mm
from repro.core.health import HealthConfig
from repro.core.metrics import (spectral_gap, spectral_gap_power,
                                streaming_concentration)
from repro.kernels import ops as kops
from repro.launch.batcher import ContinuousBatcher, synthetic_traffic
from repro.launch.mesh import compat_mesh
from repro.launch.steps import make_pool_setup
from repro.models import build_model

B, H, D, DV, T = 2, 4, 8, 8, 12


def _qkv(key, t=T, g=H):
    kq, kk, kv = jax.random.split(key, 3)
    return (jax.random.normal(kq, (B, t, H, D), jnp.float32),
            jax.random.normal(kk, (B, t, g, D), jnp.float32),
            jax.random.normal(kv, (B, t, g, DV), jnp.float32))


def _warm_state(key, steps=3):
    """A state that has folded a few chunks (c_k bound, z populated)."""
    st = lln.LLNState.init(B, H, D, DV)
    for i in range(steps):
        q, k, v = _qkv(jax.random.fold_in(key, i))
        _, st = lln.decode_chunk(st, q, k, v, 0.6, 0.6)
    return st


class TestRenormSemantics:
    def test_outputs_invariant_and_continuation_matches(self):
        """Force renorm with a tiny threshold: outputs match the
        renorm-off run, z is pinned under the threshold, and decoding ON
        from the renormalized state matches decoding on from the raw
        state."""
        key = jax.random.PRNGKey(0)
        st = _warm_state(key)
        thresh = float(jnp.max(st.z)) * 0.5     # guaranteed to fire
        q, k, v = _qkv(jax.random.fold_in(key, 100))
        out_off, st_off = lln.decode_chunk(st, q, k, v, 0.6, 0.6)
        out_on, st_on = lln.decode_chunk(st, q, k, v, 0.6, 0.6,
                                         renorm=thresh)
        np.testing.assert_allclose(np.asarray(out_on), np.asarray(out_off),
                                   rtol=2e-5, atol=2e-5)
        assert float(jnp.max(st_on.z)) <= thresh * (1 + 1e-5)
        assert float(jnp.max(st_on.log_scale)) > 0.0
        q2, k2, v2 = _qkv(jax.random.fold_in(key, 101))
        cont_off, _ = lln.decode_chunk(st_off, q2, k2, v2, 0.6, 0.6)
        cont_on, _ = lln.decode_chunk(st_on, q2, k2, v2, 0.6, 0.6)
        np.testing.assert_allclose(np.asarray(cont_on),
                                   np.asarray(cont_off),
                                   rtol=2e-5, atol=2e-5)

    def test_backend_uniform(self, backend_gqa_cell):
        """Every backend (Pallas kernel incl. GQA grouping, scan/ref
        twins) applies the renormalization with the same semantics."""
        backend, r = backend_gqa_cell
        g = H // r
        key = jax.random.PRNGKey(1)
        st = _warm_state(key)
        thresh = float(jnp.max(st.z)) * 0.5
        q, k, v = _qkv(jax.random.fold_in(key, 200), g=g)
        kf = k if g == H else jnp.repeat(k, H // g, axis=2)
        vf = v if g == H else jnp.repeat(v, H // g, axis=2)
        ref_out, ref_st = lln.decode_chunk(st, q, kf, vf, 0.6, 0.6,
                                           renorm=thresh)
        got_out, got_st = kops.lln_decode_chunk(st, q, k, v, 0.6, 0.6,
                                                backend=backend,
                                                renorm=thresh)
        np.testing.assert_allclose(np.asarray(got_out),
                                   np.asarray(ref_out),
                                   rtol=2e-4, atol=2e-4)
        assert float(jnp.max(got_st.z)) <= thresh * (1 + 1e-4)
        # z / c_k / log_scale are gauge: the Pallas GQA path carries a
        # group-level reference constant where the twin keeps per-head
        # ones.  The invariant is the c-corrected log mass.
        def mass(st):
            return streaming_concentration(
                st.z, c=jnp.squeeze(st.c_k, axis=(-1, -3)))["log_mass"]
        np.testing.assert_allclose(np.asarray(mass(got_st)),
                                   np.asarray(mass(ref_st)),
                                   rtol=2e-4, atol=2e-4)

    def test_bitwise_inert_for_masked_and_uncommitted_rows(self):
        """A renorm threshold NEVER touches rows that folded nothing this
        chunk: row_mask=False and commit_len=0 rows keep every leaf —
        including ``log_scale`` — bitwise."""
        key = jax.random.PRNGKey(2)
        st = _warm_state(key)
        thresh = float(jnp.max(st.z)) * 0.5
        q, k, v = _qkv(jax.random.fold_in(key, 300))
        for kwargs in ({"row_mask": jnp.asarray([True, False])},
                       {"commit_len": jnp.asarray([T, 0], jnp.int32)}):
            _, st2 = lln.decode_chunk(st, q, k, v, 0.6, 0.6,
                                      renorm=thresh, **kwargs)
            for name in ("s", "z", "c_k", "log_scale"):
                old = np.asarray(getattr(st, name))
                new = np.asarray(getattr(st2, name))
                np.testing.assert_array_equal(
                    old[1] if name != "c_k" else old[1:2],
                    new[1] if name != "c_k" else new[1:2],
                    err_msg=f"{name} {kwargs.keys()}")
            # ... and the folding row DID renormalize.
            assert float(np.max(np.asarray(st2.z)[0])) <= thresh * (1 + 1e-5)


class TestLengthSchedule:
    def test_gain_exactly_one_at_or_below_calib(self):
        n = jnp.asarray([1.0, 100.0, float(mm.CALIB_LEN)])
        np.testing.assert_array_equal(
            np.asarray(mm.length_gain(n, beta_n=0.7)), np.ones(3))
        assert float(mm.length_gain(jnp.asarray(4.0 * mm.CALIB_LEN),
                                    beta_n=0.7)) > 1.0

    def test_constants_reduce_to_legacy_at_short_n(self):
        for d in mm.FITTED_CONSTANTS:
            assert mm.constants_for_dim(d, n=None) == mm.FITTED_CONSTANTS[d]
            assert mm.constants_for_dim(d, n=512) == mm.FITTED_CONSTANTS[d]
            assert (mm.constants_for_dim(d, n=mm.CALIB_LEN)
                    == mm.FITTED_CONSTANTS[d])
            long = mm.constants_for_dim(d, n=4096)
            assert long == mm.FITTED_CONSTANTS_N[d][4096]

    def test_beta_n_inert_below_calib_token_parity(self):
        """With every depth in the run <= calib_len, a beta_n > 0 model
        decodes bitwise like beta_n = 0 — the schedule reduces to the
        fixed calibration."""
        h = 4
        base = dict(family="dense", n_layers=2, d_model=64, n_heads=h,
                    n_kv_heads=h, d_ff=128, vocab=128, head_dim=16,
                    attn_impl="lln_diag", diag_block=8, lln_chunk=8,
                    softmax_chunk=16, lln_fixed_ab=0.0,
                    compute_dtype="float32", param_dtype="float32",
                    remat="none", tie_embeddings=True)
        cfg0 = ArchConfig(name="sched-off", lln_beta_n=0.0, **base)
        cfg1 = ArchConfig(name="sched-on", lln_beta_n=0.7,
                          lln_calib_len=1024, **base)
        toks = {}
        for cfg in (cfg0, cfg1):
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            mesh = compat_mesh((1, 1), ("data", "model"))
            with mesh:
                setup = make_pool_setup(cfg, mesh, slots=2, max_len=32,
                                        segment=3)
                stats = ContinuousBatcher(setup, params).run(
                    synthetic_traffic(2, cfg.vocab, [8], [6], seed=0))
            toks[cfg.name] = [stats.outputs[r] for r in sorted(stats.outputs)]
        for a, b in zip(toks["sched-off"], toks["sched-on"]):
            np.testing.assert_array_equal(a, b)


def _robust_cfg(name, **over):
    h = 4
    return ArchConfig(
        name=name, family="dense", n_layers=2, d_model=64, n_heads=h,
        n_kv_heads=h, d_ff=128, vocab=128, head_dim=16,
        attn_impl="lln_diag", diag_block=8, lln_chunk=8, softmax_chunk=16,
        lln_fixed_ab=0.0, lln_beta_n=0.5, lln_calib_len=4,
        lln_renorm=4.0, compute_dtype="float32", param_dtype="float32",
        remat="none", tie_embeddings=True, **over)


class TestPoolRobustness:
    def test_mixed_depth_pool_matches_solo(self):
        """Renorm + beta(n) BOTH engaged (calib_len=4 < every depth,
        renorm threshold low enough to fire): mixed-depth pooled rows
        still decode token-for-token like solo runs — per-row gain off
        ``state.pos`` and per-row renorm do not couple slots."""
        cfg = _robust_cfg("robust-pool")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        max_len = 40
        reqs = synthetic_traffic(4, cfg.vocab, prompt_lens=[8, 8, 14],
                                 gen_lens=[3, 9, 5], seed=11)
        mesh = compat_mesh((1, 1), ("data", "model"))
        with mesh:
            setup = make_pool_setup(cfg, mesh, slots=2, max_len=max_len,
                                    segment=3)
            stats = ContinuousBatcher(setup, params).run(reqs)
            # Solo reference via the pool machinery at 1 slot: same
            # engine, no slot interleaving, no mixed depths.
            solo_setup = make_pool_setup(cfg, mesh, slots=1,
                                         max_len=max_len, segment=3)
            for req in reqs:
                solo = ContinuousBatcher(solo_setup, params).run([req])
                np.testing.assert_array_equal(
                    stats.outputs[req.rid], solo.outputs[req.rid],
                    err_msg=f"rid {req.rid}")

    def test_drift_quarantine_reuses_recovery_path(self):
        """check_drift with an absurd threshold quarantines every live
        row: health events are recorded and retries exhaust into failed
        statuses — the same path corruption takes."""
        cfg = _robust_cfg("robust-drift")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        mesh = compat_mesh((1, 1), ("data", "model"))
        with mesh:
            setup = make_pool_setup(
                cfg, mesh, slots=2, max_len=32, segment=3,
                health=HealthConfig(check_drift=True, max_conc_drift=1e-6))
            eng = ContinuousBatcher(setup, params, max_retries=1)
            stats = eng.run(synthetic_traffic(2, cfg.vocab, [8], [6],
                                              seed=0))
        assert stats.health_events
        assert all(s == "failed" for s in stats.statuses.values())

    def test_telemetry_fused_in_segment_and_surfaced(self):
        """segment_fn returns the metrics dict from inside its jit; the
        run summary surfaces finite instruments; softmax pools and
        telemetry=False report empty."""
        cfg = _robust_cfg("robust-tele")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        mesh = compat_mesh((1, 1), ("data", "model"))
        with mesh:
            setup = make_pool_setup(cfg, mesh, slots=2, max_len=32,
                                    segment=3)
            stats = ContinuousBatcher(setup, params).run(
                synthetic_traffic(2, cfg.vocab, [8], [6], seed=0))
            assert set(stats.telemetry) == {"conc_drift_max",
                                            "log_mass_mean",
                                            "log_mass_var_mean",
                                            "tau_hat_mean"}
            assert all(np.isfinite(v) for v in stats.telemetry.values())

            off = make_pool_setup(cfg, mesh, slots=2, max_len=32,
                                  segment=3, telemetry=False)
            stats_off = ContinuousBatcher(off, params).run(
                synthetic_traffic(2, cfg.vocab, [8], [6], seed=0))
            assert stats_off.telemetry == {}

            sm = cfg.replace(name="tele-sm", attn_impl="softmax",
                             lln_beta_n=0.0, lln_renorm=0.0)
            sm_model = build_model(sm)
            sm_params = sm_model.init(jax.random.PRNGKey(0))
            sm_setup = make_pool_setup(sm, mesh, slots=2, max_len=32,
                                       segment=3)
            sm_stats = ContinuousBatcher(sm_setup, sm_params).run(
                synthetic_traffic(2, sm.vocab, [8], [6], seed=0))
            assert sm_stats.telemetry == {}


class TestStreamingInstruments:
    def test_log_mass_renorm_invariant(self):
        """Same stream, renorm on vs off: the c_k-corrected log mass
        agrees to rounding (the renorm shift folds into c_k)."""
        key = jax.random.PRNGKey(5)
        st_off = lln.LLNState.init(B, H, D, DV)
        st_on = lln.LLNState.init(B, H, D, DV)
        for i in range(6):
            q, k, v = _qkv(jax.random.fold_in(key, i))
            _, st_off = lln.decode_chunk(st_off, q, k, v, 0.6, 0.6)
            _, st_on = lln.decode_chunk(st_on, q, k, v, 0.6, 0.6,
                                        renorm=2.0)

        def mass(st):
            return streaming_concentration(
                st.z, c=jnp.squeeze(st.c_k, axis=(-1, -3)),
                log_scale=st.log_scale)["log_mass"]

        assert float(jnp.max(st_on.log_scale)) > 0.0    # renorm fired
        np.testing.assert_allclose(np.asarray(mass(st_on)),
                                   np.asarray(mass(st_off)),
                                   rtol=1e-5, atol=1e-5)

    def test_spectral_gap_power_matches_dense(self):
        rng = np.random.default_rng(0)
        for n, conc in ((24, 0.5), (48, 2.0), (48, 8.0)):
            logits = conc * rng.standard_normal((n, n))
            p = np.exp(logits - logits.max(axis=-1, keepdims=True))
            p /= p.sum(axis=-1, keepdims=True)
            dense = spectral_gap(p)
            power = spectral_gap_power(p, iters=400)
            assert abs(power - dense) < 0.02, (n, conc, dense, power)

    def test_fit_pins_shipped_constants(self):
        """The seeded fit reproduces the shipped tables: exactly the grid
        entry it generated (same seed, same env), and the legacy defaults
        within a drift tolerance (they were fit under an older stack)."""
        a, b = mm.fit_lln_constants(d=64, n=1024, num_seeds=4, seed=0)
        ga, gb = mm.FITTED_CONSTANTS_N[64][1024]
        assert abs(a - ga) < 5e-3 and abs(b - gb) < 5e-2, (a, b, ga, gb)
        la, lb = mm.FITTED_CONSTANTS[64]
        assert abs(a - la) < 2e-2 and abs(b - lb) < 1.5e-1, (a, b, la, lb)

    def test_update_stats_mask_ignores_padding(self):
        """Masked update on a padded batch == unmasked update on the
        dense batch; the unmasked padded update is polluted toward 0."""
        key = jax.random.PRNGKey(9)
        kq, kk = jax.random.split(key)
        q = jax.random.normal(kq, (2, 6, H, D), jnp.float32)
        k = 2.0 * jax.random.normal(kk, (2, 6, H, D), jnp.float32)
        mask = jnp.asarray([[1, 1, 1, 1, 0, 0], [1, 1, 0, 0, 0, 0]],
                           jnp.float32)
        qp = q * mask[:, :, None, None]
        kp = k * mask[:, :, None, None]
        st0 = mm.QKStats.init(H)
        got = mm.update_stats(st0, qp, kp, decay=0.5, mask=mask)
        # Dense reference: only the real tokens, flattened into one row.
        keep = np.asarray(mask).astype(bool)
        qd = jnp.asarray(np.asarray(q)[keep])[None]
        kd = jnp.asarray(np.asarray(k)[keep])[None]
        want = mm.update_stats(st0, qd, kd, decay=0.5)
        np.testing.assert_allclose(np.asarray(got.sigma_q),
                                   np.asarray(want.sigma_q), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(got.sigma_k),
                                   np.asarray(want.sigma_k), rtol=1e-6)
        polluted = mm.update_stats(st0, qp, kp, decay=0.5)
        assert float(jnp.max(polluted.sigma_k)) < float(jnp.max(got.sigma_k))
