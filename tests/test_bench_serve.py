"""Smoke test: the serving benchmark runs end-to-end (interpret mode)."""
import json

from benchmarks.bench_serve import run


def test_bench_serve_smoke(tmp_path):
    out = tmp_path / "BENCH_serve.json"
    report = run(str(out), smoke=True, repeats=1, verbose=False)
    assert out.exists()
    on_disk = json.loads(out.read_text())
    assert on_disk["modes"].keys() == {"seed", "kernel"}
    assert len(on_disk["results"]) == len(report["results"]) == 2
    for row in on_disk["results"]:
        assert row["prefill_us"]["seed"] > 0
        assert row["prefill_us"]["kernel"] > 0
        assert row["prefill_speedup"] > 0
        assert row["decode"]["seed_loop_tok_s"] > 0
        assert row["decode"]["scan_tok_s"] > 0
        assert row["decode_chunk"]["speedup"] > 0
