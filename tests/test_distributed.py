"""Distribution: sharding rules, straggler watchdog, elastic mesh logic,
and true multi-device behaviour via subprocesses (8 host-platform devices).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.distributed.elastic import viable_mesh_shapes
from repro.distributed.straggler import StepWatchdog
from repro.launch.mesh import make_smoke_mesh

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


class TestShardingRules:
    def test_fit_spec_divisibility(self):
        mesh = make_smoke_mesh(1, 1)
        spec = shd.fit_spec(P("data", "model"), (7, 5), mesh)
        assert spec == P(None, None) or spec == P("data", "model")

    def test_fit_spec_dedup(self):
        mesh = make_smoke_mesh(1, 1)
        spec = shd.fit_spec(P(("data", "model"), None, "model"), (4, 4, 4),
                            mesh)
        flat = [a for s in spec if s for a in
                (s if isinstance(s, tuple) else (s,))]
        assert len(flat) == len(set(flat))

    def test_param_specs_cover_all_archs(self):
        mesh = make_smoke_mesh(1, 1)
        from repro.models import build_model
        for arch in ("yi-9b", "deepseek-v2-236b", "mamba2-130m",
                     "zamba2-7b", "paligemma-3b", "seamless-m4t-medium"):
            cfg = get_config(arch, smoke=True)
            model = build_model(cfg)
            tree = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            specs = shd.param_specs(tree, mesh)
            assert (len(jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P)))
                    == len(jax.tree_util.tree_leaves(tree)))

    def test_make_rules_policies(self):
        cfg = get_config("qwen3-14b")
        r = shd.make_rules(cfg, multi_pod=False)
        assert r["heads"] is None and r["attn_seq"] == "model"
        cfg = get_config("yi-9b")
        r = shd.make_rules(cfg, multi_pod=True)
        assert r["heads"] == "model" and r["act_batch"] == ("pod", "data")
        cfg = get_config("mamba2-130m")
        r = shd.make_rules(cfg, multi_pod=False)
        assert "model" in r["act_batch"]


class TestStraggler:
    def test_watchdog_flags_outlier(self):
        import time as _time
        wd = StepWatchdog(k=3.0, warmup_steps=1)
        calls = []
        wd.on_anomaly = calls.append
        for i in range(8):
            wd.start()
            wd._t0 -= 0.01          # pretend 10ms steps
            wd.stop(i)
        wd.start()
        wd._t0 -= 1.0               # 1s straggler
        rep = wd.stop(99)
        assert rep is not None and rep.step == 99 and calls


class TestElastic:
    def test_viable_shapes(self):
        shapes = viable_mesh_shapes(128, prefer_model=16)
        assert shapes[0] == (8, 16)
        assert (128, 1) in shapes

    def test_reshard_between_meshes_subprocess(self):
        """Save on a (2,4) mesh, restore + reshard on (4,2): the elastic
        restart path with a genuinely different device assignment."""
        out = _run_subprocess("""
            import jax, jax.numpy as jnp, numpy as np, tempfile, os
            from repro.checkpoint.checkpointer import save, restore
            from repro.distributed.sharding import param_shardings
            d = tempfile.mkdtemp()
            from repro.launch.mesh import compat_mesh
            mesh1 = compat_mesh((2, 4), ("data", "model"))
            tree = {"layers": {"q_w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
            tree = jax.device_put(tree, param_shardings(tree, mesh1))
            save(d, 1, tree)
            mesh2 = compat_mesh((4, 2), ("data", "model"))
            template = jax.tree_util.tree_map(
                lambda a: jnp.zeros(a.shape, a.dtype), tree)
            out = restore(d, 1, template, param_shardings(template, mesh2))
            q = out["layers"]["q_w"]
            assert len(q.sharding.device_set) == 8
            np.testing.assert_allclose(np.asarray(q),
                                       np.arange(64).reshape(8, 8))
            print("RESHARD_OK")
        """)
        assert "RESHARD_OK" in out

    def test_reshard_serving_pool_decode_parity_subprocess(self):
        """Elastic-serving path: a live continuous-batching pool
        (``AttentionState`` caches with row axis 1) built on a (2,4) mesh
        survives losing devices — ``make_degraded_mesh`` on the surviving
        prefix + ``reshard_state`` of params AND pool caches onto the
        smaller mesh, then a full decode segment emits token-for-token
        the same stream as the healthy mesh would have."""
        out = _run_subprocess("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs.base import ArchConfig
            from repro.distributed.elastic import (make_degraded_mesh,
                                                   reshard_state)
            from repro.launch.mesh import compat_mesh
            from repro.launch.steps import make_pool_setup
            from repro.models import build_model

            cfg = ArchConfig(
                name="elastic-pool", family="dense", n_layers=2,
                d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                head_dim=16, attn_impl="lln_diag", diag_block=8,
                lln_chunk=8, softmax_chunk=16, lln_fixed_ab=2.1,
                compute_dtype="float32", param_dtype="float32",
                remat="none", tie_embeddings=True)
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                        cfg.vocab, jnp.int32)
            tok = jnp.zeros((2,), jnp.int32).at[0].set(7)
            pos = jnp.zeros((2,), jnp.int32).at[0].set(8)
            remaining = jnp.zeros((2,), jnp.int32).at[0].set(4)
            active = jnp.asarray([True, False])
            key = jax.random.PRNGKey(2)

            mesh1 = compat_mesh((2, 4), ("data", "model"))
            with mesh1:
                setup1 = make_pool_setup(cfg, mesh1, slots=2, max_len=32,
                                         segment=4)
                def build(setup):
                    _, sc = setup.prefill_fn(8)(params, prompt)
                    return setup.admit_fn(setup.cache_init(), sc,
                                          jnp.asarray([0], jnp.int32))
                # Reference segment on the healthy mesh (donates caches).
                out1 = setup1.segment_fn(params, build(setup1), tok, pos,
                                         remaining, active, key)
                toks_ref, em_ref = np.asarray(out1[5]), np.asarray(out1[6])
                caches = build(setup1)          # fresh copy to carry over

            # 3 of 8 devices die -> largest pow-2 prefix of 5 is 4.
            mesh2 = make_degraded_mesh(jax.devices()[:5], prefer_model=2)
            assert mesh2.devices.size == 4, mesh2
            params2 = reshard_state(params, mesh2)
            caches2 = reshard_state(caches, mesh2)
            with mesh2:
                setup2 = make_pool_setup(cfg, mesh2, slots=2, max_len=32,
                                         segment=4)
                out2 = setup2.segment_fn(params2, caches2, tok, pos,
                                         remaining, active, key)
            np.testing.assert_array_equal(em_ref, np.asarray(out2[6]))
            np.testing.assert_array_equal(toks_ref[:, 0],
                                          np.asarray(out2[5])[:, 0])
            print("ELASTIC_POOL_OK", mesh2.shape)
        """)
        assert "ELASTIC_POOL_OK" in out

    def test_degraded_mesh_subprocess(self):
        out = _run_subprocess("""
            import jax
            from repro.distributed.elastic import make_degraded_mesh
            # 8 devices, pretend 3 died -> largest pow2 prefix of 5 = 4
            mesh = make_degraded_mesh(jax.devices()[:5], prefer_model=4)
            assert mesh.devices.size == 4, mesh
            print("DEGRADED_OK", mesh.shape)
        """)
        assert "DEGRADED_OK" in out


class TestMultiDeviceTraining:
    def test_sharded_train_step_subprocess(self):
        """Two real pjit train steps on an (2,4) mesh: loss finite, state
        sharded, gradients synchronized."""
        out = _run_subprocess("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_config
            from repro.configs.base import ShapeSpec
            from repro.launch.steps import make_train_setup
            from repro.models import build_model, synthetic_batch
            from repro.optim import adamw_init

            cfg = get_config("yi-9b", smoke=True, attn_impl="lln_diag")
            from repro.launch.mesh import compat_mesh
            mesh = compat_mesh((2, 4), ("data", "model"))
            shape = ShapeSpec("t", 32, 4, "train")
            with mesh:
                setup = make_train_setup(cfg, shape, mesh, multi_pod=False)
                model = build_model(cfg)
                params = model.init(jax.random.PRNGKey(0))
                state = jax.device_put(
                    {"params": params, "opt": adamw_init(params)},
                    setup.state_shardings)
                batch = synthetic_batch(cfg, 4, 32)
                batch = jax.device_put(batch, {k: v.sharding for k, v in setup.batch.items()})
                losses = []
                for _ in range(2):
                    state, metrics = setup.step_fn(state, batch)
                    losses.append(float(metrics["loss"]))
                assert all(np.isfinite(l) for l in losses), losses
                w = state["params"]["layers"]["attn"]["q_w"]
                assert len(w.sharding.device_set) == 8
                print("TRAIN_OK", losses)
        """)
        assert "TRAIN_OK" in out

    def test_serve_decode_sharded_subprocess(self):
        out = _run_subprocess("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_config
            from repro.configs.base import ShapeSpec
            from repro.launch.steps import make_serve_setup
            from repro.models import build_model, synthetic_batch

            cfg = get_config("yi-9b", smoke=True)
            from repro.launch.mesh import compat_mesh
            mesh = compat_mesh((2, 4), ("data", "model"))
            shape = ShapeSpec("s", 48, 4, "decode")
            with mesh:
                setup = make_serve_setup(cfg, shape, mesh, multi_pod=False)
                model = build_model(cfg)
                params = jax.device_put(model.init(jax.random.PRNGKey(0)),
                                        setup.params_shardings)
                batch = synthetic_batch(cfg, 4, 48, text_seq=32)
                logits, caches = setup.prefill_fn(params, batch)
                caches = jax.device_put(caches, setup.cache_shardings)
                tok = jnp.argmax(logits[:, -1] if logits.ndim == 3 else logits,
                                 -1).astype(jnp.int32)
                for i in range(3):
                    logits, caches = setup.decode_fn(
                        params, caches, tok, jnp.asarray(32 + i, jnp.int32))
                    tok = jnp.argmax(logits, -1).astype(jnp.int32)
                assert np.all(np.isfinite(np.asarray(logits, np.float32)))
                print("SERVE_OK")
        """)
        assert "SERVE_OK" in out
