"""MoE layer: sort-based dispatch correctness vs a dense loop reference,
capacity dropping, aux loss, and the shard_map path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.moe import (_moe_local, _positions_in_expert, _route,
                              moe_apply, moe_init)


def _cfg(**kw):
    base = get_config("qwen3-moe-235b-a22b", smoke=True)
    return base.replace(**kw)


def _dense_reference(p, x, cfg):
    """Loop-over-experts oracle (no capacity limit)."""
    idx, w, _ = _route(x, p["router_w"], cfg.top_k)
    t, d = x.shape
    out = np.zeros((t, d), np.float32)
    xg = np.asarray(x, np.float32)
    for e in range(cfg.n_experts):
        wi_g = np.asarray(p["exp_wi_gate"][e], np.float32)
        wi_u = np.asarray(p["exp_wi_up"][e], np.float32)
        wo = np.asarray(p["exp_wo"][e], np.float32)
        g = xg @ wi_g
        u = xg @ wi_u
        h = (g / (1 + np.exp(-g))) * u          # silu(g) * u
        y = h @ wo
        for slot in range(cfg.top_k):
            sel = np.asarray(idx[:, slot]) == e
            out[sel] += np.asarray(w[:, slot])[sel, None] * y[sel]
    return out


def test_positions_in_expert():
    flat = jnp.asarray([2, 0, 2, 1, 0, 2], jnp.int32)
    pos = np.asarray(_positions_in_expert(flat, 3))
    # expert 0 -> slots 1,4 get 0,1; expert 2 -> slots 0,2,5 get 0,1,2
    assert pos[1] == 0 and pos[4] == 1
    assert pos[0] == 0 and pos[2] == 1 and pos[5] == 2
    assert pos[3] == 0


def test_moe_matches_dense_reference_no_drop():
    cfg = _cfg(capacity_factor=50.0)   # no drops
    key = jax.random.PRNGKey(0)
    p = moe_init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model),
                          jnp.float32)
    cfg32 = cfg.replace(compute_dtype="float32")
    out, aux = _moe_local(x, p, cfg32, 0, cfg.n_experts, jnp.float32)
    ref = _dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-4)
    assert float(aux) > 0.9   # balance loss ~1 for near-uniform routing


def test_capacity_dropping_reduces_norm():
    cfg_tight = _cfg(capacity_factor=0.25)
    cfg_loose = _cfg(capacity_factor=50.0)
    p = moe_init(jax.random.PRNGKey(0), cfg_tight)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg_tight.d_model))
    out_t, _ = _moe_local(x, p, cfg_tight.replace(compute_dtype="float32"),
                          0, cfg_tight.n_experts, jnp.float32)
    out_l, _ = _moe_local(x, p, cfg_loose.replace(compute_dtype="float32"),
                          0, cfg_loose.n_experts, jnp.float32)
    assert float(jnp.linalg.norm(out_t)) < float(jnp.linalg.norm(out_l))


def test_expert_sharding_partition_sums():
    """Sum of per-shard partial outputs == single-shard full output (the
    psum-over-'model' invariant)."""
    cfg = _cfg().replace(compute_dtype="float32")
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, cfg.d_model))
    full, _ = _moe_local(x, p, cfg, 0, cfg.n_experts, jnp.float32)
    half = cfg.n_experts // 2

    def shard(lo, hi):
        q = dict(p)
        for k in ("exp_wi_gate", "exp_wi_up", "exp_wo"):
            q[k] = p[k][lo:hi]
        return q
    a, _ = _moe_local(x, shard(0, half), cfg, 0, half, jnp.float32)
    b, _ = _moe_local(x, shard(half, cfg.n_experts), cfg, half, half,
                      jnp.float32)
    np.testing.assert_allclose(np.asarray(a + b), np.asarray(full),
                               atol=1e-4)


def test_moe_apply_shard_map_path():
    """moe_apply under a (1,1) mesh exercises the shard_map code path and
    must agree with the meshless local path."""
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_smoke_mesh
    cfg = _cfg(n_shared_experts=1).replace(compute_dtype="float32")
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    out_local, aux_local = moe_apply(p, x, cfg)
    mesh = make_smoke_mesh(1, 1)
    with shd.logical_rules(mesh, shd.make_rules(cfg, multi_pod=False)):
        out_mesh, aux_mesh = jax.jit(lambda p, x: moe_apply(p, x, cfg))(p, x)
    np.testing.assert_allclose(np.asarray(out_mesh), np.asarray(out_local),
                               atol=1e-4)
    np.testing.assert_allclose(float(aux_mesh), float(aux_local), rtol=1e-5)
