"""Docs-rot guard: README / docs code blocks must keep resolving.

Checks, against README.md, docs/serving.md and benchmarks/README.md:
* every ``import``/``from ... import`` of first-party modules inside a
  fenced code block resolves;
* every ``python -m <module>`` command names an importable module;
* every backticked repo path (``src/...``, ``docs/...``, ...) exists;
* every ``<file>.py:<symbol>`` reference points at a real attribute.

If a module moves, this fails before the docs quietly rot.
"""
import importlib
import importlib.util
import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
DOCS = [ROOT / "README.md", ROOT / "docs" / "serving.md",
        ROOT / "docs" / "api.md", ROOT / "benchmarks" / "README.md"]
FIRST_PARTY = ("repro", "benchmarks")


def _code_blocks(text: str):
    return re.findall(r"```[a-zA-Z]*\n(.*?)```", text, re.S)


def test_docs_exist():
    for doc in DOCS:
        assert doc.exists(), doc


@pytest.mark.parametrize("doc", DOCS, ids=lambda d: d.name)
def test_code_block_imports_resolve(doc):
    pat = re.compile(r"^\s*(?:from\s+([\w.]+)\s+import\b|import\s+([\w.]+))",
                     re.M)
    for block in _code_blocks(doc.read_text()):
        for m in pat.finditer(block):
            mod = m.group(1) or m.group(2)
            if mod.split(".")[0] in FIRST_PARTY:
                importlib.import_module(mod)   # raises if the module moved


@pytest.mark.parametrize("doc", DOCS, ids=lambda d: d.name)
def test_cli_entry_points_exist(doc):
    mods = re.findall(r"python\s+-m\s+([\w.]+)", doc.read_text())
    if doc.name in ("README.md", "serving.md"):
        assert mods, f"{doc.name} lost its runnable commands"
    for mod in mods:
        assert importlib.util.find_spec(mod) is not None, (doc.name, mod)


@pytest.mark.parametrize("doc", DOCS, ids=lambda d: d.name)
def test_backticked_paths_exist(doc):
    pat = re.compile(
        r"`((?:src|docs|benchmarks|examples|tests)/[\w\-./]*[\w\-/])`")
    for path in pat.findall(doc.read_text()):
        assert (ROOT / path).exists(), (doc.name, path)


@pytest.mark.parametrize("doc", DOCS, ids=lambda d: d.name)
def test_symbol_references_resolve(doc):
    """``core/attention.py:decode_lln_chunk``-style references."""
    pat = re.compile(r"`(?:src/repro/)?([\w/]+)\.py:(\w+)`")
    for rel, sym in pat.findall(doc.read_text()):
        if not (ROOT / "src" / "repro" / f"{rel}.py").exists():
            continue                      # not a repro module reference
        mod = importlib.import_module("repro." + rel.replace("/", "."))
        assert hasattr(mod, sym), (doc.name, rel, sym)


def test_readme_documents_tier1_verify():
    text = (ROOT / "README.md").read_text()
    assert "python -m pytest -x -q" in text
    assert "PYTHONPATH=src" in text


def test_readme_quickstart_example_exists():
    text = (ROOT / "README.md").read_text()
    for script in re.findall(r"python\s+(examples/[\w.]+\.py)", text):
        assert (ROOT / script).exists(), script


@pytest.mark.parametrize(
    "script", sorted((ROOT / "examples").glob("*.py")),
    ids=lambda p: p.stem)
def test_example_imports_resolve(script):
    """Every example's first-party imports must keep resolving — the
    examples are runnable docs and rot the same way (the heavyweight
    end-to-end smokes live in tests/test_examples.py)."""
    pat = re.compile(r"^\s*(?:from\s+([\w.]+)\s+import\b|import\s+([\w.]+))",
                     re.M)
    for m in pat.finditer(script.read_text()):
        mod = m.group(1) or m.group(2)
        if mod.split(".")[0] in FIRST_PARTY:
            importlib.import_module(mod)
