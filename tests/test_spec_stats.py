"""Statistical battery for ``core/speculative.py:residual_verify``.

Chen et al. (2023) prove that draft-accept/residual-resample emits tokens
with EXACTLY the target model's distribution, for any draft.  The tests
check that identity empirically: over thousands of vectorized verify rows
(one ``residual_verify`` call — every row draws independent accept coins
and resample/bonus tokens from the shared key), the first emitted token's
frequencies must match the target softmax under both a chi-square bound
and a total-variation bound.  Seeds are fixed, so the battery is
deterministic in CI; the thresholds are calibrated far above the
fixed-seed statistics (chi-square ~6 observed vs 40 allowed at 15 dof)
and far below what a biased rule produces (the draft marginal scores
~15000).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.speculative import residual_verify

V, K, ROWS, TEMP = 16, 2, 8000, 1.3
CHI2_MAX = 40.0     # ~0.9995 quantile at V-1 = 15 dof
TV_MAX = 0.03       # ~3x the observed fixed-seed total variation


def _setup(seed: int):
    """Shared per-position logits, drafts sampled from the draft softmax
    (temperature TEMP), one verify over ROWS independent rows."""
    kq, kt, kd, kv = jax.random.split(jax.random.PRNGKey(seed), 4)
    q_logits = jax.random.normal(kq, (K, V)) * 1.2
    t_logits = jax.random.normal(kt, (K + 1, V)) * 1.2
    dkeys = jax.random.split(kd, K)
    drafts = jnp.stack(
        [jax.random.categorical(
            dkeys[i], jnp.broadcast_to(q_logits[i] / TEMP, (ROWS, V)),
            axis=-1) for i in range(K)], axis=1).astype(jnp.int32)
    dlog = jnp.broadcast_to(q_logits[None], (ROWS, K, V))
    tlog = jnp.broadcast_to(t_logits[None], (ROWS, K + 1, V))
    return q_logits, t_logits, drafts, dlog, tlog, kv


def _first_token_stats(seed: int):
    """(chi2, tv) of the first emitted token's empirical distribution
    against the target softmax at position 0."""
    _, t_logits, drafts, dlog, tlog, kv = _setup(seed)
    n_acc, nxt, _ = residual_verify(drafts, dlog, tlog, kv, TEMP)
    # First emitted token: d_1 when accepted, else the residual resample
    # at position 0 (n_accept = 0 gathers the residual at j = 0).
    tok0 = np.where(np.asarray(n_acc) >= 1, np.asarray(drafts[:, 0]),
                    np.asarray(nxt))
    p0 = np.asarray(jax.nn.softmax(t_logits[0] / TEMP), np.float64)
    counts = np.bincount(tok0, minlength=V).astype(np.float64)
    expected = ROWS * p0
    chi2 = float(((counts - expected) ** 2
                  / np.maximum(expected, 1e-9)).sum())
    tv = 0.5 * float(np.abs(counts / ROWS - p0).sum())
    return chi2, tv


class TestResidualVerifyUnbiased:
    @pytest.mark.parametrize("seed", [7, 31])
    def test_first_emitted_token_matches_target(self, seed):
        """The emitted-token marginal IS the target distribution (the
        speculative-sampling unbiasedness identity), at fixed seeds."""
        chi2, tv = _first_token_stats(seed)
        assert chi2 < CHI2_MAX, f"chi-square {chi2:.1f} >= {CHI2_MAX}"
        assert tv < TV_MAX, f"total variation {tv:.4f} >= {TV_MAX}"

    def test_statistic_rejects_a_biased_rule(self):
        """Control: the raw draft marginal (an 'always accept' rule) is
        rejected by the same statistic by orders of magnitude — the test
        has discriminating power, it is not vacuously loose."""
        _, t_logits, drafts, *_ = _setup(7)
        p0 = np.asarray(jax.nn.softmax(t_logits[0] / TEMP), np.float64)
        counts = np.bincount(np.asarray(drafts[:, 0]),
                             minlength=V).astype(np.float64)
        expected = ROWS * p0
        chi2 = float(((counts - expected) ** 2
                      / np.maximum(expected, 1e-9)).sum())
        assert chi2 > 100 * CHI2_MAX

    def test_identical_distributions_accept_everything(self):
        """p == q pointwise -> min(1, p/q) = 1: every draft accepted,
        regardless of where the drafts were sampled from."""
        _, _, drafts, _, tlog, kv = _setup(7)
        n_acc, _, commit = residual_verify(drafts, tlog[:, :K],
                                           tlog[:, :K], kv, TEMP)
        assert int(np.asarray(n_acc).min()) == K
        np.testing.assert_array_equal(np.asarray(commit), K + 1)

    def test_greedy_required_below_zero_temperature(self):
        _, _, drafts, dlog, tlog, kv = _setup(7)
        with pytest.raises(ValueError):
            residual_verify(drafts, dlog, tlog, kv, 0.0)
