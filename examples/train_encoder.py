"""End-to-end driver: pre-train a ~100M-param RoBERTa-class encoder with
LLN+Diag attention on the synthetic MLM corpus, with checkpointing and a
side-by-side softmax-attention comparison (the paper's Fig. 8a experiment).

Defaults are sized for this CPU container (~90M params, a few hundred
steps); on a real pod pass --mesh data,model and scale --batch/--seq.

Run:  PYTHONPATH=src python examples/train_encoder.py --steps 200
"""
import argparse
import json

import numpy as np

from repro.launch.train import main as train_main


def run(steps: int, compare: bool, out: str):
    curves = {}
    impls = ["lln_diag"] + (["softmax"] if compare else [])
    for impl in impls:
        print(f"=== pre-training roberta-lln [{impl}] ===")
        hist = train_main([
            "--arch", "roberta-lln", "--attn-impl", impl,
            "--steps", str(steps), "--batch", "8", "--seq", "128",
            "--lr", "3e-3", "--log-every", "20",
            "--ckpt-dir", f"/tmp/roberta_{impl}_ckpt",
            "--ckpt-interval", "100"])
        curves[impl] = [h["loss"] for h in hist]
    if compare and steps >= 20:
        gap = abs(np.mean(curves["lln_diag"][-10:])
                  - np.mean(curves["softmax"][-10:]))
        print(f"\nFig-8a gap |LLN+Diag - SA| over last 10 steps: {gap:.4f}")
    with open(out, "w") as f:
        json.dump(curves, f)
    print(f"curves written to {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--no-compare", action="store_true")
    ap.add_argument("--out", default="/tmp/encoder_curves.json")
    a = ap.parse_args()
    run(a.steps, not a.no_compare, a.out)
