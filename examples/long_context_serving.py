"""Long-context serving with the O(d^2) LLN state vs the O(N) KV cache.

The paper's scalability claim, demonstrated at the serving layer: decode
cost with ``lln_diag`` is INDEPENDENT of how much context the model has
absorbed — the per-layer state is (H, D, D) + a diag tail, whether the
prompt was 1k tokens or 500k.  With softmax attention the same model's
cache (and per-token read traffic) grows linearly.

Run:  PYTHONPATH=src python examples/long_context_serving.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model, synthetic_batch


def cache_bytes(tree):
    return sum(np.asarray(x).nbytes for x in jax.tree_util.tree_leaves(tree))


def main(prompts=(256, 1024, 4096), steps: int = 8):
    rows = []
    for impl in ("softmax", "lln_diag"):
        for prompt in prompts:
            cfg = get_config("chatglm3-6b", smoke=True, attn_impl=impl,
                             lln_fixed_ab=2.1)
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            batch = synthetic_batch(cfg, 1, prompt + 16)
            batch["inputs"] = batch["inputs"][:, :prompt]
            logits, caches = model.prefill(params, batch, prompt + 16)
            nbytes = cache_bytes(caches)

            decode = jax.jit(
                lambda p, c, t, pos: model.decode(p, c, t, pos))
            tok = jnp.argmax(logits[:, -1] if logits.ndim == 3 else logits,
                             -1).astype(jnp.int32)
            # warmup/compile then measure steady-state decode
            lg, caches = decode(params, caches, tok,
                                jnp.asarray(prompt, jnp.int32))
            t0 = time.time()
            for i in range(steps):
                lg, caches = decode(params, caches, tok,
                                    jnp.asarray(prompt + 1 + i, jnp.int32))
            jax.block_until_ready(lg)
            ms = (time.time() - t0) / steps * 1e3
            rows.append((impl, prompt, nbytes / 1e6, ms))
            print(f"{impl:9s} prompt={prompt:6d}  cache={nbytes / 1e6:8.2f}MB"
                  f"  decode={ms:7.2f}ms/tok")
    sm = [r for r in rows if r[0] == "softmax"]
    ln = [r for r in rows if r[0] == "lln_diag"]
    lo, hi = prompts[0], prompts[-1]
    print(f"\ncache growth {lo}->{hi}: softmax "
          f"{sm[-1][2] / sm[0][2]:.1f}x, "
          f"lln_diag {ln[-1][2] / ln[0][2]:.2f}x (state is context-length-"
          f"independent — what makes the long_500k cell serveable)")
    return rows


if __name__ == "__main__":
    main()
