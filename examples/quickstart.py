"""Quickstart: LLN attention as a drop-in module, in 60 lines.

Demonstrates the paper's three pieces on raw tensors:
  1. moment matching (eq. 10) — solve (alpha, beta) from input statistics;
  2. LLN attention (eq. 8) — linear-complexity, log-normal score matrix;
  3. the LLN+Diag hybrid (§4.2) via the unified multi_head_attention API,
     identical to what every assigned architecture uses internally.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (AttnConfig, multi_head_attention, lln_causal,
                        solve_alpha_beta)
from repro.core.metrics import attention_log_moments, lognormality_score
from repro.core.moment_matching import (constants_for_dim, lln_attn_matrix,
                                        softmax_attn_matrix)

key = jax.random.PRNGKey(0)
B, N, H, D = 2, 512, 8, 64

# --- 1. moment matching ----------------------------------------------------
sigma_q = sigma_k = 1.0
a, b = constants_for_dim(D)
alpha, beta = solve_alpha_beta(sigma_q, sigma_k, a, b)
print(f"moment-matched alpha={float(alpha):.2f} beta={float(beta):.2f} "
      f"(paper Fig. 9 range: 2.0-2.2)")

# --- 2. the induced attention matrix is log-normal, like softmax's ---------
kq, kk = jax.random.split(key)
q2, k2 = jax.random.normal(kq, (N, D)), jax.random.normal(kk, (N, D))
p_sm = softmax_attn_matrix(q2, k2)
p_lln = lln_attn_matrix(q2, k2, float(alpha), float(beta))
print(f"Var[ln P]  softmax={float(attention_log_moments(p_sm)[1]):.3f}  "
      f"lln={float(attention_log_moments(p_lln)[1]):.3f}")
print(f"log-normality (QQ corr)  softmax={lognormality_score(p_sm):.4f}  "
      f"lln={lognormality_score(p_lln):.4f}")

# --- 3. linear-complexity attention on (B, N, H, D) tensors ----------------
kq, kk, kv = jax.random.split(key, 3)
q = jax.random.normal(kq, (B, N, H, D), jnp.bfloat16)
k = jax.random.normal(kk, (B, N, H, D), jnp.bfloat16)
v = jax.random.normal(kv, (B, N, H, D), jnp.bfloat16)

out_lln = lln_causal(q, k, v, alpha, beta, chunk=128)      # pure LLN
cfg = AttnConfig(impl="lln_diag", causal=True)             # paper §4.2 hybrid
out_hybrid = multi_head_attention(q, k, v, cfg)            # auto moment-match
cfg_sa = AttnConfig(impl="softmax", causal=True)
out_sa = multi_head_attention(q, k, v, cfg_sa)

cos = jnp.sum(out_hybrid.astype(jnp.float32) * out_sa.astype(jnp.float32)) / (
    jnp.linalg.norm(out_hybrid.astype(jnp.float32))
    * jnp.linalg.norm(out_sa.astype(jnp.float32)))
print(f"outputs: lln {out_lln.shape}, hybrid {out_hybrid.shape}; "
      f"cos(hybrid, softmax) = {float(cos):.3f}")
print("OK")
