"""Reproduce the paper's §3 analysis pipeline on a *trained* model:
measure temperature, entropy, and spectral gap of real attention matrices
(Fig. 1 analog), then verify LLN's moment matching against them (Fig. 2).

Run:  PYTHONPATH=src python examples/concentration_analysis.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.metrics import (row_entropy, spectral_gap, temperature_sm,
                                lognormality_score)
from repro.core.moment_matching import (constants_for_dim,
                                        lln_attn_matrix,
                                        softmax_attn_matrix,
                                        solve_alpha_beta)
from repro.data.synthetic import mlm_batches
from repro.models import build_model
from repro.models.layers import apply_norm, dense, embed_lookup
from repro.optim import AdamWConfig, adamw_init, adamw_update


def layer0_qk(params, cfg, tokens):
    lp = jax.tree_util.tree_map(lambda a: a[0], params["layers"])
    x = embed_lookup(params["embed"], tokens, cfg.cdtype)
    h = apply_norm(lp["ln1"], x, cfg.norm)
    b, n, _ = h.shape
    q = dense(lp["attn"]["q_w"], h, cfg.cdtype).reshape(
        b, n, cfg.n_heads, cfg.hd)
    k = dense(lp["attn"]["k_w"], h, cfg.cdtype).reshape(
        b, n, cfg.n_kv_heads, cfg.hd)
    return q, k


def main(steps: int = 30):
    cfg = get_config("roberta-lln", smoke=True, attn_impl="softmax")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = adamw_init(params)
    gen = mlm_batches(cfg.vocab, 8, 64, seed=0)

    @jax.jit
    def step_fn(params, state, b):
        loss, grads = jax.value_and_grad(model.loss)(params, b)
        return (*adamw_update(grads, state, params, 3e-3,
                              AdamWConfig(weight_decay=0.01))[:2], loss)

    print("step  temp_sm  entropy[b]  spec_gap   (paper Fig. 1)")
    probe = {k: jnp.asarray(v) for k, v in next(gen).items()}
    for step in range(steps + 1):
        if step % 10 == 0:
            q, k = layer0_qk(params, cfg, probe["inputs"])
            sq = float(jnp.sqrt(jnp.mean(jnp.square(
                q.astype(jnp.float32)))))
            sk = float(jnp.sqrt(jnp.mean(jnp.square(
                k.astype(jnp.float32)))))
            tau = temperature_sm(sq, sk)
            p = softmax_attn_matrix(
                np.asarray(q, np.float32)[0, :, 0] * (cfg.hd ** 0.25),
                np.asarray(k, np.float32)[0, :, 0] * (cfg.hd ** 0.25))
            print(f"{step:4d}  {tau:7.3f}  {float(row_entropy(p)):9.3f}"
                  f"  {spectral_gap(np.asarray(p)):9.4f}")
        if step < steps:
            b = {k2: jnp.asarray(v) for k2, v in next(gen).items()}
            params, state, _ = step_fn(params, state, b)

    # Fig. 2 check on the trained statistics
    q, k = layer0_qk(params, cfg, probe["inputs"])
    sq = float(jnp.sqrt(jnp.mean(jnp.square(q.astype(jnp.float32)))))
    sk = float(jnp.sqrt(jnp.mean(jnp.square(k.astype(jnp.float32)))))
    a, bconst = constants_for_dim(cfg.hd)
    alpha, beta = solve_alpha_beta(sq, sk, a, bconst)
    qn = np.asarray(q, np.float32)[0, :, 0]
    kn = np.asarray(k, np.float32)[0, :, 0]
    p_sm = softmax_attn_matrix(qn * (cfg.hd ** 0.25), kn * (cfg.hd ** 0.25))
    p_lln = lln_attn_matrix(qn, kn, float(alpha), float(beta))
    print(f"\ntrained-stats moment match: alpha={float(alpha):.2f} "
          f"beta={float(beta):.2f}")
    print(f"entropy: sm={float(row_entropy(p_sm)):.3f} "
          f"lln={float(row_entropy(p_lln)):.3f}")
    print(f"spectral gap: sm={spectral_gap(np.asarray(p_sm)):.4f} "
          f"lln={spectral_gap(np.asarray(p_lln)):.4f}")
    print(f"log-normality: sm={lognormality_score(p_sm):.4f} "
          f"lln={lognormality_score(p_lln):.4f}")


if __name__ == "__main__":
    main()
