import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
os.environ["REPRO_AOT_ONLY"] = "1"   # compile-only: keep TPU-shaped bf16 dots

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, recording memory/cost analysis and the collective schedule.

MUST be run as its own process (the device-count override binds at jax
init).  --all spawns one subprocess per cell for isolation.

Examples:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  python -m repro.launch.dryrun --arch yi-9b --shape decode_32k --multi-pod
  python -m repro.launch.dryrun --all
"""
import argparse
import json
import re
import subprocess
import sys
import time
import traceback

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device output bytes of every collective op in the compiled
    (SPMD-partitioned, per-device-shapes) module."""
    out = {}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        for op in _COLLECTIVES:
            # match '<lhs> = <shape(s)> op-name(' — avoid -start/-done splits
            m = re.search(rf"=\s+(.+?)\s+{op}(-start)?\(", line)
            if m:
                b = _shape_bytes(m.group(1))
                rec = out.setdefault(op, {"count": 0, "bytes": 0})
                rec["count"] += 1
                rec["bytes"] += b
                break
    return out


def parse_overrides(s: str) -> dict:
    """'n_layers=2,scan_unroll=1,remat=none' -> typed override dict."""
    out = {}
    if not s:
        return out
    for item in s.split(","):
        k, v = item.split("=")
        if v in ("True", "False"):
            out[k] = v == "True"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                try:
                    out[k] = float(v)
                except ValueError:
                    out[k] = v
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             attn_impl: str = "auto", overrides: dict | None = None) -> dict:
    import jax
    from repro.configs import SHAPES_BY_NAME, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import make_serve_setup, make_train_setup

    shape = SHAPES_BY_NAME[shape_name]
    cfg = get_config(arch)
    impl = attn_impl
    if impl == "auto":
        # long_500k needs sub-quadratic attention: attention archs run it in
        # the paper's lln_diag mode; SSM archs natively (DESIGN.md §4).
        if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
            impl = "lln_diag"
        else:
            impl = cfg.attn_impl
    cfg = cfg.replace(attn_impl=impl, **(overrides or {}))

    mesh = make_production_mesh(multi_pod=multi_pod)
    result = {"arch": arch, "shape": shape_name,
              "mesh": "2x16x16" if multi_pod else "16x16",
              "kind": shape.kind, "attn_impl": impl,
              "overrides": overrides or {},
              "devices": int(mesh.devices.size)}
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            setup = make_train_setup(cfg, shape, mesh, multi_pod=multi_pod)
            lowered = setup.step_fn.lower(setup.state_struct, setup.batch)
        elif shape.kind == "prefill":
            setup = make_serve_setup(cfg, shape, mesh, multi_pod=multi_pod)
            lowered = setup.prefill_fn.lower(setup.params_struct, setup.batch)
        else:  # decode
            setup = make_serve_setup(cfg, shape, mesh, multi_pod=multi_pod)
            cache_in = jax.tree_util.tree_map(
                lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                   sharding=sh),
                setup.cache_struct, setup.cache_shardings)
            lowered = setup.decode_fn.lower(setup.params_struct, cache_in,
                                            setup.token_struct,
                                            setup.pos_struct)
        result["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t1, 2)

    try:
        ca = compiled.cost_analysis()
        result["flops"] = float(ca.get("flops", -1))
        result["bytes_accessed"] = float(ca.get("bytes accessed", -1))
    except Exception as e:
        result["cost_analysis_error"] = str(e)
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            if hasattr(ma, attr):
                result[attr] = int(getattr(ma, attr))
    except Exception as e:
        result["memory_analysis_error"] = str(e)
    try:
        result["collectives"] = parse_collectives(compiled.as_text())
    except Exception as e:
        result["collectives_error"] = str(e)
    result["ok"] = True
    return result


def _out_path(out_dir, arch, shape, mesh_tag):
    os.makedirs(out_dir, exist_ok=True)
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh_tag}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--attn-impl", default="auto",
                    choices=["auto", "softmax", "lln", "lln_diag"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--override", default="",
                    help="cfg overrides, e.g. n_layers=2,scan_unroll=True")
    ap.add_argument("--tag", default="",
                    help="suffix for the output filename (probe runs)")
    args = ap.parse_args()

    if args.all:
        from repro.configs.registry import ASSIGNED_ARCHS
        shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
        meshes = [False, True] if args.both_meshes else [False]
        failures = []
        for arch in ASSIGNED_ARCHS:
            for shape in shapes:
                for mp in meshes:
                    tag = "2x16x16" if mp else "16x16"
                    path = _out_path(args.out, arch, shape, tag)
                    if args.skip_existing and os.path.exists(path):
                        print(f"[skip] {path}")
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape, "--out", args.out,
                           "--attn-impl", args.attn_impl]
                    if args.override:
                        cmd += ["--override", args.override]
                    if args.tag:
                        cmd += ["--tag", args.tag]
                    if mp:
                        cmd.append("--multi-pod")
                    print(f"[run ] {arch} {shape} {tag}", flush=True)
                    rc = subprocess.call(cmd)
                    if rc != 0:
                        failures.append((arch, shape, tag))
        print(f"DONE; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    tag = "2x16x16" if args.multi_pod else "16x16"
    if args.tag:
        tag = tag + "__" + args.tag
    path = _out_path(args.out, args.arch, args.shape, tag)
    if args.skip_existing and os.path.exists(path):
        print(f"[skip] {path}")
        sys.exit(0)
    try:
        result = run_cell(args.arch, args.shape, args.multi_pod,
                          args.attn_impl, parse_overrides(args.override))
    except Exception as e:
        result = {"arch": args.arch, "shape": args.shape, "mesh": tag,
                  "ok": False, "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:]}
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps({k: v for k, v in result.items()
                      if k not in ("traceback",)}, indent=2))
    sys.exit(0 if result.get("ok") else 1)


if __name__ == "__main__":
    main()
