"""Training driver.

Runs a real training loop on whatever devices exist (CPU smoke scale up to
full pods — the step construction is identical; only the mesh differs),
with checkpoint/resume, straggler watchdog, prefetched data, and periodic
metrics.

  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --smoke \
      --steps 50 --seq 128 --batch 8 --ckpt-dir /tmp/ckpt

Production pods use the same entry point with --mesh data,model sizes.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.data.pipeline import HostShardedSource, Prefetcher, device_placer
from repro.data.synthetic import lm_batches, mlm_batches
from repro.distributed import sharding as shd
from repro.distributed.straggler import StepWatchdog
from repro.launch.steps import make_train_setup
from repro.models import build_model, synthetic_batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the arch's reduced config")
    ap.add_argument("--attn-impl", default=None,
                    choices=[None, "softmax", "lln", "lln_diag"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="1,1",
                    help="data,model mesh sizes (devices must exist)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    overrides = {}
    if args.attn_impl:
        overrides["attn_impl"] = args.attn_impl
    cfg = get_config(args.arch, smoke=args.smoke, **overrides)

    data, model_ax = (int(x) for x in args.mesh.split(","))
    from repro.launch.mesh import compat_mesh
    mesh = compat_mesh((data, model_ax), ("data", "model"))
    shape = ShapeSpec("cli", args.seq, args.batch, "train")

    with mesh:
        setup = make_train_setup(cfg, shape, mesh, multi_pod=False,
                                 peak_lr=args.lr, total_steps=args.steps)

        def init_state():
            m = build_model(cfg)
            params = m.init(jax.random.PRNGKey(args.seed))
            from repro.optim import adamw_init
            return jax.device_put(
                {"params": params, "opt": adamw_init(params)},
                setup.state_shardings)

        start_step = 0
        mgr = None
        if args.ckpt_dir:
            mgr = CheckpointManager(args.ckpt_dir,
                                    interval=args.ckpt_interval)
            state, start_step = mgr.restore_or_init(init_state,
                                                    setup.state_shardings)
        else:
            state = init_state()

        # Data pipeline: host-sharded + prefetch + device placement.
        if cfg.family == "encoder":
            gen = lambda b, s: mlm_batches(cfg.vocab, b, args.seq, seed=s)
        else:
            gen = lambda b, s: lm_batches(cfg.vocab, b, args.seq, seed=s)
        if cfg.family in ("encdec", "vlm"):
            # Multimodal stubs: synthetic continuous frontends.
            def gen(b, s):
                step = 0
                while True:
                    yield {k: np.asarray(v) for k, v in synthetic_batch(
                        cfg, b, args.seq,
                        key=jax.random.PRNGKey(hash((s, step)) % 2**31)).items()}
                    step += 1
        specs = {k: v.sharding.spec for k, v in setup.batch.items()}
        source = HostShardedSource(gen, args.batch, start_step=start_step)
        pipe = Prefetcher(source, place=device_placer(mesh, specs))

        watchdog = StepWatchdog(
            on_anomaly=lambda r: print(f"[straggler] step {r.step} took "
                                       f"{r.duration:.2f}s ({r.ratio:.1f}x)"))
        history = []
        t_start = time.time()
        for step in range(start_step, args.steps):
            batch = next(pipe)
            watchdog.start()
            state, metrics = setup.step_fn(state, batch)
            loss = float(metrics["loss"])
            watchdog.stop(step)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d}  loss {loss:8.4f}  "
                      f"gnorm {float(metrics['grad_norm']):7.3f}  "
                      f"lr {float(metrics['lr']):.2e}", flush=True)
            history.append({"step": step, "loss": loss})
            if mgr:
                mgr.maybe_save(step, state)
        pipe.close()
        if mgr:
            mgr.finalize(args.steps, state)
        dt = time.time() - t_start
        print(f"done: {args.steps - start_step} steps in {dt:.1f}s "
              f"({(args.steps - start_step) / max(dt, 1e-9):.2f} it/s); "
              f"{len(watchdog.anomalies)} straggler events")
        if args.metrics_out:
            with open(args.metrics_out, "w") as f:
                json.dump(history, f)
        return history


if __name__ == "__main__":
    main()
