"""pjit step builders: train_step / prefill_step / decode_step per arch.

Everything AOT-friendly: the builders return (step_fn, in_struct, shardings)
so launchers and the dry-run lower against ShapeDtypeStructs without
allocating anything.
"""
from __future__ import annotations

import dataclasses
import re
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.core import speculative
from repro.core.health import HealthConfig, unhealthy_rows
from repro.core.metrics import streaming_concentration_tree
from repro.distributed import sharding as shd
from repro.models import (Model, build_model, draft_config, draft_params)
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation).
# ---------------------------------------------------------------------------

def batch_struct(cfg: ArchConfig, shape: ShapeSpec, mesh, rules) -> dict:
    """Training/prefill batch ShapeDtypeStructs with shardings attached."""
    b, n = shape.global_batch, shape.seq_len
    batch_axes = rules["act_batch"]
    seq_axes = rules["act_seq"]

    def flt(shape_, spec):
        spec = shd.fit_spec(P(*spec), shape_, mesh)
        return jax.ShapeDtypeStruct(shape_, jnp.float32,
                                    sharding=NamedSharding(mesh, spec))

    n_text = n
    if cfg.family == "vlm":
        n_text = max(n - cfg.num_prefix_tokens, 8)
    out = {}
    for name in ("inputs", "targets"):
        spec = shd.fit_spec(P(batch_axes, seq_axes), (b, n_text), mesh)
        out[name] = jax.ShapeDtypeStruct(
            (b, n_text), jnp.int32, sharding=NamedSharding(mesh, spec))
    spec = shd.fit_spec(P(batch_axes, seq_axes), (b, n_text), mesh)
    out["mask"] = jax.ShapeDtypeStruct(
        (b, n_text), jnp.float32, sharding=NamedSharding(mesh, spec))
    if cfg.family == "encdec":
        out["src"] = flt((b, n, cfg.frontend_dim), (batch_axes, seq_axes, None))
    if cfg.family == "vlm":
        out["patches"] = flt((b, cfg.num_prefix_tokens, cfg.frontend_dim),
                             (batch_axes, None, None))
    return out


def cache_shardings(cache_tree, cfg, mesh, rules):
    """Decode-cache shardings.

    The dominant bytes at decode are the caches, so they MUST use the model
    axis.  Heads shard over 'model' when divisible; otherwise we shard the
    *feature* dim (head_dim, or the MLA latent) — attention contractions
    over that dim become psum partials, which XLA handles (flash-decode
    along the feature axis).  SSM conv tails and scalars replicate.
    """
    msize = shd._axis_size(mesh, "model")
    kv_div = cfg.n_kv_heads % msize == 0
    h_div = cfg.n_heads % msize == 0
    kv_ax = "model" if kv_div else None
    kv_fd = None if kv_div else "model"
    h_ax = "model" if h_div else None
    h_fd = None if h_div else "model"
    b_ax = rules["act_batch"]

    per_name = [
        (r"(^|/)(len|pos|alpha|beta|log_scale)$", ()),
        # LLN tails carry G kv-heads on the kernelized serve path (H on the
        # seed path / MLA); fit_spec drops non-divisible axes either way.
        (r"(^|/)(tail_k|tail_v)$", (b_ax, None, kv_ax, kv_fd)),
        # MLA latent cache: shard the latent dim
        (r"(^|/)ckv$", (b_ax, None, "model")),
        (r"(^|/)kr$", (b_ax, None, None)),
        (r"(^|/)c_k$", (b_ax, None, h_ax, None)),
        # log_linear Fenwick pyramid: (B, L, H, D[, Dv]) — scale axis
        # replicates (L = lln_num_scales is tiny), heads/feature as LLN
        (r"(^|/)sl$", (b_ax, None, h_ax, h_fd, None)),
        (r"(^|/)zl$", (b_ax, None, h_ax, h_fd)),
        (r"(^|/)cl$", (b_ax, None, h_ax)),
        # softmax KV caches (kv heads) / cross-attn caches
        (r"(^|/)(ck|cv|k|v)$", (b_ax, None, kv_ax, kv_fd)),
        # LLN state: heads when divisible, else the feature dim
        (r"(^|/)s$", (b_ax, h_ax, h_fd, None)),
        (r"(^|/)z$", (b_ax, h_ax, h_fd)),
        # SSM state: heads when divisible (zamba 112 ok, mamba 24 not)
        (r"(^|/)state$", (b_ax, h_ax, None, None)),
        (r"(^|/)conv$", (b_ax, None, None)),
    ]

    def leaf(kp, a):
        path = shd._path_str(kp)
        axes: tuple = (None,) * a.ndim
        for pat, ax in per_name:
            if re.search(pat, path):
                lead = a.ndim - len(ax)
                axes = (None,) * lead + tuple(ax)
                break
        spec = shd.fit_spec(P(*axes), a.shape, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(leaf, cache_tree)


# ---------------------------------------------------------------------------
# Step builders.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainSetup:
    step_fn: Any
    state_struct: Any
    state_shardings: Any
    batch: dict
    rules: dict


def make_train_setup(cfg: ArchConfig, shape: ShapeSpec, mesh, *,
                     multi_pod: bool, peak_lr: float = 3e-4,
                     total_steps: int = 10000,
                     cast_params_once: bool | None = None,
                     opt_cfg: AdamWConfig = AdamWConfig()) -> TrainSetup:
    """``cast_params_once``: cast fp32 master params to compute dtype *before*
    the loss — FSDP weight all-gathers then move bf16 instead of fp32 (2x
    collective-bytes reduction on every weight gather; gradients arrive in
    bf16 and are accumulated into the fp32 AdamW moments as usual)."""
    model = build_model(cfg)
    rules = shd.make_rules(cfg, multi_pod=multi_pod)
    if cast_params_once is None:
        cast_params_once = cfg.cast_params_once

    def init_state(key):
        params = model.init(key)
        return {"params": params, "opt": adamw_init(params)}

    state_struct = jax.eval_shape(init_state, jax.random.PRNGKey(0))
    state_shardings = shd.param_shardings(state_struct, mesh)
    batch = batch_struct(cfg, shape, mesh, rules)

    accum = max(int(cfg.grad_accum), 1)

    def compute_grads(params, batch):
        if accum == 1:
            return jax.value_and_grad(model.loss)(params, batch)
        # Microbatched gradient accumulation (activation peak / accum).
        mb = jax.tree_util.tree_map(
            lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
            batch)

        def body(carry, mbatch):
            loss_sum, gacc = carry
            loss, grads = jax.value_and_grad(model.loss)(params, mbatch)
            gacc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), gacc, grads)
            return (loss_sum + loss, gacc), None

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, gacc), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), g0), mb)
        grads = jax.tree_util.tree_map(lambda g: g / accum, gacc)
        return loss_sum / accum, grads

    def train_step(state, batch):
        with shd.logical_rules(mesh, rules):
            params_c = state["params"]
            if cast_params_once:
                params_c = jax.tree_util.tree_map(
                    lambda p: p.astype(cfg.cdtype)
                    if p.dtype == jnp.float32 and p.ndim >= 2 else p,
                    params_c)
            loss, grads = compute_grads(params_c, batch)
            lr = warmup_cosine(state["opt"]["step"], peak_lr=peak_lr,
                               warmup_steps=min(500, total_steps // 10),
                               total_steps=total_steps)
            params, opt, metrics = adamw_update(grads, state["opt"],
                                                state["params"], lr, opt_cfg)
        return ({"params": params, "opt": opt},
                {"loss": loss, "lr": lr, **metrics})

    step_fn = jax.jit(train_step,
                      in_shardings=(state_shardings, None),
                      out_shardings=(state_shardings, None),
                      donate_argnums=(0,))
    return TrainSetup(step_fn=step_fn, state_struct=state_struct,
                      state_shardings=state_shardings, batch=batch,
                      rules=rules)


def sample_token(logits, temperature: float, key) -> jnp.ndarray:
    """Greedy (temperature == 0) or temperature sampling; jit-safe.  The one
    sampling rule shared by the scanned generation loop and the per-token
    serve driver."""
    if temperature > 0:
        return jax.random.categorical(key, logits / temperature,
                                      -1).astype(jnp.int32)
    return jnp.argmax(logits, -1).astype(jnp.int32)


@dataclasses.dataclass
class ServeSetup:
    """Jitted serving entry points for one (cfg, mesh, batch-shape).

    ``prefill_fn(params, batch) -> (last logits, caches)`` — batched prompt
    forward building the decode caches (state-emitting LLN kernel path by
    default).  ``decode_fn(params, caches, token, pos) -> (logits, caches)``
    — one decode step, donated caches.  ``make_generate(steps, temperature)``
    builds a jitted scanned generation segment
    ``(params, caches, tok, pos0, key) -> (tokens (B, steps), caches)``:
    the whole segment is ONE dispatch — a ``lax.scan`` over the decode step
    with donated cache carry (vs one jitted dispatch per token from a
    Python loop).  ``tok`` is the (B,) int32 token decoded first; ``pos0``
    its scalar absolute position; greedy when ``temperature == 0`` (the
    PRNG key is then unused).  All rows advance in lockstep — for
    mixed-length traffic see ``make_pool_setup``.
    """
    prefill_fn: Any
    decode_fn: Any
    params_struct: Any
    params_shardings: Any
    batch: dict
    cache_struct: Any
    cache_shardings: Any
    rules: dict
    token_struct: Any = None
    pos_struct: Any = None
    make_generate: Any = None


def make_serve_setup(cfg: ArchConfig, shape: ShapeSpec, mesh, *,
                     multi_pod: bool) -> ServeSetup:
    model = build_model(cfg)
    rules = shd.make_rules(cfg, multi_pod=multi_pod, serve=True)
    b, n = shape.global_batch, shape.seq_len

    params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_shardings = shd.param_shardings(params_struct, mesh)
    batch = batch_struct(cfg, shape, mesh, rules)

    def prefill_step(params, batch):
        with shd.logical_rules(mesh, rules):
            return model.prefill(params, batch, n)

    cache_struct = jax.eval_shape(
        lambda p: model.cache_init(p, b, n), params_struct)
    cache_shard = cache_shardings(cache_struct, cfg, mesh, rules)

    def decode_step(params, caches, token, pos):
        with shd.logical_rules(mesh, rules):
            return model.decode(params, caches, token, pos)

    batch_axes = rules["act_batch"]
    tok_spec = shd.fit_spec(P(batch_axes), (b,), mesh)
    token_struct = jax.ShapeDtypeStruct((b,), jnp.int32,
                                        sharding=NamedSharding(mesh, tok_spec))
    pos_struct = jax.ShapeDtypeStruct((), jnp.int32)

    prefill_fn = jax.jit(prefill_step, in_shardings=(params_shardings, None))
    # Token in_sharding is left open: a (B,) int token is tiny and arrives
    # committed-replicated from the previous step's argmax; pinning it to the
    # data axis would make older jax reject the arg instead of resharding.
    decode_fn = jax.jit(decode_step,
                        in_shardings=(params_shardings, cache_shard,
                                      None, None),
                        out_shardings=(None, cache_shard),
                        donate_argnums=(1,))

    def make_generate(steps: int, temperature: float = 0.0):
        """Build a jitted scanned generation segment: ``steps`` greedy (or
        temperature-sampled) decode steps folded into one ``lax.scan`` with
        the cache carry donated — one XLA dispatch per segment."""

        def gen(params, caches, tok, pos0, key):
            def body(carry, i):
                caches, tok = carry
                logits, caches = model.decode(params, caches, tok, pos0 + i)
                tok = sample_token(logits, temperature,
                                   jax.random.fold_in(key, i))
                return (caches, tok), tok

            with shd.logical_rules(mesh, rules):
                (caches, _), toks = jax.lax.scan(
                    body, (caches, tok), jnp.arange(steps, dtype=jnp.int32))
            return toks.transpose(1, 0), caches

        return jax.jit(gen,
                       in_shardings=(params_shardings, cache_shard,
                                     None, None, None),
                       out_shardings=(None, cache_shard),
                       donate_argnums=(1,))

    setup = ServeSetup(prefill_fn=prefill_fn, decode_fn=decode_fn,
                       params_struct=params_struct,
                       params_shardings=params_shardings, batch=batch,
                       cache_struct=cache_struct, cache_shardings=cache_shard,
                       rules=rules, make_generate=make_generate)
    setup.token_struct = token_struct
    setup.pos_struct = pos_struct
    return setup


# ---------------------------------------------------------------------------
# Speculative decoding: draft-then-verify over the partial-commit contract.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SpecSetup:
    """Jitted speculative-decode entry points for one (cfg, mesh, shape).

    The loop is draft-then-verify (Leviathan et al. / Chen et al.) over
    the engine's partial-commit contract: each iteration the tied
    first-``draft_layers`` draft proposes ``spec_k`` tokens sequentially,
    the target scores the whole chunk ``[tok, d_1..d_k]`` in ONE
    ``commit_len=0`` pass (state untouched), the acceptance rule
    (``core/speculative.py``) turns the logits into a per-row
    ``commit_len``, and one verify-commit pass per model folds exactly the
    accepted prefix into the LLN ``(s, z, c_k)`` / diag tails / KV rows —
    a rejected draft never enters the running sums, so nothing is ever
    popped.  Rows of one batch accept different counts: positions, emit
    counts and commits are per-row throughout.

    * ``prefill_fn(params, batch) -> (last logits, tgt_caches,
      draft_caches)`` — both models prefill the prompt (the draft is a
      zero-copy first-k slice of the target's stacked layer params).
    * ``make_generate(steps, temperature=0.0, iters=None)`` — ONE jitted
      ``lax.scan`` whose carry holds BOTH decode states; each scan step is
      one draft+verify iteration emitting 1..k+1 tokens per row.  Returns
      ``(toks (B, iters, k+1), n_emit (B, iters), n_accept (B, iters),
      live (B, iters), tgt_caches, draft_caches)``; rows stop emitting
      once they reach ``steps`` tokens (``commit_len`` drops to 0 — the
      masked-row machinery).  ``iters`` defaults to ``steps`` (the worst
      case: every verify emits exactly one token).
      :func:`flatten_spec_tokens` flattens the per-iteration buffers into
      (B, steps) sequences on the host.

    Greedy (``temperature == 0``) speculative decode is token-for-token
    the plain greedy scanned loop (``tests/test_speculative.py``); the
    win is sequential target dispatches per token, reported by
    ``benchmarks/bench_spec.py``.
    """
    cfg: Any
    draft_cfg: Any
    model: Any
    draft_model: Any
    mesh: Any
    rules: dict
    spec_k: int
    draft_layers: int
    max_len: int
    prefill_fn: Any
    make_generate: Any = None


def make_spec_setup(cfg: ArchConfig, shape: ShapeSpec, mesh, *,
                    spec_k: int, draft_layers: int,
                    multi_pod: bool = False) -> SpecSetup:
    """Build the speculative-decode loop for a dense/MoE decoder.

    ``shape.seq_len`` is the cache budget: it must cover the prompt plus
    the generation budget plus one verify chunk of overshoot
    (``prompt + steps + spec_k + 1``).
    """
    if spec_k < 1:
        raise ValueError(f"spec_k must be >= 1, got {spec_k}")
    dcfg = draft_config(cfg, draft_layers)   # validates k and the family
    model = build_model(cfg)
    dmodel = build_model(dcfg)
    rules = shd.make_rules(cfg, multi_pod=multi_pod, serve=True)
    max_len = shape.seq_len
    k = spec_k

    def _prefill(params, batch):
        with shd.logical_rules(mesh, rules):
            logits, tgt = model.prefill(params, batch, max_len)
            _, dr = dmodel.prefill(draft_params(params, cfg, draft_layers),
                                   batch, max_len)
        return logits, tgt, dr

    prefill_fn = jax.jit(_prefill)

    def make_generate(steps: int, temperature: float = 0.0,
                      iters: Optional[int] = None):
        n_iters = steps if iters is None else iters

        def gen(params, tgt_caches, dr_caches, tok, pos0, key):
            b = tok.shape[0]
            dparams = draft_params(params, cfg, draft_layers)
            pos0 = jnp.broadcast_to(jnp.asarray(pos0, jnp.int32), (b,))

            def body(carry, i):
                tgt_caches, dr_caches, tok, pos, count = carry
                it_key = jax.random.fold_in(key, i)

                # Draft k tokens sequentially; the scratch state the
                # drafting accumulates is DISCARDED — the committed draft
                # state is refolded below through the same partial-commit
                # contract as the target.
                def dstep(dc, j):
                    dcache, cur = dc
                    lg, dcache = dmodel.decode(dparams, dcache, cur,
                                               pos + j)
                    nxt = sample_token(lg, temperature,
                                       jax.random.fold_in(it_key, j))
                    return (dcache, nxt), (nxt, lg)

                _, (drafts, dlogits) = jax.lax.scan(
                    dstep, (dr_caches, tok),
                    jnp.arange(k, dtype=jnp.int32))
                drafts = drafts.T                          # (B, k)
                dlogits = dlogits.transpose(1, 0, 2)       # (B, k, V)

                # Verify: ONE commit_len=0 target pass scores ALL k+1
                # positions (caches bitwise untouched) and returns the
                # per-layer (k, v) commit residuals.
                chunk = jnp.concatenate([tok[:, None], drafts], axis=1)
                tlogits, t_resid = model.score(params, tgt_caches, chunk,
                                               pos)
                n_acc, nxt, commit = speculative.verify_tokens(
                    drafts, tlogits, temperature,
                    key=jax.random.fold_in(it_key, k + 1),
                    draft_logits=dlogits)
                live = count < steps
                commit = jnp.where(live, commit, 0)

                # Single-pass verify: the accepted prefix folds from the
                # score residuals with the O(T d^2) per-layer einsum — no
                # second full target pass.  The draft (a first-k slice)
                # still commits via its own chunked decode.
                tgt_caches = model.commit(tgt_caches, t_resid, commit)
                _, dr_caches = dmodel.decode(dparams, dr_caches, chunk,
                                             pos, commit_len=commit)

                n_emit = jnp.where(live, n_acc + 1, 0)
                toks_out = speculative.emit_tokens(drafts, n_acc, nxt)
                tok = jnp.where(live, nxt, tok)
                pos = pos + commit
                count = count + n_emit
                return ((tgt_caches, dr_caches, tok, pos, count),
                        (toks_out, n_emit, jnp.where(live, n_acc, 0),
                         live))

            init = (tgt_caches, dr_caches, tok, pos0,
                    jnp.zeros((b,), jnp.int32))
            with shd.logical_rules(mesh, rules):
                (tgt_caches, dr_caches, *_), ys = jax.lax.scan(
                    body, init, jnp.arange(n_iters, dtype=jnp.int32))
            toks, n_emit, n_acc, live = ys
            return (toks.transpose(1, 0, 2), n_emit.T, n_acc.T, live.T,
                    tgt_caches, dr_caches)

        return jax.jit(gen, donate_argnums=(1, 2))

    return SpecSetup(cfg=cfg, draft_cfg=dcfg, model=model,
                     draft_model=dmodel, mesh=mesh, rules=rules,
                     spec_k=spec_k, draft_layers=draft_layers or
                     cfg.draft_layers, max_len=max_len,
                     prefill_fn=prefill_fn, make_generate=make_generate)


def flatten_spec_tokens(toks, n_emit, steps: int) -> np.ndarray:
    """Host-side flatten of one speculative run: per-iteration emit
    buffers ``toks (B, iters, k+1)`` + counts ``n_emit (B, iters)`` ->
    (B, steps) token sequences (each row concatenates its emitted
    prefixes; overshoot past ``steps`` is dropped)."""
    toks = np.asarray(toks)
    n_emit = np.asarray(n_emit)
    b = toks.shape[0]
    out = np.zeros((b, steps), np.int32)
    for r in range(b):
        seq: list[int] = []
        for it in range(toks.shape[1]):
            n = int(n_emit[r, it])
            seq.extend(int(x) for x in toks[r, it, :n])
            if len(seq) >= steps:
                break
        if len(seq) < steps:
            raise ValueError(f"row {r} emitted {len(seq)} < {steps} tokens"
                             " — increase iters")
        out[r] = np.asarray(seq[:steps], np.int32)
    return out


# ---------------------------------------------------------------------------
# Continuous batching: slotted request pool over per-row caches.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PoolSetup:
    """Jitted building blocks of the continuous-batching engine
    (``launch/batcher.py`` drives them; ``docs/serving.md`` has the
    lifecycle diagram).

    * ``cache_init()`` — pooled per-row caches for ``slots`` rows at
      ``max_len``: every leaf carries the slot axis, and the per-layer
      ``len``/``pos`` counters are (B,) vectors ((B, H) alpha/beta) so each
      slot sits at its own depth with its own prompt calibration.
    * ``prefill_fn(plen, batch=1)`` — a jitted slot-local prefill
      ``(params, tokens (batch, plen)) -> (last logits, slot caches)`` at
      the requests' EXACT prompt length (compiled once per distinct
      (length, group size) — the ragged-prompt rule: LLN state accumulates
      every key it sees, so right-padding a prompt would corrupt the
      carry; see docs/serving.md).  ``batch > 1`` admits a same-length
      group in one dispatch (the engine only groups when per-request
      semantics are preserved: softmax, or fixed alpha/beta — dynamic
      moment matching pools statistics over the prompt batch).
    * ``admit_fn(pooled, slot_caches, slot_idx)`` — scatters the k rows of
      a slot-local cache into pool rows ``slot_idx`` ((k,) int32) via one
      fused per-leaf scatter (donated pooled carry, no host copies).
    * ``segment_fn(params, caches, tok, pos, remaining, active, key) ->
      (caches, tok, pos, remaining, active, tokens (S, B), emitted (S, B),
      unhealthy (B,), metrics)`` — ``segment`` decode steps folded into
      ONE jitted ``lax.scan`` with donated cache carry.  Each step decodes every
      slot, samples only active rows, advances per-row positions, and
      retires rows whose ``remaining`` hits zero (in-scan evict: the
      row's mask drops, so by the masked-row contract nothing it does
      from then on can mutate state).  ``unhealthy`` is the state-health
      sentinel (``core/health.py``) evaluated on the post-segment caches
      INSIDE the same dispatch — one fused reduction, no extra round
      trip; all-False when the pool was built with ``health=None``.
      ``metrics`` is the streaming concentration telemetry
      (``core/metrics.py:streaming_concentration_tree``), a dict of (B,)
      instruments (``conc_drift``/``log_mass``/``log_mass_var``/
      ``tau_hat``) computed from the carried O(d^2) LLN state in the
      SAME jit (None when ``telemetry=False`` or the pool carries no
      LLN state — decided at trace time, so the structure is stable).  With ``health.check_drift`` set, rows whose
      ``|conc_drift|`` exceeds ``health.max_conc_drift`` are OR-ed into
      ``unhealthy`` — concentration drift rides the same quarantine /
      re-prefill / replay recovery as corruption.
      Steady-state throughput therefore matches the static
      ``make_generate`` loop — admits/evicts never leave the scan.
    * ``replay_fn(params, caches, chunk (B, R), pos (B,), commit (B,))``
      — advance per-row state over already-committed tokens WITHOUT
      emitting: one partial-commit chunked decode (``commit_len``
      contract; rows with ``commit = 0`` are bitwise untouched).  The
      quarantine → re-prefill recovery path uses it to rebuild a row's
      state from its committed tokens (re-prefill the prompt, then
      replay the emitted tokens in ``R``-sized pieces) — exact under
      every calibration mode, because the replayed trajectory IS the
      original decode trajectory.  Fixed ``R = replay_chunk`` keeps this
      one compile total.
    * ``evict_fn(caches, row_mask)`` — the engine's ``evict`` lifted over
      the stacked layer tree: zeroes the masked rows ((slots,) bool, a
      fixed shape so eviction costs ONE compile total) of every cache
      leaf in one fused (donated) pass, so stale request state never
      outlives its request.  Admission overwrites a slot wholesale either
      way; eviction keeps the pool clean between the two.
    """
    cfg: Any
    model: Any
    mesh: Any
    rules: dict
    slots: int
    max_len: int
    segment: int
    temperature: float
    cache_init: Any
    prefill_fn: Any
    admit_fn: Any
    segment_fn: Any
    evict_fn: Any = None
    replay_fn: Any = None
    health: Any = None
    replay_chunk: int = 8
    telemetry: bool = True
    # Speculative pool (spec_k >= 1): every cache tree becomes the paired
    # {"target", "draft"} dict, each segment step is one draft+verify
    # iteration emitting 0..k+1 tokens per row, ``segment_fn``'s ``toks``
    # is (S, B, k+1) with ``emitted`` (S, B) int32 counts.
    spec_k: int = 0
    draft_layers: int = 0
    draft_model: Any = None


_HEALTH_DEFAULT = HealthConfig()


def make_pool_setup(cfg: ArchConfig, mesh, params_struct=None, *,
                    slots: int, max_len: int, segment: int = 8,
                    temperature: float = 0.0,
                    multi_pod: bool = False,
                    health: Optional[HealthConfig] = _HEALTH_DEFAULT,
                    replay_chunk: int = 8,
                    telemetry: bool = True,
                    spec_k: int = 0,
                    draft_layers: int = 0) -> PoolSetup:
    """Build the jitted pieces of the continuous-batching pool.

    Supports the dense/MoE decoder families with standard attention
    (softmax / lln / lln_diag KV-state caches); MLA caches are not wired
    for per-row decode yet.

    ``health``: a ``core/health.py:HealthConfig`` (the default) folds the
    per-row state-health sentinel into ``segment_fn``'s jitted dispatch;
    ``health=None`` disables it (the ``unhealthy`` output is then all
    False).  ``replay_chunk``: token-chunk width of ``replay_fn`` (the
    quarantine-recovery replay path) — fixed so replay costs one compile.

    ``spec_k >= 1`` makes the pool rows SPECULATIVE: every cache tree is
    the paired ``{"target", "draft"}`` dict (both states prefill on
    admission, advance in lockstep through replay/evict, and the draft is
    the tied first-``draft_layers`` parameter slice — no extra weights),
    and each segment step runs one draft-k/verify/accept iteration whose
    per-row accept counts become per-row ``commit_len`` (done / masked /
    quarantined rows freeze via ``commit_len=0``).  The verify is
    SINGLE-PASS: one ``commit_len=0`` target score returns per-layer
    (k, v) residuals and the accepted prefix folds via the O(T d^2)
    ``lm_commit`` einsum instead of a second full transformer pass.
    ``segment_fn``'s token stream widens to ``toks (S, B, k+1)`` with
    ``emitted (S, B)`` int32 counts per step (0 for frozen rows, up to
    ``spec_k + 1`` otherwise); a row may overshoot its budget by up to
    ``spec_k`` tokens in its final segment — the batcher caps harvest at
    the request budget and ``check_request`` reserves ``spec_k + 1`` cache
    slack.

    The pool's model calibrates moment matching PER ROW
    (``lln_per_row_calib=True``: each request's alpha/beta come from its
    own prompt statistics, (B, H) in the slot cache), which is what makes
    a batched slot prefill exact per request and lets the batcher group
    same-length admits even under dynamic moment matching.
    """
    if cfg.family not in ("dense", "moe", "ssm", "hybrid") \
            or cfg.kv_lora > 0:
        raise NotImplementedError(
            "continuous batching supports dense/moe decoders and "
            "ssm/hybrid models "
            f"(family={cfg.family}, kv_lora={cfg.kv_lora})")
    if spec_k < 0:
        raise ValueError(f"spec_k must be >= 0, got {spec_k}")
    if spec_k >= 1 and cfg.family not in ("dense", "moe"):
        raise NotImplementedError(
            "speculative pools need a first-k-layers draft "
            f"(family={cfg.family})")
    cfg = cfg.replace(lln_per_row_calib=True)
    model = build_model(cfg)
    rules = shd.make_rules(cfg, multi_pod=multi_pod, serve=True)
    speculative_pool = spec_k >= 1
    dmodel = None
    if speculative_pool:
        dcfg = draft_config(cfg, draft_layers)  # validates k and the family
        draft_layers = draft_layers or cfg.draft_layers
        dmodel = build_model(dcfg)
    k = spec_k

    def cache_init():
        struct = params_struct if params_struct is not None else \
            jax.eval_shape(model.init, jax.random.PRNGKey(0))
        tgt = model.cache_init(struct, slots, max_len, per_row=True)
        if not speculative_pool:
            return tgt
        # lm_cache_init derives the layout from cfg alone — the params
        # struct is signature-compat only, so the target's serves both.
        return {"target": tgt,
                "draft": dmodel.cache_init(struct, slots, max_len,
                                           per_row=True)}

    def _pf(params, tokens):
        with shd.logical_rules(mesh, rules):
            logits, tgt = model.prefill(params, {"inputs": tokens}, max_len)
            if not speculative_pool:
                return logits, tgt
            _, dr = dmodel.prefill(draft_params(params, cfg, draft_layers),
                                   {"inputs": tokens}, max_len)
        return logits, {"target": tgt, "draft": dr}

    _pf_jit = jax.jit(_pf)

    def prefill_fn(plen: int, batch: int = 1):
        # jax.jit caches executables per input shape, so one jitted object
        # serves every (prompt length, admit-group size); the signature
        # documents that each distinct pair costs one trace/compile.
        del plen, batch
        return _pf_jit

    def _admit(pooled, slot_caches, slot_idx):
        """Scatter a k-row slot-local cache into pool rows ``slot_idx``
        ((k,) int32).  Scalar-per-layer leaves (len/pos/alpha/beta, which a
        batched prefill shares across its rows) broadcast over the group.
        """
        k_rows = slot_idx.shape[0]

        def leaf(pl, sl):
            sl = sl.astype(pl.dtype)
            if sl.ndim == pl.ndim - 1:     # scalar-per-layer (len/pos/alpha)
                sl = jnp.broadcast_to(
                    sl[:, None], sl.shape[:1] + (k_rows,) + sl.shape[1:])
            return pl.at[:, slot_idx].set(sl)
        return jax.tree_util.tree_map(leaf, pooled, slot_caches)

    admit_fn = jax.jit(_admit, donate_argnums=(0,))

    def _evict(pooled, row_mask):
        """AttentionEngine.evict lifted over the stacked layer tree: reset
        the rows where ``row_mask`` ((slots,) bool) is True, on every leaf
        (slot axis at position 1, after the stacked-layer axis), to their
        ``init_state`` values — zeros everywhere EXCEPT the per-row
        calibration ``alpha``/``beta``, which reset to ones.  Zeroing the
        calibration would leave a freed slot carrying an out-of-contract
        value (init is ones), and a stale previous-request alpha/beta must
        never survive into the next request admitted to that slot.  A
        fixed (slots,) mask keeps this ONE compiled executable regardless
        of how many slots free per segment."""
        def clear(path, leaf):
            name = getattr(path[-1], "key", None)
            fill = (jnp.ones((), leaf.dtype) if name in ("alpha", "beta")
                    else jnp.zeros((), leaf.dtype))
            keep = ~row_mask.reshape((1, -1) + (1,) * (leaf.ndim - 2))
            return jnp.where(keep, leaf, fill)
        return jax.tree_util.tree_map_with_path(clear, pooled)

    evict_fn = jax.jit(_evict, donate_argnums=(0,))

    def _sentinel(tree, active):
        """Health + telemetry on the post-segment caches, fused into the
        segment dispatch.  ``tree`` is the TARGET cache tree (the draft of
        a speculative pool is a derived scratch state — corruption shows
        up in the target it commits against).  Row axis is 1 (after the
        stacked-layer axis)."""
        if health is not None:
            unhealthy = unhealthy_rows(tree, row_axis=1, config=health)
        else:
            unhealthy = jnp.zeros((slots,), jnp.bool_)
        # Streaming concentration telemetry on the same post-segment caches
        # (core/metrics.py): O(H d) per row off the carried (s, z, c_k)
        # state, in the SAME jit.  Whether the metrics dict exists is
        # decided at trace time (the cache tree either carries LLN ``z``
        # leaves or it doesn't), so the output pytree is stable per
        # compiled executable: a dict of fixed (B,) keys, or None for
        # ``telemetry=False`` / softmax-only pools.
        metrics = None
        conc = streaming_concentration_tree(tree, row_axis=1) \
            if telemetry else None
        if conc is not None:
            zero = jnp.zeros((slots,), jnp.float32)
            metrics = {k: conc.get(k, zero).astype(jnp.float32)
                       for k in ("log_mass", "log_mass_var",
                                 "tau_hat", "conc_drift")}
            if health is not None and health.check_drift:
                # Concentration drift -> quarantine: rides the same
                # re-prefill/replay recovery as a corrupted row.  Gated on
                # ``active``: a freed slot's zero state has meaningless
                # (hugely negative) log mass.
                drift_bad = active & (jnp.abs(metrics["conc_drift"])
                                      > health.max_conc_drift)
                unhealthy = unhealthy | drift_bad
        return unhealthy, metrics

    def _segment(params, caches, tok, pos, remaining, active, key):
        def body(carry, i):
            caches, tok, pos, remaining, active = carry
            logits, caches = model.decode(params, caches, tok, pos,
                                          row_mask=active)
            # Masked rows' logits are garbage by the decode contract (they
            # may even be NaN from a freshly evicted slot); neutralize them
            # BEFORE sampling so garbage never reaches sample_token.
            logits = jnp.where(active[:, None], logits, 0.0)
            nxt = sample_token(logits, temperature,
                               jax.random.fold_in(key, i))
            tok = jnp.where(active, nxt, tok)
            emitted = active
            adv = active.astype(jnp.int32)
            pos = pos + adv
            remaining = remaining - adv
            active = active & (remaining > 0)
            return (caches, tok, pos, remaining, active), (tok, emitted)

        with shd.logical_rules(mesh, rules):
            carry, (toks, emitted) = jax.lax.scan(
                body, (caches, tok, pos, remaining, active),
                jnp.arange(segment, dtype=jnp.int32))
        caches, tok, pos, remaining, active = carry
        unhealthy, metrics = _sentinel(caches, active)
        return (caches, tok, pos, remaining, active, toks, emitted,
                unhealthy, metrics)

    def _segment_spec(params, caches, tok, pos, remaining, active, key):
        """Speculative segment: each scan step is one draft-k/verify/accept
        iteration over the paired {"target", "draft"} states.  Frozen rows
        (done / masked / quarantined) ride ``commit_len=0`` — bitwise
        inert on both states.  Emits (S, B, k+1) tokens with (S, B) int32
        per-step counts (0 for frozen rows)."""
        dparams = draft_params(params, cfg, draft_layers)

        def body(carry, i):
            caches, tok, pos, remaining, active = carry
            tgt, dr = caches["target"], caches["draft"]
            it_key = jax.random.fold_in(key, i)

            # Draft k tokens sequentially on scratch draft state (the
            # scratch advance is discarded; the committed draft state
            # refolds below through the partial-commit contract).
            def dstep(dc, j):
                dcache, cur = dc
                lg, dcache = dmodel.decode(dparams, dcache, cur, pos + j,
                                           row_mask=active)
                lg = jnp.where(active[:, None], lg, 0.0)
                nxt = sample_token(lg, temperature,
                                   jax.random.fold_in(it_key, j))
                return (dcache, nxt), (nxt, lg)

            _, (drafts, dlogits) = jax.lax.scan(
                dstep, (dr, tok), jnp.arange(k, dtype=jnp.int32))
            drafts = drafts.T                          # (B, k)
            dlogits = dlogits.transpose(1, 0, 2)       # (B, k, V)

            # Single-pass verify: ONE commit_len=0 target score over the
            # whole [tok, d_1..d_k] chunk returns logits for all k+1
            # positions AND the per-layer (k, v) commit residuals; the
            # target caches stay bitwise untouched.
            chunk = jnp.concatenate([tok[:, None], drafts], axis=1)
            tlogits, t_resid = model.score(params, tgt, chunk, pos,
                                           row_mask=active)
            tlogits = jnp.where(active[:, None, None], tlogits, 0.0)
            n_acc, nxt, commit = speculative.verify_tokens(
                drafts, tlogits, temperature,
                key=jax.random.fold_in(it_key, k + 1),
                draft_logits=dlogits)
            # Per-row accept counts -> per-row commit_len; frozen rows
            # commit nothing (the masked-row contract, bitwise).
            commit = jnp.where(active, commit, 0)
            tgt = model.commit(tgt, t_resid, commit, row_mask=active)
            _, dr = dmodel.decode(dparams, dr, chunk, pos,
                                  commit_len=commit, row_mask=active)

            n_emit = jnp.where(active, n_acc + 1, 0)
            toks_out = speculative.emit_tokens(drafts, n_acc, nxt)
            tok = jnp.where(active, nxt, tok)
            pos = pos + commit
            remaining = remaining - n_emit
            active = active & (remaining > 0)
            return ({"target": tgt, "draft": dr}, tok, pos, remaining,
                    active), (toks_out, n_emit)

        with shd.logical_rules(mesh, rules):
            carry, (toks, emitted) = jax.lax.scan(
                body, (caches, tok, pos, remaining, active),
                jnp.arange(segment, dtype=jnp.int32))
        caches, tok, pos, remaining, active = carry
        unhealthy, metrics = _sentinel(caches["target"], active)
        return (caches, tok, pos, remaining, active, toks, emitted,
                unhealthy, metrics)

    segment_fn = jax.jit(_segment_spec if speculative_pool else _segment,
                         donate_argnums=(1,))

    def _replay(params, caches, chunk, pos, commit):
        """Advance per-row state over already-committed tokens without
        emitting: one chunked decode under the partial-commit contract
        (rows with ``commit = 0`` are bitwise untouched).  A speculative
        pool replays BOTH paired states — the replayed trajectory is the
        original committed trajectory for each."""
        with shd.logical_rules(mesh, rules):
            if speculative_pool:
                _, tgt = model.decode(params, caches["target"], chunk,
                                      pos, commit_len=commit)
                _, dr = dmodel.decode(draft_params(params, cfg,
                                                   draft_layers),
                                      caches["draft"], chunk, pos,
                                      commit_len=commit)
                return {"target": tgt, "draft": dr}
            _, caches = model.decode(params, caches, chunk, pos,
                                     commit_len=commit)
        return caches

    replay_fn = jax.jit(_replay, donate_argnums=(1,))

    return PoolSetup(cfg=cfg, model=model, mesh=mesh, rules=rules,
                     slots=slots, max_len=max_len, segment=segment,
                     temperature=temperature, cache_init=cache_init,
                     prefill_fn=prefill_fn, admit_fn=admit_fn,
                     segment_fn=segment_fn, evict_fn=evict_fn,
                     replay_fn=replay_fn, health=health,
                     replay_chunk=replay_chunk, telemetry=telemetry,
                     spec_k=spec_k, draft_layers=draft_layers,
                     draft_model=dmodel)
