"""Fault-injection harness for the continuous-batching serving engine.

A :class:`FaultPlan` is a deterministic, seedable script of failures the
``ContinuousBatcher`` applies at segment boundaries — the only way to
*prove* the recovery paths (sentinel → quarantine → re-prefill, deadline
timeouts, snapshot/restore) actually work end to end, and to reproduce a
production failure offline from its plan.

Event kinds (``FaultEvent.kind``):

* ``"nan"``   — poison every float cache leaf of pool row ``row`` with
  NaN before segment ``segment`` runs (``row = -1`` picks a seeded
  pseudo-random row).  Exercises the state-health sentinel and the
  quarantine → re-prefill recovery path.
* ``"drop"``  — drop request ``rid`` (client-cancel): evicted from its
  slot or removed from the queue; terminates with status ``failed``.
* ``"delay"`` — sleep ``seconds`` inside the segment's timed window:
  trips per-request deadlines and the straggler watchdog.
* ``"kill"``  — simulate a process crash at the boundary by raising
  :class:`SimulatedCrash`; the driver restores from the last pool
  snapshot (``serve.py --restore``) and every in-flight request must
  resume to the same final tokens.

Plans serialize to/from JSON (``--fault-plan`` accepts a path or an
inline JSON literal)::

    {"seed": 0, "events": [{"kind": "nan", "segment": 2, "row": 1},
                           {"kind": "kill", "segment": 4}]}
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

FAULT_KINDS = ("nan", "drop", "delay", "kill")


class SimulatedCrash(RuntimeError):
    """Raised by a ``kill`` fault event: the serving loop 'crashed' at a
    segment boundary.  ``segment`` is the boundary index; the driver
    resumes from the last snapshot (``ContinuousBatcher.run(resume=...)``)."""

    def __init__(self, segment: int):
        super().__init__(f"simulated crash at segment boundary {segment}")
        self.segment = segment


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scripted failure, fired at the boundary BEFORE segment
    ``segment`` runs (0-based: ``segment=0`` fires before any decode)."""
    kind: str
    segment: int
    row: int = -1          # nan: pool row (-1 = seeded random active row)
    rid: int = -1          # drop: request id
    seconds: float = 0.0   # delay: sleep duration

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.segment < 0:
            raise ValueError("fault segment must be >= 0")


@dataclasses.dataclass
class FaultPlan:
    """A deterministic schedule of :class:`FaultEvent`\\ s.  ``seed``
    drives any randomized choices (e.g. ``row = -1`` NaN targets) so a
    plan replays identically run over run."""
    events: list = dataclasses.field(default_factory=list)
    seed: int = 0

    def __post_init__(self):
        self.events = [e if isinstance(e, FaultEvent) else FaultEvent(**e)
                       for e in self.events]
        self._rng = np.random.RandomState(self.seed)

    def at(self, segment: int) -> list:
        """Events scheduled for the given segment boundary, in order."""
        return [e for e in self.events if e.segment == segment]

    def pick_row(self, event: FaultEvent, slots: int,
                 active: Optional[np.ndarray] = None) -> int:
        """Resolve an event's target row; ``row = -1`` draws a seeded
        pseudo-random row (preferring currently active ones)."""
        if event.row >= 0:
            return event.row
        if active is not None and active.any():
            cand = np.nonzero(active)[0]
        else:
            cand = np.arange(slots)
        return int(cand[self._rng.randint(len(cand))])

    def to_json(self) -> str:
        return json.dumps({"seed": self.seed,
                           "events": [dataclasses.asdict(e)
                                      for e in self.events]})

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        obj = json.loads(text)
        return cls(events=obj.get("events", []), seed=obj.get("seed", 0))

    @classmethod
    def load(cls, spec: str) -> "FaultPlan":
        """Parse a CLI ``--fault-plan`` argument: a JSON file path or an
        inline JSON literal."""
        if os.path.exists(spec):
            with open(spec) as f:
                return cls.from_json(f.read())
        return cls.from_json(spec)


def poison_rows(caches, rows) -> object:
    """Set every float leaf of the given pool rows to NaN.

    ``caches`` is the pooled stacked-layer cache tree (row axis at
    position 1, after the layer axis); ``rows`` is a sequence of slot
    indices.  This is the worst legal corruption a row can suffer — the
    sentinel must detect it and the quarantine machinery must contain it.
    """
    idx = jnp.asarray(list(rows), jnp.int32)

    def leaf(a):
        if not jnp.issubdtype(a.dtype, jnp.floating) or a.ndim < 2:
            return a
        return a.at[:, idx].set(jnp.nan)
    return jax.tree_util.tree_map(leaf, caches)


__all__ = ["FaultEvent", "FaultPlan", "SimulatedCrash", "poison_rows",
           "FAULT_KINDS"]
