"""Continuous-batching serving engine: a slotted request pool.

The static serving loop (``ServeSetup.make_generate``) advances one batch
shape in lockstep: every row prefills together and decodes until the LAST
row finishes, so under skewed request lengths short requests pin their slot
while a straggler drains.  This engine keeps a pool of ``slots`` rows where
each slot carries its own absolute position, its own remaining-token budget
and an active mask:

* **admit** — queued requests are prefilled slot-locally at their EXACT
  prompt length (see the ragged-prompt rule in docs/serving.md) and their
  decode state (LLN ``(s, z)`` + diag tail, or the softmax KV block) is
  scattered into the freed pool rows (``PoolSetup.admit_fn``), while the
  other rows keep decoding from where they are — admission is mid-segment
  from the pool's point of view.  Same-length queued prompts admit as ONE
  batched prefill when that is exact (softmax / fixed alpha/beta; dynamic
  moment matching pools prompt-batch statistics, so those configs prefill
  per request);
* **decode** — ``segment`` steps run as ONE jitted ``lax.scan`` with the
  pooled cache carry donated (``PoolSetup.segment_fn``), so steady-state
  throughput matches the static scanned loop;
* **evict** — a row whose budget hits zero drops out of the active mask
  *inside* the scan (masked rows provably advance nothing: KV writes, LLN
  state, tails and positions are all ``where``-guarded on the mask), and
  its slot is handed back to the queue at the next segment boundary.

Why this is cheap for LLN attention: the per-request decode state is
O(d^2) — a (H, D, Dv) matrix, a (H, D) vector and a diag tail block —
independent of how long the request's history is, so admitting a request
into a slot moves a few hundred KB instead of re-paging a full softmax KV
cache.  (Softmax caches work too; they just move O(max_len) bytes.)

The engine is deliberately host-driven between segments (admission needs a
queue, which jit cannot own); everything per-token is inside the scan.

Robustness layer (``docs/serving.md`` "Failure handling" has the lifecycle
diagram; ``tests/test_robustness.py`` proves each path end to end):

* **lifecycle guards** — admission validates every request (rid, prompt
  shape/vocab, budget vs. pool capacity) and rejects with typed
  :class:`AdmissionError`/:class:`QueueFullError` reasons instead of
  crashing mid-scan; per-request ``deadline_s`` budgets are enforced at
  segment boundaries; every request terminates with an explicit status
  (``done | timeout | rejected | failed | retried``) in
  :class:`BatchingStats`;
* **state-health sentinel** — ``segment_fn`` returns a per-row
  ``unhealthy`` flag (``core/health.py``, fused into the decode dispatch).
  A flagged row is QUARANTINED: its segment tokens are discarded (its
  committed prefix stays clean), its slot is evicted, and the request is
  re-queued with exponential backoff.  On re-admission the row is rebuilt
  exactly — re-prefill the original prompt (bitwise-identical calibration)
  then replay the already-emitted tokens through
  ``PoolSetup.replay_fn`` (the partial-commit contract) — so one poisoned
  row costs one slot re-prefill, never the pool;
* **streaming concentration telemetry** — ``segment_fn`` also returns the
  per-row concentration instruments
  (``core/metrics.py:streaming_concentration_tree``: log key mass, its
  per-token drift, log-variance, temperature proxy) computed from the
  carried O(d^2) LLN state inside the same jit; the last segment's
  summary lands in ``BatchingStats.telemetry``, and with
  ``HealthConfig.check_drift`` a drifting row is quarantined through the
  sentinel path above;
* **snapshot/restore** — with a ``snapshot_mgr``
  (``checkpoint/manager.py:CheckpointManager``), the full serving carry
  (pooled caches + tok/pos/remaining/active + the loop PRNG key) plus the
  host metadata (queue, per-row request map, outputs, statuses) is saved
  atomically every ``snapshot_every`` segments; ``run(resume=True)``
  resumes every in-flight request mid-stream after a crash
  (``launch/serve.py --restore``);
* **fault injection** — ``run(fault_plan=...)`` applies a deterministic
  ``launch/faults.py:FaultPlan`` (NaN poison / drop / delay / kill) at
  segment boundaries;
* **straggler watchdog** — each segment's wall clock feeds a
  ``distributed/straggler.py:StepWatchdog`` EWMA; anomalies surface as
  ``StragglerReport`` entries in the final stats.
"""
from __future__ import annotations

import dataclasses
import json
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import restore as _restore_tree
from repro.distributed.straggler import StepWatchdog
from repro.launch.faults import FaultPlan, SimulatedCrash, poison_rows
from repro.launch.steps import PoolSetup, make_pool_setup


class RequestError(ValueError):
    """Base class for typed request-lifecycle failures."""


class AdmissionError(RequestError):
    """Request failed admission validation (bad rid/prompt/budget)."""


class QueueFullError(RequestError):
    """Admission queue is at ``queue_cap``; request rejected, not queued."""


#: Every request ends in exactly one of these (``BatchingStats.statuses``).
REQUEST_STATUSES = ("done", "timeout", "rejected", "failed", "retried")


@dataclasses.dataclass
class Request:
    """One generation request: ``prompt`` (plen,) int32 token ids and the
    number of tokens to generate (``gen_len`` >= 1; the first generated
    token comes from the prefill's last-position logits).  ``deadline_s``
    is an optional wall-clock budget measured from enqueue and enforced at
    segment boundaries; ``max_tokens`` optionally caps the stored output
    buffer below ``gen_len`` (the effective budget is the min of the
    two)."""
    rid: int
    prompt: np.ndarray
    gen_len: int
    deadline_s: Optional[float] = None
    max_tokens: Optional[int] = None

    @property
    def budget(self) -> int:
        """Effective generation budget: ``min(gen_len, max_tokens)``."""
        if self.max_tokens is None:
            return self.gen_len
        return min(self.gen_len, self.max_tokens)


@dataclasses.dataclass
class BatchingStats:
    """Engine run summary.  ``outputs`` maps rid -> generated tokens
    (length == the request's budget for completed requests; partial for
    timeouts/failures).  ``completed_tokens`` counts tokens of requests
    that finished (status ``done``/``retried`` — the goodput numerator);
    ``decode_steps`` counts scan steps actually dispatched (segments *
    segment length).  ``statuses`` maps every rid to its terminal status
    (one of :data:`REQUEST_STATUSES`); ``reject_reasons`` carries the
    typed-error message for rejected/failed rids.  ``telemetry`` is the
    LAST segment's streaming-concentration summary over live rows
    (``conc_drift_max``/``log_mass_mean``/``log_mass_var_mean``/
    ``tau_hat_mean``) — empty for softmax pools or ``telemetry=False``
    setups."""
    outputs: dict
    completed_tokens: int
    decode_steps: int
    segments: int
    admitted: int
    wall_s: float
    statuses: dict = dataclasses.field(default_factory=dict)
    reject_reasons: dict = dataclasses.field(default_factory=dict)
    recoveries: int = 0
    retries: int = 0
    timeouts: int = 0
    rejected: int = 0
    failed: int = 0
    health_events: list = dataclasses.field(default_factory=list)
    stragglers: list = dataclasses.field(default_factory=list)
    segment_ewma_s: float = 0.0
    snapshots: int = 0
    restored_step: Optional[int] = None
    telemetry: dict = dataclasses.field(default_factory=dict)
    # Speculative pools (``PoolSetup.spec_k >= 1``): acceptance-aware
    # goodput.  ``verify_iters`` counts draft+verify iterations that
    # emitted anything; ``drafted_tokens`` = spec_k * verify_iters;
    # ``accepted_tokens`` counts accepted DRAFT tokens (the bonus/resample
    # token each iteration emits is excluded — acceptance_rate is the
    # draft hit rate); ``goodput_tokens_per_iter`` = emitted tokens per
    # verify iteration, in [1, spec_k + 1].
    spec_k: int = 0
    drafted_tokens: int = 0
    accepted_tokens: int = 0
    acceptance_rate: float = 0.0
    verify_iters: int = 0
    goodput_tokens_per_iter: float = 0.0


def synthetic_traffic(n_requests: int, vocab: int, prompt_lens,
                      gen_lens, seed: int = 0) -> list[Request]:
    """Mixed-length synthetic traffic: prompts/gen budgets drawn round-robin
    from the given length menus (deterministic — benchmarks and parity
    tests need identical request streams across engines)."""
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n_requests):
        plen = int(prompt_lens[i % len(prompt_lens)])
        glen = int(gen_lens[i % len(gen_lens)])
        prompt = rng.randint(0, vocab, size=(plen,)).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, gen_len=glen))
    return reqs


@dataclasses.dataclass
class _Tracked:
    """Host-side lifecycle record for one accepted request."""
    req: Request
    deadline_at: Optional[float] = None   # absolute time.monotonic() bound
    retries: int = 0
    eligible_seg: int = 0                 # backoff: earliest admit boundary


@dataclasses.dataclass
class _RunState:
    """Everything one :meth:`ContinuousBatcher.run` mutates — bundled so
    the snapshot/restore path serializes ONE object's fields."""
    caches: object = None
    tok: object = None
    pos: object = None
    remaining: object = None
    active: object = None
    key: object = None
    slot_rid: np.ndarray = None
    queue: deque = dataclasses.field(default_factory=deque)
    tracked: dict = dataclasses.field(default_factory=dict)
    outputs: dict = dataclasses.field(default_factory=dict)
    statuses: dict = dataclasses.field(default_factory=dict)
    reject_reasons: dict = dataclasses.field(default_factory=dict)
    health_events: list = dataclasses.field(default_factory=list)
    segments: int = 0
    decode_steps: int = 0
    admitted: int = 0
    recoveries: int = 0
    rejected: int = 0
    snapshots: int = 0
    restored_step: Optional[int] = None
    telemetry: dict = dataclasses.field(default_factory=dict)
    emitted_tokens: int = 0
    verify_iters: int = 0
    accepted_tokens: int = 0
    drafted_tokens: int = 0


class ContinuousBatcher:
    """Drives a ``PoolSetup`` over a queue of :class:`Request`s.

    Typical use (see ``launch/serve.py --continuous`` for the CLI form)::

        setup = make_pool_setup(cfg, mesh, slots=4, max_len=256, segment=8)
        eng = ContinuousBatcher(setup, params)
        stats = eng.run(synthetic_traffic(...))

    ``queue_cap`` bounds the admission queue (excess requests reject with
    status ``rejected`` instead of growing host memory without bound);
    ``max_retries`` bounds quarantine-recovery attempts per request;
    ``snapshot_mgr``/``snapshot_every`` enable pool snapshots (see the
    module docstring).
    """

    def __init__(self, setup: PoolSetup, params, *, queue_cap: int = 1024,
                 max_retries: int = 2, snapshot_mgr=None,
                 snapshot_every: int = 0):
        self.setup = setup
        self.params = params
        self.key = jax.random.PRNGKey(0)
        self.queue_cap = queue_cap
        self.max_retries = max_retries
        self.snapshot_mgr = snapshot_mgr
        self.snapshot_every = snapshot_every
        # Grouped admission (one batched prefill for several same-length
        # queued prompts) is exact whenever prefill is per-row
        # independent: softmax has no calibration, fixed alpha/beta skips
        # moment matching, and per-row calibration
        # (``lln_per_row_calib``, the make_pool_setup default) measures
        # each row's statistics alone — so dynamic moment matching can
        # now use batched slot prefill too.  Only a pool explicitly built
        # with batch-pooled calibration must admit one request at a time.
        cfg = setup.cfg
        self.group_admits = (cfg.attn_impl == "softmax"
                             or cfg.lln_fixed_ab != 0
                             or getattr(cfg, "lln_per_row_calib", False))

    # ------------------------------------------------------------------
    # Validation (the typed-rejection path).
    # ------------------------------------------------------------------

    def check_request(self, req: Request) -> None:
        """Raise :class:`AdmissionError` if the request can never be
        served by this pool (bad rid, malformed prompt, out-of-vocab
        tokens, budget exceeding pool capacity)."""
        s = self.setup
        if req.rid < 0:
            raise AdmissionError(
                f"request rid must be >= 0 (-1 marks a free slot), "
                f"got {req.rid}")
        p = np.asarray(req.prompt)
        if p.ndim != 1 or p.shape[0] < 1:
            raise AdmissionError(
                f"request {req.rid}: prompt must be a non-empty 1-D "
                f"token array, got shape {p.shape}")
        if not np.issubdtype(p.dtype, np.integer):
            raise AdmissionError(
                f"request {req.rid}: prompt dtype {p.dtype} is not "
                "integer token ids")
        vocab = int(getattr(s.cfg, "vocab", 0) or 0)
        if vocab and (int(p.min()) < 0 or int(p.max()) >= vocab):
            raise AdmissionError(
                f"request {req.rid}: token ids outside [0, {vocab})")
        if req.gen_len < 1:
            raise AdmissionError(
                f"request {req.rid}: gen_len must be >= 1, "
                f"got {req.gen_len}")
        if req.max_tokens is not None and req.max_tokens < 1:
            raise AdmissionError(
                f"request {req.rid}: max_tokens must be >= 1, "
                f"got {req.max_tokens}")
        if req.deadline_s is not None and req.deadline_s <= 0:
            raise AdmissionError(
                f"request {req.rid}: deadline_s must be > 0, "
                f"got {req.deadline_s}")
        # Speculative pools reserve ``spec_k`` rows of cache slack: a
        # row's final iteration may commit up to spec_k tokens past its
        # budget, and every score pass needs room for the whole
        # (spec_k + 1)-token chunk before the partial commit rolls the
        # unaccepted suffix back.
        slack = getattr(s, "spec_k", 0)
        if p.shape[0] + req.budget + slack > s.max_len:
            raise AdmissionError(
                f"request {req.rid}: prompt {p.shape[0]} + gen "
                f"{req.budget}" + (f" + spec slack {slack}" if slack else "")
                + f" exceeds max_len {s.max_len}")

    def _enqueue(self, st: _RunState, req: Request) -> bool:
        try:
            self.check_request(req)
            if req.rid in st.tracked or req.rid in st.outputs:
                raise AdmissionError(f"duplicate request rid {req.rid}")
            if len(st.queue) >= self.queue_cap:
                raise QueueFullError(
                    f"request {req.rid}: admission queue at cap "
                    f"{self.queue_cap}")
        except RequestError as e:
            st.rejected += 1
            rid = req.rid
            if rid >= 0 and rid not in st.tracked and rid not in st.outputs:
                st.outputs[rid] = []
                st.statuses[rid] = "rejected"
                st.reject_reasons[rid] = str(e)
            return False
        deadline = (time.monotonic() + req.deadline_s
                    if req.deadline_s is not None else None)
        tr = _Tracked(req=req, deadline_at=deadline)
        st.tracked[req.rid] = tr
        st.outputs[req.rid] = []
        st.queue.append(tr)
        return True

    # ------------------------------------------------------------------
    # Admission (fresh groups + quarantine-recovery resumes).
    # ------------------------------------------------------------------

    def _admit_all(self, st: _RunState) -> None:
        s = self.setup
        free = list(np.nonzero(st.slot_rid < 0)[0])
        while free:
            idx = next((i for i, tr in enumerate(st.queue)
                        if tr.eligible_seg <= st.segments), None)
            if idx is None:
                break
            tr = st.queue[idx]
            del st.queue[idx]
            if st.outputs[tr.req.rid]:
                # Re-queued by quarantine recovery: the request already
                # holds committed tokens — rebuild its row mid-stream.
                self._admit_resume(st, tr, int(free.pop(0)))
                continue
            group = [tr]
            plen = tr.req.prompt.shape[0]
            # Group only CONSECUTIVE eligible fresh same-length prompts
            # (keeps admission order close to FCFS).
            while (self.group_admits and idx < len(st.queue)
                   and len(group) < len(free)):
                nxt = st.queue[idx]
                if (nxt.eligible_seg > st.segments
                        or st.outputs[nxt.req.rid]
                        or nxt.req.prompt.shape[0] != plen):
                    break
                group.append(nxt)
                del st.queue[idx]
            self._admit_group(st, group, free)

    def _admit_group(self, st: _RunState, group: list, free: list) -> None:
        s = self.setup
        plen = group[0].req.prompt.shape[0]
        pf = s.prefill_fn(plen, len(group))
        prompts = jnp.asarray(np.stack([t.req.prompt for t in group]))
        logits, slot_caches = pf(self.params, prompts)
        last = logits[:, -1] if logits.ndim == 3 else logits
        tok0 = np.asarray(jnp.argmax(last, -1).astype(jnp.int32))
        live, live_slots, live_rem = [], [], []
        for j, tr in enumerate(group):
            rid = tr.req.rid
            st.outputs[rid].append(int(tok0[j]))
            st.admitted += 1
            if tr.req.budget <= 1:          # done at prefill; slot free
                st.statuses[rid] = "done"
                del st.tracked[rid]
                continue
            slot = int(free.pop(0))
            live.append(j)
            live_slots.append(slot)
            live_rem.append(tr.req.budget - 1)
            st.slot_rid[slot] = rid
        if not live:
            return
        if len(live) != len(group):          # drop prefill-only rows
            sel = jnp.asarray(live)
            # Leaves whose rank matches the pooled leaf carry the
            # admit-group axis at position 1; lower-rank leaves
            # (len/pos/alpha/beta) are shared across the group.
            slot_caches = jax.tree_util.tree_map(
                lambda sl, pl: sl[:, sel] if sl.ndim == pl.ndim
                else sl, slot_caches, st.caches)
        slots_dev = jnp.asarray(live_slots, jnp.int32)
        st.caches = s.admit_fn(st.caches, slot_caches, slots_dev)
        st.tok = st.tok.at[slots_dev].set(jnp.asarray(tok0[live]))
        st.pos = st.pos.at[slots_dev].set(
            jnp.full((len(live),), plen, jnp.int32))
        st.remaining = st.remaining.at[slots_dev].set(
            jnp.asarray(live_rem, jnp.int32))
        st.active = st.active.at[slots_dev].set(True)

    def _admit_resume(self, st: _RunState, tr: _Tracked, slot: int) -> None:
        """Rebuild a quarantined request's row from its committed tokens:
        re-prefill the ORIGINAL prompt solo (bitwise-identical per-row
        calibration), then replay the emitted tokens minus the last one
        through ``replay_fn`` (partial-commit: every other row's
        ``commit_len`` is 0, so the rest of the pool is untouched).  The
        replayed trajectory IS the original decode trajectory, so the
        rebuilt state is exact under every calibration mode."""
        s = self.setup
        req = tr.req
        emitted = st.outputs[req.rid]
        plen = req.prompt.shape[0]
        n = len(emitted)
        pf = s.prefill_fn(plen, 1)
        _, slot_caches = pf(self.params, jnp.asarray(req.prompt)[None, :])
        slot_dev = jnp.asarray([slot], jnp.int32)
        st.caches = s.admit_fn(st.caches, slot_caches, slot_dev)
        replay = emitted[:-1]
        r_chunk = s.replay_chunk
        for off in range(0, len(replay), r_chunk):
            piece = replay[off:off + r_chunk]
            chunk = np.zeros((s.slots, r_chunk), np.int32)
            chunk[slot, :len(piece)] = piece
            commit = np.zeros((s.slots,), np.int32)
            commit[slot] = len(piece)
            pos_r = st.pos.at[slot].set(plen + off)
            st.caches = s.replay_fn(self.params, st.caches,
                                    jnp.asarray(chunk), pos_r,
                                    jnp.asarray(commit))
        st.tok = st.tok.at[slot].set(int(emitted[-1]))
        st.pos = st.pos.at[slot].set(plen + n - 1)
        left = req.budget - n
        st.remaining = st.remaining.at[slot].set(left)
        st.active = st.active.at[slot].set(left > 0)
        st.slot_rid[slot] = req.rid
        st.recoveries += 1

    # ------------------------------------------------------------------
    # Segment-boundary bookkeeping: harvest, quarantine, deadlines, drops.
    # ------------------------------------------------------------------

    def _free_rows(self, st: _RunState, rows: list) -> None:
        """Deactivate + evict the given pool rows (device side)."""
        s = self.setup
        if not rows:
            return
        sel = jnp.asarray(rows, jnp.int32)
        st.active = st.active.at[sel].set(False)
        st.remaining = st.remaining.at[sel].set(0)
        if s.evict_fn is not None:
            mask = np.zeros((s.slots,), np.bool_)
            mask[rows] = True
            st.caches = s.evict_fn(st.caches, jnp.asarray(mask))

    def _quarantine(self, st: _RunState, idx: int) -> None:
        """Sentinel fired on row ``idx``: discard the segment's tokens
        (the committed prefix stays clean), evict the row, and re-queue
        the request with exponential backoff — or fail it once retries
        are exhausted.  A poisoned FREE slot just resets."""
        rid = int(st.slot_rid[idx])
        st.health_events.append(
            {"segment": st.segments - 1, "slot": idx, "rid": rid})
        if rid < 0:
            return
        st.slot_rid[idx] = -1
        tr = st.tracked[rid]
        tr.retries += 1
        if tr.retries > self.max_retries:
            st.statuses[rid] = "failed"
            st.reject_reasons[rid] = (
                f"unhealthy state; {self.max_retries} retries exhausted")
            del st.tracked[rid]
        else:
            tr.eligible_seg = st.segments + (1 << (tr.retries - 1))
            st.queue.append(tr)

    def _harvest(self, st: _RunState, toks_h, emitted_h, active_h,
                 unhealthy_h) -> None:
        """``toks_h``: (S, B, E) token panel, ``emitted_h``: (S, B) int
        per-step emission counts (E = 1 / counts in {0, 1} for plain
        pools; E = spec_k + 1 for speculative pools).  A speculative row
        may emit up to spec_k + 1 tokens in its budget-expiry step, so the
        harvest caps the FLATTENED per-row stream at ``Request.budget`` —
        overshoot tokens are committed on-device (the cache slack
        ``check_request`` reserved) but never surface in ``outputs``."""
        s = self.setup
        freed: list = []
        for idx in range(s.slots):
            if unhealthy_h[idx]:
                self._quarantine(st, idx)
                freed.append(idx)
                continue
            rid = int(st.slot_rid[idx])
            if rid < 0:
                continue
            tr = st.tracked[rid]
            out = st.outputs[rid]
            room = tr.req.budget - len(out)   # hard buffer bound
            for step in np.nonzero(emitted_h[:, idx])[0]:
                if room <= 0:
                    break
                take = toks_h[step, idx, :int(emitted_h[step, idx])][:room]
                out.extend(int(t) for t in take)
                room -= len(take)
            if not active_h[idx]:             # evict: budget exhausted
                st.statuses[rid] = "retried" if tr.retries else "done"
                st.slot_rid[idx] = -1
                del st.tracked[rid]
                freed.append(idx)
        self._free_rows(st, freed)

    def _sweep_deadlines(self, st: _RunState) -> None:
        now = time.monotonic()
        expired_rows = []
        for idx in range(self.setup.slots):
            rid = int(st.slot_rid[idx])
            if rid < 0:
                continue
            tr = st.tracked[rid]
            if tr.deadline_at is not None and now >= tr.deadline_at:
                st.statuses[rid] = "timeout"   # partial output kept
                st.slot_rid[idx] = -1
                del st.tracked[rid]
                expired_rows.append(idx)
        self._free_rows(st, expired_rows)
        for tr in [t for t in st.queue
                   if t.deadline_at is not None
                   and now >= t.deadline_at]:
            st.queue.remove(tr)
            st.statuses[tr.req.rid] = "timeout"
            del st.tracked[tr.req.rid]

    def _drop(self, st: _RunState, rid: int) -> None:
        """Client-cancel (``drop`` fault): terminate ``rid`` wherever it
        is — queued or slot-resident — with status ``failed``."""
        if rid in st.tracked:
            tr = st.tracked[rid]
            if tr in st.queue:
                st.queue.remove(tr)
            st.statuses[rid] = "failed"
            st.reject_reasons[rid] = "dropped by client"
            del st.tracked[rid]
        rows = [i for i in range(self.setup.slots)
                if int(st.slot_rid[i]) == rid]
        for i in rows:
            st.slot_rid[i] = -1
        self._free_rows(st, rows)

    def _fire_faults(self, st: _RunState, plan: Optional[FaultPlan],
                     fired: set, kinds: tuple) -> None:
        if plan is None:
            return
        for i, ev in enumerate(plan.events):
            if i in fired or ev.kind not in kinds \
                    or ev.segment > st.segments:
                continue
            fired.add(i)
            if ev.kind == "kill":
                raise SimulatedCrash(st.segments)
            if ev.kind == "drop":
                self._drop(st, ev.rid)
            elif ev.kind == "delay":
                time.sleep(ev.seconds)
            elif ev.kind == "nan":
                row = plan.pick_row(ev, self.setup.slots,
                                    active=st.slot_rid >= 0)
                st.caches = poison_rows(st.caches, [row])

    # ------------------------------------------------------------------
    # Snapshot / restore.
    # ------------------------------------------------------------------

    @staticmethod
    def _ser_tracked(tr: _Tracked, now: float) -> dict:
        return {"rid": tr.req.rid,
                "prompt": np.asarray(tr.req.prompt).tolist(),
                "gen_len": tr.req.gen_len,
                "max_tokens": tr.req.max_tokens,
                "deadline_left": (tr.deadline_at - now
                                  if tr.deadline_at is not None else None),
                "retries": tr.retries,
                "eligible_seg": tr.eligible_seg}

    @staticmethod
    def _deser_tracked(entry: dict, now: float) -> _Tracked:
        req = Request(rid=int(entry["rid"]),
                      prompt=np.asarray(entry["prompt"], np.int32),
                      gen_len=int(entry["gen_len"]),
                      max_tokens=entry.get("max_tokens"))
        left = entry.get("deadline_left")
        return _Tracked(req=req,
                        deadline_at=(now + left if left is not None
                                     else None),
                        retries=int(entry.get("retries", 0)),
                        eligible_seg=int(entry.get("eligible_seg", 0)))

    def _snapshot(self, st: _RunState) -> None:
        """Atomic pool snapshot: device carry through the checkpointer
        (CRC-verified shards) + the host metadata as a JSON sidecar in the
        SAME committed step dir — restore sees both or neither."""
        now = time.monotonic()
        tree = {"caches": st.caches, "tok": st.tok, "pos": st.pos,
                "remaining": st.remaining, "active": st.active,
                "key": st.key}
        queued_rids = [tr.req.rid for tr in st.queue]
        meta = {
            "slot_rid": [int(r) for r in st.slot_rid],
            "segments": st.segments, "decode_steps": st.decode_steps,
            "admitted": st.admitted, "recoveries": st.recoveries,
            "rejected": st.rejected, "snapshots": st.snapshots,
            "emitted_tokens": st.emitted_tokens,
            "verify_iters": st.verify_iters,
            "accepted_tokens": st.accepted_tokens,
            "drafted_tokens": st.drafted_tokens,
            "queue": [self._ser_tracked(tr, now) for tr in st.queue],
            "resident": [self._ser_tracked(tr, now)
                         for rid, tr in st.tracked.items()
                         if rid not in queued_rids],
            "outputs": {str(r): list(t) for r, t in st.outputs.items()},
            "statuses": {str(r): v for r, v in st.statuses.items()},
            "reject_reasons": {str(r): v
                               for r, v in st.reject_reasons.items()},
            "health_events": st.health_events,
        }
        self.snapshot_mgr.save_now(st.segments, tree,
                                   extra={"batcher.json": json.dumps(meta)})
        st.snapshots += 1

    def _restore(self, st: _RunState) -> None:
        if self.snapshot_mgr is None:
            raise RuntimeError("resume=True requires a snapshot_mgr")
        step = self.snapshot_mgr.latest_step()
        if step is None:
            raise RuntimeError(
                f"resume=True but no restorable snapshot in "
                f"{self.snapshot_mgr.directory}")
        s = self.setup
        template = {"caches": s.cache_init(),
                    "tok": jnp.zeros((s.slots,), jnp.int32),
                    "pos": jnp.zeros((s.slots,), jnp.int32),
                    "remaining": jnp.zeros((s.slots,), jnp.int32),
                    "active": jnp.zeros((s.slots,), jnp.bool_),
                    "key": jax.random.PRNGKey(0)}
        tree = _restore_tree(self.snapshot_mgr.directory, step, template)
        meta = json.loads(
            self.snapshot_mgr.read_extra(step, "batcher.json"))
        st.caches, st.tok, st.pos = tree["caches"], tree["tok"], tree["pos"]
        st.remaining, st.active = tree["remaining"], tree["active"]
        st.key = tree["key"]
        st.slot_rid = np.asarray(meta["slot_rid"], np.int64)
        st.segments = int(meta["segments"])
        st.decode_steps = int(meta["decode_steps"])
        st.admitted = int(meta["admitted"])
        st.recoveries = int(meta["recoveries"])
        st.rejected = int(meta["rejected"])
        st.snapshots = int(meta["snapshots"])
        st.emitted_tokens = int(meta.get("emitted_tokens", 0))
        st.verify_iters = int(meta.get("verify_iters", 0))
        st.accepted_tokens = int(meta.get("accepted_tokens", 0))
        st.drafted_tokens = int(meta.get("drafted_tokens", 0))
        st.health_events = list(meta["health_events"])
        st.outputs = {int(r): list(t) for r, t in meta["outputs"].items()}
        st.statuses = {int(r): v for r, v in meta["statuses"].items()}
        st.reject_reasons = {int(r): v
                             for r, v in meta["reject_reasons"].items()}
        now = time.monotonic()
        for entry in meta["queue"]:
            tr = self._deser_tracked(entry, now)
            st.tracked[tr.req.rid] = tr
            st.queue.append(tr)
        for entry in meta["resident"]:
            tr = self._deser_tracked(entry, now)
            st.tracked[tr.req.rid] = tr
        st.restored_step = step

    # ------------------------------------------------------------------
    # The serving loop.
    # ------------------------------------------------------------------

    def warmup(self, prompt_lens) -> None:
        """Compile every (prompt length, admit-group size) prefill, the
        admit scatters and the segment scan so a timed :meth:`run` measures
        steady state, not compiles."""
        s = self.setup
        plens = list(dict.fromkeys(int(p) for p in prompt_lens))
        sizes = range(1, s.slots + 1) if self.group_admits else (1,)
        pooled = s.cache_init()
        for p in plens:
            for k in sizes:       # mid-stream admits form every group size
                _, sc = s.prefill_fn(p, k)(self.params,
                                           jnp.zeros((k, p), jnp.int32))
                pooled = s.admit_fn(pooled, sc,
                                    jnp.arange(k, dtype=jnp.int32))
        del pooled
        # One tiny end-to-end pass for the segment scan + harvest path;
        # generation budgets are clamped to the pool's max_len.  Snapshots
        # are disabled for the warmup run — it is not real traffic.
        slack = getattr(s, "spec_k", 0)
        dummy = [Request(rid=i, prompt=np.zeros((p,), np.int32),
                         gen_len=max(1, min(s.segment + 1,
                                            s.max_len - p - slack)))
                 for i, p in enumerate(plens)]
        every, self.snapshot_every = self.snapshot_every, 0
        try:
            self.run(dummy)
        finally:
            self.snapshot_every = every

    def run(self, requests, key: Optional[jax.Array] = None,
            fault_plan: Optional[FaultPlan] = None,
            resume: bool = False) -> BatchingStats:
        """Serve ``requests`` to completion.  ``fault_plan`` injects
        scripted failures at segment boundaries; ``resume=True`` first
        restores the pool from the latest snapshot (a ``kill`` fault /
        crash mid-run) and finishes every in-flight request, then serves
        ``requests`` on top (pass ``[]`` to just drain)."""
        s = self.setup
        st = _RunState()
        if resume:
            self._restore(st)
        else:
            st.caches = s.cache_init()
            st.tok = jnp.zeros((s.slots,), jnp.int32)
            st.pos = jnp.zeros((s.slots,), jnp.int32)
            st.remaining = jnp.zeros((s.slots,), jnp.int32)
            st.active = jnp.zeros((s.slots,), jnp.bool_)
            st.slot_rid = np.full((s.slots,), -1, np.int64)
            if key is None:   # advance so repeated runs sample fresh streams
                self.key, key = jax.random.split(self.key)
            st.key = key
        for r in requests:
            self._enqueue(st, r)

        wd = StepWatchdog()
        fired: set = set()
        t0 = time.perf_counter()
        while st.queue or (st.slot_rid >= 0).any():
            # Kills/drops fire at the boundary, before admission — a
            # restore replays the admissions deterministically.
            self._fire_faults(st, fault_plan, fired, ("kill", "drop"))
            self._admit_all(st)
            if (st.slot_rid < 0).all():
                if st.queue:
                    # Every queued request is backoff-deferred: advance
                    # the boundary clock so eligibility can arrive.
                    st.segments += 1
                    continue
                break                         # all admits finished early

            # --- one scanned decode segment -----------------------------
            wd.start()
            self._fire_faults(st, fault_plan, fired, ("delay", "nan"))
            st.key, seg_key = jax.random.split(st.key)
            (st.caches, st.tok, st.pos, st.remaining, st.active,
             toks, emitted, unhealthy, metrics) = s.segment_fn(
                self.params, st.caches, st.tok, st.pos, st.remaining,
                st.active, seg_key)
            # Host syncs land inside the watchdog window so the EWMA sees
            # the real segment wall clock, not async-dispatch latency.
            # Normalize the two segment shapes to one panel: plain pools
            # emit (S, B) tokens with bool masks -> (S, B, 1) + {0, 1}
            # counts; speculative pools emit (S, B, k+1) + int counts.
            toks_h = np.asarray(toks)
            emitted_h = np.asarray(emitted).astype(np.int64)
            if toks_h.ndim == 2:
                toks_h = toks_h[..., None]
            active_h = np.asarray(st.active)
            unhealthy_h = np.asarray(unhealthy)
            wd.stop(st.segments)
            st.segments += 1
            st.decode_steps += s.segment
            st.emitted_tokens += int(emitted_h.sum())
            spec_k = getattr(s, "spec_k", 0)
            if spec_k:
                iters = int((emitted_h > 0).sum())
                st.verify_iters += iters
                st.drafted_tokens += spec_k * iters
                st.accepted_tokens += int(
                    np.maximum(emitted_h - 1, 0).sum())
            live = emitted_h.any(axis=0)          # rows that decoded here
            if metrics is not None and live.any():
                m = {k: np.asarray(v) for k, v in metrics.items()}
                st.telemetry = {
                    "conc_drift_max": float(
                        np.max(np.abs(m["conc_drift"][live]))),
                    "log_mass_mean": float(np.mean(m["log_mass"][live])),
                    "log_mass_var_mean": float(
                        np.mean(m["log_mass_var"][live])),
                    "tau_hat_mean": float(np.mean(m["tau_hat"][live]))}

            # --- harvest / quarantine / deadlines / snapshot ------------
            self._harvest(st, toks_h, emitted_h, active_h, unhealthy_h)
            self._sweep_deadlines(st)
            if (self.snapshot_mgr is not None and self.snapshot_every
                    and st.segments % self.snapshot_every == 0):
                self._snapshot(st)
        wall = time.perf_counter() - t0

        outputs = {rid: np.asarray(t, np.int32)
                   for rid, t in st.outputs.items()}
        done = sum(len(outputs[rid]) for rid, v in st.statuses.items()
                   if v in ("done", "retried"))
        by = {k: sum(1 for v in st.statuses.values() if v == k)
              for k in REQUEST_STATUSES}
        return BatchingStats(
            outputs=outputs, completed_tokens=done,
            decode_steps=st.decode_steps, segments=st.segments,
            admitted=st.admitted, wall_s=wall,
            statuses=dict(st.statuses),
            reject_reasons=dict(st.reject_reasons),
            recoveries=st.recoveries, retries=by["retried"],
            timeouts=by["timeout"], rejected=st.rejected,
            failed=by["failed"],
            health_events=list(st.health_events),
            stragglers=list(wd.anomalies),
            segment_ewma_s=wd.ewma or 0.0,
            snapshots=st.snapshots, restored_step=st.restored_step,
            telemetry=dict(st.telemetry),
            spec_k=getattr(s, "spec_k", 0),
            drafted_tokens=st.drafted_tokens,
            accepted_tokens=st.accepted_tokens,
            acceptance_rate=(st.accepted_tokens / st.drafted_tokens
                             if st.drafted_tokens else 0.0),
            verify_iters=st.verify_iters,
            goodput_tokens_per_iter=(st.emitted_tokens / st.verify_iters
                                     if st.verify_iters else 0.0))


__all__ = ["Request", "BatchingStats", "ContinuousBatcher",
           "RequestError", "AdmissionError", "QueueFullError",
           "REQUEST_STATUSES", "synthetic_traffic", "make_pool_setup",
           "PoolSetup"]
