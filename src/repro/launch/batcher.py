"""Continuous-batching serving engine: a slotted request pool.

The static serving loop (``ServeSetup.make_generate``) advances one batch
shape in lockstep: every row prefills together and decodes until the LAST
row finishes, so under skewed request lengths short requests pin their slot
while a straggler drains.  This engine keeps a pool of ``slots`` rows where
each slot carries its own absolute position, its own remaining-token budget
and an active mask:

* **admit** — queued requests are prefilled slot-locally at their EXACT
  prompt length (see the ragged-prompt rule in docs/serving.md) and their
  decode state (LLN ``(s, z)`` + diag tail, or the softmax KV block) is
  scattered into the freed pool rows (``PoolSetup.admit_fn``), while the
  other rows keep decoding from where they are — admission is mid-segment
  from the pool's point of view.  Same-length queued prompts admit as ONE
  batched prefill when that is exact (softmax / fixed alpha/beta; dynamic
  moment matching pools prompt-batch statistics, so those configs prefill
  per request);
* **decode** — ``segment`` steps run as ONE jitted ``lax.scan`` with the
  pooled cache carry donated (``PoolSetup.segment_fn``), so steady-state
  throughput matches the static scanned loop;
* **evict** — a row whose budget hits zero drops out of the active mask
  *inside* the scan (masked rows provably advance nothing: KV writes, LLN
  state, tails and positions are all ``where``-guarded on the mask), and
  its slot is handed back to the queue at the next segment boundary.

Why this is cheap for LLN attention: the per-request decode state is
O(d^2) — a (H, D, Dv) matrix, a (H, D) vector and a diag tail block —
independent of how long the request's history is, so admitting a request
into a slot moves a few hundred KB instead of re-paging a full softmax KV
cache.  (Softmax caches work too; they just move O(max_len) bytes.)

The engine is deliberately host-driven between segments (admission needs a
queue, which jit cannot own); everything per-token is inside the scan.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import PoolSetup, make_pool_setup


@dataclasses.dataclass
class Request:
    """One generation request: ``prompt`` (plen,) int32 token ids and the
    number of tokens to generate (``gen_len`` >= 1; the first generated
    token comes from the prefill's last-position logits)."""
    rid: int
    prompt: np.ndarray
    gen_len: int


@dataclasses.dataclass
class BatchingStats:
    """Engine run summary.  ``outputs`` maps rid -> generated tokens
    (length == the request's ``gen_len``).  ``completed_tokens`` counts
    exactly those tokens (goodput numerator); ``decode_steps`` counts
    scan steps actually dispatched (segments * segment length)."""
    outputs: dict
    completed_tokens: int
    decode_steps: int
    segments: int
    admitted: int
    wall_s: float


def synthetic_traffic(n_requests: int, vocab: int, prompt_lens,
                      gen_lens, seed: int = 0) -> list[Request]:
    """Mixed-length synthetic traffic: prompts/gen budgets drawn round-robin
    from the given length menus (deterministic — benchmarks and parity
    tests need identical request streams across engines)."""
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n_requests):
        plen = int(prompt_lens[i % len(prompt_lens)])
        glen = int(gen_lens[i % len(gen_lens)])
        prompt = rng.randint(0, vocab, size=(plen,)).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, gen_len=glen))
    return reqs


class ContinuousBatcher:
    """Drives a ``PoolSetup`` over a queue of :class:`Request`s.

    Typical use (see ``launch/serve.py --continuous`` for the CLI form)::

        setup = make_pool_setup(cfg, mesh, slots=4, max_len=256, segment=8)
        eng = ContinuousBatcher(setup, params)
        stats = eng.run(synthetic_traffic(...))
    """

    def __init__(self, setup: PoolSetup, params):
        self.setup = setup
        self.params = params
        self.key = jax.random.PRNGKey(0)
        # Grouped admission (one batched prefill for several same-length
        # queued prompts) is exact whenever prefill is per-row
        # independent: softmax has no calibration, fixed alpha/beta skips
        # moment matching, and per-row calibration
        # (``lln_per_row_calib``, the make_pool_setup default) measures
        # each row's statistics alone — so dynamic moment matching can
        # now use batched slot prefill too.  Only a pool explicitly built
        # with batch-pooled calibration must admit one request at a time.
        cfg = setup.cfg
        self.group_admits = (cfg.attn_impl == "softmax"
                             or cfg.lln_fixed_ab != 0
                             or getattr(cfg, "lln_per_row_calib", False))

    def warmup(self, prompt_lens) -> None:
        """Compile every (prompt length, admit-group size) prefill, the
        admit scatters and the segment scan so a timed :meth:`run` measures
        steady state, not compiles."""
        s = self.setup
        plens = list(dict.fromkeys(int(p) for p in prompt_lens))
        sizes = range(1, s.slots + 1) if self.group_admits else (1,)
        pooled = s.cache_init()
        for p in plens:
            for k in sizes:       # mid-stream admits form every group size
                _, sc = s.prefill_fn(p, k)(self.params,
                                           jnp.zeros((k, p), jnp.int32))
                pooled = s.admit_fn(pooled, sc,
                                    jnp.arange(k, dtype=jnp.int32))
        del pooled
        # One tiny end-to-end pass for the segment scan + harvest path;
        # generation budgets are clamped to the pool's max_len.
        dummy = [Request(rid=i, prompt=np.zeros((p,), np.int32),
                         gen_len=max(1, min(s.segment + 1, s.max_len - p)))
                 for i, p in enumerate(plens)]
        self.run(dummy)

    def run(self, requests, key: Optional[jax.Array] = None
            ) -> BatchingStats:
        s = self.setup
        if any(r.rid < 0 for r in requests):
            raise ValueError("request ids must be >= 0 (-1 marks a free slot)")
        queue = deque(requests)
        outputs: dict = {r.rid: [] for r in requests}
        slot_rid = np.full((s.slots,), -1, np.int64)

        caches = s.cache_init()
        tok = jnp.zeros((s.slots,), jnp.int32)
        pos = jnp.zeros((s.slots,), jnp.int32)
        remaining = jnp.zeros((s.slots,), jnp.int32)
        active = jnp.zeros((s.slots,), jnp.bool_)
        if key is None:    # advance so repeated runs sample fresh streams
            self.key, key = jax.random.split(self.key)

        admitted = segments = decode_steps = 0
        t0 = time.perf_counter()
        while queue or slot_rid.max() >= 0:
            # --- admit into every free slot, grouped by prompt length ---
            free = list(np.nonzero(slot_rid < 0)[0])
            while queue and free:
                group = [queue.popleft()]
                plen = group[0].prompt.shape[0]
                if self.group_admits:
                    while (queue and len(group) < len(free)
                           and queue[0].prompt.shape[0] == plen):
                        group.append(queue.popleft())
                for req in group:
                    if plen + req.gen_len > s.max_len:
                        raise ValueError(
                            f"request {req.rid}: prompt {plen} + gen "
                            f"{req.gen_len} exceeds max_len {s.max_len}")
                pf = s.prefill_fn(plen, len(group))
                prompts = jnp.asarray(np.stack([r.prompt for r in group]))
                logits, slot_caches = pf(self.params, prompts)
                last = logits[:, -1] if logits.ndim == 3 else logits
                tok0 = np.asarray(jnp.argmax(last, -1).astype(jnp.int32))
                live, live_slots = [], []
                for j, req in enumerate(group):
                    outputs[req.rid].append(int(tok0[j]))
                    admitted += 1
                    if req.gen_len <= 1:
                        continue                 # done at prefill; slot free
                    slot = int(free.pop(0))
                    live.append(j)
                    live_slots.append(slot)
                    slot_rid[slot] = req.rid
                if not live:
                    continue
                if len(live) != len(group):      # drop prefill-only rows
                    sel = jnp.asarray(live)
                    # Leaves whose rank matches the pooled leaf carry the
                    # admit-group axis at position 1; lower-rank leaves
                    # (len/pos/alpha/beta) are shared across the group.
                    slot_caches = jax.tree_util.tree_map(
                        lambda sl, pl: sl[:, sel] if sl.ndim == pl.ndim
                        else sl, slot_caches, caches)
                slots_dev = jnp.asarray(live_slots, jnp.int32)
                caches = s.admit_fn(caches, slot_caches, slots_dev)
                tok = tok.at[slots_dev].set(jnp.asarray(tok0[live]))
                pos = pos.at[slots_dev].set(
                    jnp.full((len(live),), plen, jnp.int32))
                remaining = remaining.at[slots_dev].set(jnp.asarray(
                    [r.gen_len - 1 for i, r in enumerate(group)
                     if i in live], jnp.int32))
                active = active.at[slots_dev].set(True)

            if slot_rid.max() < 0:
                continue                          # all admits finished early

            # --- one scanned decode segment -----------------------------
            key, seg_key = jax.random.split(key)
            (caches, tok, pos, remaining, active,
             toks, emitted) = s.segment_fn(self.params, caches, tok, pos,
                                           remaining, active, seg_key)
            segments += 1
            decode_steps += s.segment

            # --- harvest + evict ---------------------------------------
            toks_h = np.asarray(toks)             # (S, B)
            emitted_h = np.asarray(emitted)
            active_h = np.asarray(active)
            freed = []
            for idx in range(s.slots):
                rid = int(slot_rid[idx])
                if rid == -1:
                    continue
                steps = np.nonzero(emitted_h[:, idx])[0]
                outputs[rid].extend(int(t) for t in toks_h[steps, idx])
                if not active_h[idx]:             # evict: budget exhausted
                    slot_rid[idx] = -1
                    freed.append(idx)
            if freed and s.evict_fn is not None:
                # Engine evict: zero the freed rows so stale request state
                # never outlives its request (admission overwrites a slot
                # wholesale anyway; this keeps the pool clean in between).
                # Fixed-shape (slots,) mask => one compile total.
                mask = np.zeros((s.slots,), np.bool_)
                mask[freed] = True
                caches = s.evict_fn(caches, jnp.asarray(mask))
        wall = time.perf_counter() - t0

        outputs = {rid: np.asarray(t, np.int32) for rid, t in
                   outputs.items()}
        done = sum(len(t) for t in outputs.values())
        return BatchingStats(outputs=outputs, completed_tokens=done,
                             decode_steps=decode_steps, segments=segments,
                             admitted=admitted, wall_s=wall)


__all__ = ["Request", "BatchingStats", "ContinuousBatcher",
           "synthetic_traffic", "make_pool_setup", "PoolSetup"]
