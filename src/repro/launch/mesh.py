"""Production meshes.

Single pod: (data=16, model=16) — 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the 'pod' axis is the
DCN-connected dimension (kept outermost so cross-pod collectives are pure
data-parallel gradient reductions, optionally bf16/int8-compressed).

Defined as functions (never module-level) so importing this module does not
touch jax device state.
"""
from __future__ import annotations

import jax


def compat_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions: ``axis_types`` (explicit-Auto)
    only exists on newer releases; older ones are Auto-only anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_mesh(shape, axes)


def make_smoke_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over whatever devices exist (tests)."""
    return compat_mesh((data, model), ("data", "model"))
