"""Production meshes.

Single pod: (data=16, model=16) — 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the 'pod' axis is the
DCN-connected dimension (kept outermost so cross-pod collectives are pure
data-parallel gradient reductions, optionally bf16/int8-compressed).

Defined as functions (never module-level) so importing this module does not
touch jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_smoke_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over whatever devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
