"""Serving driver: batched prefill + scanned decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
      --batch 4 --prompt-len 64 --gen 32

Demonstrates the two cache regimes: softmax KV cache vs the paper's O(d^2)
LLN state (--attn-impl lln_diag), which is what makes long_500k serveable.

Generation runs as a single jitted ``lax.scan`` segment (one dispatch for
the whole tail of the generation, donated cache carry); the first decode
step runs standalone — it carries the compile — and is reported separately
so the tok/s figure measures steady state.  ``--no-scan`` restores the
seed-style one-dispatch-per-token Python loop (the benchmark baseline);
``--no-serve-kernel`` selects ``attn_backend=ref`` (the seed two-pass jnp
path); ``--attn-backend`` picks any registry backend explicitly
(``kernels/registry.py``: auto | pallas | scan | ref).

``--continuous`` switches to the continuous-batching pool
(``launch/batcher.py``): mixed-length synthetic traffic is admitted into
freed slots mid-stream (per-row positions, masked rows), e.g.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
      --continuous --requests 16 --batch 4 --gen-lens 4,4,4,24

and reports goodput (completed tok/s) instead of lockstep tok/s.
``--continuous --speculative`` makes the pool rows speculative (pooled
draft+verify with per-row ``commit_len`` and single-pass verify;
docs/serving.md "Speculative continuous batching") and adds
acceptance-aware goodput to the report:

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
      --continuous --speculative --spec-k 3 --requests 8 --batch 2

The continuous pool carries the robustness layer (docs/serving.md
"Failure handling"): ``--deadline`` puts a wall-clock budget on every
request, ``--queue-cap`` bounds admission, ``--no-health`` disables the
state-health sentinel, ``--fault-plan`` injects a scripted
``launch/faults.py:FaultPlan`` (JSON path or inline literal), and
``--snapshot-dir``/``--snapshot-every``/``--restore`` snapshot the pool
at segment boundaries and resume it after a crash:

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
      --continuous --requests 8 --snapshot-dir /tmp/pool --snapshot-every 2 \
      --fault-plan '{"events": [{"kind": "kill", "segment": 4}]}'
  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
      --continuous --requests 0 --snapshot-dir /tmp/pool --restore
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.launch.mesh import compat_mesh
from repro.launch.steps import (flatten_spec_tokens, make_pool_setup,
                                make_serve_setup, make_spec_setup,
                                sample_token)
from repro.models import build_model, synthetic_batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--attn-impl", default=None,
                    choices=[None, "softmax", "lln", "lln_diag",
                             "log_linear"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default="1,1")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-scan", dest="scan", action="store_false",
                    default=True, help="seed-style per-token dispatch loop")
    ap.add_argument("--no-serve-kernel", dest="serve_kernel",
                    action="store_false", default=True,
                    help="seed two-pass prefill (attn_backend=ref)")
    ap.add_argument("--attn-backend", default=None,
                    choices=[None, "auto", "pallas", "scan", "ref"],
                    help="explicit attention backend (kernels/registry.py)")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching pool (mixed-length traffic)")
    ap.add_argument("--speculative", action="store_true",
                    help="draft-then-verify decoding (partial-commit "
                         "verify; see docs/serving.md)")
    ap.add_argument("--draft-layers", type=int, default=0,
                    help="[--speculative] tied first-k-layers draft depth "
                         "(default: half the target's layers)")
    ap.add_argument("--spec-k", type=int, default=3,
                    help="[--speculative] draft tokens per verify chunk")
    ap.add_argument("--requests", type=int, default=16,
                    help="[--continuous] synthetic requests to serve")
    ap.add_argument("--segment", type=int, default=8,
                    help="[--continuous] decode steps per scanned segment")
    ap.add_argument("--gen-lens", default=None,
                    help="[--continuous] comma list of generation budgets "
                         "(skewed by default)")
    ap.add_argument("--prompt-lens", default=None,
                    help="[--continuous] comma list of prompt lengths")
    ap.add_argument("--deadline", type=float, default=None,
                    help="[--continuous] per-request wall-clock budget (s)")
    ap.add_argument("--queue-cap", type=int, default=1024,
                    help="[--continuous] admission-queue bound")
    ap.add_argument("--drift", action="store_true",
                    help="[--continuous] quarantine rows whose streaming "
                         "concentration drift exceeds the HealthConfig "
                         "threshold (long-horizon serving)")
    ap.add_argument("--no-health", dest="health", action="store_false",
                    default=True,
                    help="[--continuous] disable the state-health sentinel")
    ap.add_argument("--fault-plan", default=None,
                    help="[--continuous] FaultPlan JSON (path or inline)")
    ap.add_argument("--snapshot-dir", default=None,
                    help="[--continuous] pool snapshot directory")
    ap.add_argument("--snapshot-every", type=int, default=4,
                    help="[--continuous] segments between snapshots")
    ap.add_argument("--restore", action="store_true",
                    help="[--continuous] resume from the latest snapshot "
                         "in --snapshot-dir before serving new requests")
    args = ap.parse_args(argv)

    overrides = {}
    if args.attn_impl:
        overrides["attn_impl"] = args.attn_impl
    if not args.serve_kernel:
        overrides["use_serve_kernel"] = False
    if args.attn_backend:
        overrides["attn_backend"] = args.attn_backend
    cfg = get_config(args.arch, smoke=args.smoke, **overrides)
    model = build_model(cfg)

    data, model_ax = (int(x) for x in args.mesh.split(","))
    mesh = compat_mesh((data, model_ax), ("data", "model"))
    if args.continuous:
        return _run_continuous(cfg, model, mesh, args)
    if args.speculative:
        return _run_speculative(cfg, model, mesh, args)
    max_len = args.prompt_len + args.gen + cfg.num_prefix_tokens
    shape = ShapeSpec("cli", max_len, args.batch, "decode")

    with mesh:
        setup = make_serve_setup(cfg, shape, mesh, multi_pod=False)
        params = jax.device_put(model.init(jax.random.PRNGKey(args.seed)),
                                setup.params_shardings)
        batch = synthetic_batch(cfg, args.batch, max_len,
                                text_seq=args.prompt_len)
        batch = {k: v for k, v in batch.items()}

        t0 = time.time()
        logits, caches = setup.prefill_fn(params, batch)
        logits.block_until_ready()
        t_prefill = time.time() - t0
        caches = jax.device_put(caches, setup.cache_shardings)

        tok0 = jnp.argmax(logits[:, -1] if logits.ndim == 3 else logits,
                          -1).astype(jnp.int32)
        tok = tok0
        generated = [np.asarray(tok0)]
        pos = batch["inputs"].shape[1]
        if cfg.family == "vlm":
            pos += cfg.num_prefix_tokens

        # First decode step standalone: it carries the compile, so it is
        # excluded from the steady-state tok/s either way.
        t_first = t_steady = 0.0
        if args.gen > 1:
            t0 = time.time()
            logits, caches = setup.decode_fn(params, caches, tok,
                                             jnp.asarray(pos, jnp.int32))
            tok = sample_token(logits, args.temperature,
                               jax.random.PRNGKey(args.seed))
            generated.append(np.asarray(tok))
            jax.block_until_ready(tok)
            t_first = time.time() - t0

        steady_steps = max(args.gen - 2, 0)
        if steady_steps > 0 and args.scan:
            gen_fn = setup.make_generate(steady_steps, args.temperature)
            key = jax.random.PRNGKey(args.seed + 1)
            # AOT-compile the segment so the compile does not pollute the
            # steady-state figure — lowering never executes, so the segment
            # (and its donated cache carry) runs exactly once below.
            gen_fn = gen_fn.lower(params, caches, tok,
                                  jnp.asarray(pos + 1, jnp.int32),
                                  key).compile()
            t0 = time.time()
            toks, caches = gen_fn(params, caches, tok,
                                  jnp.asarray(pos + 1, jnp.int32), key)
            toks.block_until_ready()
            t_steady = time.time() - t0
            generated.extend(np.asarray(toks).T)
        elif steady_steps > 0:
            t0 = time.time()
            for i in range(steady_steps):
                logits, caches = setup.decode_fn(
                    params, caches, tok, jnp.asarray(pos + 1 + i, jnp.int32))
                tok = sample_token(logits, args.temperature,
                                   jax.random.PRNGKey(args.seed + 1 + i))
                generated.append(np.asarray(tok))
            jax.block_until_ready(tok)
            t_steady = time.time() - t0

        toks = np.stack(generated, 1)
        mode = "scan" if args.scan else "loop"
        tok_s = steady_steps * args.batch / max(t_steady, 1e-9)
        print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill:.3f}s"
              f"  (serve_kernel={cfg.use_serve_kernel})")
        print(f"decode : first step {t_first:.3f}s (compile, excluded); "
              f"{steady_steps} steady steps [{mode}] in {t_steady:.3f}s "
              f"({tok_s:.1f} tok/s)")
        print("sample tokens:", toks[0, :16].tolist())
        return toks


def _run_speculative(cfg, model, mesh, args):
    """Draft-then-verify decoding: tied first-k-layers draft + chunked
    verify with per-row partial commit (docs/serving.md)."""
    draft_layers = args.draft_layers or max(cfg.n_layers // 2, 1)
    steps = max(args.gen - 1, 1)
    max_len = args.prompt_len + args.gen + args.spec_k + 2
    shape = ShapeSpec("spec", max_len, args.batch, "decode")

    with mesh:
        setup = make_spec_setup(cfg, shape, mesh, spec_k=args.spec_k,
                                draft_layers=draft_layers)
        params = jax.device_put(model.init(jax.random.PRNGKey(args.seed)))
        batch = synthetic_batch(cfg, args.batch, max_len,
                                text_seq=args.prompt_len)

        t0 = time.time()
        logits, tgt_caches, dr_caches = setup.prefill_fn(params, batch)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0
        tok0 = jnp.argmax(logits[:, -1] if logits.ndim == 3 else logits,
                          -1).astype(jnp.int32)

        gen_fn = setup.make_generate(steps, args.temperature)
        pos0 = jnp.asarray(args.prompt_len, jnp.int32)
        key = jax.random.PRNGKey(args.seed + 1)
        gen_fn = gen_fn.lower(params, tgt_caches, dr_caches, tok0, pos0,
                              key).compile()
        t0 = time.time()
        toks, n_emit, n_acc, live, *_ = gen_fn(params, tgt_caches,
                                               dr_caches, tok0, pos0, key)
        jax.block_until_ready(toks)
        t_gen = time.time() - t0

        n_emit_h = np.asarray(n_emit)
        n_acc_h = np.asarray(n_acc)
        live_h = np.asarray(live)
        drafted = float(live_h.sum() * args.spec_k)
        acc_rate = float(n_acc_h.sum()) / max(drafted, 1.0)
        iters_used = [int(np.argmax(np.cumsum(n_emit_h[r]) >= steps)) + 1
                      for r in range(args.batch)]
        tps = float(np.mean([steps / i for i in iters_used]))
        flat = flatten_spec_tokens(toks, n_emit, steps)
        tok_s = steps * args.batch / max(t_gen, 1e-9)
        print(f"prefill: {args.batch}x{args.prompt_len} (target + "
              f"{draft_layers}-layer draft) in {t_prefill:.3f}s")
        print(f"speculative: k={args.spec_k}, draft_layers={draft_layers}; "
              f"{steps} tokens/row in {t_gen:.3f}s ({tok_s:.1f} tok/s over "
              f"the worst-case {steps}-iteration scan; bench_spec times a "
              f"right-sized scan)")
        print(f"  acceptance rate {acc_rate:.2f}, "
              f"tokens/verify-step {tps:.2f} "
              f"(1.0 = non-speculative)")
        print("sample tokens:", flat[0, :16].tolist())
        return flat


def _run_continuous(cfg, model, mesh, args):
    """Continuous-batching pool over mixed-length synthetic traffic."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.core.health import HealthConfig
    from repro.launch.batcher import ContinuousBatcher, synthetic_traffic
    from repro.launch.faults import FaultPlan, SimulatedCrash

    gen_lens = ([int(x) for x in args.gen_lens.split(",")]
                if args.gen_lens else [args.gen // 4 or 1] * 3 + [args.gen])
    prompt_lens = ([int(x) for x in args.prompt_lens.split(",")]
                   if args.prompt_lens else [args.prompt_len])
    # --speculative composes with --continuous: the pool rows run the
    # pooled draft+verify loop (spec_k slack reserved in the cache).
    spec_k = args.spec_k if args.speculative else 0
    draft_layers = (args.draft_layers or max(cfg.n_layers // 2, 1)) \
        if args.speculative else 0
    max_len = max(prompt_lens) + max(gen_lens) + spec_k
    plan = FaultPlan.load(args.fault_plan) if args.fault_plan else None
    mgr = (CheckpointManager(args.snapshot_dir, keep_n=3, interval=1)
           if args.snapshot_dir else None)

    with mesh:
        setup = make_pool_setup(cfg, mesh, slots=args.batch,
                                max_len=max_len, segment=args.segment,
                                temperature=args.temperature,
                                spec_k=spec_k, draft_layers=draft_layers,
                                health=HealthConfig(
                                    check_drift=bool(args.drift))
                                if args.health else None)
        params = jax.device_put(model.init(jax.random.PRNGKey(args.seed)))
        eng = ContinuousBatcher(setup, params, queue_cap=args.queue_cap,
                                snapshot_mgr=mgr,
                                snapshot_every=(args.snapshot_every
                                                if mgr else 0))
        reqs = synthetic_traffic(args.requests, cfg.vocab, prompt_lens,
                                 gen_lens, seed=args.seed)
        if args.deadline is not None:
            for r in reqs:
                r.deadline_s = args.deadline
        eng.warmup(prompt_lens)
        try:
            stats = eng.run(reqs, key=jax.random.PRNGKey(args.seed + 1),
                            fault_plan=plan, resume=args.restore)
        except SimulatedCrash as e:
            print(f"simulated crash at segment boundary {e.segment}; "
                  f"resume with --restore --snapshot-dir "
                  f"{args.snapshot_dir}")
            return None

    # Same definition as benchmarks/bench_batching.py: useful tokens over
    # dispatched row-steps (+1 prefill-emitted token per request).
    util = stats.completed_tokens / max(
        stats.decode_steps * args.batch + max(stats.admitted, 1), 1)
    print(f"continuous: {args.requests} requests over {args.batch} slots, "
          f"segment={args.segment}, gen_lens={gen_lens}"
          + (f", speculative k={spec_k} draft_layers={draft_layers}"
             if spec_k else ""))
    print(f"  {stats.completed_tokens} tokens in {stats.wall_s:.3f}s "
          f"({stats.completed_tokens / max(stats.wall_s, 1e-9):.1f} tok/s "
          f"goodput), {stats.segments} segments, "
          f"slot utilization {util:.2f}")
    if stats.spec_k:
        print(f"  speculative: acceptance {stats.acceptance_rate:.2f} "
              f"({stats.accepted_tokens}/{stats.drafted_tokens} drafts), "
              f"{stats.goodput_tokens_per_iter:.2f} tokens/verify-iter "
              f"over {stats.verify_iters} iterations")
    by = {}
    for v in stats.statuses.values():
        by[v] = by.get(v, 0) + 1
    print(f"  statuses: {by}; recoveries={stats.recoveries}, "
          f"snapshots={stats.snapshots}, "
          f"stragglers={len(stats.stragglers)}, "
          f"segment EWMA {stats.segment_ewma_s * 1e3:.1f}ms"
          + (f" (restored from step {stats.restored_step})"
             if stats.restored_step is not None else ""))
    if stats.telemetry:
        t = stats.telemetry
        print(f"  concentration: drift_max {t['conc_drift_max']:.2f}, "
              f"log_mass {t['log_mass_mean']:.2f}, "
              f"log_var {t['log_mass_var_mean']:.3f}, "
              f"tau_hat {t['tau_hat_mean']:.3f}"
              + (" [drift quarantine ON]" if args.drift else ""))
    if stats.outputs:
        rid0 = min(stats.outputs)
        print(f"request {rid0} tokens:",
              stats.outputs[rid0][:16].tolist())
    return stats


if __name__ == "__main__":
    main()
