"""Serving driver: batched prefill + decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
      --batch 4 --prompt-len 64 --gen 32

Demonstrates the two cache regimes: softmax KV cache vs the paper's O(d^2)
LLN state (--attn-impl lln_diag), which is what makes long_500k serveable.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.launch.steps import make_serve_setup
from repro.models import build_model, synthetic_batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--attn-impl", default=None,
                    choices=[None, "softmax", "lln", "lln_diag"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default="1,1")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    overrides = {}
    if args.attn_impl:
        overrides["attn_impl"] = args.attn_impl
    cfg = get_config(args.arch, smoke=args.smoke, **overrides)
    model = build_model(cfg)

    data, model_ax = (int(x) for x in args.mesh.split(","))
    mesh = jax.make_mesh((data, model_ax), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    max_len = args.prompt_len + args.gen + cfg.num_prefix_tokens
    shape = ShapeSpec("cli", max_len, args.batch, "decode")

    with mesh:
        setup = make_serve_setup(cfg, shape, mesh, multi_pod=False)
        params = jax.device_put(model.init(jax.random.PRNGKey(args.seed)),
                                setup.params_shardings)
        batch = synthetic_batch(cfg, args.batch, max_len,
                                text_seq=args.prompt_len)
        batch = {k: v for k, v in batch.items()}

        t0 = time.time()
        logits, caches = setup.prefill_fn(params, batch)
        logits.block_until_ready()
        t_prefill = time.time() - t0
        caches = jax.device_put(caches, setup.cache_shardings)

        tok = jnp.argmax(logits[:, -1] if logits.ndim == 3 else logits,
                         -1).astype(jnp.int32)
        generated = [np.asarray(tok)]
        pos = batch["inputs"].shape[1]
        if cfg.family == "vlm":
            pos += cfg.num_prefix_tokens
        t0 = time.time()
        for i in range(args.gen - 1):
            logits, caches = setup.decode_fn(params, caches, tok,
                                             jnp.asarray(pos + i, jnp.int32))
            if args.temperature > 0:
                key = jax.random.PRNGKey(args.seed + i)
                tok = jax.random.categorical(
                    key, logits / args.temperature, -1).astype(jnp.int32)
            else:
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
            generated.append(np.asarray(tok))
        jax.block_until_ready(tok)
        t_decode = time.time() - t0
        toks = np.stack(generated, 1)
        print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill:.2f}s")
        print(f"decode : {args.gen - 1} steps in {t_decode:.2f}s "
              f"({(args.gen - 1) * args.batch / max(t_decode, 1e-9):.1f} tok/s)")
        print("sample tokens:", toks[0, :16].tolist())
        return toks


if __name__ == "__main__":
    main()
