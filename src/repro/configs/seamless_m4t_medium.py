"""seamless-m4t-medium [arXiv:2308.11596; hf] — enc-dec audio/text backbone.

12L encoder + 12L decoder, d_model=1024, 16H (kv=16), d_ff=4096,
vocab=256206 (padded to 256256 for 16-way sharding).  Audio frontend is a
stub providing precomputed frame embeddings (assignment spec).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    head_dim=64, d_ff=4096, vocab=256206, frontend_dim=1024,
    norm="layernorm", act="gelu", attn_shard="tp_heads",
)

SMOKE = CONFIG.replace(
    n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab=512, frontend_dim=32,
    diag_block=16, lln_chunk=16, softmax_chunk=32, remat="none")
