"""zamba2-7b [arXiv:2411.15242; unverified] — Mamba2 backbone + shared attention block.

81L mamba2 (d_inner=7168, head_dim 64 -> 112 heads, state 64) with a shared
transformer block (32H MHA, d_ff=14336) applied every 6 layers on
concat(hidden, embedding); d_model=3584, vocab=32000.  LLN applies to the
shared attention block.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab=32000, ssm_state=64, ssm_expand=2, ssm_head_dim=64,
    ssm_groups=1, shared_attn_period=6, attn_shard="tp_heads",
)

SMOKE = CONFIG.replace(
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    vocab=512, ssm_state=16, ssm_head_dim=32, shared_attn_period=2,
    ssm_chunk=16, diag_block=16, lln_chunk=16, softmax_chunk=32, remat="none")
