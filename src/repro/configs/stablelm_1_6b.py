"""stablelm-1.6b [hf:stabilityai/stablelm-2-1_6b; unverified] — dense MHA, partial RoPE.

24L, d_model=2048, 32H (kv=32), d_ff=5632, vocab=100352, LayerNorm,
rotary_pct=0.25.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=5632, vocab=100352, norm="layernorm", rotary_pct=0.25,
    attn_shard="tp_heads",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    vocab=512, diag_block=16, lln_chunk=16, softmax_chunk=32, remat="none")
