"""paligemma-3b [arXiv:2407.07726; hf] — SigLIP patch stub + gemma decoder.

18L, d_model=2048, 8H MQA (kv=1, head_dim 256), d_ff=16384 (GeGLU),
vocab=257216, 256 image-patch prefix tokens (frontend stub, dim 1152).
8 heads % 16 != 0 -> context-parallel attention sharding.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=257216, act="gelu_glu", embed_scale=True,
    tie_embeddings=True, frontend_dim=1152, num_prefix_tokens=256,
    attn_shard="context",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128,
    vocab=512, frontend_dim=32, num_prefix_tokens=8,
    diag_block=16, lln_chunk=16, softmax_chunk=32, remat="none")
