"""Architecture configs: the 10 assigned archs + the paper's RoBERTa setting."""
from .base import ArchConfig, ShapeSpec, SHAPES, SHAPES_BY_NAME
from .registry import ASSIGNED_ARCHS, get_config, list_archs
