"""RoBERTa-base-style bidirectional encoder — the paper's own experimental
setting (§5): LLN / LLN+Diag attention pre-trained with MLM.

12L, d_model=768, 12H, d_ff=3072, vocab=50265 (RoPE replaces learned
positions — recorded in DESIGN.md).  attn_impl selects SA vs LLN vs
LLN+Diag, exactly the paper's Table 1 rows.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="roberta-lln", family="encoder",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab=50265, norm="layernorm", act="gelu",
    attn_impl="lln_diag", attn_shard="replicate",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    vocab=512, diag_block=16, lln_chunk=16, softmax_chunk=32, remat="none")
