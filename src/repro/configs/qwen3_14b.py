"""qwen3-14b [hf:Qwen/Qwen3-8B; hf] — dense GQA with qk_norm.

40L, d_model=5120, 40H (kv=8, head_dim 128), d_ff=17408, vocab=151936.
40 heads % 16 != 0 -> context-parallel attention sharding (DESIGN.md §4).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=17408, vocab=151936, qk_norm=True, rope_theta=1e6,
    attn_shard="context",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab=512, diag_block=16, lln_chunk=16, softmax_chunk=32, remat="none")
