"""chatglm3-6b [arXiv:2406.12793; hf] — dense GQA (kv=2), half-rotary ("2d") RoPE.

28L, d_model=4096, 32H, d_ff=13696, vocab=65024.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
    d_ff=13696, vocab=65024, rotary_pct=0.5, attn_shard="tp_heads",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab=512, diag_block=16, lln_chunk=16, softmax_chunk=32, remat="none")
