"""mamba2-130m [arXiv:2405.21060; unverified] — SSD (state-space duality), attn-free.

24L, d_model=768, d_inner=1536 (expand 2, head_dim 64 -> 24 heads),
ssm_state=128, vocab=50280 (padded to 50432).  The paper's LLN technique is
inapplicable (attention-free) — see DESIGN.md §Arch-applicability.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=24, n_kv_heads=24, d_ff=0,
    vocab=50280, ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
    shared_attn_period=0, tie_embeddings=True, attn_shard="replicate",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, vocab=512,
    ssm_state=16, ssm_head_dim=32, ssm_chunk=16, remat="none")
