"""Config registry: --arch <id> resolution for launchers and tests."""
from __future__ import annotations

import importlib

from .base import ArchConfig

_MODULES = {
    "seamless-m4t-medium": "seamless_m4t_medium",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "yi-9b": "yi_9b",
    "stablelm-1.6b": "stablelm_1_6b",
    "qwen3-14b": "qwen3_14b",
    "chatglm3-6b": "chatglm3_6b",
    "mamba2-130m": "mamba2_130m",
    "zamba2-7b": "zamba2_7b",
    "paligemma-3b": "paligemma_3b",
    "roberta-lln": "roberta_lln",
}

ASSIGNED_ARCHS = tuple(k for k in _MODULES if k != "roberta-lln")


def get_config(name: str, smoke: bool = False, **overrides) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    cfg = mod.SMOKE if smoke else mod.CONFIG
    return cfg.replace(**overrides) if overrides else cfg


def list_archs() -> tuple[str, ...]:
    return tuple(_MODULES)
