"""yi-9b [arXiv:2403.04652; hf] — llama-arch dense GQA.

48L, d_model=4096, 32H (kv=4), d_ff=11008, vocab=64000.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="yi-9b", family="dense",
    n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, vocab=64000, rope_theta=5e6, attn_shard="tp_heads",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab=512, diag_block=16, lln_chunk=16, softmax_chunk=32, remat="none")
