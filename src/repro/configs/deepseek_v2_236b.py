"""deepseek-v2-236b [arXiv:2405.04434; hf] — MLA + MoE (160 routed top-6 + 2 shared).

60L, d_model=5120, 128H, MLA kv_lora=512 / q_lora=1536 / rope 64 / nope 128,
experts d_ff=1536, first layer dense (d_ff=12288), vocab=102400.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=192,
    d_ff=12288, vocab=102400,
    n_experts=160, n_shared_experts=2, top_k=6, expert_d_ff=1536,
    first_dense_layers=1,
    kv_lora=512, q_lora=1536, rope_head_dim=64, nope_head_dim=128,
    v_head_dim=128,
    param_dtype="bfloat16", attn_shard="tp_heads", grad_accum=8,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, head_dim=24, d_ff=128, vocab=512,
    n_experts=8, n_shared_experts=1, top_k=2, expert_d_ff=32,
    kv_lora=32, q_lora=48, rope_head_dim=8, nope_head_dim=16, v_head_dim=16,
    param_dtype="float32", diag_block=16, lln_chunk=16, softmax_chunk=32,
    remat="none")
