"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-30B-A3B; hf] — 128 experts top-8, GQA kv=4, qk_norm.

94L, d_model=4096, 64H (head_dim 128), expert d_ff=1536, vocab=151936.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936,
    n_experts=128, n_shared_experts=0, top_k=8, expert_d_ff=1536,
    qk_norm=True, rope_theta=1e6,
    param_dtype="bfloat16", attn_shard="tp_heads", grad_accum=8,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=64,
    vocab=512, n_experts=8, top_k=2, expert_d_ff=32,
    param_dtype="float32", diag_block=16, lln_chunk=16, softmax_chunk=32,
    remat="none")
