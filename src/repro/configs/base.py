"""Architecture configuration schema.

One dataclass covers all assigned families; family-specific fields are
ignored by other families.  Every assigned architecture provides both its
full (paper-exact) config and a reduced smoke config of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | mla_moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads

    # --- attention ---------------------------------------------------------
    attn_impl: str = "softmax"       # softmax | lln | lln_diag (paper
                                     # technique) | log_linear (Fenwick
                                     # multi-scale LLN state)
    diag_block: int = 256
    lln_chunk: int = 256
    use_kernel: bool = False         # Pallas kernels (TPU); jnp path on CPU
    use_serve_kernel: bool = True    # legacy escape: False maps to
                                     # attn_backend="ref" (the seed jnp
                                     # serving path), kept for benchmarking
    attn_backend: str = "auto"       # kernels/registry.py backend:
                                     # auto | pallas | scan | ref
    qk_norm: bool = False
    lln_fixed_ab: float = 0.0        # fixed alpha=beta (paper §A.8.4); 0=dynamic
    lln_per_row_calib: bool = False  # moment-match each batch row alone
                                     # ((B,H) alpha/beta — the continuous-
                                     # batching admission setting)
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0          # stablelm 0.25; chatglm 0.5 ("2d" RoPE)
    softmax_chunk: int = 1024

    # --- long-context robustness (length-aware LLN serving) -----------------
    lln_beta_n: float = 0.0          # beta(n) log-length temperature schedule
                                     # coefficient: alpha/beta gain
                                     # sqrt(1 + beta_n*ln(n/calib_len)) past
                                     # the calibration length (0 = off)
    lln_calib_len: int = 1024        # reference length n0 the schedule is
                                     # anchored at (identity for n <= n0)
    lln_renorm: float = 0.0          # drift renorm threshold on the carried
                                     # |z| magnitude: rescale (s, z) against
                                     # the per-row log-scale when max|z|
                                     # exceeds it (0 = off)
    lln_num_scales: int = 4          # log_linear only: Fenwick pyramid depth
                                     # L — level l holds a dyadic span of 2^l
                                     # closed lln_chunk granules (L=1 == lln)
    lln_scale_decay: float = 0.5     # log_linear only: per-level mix weight
                                     # w_l = decay^l (1.0 == flat == lln)

    # --- speculative decoding ------------------------------------------------
    draft_layers: int = 0            # tied first-k-layers draft (0 = off;
                                     # n_layers = tied full model)
    spec_k: int = 0                  # draft tokens per verify chunk (0 = off)

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    first_dense_layers: int = 0      # deepseek-v2: first layer keeps dense FFN
    router_aux_coef: float = 0.001

    # --- MLA (deepseek-v2) ---------------------------------------------------
    kv_lora: int = 0
    q_lora: int = 0
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    # --- SSM (mamba2 / zamba2) ----------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    conv_width: int = 4
    ssm_chunk: int = 256
    shared_attn_period: int = 6      # zamba2: shared attn block cadence

    # --- enc-dec / vlm frontends ---------------------------------------------
    enc_layers: int = 0              # seamless: encoder depth
    frontend_dim: int = 0            # stub embedding dim (audio frames / patches)
    num_prefix_tokens: int = 0       # vlm: image patch count

    # --- norm / act / misc ---------------------------------------------------
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu_glu"            # silu_glu | gelu_glu | gelu
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma-style sqrt(d_model) embed scaling
    logit_softcap: float = 0.0

    # --- dtypes / remat / microbatching --------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"              # full | dots | none
    grad_accum: int = 1              # microbatches per step (activation peak /N)
    cast_params_once: bool = False   # bf16-cast before FSDP gathers (2x comm)
    scan_unroll: bool = False        # unroll layer scans (roofline probes:
                                     # makes HLO cost_analysis trip-count-exact)

    # --- distribution policy -------------------------------------------------
    attn_shard: str = "tp_heads"     # tp_heads | context | replicate
    vocab_pad_to: int = 256

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return ((self.vocab + m - 1) // m) * m

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_serve(self) -> bool:
        return self.kind in ("prefill", "decode")


# The four assigned LM shapes (identical for all 10 archs).
SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}
