"""Attention backend registry: one declarative spec, one dispatch table.

Before this module, every attention entry point re-decided its own backend:
``kernels/ops.py`` threaded ``interpret=`` flags per call,
``models/attention_block.py`` forked on ``cfg.use_serve_kernel`` and
``models/mla.py`` hand-rolled its own decode.  The registry centralizes
that choice behind a single declarative :class:`AttnSpec` and four named
backends:

``auto``
    Reproduces the historical dispatch exactly: compiled backends (TPU) run
    the Pallas kernels, the CPU container runs each op's designated twin
    (interpreted Pallas for the training forward, the chunked ``lax.scan``
    twin for prefill, the jnp twin for decode), and ragged sequence lengths
    fall back to the jnp reference.
``pallas``
    Force the Pallas kernel (interpret mode on CPU, so the kernel path is a
    first-class testable target everywhere).  Raises on ragged lengths —
    there is no kernel for those.
``scan``
    Force the chunked ``lax.scan`` / grouped-einsum twin (kernel layout, no
    repeated KV).  For ops with no dedicated twin this is the core chunked
    scan.
``ref``
    Force the jnp reference (``core/lln.py`` / ``core/diag.py`` — model
    layout, repeated KV).  This is exactly the seed serving path that
    ``use_serve_kernel=False`` used to select; for the training forward it
    is the quadratic oracle from ``kernels/ref.py``.

The per-op twin tables live next to the kernels in ``kernels/ops.py``;
this module owns the *policy* (spec validation + backend resolution) and
the spec-level entry points the :class:`~repro.core.engine.AttentionEngine`
calls (:func:`attention`, :func:`prefill`, :func:`decode_chunk`,
:func:`diag_fwd`).  It also hosts the deprecation machinery for the legacy
entry points that the engine supersedes.
"""
from __future__ import annotations

import dataclasses
import warnings
import functools
from typing import Callable, Optional

import jax

IMPLS = ("softmax", "lln", "lln_diag", "log_linear")
BACKENDS = ("auto", "pallas", "scan", "ref")
CALIBRATIONS = ("batch", "per_row")
PRECISIONS = ("float32", "bfloat16", "float16")


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    """Declarative description of one attention configuration.

    Every knob that used to be scattered across ``AttnConfig`` flags,
    ``use_serve_kernel`` forks and per-call ``interpret=`` arguments in one
    validated place.  The spec is hashable and cheap — build one per layer
    call (``AttnSpec.from_cfg``) or inline in tests.

    Attributes:
      impl: ``softmax`` | ``lln`` | ``lln_diag`` (paper §4.2 hybrid) |
        ``log_linear`` (Fenwick multi-scale LLN state, causal-only).
      causal: decoder (True) vs encoder (False) masking.
      r: GQA ratio ``H // G`` (1 = MHA; k/v carry ``G = H // r`` heads).
      backend: ``auto`` | ``pallas`` | ``scan`` | ``ref`` — see module
        docstring.  ``auto`` reproduces the historical dispatch.
      precision: dtype name for cached tensors (KV cache / diag tails);
        accumulators are always fp32.
      calibration: ``batch`` pools moment-matching statistics over the
        whole (batch, seq) like the paper's training setting; ``per_row``
        measures each batch row alone and yields (B, H)/(B, G) constants —
        the continuous-batching admission setting.
      lln_chunk: chunk of the causal LLN scan.
      diag_block: block size of the §4.2 diagonal component (also the
        decode tail length).
      softmax_chunk: key-chunk of the flash softmax path.
      fixed_ab: fixed alpha=beta (paper §A.8.4 ablation); 0 = dynamic
        moment matching.
      mm_a / mm_b: moment-matching constants; None = calibrated defaults
        for the head dim.
      beta_n: beta(n) log-length temperature schedule coefficient — the
        effective (alpha, beta) of a row at depth n are scaled by
        ``sqrt(1 + beta_n * ln(n / calib_len))`` past the calibration
        length (0 = off; see ``core/moment_matching.py:length_gain``).
      calib_len: reference length n0 the schedule is anchored at; the
        schedule is the identity for n <= calib_len.
      renorm: drift renormalization threshold on the carried LLN ``z``
        magnitude — decode rescales (s, z) against the per-row log-scale
        when ``max|z|`` crosses it (0 = off; semantics-preserving, see
        ``core/lln.py:decode_chunk``).  For ``log_linear`` the shift is
        repaid through each bucket's reference constant
        (``core/loglinear.py``).
      num_scales: ``log_linear`` only — number of Fenwick pyramid levels
        L; level ``l`` summarizes a dyadic span of ``2^l`` closed
        granules (``lln_chunk`` tokens each).  ``num_scales=1`` is
        exactly plain ``lln``.
      scale_decay: ``log_linear`` only — per-level mix weight
        ``scale_decay ** l`` (the open bucket and intra-chunk keys score
        at weight 1).  ``scale_decay=1`` is exactly plain ``lln``; the
        default 0.5 equalizes per-level mass so recent tokens outweigh
        distant ones.
    """
    impl: str = "softmax"
    causal: bool = True
    r: int = 1
    backend: str = "auto"
    precision: str = "float32"
    calibration: str = "batch"
    lln_chunk: int = 128
    diag_block: int = 256
    softmax_chunk: int = 1024
    fixed_ab: float = 0.0
    mm_a: Optional[float] = None
    mm_b: Optional[float] = None
    beta_n: float = 0.0
    calib_len: int = 1024
    renorm: float = 0.0
    num_scales: int = 4
    scale_decay: float = 0.5

    def __post_init__(self):
        if self.impl not in IMPLS:
            raise ValueError(
                f"AttnSpec.impl must be one of {IMPLS}, got {self.impl!r}")
        if self.backend not in BACKENDS:
            raise ValueError(
                f"AttnSpec.backend must be one of {BACKENDS}, "
                f"got {self.backend!r}")
        if self.calibration not in CALIBRATIONS:
            raise ValueError(
                f"AttnSpec.calibration must be one of {CALIBRATIONS}, "
                f"got {self.calibration!r}")
        if self.precision not in PRECISIONS:
            raise ValueError(
                f"AttnSpec.precision must be one of {PRECISIONS}, "
                f"got {self.precision!r}")
        if self.r < 1:
            raise ValueError(f"AttnSpec.r (GQA ratio) must be >= 1, "
                             f"got {self.r}")
        if self.impl == "softmax" and self.backend == "pallas":
            raise ValueError(
                "softmax attention has no Pallas kernel; use backend "
                "'auto', 'scan' (flash) or 'ref' (naive quadratic)")
        for name in ("lln_chunk", "diag_block", "softmax_chunk"):
            if getattr(self, name) < 1:
                raise ValueError(f"AttnSpec.{name} must be positive")
        if self.fixed_ab < 0:
            raise ValueError("AttnSpec.fixed_ab must be >= 0")
        if self.beta_n < 0:
            raise ValueError("AttnSpec.beta_n must be >= 0")
        if self.renorm < 0:
            raise ValueError("AttnSpec.renorm must be >= 0")
        if self.calib_len < 1:
            raise ValueError("AttnSpec.calib_len must be positive")
        if self.num_scales < 1:
            raise ValueError("AttnSpec.num_scales must be >= 1")
        if self.scale_decay <= 0:
            raise ValueError("AttnSpec.scale_decay must be > 0")
        if self.impl == "log_linear" and not self.causal:
            raise ValueError(
                "log_linear attention is causal-only (the Fenwick bucket "
                "pyramid is a running prefix summary)")

    @classmethod
    def from_cfg(cls, cfg, causal: bool = True,
                 r: Optional[int] = None) -> "AttnSpec":
        """Build the spec an :class:`ArchConfig` implies.

        ``cfg.attn_backend`` selects the backend explicitly; the legacy
        ``use_serve_kernel=False`` escape maps to ``backend='ref'`` (the
        seed jnp serving path it used to select).  ``r`` overrides the
        GQA ratio (MLA runs full heads regardless of ``cfg.n_kv_heads``).
        """
        backend = getattr(cfg, "attn_backend", "auto")
        if backend == "auto" and not getattr(cfg, "use_serve_kernel", True):
            backend = "ref"
        return cls(impl=cfg.attn_impl, causal=causal,
                   r=r if r is not None else cfg.n_heads // cfg.n_kv_heads,
                   backend=backend,
                   precision=str(cfg.compute_dtype),
                   calibration=("per_row" if getattr(
                       cfg, "lln_per_row_calib", False) else "batch"),
                   lln_chunk=cfg.lln_chunk, diag_block=cfg.diag_block,
                   softmax_chunk=cfg.softmax_chunk,
                   fixed_ab=cfg.lln_fixed_ab,
                   beta_n=getattr(cfg, "lln_beta_n", 0.0),
                   calib_len=getattr(cfg, "lln_calib_len", 1024),
                   renorm=getattr(cfg, "lln_renorm", 0.0),
                   num_scales=getattr(cfg, "lln_num_scales", 4),
                   scale_decay=getattr(cfg, "lln_scale_decay", 0.5))


# ---------------------------------------------------------------------------
# Backend resolution — the one place that owns the interpret/twin/ref choice.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Resolution:
    """A concrete dispatch decision: which implementation kind runs, and
    whether a Pallas kernel runs in interpret mode."""
    kind: str            # "pallas" | "scan" | "ref"
    interpret: bool = False


def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def resolve(backend: str, *, ragged: bool = False,
            cpu_twin: str = "scan") -> Resolution:
    """Resolve a backend name to a concrete implementation kind.

    Args:
      backend: one of :data:`BACKENDS`.
      ragged: sequence length not divisible by the op's chunk/block — no
        kernel or twin exists; ``auto`` falls back to the jnp reference and
        explicit ``pallas``/``scan`` raise.
      cpu_twin: the kind ``auto`` selects on the CPU container (per-op:
        the training forwards run the Pallas kernel interpreted, the
        serving ops run their ``lax.scan``/jnp twins).
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown attention backend {backend!r}; "
                         f"expected one of {BACKENDS}")
    if backend == "auto":
        if ragged:
            return Resolution("ref")
        if on_cpu():
            return Resolution(cpu_twin, interpret=True)
        return Resolution("pallas")
    if backend in ("pallas", "scan"):
        if ragged:
            raise ValueError(
                f"backend={backend!r} has no ragged-length path "
                "(sequence length must be a chunk/block multiple); "
                "use backend='auto' or 'ref'")
        if backend == "pallas":
            return Resolution("pallas", interpret=on_cpu())
        return Resolution("scan")
    return Resolution("ref")


# ---------------------------------------------------------------------------
# Spec-level entry points (what the AttentionEngine calls).  These import
# kernels.ops lazily: ops imports this module for `resolve`.
# ---------------------------------------------------------------------------

def attention(spec: AttnSpec, q, k, v, alpha, beta, **kw):
    """Full-sequence LLN / LLN+Diag attention under ``spec.backend``.

    (Softmax lives in ``core/attention.py`` — it has no Pallas kernel and
    its flash/naive fork is resolved there.)
    """
    from . import ops
    if spec.impl == "lln":
        return ops.lln_attention(q, k, v, alpha, beta, spec.causal,
                                 spec.lln_chunk, backend=spec.backend, **kw)
    if spec.impl == "lln_diag":
        return ops.lln_diag_attention(q, k, v, alpha, beta, spec.causal,
                                      spec.diag_block, backend=spec.backend,
                                      **kw)
    if spec.impl == "log_linear":
        return ops.loglin_attention(q, k, v, alpha, beta, spec.causal,
                                    spec.lln_chunk,
                                    num_scales=spec.num_scales,
                                    scale_decay=spec.scale_decay,
                                    backend=spec.backend, **kw)
    raise ValueError(f"registry.attention does not handle {spec.impl!r}")


def prefill(spec: AttnSpec, q, k, v, alpha, beta):
    """State-emitting causal LLN prefill under ``spec.backend``.
    Returns ``(out, s, z, c_k)`` in the decode-state layout."""
    from . import ops
    return ops.lln_prefill(q, k, v, alpha, beta, chunk=spec.lln_chunk,
                           backend=spec.backend)


def loglin_prefill(spec: AttnSpec, q, k, v, alpha, beta):
    """State-emitting causal log-linear prefill under ``spec.backend``.
    Returns ``(out, s, z, c_k, sl, zl, cl)`` — the open-bucket LLN state
    plus the Fenwick bucket pyramid (``core/loglinear.py`` layout)."""
    from . import ops
    return ops.loglin_prefill(q, k, v, alpha, beta, chunk=spec.lln_chunk,
                              num_scales=spec.num_scales,
                              scale_decay=spec.scale_decay,
                              backend=spec.backend)


def decode_chunk(spec: AttnSpec, state, q, k, v, alpha, beta,
                 row_mask=None, commit_len=None, pos=None):
    """Advance an ``LLNState`` over T tokens under ``spec.backend``.
    ``commit_len`` (B,) folds only the accepted prefix (speculative
    verify — see ``ops.lln_decode_chunk``).  ``log_linear`` specs route
    to :func:`ops.loglin_decode_chunk` and additionally need ``pos``
    (B,) — the per-row depth that determines each row's bucket layout."""
    from . import ops
    if spec.impl == "log_linear":
        return ops.loglin_decode_chunk(state, q, k, v, alpha, beta,
                                       pos=pos, granule=spec.lln_chunk,
                                       num_scales=spec.num_scales,
                                       scale_decay=spec.scale_decay,
                                       row_mask=row_mask,
                                       backend=spec.backend,
                                       commit_len=commit_len,
                                       renorm=spec.renorm or None)
    return ops.lln_decode_chunk(state, q, k, v, alpha, beta,
                                row_mask=row_mask, backend=spec.backend,
                                commit_len=commit_len,
                                renorm=spec.renorm or None)


def commit_chunk(spec: AttnSpec, state, k, v, beta,
                 row_mask=None, commit_len=None, pos=None):
    """Fold a scored chunk's accepted prefix into an ``LLNState`` under
    ``spec.backend`` — the single-pass speculative-verify commit (no
    scoring; see ``ops.lln_commit_chunk``)."""
    from . import ops
    if spec.impl == "log_linear":
        return ops.loglin_commit_chunk(state, k, v, beta,
                                       pos=pos, granule=spec.lln_chunk,
                                       num_scales=spec.num_scales,
                                       row_mask=row_mask,
                                       backend=spec.backend,
                                       commit_len=commit_len,
                                       renorm=spec.renorm or None)
    return ops.lln_commit_chunk(state, k, v, beta,
                                row_mask=row_mask, backend=spec.backend,
                                commit_len=commit_len,
                                renorm=spec.renorm or None)


def diag_fwd(spec: AttnSpec, q, k, v):
    """Inference block-diagonal softmax (the §4.2 diag component) under
    ``spec.backend``."""
    from . import ops
    return ops.block_diag_fwd(q, k, v, spec.diag_block, spec.causal,
                              backend=spec.backend)


# ---------------------------------------------------------------------------
# Deprecation shims for the legacy entry points the engine supersedes.
# ---------------------------------------------------------------------------

_WARNED: set[str] = set()


def reset_deprecations() -> None:
    """Forget which shims already warned (tests assert warn-once)."""
    _WARNED.clear()


def warn_deprecated(name: str, replacement: str) -> None:
    """Emit one DeprecationWarning per process for ``name``."""
    if name in _WARNED:
        return
    _WARNED.add(name)
    warnings.warn(
        f"{name} is deprecated; use {replacement} instead",
        DeprecationWarning, stacklevel=3)


def deprecated_shim(name: str, replacement: str) -> Callable:
    """Decorator marking a legacy entry point: warns once, then delegates.

    The wrapped function keeps its signature and return value — it IS the
    delegation.  ``tests/test_shims.py`` guards that every shim both warns
    exactly once and reaches the engine path.
    """
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            warn_deprecated(name, replacement)
            return fn(*args, **kwargs)
        wrapper.__deprecated_shim__ = (name, replacement)
        return wrapper
    return deco
