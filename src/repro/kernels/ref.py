"""Pure-jnp oracles for every Pallas kernel in this package.

Kernel-native layout: q/k: (BH, N, D) (already alpha/beta-scaled and
stabilized for the LLN kernels), v: (BH, N, DV).  GQA is expressed by
``r = H // G``: k/v carry (B*G, N, D) and query row ``bh`` reads kv row
``bh // r``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-6
NEG_INF = -1e30


def _expand_kv(t: jnp.ndarray, r: int) -> jnp.ndarray:
    return t if r == 1 else jnp.repeat(t, r, axis=0)


def lln_bidir_ref(qs: jnp.ndarray, ks: jnp.ndarray, v: jnp.ndarray,
                  r: int = 1) -> jnp.ndarray:
    """Bidirectional LLN: out_i = e^{qs_i} S / (e^{qs_i} . z)."""
    fq = jnp.exp(qs.astype(jnp.float32))
    fk = jnp.exp(ks.astype(jnp.float32))
    vf = v.astype(jnp.float32)
    s = jnp.einsum("gnd,gnv->gdv", fk, vf)
    z = jnp.sum(fk, axis=1)
    s = _expand_kv(s, r)
    z = _expand_kv(z, r)
    num = jnp.einsum("hnd,hdv->hnv", fq, s)
    den = jnp.einsum("hnd,hd->hn", fq, z)
    return (num / (den[..., None] + EPS)).astype(v.dtype)


def lln_causal_ref(qs: jnp.ndarray, ks: jnp.ndarray, v: jnp.ndarray,
                   r: int = 1) -> jnp.ndarray:
    """Causal LLN, quadratic-form oracle: P = tril(e^{qs} e^{ks}^T) row-norm."""
    fq = jnp.exp(qs.astype(jnp.float32))
    fk = jnp.exp(_expand_kv(ks, r).astype(jnp.float32))
    vf = _expand_kv(v, r).astype(jnp.float32)
    n = qs.shape[1]
    scores = jnp.einsum("hid,hjd->hij", fq, fk)
    scores = scores * jnp.tril(jnp.ones((n, n), jnp.float32))
    out = jnp.einsum("hij,hjv->hiv", scores, vf)
    den = jnp.sum(scores, axis=-1)
    return (out / (den[..., None] + EPS)).astype(v.dtype)


def block_diag_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                   block: int, causal: bool, r: int = 1,
                   scale: float | None = None) -> jnp.ndarray:
    """Block-diagonal softmax attention oracle (N divisible by block)."""
    k = _expand_kv(k, r)
    v = _expand_kv(v, r)
    bh, n, d = q.shape
    dv = v.shape[-1]
    scale = (d ** -0.5) if scale is None else scale
    nb = n // block
    qb = q.reshape(bh, nb, block, d).astype(jnp.float32) * scale
    kb = k.reshape(bh, nb, block, d).astype(jnp.float32)
    vb = v.reshape(bh, nb, block, dv).astype(jnp.float32)
    s = jnp.einsum("hgid,hgjd->hgij", qb, kb)
    if causal:
        tri = jnp.tril(jnp.ones((block, block), jnp.bool_))
        s = jnp.where(tri[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("hgij,hgjv->hgiv", p, vb)
    return out.reshape(bh, n, dv).astype(v.dtype)


def lln_prefill_state_ref(qs: jnp.ndarray, ks: jnp.ndarray, v: jnp.ndarray,
                          r: int = 1):
    """Oracle for the state-emitting causal kernel: (out, s, z) with the
    final running state s = sum_j Phi(k_j) v_j^T (BH, D, DV) and
    z = sum_j Phi(k_j) (BH, 1, D), per query-head row (GQA rows repeat the
    group state, matching the H-head decode cache)."""
    out = lln_causal_ref(qs, ks, v, r)
    fk = jnp.exp(_expand_kv(ks, r).astype(jnp.float32))
    vf = _expand_kv(v, r).astype(jnp.float32)
    s = jnp.einsum("hnd,hnv->hdv", fk, vf)
    z = jnp.sum(fk, axis=1, keepdims=True)
    return out, s, z


def _segsum_kv(t: jnp.ndarray, r: int) -> jnp.ndarray:
    """Sum a per-query-head gradient over the r heads sharing each KV row."""
    if r == 1:
        return t
    bh = t.shape[0]
    return t.reshape(bh // r, r, *t.shape[1:]).sum(axis=1)


def lln_fwd_res_ref(qs: jnp.ndarray, ks: jnp.ndarray, v: jnp.ndarray,
                    causal: bool, r: int = 1):
    """Forward oracle that also returns the fp32 (out, den) residual pair."""
    fq = jnp.exp(qs.astype(jnp.float32))
    fk = jnp.exp(_expand_kv(ks, r).astype(jnp.float32))
    vf = _expand_kv(v, r).astype(jnp.float32)
    scores = jnp.einsum("hid,hjd->hij", fq, fk)
    if causal:
        scores = scores * jnp.tril(jnp.ones(scores.shape[1:], jnp.float32))
    den = jnp.sum(scores, axis=-1) + EPS
    out = jnp.einsum("hij,hjv->hiv", scores, vf) / den[..., None]
    return out, den


def lln_bwd_ref(qs: jnp.ndarray, ks: jnp.ndarray, v: jnp.ndarray,
                g: jnp.ndarray, o: jnp.ndarray, den: jnp.ndarray,
                causal: bool, r: int = 1):
    """Analytic LLN backward oracle (quadratic form), kernel layout.

    Mirrors the normalizer-aware decomposition used by the Pallas backward:
    u = g/den, w = (g.o)/den, G_ij = (u_i.v_j - w_i) * mask, then
    dqs = fq * (G @ fk), dks = fk * (G^T @ fq), dv = scores^T @ u, with
    dks/dv segment-summed over the r repeated query heads.
    """
    fq = jnp.exp(qs.astype(jnp.float32))
    fk = jnp.exp(_expand_kv(ks, r).astype(jnp.float32))
    vf = _expand_kv(v, r).astype(jnp.float32)
    gf = g.astype(jnp.float32)
    of = o.astype(jnp.float32)
    u = gf / den[..., None]
    w = jnp.sum(gf * of, axis=-1) / den
    mask = jnp.tril(jnp.ones((qs.shape[1], qs.shape[1]), jnp.float32)) \
        if causal else jnp.ones((qs.shape[1], qs.shape[1]), jnp.float32)
    scores = jnp.einsum("hid,hjd->hij", fq, fk) * mask
    gmat = (jnp.einsum("hiv,hjv->hij", u, vf) - w[..., None]) * mask
    dqs = fq * jnp.einsum("hij,hjd->hid", gmat, fk)
    dks = fk * jnp.einsum("hij,hid->hjd", gmat, fq)
    dv = jnp.einsum("hij,hiv->hjv", scores, u)
    return dqs, _segsum_kv(dks, r), _segsum_kv(dv, r)


def block_diag_bwd_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                       g: jnp.ndarray, *, block: int, causal: bool,
                       r: int = 1, scale: float | None = None):
    """Block-diagonal softmax backward oracle via jax.vjp (kernel layout)."""
    kf = _expand_kv(k, r)
    vf = _expand_kv(v, r)
    _, vjp = jax.vjp(
        lambda q_, k_, v_: block_diag_ref(
            q_.astype(jnp.float32), k_.astype(jnp.float32),
            v_.astype(jnp.float32), block=block, causal=causal, r=1,
            scale=scale), q, kf, vf)
    dq, dk, dv = vjp(g.astype(jnp.float32))
    return dq, _segsum_kv(dk, r), _segsum_kv(dv, r)


def lln_diag_fused_bwd_ref(qs, ks, q, k, v, g, o, den, *, block: int,
                           r: int = 1, scale: float | None = None):
    """Backward oracle for the fused causal LLN + diag kernel.

    The LLN cotangent w needs the LLN component of the averaged output,
    reconstructed exactly like the kernel does: 2*o - diag_out.
    """
    diag_out = block_diag_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), block=block,
                              causal=True, r=r, scale=scale)
    lln_out = 2.0 * o.astype(jnp.float32) - diag_out
    gh = 0.5 * g.astype(jnp.float32)
    dqs, dks, dv_lln = lln_bwd_ref(qs, ks, v, gh, lln_out, den,
                                   causal=True, r=r)
    dqd, dkd, dv_diag = block_diag_bwd_ref(q, k, v, gh, block=block,
                                           causal=True, r=r, scale=scale)
    return dqs, dqd, dks, dkd, dv_lln + dv_diag


def lln_diag_fused_ref(qs: jnp.ndarray, ks: jnp.ndarray, q: jnp.ndarray,
                       k: jnp.ndarray, v: jnp.ndarray, *, block: int,
                       causal: bool, r: int = 1,
                       scale: float | None = None) -> jnp.ndarray:
    """Oracle for the fused LLN+Diag kernel: 0.5*(LLN + block-diag softmax).

    qs/ks are the stabilized LLN-scaled tensors; q/k the raw ones for the
    softmax diagonal.
    """
    lln = (lln_causal_ref(qs, ks, v, r) if causal
           else lln_bidir_ref(qs, ks, v, r))
    diag = block_diag_ref(q, k, v, block=block, causal=causal, r=r,
                          scale=scale)
    return (0.5 * (lln.astype(jnp.float32) + diag.astype(jnp.float32))
            ).astype(v.dtype)
