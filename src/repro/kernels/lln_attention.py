"""Pallas TPU kernels for Linear Log-Normal attention (paper eq. 8).

TPU adaptation (vs. the paper's PyTorch einsum implementation):
* the feature map exp(.) is fused into the matmul pipeline — Phi(Q), Phi(K)
  (each N x D in HBM) are never materialized;
* the running state S (D x DV) and normalizer z (1 x D) live in fp32 VMEM
  scratch across sequence blocks (grid minor dimension is sequential on TPU);
* block sizes are MXU-aligned (multiples of 128 on the lane dim; D = head_dim
  is 64/128 for all assigned archs);
* GQA without materializing repeated KV: query row ``bh`` reads kv row
  ``bh // r`` via BlockSpec index maps.

Inputs are pre-scaled and pre-stabilized by ops.py:  qs = alpha*q - c_q,
ks = beta*k - c_k  with per-(batch,head) global constants that cancel exactly
in the normalized form (see core/lln.py docstring).

Training residuals
------------------
Every forward entry point accepts ``return_res=True`` to additionally emit
the per-row normalizer ``den_i = Phi(q_i) . (z_prefix + sum_block Phi(k))``
(fp32, shape (BH, N)) — and, for the bidirectional variant, the reduced
``(S, z)`` summary state.  ops.py saves these (together with the already
pre-scaled ``qs``/``ks``) as custom_vjp residuals so the backward kernels in
``lln_backward.py`` never recompute the stabilization constants or the
forward normalizers: the quotient rule through ``out = num / den`` is applied
analytically from the saved ``den`` and the forward output.  The fused
LLN+diag kernel saves only the LLN ``den`` — its backward reconstructs the
LLN component as ``2*out - diag_out`` from an in-kernel softmax recompute
that it needs anyway for the softmax gradient.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

EPS = 1e-6


# ---------------------------------------------------------------------------
# Causal LLN: chunked scan with VMEM-resident state.
# ---------------------------------------------------------------------------

def _lln_causal_kernel(qs_ref, ks_ref, v_ref, o_ref, *rest, blk, with_res,
                       with_state):
    # rest = (*extra outputs, s_acc, z_acc): den if with_res, then the final
    # (s, z) state outputs if with_state.
    den_ref = rest[0] if with_res else None
    s_out = rest[int(with_res)] if with_state else None
    z_out = rest[int(with_res) + 1] if with_state else None
    s_acc, z_acc = rest[-2:]
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        s_acc[...] = jnp.zeros_like(s_acc)
        z_acc[...] = jnp.zeros_like(z_acc)

    fq = jnp.exp(qs_ref[0].astype(jnp.float32))          # (blk, d)
    fk = jnp.exp(ks_ref[0].astype(jnp.float32))          # (blk, d)
    vv = v_ref[0].astype(jnp.float32)                    # (blk, dv)

    row = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1)
    causal = (row >= col).astype(jnp.float32)

    scores = jax.lax.dot_general(fq, fk, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * causal
    intra = jnp.dot(scores, vv, preferred_element_type=jnp.float32)
    intra_z = jnp.sum(scores, axis=-1)

    inter = jnp.dot(fq, s_acc[...], preferred_element_type=jnp.float32)
    inter_z = jnp.dot(fq, z_acc[...].reshape(-1, 1),
                      preferred_element_type=jnp.float32)[:, 0]

    den = intra_z + inter_z + EPS
    o_ref[0] = ((intra + inter) / den[:, None]).astype(o_ref.dtype)
    if with_res:
        den_ref[0] = den

    s_acc[...] += jax.lax.dot_general(fk, vv, (((0,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
    z_acc[...] += jnp.sum(fk, axis=0, keepdims=True)
    if with_state:
        # The (h, 0, 0)-mapped output blocks are revisited every j; the
        # value committed after the last grid step is the final carry.
        s_out[0] = s_acc[...]
        z_out[0] = z_acc[...]


def lln_causal_pallas(qs: jnp.ndarray, ks: jnp.ndarray, v: jnp.ndarray, *,
                      r: int = 1, blk: int = 256, interpret: bool = False,
                      return_res: bool = False, return_state: bool = False):
    """qs: (BH, N, D) pre-scaled; ks/v: (BG, N, D[v]); N % blk == 0.

    With ``return_res`` also emits the fp32 normalizer ``den`` (BH, N) used
    by the custom backward (see module docstring).  With ``return_state``
    also emits the final running state ``s`` (BH, D, DV) and ``z`` (BH, 1, D)
    — the O(d^2) decode state, produced by the same pass that computes the
    prefill outputs (serving path; see ops.lln_prefill).
    """
    bh, n, d = qs.shape
    dv = v.shape[-1]
    nb = n // blk
    grid = (bh, nb)
    out_specs = [pl.BlockSpec((1, blk, dv), lambda h, j: (h, j, 0))]
    out_shape = [jax.ShapeDtypeStruct((bh, n, dv), v.dtype)]
    if return_res:
        out_specs.append(pl.BlockSpec((1, blk), lambda h, j: (h, j)))
        out_shape.append(jax.ShapeDtypeStruct((bh, n), jnp.float32))
    if return_state:
        out_specs.append(pl.BlockSpec((1, d, dv), lambda h, j: (h, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((bh, d, dv), jnp.float32))
        out_specs.append(pl.BlockSpec((1, 1, d), lambda h, j: (h, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((bh, 1, d), jnp.float32))
    res = pl.pallas_call(
        functools.partial(_lln_causal_kernel, blk=blk, with_res=return_res,
                          with_state=return_state),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk, d), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, blk, d), lambda h, j, r=r: (h // r, j, 0)),
            pl.BlockSpec((1, blk, dv), lambda h, j, r=r: (h // r, j, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((d, dv), jnp.float32),
                        pltpu.VMEM((1, d), jnp.float32)],
        interpret=interpret,
    )(qs, ks, v)
    return tuple(res) if (return_res or return_state) else res[0]


# ---------------------------------------------------------------------------
# Bidirectional LLN: reduce pass (S, z) + apply pass.
# ---------------------------------------------------------------------------

def _lln_reduce_kernel(ks_ref, v_ref, s_ref, z_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    fk = jnp.exp(ks_ref[0].astype(jnp.float32))
    vv = v_ref[0].astype(jnp.float32)
    s_ref[0] += jax.lax.dot_general(fk, vv, (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    z_ref[0] += jnp.sum(fk, axis=0, keepdims=True)


def _lln_apply_kernel(qs_ref, s_ref, z_ref, o_ref, *rest, with_res):
    fq = jnp.exp(qs_ref[0].astype(jnp.float32))
    num = jnp.dot(fq, s_ref[0], preferred_element_type=jnp.float32)
    den = jnp.dot(fq, z_ref[0].reshape(-1, 1),
                  preferred_element_type=jnp.float32)[:, 0] + EPS
    o_ref[0] = (num / den[:, None]).astype(o_ref.dtype)
    if with_res:
        rest[0][0] = den


def lln_bidir_pallas(qs: jnp.ndarray, ks: jnp.ndarray, v: jnp.ndarray, *,
                     r: int = 1, blk: int = 256, interpret: bool = False,
                     return_res: bool = False):
    """qs: (BH, N, D); ks/v: (BG, N, D[v]); N % blk == 0.

    With ``return_res`` returns ``(out, s, z, den)``: the reduced summary
    state (BG, D, DV)/(BG, 1, D) and the fp32 normalizer (BH, N), reused by
    the backward pass.
    """
    bh, n, d = qs.shape
    bg = ks.shape[0]
    dv = v.shape[-1]
    nb = n // blk
    s, z = pl.pallas_call(
        _lln_reduce_kernel,
        grid=(bg, nb),
        in_specs=[
            pl.BlockSpec((1, blk, d), lambda g, j: (g, j, 0)),
            pl.BlockSpec((1, blk, dv), lambda g, j: (g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d, dv), lambda g, j: (g, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda g, j: (g, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bg, d, dv), jnp.float32),
                   jax.ShapeDtypeStruct((bg, 1, d), jnp.float32)],
        interpret=interpret,
    )(ks, v)
    out_specs = [pl.BlockSpec((1, blk, dv), lambda h, j: (h, j, 0))]
    out_shape = [jax.ShapeDtypeStruct((bh, n, dv), v.dtype)]
    if return_res:
        out_specs.append(pl.BlockSpec((1, blk), lambda h, j: (h, j)))
        out_shape.append(jax.ShapeDtypeStruct((bh, n), jnp.float32))
    res = pl.pallas_call(
        functools.partial(_lln_apply_kernel, with_res=return_res),
        grid=(bh, nb),
        in_specs=[
            pl.BlockSpec((1, blk, d), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, d, dv), lambda h, j, r=r: (h // r, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda h, j, r=r: (h // r, 0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(qs, s, z)
    if return_res:
        return res[0], s, z, res[1]
    return res[0]


# ---------------------------------------------------------------------------
# Fused LLN + block-diagonal softmax (the §4.2 hybrid in a single pass).
# Beyond-paper optimization: shares the v (and q/k) block loads between the
# two components and writes the averaged output once.
# ---------------------------------------------------------------------------

def _lln_diag_fused_kernel(qs_ref, ks_ref, q_ref, k_ref, v_ref, o_ref,
                           *rest, blk, scale, causal, with_res):
    den_ref = rest[0] if with_res else None
    s_acc, z_acc = rest[-2:]
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        s_acc[...] = jnp.zeros_like(s_acc)
        z_acc[...] = jnp.zeros_like(z_acc)

    fq = jnp.exp(qs_ref[0].astype(jnp.float32))
    fk = jnp.exp(ks_ref[0].astype(jnp.float32))
    vv = v_ref[0].astype(jnp.float32)

    row = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1)
    tril = row >= col

    # --- LLN component (causal chunked or full-block bidir handled by ops) --
    scores = jax.lax.dot_general(fq, fk, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    if causal:
        scores = scores * tril.astype(jnp.float32)
    intra = jnp.dot(scores, vv, preferred_element_type=jnp.float32)
    intra_z = jnp.sum(scores, axis=-1)
    inter = jnp.dot(fq, s_acc[...], preferred_element_type=jnp.float32)
    inter_z = jnp.dot(fq, z_acc[...].reshape(-1, 1),
                      preferred_element_type=jnp.float32)[:, 0]
    den = intra_z + inter_z + EPS
    lln_out = (intra + inter) / den[:, None]
    if with_res:
        den_ref[0] = den
    s_acc[...] += jax.lax.dot_general(fk, vv, (((0,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
    z_acc[...] += jnp.sum(fk, axis=0, keepdims=True)

    # --- block-diagonal softmax component ----------------------------------
    qq = q_ref[0].astype(jnp.float32) * scale
    kk = k_ref[0].astype(jnp.float32)
    ds = jax.lax.dot_general(qq, kk, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    if causal:
        ds = jnp.where(tril, ds, -1e30)
    ds = ds - jnp.max(ds, axis=-1, keepdims=True)
    p = jnp.exp(ds)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    diag_out = jnp.dot(p, vv, preferred_element_type=jnp.float32)

    o_ref[0] = (0.5 * (lln_out + diag_out)).astype(o_ref.dtype)


def lln_diag_fused_pallas(qs, ks, q, k, v, *, r: int = 1, blk: int = 256,
                          causal: bool = True, scale: float | None = None,
                          interpret: bool = False, return_res: bool = False):
    """Fused §4.2 hybrid.  Diag block size == LLN chunk size == blk.

    Causal only: the bidirectional LLN needs the full-sequence state, which
    the single-pass fusion cannot provide (use lln_bidir_pallas + block_diag).
    With ``return_res`` also emits the LLN normalizer ``den`` (BH, N, fp32);
    the diag softmax needs no residual — its backward recomputes the block
    probabilities from the shared q/k loads.
    """
    if not causal:
        raise ValueError("fused lln+diag kernel is causal-only")
    bh, n, d = qs.shape
    dv = v.shape[-1]
    nb = n // blk
    scale = (d ** -0.5) if scale is None else scale
    out_specs = [pl.BlockSpec((1, blk, dv), lambda h, j: (h, j, 0))]
    out_shape = [jax.ShapeDtypeStruct((bh, n, dv), v.dtype)]
    if return_res:
        out_specs.append(pl.BlockSpec((1, blk), lambda h, j: (h, j)))
        out_shape.append(jax.ShapeDtypeStruct((bh, n), jnp.float32))
    res = pl.pallas_call(
        functools.partial(_lln_diag_fused_kernel, blk=blk, scale=scale,
                          causal=causal, with_res=return_res),
        grid=(bh, nb),
        in_specs=[
            pl.BlockSpec((1, blk, d), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, blk, d), lambda h, j, r=r: (h // r, j, 0)),
            pl.BlockSpec((1, blk, d), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, blk, d), lambda h, j, r=r: (h // r, j, 0)),
            pl.BlockSpec((1, blk, dv), lambda h, j, r=r: (h // r, j, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((d, dv), jnp.float32),
                        pltpu.VMEM((1, d), jnp.float32)],
        interpret=interpret,
    )(qs, ks, q, k, v)
    return tuple(res) if return_res else res[0]


# ---------------------------------------------------------------------------
# Chunked multi-token decode: advance the (S, z) state over T new tokens in
# one grid step per (batch, head) — the serving-path building block for
# speculative/multi-token decode (ops.lln_decode_chunk).
# ---------------------------------------------------------------------------

def _lln_decode_kernel(qs_ref, ks_ref, v_ref, s0_ref, z0_ref,
                       o_ref, s1_ref, z1_ref, *, t):
    fq = jnp.exp(qs_ref[0].astype(jnp.float32))          # (t, d)
    fk = jnp.exp(ks_ref[0].astype(jnp.float32))          # (t, d)
    vv = v_ref[0].astype(jnp.float32)                    # (t, dv)
    s0 = s0_ref[0]                                       # (d, dv) fp32
    z0 = z0_ref[0]                                       # (1, d) fp32

    row = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    causal = (row >= col).astype(jnp.float32)

    scores = jax.lax.dot_general(fq, fk, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * causal
    intra = jnp.dot(scores, vv, preferred_element_type=jnp.float32)
    intra_z = jnp.sum(scores, axis=-1)
    inter = jnp.dot(fq, s0, preferred_element_type=jnp.float32)
    inter_z = jnp.dot(fq, z0.reshape(-1, 1),
                      preferred_element_type=jnp.float32)[:, 0]
    den = intra_z + inter_z + EPS
    o_ref[0] = ((intra + inter) / den[:, None]).astype(o_ref.dtype)
    s1_ref[0] = s0 + jax.lax.dot_general(fk, vv, (((0,), (0,)), ((), ())),
                                         preferred_element_type=jnp.float32)
    z1_ref[0] = z0 + jnp.sum(fk, axis=0, keepdims=True)


def lln_decode_pallas(qs: jnp.ndarray, ks: jnp.ndarray, v: jnp.ndarray,
                      s0: jnp.ndarray, z0: jnp.ndarray, *, r: int = 1,
                      interpret: bool = False):
    """qs: (BH, T, D) pre-scaled; ks/v: (BG, T, D[v]); s0: (BH, D, DV) and
    z0: (BH, 1, D) pre-rescaled to the chunk's reference constant (fp32).

    Returns (out (BH, T, DV), s1, z1).  T should be padded by the caller to
    a sublane multiple with ks rows at NEG_INF (=> Phi(k) = 0, no state
    contribution) and qs/v rows at 0 (output rows sliced off).
    """
    bh, t, d = qs.shape
    dv = v.shape[-1]
    return pl.pallas_call(
        functools.partial(_lln_decode_kernel, t=t),
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, t, d), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, t, d), lambda h, r=r: (h // r, 0, 0)),
            pl.BlockSpec((1, t, dv), lambda h, r=r: (h // r, 0, 0)),
            pl.BlockSpec((1, d, dv), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda h: (h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, t, dv), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, d, dv), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda h: (h, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bh, t, dv), v.dtype),
                   jax.ShapeDtypeStruct((bh, d, dv), jnp.float32),
                   jax.ShapeDtypeStruct((bh, 1, d), jnp.float32)],
        interpret=interpret,
    )(qs, ks, v, s0, z0)
