"""Pallas TPU kernel for block-diagonal softmax attention (paper §4.2).

Each sequence block attends only within itself: scores, softmax and the
weighted sum all live in VMEM — no N x N HBM round-trip.  Grid is
(batch*heads, num_blocks); blocks are MXU-aligned (default 256 x head_dim).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block_diag_kernel(q_ref, k_ref, v_ref, o_ref, *, blk, scale, causal):
    q = q_ref[0].astype(jnp.float32) * scale
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if causal:
        row = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1)
        s = jnp.where(row >= col, s, -1e30)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32
                       ).astype(o_ref.dtype)


def _block_diag_bwd_kernel(q_ref, k_ref, v_ref, g_ref, dq_ref, dk_ref,
                           dv_ref, *, blk, scale, causal):
    rr = pl.program_id(2)

    # dk/dv output blocks accumulate the GQA segment-sum over the r
    # repeated query heads (innermost grid axis -> consecutive revisits).
    @pl.when(rr == 0)
    def _init_out():
        dk_ref[...] = jnp.zeros_like(dk_ref)
        dv_ref[...] = jnp.zeros_like(dv_ref)

    qq = q_ref[0].astype(jnp.float32) * scale
    kk = k_ref[0].astype(jnp.float32)
    vv = v_ref[0].astype(jnp.float32)
    gg = g_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(qq, kk, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if causal:
        row = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1)
        s = jnp.where(row >= col, s, -1e30)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)

    dp = jax.lax.dot_general(gg, vv, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    dsm = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq_ref[0] = jnp.dot(dsm, kk, preferred_element_type=jnp.float32) * scale
    dk_ref[0] += jax.lax.dot_general(dsm, qq, (((0,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)
    dv_ref[0] += jax.lax.dot_general(p, gg, (((0,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)


def block_diag_bwd_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                          g: jnp.ndarray, *, r: int = 1, blk: int = 256,
                          causal: bool = False, scale: float | None = None,
                          interpret: bool = False):
    """Backward of the block-diagonal softmax kernel.

    Needs no forward residuals: the block probabilities are recomputed
    in-kernel.  Returns fp32 (dq, dk, dv); dk/dv are segment-summed over
    the r = H // G repeated query heads.
    """
    bh, n, d = q.shape
    bg = k.shape[0]
    dv = v.shape[-1]
    nb = n // blk
    scale = (d ** -0.5) if scale is None else scale
    return pl.pallas_call(
        functools.partial(_block_diag_bwd_kernel, blk=blk, scale=scale,
                          causal=causal),
        grid=(bg, nb, r),
        in_specs=[
            pl.BlockSpec((1, blk, d),
                         lambda gi, j, rr, r=r: (gi * r + rr, j, 0)),
            pl.BlockSpec((1, blk, d), lambda gi, j, rr: (gi, j, 0)),
            pl.BlockSpec((1, blk, dv), lambda gi, j, rr: (gi, j, 0)),
            pl.BlockSpec((1, blk, dv),
                         lambda gi, j, rr, r=r: (gi * r + rr, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk, d),
                         lambda gi, j, rr, r=r: (gi * r + rr, j, 0)),
            pl.BlockSpec((1, blk, d), lambda gi, j, rr: (gi, j, 0)),
            pl.BlockSpec((1, blk, dv), lambda gi, j, rr: (gi, j, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bh, n, d), jnp.float32),
                   jax.ShapeDtypeStruct((bg, n, d), jnp.float32),
                   jax.ShapeDtypeStruct((bg, n, dv), jnp.float32)],
        interpret=interpret,
    )(q, k, v, g)


def block_diag_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      r: int = 1, blk: int = 256, causal: bool = False,
                      scale: float | None = None,
                      interpret: bool = False) -> jnp.ndarray:
    """q: (BH, N, D); k/v: (BG, N, D[v]); N % blk == 0."""
    bh, n, d = q.shape
    dv = v.shape[-1]
    nb = n // blk
    scale = (d ** -0.5) if scale is None else scale
    return pl.pallas_call(
        functools.partial(_block_diag_kernel, blk=blk, scale=scale,
                          causal=causal),
        grid=(bh, nb),
        in_specs=[
            pl.BlockSpec((1, blk, d), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, blk, d), lambda h, j, r=r: (h // r, j, 0)),
            pl.BlockSpec((1, blk, dv), lambda h, j, r=r: (h // r, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk, dv), lambda h, j: (h, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n, dv), v.dtype),
        interpret=interpret,
    )(q, k, v)
