"""Pallas TPU backward kernels for Linear Log-Normal attention.

The forward computes ``out_i = num_i / den_i`` with ``num_i = Phi(q_i) S_i``,
``den_i = Phi(q_i) . z_i + EPS`` over prefix (causal) or full-sequence
(bidir) summaries ``S_i = sum_j Phi(k_j) v_j^T``, ``z_i = sum_j Phi(k_j)``.
The quotient rule is applied analytically from the saved normalizer instead
of via ``jax.vjp``: with the cotangent ``g_i`` and the saved forward output,

    u_i = g_i / den_i                    (value-space cotangent, Dv)
    w_i = (g_i . out_i) / den_i          (normalizer cotangent, scalar)

the three input gradients factor through the same linear-attention summaries
as the forward (cf. the normalizer-aware decomposition in "The Devil in
Linear Transformer", Qin et al. 2022):

    dPhi(q)_i = sum_{j<=i} (u_i . v_j - w_i) Phi(k)_j = S_i u_i - w_i z_i
    dPhi(k)_j = sum_{i>=j} (u_i . v_j - w_i) Phi(q)_i = dS_j v_j - dz_j
    dv_j      = sum_{i>=j} (Phi(q)_i . Phi(k)_j) u_i  = dS_j^T Phi(k)_j

with the *reverse* running state ``dS_j = sum_{i>=j} Phi(q)_i u_i^T`` and
``dz_j = sum_{i>=j} w_i Phi(q)_i`` (the mirror of the forward scan, cf. the
chunked backward of "Log-Linear Attention", Guo et al. 2025).  Since the
feature map is exp(.), ``d qs = Phi(q) * dPhi(q)`` elementwise.

Kernel structure:

* ``lln_causal_bwd_pallas`` — two kernels.  dQ runs a forward-order scan
  re-building the running ``(S, z)`` prefix state in VMEM scratch (same
  recurrence as the forward); dK/dV runs a reverse-order scan with the
  gradient state ``(dS, dz)`` in VMEM scratch.
* GQA (r = H // G > 1): the dK/dV grid is (BG, num_blocks, r) with the
  query-head repeat innermost — dk/dv output blocks are revisited
  consecutively and accumulated in place (a segment-sum over the ``h // r``
  index map), so repeated K/V is never materialized; the reverse state
  ``dS``/``dz`` is kept per repeated head in an (r, D, Dv) scratch.
* ``lln_bidir_bwd_pallas`` — reduce/apply structure mirroring the forward:
  dQ applies the saved forward summaries ``(S, z)``; a reduce pass
  accumulates the full-sequence ``(dS, dz)`` per KV head; an apply pass
  produces dK/dV.
* ``lln_diag_fused_bwd_pallas`` — backward of the §4.2 hybrid.  Shares the
  q/k/v block loads between the LLN gradient and the block-diagonal-softmax
  gradient exactly like the forward fusion; the softmax probabilities are
  recomputed in-kernel (they are block-local), which also reconstructs the
  LLN component of the saved averaged output as ``2*out - diag_out`` so the
  forward only stores the LLN normalizer.

All gradients are emitted in fp32 (ops.py applies the alpha/beta chain rule
and casts back to the model dtypes).

Each kernel has a chunked ``lax.scan`` twin (``*_bwd_scan``) implementing
the identical recurrences in plain jnp.  ops.py dispatches to the scan twin
when the kernels would run in interpret mode (the CPU container): interpret
mode pays a full block copy per grid step, so it is a correctness tool, not
a perf path — while the scan twin keeps the structural wins (saved
residuals instead of forward recompute, no ``jax.checkpoint`` remat, GQA
segment-sum instead of repeated KV) and measurably beats the legacy
``jax.vjp``-through-the-reference fallback on CPU too.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _contract(a, b, dims):
    return jax.lax.dot_general(a, b, (dims, ((), ())),
                               preferred_element_type=jnp.float32)


def _load_uw(g_ref, o_ref, den_ref):
    """Cotangents u = g/den (blk, Dv) and w = (g.o)/den (blk,)."""
    gg = g_ref[0].astype(jnp.float32)
    oo = o_ref[0].astype(jnp.float32)
    den = den_ref[0].astype(jnp.float32)
    return gg / den[:, None], jnp.sum(gg * oo, axis=-1) / den


def _tril(blk):
    row = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1)
    return row >= col


# ---------------------------------------------------------------------------
# Causal LLN backward.
# ---------------------------------------------------------------------------

def _causal_dq_kernel(qs_ref, ks_ref, v_ref, g_ref, o_ref, den_ref,
                      dqs_ref, s_acc, z_acc, *, blk):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        s_acc[...] = jnp.zeros_like(s_acc)
        z_acc[...] = jnp.zeros_like(z_acc)

    fq = jnp.exp(qs_ref[0].astype(jnp.float32))          # (blk, d)
    fk = jnp.exp(ks_ref[0].astype(jnp.float32))          # (blk, d)
    vv = v_ref[0].astype(jnp.float32)                    # (blk, dv)
    u, w = _load_uw(g_ref, o_ref, den_ref)

    mask = _tril(blk).astype(jnp.float32)
    # G_ij = (u_i . v_j - w_i) for j <= i within the block.
    gmat = (_contract(u, vv, ((1,), (1,))) - w[:, None]) * mask
    # intra (j <= i, same block) + inter (all earlier blocks via S, z).
    dfq = _contract(gmat, fk, ((1,), (0,)))
    dfq += _contract(u, s_acc[...], ((1,), (1,)))
    dfq -= w[:, None] * z_acc[...]
    dqs_ref[0] = fq * dfq

    s_acc[...] += _contract(fk, vv, ((0,), (0,)))
    z_acc[...] += jnp.sum(fk, axis=0, keepdims=True)


def _causal_dkv_kernel(qs_ref, ks_ref, v_ref, g_ref, o_ref, den_ref,
                       dks_ref, dv_ref, ds_acc, dz_acc, *, blk, r):
    j = pl.program_id(1)
    rr = pl.program_id(2)

    # New reverse scan for this repeated query head starts at the last block.
    @pl.when(j == 0)
    def _init_state():
        ds_acc[pl.ds(rr, 1)] = jnp.zeros((1,) + ds_acc.shape[1:], jnp.float32)
        dz_acc[pl.ds(rr, 1)] = jnp.zeros((1,) + dz_acc.shape[1:], jnp.float32)

    # dk/dv output blocks accumulate across the r repeated query heads.
    @pl.when(rr == 0)
    def _init_out():
        dks_ref[...] = jnp.zeros_like(dks_ref)
        dv_ref[...] = jnp.zeros_like(dv_ref)

    fq = jnp.exp(qs_ref[0].astype(jnp.float32))
    fk = jnp.exp(ks_ref[0].astype(jnp.float32))
    vv = v_ref[0].astype(jnp.float32)
    u, w = _load_uw(g_ref, o_ref, den_ref)
    ds = ds_acc[pl.ds(rr, 1)][0]                         # (d, dv), later blks
    dz = dz_acc[pl.ds(rr, 1)][0]                         # (1, d)

    mask = _tril(blk).astype(jnp.float32)
    scores = _contract(fq, fk, ((1,), (1,))) * mask      # (blk_i, blk_j)
    gmat = (_contract(u, vv, ((1,), (1,))) - w[:, None]) * mask

    dv_ref[0] += _contract(scores, u, ((0,), (0,))) \
        + _contract(fk, ds, ((1,), (0,)))
    dfk = _contract(gmat, fq, ((0,), (0,))) \
        + _contract(vv, ds, ((1,), (1,))) - dz
    dks_ref[0] += fk * dfk

    ds_acc[pl.ds(rr, 1)] = (ds + _contract(fq, u, ((0,), (0,))))[None]
    dz_acc[pl.ds(rr, 1)] = (dz + jnp.sum(fq * w[:, None], axis=0,
                                         keepdims=True))[None]


def lln_causal_bwd_pallas(qs, ks, v, g, o, den, *, r: int = 1,
                          blk: int = 256, interpret: bool = False):
    """Backward of the causal LLN kernel.

    qs/g/o/den: (BH, N, .) query-side tensors; ks/v: (BG, N, .) with
    r = H // G.  Returns fp32 (dqs, dks, dv) in kernel layout, with dks/dv
    already segment-summed over the repeated query heads.
    """
    bh, n, d = qs.shape
    bg = ks.shape[0]
    dv = v.shape[-1]
    nb = n // blk
    dqs = pl.pallas_call(
        functools.partial(_causal_dq_kernel, blk=blk),
        grid=(bh, nb),
        in_specs=[
            pl.BlockSpec((1, blk, d), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, blk, d), lambda h, j, r=r: (h // r, j, 0)),
            pl.BlockSpec((1, blk, dv), lambda h, j, r=r: (h // r, j, 0)),
            pl.BlockSpec((1, blk, dv), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, blk, dv), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, blk), lambda h, j: (h, j)),
        ],
        out_specs=pl.BlockSpec((1, blk, d), lambda h, j: (h, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d, dv), jnp.float32),
                        pltpu.VMEM((1, d), jnp.float32)],
        interpret=interpret,
    )(qs, ks, v, g, o, den)

    # Reverse-order scan: grid index j walks blocks last-to-first; the
    # innermost r axis accumulates the GQA segment-sum in the output block.
    dks, dvv = pl.pallas_call(
        functools.partial(_causal_dkv_kernel, blk=blk, r=r),
        grid=(bg, nb, r),
        in_specs=[
            pl.BlockSpec((1, blk, d),
                         lambda gi, j, rr, r=r, nb=nb:
                         (gi * r + rr, nb - 1 - j, 0)),
            pl.BlockSpec((1, blk, d),
                         lambda gi, j, rr, nb=nb: (gi, nb - 1 - j, 0)),
            pl.BlockSpec((1, blk, dv),
                         lambda gi, j, rr, nb=nb: (gi, nb - 1 - j, 0)),
            pl.BlockSpec((1, blk, dv),
                         lambda gi, j, rr, r=r, nb=nb:
                         (gi * r + rr, nb - 1 - j, 0)),
            pl.BlockSpec((1, blk, dv),
                         lambda gi, j, rr, r=r, nb=nb:
                         (gi * r + rr, nb - 1 - j, 0)),
            pl.BlockSpec((1, blk),
                         lambda gi, j, rr, r=r, nb=nb:
                         (gi * r + rr, nb - 1 - j)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk, d),
                         lambda gi, j, rr, nb=nb: (gi, nb - 1 - j, 0)),
            pl.BlockSpec((1, blk, dv),
                         lambda gi, j, rr, nb=nb: (gi, nb - 1 - j, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bg, n, d), jnp.float32),
                   jax.ShapeDtypeStruct((bg, n, dv), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((r, d, dv), jnp.float32),
                        pltpu.VMEM((r, 1, d), jnp.float32)],
        interpret=interpret,
    )(qs, ks, v, g, o, den)
    return dqs, dks, dvv


# ---------------------------------------------------------------------------
# Bidirectional LLN backward: dQ apply + (dS, dz) reduce + dK/dV apply.
# ---------------------------------------------------------------------------

def _bidir_dq_kernel(qs_ref, g_ref, o_ref, den_ref, s_ref, z_ref, dqs_ref):
    fq = jnp.exp(qs_ref[0].astype(jnp.float32))
    u, w = _load_uw(g_ref, o_ref, den_ref)
    dfq = _contract(u, s_ref[0], ((1,), (1,))) - w[:, None] * z_ref[0]
    dqs_ref[0] = fq * dfq


def _bidir_reduce_kernel(qs_ref, g_ref, o_ref, den_ref, ds_ref, dz_ref):
    first = (pl.program_id(1) == 0) & (pl.program_id(2) == 0)

    @pl.when(first)
    def _init():
        ds_ref[...] = jnp.zeros_like(ds_ref)
        dz_ref[...] = jnp.zeros_like(dz_ref)

    fq = jnp.exp(qs_ref[0].astype(jnp.float32))
    u, w = _load_uw(g_ref, o_ref, den_ref)
    ds_ref[0] += _contract(fq, u, ((0,), (0,)))
    dz_ref[0] += jnp.sum(fq * w[:, None], axis=0, keepdims=True)


def _bidir_dkv_kernel(ks_ref, v_ref, ds_ref, dz_ref, dks_ref, dv_ref):
    fk = jnp.exp(ks_ref[0].astype(jnp.float32))
    vv = v_ref[0].astype(jnp.float32)
    ds = ds_ref[0]
    dv_ref[0] = _contract(fk, ds, ((1,), (0,)))
    dks_ref[0] = fk * (_contract(vv, ds, ((1,), (1,))) - dz_ref[0])


def lln_bidir_bwd_pallas(qs, ks, v, g, o, den, s, z, *, r: int = 1,
                         blk: int = 256, interpret: bool = False):
    """Backward of the bidirectional LLN kernel.

    s/z are the forward's reduced summaries (BG, D, DV)/(BG, 1, D), saved as
    residuals.  Returns fp32 (dqs, dks, dv) in kernel layout.
    """
    bh, n, d = qs.shape
    bg = ks.shape[0]
    dv = v.shape[-1]
    nb = n // blk
    dqs = pl.pallas_call(
        _bidir_dq_kernel,
        grid=(bh, nb),
        in_specs=[
            pl.BlockSpec((1, blk, d), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, blk, dv), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, blk, dv), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, blk), lambda h, j: (h, j)),
            pl.BlockSpec((1, d, dv), lambda h, j, r=r: (h // r, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda h, j, r=r: (h // r, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk, d), lambda h, j: (h, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n, d), jnp.float32),
        interpret=interpret,
    )(qs, g, o, den, s, z)

    # Full-sequence gradient summaries, segment-summed over repeated heads:
    # for a fixed KV head every (rr, j) iteration lands on the same output
    # block, so the accumulation stays in VMEM until the head changes.
    dsg, dzg = pl.pallas_call(
        _bidir_reduce_kernel,
        grid=(bg, r, nb),
        in_specs=[
            pl.BlockSpec((1, blk, d),
                         lambda gi, rr, j, r=r: (gi * r + rr, j, 0)),
            pl.BlockSpec((1, blk, dv),
                         lambda gi, rr, j, r=r: (gi * r + rr, j, 0)),
            pl.BlockSpec((1, blk, dv),
                         lambda gi, rr, j, r=r: (gi * r + rr, j, 0)),
            pl.BlockSpec((1, blk),
                         lambda gi, rr, j, r=r: (gi * r + rr, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, d, dv), lambda gi, rr, j: (gi, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda gi, rr, j: (gi, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bg, d, dv), jnp.float32),
                   jax.ShapeDtypeStruct((bg, 1, d), jnp.float32)],
        interpret=interpret,
    )(qs, g, o, den)

    dks, dvv = pl.pallas_call(
        _bidir_dkv_kernel,
        grid=(bg, nb),
        in_specs=[
            pl.BlockSpec((1, blk, d), lambda gi, j: (gi, j, 0)),
            pl.BlockSpec((1, blk, dv), lambda gi, j: (gi, j, 0)),
            pl.BlockSpec((1, d, dv), lambda gi, j: (gi, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda gi, j: (gi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk, d), lambda gi, j: (gi, j, 0)),
            pl.BlockSpec((1, blk, dv), lambda gi, j: (gi, j, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bg, n, d), jnp.float32),
                   jax.ShapeDtypeStruct((bg, n, dv), jnp.float32)],
        interpret=interpret,
    )(ks, v, dsg, dzg)
    return dqs, dks, dvv


# ---------------------------------------------------------------------------
# Fused LLN + block-diagonal softmax backward (§4.2 hybrid).
# ---------------------------------------------------------------------------

def _diag_recompute(q_ref, k_ref, vv, *, blk, scale, causal):
    """Block softmax probabilities p and diag output (shared-load recompute)."""
    qq = q_ref[0].astype(jnp.float32) * scale
    kk = k_ref[0].astype(jnp.float32)
    s = _contract(qq, kk, ((1,), (1,)))
    if causal:
        s = jnp.where(_tril(blk), s, NEG_INF)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return qq, kk, p, jnp.dot(p, vv, preferred_element_type=jnp.float32)


def _fused_uw(g_ref, o_ref, den_ref, diag_out):
    """LLN cotangents for the averaged output: the LLN component is
    reconstructed as 2*out - diag_out, and the 0.5 averaging weight is
    folded into u/w via g/2."""
    gh = 0.5 * g_ref[0].astype(jnp.float32)
    den = den_ref[0].astype(jnp.float32)
    lln_out = 2.0 * o_ref[0].astype(jnp.float32) - diag_out
    u = gh / den[:, None]
    w = jnp.sum(gh * lln_out, axis=-1) / den
    return gh, u, w


def _dsoftmax(p, dp):
    return p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))


def _fused_dq_kernel(qs_ref, ks_ref, q_ref, k_ref, v_ref, g_ref, o_ref,
                     den_ref, dqs_ref, dqd_ref, s_acc, z_acc, *, blk, scale):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        s_acc[...] = jnp.zeros_like(s_acc)
        z_acc[...] = jnp.zeros_like(z_acc)

    fq = jnp.exp(qs_ref[0].astype(jnp.float32))
    fk = jnp.exp(ks_ref[0].astype(jnp.float32))
    vv = v_ref[0].astype(jnp.float32)
    qq, kk, p, diag_out = _diag_recompute(q_ref, k_ref, vv, blk=blk,
                                          scale=scale, causal=True)
    gh, u, w = _fused_uw(g_ref, o_ref, den_ref, diag_out)

    mask = _tril(blk).astype(jnp.float32)
    gmat = (_contract(u, vv, ((1,), (1,))) - w[:, None]) * mask
    dfq = _contract(gmat, fk, ((1,), (0,)))
    dfq += _contract(u, s_acc[...], ((1,), (1,)))
    dfq -= w[:, None] * z_acc[...]
    dqs_ref[0] = fq * dfq

    dp = _contract(gh, vv, ((1,), (1,)))
    dqd_ref[0] = _contract(_dsoftmax(p, dp), kk, ((1,), (0,))) * scale

    s_acc[...] += _contract(fk, vv, ((0,), (0,)))
    z_acc[...] += jnp.sum(fk, axis=0, keepdims=True)


def _fused_dkv_kernel(qs_ref, ks_ref, q_ref, k_ref, v_ref, g_ref, o_ref,
                      den_ref, dks_ref, dkd_ref, dv_ref, ds_acc, dz_acc,
                      *, blk, scale, r):
    j = pl.program_id(1)
    rr = pl.program_id(2)

    @pl.when(j == 0)
    def _init_state():
        ds_acc[pl.ds(rr, 1)] = jnp.zeros((1,) + ds_acc.shape[1:], jnp.float32)
        dz_acc[pl.ds(rr, 1)] = jnp.zeros((1,) + dz_acc.shape[1:], jnp.float32)

    @pl.when(rr == 0)
    def _init_out():
        dks_ref[...] = jnp.zeros_like(dks_ref)
        dkd_ref[...] = jnp.zeros_like(dkd_ref)
        dv_ref[...] = jnp.zeros_like(dv_ref)

    fq = jnp.exp(qs_ref[0].astype(jnp.float32))
    fk = jnp.exp(ks_ref[0].astype(jnp.float32))
    vv = v_ref[0].astype(jnp.float32)
    qq, _, p, diag_out = _diag_recompute(q_ref, k_ref, vv, blk=blk,
                                         scale=scale, causal=True)
    gh, u, w = _fused_uw(g_ref, o_ref, den_ref, diag_out)
    ds = ds_acc[pl.ds(rr, 1)][0]
    dz = dz_acc[pl.ds(rr, 1)][0]

    mask = _tril(blk).astype(jnp.float32)
    scores = _contract(fq, fk, ((1,), (1,))) * mask
    gmat = (_contract(u, vv, ((1,), (1,))) - w[:, None]) * mask

    dp = _contract(gh, vv, ((1,), (1,)))
    dsm = _dsoftmax(p, dp)
    dv_ref[0] += _contract(scores, u, ((0,), (0,))) \
        + _contract(fk, ds, ((1,), (0,))) \
        + _contract(p, gh, ((0,), (0,)))
    dfk = _contract(gmat, fq, ((0,), (0,))) \
        + _contract(vv, ds, ((1,), (1,))) - dz
    dks_ref[0] += fk * dfk
    dkd_ref[0] += _contract(dsm, qq, ((0,), (0,)))

    ds_acc[pl.ds(rr, 1)] = (ds + _contract(fq, u, ((0,), (0,))))[None]
    dz_acc[pl.ds(rr, 1)] = (dz + jnp.sum(fq * w[:, None], axis=0,
                                         keepdims=True))[None]


def lln_diag_fused_bwd_pallas(qs, ks, q, k, v, g, o, den, *, r: int = 1,
                              blk: int = 256, scale: float | None = None,
                              interpret: bool = False):
    """Backward of the fused causal LLN + block-diag softmax kernel.

    Returns fp32 (dqs, dq_diag, dks, dk_diag, dv): dqs/dks feed the LLN
    alpha/beta chain rule, dq_diag/dk_diag are the raw-q/k softmax grads,
    dv carries both components.  dks/dk_diag/dv are segment-summed over the
    r repeated query heads.
    """
    bh, n, d = qs.shape
    bg = ks.shape[0]
    dvd = v.shape[-1]
    nb = n // blk
    scale = (d ** -0.5) if scale is None else scale

    def q_spec(shape):
        return pl.BlockSpec(shape, lambda h, j: (h, j, 0))

    def kv_spec(shape):
        return pl.BlockSpec(shape, lambda h, j, r=r: (h // r, j, 0))

    dqs, dqd = pl.pallas_call(
        functools.partial(_fused_dq_kernel, blk=blk, scale=scale),
        grid=(bh, nb),
        in_specs=[
            q_spec((1, blk, d)),
            kv_spec((1, blk, d)),
            q_spec((1, blk, d)),
            kv_spec((1, blk, d)),
            kv_spec((1, blk, dvd)),
            q_spec((1, blk, dvd)),
            q_spec((1, blk, dvd)),
            pl.BlockSpec((1, blk), lambda h, j: (h, j)),
        ],
        out_specs=[q_spec((1, blk, d)), q_spec((1, blk, d))],
        out_shape=[jax.ShapeDtypeStruct((bh, n, d), jnp.float32),
                   jax.ShapeDtypeStruct((bh, n, d), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((d, dvd), jnp.float32),
                        pltpu.VMEM((1, d), jnp.float32)],
        interpret=interpret,
    )(qs, ks, q, k, v, g, o, den)

    def qr_spec(shape):
        return pl.BlockSpec(shape,
                            lambda gi, j, rr, r=r, nb=nb:
                            (gi * r + rr, nb - 1 - j, 0))

    def kvr_spec(shape):
        return pl.BlockSpec(shape,
                            lambda gi, j, rr, nb=nb: (gi, nb - 1 - j, 0))

    dks, dkd, dvv = pl.pallas_call(
        functools.partial(_fused_dkv_kernel, blk=blk, scale=scale, r=r),
        grid=(bg, nb, r),
        in_specs=[
            qr_spec((1, blk, d)),
            kvr_spec((1, blk, d)),
            qr_spec((1, blk, d)),
            kvr_spec((1, blk, d)),
            kvr_spec((1, blk, dvd)),
            qr_spec((1, blk, dvd)),
            qr_spec((1, blk, dvd)),
            pl.BlockSpec((1, blk),
                         lambda gi, j, rr, r=r, nb=nb:
                         (gi * r + rr, nb - 1 - j)),
        ],
        out_specs=[kvr_spec((1, blk, d)), kvr_spec((1, blk, d)),
                   kvr_spec((1, blk, dvd))],
        out_shape=[jax.ShapeDtypeStruct((bg, n, d), jnp.float32),
                   jax.ShapeDtypeStruct((bg, n, d), jnp.float32),
                   jax.ShapeDtypeStruct((bg, n, dvd), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((r, d, dvd), jnp.float32),
                        pltpu.VMEM((r, 1, d), jnp.float32)],
        interpret=interpret,
    )(qs, ks, q, k, v, g, o, den)
    return dqs, dqd, dks, dkd, dvv


# ---------------------------------------------------------------------------
# Chunked lax.scan twins (interpret-mode / CPU dispatch; identical math).
# ---------------------------------------------------------------------------

def _uw_full(g, o, den):
    gf = g.astype(jnp.float32)
    u = gf / den[..., None]
    w = jnp.sum(gf * o.astype(jnp.float32), axis=-1) / den
    return u, w


def _chunked_q(t, bg, r, nc, blk):
    """(BG*r, N, D) -> (nc, BG, r, blk, D) chunk-major for lax.scan."""
    d = t.shape[-1]
    return t.reshape(bg, r, nc, blk, d).transpose(2, 0, 1, 3, 4)


def _chunked_kv(t, nc, blk):
    """(BG, N, D) -> (nc, BG, blk, D)."""
    bg, _, d = t.shape
    return t.reshape(bg, nc, blk, d).transpose(1, 0, 2, 3)


def _unchunk_q(t, bh):
    nc, bg, r, blk, d = t.shape
    return t.transpose(1, 2, 0, 3, 4).reshape(bh, nc * blk, d)


def _unchunk_kv(t):
    nc, bg, blk, d = t.shape
    return t.transpose(1, 0, 2, 3).reshape(bg, nc * blk, d)


def lln_causal_bwd_scan(qs, ks, v, g, o, den, *, r: int = 1,
                        blk: int = 256):
    """jnp twin of :func:`lln_causal_bwd_pallas` (same residuals, same
    two-pass scan structure, chunk-parallel over heads)."""
    bh, n, d = qs.shape
    bg = ks.shape[0]
    dv = v.shape[-1]
    nc = n // blk
    fq = _chunked_q(jnp.exp(qs.astype(jnp.float32)), bg, r, nc, blk)
    fk = _chunked_kv(jnp.exp(ks.astype(jnp.float32)), nc, blk)
    vf = _chunked_kv(v.astype(jnp.float32), nc, blk)
    u, w = _uw_full(g, o, den)
    u = _chunked_q(u, bg, r, nc, blk)
    w = _chunked_q(w[..., None], bg, r, nc, blk)[..., 0]
    mask = jnp.tril(jnp.ones((blk, blk), jnp.float32))

    def dq_step(carry, xs):
        s, z = carry                                 # (BG,D,Dv), (BG,D)
        fq_c, fk_c, v_c, u_c, w_c = xs
        gmat = (jnp.einsum("brie,bje->brij", u_c, v_c)
                - w_c[..., None]) * mask
        dfq = jnp.einsum("brij,bjd->brid", gmat, fk_c)
        dfq += jnp.einsum("brie,bde->brid", u_c, s)
        dfq -= w_c[..., None] * z[:, None, None, :]
        s = s + jnp.einsum("bjd,bje->bde", fk_c, v_c)
        z = z + jnp.sum(fk_c, axis=1)
        return (s, z), fq_c * dfq

    s0 = jnp.zeros((bg, d, dv), jnp.float32)
    z0 = jnp.zeros((bg, d), jnp.float32)
    _, dqs = jax.lax.scan(dq_step, (s0, z0), (fq, fk, vf, u, w))

    def dkv_step(carry, xs):
        ds, dz = carry                               # (BG,D,Dv), (BG,D)
        fq_c, fk_c, v_c, u_c, w_c = xs
        scores = jnp.einsum("brid,bjd->brij", fq_c, fk_c) * mask
        gmat = (jnp.einsum("brie,bje->brij", u_c, v_c)
                - w_c[..., None]) * mask
        dv_c = jnp.einsum("brij,brie->bje", scores, u_c)
        dv_c += jnp.einsum("bjd,bde->bje", fk_c, ds)
        dfk = jnp.einsum("brij,brid->bjd", gmat, fq_c)
        dfk += jnp.einsum("bje,bde->bjd", v_c, ds) - dz[:, None, :]
        ds = ds + jnp.einsum("brid,brie->bde", fq_c, u_c)
        dz = dz + jnp.sum(fq_c * w_c[..., None], axis=(1, 2))
        return (ds, dz), (fk_c * dfk, dv_c)

    _, (dks, dvv) = jax.lax.scan(dkv_step, (s0, z0), (fq, fk, vf, u, w),
                                 reverse=True)
    return _unchunk_q(dqs, bh), _unchunk_kv(dks), _unchunk_kv(dvv)


def lln_bidir_bwd_scan(qs, ks, v, g, o, den, s, z, *, r: int = 1,
                       blk: int = 256):
    """jnp twin of :func:`lln_bidir_bwd_pallas` (full-sequence einsums)."""
    bh, n, d = qs.shape
    bg = ks.shape[0]
    fq = jnp.exp(qs.astype(jnp.float32)).reshape(bg, r, n, d)
    fk = jnp.exp(ks.astype(jnp.float32))
    vf = v.astype(jnp.float32)
    u, w = _uw_full(g, o, den)
    u = u.reshape(bg, r, n, -1)
    w = w.reshape(bg, r, n)
    dfq = jnp.einsum("brne,bde->brnd", u, s) \
        - w[..., None] * z[:, 0][:, None, None, :]
    dqs = (fq * dfq).reshape(bh, n, d)
    ds = jnp.einsum("brnd,brne->bde", fq, u)
    dz = jnp.sum(fq * w[..., None], axis=(1, 2))
    dvv = jnp.einsum("bnd,bde->bne", fk, ds)
    dks = fk * (jnp.einsum("bne,bde->bnd", vf, ds) - dz[:, None, :])
    return dqs, dks, dvv


def block_diag_bwd_scan(q, k, v, g, *, r: int = 1, blk: int = 256,
                        causal: bool = False, scale: float | None = None):
    """jnp twin of :func:`block_diag.block_diag_bwd_pallas`."""
    bh, n, d = q.shape
    bg = k.shape[0]
    dv = v.shape[-1]
    nb = n // blk
    scale = (d ** -0.5) if scale is None else scale
    qq = q.astype(jnp.float32).reshape(bg, r, nb, blk, d) * scale
    kk = k.astype(jnp.float32).reshape(bg, nb, blk, d)
    vf = v.astype(jnp.float32).reshape(bg, nb, blk, dv)
    gf = g.astype(jnp.float32).reshape(bg, r, nb, blk, dv)
    s = jnp.einsum("brcid,bcjd->brcij", qq, kk)
    if causal:
        s = jnp.where(jnp.tril(jnp.ones((blk, blk), jnp.bool_)), s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    dp = jnp.einsum("brcie,bcje->brcij", gf, vf)
    dsm = _dsoftmax(p, dp)
    dq = jnp.einsum("brcij,bcjd->brcid", dsm, kk) * scale
    dk = jnp.einsum("brcij,brcid->bcjd", dsm, qq)
    dvv = jnp.einsum("brcij,brcie->bcje", p, gf)
    return (dq.reshape(bh, n, d), dk.reshape(bg, n, d),
            dvv.reshape(bg, n, dv))


def lln_diag_fused_bwd_scan(qs, ks, q, k, v, g, o, den, *, r: int = 1,
                            blk: int = 256, scale: float | None = None):
    """jnp twin of :func:`lln_diag_fused_bwd_pallas`: LLN scan backward on
    g/2 plus the block-softmax backward, with the LLN output reconstructed
    as 2*o - diag_out exactly like the kernel."""
    bg = ks.shape[0]
    dv = v.shape[-1]
    n = qs.shape[1]
    nb = n // blk
    scale = (qs.shape[-1] ** -0.5) if scale is None else scale
    qq = q.astype(jnp.float32).reshape(bg, r, nb, blk, -1) * scale
    kk = k.astype(jnp.float32).reshape(bg, nb, blk, -1)
    vf = v.astype(jnp.float32).reshape(bg, nb, blk, dv)
    s = jnp.einsum("brcid,bcjd->brcij", qq, kk)
    s = jnp.where(jnp.tril(jnp.ones((blk, blk), jnp.bool_)), s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    diag_out = jnp.einsum("brcij,bcje->brcie", p, vf).reshape(*g.shape)
    gh = 0.5 * g.astype(jnp.float32)
    lln_out = 2.0 * o.astype(jnp.float32) - diag_out
    dqs, dks, dv_lln = lln_causal_bwd_scan(qs, ks, v, gh, lln_out, den,
                                           r=r, blk=blk)
    # Diag softmax backward reusing the probabilities computed above (the
    # kernel shares the same recompute between components).
    ghb = gh.reshape(bg, r, nb, blk, dv)
    dp = jnp.einsum("brcie,bcje->brcij", ghb, vf)
    dsm = _dsoftmax(p, dp)
    dqd = (jnp.einsum("brcij,bcjd->brcid", dsm, kk) * scale
           ).reshape(qs.shape[0], n, -1)
    dkd = jnp.einsum("brcij,brcid->bcjd", dsm, qq).reshape(bg, n, -1)
    dv_diag = jnp.einsum("brcij,brcie->bcje", p, ghb).reshape(bg, n, dv)
    return dqs, dqd, dks, dkd, dv_lln + dv_diag
