"""Pallas TPU kernel for log-linear (Fenwick multi-scale) LLN attention.

One pass over the sequence in ``granule``-sized blocks (grid minor
dimension is sequential on TPU) maintaining the full bucket pyramid in
VMEM scratch: level ``l`` holds the LLN ``(S, z)`` summary of a dyadic
span of ``2^l`` closed granules.  Queries in block ``j`` read the
pyramid-of-``j`` aggregate (per-level static weights ``decay**l``) plus
a causal intra-block term at weight 1 — exactly the sequential decode
semantics of ``core/loglinear.py``.

Because ops.py pre-stabilizes ``ks = beta*k - c_k`` with ONE global
per-(batch,head) constant, every bucket shares the same reference: the
Fenwick carry-merge degenerates to pure adds and merged-out levels are
simply zeroed, so unoccupied levels contribute nothing to the aggregate
and no per-bucket max/exp bookkeeping is needed in-kernel.  The carry
path at block ``j`` is the binary increment ``j -> j+1``: the carry
propagates through level ``l`` iff bits ``0..l`` of ``j`` are all set,
and the top level saturates (pure add).

GQA without materializing repeated KV: query row ``bh`` reads kv row
``bh // r`` via BlockSpec index maps, same idiom as lln_attention.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

EPS = 1e-6


def _loglin_causal_kernel(qs_ref, ks_ref, v_ref, o_ref, *rest, blk,
                          num_scales, weights, with_state):
    # rest = (*state outputs if with_state, sl_scr, zl_scr)
    sl_out = rest[0] if with_state else None
    zl_out = rest[1] if with_state else None
    sl_scr, zl_scr = rest[-2:]
    ls = num_scales
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        sl_scr[...] = jnp.zeros_like(sl_scr)
        zl_scr[...] = jnp.zeros_like(zl_scr)

    fq = jnp.exp(qs_ref[0].astype(jnp.float32))          # (blk, d)
    fk = jnp.exp(ks_ref[0].astype(jnp.float32))          # (blk, d)
    vv = v_ref[0].astype(jnp.float32)                    # (blk, dv)

    row = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1)
    causal = (row >= col).astype(jnp.float32)

    scores = jax.lax.dot_general(fq, fk, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * causal
    intra = jnp.dot(scores, vv, preferred_element_type=jnp.float32)
    intra_z = jnp.sum(scores, axis=-1)

    # Pyramid-of-j aggregate.  Merged-out / never-filled levels hold
    # zeros, so the static per-level weight is all that is needed.
    s_eff = weights[0] * sl_scr[0]
    z_eff = weights[0] * zl_scr[0]
    for l in range(1, ls):
        s_eff = s_eff + weights[l] * sl_scr[l]
        z_eff = z_eff + weights[l] * zl_scr[l]
    inter = jnp.dot(fq, s_eff, preferred_element_type=jnp.float32)
    inter_z = jnp.dot(fq, z_eff.reshape(-1, 1),
                      preferred_element_type=jnp.float32)[:, 0]

    den = intra_z + inter_z + EPS
    o_ref[0] = ((intra + inter) / den[:, None]).astype(o_ref.dtype)

    # Fenwick carry-merge of the (now closed) block j: binary increment
    # j -> j+1 with pure adds (shared reference).
    g_s = jax.lax.dot_general(fk, vv, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    g_z = jnp.sum(fk, axis=0, keepdims=True)
    carry_s, carry_z = g_s, g_z
    for l in range(ls - 1):
        reach = (j & ((1 << l) - 1)) == ((1 << l) - 1)   # carry reaches l
        bit = ((j >> l) & 1) == 1
        mrg = jnp.logical_and(reach, bit)
        take = jnp.logical_and(reach, jnp.logical_not(bit))
        old_s = sl_scr[l]
        old_z = zl_scr[l]
        sl_scr[l] = jnp.where(take, carry_s,
                              jnp.where(mrg, jnp.zeros_like(old_s), old_s))
        zl_scr[l] = jnp.where(take, carry_z,
                              jnp.where(mrg, jnp.zeros_like(old_z), old_z))
        carry_s = jnp.where(mrg, carry_s + old_s, carry_s)
        carry_z = jnp.where(mrg, carry_z + old_z, carry_z)
    top = ls - 1
    if top > 0:
        reach_top = (j & ((1 << top) - 1)) == ((1 << top) - 1)
        sl_scr[top] += jnp.where(reach_top, carry_s, jnp.zeros_like(carry_s))
        zl_scr[top] += jnp.where(reach_top, carry_z, jnp.zeros_like(carry_z))
    else:
        sl_scr[0] += carry_s
        zl_scr[0] += carry_z

    if with_state:
        # The (h, 0, 0, 0)-mapped output blocks are revisited every j;
        # the value committed after the last grid step is the final carry.
        sl_out[0] = sl_scr[...]
        zl_out[0] = zl_scr[...]


def loglin_causal_pallas(qs: jnp.ndarray, ks: jnp.ndarray, v: jnp.ndarray, *,
                         num_scales: int, scale_decay: float, r: int = 1,
                         blk: int = 256, interpret: bool = False,
                         return_state: bool = False):
    """qs: (BH, N, D) pre-scaled alpha*q - c_q; ks/v: (BG, N, D[v])
    pre-scaled beta*k - c_k with a single global reference; N % blk == 0
    and ``blk`` is the bucket granule.

    With ``return_state`` also emits the final bucket pyramid
    ``sl`` (BH, L, D, DV) and ``zl`` (BH, L, 1, D) fp32 — all levels at
    the shared global reference (ops broadcasts ``c_k`` into ``cl``).
    """
    bh, n, d = qs.shape
    dv = v.shape[-1]
    nb = n // blk
    ls = num_scales
    weights = tuple(float(scale_decay) ** l for l in range(ls))
    grid = (bh, nb)
    out_specs = [pl.BlockSpec((1, blk, dv), lambda h, j: (h, j, 0))]
    out_shape = [jax.ShapeDtypeStruct((bh, n, dv), v.dtype)]
    if return_state:
        out_specs.append(
            pl.BlockSpec((1, ls, d, dv), lambda h, j: (h, 0, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((bh, ls, d, dv), jnp.float32))
        out_specs.append(
            pl.BlockSpec((1, ls, 1, d), lambda h, j: (h, 0, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((bh, ls, 1, d), jnp.float32))
    res = pl.pallas_call(
        functools.partial(_loglin_causal_kernel, blk=blk,
                          num_scales=ls, weights=weights,
                          with_state=return_state),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk, d), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, blk, d), lambda h, j, r=r: (h // r, j, 0)),
            pl.BlockSpec((1, blk, dv), lambda h, j, r=r: (h // r, j, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((ls, d, dv), jnp.float32),
                        pltpu.VMEM((ls, 1, d), jnp.float32)],
        interpret=interpret,
    )(qs, ks, v)
    return tuple(res) if return_state else res[0]
