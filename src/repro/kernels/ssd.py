"""Pallas TPU kernel for the Mamba2 SSD chunked scan (arXiv:2405.21060).

Structurally the same kernel family as the causal LLN scan
(kernels/lln_attention.py): an intra-chunk quadratic form plus a VMEM-
resident state pass — with per-step exponential decay folded in log-space.
One grid step processes one (batch*head, chunk) tile:

    lcum_i   = cumsum(log a)_i                      (within chunk)
    scores   = (C B^T) * exp(lcum_i - lcum_j) * tril
    y        = scores xbar + (C * exp(lcum)) state
    state   <- exp(lcum_last) state + (B * exp(lcum_last - lcum))^T xbar

B/C group sharing (ssm_groups < heads) is expressed with BlockSpec index
maps, like GQA in the attention kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(la_ref, xb_ref, b_ref, c_ref, o_ref, state, *, blk):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    la = la_ref[0].astype(jnp.float32)                   # (blk,)
    xb = xb_ref[0].astype(jnp.float32)                   # (blk, P)
    bb = b_ref[0].astype(jnp.float32)                    # (blk, S)
    cc = c_ref[0].astype(jnp.float32)                    # (blk, S)

    lcum = jnp.cumsum(la)                                # (blk,)
    row = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1)
    tril = (row >= col).astype(jnp.float32)
    dec = jnp.exp(jnp.clip(lcum[:, None] - lcum[None, :], -60.0, 0.0))

    dot = jax.lax.dot_general(cc, bb, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    scores = dot * dec * tril
    y_intra = jnp.dot(scores, xb, preferred_element_type=jnp.float32)

    ein = jnp.exp(jnp.clip(lcum, -60.0, 0.0))[:, None]
    y_inter = jnp.dot(cc * ein, state[...],
                      preferred_element_type=jnp.float32)
    o_ref[0] = (y_intra + y_inter).astype(o_ref.dtype)

    l_last = lcum[-1]
    carry = jnp.exp(jnp.clip(l_last - lcum, -60.0, 0.0))[:, None]
    state[...] = state[...] * jnp.exp(jnp.clip(l_last, -60.0, 0.0)) + \
        jax.lax.dot_general(bb * carry, xb, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)


def ssd_pallas(log_a: jnp.ndarray, xbar: jnp.ndarray, b_in: jnp.ndarray,
               c_in: jnp.ndarray, *, r: int = 1, blk: int = 256,
               interpret: bool = False) -> jnp.ndarray:
    """log_a: (BH, N); xbar: (BH, N, P); b_in/c_in: (BG, N, S); N % blk == 0.
    Head bh reads group row bh // r.  Returns y: (BH, N, P)."""
    bh, n, p = xbar.shape
    s = b_in.shape[-1]
    nb = n // blk
    return pl.pallas_call(
        functools.partial(_ssd_kernel, blk=blk),
        grid=(bh, nb),
        in_specs=[
            pl.BlockSpec((1, blk), lambda h, j: (h, j)),
            pl.BlockSpec((1, blk, p), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, blk, s), lambda h, j, r=r: (h // r, j, 0)),
            pl.BlockSpec((1, blk, s), lambda h, j, r=r: (h // r, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk, p), lambda h, j: (h, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n, p), xbar.dtype),
        scratch_shapes=[pltpu.VMEM((s, p), jnp.float32)],
        interpret=interpret,
    )(log_a, xbar, b_in, c_in)
