"""Pallas TPU kernels for the perf-critical attention paths.

Each kernel has a pure-jnp oracle in ref.py; ops.py exposes jit'd wrappers
with custom_vjp and backend dispatch (registry.py: ``auto`` | ``pallas`` |
``scan`` | ``ref`` — one declarative :class:`AttnSpec` per configuration).
"""
from .ops import (block_diag_attention, block_diag_fwd, lln_attention,
                  lln_decode_chunk, lln_diag_attention, lln_prefill,
                  ssd_scan)
from .registry import AttnSpec, BACKENDS, IMPLS, resolve

__all__ = ["lln_attention", "block_diag_attention", "block_diag_fwd",
           "lln_diag_attention", "lln_prefill", "lln_decode_chunk",
           "ssd_scan", "AttnSpec", "BACKENDS", "IMPLS", "resolve"]
