"""Pallas TPU kernels for the perf-critical attention paths.

Each kernel has a pure-jnp oracle in ref.py; ops.py exposes jit'd wrappers
with custom_vjp and interpret-mode dispatch for the CPU container.
"""
from .ops import (block_diag_attention, lln_attention, lln_decode_chunk,
                  lln_diag_attention, lln_prefill, ssd_scan)

__all__ = ["lln_attention", "block_diag_attention",
           "lln_diag_attention", "lln_prefill", "lln_decode_chunk",
           "ssd_scan"]
