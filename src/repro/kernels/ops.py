"""jit'd public wrappers around the Pallas kernels.

Responsibilities:
* layout:  (B, N, H, D) model convention  <->  (B*H, N, D) kernel convention;
* LLN pre-scaling + stabilization:  qs = alpha*q - c_q, ks = beta*k - c_k
  (global per batch*head constants — exactly invariant, see core/lln.py);
* GQA ratio r = H // G threaded to the kernels' BlockSpec index maps
  (repeated KV is never materialized);
* interpret-mode dispatch (CPU container -> interpret=True; TPU -> compiled);
* custom_vjp: kernel forward, chunked-jnp backward (same math, linear
  complexity, robust autodiff).

alpha/beta are calibration constants (moment matching) — non-differentiable
by construction; gradients w.r.t. them are zero.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import lln as core_lln
from repro.core.diag import block_diag_attn as core_diag
from .block_diag import block_diag_pallas
from .lln_attention import (lln_bidir_pallas, lln_causal_pallas,
                            lln_diag_fused_pallas)
from .ssd import ssd_pallas


def _interpret(flag: Optional[bool]) -> bool:
    if flag is not None:
        return flag
    return jax.default_backend() == "cpu"


def _to_kernel(t: jnp.ndarray) -> jnp.ndarray:
    """(B, N, H, D) -> (B*H, N, D)."""
    b, n, h, d = t.shape
    return t.transpose(0, 2, 1, 3).reshape(b * h, n, d)


def _from_kernel(t: jnp.ndarray, b: int) -> jnp.ndarray:
    bh, n, d = t.shape
    return t.reshape(b, bh // b, n, d).transpose(0, 2, 1, 3)


def _scaled_stabilized(q, k, alpha, beta):
    """Return (qs, ks) in kernel layout, fp32-safe exponents."""
    alpha = jax.lax.stop_gradient(jnp.asarray(alpha, jnp.float32))
    beta = jax.lax.stop_gradient(jnp.asarray(beta, jnp.float32))
    if alpha.ndim == 0:
        alpha = jnp.broadcast_to(alpha, (q.shape[2],))
    if beta.ndim == 0:
        beta = jnp.broadcast_to(beta, (k.shape[2],))
    aq = q.astype(jnp.float32) * alpha[None, None, :, None]
    bk = k.astype(jnp.float32) * beta[None, None, :, None]
    c_q = jax.lax.stop_gradient(jnp.max(aq, axis=(1, 3), keepdims=True))
    c_k = jax.lax.stop_gradient(jnp.max(bk, axis=(1, 3), keepdims=True))
    return _to_kernel(aq - c_q), _to_kernel(bk - c_k)


# ---------------------------------------------------------------------------
# LLN attention.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def lln_attention(q, k, v, alpha, beta, causal: bool = True,
                  chunk: int = 256, interpret: Optional[bool] = None):
    """LLN attention via Pallas.  q: (B,N,H,D); k/v: (B,N,G,D[v])."""
    return _lln_fwd_impl(q, k, v, alpha, beta, causal, chunk, interpret)


def _lln_fwd_impl(q, k, v, alpha, beta, causal, chunk, interpret):
    b, n, h, _ = q.shape
    g = k.shape[2]
    if n % chunk:
        return _lln_ref(q, k, v, alpha, beta, causal, chunk)
    qs, ks = _scaled_stabilized(q, k, alpha, beta)
    vk = _to_kernel(v)
    fn = lln_causal_pallas if causal else lln_bidir_pallas
    out = fn(qs, ks, vk, r=h // g, blk=chunk, interpret=_interpret(interpret))
    return _from_kernel(out, b)


def _lln_ref(q, k, v, alpha, beta, causal, chunk):
    h = q.shape[2]
    g = k.shape[2]
    kf = k if g == h else jnp.repeat(k, h // g, axis=2)
    vf = v if g == h else jnp.repeat(v, h // g, axis=2)
    beta = jnp.asarray(beta, jnp.float32)
    if beta.ndim and beta.shape[0] == g and g != h:
        beta = jnp.repeat(beta, h // g)
    if causal:
        return core_lln.lln_causal(q, kf, vf, alpha, beta, chunk=chunk)
    return core_lln.lln_bidir(q, kf, vf, alpha, beta)


def _lln_vjp_fwd(q, k, v, alpha, beta, causal, chunk, interpret):
    out = _lln_fwd_impl(q, k, v, alpha, beta, causal, chunk, interpret)
    return out, (q, k, v, alpha, beta)


def _lln_vjp_bwd(causal, chunk, interpret, res, g_out):
    q, k, v, alpha, beta = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _lln_ref(q_, k_, v_, alpha, beta, causal, chunk),
        q, k, v)
    dq, dk, dv = vjp(g_out)
    zero_a = jnp.zeros_like(jnp.asarray(alpha, jnp.float32))
    zero_b = jnp.zeros_like(jnp.asarray(beta, jnp.float32))
    return dq, dk, dv, zero_a, zero_b


lln_attention.defvjp(_lln_vjp_fwd, _lln_vjp_bwd)


# ---------------------------------------------------------------------------
# Block-diagonal softmax attention.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def block_diag_attention(q, k, v, block: int = 256, causal: bool = False,
                         interpret: Optional[bool] = None):
    """Block-diagonal softmax attention via Pallas. q: (B,N,H,D)."""
    return _diag_fwd_impl(q, k, v, block, causal, interpret)


def _diag_fwd_impl(q, k, v, block, causal, interpret):
    b, n, h, _ = q.shape
    g = k.shape[2]
    if n % block:
        return _diag_ref(q, k, v, block, causal)
    out = block_diag_pallas(_to_kernel(q), _to_kernel(k), _to_kernel(v),
                            r=h // g, blk=block, causal=causal,
                            interpret=_interpret(interpret))
    return _from_kernel(out, b)


def _diag_ref(q, k, v, block, causal):
    h = q.shape[2]
    g = k.shape[2]
    kf = k if g == h else jnp.repeat(k, h // g, axis=2)
    vf = v if g == h else jnp.repeat(v, h // g, axis=2)
    return core_diag(q, kf, vf, block=block, causal=causal)


def _diag_vjp_fwd(q, k, v, block, causal, interpret):
    return _diag_fwd_impl(q, k, v, block, causal, interpret), (q, k, v)


def _diag_vjp_bwd(block, causal, interpret, res, g_out):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: _diag_ref(q_, k_, v_, block, causal),
                     q, k, v)
    return vjp(g_out)


block_diag_attention.defvjp(_diag_vjp_fwd, _diag_vjp_bwd)


# ---------------------------------------------------------------------------
# Fused LLN + Diag (causal): single-pass hybrid, shared block loads.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def lln_diag_attention(q, k, v, alpha, beta, causal: bool = True,
                       block: int = 256, interpret: Optional[bool] = None):
    """0.5 * (LLN + block-diag softmax); fused kernel when causal."""
    return _lln_diag_fwd_impl(q, k, v, alpha, beta, causal, block, interpret)


def _lln_diag_fwd_impl(q, k, v, alpha, beta, causal, block, interpret):
    b, n, h, _ = q.shape
    g = k.shape[2]
    if not causal or n % block:
        lln = _lln_fwd_impl(q, k, v, alpha, beta, causal, block, interpret)
        diag = _diag_fwd_impl(q, k, v, block, causal, interpret)
        return (0.5 * (lln.astype(jnp.float32) + diag.astype(jnp.float32))
                ).astype(v.dtype)
    qs, ks = _scaled_stabilized(q, k, alpha, beta)
    out = lln_diag_fused_pallas(qs, ks, _to_kernel(q), _to_kernel(k),
                                _to_kernel(v), r=h // g, blk=block,
                                causal=True, interpret=_interpret(interpret))
    return _from_kernel(out, b)


def _lln_diag_ref(q, k, v, alpha, beta, causal, block):
    lln = _lln_ref(q, k, v, alpha, beta, causal, block)
    diag = _diag_ref(q, k, v, block, causal)
    return (0.5 * (lln.astype(jnp.float32) + diag.astype(jnp.float32))
            ).astype(v.dtype)


def _lln_diag_vjp_fwd(q, k, v, alpha, beta, causal, block, interpret):
    out = _lln_diag_fwd_impl(q, k, v, alpha, beta, causal, block, interpret)
    return out, (q, k, v, alpha, beta)


def _lln_diag_vjp_bwd(causal, block, interpret, res, g_out):
    q, k, v, alpha, beta = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _lln_diag_ref(q_, k_, v_, alpha, beta, causal,
                                         block), q, k, v)
    dq, dk, dv = vjp(g_out)
    zero_a = jnp.zeros_like(jnp.asarray(alpha, jnp.float32))
    zero_b = jnp.zeros_like(jnp.asarray(beta, jnp.float32))
    return dq, dk, dv, zero_a, zero_b


lln_diag_attention.defvjp(_lln_diag_vjp_fwd, _lln_diag_vjp_bwd)


# ---------------------------------------------------------------------------
# Mamba2 SSD chunked scan.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def ssd_scan(xbar, b_in, c_in, log_a, chunk: int = 256,
             interpret: Optional[bool] = None):
    """SSD via Pallas.  xbar: (B,L,H,P); b_in/c_in: (B,L,G,S);
    log_a: (B,L,H).  Returns y: (B,L,H,P) (no final state — training path;
    prefill uses the jnp ssd_chunked which also returns the state)."""
    return _ssd_fwd_impl(xbar, b_in, c_in, log_a, chunk, interpret)


def _ssd_fwd_impl(xbar, b_in, c_in, log_a, chunk, interpret):
    b, l, h, p_dim = xbar.shape
    g = b_in.shape[2]
    if l % chunk:
        return _ssd_ref(xbar, b_in, c_in, log_a, chunk)
    xk = _to_kernel(xbar)
    bk = _to_kernel(b_in)
    ck = _to_kernel(c_in)
    lk = log_a.transpose(0, 2, 1).reshape(b * h, l)
    out = ssd_pallas(lk, xk, bk, ck, r=h // g, blk=chunk,
                     interpret=_interpret(interpret))
    return _from_kernel(out, b)


def _ssd_ref(xbar, b_in, c_in, log_a, chunk):
    from repro.models.ssm import ssd_chunked
    h, g = xbar.shape[2], b_in.shape[2]
    rep = h // g
    bf = jnp.repeat(b_in, rep, axis=2) if rep > 1 else b_in
    cf = jnp.repeat(c_in, rep, axis=2) if rep > 1 else c_in
    y, _ = ssd_chunked(xbar, bf, cf, log_a, chunk=chunk)
    return y.astype(xbar.dtype)


def _ssd_vjp_fwd(xbar, b_in, c_in, log_a, chunk, interpret):
    return _ssd_fwd_impl(xbar, b_in, c_in, log_a, chunk, interpret), \
        (xbar, b_in, c_in, log_a)


def _ssd_vjp_bwd(chunk, interpret, res, g_out):
    xbar, b_in, c_in, log_a = res
    _, vjp = jax.vjp(
        lambda x, b, c, a: _ssd_ref(x, b, c, a, chunk),
        xbar, b_in, c_in, log_a)
    return vjp(g_out.astype(jnp.float32))


ssd_scan.defvjp(_ssd_vjp_fwd, _ssd_vjp_bwd)
