"""jit'd public wrappers around the Pallas kernels.

Responsibilities:
* layout:  (B, N, H, D) model convention  <->  (B*H, N, D) kernel convention;
* LLN pre-scaling + stabilization:  qs = alpha*q - c_q, ks = beta*k - c_k
  (global per batch*head constants — exactly invariant, see core/lln.py);
* GQA ratio r = H // G threaded to the kernels' BlockSpec index maps
  (repeated KV is never materialized);
* interpret-mode dispatch (CPU container -> interpret=True; TPU -> compiled);
* custom_vjp: Pallas forward AND a fused analytic backward (lln_backward.py
  / block_diag.py).  The forward saves the pre-scaled (qs, ks), the kernel-
  layout v, the output and the per-row normalizer ``den`` as residuals, so
  the backward never recomputes the stabilization constants or the feature
  maps' normalizers; GQA dK/dV is segment-summed over the ``h // r`` index
  map without materializing repeated KV.  On compiled backends the backward
  runs the Pallas kernels; under interpret mode it runs their lax.scan
  twins (same math/residuals — see lln_backward.py docstring).  The legacy
  jax.vjp-through-the-reference backward remains as (a) the fallback for
  ragged sequence lengths (n % chunk != 0, same static dispatch as the
  forward) and (b) an explicit ``pallas_bwd=False`` escape used by
  ``benchmarks/bench_train_step.py`` to measure the speedup.

alpha/beta are calibration constants (moment matching) — non-differentiable
by construction; gradients w.r.t. them are zero.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import lln as core_lln
from repro.core import loglinear as core_loglin
from repro.core.diag import block_diag_attn as core_diag
from . import ref as kref
from . import registry
from .block_diag import block_diag_bwd_pallas, block_diag_pallas
from .lln_attention import (lln_bidir_pallas, lln_causal_pallas,
                            lln_decode_pallas, lln_diag_fused_pallas)
from .loglinear import loglin_causal_pallas
from .lln_backward import (lln_bidir_bwd_pallas, lln_bidir_bwd_scan,
                           lln_causal_bwd_pallas, lln_causal_bwd_scan,
                           lln_diag_fused_bwd_pallas,
                           lln_diag_fused_bwd_scan, block_diag_bwd_scan)
from .ssd import ssd_pallas


def _interpret(flag: Optional[bool]) -> bool:
    if flag is not None:
        return flag
    return jax.default_backend() == "cpu"


def _dispatch(backend: str, interpret: Optional[bool], *, ragged: bool,
              cpu_twin: str, ragged_kind: str = "ref") -> tuple[str, bool]:
    """Resolve (kind, interpret) for one op call.

    ``backend='auto'`` reproduces the historical per-op dispatch (honouring
    the legacy ``interpret=`` override): ragged lengths fall back to
    ``ragged_kind``, interpret mode runs ``cpu_twin``, compiled backends run
    the Pallas kernel.  Explicit backends go through
    :func:`repro.kernels.registry.resolve`.
    """
    if backend == "auto":
        if ragged:
            return ragged_kind, False
        ip = _interpret(interpret)
        return (cpu_twin if ip else "pallas"), ip
    res = registry.resolve(backend, ragged=ragged, cpu_twin=cpu_twin)
    return res.kind, res.interpret


# Interpret-mode Pallas pays a full block copy per grid step, so the fused
# backward dispatches to the lax.scan twins there (same math, same
# residuals); compiled backends run the Pallas kernels.  Tests flip this to
# exercise the kernel path end-to-end on CPU.
FORCE_KERNEL_BWD = False


def _kernel_bwd(interpret: Optional[bool]) -> bool:
    return FORCE_KERNEL_BWD or not _interpret(interpret)


def _to_kernel(t: jnp.ndarray) -> jnp.ndarray:
    """(B, N, H, D) -> (B*H, N, D)."""
    b, n, h, d = t.shape
    return t.transpose(0, 2, 1, 3).reshape(b * h, n, d)


def _from_kernel(t: jnp.ndarray, b: int) -> jnp.ndarray:
    bh, n, d = t.shape
    return t.reshape(b, bh // b, n, d).transpose(0, 2, 1, 3)


def _bcast_heads(p, heads: int) -> jnp.ndarray:
    """Scalar -> (heads,); (heads,) and per-row (B, heads) pass through."""
    p = jax.lax.stop_gradient(jnp.asarray(p, jnp.float32))
    if p.ndim == 0:
        p = jnp.broadcast_to(p, (heads,))
    return p


def _row_head_bcast(p: jnp.ndarray) -> jnp.ndarray:
    """Broadcast (H,) or per-row (B, H) calibration over (B, N, H, D)."""
    return p[:, None, :, None] if p.ndim == 2 else p[None, None, :, None]


def _scaled_stabilized(q, k, alpha, beta, with_const: bool = False):
    """Return (qs, ks) in kernel layout plus the broadcast (alpha, beta);
    fp32-safe exponents.  alpha/beta may be scalar, per-head (H,)/(G,) or
    per-row (B, H)/(B, G) (continuous-batching calibration).  ``with_const``
    appends the key stabilization constant ``c_k`` (B, 1, G, 1) — the
    decode state's reference constant."""
    alpha = _bcast_heads(alpha, q.shape[2])
    beta = _bcast_heads(beta, k.shape[2])
    aq = q.astype(jnp.float32) * _row_head_bcast(alpha)
    bk = k.astype(jnp.float32) * _row_head_bcast(beta)
    c_q = jax.lax.stop_gradient(jnp.max(aq, axis=(1, 3), keepdims=True))
    c_k = jax.lax.stop_gradient(jnp.max(bk, axis=(1, 3), keepdims=True))
    out = (_to_kernel(aq - c_q), _to_kernel(bk - c_k), alpha, beta)
    return out + (c_k,) if with_const else out


def _dtype_tag(t: jnp.ndarray) -> jnp.ndarray:
    """Zero-size carrier so the backward can recover a primal dtype from
    residuals (residual leaves must be arrays, not dtypes)."""
    return jnp.zeros((0,), t.dtype)


def _zero_ab(alpha, beta):
    zero_a = jnp.zeros_like(jnp.asarray(alpha, jnp.float32))
    zero_b = jnp.zeros_like(jnp.asarray(beta, jnp.float32))
    return zero_a, zero_b


# ---------------------------------------------------------------------------
# LLN attention.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def lln_attention(q, k, v, alpha, beta, causal: bool = True,
                  chunk: int = 256, interpret: Optional[bool] = None,
                  pallas_bwd: bool = True, backend: str = "auto"):
    """LLN attention (paper eq. 8) via Pallas — the training entry point.

    Args:
      q: (B, N, H, D); k/v: (B, N, G, D[v]) with G | H — GQA ratio
        ``r = H // G`` is threaded to the kernels' BlockSpec index maps, so
        repeated KV is never materialized.  Any float dtype; output is
        ``v.dtype``, internal exponents/accumulators fp32.
      alpha/beta: moment-matching calibration, scalar or per-head
        ((H,) / (G,)); non-differentiable by construction (zero gradients).
      chunk: block size of the causal scan; ``N % chunk != 0`` falls back
        to the jnp reference (``core.lln``) — same math, ragged-safe.

    Backend: ``backend='auto'`` (the default) keeps the historical
    dispatch — compiled (TPU) runs the Pallas forward and, under
    ``custom_vjp``, the fused Pallas backward (kernels/lln_backward.py);
    interpret mode (CPU container) runs the forward kernel interpreted and
    the backward's chunked ``lax.scan`` twins.  Explicit
    ``backend='pallas' | 'scan' | 'ref'`` forces the Pallas kernel
    (interpreted on CPU), the core chunked-scan reference, or the quadratic
    oracle (kernels/ref.py) respectively — see kernels/registry.py.
    ``pallas_bwd=False`` forces the chunked-jnp reference backward (the
    pre-fused behaviour) — kept for benchmarking and debugging.
    """
    return _lln_fwd_impl(q, k, v, alpha, beta, causal, chunk, interpret,
                         backend)


def _lln_fwd_impl(q, k, v, alpha, beta, causal, chunk, interpret,
                  backend="auto"):
    b, n, h, _ = q.shape
    g = k.shape[2]
    # The historical ragged fallback IS the core chunked scan ("scan").
    kind, ip = _dispatch(backend, interpret, ragged=bool(n % chunk),
                         cpu_twin="pallas", ragged_kind="scan")
    if kind == "scan":
        return _lln_ref(q, k, v, alpha, beta, causal, chunk)
    if kind == "ref":
        return _lln_quad_ref(q, k, v, alpha, beta, causal)
    qs, ks, _, _ = _scaled_stabilized(q, k, alpha, beta)
    vk = _to_kernel(v)
    fn = lln_causal_pallas if causal else lln_bidir_pallas
    out = fn(qs, ks, vk, r=h // g, blk=chunk, interpret=ip)
    return _from_kernel(out, b)


def _lln_ref(q, k, v, alpha, beta, causal, chunk):
    h = q.shape[2]
    g = k.shape[2]
    kf = k if g == h else jnp.repeat(k, h // g, axis=2)
    vf = v if g == h else jnp.repeat(v, h // g, axis=2)
    beta = jnp.asarray(beta, jnp.float32)
    if beta.ndim and beta.shape[-1] == g and g != h:
        beta = jnp.repeat(beta, h // g, axis=-1)
    if causal:
        out = core_lln.lln_causal(q, kf, vf, alpha, beta, chunk=chunk)
    else:
        out = core_lln.lln_bidir(q, kf, vf, alpha, beta)
    # The Pallas path emits v.dtype; pin the fallback to the same so jit'd
    # callers don't recompile (or silently upcast) with the sequence length.
    return out.astype(v.dtype)


def _lln_quad_ref(q, k, v, alpha, beta, causal):
    """Quadratic-form oracle (kernels/ref.py) — the ``backend='ref'``
    target for the training forward: materializes the full (masked) score
    matrix, O(N^2) memory."""
    b, _, h, _ = q.shape
    g = k.shape[2]
    qs, ks, _, _ = _scaled_stabilized(q, k, alpha, beta)
    vk = _to_kernel(v)
    fn = kref.lln_causal_ref if causal else kref.lln_bidir_ref
    return _from_kernel(fn(qs, ks, vk, r=h // g), b).astype(v.dtype)


def _lln_vjp_fwd(q, k, v, alpha, beta, causal, chunk, interpret, pallas_bwd,
                 backend="auto"):
    n, h = q.shape[1], q.shape[2]
    g = k.shape[2]
    if n % chunk or not pallas_bwd or backend in ("scan", "ref"):
        out = _lln_fwd_impl(q, k, v, alpha, beta, causal, chunk, interpret,
                            backend)
        return out, {"ref": (q, k, v, alpha, beta)}
    b = q.shape[0]
    qs, ks, alpha_b, beta_b = _scaled_stabilized(q, k, alpha, beta)
    vk = _to_kernel(v)
    ip = True if backend == "pallas" and registry.on_cpu() \
        else _interpret(interpret)
    if causal:
        out_k, den = lln_causal_pallas(qs, ks, vk, r=h // g, blk=chunk,
                                       interpret=ip, return_res=True)
        s = z = None
    else:
        out_k, s, z, den = lln_bidir_pallas(qs, ks, vk, r=h // g, blk=chunk,
                                            interpret=ip, return_res=True)
    res = {"pallas": (qs, ks, vk, out_k, den, s, z, alpha_b, beta_b,
                      _dtype_tag(q), _dtype_tag(k), _dtype_tag(v),
                      jnp.asarray(alpha, jnp.float32),
                      jnp.asarray(beta, jnp.float32))}
    return _from_kernel(out_k, b), res


def _lln_vjp_bwd(causal, chunk, interpret, pallas_bwd, backend, res, g_out):
    if "ref" in res:
        q, k, v, alpha, beta = res["ref"]
        _, vjp = jax.vjp(
            lambda q_, k_, v_: _lln_ref(q_, k_, v_, alpha, beta, causal,
                                        chunk), q, k, v)
        dq, dk, dv = vjp(g_out)
        return (dq, dk, dv) + _zero_ab(alpha, beta)
    (qs, ks, vk, out_k, den, s, z, alpha_b, beta_b,
     tq, tk, tv, alpha0, beta0) = res["pallas"]
    b = g_out.shape[0]
    r = (qs.shape[0] // b) // (ks.shape[0] // b)
    gk = _to_kernel(g_out)
    ip = _interpret(interpret)
    if causal:
        if _kernel_bwd(interpret):
            dqs, dks, dvk = lln_causal_bwd_pallas(qs, ks, vk, gk, out_k,
                                                  den, r=r, blk=chunk,
                                                  interpret=ip)
        else:
            dqs, dks, dvk = lln_causal_bwd_scan(qs, ks, vk, gk, out_k, den,
                                                r=r, blk=chunk)
    else:
        if _kernel_bwd(interpret):
            dqs, dks, dvk = lln_bidir_bwd_pallas(qs, ks, vk, gk, out_k, den,
                                                 s, z, r=r, blk=chunk,
                                                 interpret=ip)
        else:
            dqs, dks, dvk = lln_bidir_bwd_scan(qs, ks, vk, gk, out_k, den,
                                               s, z, r=r, blk=chunk)
    # Chain rule through qs = alpha*q - stop_grad(c_q) (and same for k);
    # _row_head_bcast handles per-head (H,) and per-row (B, H) calibration.
    dq = (_from_kernel(dqs, b) * _row_head_bcast(alpha_b)).astype(tq.dtype)
    dk = (_from_kernel(dks, b) * _row_head_bcast(beta_b)).astype(tk.dtype)
    dv = _from_kernel(dvk, b).astype(tv.dtype)
    return dq, dk, dv, jnp.zeros_like(alpha0), jnp.zeros_like(beta0)


lln_attention.defvjp(_lln_vjp_fwd, _lln_vjp_bwd)


# ---------------------------------------------------------------------------
# Serving entry points: state-emitting prefill + chunked multi-token decode.
# Inference-only (no custom_vjp); same three-way dispatch as the training
# forward: Pallas on compiled backends, chunked lax.scan twin under
# interpret mode (CPU container), jnp reference for ragged lengths.
# ---------------------------------------------------------------------------

def lln_prefill(q, k, v, alpha, beta, chunk: int = 256,
                interpret: Optional[bool] = None, backend: str = "auto"):
    """Causal LLN prefill emitting outputs AND the decode state in one pass.

    q: (B,N,H,D); k/v: (B,N,G,D[v]) — GQA via the kernels' ``h // r`` index
    maps, repeated KV never materialized.  Returns ``(out, s, z, c_k)``:
    out (B,N,H,Dv); s (B,H,D,Dv) fp32; z (B,H,D) fp32; c_k (B,1,H,1) fp32 —
    exactly the ``core.lln.LLNState`` layout the decode cache stores (state
    per query head: GQA groups share values, matching the H-head cache).

    ``backend``: ``auto`` (historical dispatch — Pallas compiled, scan twin
    on CPU, jnp reference for ragged lengths) | ``pallas`` | ``scan`` |
    ``ref`` (the seed two-pass jnp path, ``core/lln.py:prefill``).
    """
    b, n, h, _ = q.shape
    g = k.shape[2]
    kind, ip = _dispatch(backend, interpret, ragged=bool(n % chunk),
                         cpu_twin="scan")
    if kind == "ref":
        return _lln_prefill_ref(q, k, v, alpha, beta, chunk)
    qs, ks, _, _, c_k = _scaled_stabilized(q, k, alpha, beta, with_const=True)
    vk = _to_kernel(v)
    if kind == "scan":
        out_k, s, z = _lln_prefill_scan(qs, ks, vk, r=h // g, blk=chunk)
    else:
        out_k, s, z = lln_causal_pallas(qs, ks, vk, r=h // g, blk=chunk,
                                        interpret=ip, return_state=True)
    s = s.reshape(b, h, *s.shape[1:])                  # (B, H, D, Dv)
    z = z.reshape(b, h, z.shape[-1])                   # (B, H, D)
    c_kh = jnp.repeat(c_k, h // g, axis=2) if g != h else c_k
    return _from_kernel(out_k, b), s, z, c_kh


def _lln_prefill_ref(q, k, v, alpha, beta, chunk):
    """Ragged-length fallback: the jnp causal scan (whose final carry is the
    state — see core/lln.py:prefill) over repeated KV."""
    h, g = q.shape[2], k.shape[2]
    kf = k if g == h else jnp.repeat(k, h // g, axis=2)
    vf = v if g == h else jnp.repeat(v, h // g, axis=2)
    beta = jnp.asarray(beta, jnp.float32)
    if beta.ndim and beta.shape[-1] == g and g != h:
        beta = jnp.repeat(beta, h // g, axis=-1)
    out, st = core_lln.prefill(q, kf, vf, alpha, beta, chunk=chunk)
    return out.astype(v.dtype), st.s, st.z, st.c_k


def _lln_prefill_scan(qs, ks, vk, *, r: int, blk: int):
    """Chunked lax.scan twin of the state-emitting causal kernel (kernel
    layout, GQA via a (BG, R) head split — no repeated KV)."""
    bh, n, d = qs.shape
    bg, dv = ks.shape[0], vk.shape[-1]
    nc = n // blk
    fq = jnp.exp(qs.astype(jnp.float32)).reshape(bg, r, nc, blk, d) \
        .transpose(2, 0, 1, 3, 4)                      # (nc, BG, R, blk, D)
    fk = jnp.exp(ks.astype(jnp.float32)).reshape(bg, nc, blk, d) \
        .transpose(1, 0, 2, 3)                         # (nc, BG, blk, D)
    vf = vk.astype(jnp.float32).reshape(bg, nc, blk, dv).transpose(1, 0, 2, 3)
    causal = jnp.tril(jnp.ones((blk, blk), jnp.float32))

    def step(carry, xs):
        s, z = carry                                   # (BG,D,Dv), (BG,D)
        cq, ck, cv = xs
        scores = jnp.einsum("grid,gjd->grij", cq, ck) * causal
        intra = jnp.einsum("grij,gjv->griv", scores, cv)
        intra_z = jnp.sum(scores, axis=-1)
        inter = jnp.einsum("grid,gdv->griv", cq, s)
        inter_z = jnp.einsum("grid,gd->gri", cq, z)
        out = (intra + inter) / (intra_z + inter_z + 1e-6)[..., None]
        s = s + jnp.einsum("gjd,gjv->gdv", ck, cv)
        z = z + jnp.sum(ck, axis=1)
        return (s, z), out

    s0 = jnp.zeros((bg, d, dv), jnp.float32)
    z0 = jnp.zeros((bg, d), jnp.float32)
    (s, z), out = jax.lax.scan(step, (s0, z0), (fq, fk, vf))
    out = out.transpose(1, 2, 0, 3, 4).reshape(bh, n, dv).astype(vk.dtype)
    s = jnp.repeat(s, r, axis=0) if r != 1 else s      # group state -> H rows
    z = jnp.repeat(z, r, axis=0) if r != 1 else z
    return out, s, z[:, None, :]


def block_diag_fwd(q, k, v, block: int = 256, causal: bool = True,
                   interpret: Optional[bool] = None, backend: str = "auto"):
    """Inference-only block-diagonal softmax with the serving dispatch:
    Pallas kernel on compiled backends, a GQA-aware grouped-einsum twin
    under interpret mode (no repeated KV either way), jnp reference for
    ragged lengths; explicit ``backend=pallas|scan|ref`` forces one path
    (kernels/registry.py).  Training keeps the ``block_diag_attention``
    custom_vjp entry; this is the prefill path of the §4.2 hybrid."""
    b, n, h, _ = q.shape
    g = k.shape[2]
    kind, ip = _dispatch(backend, interpret, ragged=bool(n % block),
                         cpu_twin="scan")
    if kind == "ref":
        return _diag_ref(q, k, v, block, causal)
    if kind == "scan":
        return _block_diag_twin(q, k, v, block, causal)
    out = block_diag_pallas(_to_kernel(q), _to_kernel(k), _to_kernel(v),
                            r=h // g, blk=block, causal=causal,
                            interpret=ip)
    return _from_kernel(out, b)


def _block_diag_twin(q, k, v, block, causal):
    """Grouped-einsum block-diag softmax: heads split (G, R) so the R query
    heads sharing a kv head contract against it directly."""
    b, n, h, d = q.shape
    g, dv = k.shape[2], v.shape[-1]
    r = h // g
    nb = n // block
    scale = d ** -0.5
    qb = q.reshape(b, nb, block, g, r, d).astype(jnp.float32) * scale
    kb = k.reshape(b, nb, block, g, d).astype(jnp.float32)
    vb = v.reshape(b, nb, block, g, dv).astype(jnp.float32)
    s = jnp.einsum("bnigrd,bnjgd->bngrij", qb, kb)
    if causal:
        tri = jnp.tril(jnp.ones((block, block), jnp.bool_))
        s = jnp.where(tri[None, None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bngrij,bnjgv->bnigrv", p, vb)
    return out.reshape(b, n, h, dv).astype(v.dtype)


def lln_decode_chunk(state, q, k, v, alpha, beta,
                     interpret: Optional[bool] = None,
                     row_mask: Optional[jnp.ndarray] = None,
                     backend: str = "auto",
                     commit_len: Optional[jnp.ndarray] = None,
                     renorm: Optional[float] = None):
    """Advance an ``LLNState`` over T new tokens in one dispatch.

    Args:
      state: ``core.lln.LLNState`` — ``s`` (B,H,D,Dv) fp32, ``z`` (B,H,D)
        fp32, ``c_k`` (B,1,H,1) fp32 reference stabilization constant.
      q: (B,T,H,D); k/v: (B,T,G,D[v]) — any dtype (cast to fp32 inside);
        GQA ratio ``r = H // G``: the kernel contracts each query head
        against its group's kv head via the grid index map, repeated KV is
        never materialized (compiled path).
      alpha/beta: calibration constants — scalar, per-head (H,)/(G,), or
        per-row (B, H)/(B, G) for continuous batching.  An (H,)-shaped beta
        that is not a group-uniform repeat is group-mean-pooled to (G,)
        (the ``batch_alpha_beta`` convention) identically on every backend.
      row_mask: optional (B,) bool — rows where it is False keep their old
        ``(s, z, c_k)`` exactly (masked rows must not advance state; their
        outputs are garbage and must be discarded by the caller).
      commit_len: optional per-row (B,) int32 in [0, T] — the speculative
        partial-commit contract: all T positions are scored, but only
        tokens ``j < commit_len[b]`` fold into ``(s, z, c_k)`` (the
        reference constant advances over committed keys only;
        ``commit_len=0`` ≡ ``row_mask=False``, ``commit_len=T`` ≡ a plain
        decode).  On the Pallas path the kernel still scores the full
        chunk; the committed fold is the cheap O(T d^2) jnp einsum below.
      renorm: optional drift-renormalization threshold on the carried
        ``max_d z`` magnitude (``core.lln.decode_chunk``).  Applied with
        identical semantics on every backend: the non-Pallas twins get it
        from the core, the Pallas path applies the same group-level shift
        to its folded state below.  Never fires for masked or
        ``commit_len=0`` rows.

    Returns ``(out (B,T,H,Dv) in v.dtype, new LLNState)``.

    Backend dispatch: one Pallas kernel launch (grid over B*H, T padded to
    a sublane multiple with NEG_INF keys so padded Phi(k) = 0) after a
    single group-level max-rescale of the carried state on compiled
    backends; the jnp twin ``core.lln.decode_chunk`` under interpret mode
    (the CPU container).  Both equal T sequential ``decode_step`` calls.
    ``backend='pallas'`` forces the kernel (interpreted on CPU);
    ``'scan'``/``'ref'`` force the jnp twin (they coincide for decode —
    the twin IS the reference).
    """
    from repro.core.lln import LLNState

    b, t, h, d = q.shape
    g = k.shape[2]
    kind, ip = _dispatch(backend, interpret, ragged=False, cpu_twin="ref")
    # Per-G-head beta shared by BOTH dispatch branches: an (H,)/(B,H) beta
    # that is not a group-uniform repeat is group-mean-pooled (the
    # batch_alpha_beta convention, cf. multi_head_attention) — identically
    # on every backend.
    beta_b = jnp.asarray(beta, jnp.float32)
    if beta_b.ndim and beta_b.shape[-1] == h and g != h:
        beta_b = beta_b.reshape(beta_b.shape[:-1] + (g, h // g)).mean(axis=-1)
    beta_b = _bcast_heads(beta_b, g)
    if kind != "pallas":
        kf = k if g == h else jnp.repeat(k, h // g, axis=2)
        vf = v if g == h else jnp.repeat(v, h // g, axis=2)
        beta_h = jnp.repeat(beta_b, h // g, axis=-1) if g != h else beta_b
        return core_lln.decode_chunk(state, q, kf, vf, alpha, beta_h,
                                     row_mask=row_mask,
                                     commit_len=commit_len,
                                     renorm=renorm)
    alpha_b = _bcast_heads(alpha, h)
    aq = q.astype(jnp.float32) * _row_head_bcast(alpha_b)
    bk = k.astype(jnp.float32) * _row_head_bcast(beta_b)
    c_q = jax.lax.stop_gradient(jnp.max(aq, axis=(1, 3), keepdims=True))
    # Group-level new reference constant: max of the group's carried c_k and
    # the chunk keys; each query head rescales from its own old constant.
    r = h // g
    c_old_g = jnp.max(state.c_k.reshape(b, 1, g, r, 1), axis=3)
    c_bk = jax.lax.stop_gradient(jnp.max(bk, axis=(1, 3), keepdims=True))
    c_new_g = jnp.maximum(c_old_g, c_bk)               # (B,1,G,1)
    c_new_h = jnp.repeat(c_new_g, r, axis=2) if r != 1 else c_new_g
    rescale = jnp.exp(state.c_k - c_new_h)[:, 0, :, 0]  # (B,H)
    s0 = (state.s * rescale[..., None, None]).reshape(b * h, d, -1)
    z0 = (state.z * rescale[..., None]).reshape(b * h, 1, d)

    # Pad T to a sublane multiple; padded keys at NEG_INF => Phi(k) = 0.
    tp = -(-t // 16) * 16
    qs = _to_kernel(aq - c_q)
    ks = _to_kernel(bk - c_new_g)
    vk = _to_kernel(v)
    if tp != t:
        qs = jnp.pad(qs, ((0, 0), (0, tp - t), (0, 0)))
        ks = jnp.pad(ks, ((0, 0), (0, tp - t), (0, 0)),
                     constant_values=-1e30)
        vk = jnp.pad(vk, ((0, 0), (0, tp - t), (0, 0)))
    out_k, s1, z1 = lln_decode_pallas(qs, ks, vk, s0, z0, r=r,
                                      interpret=ip)
    out = _from_kernel(out_k[:, :t], b)
    if commit_len is not None:
        # Partial commit: the kernel scored the full chunk (and its s1/z1
        # folded every key — discarded); refold only the accepted prefix,
        # with the reference constant advanced over committed keys only.
        cl = core_lln.commit_lengths(commit_len, row_mask, t)
        cmask = jnp.arange(t)[None, :] < cl[:, None]             # (B, T)
        bk_c = jnp.where(cmask[:, :, None, None], bk, -jnp.inf)
        c_com_g = jnp.maximum(c_old_g, jax.lax.stop_gradient(
            jnp.max(bk_c, axis=(1, 3), keepdims=True)))          # (B,1,G,1)
        c_com_h = jnp.repeat(c_com_g, r, axis=2) if r != 1 else c_com_g
        resc = jnp.exp(state.c_k - c_com_h)[:, 0, :, 0]          # (B,H)
        fk_c = jnp.exp(bk_c - c_com_g)                # (B,T,G,D), 0 beyond
        add_s = jnp.einsum("bjgd,bjgv->bgdv", fk_c, v.astype(jnp.float32))
        add_z = jnp.sum(fk_c, axis=1)                            # (B,G,D)
        if r != 1:
            add_s = jnp.repeat(add_s, r, axis=1)
            add_z = jnp.repeat(add_z, r, axis=1)
        s_new = state.s * resc[..., None, None] + add_s
        z_new = state.z * resc[..., None] + add_z
        c_new_h = c_com_h
    else:
        s_new = s1.reshape(b, h, d, -1)
        z_new = z1.reshape(b, h, d)
    log_scale = state.log_scale
    if renorm is not None and renorm > 0.0:
        # Same drift renorm as core.lln.decode_chunk: raise the reference
        # constant by delta = ln(max_d z) past the threshold, scale (s, z)
        # by exp(-delta).  Gated on rows that folded at least one token.
        zmax = jax.lax.stop_gradient(jnp.max(z_new, axis=-1))    # (B,H)
        if commit_len is not None:
            folded = (cl > 0)[:, None]
        elif row_mask is not None:
            folded = row_mask[:, None]
        else:
            folded = jnp.ones((b, 1), bool)
        delta = jnp.where(folded & (zmax > renorm),
                          jnp.log(jnp.maximum(zmax, 1e-6)), 0.0)
        scale = jnp.exp(-delta)
        s_new = s_new * scale[..., None, None]
        z_new = z_new * scale[..., None]
        c_new_h = c_new_h + delta[:, None, :, None]
        if log_scale is not None:
            log_scale = log_scale + delta
    if row_mask is not None:
        keep = row_mask
        s_new = jnp.where(keep[:, None, None, None], s_new, state.s)
        z_new = jnp.where(keep[:, None, None], z_new, state.z)
        c_new_h = jnp.where(keep[:, None, None, None], c_new_h, state.c_k)
        if log_scale is not None:
            log_scale = jnp.where(keep[:, None], log_scale, state.log_scale)
    return out, LLNState(s=s_new, z=z_new, c_k=c_new_h,
                         log_scale=log_scale)


def lln_commit_chunk(state, k, v, beta,
                     interpret: Optional[bool] = None,
                     row_mask: Optional[jnp.ndarray] = None,
                     backend: str = "auto",
                     commit_len: Optional[jnp.ndarray] = None,
                     renorm: Optional[float] = None):
    """Fold a chunk's accepted prefix into an ``LLNState`` without scoring.

    The commit half of :func:`lln_decode_chunk` — the single-pass
    speculative-verify primitive.  A ``commit_len=0`` verify pass scores
    the draft chunk and leaves the state untouched; this folds the
    accepted prefix from the (k, v) residuals with the cheap O(T d^2)
    einsum, bit-identical per backend to re-running
    :func:`lln_decode_chunk` with the final ``commit_len`` (the pallas
    kind runs the same group-level jnp fold the kernel path uses; scan/ref
    run the jnp core twin at H heads).  k/v: (B,T,G,D[v]); beta as in
    :func:`lln_decode_chunk`.  Returns the new ``LLNState``.
    """
    from repro.core.lln import LLNState

    b, t, g, _ = k.shape
    h = state.s.shape[1]
    kind, _ = _dispatch(backend, interpret, ragged=False, cpu_twin="ref")
    beta_b = jnp.asarray(beta, jnp.float32)
    if beta_b.ndim and beta_b.shape[-1] == h and g != h:
        beta_b = beta_b.reshape(beta_b.shape[:-1] + (g, h // g)).mean(axis=-1)
    beta_b = _bcast_heads(beta_b, g)
    if kind != "pallas":
        kf = k if g == h else jnp.repeat(k, h // g, axis=2)
        vf = v if g == h else jnp.repeat(v, h // g, axis=2)
        beta_h = jnp.repeat(beta_b, h // g, axis=-1) if g != h else beta_b
        return core_lln.commit_chunk(state, kf, vf, beta_h,
                                     row_mask=row_mask,
                                     commit_len=commit_len, renorm=renorm)
    r = h // g
    bk = k.astype(jnp.float32) * _row_head_bcast(beta_b)
    c_old_g = jnp.max(state.c_k.reshape(b, 1, g, r, 1), axis=3)
    cl = core_lln.commit_lengths(
        commit_len if commit_len is not None
        else jnp.full((b,), t, jnp.int32), row_mask, t)
    cmask = jnp.arange(t)[None, :] < cl[:, None]                 # (B, T)
    bk_c = jnp.where(cmask[:, :, None, None], bk, -jnp.inf)
    c_com_g = jnp.maximum(c_old_g, jax.lax.stop_gradient(
        jnp.max(bk_c, axis=(1, 3), keepdims=True)))              # (B,1,G,1)
    c_com_h = jnp.repeat(c_com_g, r, axis=2) if r != 1 else c_com_g
    resc = jnp.exp(state.c_k - c_com_h)[:, 0, :, 0]              # (B,H)
    fk_c = jnp.exp(bk_c - c_com_g)                    # (B,T,G,D), 0 beyond
    add_s = jnp.einsum("bjgd,bjgv->bgdv", fk_c, v.astype(jnp.float32))
    add_z = jnp.sum(fk_c, axis=1)                                # (B,G,D)
    if r != 1:
        add_s = jnp.repeat(add_s, r, axis=1)
        add_z = jnp.repeat(add_z, r, axis=1)
    s_new = state.s * resc[..., None, None] + add_s
    z_new = state.z * resc[..., None] + add_z
    c_new_h = c_com_h
    log_scale = state.log_scale
    if renorm is not None and renorm > 0.0:
        zmax = jax.lax.stop_gradient(jnp.max(z_new, axis=-1))    # (B,H)
        folded = (cl > 0)[:, None]
        delta = jnp.where(folded & (zmax > renorm),
                          jnp.log(jnp.maximum(zmax, 1e-6)), 0.0)
        scale = jnp.exp(-delta)
        s_new = s_new * scale[..., None, None]
        z_new = z_new * scale[..., None]
        c_new_h = c_new_h + delta[:, None, :, None]
        if log_scale is not None:
            log_scale = log_scale + delta
    if row_mask is not None:
        keep = row_mask
        s_new = jnp.where(keep[:, None, None, None], s_new, state.s)
        z_new = jnp.where(keep[:, None, None], z_new, state.z)
        c_new_h = jnp.where(keep[:, None, None, None], c_new_h, state.c_k)
        if log_scale is not None:
            log_scale = jnp.where(keep[:, None], log_scale, state.log_scale)
    return LLNState(s=s_new, z=z_new, c_k=c_new_h, log_scale=log_scale)


# ---------------------------------------------------------------------------
# Log-linear (Fenwick multi-scale) LLN: full-sequence forward, state-
# emitting prefill and chunked decode/commit.  Inference-only entry points
# (the serving path); the scan/ref kinds are pure jnp and autodiff-able.
# ---------------------------------------------------------------------------

def _loglin_repeat(q, k, v, beta):
    """Model-layout fallback prep: repeated KV + (H,)-shaped beta."""
    h, g = q.shape[2], k.shape[2]
    kf = k if g == h else jnp.repeat(k, h // g, axis=2)
    vf = v if g == h else jnp.repeat(v, h // g, axis=2)
    beta = jnp.asarray(beta, jnp.float32)
    if beta.ndim and beta.shape[-1] == g and g != h:
        beta = jnp.repeat(beta, h // g, axis=-1)
    return kf, vf, beta


def loglin_attention(q, k, v, alpha, beta, causal: bool = True,
                     chunk: int = 256, num_scales: int = 4,
                     scale_decay: float = 0.5,
                     interpret: Optional[bool] = None,
                     backend: str = "auto"):
    """Full-sequence log-linear LLN attention (causal-only).

    Each query mixes a causal intra-granule term (weight 1) with the
    Fenwick bucket pyramid of its prefix: the granule holding key ``j``
    sits at level ``l`` of the pyramid at query time and scores at weight
    ``scale_decay ** l`` (see ``core/loglinear.py``).  ``num_scales=1``
    or ``scale_decay=1`` reduce exactly to plain :func:`lln_attention`.

    Dispatch: Pallas kernel (``kernels/loglinear.py``) on compiled
    backends; the core granule-``lax.scan`` under ``scan`` / interpret
    mode; the quadratic jnp oracle under ``ref`` (and for ragged
    lengths).
    """
    if not causal:
        raise ValueError("log_linear attention is causal-only")
    b, n, h, _ = q.shape
    g = k.shape[2]
    kind, ip = _dispatch(backend, interpret, ragged=bool(n % chunk),
                         cpu_twin="scan")
    if kind in ("ref", "scan"):
        kf, vf, beta_h = _loglin_repeat(q, k, v, beta)
        if kind == "ref":
            out = core_loglin.loglin_attention_ref(
                q, kf, vf, alpha, beta_h, granule=chunk,
                num_scales=num_scales, scale_decay=scale_decay)
        else:
            out, _ = core_loglin.prefill(
                q, kf, vf, alpha, beta_h, granule=chunk,
                num_scales=num_scales, scale_decay=scale_decay)
        return out.astype(v.dtype)
    qs, ks, _, _ = _scaled_stabilized(q, k, alpha, beta)
    out = loglin_causal_pallas(qs, ks, _to_kernel(v),
                               num_scales=num_scales,
                               scale_decay=scale_decay, r=h // g,
                               blk=chunk, interpret=ip)
    return _from_kernel(out, b)


def loglin_prefill(q, k, v, alpha, beta, chunk: int = 256,
                   num_scales: int = 4, scale_decay: float = 0.5,
                   interpret: Optional[bool] = None,
                   backend: str = "auto"):
    """Causal log-linear prefill emitting outputs AND the multi-scale
    decode state in one pass.

    Returns ``(out, s, z, c_k, sl, zl, cl)``: the open-bucket LLN state
    (``s``/``z``/``c_k`` exactly as :func:`lln_prefill` — holding the
    ragged tail past the last closed granule, empty for aligned N) plus
    the Fenwick bucket pyramid ``sl`` (B,L,H,D,Dv), ``zl`` (B,L,H,D),
    ``cl`` (B,L,H) fp32 — the ``core.loglinear.LogLinState`` layout.
    On the kernel/scan paths every bucket shares the global reference
    constant, so ``cl`` is the broadcast ``c_k``.
    """
    b, n, h, d = q.shape
    g, dv = k.shape[2], v.shape[-1]
    ls = num_scales
    kind, ip = _dispatch(backend, interpret, ragged=bool(n % chunk),
                         cpu_twin="scan")
    if kind == "ref":
        kf, vf, beta_h = _loglin_repeat(q, k, v, beta)
        out, st = core_loglin.prefill(q, kf, vf, alpha, beta_h,
                                      granule=chunk, num_scales=ls,
                                      scale_decay=scale_decay)
        return (out.astype(v.dtype), st.s, st.z, st.c_k,
                st.sl, st.zl, st.cl)
    qs, ks, _, _, c_k = _scaled_stabilized(q, k, alpha, beta,
                                           with_const=True)
    vk = _to_kernel(v)
    if kind == "scan":
        out_k, sl, zl = _loglin_prefill_scan(
            qs, ks, vk, r=h // g, blk=chunk, num_scales=ls,
            scale_decay=scale_decay)
    else:
        out_k, sl, zl = loglin_causal_pallas(
            qs, ks, vk, num_scales=ls, scale_decay=scale_decay,
            r=h // g, blk=chunk, interpret=ip, return_state=True)
        zl = zl[:, :, 0, :]                            # (BH, L, D)
    sl = sl.reshape(b, h, ls, d, dv).transpose(0, 2, 1, 3, 4)
    zl = zl.reshape(b, h, ls, d).transpose(0, 2, 1, 3)
    c_kh = jnp.repeat(c_k, h // g, axis=2) if g != h else c_k
    cl = jnp.broadcast_to(c_kh[:, 0, :, 0][:, None, :], (b, ls, h))
    s = jnp.zeros((b, h, d, dv), jnp.float32)
    z = jnp.zeros((b, h, d), jnp.float32)
    return _from_kernel(out_k, b), s, z, c_kh, sl, zl, cl


def _loglin_prefill_scan(qs, ks, vk, *, r: int, blk: int, num_scales: int,
                         scale_decay: float):
    """Chunked lax.scan twin of the state-emitting log-linear kernel
    (kernel layout, GQA via the (BG, R) head split — no repeated KV).
    All buckets share the global pre-stabilized reference, so the
    Fenwick carry-merge is pure adds and merged-out levels are zeroed."""
    bh, n, d = qs.shape
    bg, dv = ks.shape[0], vk.shape[-1]
    nc = n // blk
    ls = num_scales
    wv = jnp.asarray([float(scale_decay) ** l for l in range(ls)],
                     jnp.float32)
    fq = jnp.exp(qs.astype(jnp.float32)).reshape(bg, r, nc, blk, d) \
        .transpose(2, 0, 1, 3, 4)                      # (nc, BG, R, blk, D)
    fk = jnp.exp(ks.astype(jnp.float32)).reshape(bg, nc, blk, d) \
        .transpose(1, 0, 2, 3)                         # (nc, BG, blk, D)
    vf = vk.astype(jnp.float32).reshape(bg, nc, blk, dv).transpose(1, 0, 2, 3)
    causal = jnp.tril(jnp.ones((blk, blk), jnp.float32))

    def step(carry, xs):
        sl, zl = carry                                 # (BG,L,D,Dv),(BG,L,D)
        i, cq, ck, cv = xs
        s_eff = jnp.einsum("l,gldv->gdv", wv, sl)
        z_eff = jnp.einsum("l,gld->gd", wv, zl)
        scores = jnp.einsum("grid,gjd->grij", cq, ck) * causal
        intra = jnp.einsum("grij,gjv->griv", scores, cv)
        intra_z = jnp.sum(scores, axis=-1)
        inter = jnp.einsum("grid,gdv->griv", cq, s_eff)
        inter_z = jnp.einsum("grid,gd->gri", cq, z_eff)
        out = (intra + inter) / (intra_z + inter_z + 1e-6)[..., None]
        c_s = jnp.einsum("gjd,gjv->gdv", ck, cv)
        c_z = jnp.sum(ck, axis=1)
        for l in range(ls - 1):
            reach = (i & ((1 << l) - 1)) == ((1 << l) - 1)
            bit = ((i >> l) & 1) == 1
            mrg = reach & bit
            take = reach & ~bit
            old_s, old_z = sl[:, l], zl[:, l]
            sl = sl.at[:, l].set(jnp.where(
                take, c_s, jnp.where(mrg, jnp.zeros_like(old_s), old_s)))
            zl = zl.at[:, l].set(jnp.where(
                take, c_z, jnp.where(mrg, jnp.zeros_like(old_z), old_z)))
            c_s = jnp.where(mrg, c_s + old_s, c_s)
            c_z = jnp.where(mrg, c_z + old_z, c_z)
        if ls > 1:
            reach_top = (i & ((1 << (ls - 1)) - 1)) == ((1 << (ls - 1)) - 1)
            sl = sl.at[:, ls - 1].add(jnp.where(reach_top, c_s, 0.0))
            zl = zl.at[:, ls - 1].add(jnp.where(reach_top, c_z, 0.0))
        else:
            sl = sl.at[:, 0].add(c_s)
            zl = zl.at[:, 0].add(c_z)
        return (sl, zl), out

    sl0 = jnp.zeros((bg, ls, d, dv), jnp.float32)
    zl0 = jnp.zeros((bg, ls, d), jnp.float32)
    (sl, zl), out = jax.lax.scan(step, (sl0, zl0),
                                 (jnp.arange(nc), fq, fk, vf))
    out = out.transpose(1, 2, 0, 3, 4).reshape(bh, n, dv).astype(vk.dtype)
    sl = jnp.repeat(sl, r, axis=0) if r != 1 else sl   # group state -> H
    zl = jnp.repeat(zl, r, axis=0) if r != 1 else zl
    return out, sl, zl


def loglin_decode_chunk(state, q, k, v, alpha, beta, *,
                        pos, granule: int, num_scales: int,
                        scale_decay: float,
                        interpret: Optional[bool] = None,
                        row_mask: Optional[jnp.ndarray] = None,
                        backend: str = "auto",
                        commit_len: Optional[jnp.ndarray] = None,
                        renorm: Optional[float] = None):
    """Advance a ``core.loglinear.LogLinState`` over T new tokens.

    Same serving contract as :func:`lln_decode_chunk` (``row_mask`` rows
    bitwise inert, ``commit_len`` scores all T but folds the accepted
    prefix, ``renorm`` per-bucket drift guard) plus the multi-scale
    extras: per-row ``pos`` (B,) int32 — tokens already folded, which
    determines each row's bucket layout — and the Fenwick carry-merge
    when the chunk crosses a granule boundary.

    Backend dispatch: ``scan``/``ref``/interpret run the jnp core twin
    (the twin IS the reference, as for lln decode).  The ``pallas`` kind
    runs the committed fold as the same jnp ``core.loglinear._advance``
    (bitwise-identical state on every backend) and scores with TWO
    :func:`kernels.lln_attention.lln_decode_pallas` launches sharing one
    group-level reference: pass A masks keys at/past each row's granule
    boundary and carries the pyramid(n)+open aggregate as its ``s0``;
    pass B masks pre-boundary keys and carries the cascaded pyramid(n+1)
    aggregate; per-position outputs select between the two views.

    ``T > granule`` chunks are processed in granule-sized sub-chunks
    (full commit only — speculative drafts never exceed a granule).
    """
    b, t, h, d = q.shape
    g = k.shape[2]
    kind, ip = _dispatch(backend, interpret, ragged=False, cpu_twin="ref")
    beta_b = jnp.asarray(beta, jnp.float32)
    if beta_b.ndim and beta_b.shape[-1] == h and g != h:
        beta_b = beta_b.reshape(beta_b.shape[:-1] + (g, h // g)).mean(axis=-1)
    beta_b = _bcast_heads(beta_b, g)
    beta_h = jnp.repeat(beta_b, h // g, axis=-1) if g != h else beta_b
    kf = k if g == h else jnp.repeat(k, h // g, axis=2)
    vf = v if g == h else jnp.repeat(v, h // g, axis=2)
    if kind != "pallas":
        return core_loglin.decode_chunk(state, q, kf, vf, alpha, beta_h,
                                        pos=pos, granule=granule,
                                        num_scales=num_scales,
                                        scale_decay=scale_decay,
                                        row_mask=row_mask,
                                        commit_len=commit_len,
                                        renorm=renorm)
    if t > granule:
        if commit_len is not None:
            raise ValueError(
                "log_linear decode_chunk supports commit_len only for "
                f"T <= granule (T={t}, granule={granule})")
        outs = []
        posv = jnp.asarray(pos, jnp.int32)
        done = jnp.zeros((b,), jnp.int32)
        for i0 in range(0, t, granule):
            sl = slice(i0, min(i0 + granule, t))
            o, state = loglin_decode_chunk(
                state, q[:, sl], k[:, sl], v[:, sl], alpha, beta_b,
                pos=posv + done, granule=granule, num_scales=num_scales,
                scale_decay=scale_decay, interpret=interpret,
                row_mask=row_mask, backend=backend, renorm=renorm)
            step = sl.stop - sl.start
            adv = jnp.full((b,), step, jnp.int32)
            done = done + (jnp.where(row_mask, adv, 0)
                           if row_mask is not None else adv)
            outs.append(o)
        return jnp.concatenate(outs, axis=1), state
    # Committed fold: the exact jnp `_advance` the core twin runs, at H
    # heads — the new state is bitwise-identical across backends.
    bk_h = (kf * _row_head_bcast(beta_h)).astype(jnp.float32)
    vf32 = vf.astype(jnp.float32)
    new_state, aux = core_loglin._advance(
        state, bk_h, vf32, pos=pos, granule=granule,
        num_scales=num_scales, row_mask=row_mask,
        commit_len=commit_len, renorm=renorm, t=t)
    (cl_c, split, crossed, occ, occ2, sl2, zl2, cl2,
     closed_s, closed_z, closed_c) = aux
    # Group-level scoring reference covering every bucket and chunk key
    # (the normalized form is exactly invariant to the reference, so the
    # group pooling only changes rounding, not semantics).
    alpha_b = _bcast_heads(alpha, h)
    aq = q.astype(jnp.float32) * _row_head_bcast(alpha_b)
    c_q = jax.lax.stop_gradient(jnp.max(aq, axis=(1, 3), keepdims=True))
    w = core_loglin.level_weights(num_scales, scale_decay)
    cl_occ = jnp.where(occ[..., None] > 0.5, state.cl, -jnp.inf)
    c_state = jnp.max(cl_occ, axis=1)[:, None, :, None]      # (B,1,H,1)
    c_h = jnp.maximum(jnp.maximum(state.c_k, c_state),
                      jax.lax.stop_gradient(
                          jnp.max(bk_h, axis=(1, 3), keepdims=True)))
    r = h // g
    c_g = jnp.max(c_h.reshape(b, 1, g, r, 1), axis=3)        # (B,1,G,1)
    c_out = jnp.repeat(c_g, r, axis=2) if r != 1 else c_g    # (B,1,H,1)
    # Two inter views at the shared reference (jnp aggregates, H heads).
    s_effa, z_effa = core_loglin._aggregate(state.sl, state.zl, state.cl,
                                            occ, w, c_out)
    r_open = jnp.exp(state.c_k - c_out)[:, 0, :, 0]          # (B,H)
    s_effa = s_effa + state.s * r_open[..., None, None]
    z_effa = z_effa + state.z * r_open[..., None]
    s_effb, z_effb = core_loglin._aggregate(sl2, zl2, cl2, occ2, w, c_out)
    # Pass A scores pre-boundary queries (keys at/past the row's split
    # masked to NEG_INF => Phi(k) = 0); pass B scores post-boundary
    # queries (pre-boundary keys masked — they arrive via pyramid(n+1)).
    j = jnp.arange(t)
    bk_g = k.astype(jnp.float32) * _row_head_bcast(beta_b)   # (B,T,G,D)
    ks_full = bk_g - c_g
    pre_key = j[None, :, None, None] < split[:, None, None, None]
    ks_a = jnp.where(pre_key, ks_full, -1e30)
    ks_b = jnp.where(pre_key, -1e30, ks_full)
    qs = _to_kernel(aq - c_q)
    ka = _to_kernel(ks_a)
    kb = _to_kernel(ks_b)
    vk = _to_kernel(v)
    tp = -(-t // 16) * 16
    if tp != t:
        qs = jnp.pad(qs, ((0, 0), (0, tp - t), (0, 0)))
        ka = jnp.pad(ka, ((0, 0), (0, tp - t), (0, 0)),
                     constant_values=-1e30)
        kb = jnp.pad(kb, ((0, 0), (0, tp - t), (0, 0)),
                     constant_values=-1e30)
        vk = jnp.pad(vk, ((0, 0), (0, tp - t), (0, 0)))
    dv = v.shape[-1]
    out_a, _, _ = lln_decode_pallas(qs, ka, vk,
                                    s_effa.reshape(b * h, d, dv),
                                    z_effa.reshape(b * h, 1, d),
                                    r=r, interpret=ip)
    out_b, _, _ = lln_decode_pallas(qs, kb, vk,
                                    s_effb.reshape(b * h, d, dv),
                                    z_effb.reshape(b * h, 1, d),
                                    r=r, interpret=ip)
    pre = j[None, :] < split[:, None]                        # (B,T)
    out = jnp.where(pre[..., None, None],
                    _from_kernel(out_a[:, :t], b),
                    _from_kernel(out_b[:, :t], b))
    return out, new_state


def loglin_commit_chunk(state, k, v, beta, *, pos, granule: int,
                        num_scales: int,
                        interpret: Optional[bool] = None,
                        row_mask: Optional[jnp.ndarray] = None,
                        backend: str = "auto",
                        commit_len: Optional[jnp.ndarray] = None,
                        renorm: Optional[float] = None):
    """Fold a scored chunk's accepted prefix into a ``LogLinState``
    without scoring — the single-pass speculative-verify commit.

    Every backend kind runs the same O(T d^2 L) jnp
    ``core.loglinear._advance`` fold (the Pallas decode path uses it
    too), so commit is bit-identical to re-running
    :func:`loglin_decode_chunk` with the final ``commit_len`` on every
    backend.  k/v: (B,T,G,D[v]); beta as in :func:`lln_decode_chunk`.
    """
    t = k.shape[1]
    g = k.shape[2]
    h = state.s.shape[1]
    _dispatch(backend, interpret, ragged=False, cpu_twin="ref")
    beta_b = jnp.asarray(beta, jnp.float32)
    if beta_b.ndim and beta_b.shape[-1] == h and g != h:
        beta_b = beta_b.reshape(beta_b.shape[:-1] + (g, h // g)).mean(axis=-1)
    beta_b = _bcast_heads(beta_b, g)
    beta_h = jnp.repeat(beta_b, h // g, axis=-1) if g != h else beta_b
    kf = k if g == h else jnp.repeat(k, h // g, axis=2)
    vf = v if g == h else jnp.repeat(v, h // g, axis=2)
    return core_loglin.commit_chunk(state, kf, vf, beta_h, pos=pos,
                                    granule=granule,
                                    num_scales=num_scales,
                                    row_mask=row_mask,
                                    commit_len=commit_len, renorm=renorm)


# ---------------------------------------------------------------------------
# Block-diagonal softmax attention.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def block_diag_attention(q, k, v, block: int = 256, causal: bool = False,
                         interpret: Optional[bool] = None,
                         pallas_bwd: bool = True, backend: str = "auto"):
    """Block-diagonal softmax attention via Pallas (§4.2 diag component).

    q: (B, N, H, D); k/v: (B, N, G, D[v]), GQA via the ``h // r`` index map.
    Each ``block``-sized diagonal block attends only within itself
    (causally masked when ``causal``).  Training entry point (custom_vjp:
    Pallas backward on compiled backends, scan twin under interpret mode,
    jnp reference when ``N % block`` or ``pallas_bwd=False``); returns
    (B, N, H, Dv) in ``v.dtype``.  Inference prefill uses
    :func:`block_diag_fwd` instead.
    """
    return _diag_fwd_impl(q, k, v, block, causal, interpret, backend)


def _diag_fwd_impl(q, k, v, block, causal, interpret, backend="auto"):
    b, n, h, _ = q.shape
    g = k.shape[2]
    kind, ip = _dispatch(backend, interpret, ragged=bool(n % block),
                         cpu_twin="pallas")
    if kind == "ref":
        return _diag_ref(q, k, v, block, causal)
    if kind == "scan":
        return _block_diag_twin(q, k, v, block, causal)
    out = block_diag_pallas(_to_kernel(q), _to_kernel(k), _to_kernel(v),
                            r=h // g, blk=block, causal=causal,
                            interpret=ip)
    return _from_kernel(out, b)


def _diag_ref(q, k, v, block, causal):
    h = q.shape[2]
    g = k.shape[2]
    kf = k if g == h else jnp.repeat(k, h // g, axis=2)
    vf = v if g == h else jnp.repeat(v, h // g, axis=2)
    return core_diag(q, kf, vf, block=block, causal=causal).astype(v.dtype)


def _diag_vjp_fwd(q, k, v, block, causal, interpret, pallas_bwd,
                  backend="auto"):
    n = q.shape[1]
    if n % block or not pallas_bwd or backend in ("scan", "ref"):
        return (_diag_fwd_impl(q, k, v, block, causal, interpret, backend),
                {"ref": (q, k, v)})
    qk, kk, vk = _to_kernel(q), _to_kernel(k), _to_kernel(v)
    out = block_diag_pallas(qk, kk, vk, r=q.shape[2] // k.shape[2],
                            blk=block, causal=causal,
                            interpret=_interpret(interpret))
    res = {"pallas": (qk, kk, vk, _dtype_tag(q), _dtype_tag(k),
                      _dtype_tag(v))}
    return _from_kernel(out, q.shape[0]), res


def _diag_vjp_bwd(block, causal, interpret, pallas_bwd, backend, res, g_out):
    if "ref" in res:
        q, k, v = res["ref"]
        _, vjp = jax.vjp(lambda q_, k_, v_: _diag_ref(q_, k_, v_, block,
                                                      causal), q, k, v)
        return vjp(g_out)
    qk, kk, vk, tq, tk, tv = res["pallas"]
    b = g_out.shape[0]
    r = (qk.shape[0] // b) // (kk.shape[0] // b)
    if _kernel_bwd(interpret):
        dq, dk, dv = block_diag_bwd_pallas(qk, kk, vk, _to_kernel(g_out),
                                           r=r, blk=block, causal=causal,
                                           interpret=_interpret(interpret))
    else:
        dq, dk, dv = block_diag_bwd_scan(qk, kk, vk, _to_kernel(g_out),
                                         r=r, blk=block, causal=causal)
    return (_from_kernel(dq, b).astype(tq.dtype),
            _from_kernel(dk, b).astype(tk.dtype),
            _from_kernel(dv, b).astype(tv.dtype))


block_diag_attention.defvjp(_diag_vjp_fwd, _diag_vjp_bwd)


# ---------------------------------------------------------------------------
# Fused LLN + Diag (causal): single-pass hybrid, shared block loads.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def lln_diag_attention(q, k, v, alpha, beta, causal: bool = True,
                       block: int = 256, interpret: Optional[bool] = None,
                       pallas_bwd: bool = True, backend: str = "auto"):
    """The paper's §4.2 hybrid: 0.5 * (LLN + block-diag softmax).

    Shapes/dtypes/GQA semantics as :func:`lln_attention` (``block`` doubles
    as the LLN chunk and the diag block).  When ``causal`` the two
    components run as ONE fused Pallas kernel sharing block loads (fused
    backward likewise); bidirectional runs them as two kernels.  Fallbacks:
    jnp reference when ``N % block`` or ``pallas_bwd=False``; scan twins
    under interpret mode for the backward.  ``backend='scan'`` forces the
    core chunked-scan hybrid, ``'ref'`` the quadratic-oracle hybrid,
    ``'pallas'`` the fused kernel (interpreted on CPU).
    """
    return _lln_diag_fwd_impl(q, k, v, alpha, beta, causal, block, interpret,
                              backend)


def _lln_diag_fwd_impl(q, k, v, alpha, beta, causal, block, interpret,
                       backend="auto"):
    b, n, h, _ = q.shape
    g = k.shape[2]
    kind, ip_forced = _dispatch(backend, interpret, ragged=bool(n % block),
                                cpu_twin="pallas", ragged_kind="scan")
    if kind == "scan":
        return _lln_diag_ref(q, k, v, alpha, beta, causal, block)
    if kind == "ref":
        lln = _lln_quad_ref(q, k, v, alpha, beta, causal)
        diag = _diag_ref(q, k, v, block, causal)
        return (0.5 * (lln.astype(jnp.float32) + diag.astype(jnp.float32))
                ).astype(v.dtype)
    # Kernel-layout conversion hoisted: q/k/v are transposed exactly once
    # per call, and the LLN pre-scaling runs once for both components.
    qs, ks, _, _ = _scaled_stabilized(q, k, alpha, beta)
    vk = _to_kernel(v)
    ip = ip_forced
    if causal:
        out = lln_diag_fused_pallas(qs, ks, _to_kernel(q), _to_kernel(k),
                                    vk, r=h // g, blk=block, causal=True,
                                    interpret=ip)
        return _from_kernel(out, b)
    lln = lln_bidir_pallas(qs, ks, vk, r=h // g, blk=block, interpret=ip)
    diag = block_diag_pallas(_to_kernel(q), _to_kernel(k), vk, r=h // g,
                             blk=block, causal=False, interpret=ip)
    out = 0.5 * (lln.astype(jnp.float32) + diag.astype(jnp.float32))
    return _from_kernel(out, b).astype(v.dtype)


def _lln_diag_ref(q, k, v, alpha, beta, causal, block):
    lln = _lln_ref(q, k, v, alpha, beta, causal, block)
    diag = _diag_ref(q, k, v, block, causal)
    return (0.5 * (lln.astype(jnp.float32) + diag.astype(jnp.float32))
            ).astype(v.dtype)


def _lln_diag_vjp_fwd(q, k, v, alpha, beta, causal, block, interpret,
                      pallas_bwd, backend="auto"):
    b, n, h, _ = q.shape
    g = k.shape[2]
    if n % block or not pallas_bwd or backend in ("scan", "ref"):
        out = _lln_diag_fwd_impl(q, k, v, alpha, beta, causal, block,
                                 interpret, backend)
        return out, {"ref": (q, k, v, alpha, beta)}
    qs, ks, alpha_b, beta_b = _scaled_stabilized(q, k, alpha, beta)
    qk, kk, vk = _to_kernel(q), _to_kernel(k), _to_kernel(v)
    ip = _interpret(interpret)
    tags = (_dtype_tag(q), _dtype_tag(k), _dtype_tag(v),
            jnp.asarray(alpha, jnp.float32), jnp.asarray(beta, jnp.float32))
    if causal:
        out_k, den = lln_diag_fused_pallas(qs, ks, qk, kk, vk, r=h // g,
                                           blk=block, causal=True,
                                           interpret=ip, return_res=True)
        res = {"pallas_fused": (qs, ks, qk, kk, vk, out_k, den,
                                alpha_b, beta_b) + tags}
        return _from_kernel(out_k, b), res
    lln_k, s, z, den = lln_bidir_pallas(qs, ks, vk, r=h // g, blk=block,
                                        interpret=ip, return_res=True)
    diag_k = block_diag_pallas(qk, kk, vk, r=h // g, blk=block, causal=False,
                               interpret=ip)
    out = 0.5 * (lln_k.astype(jnp.float32) + diag_k.astype(jnp.float32))
    res = {"pallas_bidir": (qs, ks, qk, kk, vk, lln_k, den, s, z,
                            alpha_b, beta_b) + tags}
    return _from_kernel(out, b).astype(v.dtype), res


def _lln_diag_vjp_bwd(causal, block, interpret, pallas_bwd, backend, res,
                      g_out):
    if "ref" in res:
        q, k, v, alpha, beta = res["ref"]
        _, vjp = jax.vjp(
            lambda q_, k_, v_: _lln_diag_ref(q_, k_, v_, alpha, beta, causal,
                                             block), q, k, v)
        dq, dk, dv = vjp(g_out)
        return (dq, dk, dv) + _zero_ab(alpha, beta)
    b = g_out.shape[0]
    gk = _to_kernel(g_out)
    ip = _interpret(interpret)
    if causal:
        (qs, ks, qk, kk, vk, out_k, den, alpha_b, beta_b,
         tq, tk, tv, alpha0, beta0) = res["pallas_fused"]
        r = (qs.shape[0] // b) // (ks.shape[0] // b)
        if _kernel_bwd(interpret):
            dqs, dqd, dks, dkd, dvk = lln_diag_fused_bwd_pallas(
                qs, ks, qk, kk, vk, gk, out_k, den, r=r, blk=block,
                interpret=ip)
        else:
            dqs, dqd, dks, dkd, dvk = lln_diag_fused_bwd_scan(
                qs, ks, qk, kk, vk, gk, out_k, den, r=r, blk=block)
    else:
        (qs, ks, qk, kk, vk, lln_k, den, s, z, alpha_b, beta_b,
         tq, tk, tv, alpha0, beta0) = res["pallas_bidir"]
        r = (qs.shape[0] // b) // (ks.shape[0] // b)
        gh = 0.5 * gk.astype(jnp.float32)
        if _kernel_bwd(interpret):
            dqs, dks, dvl = lln_bidir_bwd_pallas(qs, ks, vk, gh, lln_k, den,
                                                 s, z, r=r, blk=block,
                                                 interpret=ip)
            dqd, dkd, dvd = block_diag_bwd_pallas(qk, kk, vk, gh, r=r,
                                                  blk=block, causal=False,
                                                  interpret=ip)
        else:
            dqs, dks, dvl = lln_bidir_bwd_scan(qs, ks, vk, gh, lln_k, den,
                                               s, z, r=r, blk=block)
            dqd, dkd, dvd = block_diag_bwd_scan(qk, kk, vk, gh, r=r,
                                                blk=block, causal=False)
        dvk = dvl + dvd
    dq = (_from_kernel(dqs, b) * _row_head_bcast(alpha_b)
          + _from_kernel(dqd, b)).astype(tq.dtype)
    dk = (_from_kernel(dks, b) * _row_head_bcast(beta_b)
          + _from_kernel(dkd, b)).astype(tk.dtype)
    dv = _from_kernel(dvk, b).astype(tv.dtype)
    return dq, dk, dv, jnp.zeros_like(alpha0), jnp.zeros_like(beta0)


lln_diag_attention.defvjp(_lln_diag_vjp_fwd, _lln_diag_vjp_bwd)


# ---------------------------------------------------------------------------
# Mamba2 SSD chunked scan.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def ssd_scan(xbar, b_in, c_in, log_a, chunk: int = 256,
             interpret: Optional[bool] = None):
    """SSD via Pallas.  xbar: (B,L,H,P); b_in/c_in: (B,L,G,S);
    log_a: (B,L,H).  Returns y: (B,L,H,P) (no final state — training path;
    prefill uses the jnp ssd_chunked which also returns the state)."""
    return _ssd_fwd_impl(xbar, b_in, c_in, log_a, chunk, interpret)


def _ssd_fwd_impl(xbar, b_in, c_in, log_a, chunk, interpret):
    b, l, h, p_dim = xbar.shape
    g = b_in.shape[2]
    if l % chunk:
        return _ssd_ref(xbar, b_in, c_in, log_a, chunk)
    xk = _to_kernel(xbar)
    bk = _to_kernel(b_in)
    ck = _to_kernel(c_in)
    lk = log_a.transpose(0, 2, 1).reshape(b * h, l)
    out = ssd_pallas(lk, xk, bk, ck, r=h // g, blk=chunk,
                     interpret=_interpret(interpret))
    return _from_kernel(out, b)


def _ssd_ref(xbar, b_in, c_in, log_a, chunk):
    from repro.models.ssm import ssd_chunked
    h, g = xbar.shape[2], b_in.shape[2]
    rep = h // g
    bf = jnp.repeat(b_in, rep, axis=2) if rep > 1 else b_in
    cf = jnp.repeat(c_in, rep, axis=2) if rep > 1 else c_in
    y, _ = ssd_chunked(xbar, bf, cf, log_a, chunk=chunk)
    return y.astype(xbar.dtype)


def _ssd_vjp_fwd(xbar, b_in, c_in, log_a, chunk, interpret):
    return _ssd_fwd_impl(xbar, b_in, c_in, log_a, chunk, interpret), \
        (xbar, b_in, c_in, log_a)


def _ssd_vjp_bwd(chunk, interpret, res, g_out):
    xbar, b_in, c_in, log_a = res
    _, vjp = jax.vjp(
        lambda x, b, c, a: _ssd_ref(x, b, c, a, chunk),
        xbar, b_in, c_in, log_a)
    return vjp(g_out.astype(jnp.float32))


ssd_scan.defvjp(_ssd_vjp_fwd, _ssd_vjp_bwd)
