"""Block-diagonal softmax attention (paper §4.2, after Qin et al. 2022b).

Regular softmax attention applied to non-overlapping blocks along the
sequence — computes only the diagonal blocks of the full attention matrix,
keeping O(N * block) time/memory.  Combined (averaged) with LLN attention it
restores the short-range interactions that linear attention "dilutes".
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def block_diag_attn(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    block: int = 256,
    causal: bool = False,
    mask: Optional[jnp.ndarray] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """q,k: (B, N, H, D); v: (B, N, H, Dv); mask: optional (B, N) validity.

    Sequences are zero-padded to a block multiple; padded keys are masked out.
    """
    b, n, h, d = q.shape
    dv = v.shape[-1]
    scale = (d ** -0.5) if scale is None else scale
    nb = -(-n // block)
    pad = nb * block - n
    if mask is None:
        mask = jnp.ones((b, n), jnp.bool_)
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))

    qb = q.reshape(b, nb, block, h, d)
    kb = k.reshape(b, nb, block, h, d)
    vb = v.reshape(b, nb, block, h, dv)
    mb = mask.reshape(b, nb, block)

    scores = jnp.einsum("bgihd,bgjhd->bghij", qb, kb) * scale
    bias = jnp.where(mb[:, :, None, None, :], 0.0, NEG_INF)
    if causal:
        tri = jnp.tril(jnp.ones((block, block), jnp.bool_))
        bias = bias + jnp.where(tri[None, None, None], 0.0, NEG_INF)
    p = jax.nn.softmax(scores.astype(jnp.float32) + bias, axis=-1)
    out = jnp.einsum("bghij,bgjhv->bgihv", p.astype(v.dtype), vb)
    return out.reshape(b, nb * block, h, dv)[:, :n]
