"""fp32-accumulating einsum that is both TPU-shaped and CPU-runnable.

On TPU (the target), bf16 x bf16 -> f32 dots run natively on the MXU via
``preferred_element_type`` — upcasting operands first would materialize
fp32 copies of whole activation streams (measured: 36 GB/layer of gathers,
see EXPERIMENTS.md §Perf cell 2).  The CPU backend, however, cannot
*execute* several of those mixed dots (``DotThunk: BF16 x BF16 = F32``).

Resolution: the AOT dry-run (compile-only) keeps the TPU-shaped program —
``repro.launch.dryrun`` sets REPRO_AOT_ONLY=1 — while CPU *execution*
paths (tests, smoke training, examples) upcast operands instead.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp


def _cpu_exec() -> bool:
    return (jax.default_backend() == "cpu"
            and not os.environ.get("REPRO_AOT_ONLY"))


def einsum_f32(subscripts: str, *operands) -> jnp.ndarray:
    """einsum with fp32 accumulation; see module docstring."""
    if _cpu_exec():
        return jnp.einsum(subscripts,
                          *(o.astype(jnp.float32) for o in operands))
    return jnp.einsum(subscripts, *operands,
                      preferred_element_type=jnp.float32)
