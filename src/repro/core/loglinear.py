"""Log-linear multi-scale LLN state: a Fenwick-tree pyramid of buckets.

One LLN ``(s, z)`` running sum compresses the whole context into a single
O(d^2) state — expressive for concentration (the paper's point) but unable
to weight recent tokens differently from distant ones.  Following
Log-Linear Attention (Guo, Yang, Dao & Kim 2025; PAPERS.md), this module
replaces the single state with O(log N) dyadic buckets arranged as a
binary counter (Fenwick layout): closing one ``granule``-sized chunk of
keys inserts a level-0 bucket; two level-l buckets merge into one
level-(l+1) bucket exactly like a carry in binary increment.  After ``n``
closed granules the occupied levels are the set bits of ``n`` (the top
level saturates — see :func:`occupancy`), and bucket level ``l`` holds a
contiguous dyadic span of ``2^l`` granules.

Scoring mixes the buckets with derived per-scale weights
``w_l = scale_decay**l`` under ONE shared normalizer:

    out_i = (sum_l w_l Phi(q_i) . S_l  +  Phi(q_i) . S_open  +  intra_i)
            / (same with z  +  EPS)

The open (partially filled) granule and the intra-chunk keys score at
``w_0 = 1``.  ``scale_decay = 1`` makes every weight 1 and the bucket sums
telescope back to the single LLN state — plain ``lln`` exactly.  With
``scale_decay = 0.5`` each level contributes ~constant total mass
(``w_l * 2^l ~ 1``), so the normalizer grows ~log N instead of ~N and a
single distant associated key is diluted by 1/log N rather than 1/N —
the mechanism by which multi-scale wins the association-recall proxy
(``benchmarks/bench_loglinear.py``).

Numerics follow ``core/lln.py``: every bucket carries its own reference
constant (``cl`` per level, ``c_k`` for the open bucket); merges rescale
both operands to the max of their references; the drift renorm raises a
bucket's reference by ``ln(max_d z)`` and scales ``(s, z)`` down — exact,
because the mix weights repay ``exp(cl - c_out)`` at scoring time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .lln import EPS, _bcast, _stab_const, commit_lengths


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LogLinState:
    """Multi-scale decode state for one layer (full H query heads).

    s / z / c_k / log_scale: the OPEN bucket — the partially filled
        current granule, exactly an ``LLNState`` (same shapes, same
        renorm semantics).  s (B,H,D,Dv) f32, z (B,H,D) f32, c_k
        (B,1,H,1) f32, log_scale (B,H) f32.
    sl: (B, L, H, D, Dv) f32 — closed-bucket pyramid, level l at index l.
    zl: (B, L, H, D) f32.
    cl: (B, L, H) f32 — per-bucket reference constants.  Unoccupied
        levels hold zeros (occupancy is DERIVED from the row position,
        not stored — see :func:`occupancy`).
    """
    s: jnp.ndarray
    z: jnp.ndarray
    c_k: jnp.ndarray
    sl: jnp.ndarray
    zl: jnp.ndarray
    cl: jnp.ndarray
    log_scale: Optional[jnp.ndarray] = None

    @staticmethod
    def init(batch: int, heads: int, d: int, dv: int,
             num_scales: int) -> "LogLinState":
        return LogLinState(
            s=jnp.zeros((batch, heads, d, dv), jnp.float32),
            z=jnp.zeros((batch, heads, d), jnp.float32),
            c_k=jnp.zeros((batch, 1, heads, 1), jnp.float32),
            sl=jnp.zeros((batch, num_scales, heads, d, dv), jnp.float32),
            zl=jnp.zeros((batch, num_scales, heads, d), jnp.float32),
            cl=jnp.zeros((batch, num_scales, heads), jnp.float32),
            log_scale=jnp.zeros((batch, heads), jnp.float32))


def level_weights(num_scales: int, scale_decay: float) -> jnp.ndarray:
    """Derived per-scale mix weights ``w_l = scale_decay**l`` (L,) f32."""
    return jnp.asarray([float(scale_decay) ** l for l in range(num_scales)],
                       jnp.float32)


def occupancy(n: jnp.ndarray, num_scales: int) -> jnp.ndarray:
    """Which pyramid levels hold a bucket after ``n`` closed granules.

    Binary-counter layout: level ``l < L-1`` is occupied iff bit ``l`` of
    ``n`` is set; the TOP level saturates (``n >= 2^(L-1)``) — carries
    past it merge into it instead of overflowing, so the top bucket's
    span keeps growing while the lower bits stay exact binary arithmetic.
    Returns (..., L) float32 in {0, 1}.
    """
    n = jnp.asarray(n, jnp.int32)
    if num_scales == 1:
        return (n[..., None] >= 1).astype(jnp.float32)
    ls = jnp.arange(num_scales - 1, dtype=jnp.int32)
    low = ((n[..., None] >> ls) & 1).astype(jnp.float32)
    top = (n >= 2 ** (num_scales - 1)).astype(jnp.float32)
    return jnp.concatenate([low, top[..., None]], axis=-1)


# ---------------------------------------------------------------------------
# Quadratic oracle — the test reference.  Materializes, for every
# (query t, key j) pair, the level that key's granule sits at in the
# pyramid layout of t's granule count, and scores the full weighted
# quadratic.  O(N^2); never a serving path.
# ---------------------------------------------------------------------------

def level_matrix(n: int, *, granule: int, num_scales: int) -> jnp.ndarray:
    """(N, N) int32: pyramid level of key j as seen by query t (both
    0-indexed positions, prefix starting at position 0).  Intra-granule
    keys (the open bucket) are level 0; entries above the diagonal are
    level 0 too (callers mask causally)."""
    pos = jnp.arange(n, dtype=jnp.int32)
    gq = pos // granule                      # query's granule == closed count
    gj = (pos // granule)[None, :]           # key's granule
    nq = gq[:, None]
    ls = num_scales
    top_count = nq - (nq & ((1 << (ls - 1)) - 1))   # low L-1 bits cleared
    lev = jnp.where(gj < top_count, ls - 1, 0)
    for l in range(ls - 1):
        hi = (nq >> (l + 1)) << (l + 1)
        in_l = (((nq >> l) & 1) == 1) & (gj >= hi) \
            & (gj < hi + (1 << l)) & (gj >= top_count)
        lev = jnp.where(in_l, l, lev)
    return jnp.where(gj == nq, 0, lev)


def loglin_attention_ref(q, k, v, alpha, beta, *, granule: int,
                         num_scales: int, scale_decay: float) -> jnp.ndarray:
    """Causal multi-scale LLN attention, quadratic form (full H heads).

    Weight of key j for query t is ``scale_decay**level(t, j)`` where the
    level follows the Fenwick layout at t's granule count; intra-granule
    and open-bucket keys weigh 1.  ``scale_decay=1`` or ``num_scales=1``
    reduce exactly to plain causal LLN.
    """
    b, n, h, d = q.shape
    aq = q * _bcast(alpha, q)
    bk = k * _bcast(beta, k)
    fq = jnp.exp(aq - _stab_const(aq, (1, 3))).astype(jnp.float32)
    fk = jnp.exp(bk - _stab_const(bk, (1, 3))).astype(jnp.float32)
    vf = v.astype(jnp.float32)
    lev = level_matrix(n, granule=granule, num_scales=num_scales)
    w = (jnp.float32(scale_decay) ** lev.astype(jnp.float32)) \
        * jnp.tril(jnp.ones((n, n), jnp.float32))
    scores = jnp.einsum("bihd,bjhd->bhij", fq, fk) * w[None, None]
    num = jnp.einsum("bhij,bjhv->bihv", scores, vf)
    den = jnp.sum(scores, axis=-1).transpose(0, 2, 1)            # (B,N,H)
    return (num / (den[..., None] + EPS)).astype(v.dtype)


# ---------------------------------------------------------------------------
# Prefill: chunked scan over granules carrying the bucket pyramid.
# One global stabilization constant per (batch, head) — every bucket is
# built at the same reference, so the in-scan cascade merges are pure adds.
# ---------------------------------------------------------------------------

def _cascade_same_ref(sl, zl, g_s, g_z, i, num_scales: int):
    """Insert a freshly closed granule (``g_s``/``g_z``) into a pyramid
    whose buckets all share ONE reference constant.  ``i`` is the closed
    count BEFORE this insert (occupancy bits).  Binary-increment carry:
    merge-and-propagate while the level is occupied; the top saturates."""
    inc_s, inc_z = g_s, g_z
    carry = jnp.asarray(True)
    new_s, new_z = [], []
    for l in range(num_scales - 1):
        occ = ((i >> l) & 1) == 1
        mrg = carry & occ
        take = carry & ~occ
        new_s.append(jnp.where(take, inc_s,
                               jnp.where(mrg, 0.0, sl[:, l])))
        new_z.append(jnp.where(take, inc_z,
                               jnp.where(mrg, 0.0, zl[:, l])))
        inc_s = jnp.where(mrg, sl[:, l] + inc_s, inc_s)
        inc_z = jnp.where(mrg, zl[:, l] + inc_z, inc_z)
        carry = mrg
    top = num_scales - 1
    new_s.append(jnp.where(carry, sl[:, top] + inc_s, sl[:, top]))
    new_z.append(jnp.where(carry, zl[:, top] + inc_z, zl[:, top]))
    return jnp.stack(new_s, axis=1), jnp.stack(new_z, axis=1)


def prefill(q, k, v, alpha, beta, *, granule: int, num_scales: int,
            scale_decay: float):
    """Causal multi-scale forward over a prompt; returns
    ``(out, LogLinState)``.  Ragged lengths are first-class: the trailing
    ``n % granule`` keys land in the open bucket.

    q: (B,N,H,D); k/v: (B,N,H,D[v]) (full heads — callers repeat KV for
    GQA, as with ``core/lln.py``)."""
    b, n, h, d = q.shape
    dv = v.shape[-1]
    ls = num_scales
    aq = q * _bcast(alpha, q)
    bk = k * _bcast(beta, k)
    c_q = _stab_const(aq, (1, 3))
    c_k = _stab_const(bk, (1, 3))
    fq = jnp.exp(aq - c_q).astype(jnp.float32)
    fk = jnp.exp(bk - c_k).astype(jnp.float32)
    vf = v.astype(jnp.float32)
    w = level_weights(ls, scale_decay)
    nf = n // granule
    tail = n - nf * granule
    sl = jnp.zeros((b, ls, h, d, dv), jnp.float32)
    zl = jnp.zeros((b, ls, h, d), jnp.float32)
    pieces = []
    if nf:
        causal = jnp.tril(jnp.ones((granule, granule), jnp.float32))
        fqc = fq[:, :nf * granule].reshape(b, nf, granule, h, d) \
            .transpose(1, 0, 2, 3, 4)
        fkc = fk[:, :nf * granule].reshape(b, nf, granule, h, d) \
            .transpose(1, 0, 2, 3, 4)
        vfc = vf[:, :nf * granule].reshape(b, nf, granule, h, dv) \
            .transpose(1, 0, 2, 3, 4)

        def step(carry, xs):
            slc, zlc = carry
            i, cq, ck, cv = xs
            occf = occupancy(i, ls)                       # (L,)
            wvec = w * occf
            s_eff = jnp.einsum("l,blhdv->bhdv", wvec, slc)
            z_eff = jnp.einsum("l,blhd->bhd", wvec, zlc)
            scores = jnp.einsum("bihd,bjhd->bhij", cq, ck) \
                * causal[None, None]
            intra = jnp.einsum("bhij,bjhv->bihv", scores, cv)
            intra_z = jnp.sum(scores, axis=-1).transpose(0, 2, 1)
            inter = jnp.einsum("bihd,bhdv->bihv", cq, s_eff)
            inter_z = jnp.einsum("bihd,bhd->bih", cq, z_eff)
            out = (intra + inter) / (intra_z + inter_z + EPS)[..., None]
            g_s = jnp.einsum("bjhd,bjhv->bhdv", ck, cv)
            g_z = jnp.sum(ck, axis=1)
            slc, zlc = _cascade_same_ref(slc, zlc, g_s, g_z, i, ls)
            return (slc, zlc), out

        (sl, zl), outs = jax.lax.scan(
            jax.checkpoint(step), (sl, zl),
            (jnp.arange(nf, dtype=jnp.int32), fqc, fkc, vfc))
        pieces.append(outs.transpose(1, 0, 2, 3, 4)
                      .reshape(b, nf * granule, h, dv))
    if tail:
        tq, tk, tv = fq[:, -tail:], fk[:, -tail:], vf[:, -tail:]
        occf = occupancy(jnp.asarray(nf, jnp.int32), ls)
        wvec = w * occf
        s_eff = jnp.einsum("l,blhdv->bhdv", wvec, sl)
        z_eff = jnp.einsum("l,blhd->bhd", wvec, zl)
        tri = jnp.tril(jnp.ones((tail, tail), jnp.float32))
        scores = jnp.einsum("bihd,bjhd->bhij", tq, tk) * tri[None, None]
        intra = jnp.einsum("bhij,bjhv->bihv", scores, tv)
        intra_z = jnp.sum(scores, axis=-1).transpose(0, 2, 1)
        inter = jnp.einsum("bihd,bhdv->bihv", tq, s_eff)
        inter_z = jnp.einsum("bihd,bhd->bih", tq, z_eff)
        pieces.append((intra + inter)
                      / (intra_z + inter_z + EPS)[..., None])
        s_open = jnp.einsum("bjhd,bjhv->bhdv", tk, tv)
        z_open = jnp.sum(tk, axis=1)
    else:
        s_open = jnp.zeros((b, h, d, dv), jnp.float32)
        z_open = jnp.zeros((b, h, d), jnp.float32)
    out = jnp.concatenate(pieces, axis=1) if len(pieces) > 1 else pieces[0]
    cl = jnp.broadcast_to(c_k[:, 0, :, 0][:, None, :], (b, ls, h)) \
        .astype(jnp.float32)
    state = LogLinState(
        s=s_open, z=z_open, c_k=c_k.astype(jnp.float32),
        sl=sl, zl=zl, cl=cl,
        log_scale=jnp.zeros((b, h), jnp.float32))
    return out.astype(v.dtype), state


# ---------------------------------------------------------------------------
# Decode: chunked multi-token advance with at most one dyadic boundary.
# ---------------------------------------------------------------------------

def _sel(mask, a, b):
    """Per-row select: broadcast a (B,) bool over a's trailing dims."""
    return jnp.where(mask.reshape((-1,) + (1,) * (a.ndim - 1)), a, b)


def _advance(state: LogLinState, bk, vf, *, pos, granule: int,
             num_scales: int, row_mask, commit_len, renorm, t: int):
    """The ONE state-advance computation shared by decode and commit.

    ``bk`` = beta*k (B,T,H,D) f32; ``vf`` (B,T,H,Dv) f32; ``pos`` (B,)
    int32 tokens already folded.  Returns ``(new_state, aux)`` where
    ``aux`` carries everything scoring needs: ``(cl_c, split, crossed,
    occ, occ2, sl2, zl2, cl2)`` — the cascaded pyramid folds ALL
    pre-boundary chunk keys (what a sequential decode would have seen),
    while the committed state folds only ``j < commit_len`` per the
    partial-commit contract.  When the commit crosses the boundary the
    two folds coincide (crossing requires every pre-boundary key to be
    committed), so commit == decode bitwise.
    """
    b, _, h, d = bk.shape
    ls = num_scales
    cl_c = commit_lengths(
        commit_len if commit_len is not None
        else jnp.full((b,), t, jnp.int32), row_mask, t)
    pos = jnp.asarray(pos, jnp.int32)
    n = pos // granule
    split = granule - (pos - n * granule)            # (B,) in [1, granule]
    crossed = cl_c >= split                          # close fires this call
    j = jnp.arange(t)
    # Close the open granule: fold ALL pre-boundary keys (scoring view; it
    # is also the committed view whenever ``crossed``).
    amask = j[None, :] < jnp.minimum(split, t)[:, None]
    bk_a = jnp.where(amask[:, :, None, None], bk, -jnp.inf)
    c_cas = jnp.maximum(state.c_k, jax.lax.stop_gradient(
        jnp.max(bk_a, axis=(1, 3), keepdims=True)))          # (B,1,H,1)
    r_a = jnp.exp(state.c_k - c_cas)[:, 0, :, 0]             # (B,H)
    fk_a = jnp.exp(bk_a - c_cas).astype(jnp.float32)
    closed_s = state.s * r_a[..., None, None] \
        + jnp.einsum("bjhd,bjhv->bhdv", fk_a, vf)
    closed_z = state.z * r_a[..., None] + jnp.sum(fk_a, axis=1)
    closed_c = c_cas[:, 0, :, 0]                             # (B,H)
    # Fenwick carry-merge: insert the closed bucket at level 0, merging
    # upward while occupied (binary increment); the top level saturates.
    occ = occupancy(n, ls)                                   # (B,L)
    inc_s, inc_z, inc_c = closed_s, closed_z, closed_c
    carry = jnp.ones((b,), bool)
    new_sl, new_zl, new_cl = [], [], []
    for l in range(ls - 1):
        o_l = occ[:, l] > 0.5
        mrg = carry & o_l
        take = carry & ~o_l
        cm = jnp.maximum(state.cl[:, l], inc_c)              # (B,H)
        e_old = jnp.exp(state.cl[:, l] - cm)
        e_inc = jnp.exp(inc_c - cm)
        sm = state.sl[:, l] * e_old[..., None, None] \
            + inc_s * e_inc[..., None, None]
        zm = state.zl[:, l] * e_old[..., None] + inc_z * e_inc[..., None]
        new_sl.append(_sel(take, inc_s,
                           _sel(mrg, jnp.zeros_like(inc_s), state.sl[:, l])))
        new_zl.append(_sel(take, inc_z,
                           _sel(mrg, jnp.zeros_like(inc_z), state.zl[:, l])))
        new_cl.append(_sel(take, inc_c,
                           _sel(mrg, jnp.zeros_like(inc_c), state.cl[:, l])))
        inc_s = _sel(mrg, sm, inc_s)
        inc_z = _sel(mrg, zm, inc_z)
        inc_c = _sel(mrg, cm, inc_c)
        carry = mrg
    top = ls - 1
    o_t = occ[:, top] > 0.5
    cm = jnp.maximum(state.cl[:, top], inc_c)
    e_old = jnp.exp(state.cl[:, top] - cm)
    e_inc = jnp.exp(inc_c - cm)
    sm = state.sl[:, top] * e_old[..., None, None] \
        + inc_s * e_inc[..., None, None]
    zm = state.zl[:, top] * e_old[..., None] + inc_z * e_inc[..., None]
    t_mrg = carry & o_t
    t_take = carry & ~o_t
    new_sl.append(_sel(t_take, inc_s, _sel(t_mrg, sm, state.sl[:, top])))
    new_zl.append(_sel(t_take, inc_z, _sel(t_mrg, zm, state.zl[:, top])))
    new_cl.append(_sel(t_take, inc_c, _sel(t_mrg, cm, state.cl[:, top])))
    sl2 = jnp.stack(new_sl, axis=1)
    zl2 = jnp.stack(new_zl, axis=1)
    cl2 = jnp.stack(new_cl, axis=1)
    occ2 = occupancy(n + 1, ls)
    # Committed pyramid: the cascade only lands when the commit crossed.
    cx = crossed
    sl_new = _sel(cx, sl2, state.sl)
    zl_new = _sel(cx, zl2, state.zl)
    cl_new = _sel(cx, cl2, state.cl)
    # Committed open bucket.  Not crossed: plain LLN fold of j < commit.
    cmask = j[None, :] < jnp.minimum(cl_c, split)[:, None]
    bk_nc = jnp.where(cmask[:, :, None, None], bk, -jnp.inf)
    c_nc = jnp.maximum(state.c_k, jax.lax.stop_gradient(
        jnp.max(bk_nc, axis=(1, 3), keepdims=True)))
    r_nc = jnp.exp(state.c_k - c_nc)[:, 0, :, 0]
    fk_nc = jnp.exp(bk_nc - c_nc).astype(jnp.float32)
    s_nc = state.s * r_nc[..., None, None] \
        + jnp.einsum("bjhd,bjhv->bhdv", fk_nc, vf)
    z_nc = state.z * r_nc[..., None] + jnp.sum(fk_nc, axis=1)
    # Crossed: the old open bucket closed; a NEW open bucket starts from
    # the committed post-boundary keys (reference from zero-init, exactly
    # like a fresh row's first fold).
    bmask = (j[None, :] >= split[:, None]) & (j[None, :] < cl_c[:, None])
    bk_b = jnp.where(bmask[:, :, None, None], bk, -jnp.inf)
    c_b = jnp.maximum(0.0, jax.lax.stop_gradient(
        jnp.max(bk_b, axis=(1, 3), keepdims=True)))
    fk_b = jnp.exp(bk_b - c_b).astype(jnp.float32)
    s_b = jnp.einsum("bjhd,bjhv->bhdv", fk_b, vf)
    z_b = jnp.sum(fk_b, axis=1)
    s_new = _sel(cx, s_b, s_nc)
    z_new = _sel(cx, z_b, z_nc)
    c_new = _sel(cx, c_b, c_nc)
    log_scale = state.log_scale
    if renorm is not None and renorm > 0.0:
        # Open bucket: same drift renorm as core.lln.decode_chunk, except
        # the shift folds into c_k (the mix weight ``exp(c_k - c_out)``
        # repays it exactly — scaling one bucket alone would change its
        # weight relative to the pyramid).
        folded = (cl_c > 0)[:, None]
        zmax = jax.lax.stop_gradient(jnp.max(z_new, axis=-1))    # (B,H)
        delta = jnp.where(folded & (zmax > renorm),
                          jnp.log(jnp.maximum(zmax, EPS)), 0.0)
        scale = jnp.exp(-delta)
        s_new = s_new * scale[..., None, None]
        z_new = z_new * scale[..., None]
        c_new = c_new + delta[:, None, :, None]
        if log_scale is not None:
            log_scale = log_scale + delta
        # Closed buckets renormalize into their own cl at merge time.
        zlmax = jax.lax.stop_gradient(jnp.max(zl_new, axis=-1))  # (B,L,H)
        dl = jnp.where(cx[:, None, None] & (zlmax > renorm),
                       jnp.log(jnp.maximum(zlmax, EPS)), 0.0)
        sc = jnp.exp(-dl)
        sl_new = sl_new * sc[..., None, None]
        zl_new = zl_new * sc[..., None]
        cl_new = cl_new + dl
    if row_mask is not None:
        keep = row_mask
        s_new = _sel(keep, s_new, state.s)
        z_new = _sel(keep, z_new, state.z)
        c_new = _sel(keep, c_new, state.c_k)
        sl_new = _sel(keep, sl_new, state.sl)
        zl_new = _sel(keep, zl_new, state.zl)
        cl_new = _sel(keep, cl_new, state.cl)
        if log_scale is not None:
            log_scale = _sel(keep, log_scale, state.log_scale)
    new = LogLinState(s=s_new, z=z_new, c_k=c_new, sl=sl_new, zl=zl_new,
                      cl=cl_new, log_scale=log_scale)
    return new, (cl_c, split, crossed, occ, occ2, sl2, zl2, cl2,
                 closed_s, closed_z, closed_c)


def _aggregate(sl, zl, cl, occ, w, c_out):
    """Weighted pyramid aggregate at reference ``c_out`` (B,1,H,1):
    ``sum_l occ_l * w_l * exp(cl_l - c_out) * (sl_l, zl_l)``.  Unoccupied
    levels are masked BEFORE the exp (stale ``cl`` must not overflow)."""
    c_o = c_out[:, 0, :, 0]                                  # (B,H)
    cl_occ = jnp.where(occ[..., None] > 0.5, cl, -jnp.inf)   # (B,L,H)
    wl = occ[..., None] * w[None, :, None] * jnp.exp(cl_occ - c_o[:, None, :])
    s_eff = jnp.einsum("blh,blhdv->bhdv", wl, sl)
    z_eff = jnp.einsum("blh,blhd->bhd", wl, zl)
    return s_eff, z_eff


def decode_chunk(state: LogLinState, q, k, v, alpha, beta, *,
                 pos, granule: int, num_scales: int, scale_decay: float,
                 row_mask: Optional[jnp.ndarray] = None,
                 commit_len: Optional[jnp.ndarray] = None,
                 renorm: Optional[float] = None):
    """Advance the multi-scale state over T new tokens.

    q/k/v: (B,T,H,D[v]) full heads; ``pos``: (B,) int32 tokens already in
    the state (per-row — rows at different depths see different bucket
    layouts).  Honors the serving contract of ``core/lln.py:decode_chunk``:
    ``row_mask`` rows stay bitwise inert, ``commit_len`` scores all T
    positions but folds only the accepted prefix, ``renorm`` bounds the
    carried magnitudes semantics-preservingly (per bucket).

    A chunk crosses at most one dyadic boundary when ``T <= granule``;
    longer chunks are processed in ``granule``-sized sub-chunks (full
    commit only — speculative drafts are never longer than a granule).
    Each position scores exactly what a sequential decode would see:
    pre-boundary queries mix pyramid(n) + open + intra; post-boundary
    queries mix pyramid(n+1) (which absorbed the closed granule — and with
    it every pre-boundary chunk key) + intra over post-boundary keys only.
    """
    b, t, h, d = q.shape
    if t > granule:
        if commit_len is not None:
            raise ValueError(
                "log_linear decode_chunk supports commit_len only for "
                f"T <= granule (T={t}, granule={granule})")
        outs = []
        pos = jnp.asarray(pos, jnp.int32)
        done = jnp.zeros((b,), jnp.int32)
        for i0 in range(0, t, granule):
            sl = slice(i0, min(i0 + granule, t))
            o, state = decode_chunk(
                state, q[:, sl], k[:, sl], v[:, sl], alpha, beta,
                pos=pos + done, granule=granule, num_scales=num_scales,
                scale_decay=scale_decay, row_mask=row_mask, renorm=renorm)
            step = sl.stop - sl.start
            adv = jnp.full((b,), step, jnp.int32)
            done = done + (jnp.where(row_mask, adv, 0)
                           if row_mask is not None else adv)
            outs.append(o)
        return jnp.concatenate(outs, axis=1), state
    ls = num_scales
    bk = (k * _bcast(beta, k)).astype(jnp.float32)
    aq = q * _bcast(alpha, q)
    fq = jnp.exp(aq - _stab_const(aq, (1, 3))).astype(jnp.float32)
    vf = v.astype(jnp.float32)
    w = level_weights(ls, scale_decay)
    new_state, aux = _advance(state, bk, vf, pos=pos, granule=granule,
                              num_scales=ls, row_mask=row_mask,
                              commit_len=commit_len, renorm=renorm, t=t)
    (cl_c, split, crossed, occ, occ2, sl2, zl2, cl2,
     closed_s, closed_z, closed_c) = aux
    # One scoring reference covering every bucket and every chunk key.
    cl_occ = jnp.where(occ[..., None] > 0.5, state.cl, -jnp.inf)  # (B,L,H)
    c_state = jnp.max(cl_occ, axis=1)[:, None, :, None]      # (B,1,H,1)
    c_out = jnp.maximum(jnp.maximum(state.c_k, c_state),
                        jax.lax.stop_gradient(
                            jnp.max(bk, axis=(1, 3), keepdims=True)))
    fk = jnp.exp(bk - c_out).astype(jnp.float32)
    # Pre-boundary view: pyramid(n) + open bucket.
    s_effa, z_effa = _aggregate(state.sl, state.zl, state.cl, occ, w, c_out)
    r_open = jnp.exp(state.c_k - c_out)[:, 0, :, 0]          # (B,H)
    s_effa = s_effa + state.s * r_open[..., None, None]
    z_effa = z_effa + state.z * r_open[..., None]
    # Post-boundary view: pyramid(n+1) only (the closed granule absorbed
    # the old open bucket and all pre-boundary chunk keys; post-boundary
    # chunk keys arrive via intra).
    s_effb, z_effb = _aggregate(sl2, zl2, cl2, occ2, w, c_out)
    # Intra: causal AND same-side-of-boundary (post-boundary queries see
    # pre-boundary chunk keys through pyramid(n+1), not intra).
    j = jnp.arange(t)
    tri = (j[:, None] >= j[None, :])
    side = ~((j[None, :, None] >= split[:, None, None])
             & (j[None, None, :] < split[:, None, None]))    # (B,T,T)
    mask = (tri[None] & side).astype(jnp.float32)
    scores = jnp.einsum("bihd,bjhd->bhij", fq, fk) * mask[:, None]
    intra = jnp.einsum("bhij,bjhv->bihv", scores, vf)
    intra_z = jnp.sum(scores, axis=-1).transpose(0, 2, 1)    # (B,T,H)
    inter_a = jnp.einsum("bihd,bhdv->bihv", fq, s_effa)
    inter_az = jnp.einsum("bihd,bhd->bih", fq, z_effa)
    inter_b = jnp.einsum("bihd,bhdv->bihv", fq, s_effb)
    inter_bz = jnp.einsum("bihd,bhd->bih", fq, z_effb)
    pre = j[None, :] < split[:, None]                        # (B,T)
    inter = jnp.where(pre[..., None, None], inter_a, inter_b)
    inter_z = jnp.where(pre[..., None], inter_az, inter_bz)
    out = (intra + inter) / (intra_z + inter_z + EPS)[..., None]
    return out.astype(v.dtype), new_state


def commit_chunk(state: LogLinState, k, v, beta, *,
                 pos, granule: int, num_scales: int,
                 row_mask: Optional[jnp.ndarray] = None,
                 commit_len: Optional[jnp.ndarray] = None,
                 renorm: Optional[float] = None) -> LogLinState:
    """Fold a scored chunk's accepted prefix WITHOUT scoring — the
    single-pass speculative-verify commit.  Runs the exact ``_advance``
    computation :func:`decode_chunk` runs, so it is bit-identical to
    re-running decode with the final ``commit_len``."""
    t = k.shape[1]
    if t > granule:
        raise ValueError(
            f"log_linear commit_chunk requires T <= granule "
            f"(T={t}, granule={granule})")
    bk = (k * _bcast(beta, k)).astype(jnp.float32)
    vf = v.astype(jnp.float32)
    new_state, _ = _advance(state, bk, vf, pos=pos, granule=granule,
                            num_scales=num_scales, row_mask=row_mask,
                            commit_len=commit_len, renorm=renorm, t=t)
    return new_state
