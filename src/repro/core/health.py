"""State-health sentinel: cheap per-row checks over attention decode state.

Linear-attention decode states are exactly where length pathologies
accumulate: the LLN ``(s, z, c_k)`` recurrence is a running sum, so a
single non-finite value — a poisoned activation, an overflowed feature, a
bad cache write — persists forever and silently corrupts every token the
row emits from then on ("The Devil in Linear Transformer" diagnoses the
unbounded-growth/dilution failure modes; "Critical attention scaling"
shows calibration drifts with context).  The serving stack therefore
checks state health PER ROW and quarantines only the poisoned slot
(``launch/batcher.py``) instead of letting one row take down the pool.

Checks (each yields a per-row bool, all OR-ed into ``unhealthy``):

* ``nonfinite`` — any NaN/Inf in any float leaf of the row;
* ``magnitude`` — any float state leaf with ``|x| > max_abs`` (running
  sums exploding long before they reach Inf);
* ``calib``     — per-row ``alpha``/``beta`` moment-matching constants
  outside ``(0, max_calib]`` (drifted or corrupted calibration).

The functions are pure jnp reductions (jit-safe, no host sync) designed
to be folded into an existing jitted step — ``PoolSetup.segment_fn``
computes them on the post-segment caches inside the same dispatch, so
the sentinel costs one fused reduction, not an extra round trip.  A free
(evicted) slot is all zeros with ``alpha = beta = 1`` and is healthy by
construction.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

_CALIB_NAMES = ("alpha", "beta")


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Sentinel thresholds.  ``max_abs`` bounds every float state leaf
    (LLN ``s``/``z``/``c_k``, KV rows, diag tails); ``max_calib`` bounds
    the per-row moment-matching constants.  Both are generous by design:
    the sentinel exists to catch corruption (NaN, Inf, runaway sums), not
    to second-guess healthy numerics.

    Concentration-drift thresholds (``check_drift``): the streaming
    telemetry (``core/metrics.py:streaming_concentration_tree``) runs in
    the same fused segment; a row whose ``|conc_drift|`` (log key mass
    per committed token) exceeds ``max_conc_drift`` is quarantined through
    the same re-prefill/replay recovery path as a corrupted row — drift
    is corruption in slow motion.  Off by default: enable for
    long-horizon serving (``launch/serve.py --drift``)."""
    max_abs: float = 1e6
    max_calib: float = 1e3
    check_nonfinite: bool = True
    check_magnitude: bool = True
    check_calib: bool = True
    check_drift: bool = False
    max_conc_drift: float = 20.0


def _leaf_name(path) -> str:
    last = path[-1]
    return str(getattr(last, "key", getattr(last, "idx", last)))


def _row_reduce(leaf: jnp.ndarray, row_axis: int, bad) -> jnp.ndarray:
    """OR-reduce ``bad(leaf)`` over every axis except ``row_axis`` ->
    (B,) bool."""
    axes = tuple(a for a in range(leaf.ndim) if a != row_axis)
    return jnp.any(bad, axis=axes)


def row_health(tree, *, row_axis: int = 0,
               config: HealthConfig = HealthConfig()) -> dict:
    """Per-row health flags for an attention-state pytree.

    ``tree``: any pytree of arrays whose float leaves carry the row
    (slot) axis at position ``row_axis`` — an ``AttentionState`` (row
    axis 0) or the pool's stacked-layer cache tree (layer axis first, row
    axis 1).  Integer leaves and leaves too small to carry the row axis
    are skipped.

    Returns ``{"unhealthy", "nonfinite", "magnitude", "calib"}``, each a
    (B,) bool array (``unhealthy`` is the OR of the enabled checks).
    Pure jnp; safe to call inside jit.
    """
    nonfinite = magnitude = calib = None

    def acc(cur, new):
        return new if cur is None else cur | new

    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        if not hasattr(leaf, "dtype") or not hasattr(leaf, "ndim"):
            continue
        if leaf.ndim <= row_axis or not jnp.issubdtype(leaf.dtype,
                                                       jnp.floating):
            continue
        name = _leaf_name(path)
        if name in _CALIB_NAMES:
            if config.check_calib:
                bad = (~jnp.isfinite(leaf) | (leaf <= 0.0)
                       | (leaf > config.max_calib))
                calib = acc(calib, _row_reduce(leaf, row_axis, bad))
            continue
        if config.check_nonfinite:
            nonfinite = acc(nonfinite,
                            _row_reduce(leaf, row_axis, ~jnp.isfinite(leaf)))
        if config.check_magnitude:
            bad = jnp.abs(leaf) > jnp.asarray(config.max_abs, leaf.dtype)
            magnitude = acc(magnitude, _row_reduce(leaf, row_axis, bad))

    if nonfinite is None and magnitude is None and calib is None:
        raise ValueError("state tree has no float leaves with a row axis "
                         f"at position {row_axis}")
    some = next(f for f in (nonfinite, magnitude, calib) if f is not None)
    zero = jnp.zeros_like(some)
    flags = {"nonfinite": nonfinite if nonfinite is not None else zero,
             "magnitude": magnitude if magnitude is not None else zero,
             "calib": calib if calib is not None else zero}
    flags["unhealthy"] = (flags["nonfinite"] | flags["magnitude"]
                          | flags["calib"])
    return flags


def unhealthy_rows(tree, *, row_axis: int = 0,
                   config: HealthConfig = HealthConfig()) -> jnp.ndarray:
    """(B,) bool: rows whose state fails any enabled health check."""
    return row_health(tree, row_axis=row_axis, config=config)["unhealthy"]


__all__ = ["HealthConfig", "row_health", "unhealthy_rows"]
