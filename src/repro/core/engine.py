"""The unified AttentionEngine: one spec, one state pytree, one lifecycle.

Every attention path in this repo — training forward, prefill, chunked
decode, continuous batching, MLA — now runs through this module:

* :class:`AttentionState` is the ONE decode-state pytree.  It carries the
  softmax KV cache (``k``/``v``/``len``), the LLN O(d^2) state
  (``s``/``z``/``c_k``), the §4.2 diag tails at the G kv heads
  (``tail_k``/``tail_v``), the MLA latent cache (``ckv``/``kr``) and the
  per-row serving contract (``pos``/``len`` (B,), ``alpha``/``beta``
  (B, H)) — unused fields are ``None`` and vanish from the pytree.
  Scalar-position static batching is just the degenerate case where every
  row agrees; there is no separate scalar cache layout any more.
* :class:`AttentionEngine` binds an :class:`~repro.kernels.registry.AttnSpec`
  to one layer's head geometry and exposes the lifecycle
  ``init_state -> prefill -> decode* -> evict``.  Backend selection
  (pallas / scan twin / jnp ref) is owned by ``kernels/registry.py``.

The legacy entry points (``attn_prefill``/``attn_decode``/
``attn_cache_init``/``mla_decode``/…) survive as thin shims delegating
here — see ``models/attention_block.py`` and ``docs/api.md`` for the
old→new migration table.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import attention as ca
from . import health as health_mod
from . import moment_matching as mm
from .attention import KVCache, LLNDecodeState, batch_alpha_beta
from .lln import LLNState, commit_lengths
from .loglinear import LogLinState
from repro.kernels import registry as kreg
from repro.kernels.registry import AttnSpec


# ---------------------------------------------------------------------------
# The one state pytree.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AttentionState:
    """Unified per-layer attention decode state (a registered pytree).

    Exactly one family of fields is populated per impl; ``None`` fields
    contribute no leaves:

    ==========  =======================================================
    softmax     ``k``/``v`` (B, S, G, D[v]) KV cache, ``len`` (B,)
    lln(+diag)  ``s`` (B,H,D,Dv) fp32, ``z`` (B,H,D) fp32, ``c_k``
                (B,1,H,1) fp32, ``tail_k``/``tail_v`` (B,BLK,G,D[v]),
                ``pos`` (B,), ``alpha``/``beta`` (B,H) fp32,
                ``log_scale`` (B,H) fp32 accumulated drift-renorm shift
    log_linear  lln leaves (no tails) plus the Fenwick bucket pyramid:
                ``sl`` (B,L,H,D,Dv), ``zl`` (B,L,H,D), ``cl`` (B,L,H)
                fp32 — level l summarizes a dyadic span of 2^l closed
                granules; occupancy is derived from ``pos``
                (``core/loglinear.py:occupancy``), so no extra counter
    MLA latent  ``ckv`` (B,S,kv_lora), ``kr`` (B,S,rd), ``len`` (B,)
    ==========  =======================================================

    Counters are ALWAYS per-row (B,): a static lockstep batch is simply
    every row holding the same value.  The pytree flattens with dict-style
    key paths (``DictKey``), so path-pattern consumers (the sharding rules
    in ``launch/steps.py:cache_shardings``, tree-walking tests) see the
    same leaf names the legacy dict caches used; ``state["pos"]`` works as
    an alias of ``state.pos`` for the same reason.
    """
    k: Optional[jnp.ndarray] = None
    v: Optional[jnp.ndarray] = None
    len: Optional[jnp.ndarray] = None
    s: Optional[jnp.ndarray] = None
    z: Optional[jnp.ndarray] = None
    c_k: Optional[jnp.ndarray] = None
    tail_k: Optional[jnp.ndarray] = None
    tail_v: Optional[jnp.ndarray] = None
    pos: Optional[jnp.ndarray] = None
    alpha: Optional[jnp.ndarray] = None
    beta: Optional[jnp.ndarray] = None
    log_scale: Optional[jnp.ndarray] = None
    sl: Optional[jnp.ndarray] = None
    zl: Optional[jnp.ndarray] = None
    cl: Optional[jnp.ndarray] = None
    ckv: Optional[jnp.ndarray] = None
    kr: Optional[jnp.ndarray] = None

    def __getitem__(self, name: str):
        """Dict-style read access (legacy cache-dict compatibility)."""
        if name not in _STATE_FIELDS:
            raise KeyError(name)
        return getattr(self, name)

    def replace(self, **kw) -> "AttentionState":
        return dataclasses.replace(self, **kw)


_STATE_FIELDS = tuple(f.name for f in dataclasses.fields(AttentionState))


def _state_flatten_with_keys(st: AttentionState):
    return ([(jax.tree_util.DictKey(n), getattr(st, n))
             for n in _STATE_FIELDS], None)


def _state_flatten(st: AttentionState):
    return tuple(getattr(st, n) for n in _STATE_FIELDS), None


def _state_unflatten(_, children) -> AttentionState:
    return AttentionState(**dict(zip(_STATE_FIELDS, children)))


jax.tree_util.register_pytree_with_keys(
    AttentionState, _state_flatten_with_keys, _state_unflatten,
    _state_flatten)


def _tail_of(t: jnp.ndarray, n: int, blk: int) -> jnp.ndarray:
    """Contents of the (partially filled) last ``blk``-sized block."""
    nb = -(-n // blk)
    last = (nb - 1) * blk
    pad = nb * blk - n
    return jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))[:, last:]


# ---------------------------------------------------------------------------
# The engine.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttentionEngine:
    """One attention configuration bound to one layer's head geometry.

    ``spec`` declares impl/causality/backend/chunking
    (:class:`~repro.kernels.registry.AttnSpec`); ``heads``/``kv_heads``/
    ``head_dim``/``v_dim`` are the layer's projection shapes and
    ``cache_dtype`` the KV/tail storage dtype.  All methods are pure and
    jit-safe; the engine object itself is static (hashable) and cheap to
    construct per call.

    Lifecycle::

        eng = AttentionEngine.from_cfg(cfg)          # or explicit dims
        state = eng.init_state(batch, max_len)       # zeroed, per-row
        out, state = eng.prefill(q, k, v, max_len=max_len)
        out, state = eng.decode(state, q1, k1, v1)   # T >= 1 tokens
        state = eng.evict(state, rows)               # free slots
    """
    spec: AttnSpec
    heads: int
    kv_heads: int
    head_dim: int
    v_dim: int
    # KV/tail storage dtype; None derives it from ``spec.precision`` (the
    # one declared source — pass cache_dtype only to override it).
    cache_dtype: Any = None

    @property
    def state_dtype(self):
        return (jnp.dtype(self.spec.precision) if self.cache_dtype is None
                else jnp.dtype(self.cache_dtype))

    @classmethod
    def from_cfg(cls, cfg, causal: bool = True, *,
                 heads: Optional[int] = None,
                 kv_heads: Optional[int] = None,
                 head_dim: Optional[int] = None,
                 v_dim: Optional[int] = None) -> "AttentionEngine":
        """Engine for an ``ArchConfig`` layer (dims overridable — MLA binds
        its assembled ``nope+rope`` q/k dim and its own v dim)."""
        h = heads if heads is not None else cfg.n_heads
        g = kv_heads if kv_heads is not None else cfg.n_kv_heads
        d = head_dim if head_dim is not None else cfg.hd
        spec = AttnSpec.from_cfg(cfg, causal=causal, r=h // g)
        return cls(spec=spec, heads=h, kv_heads=g, head_dim=d,
                   v_dim=v_dim if v_dim is not None else d)

    # -- lifecycle ----------------------------------------------------------

    def init_state(self, batch: int, max_len: int) -> AttentionState:
        """Zeroed decode state for ``batch`` rows.  Always per-row: ``len``
        / ``pos`` are (B,) and calibration is (B, H) — the static lockstep
        batch is the degenerate case where all rows stay equal."""
        h, g, d, dv = self.heads, self.kv_heads, self.head_dim, self.v_dim
        if self.spec.impl == "softmax":
            return AttentionState(
                k=jnp.zeros((batch, max_len, g, d), self.state_dtype),
                v=jnp.zeros((batch, max_len, g, dv), self.state_dtype),
                len=jnp.zeros((batch,), jnp.int32))
        if self.spec.impl == "log_linear":
            ls = self.spec.num_scales
            return AttentionState(
                s=jnp.zeros((batch, h, d, dv), jnp.float32),
                z=jnp.zeros((batch, h, d), jnp.float32),
                c_k=jnp.zeros((batch, 1, h, 1), jnp.float32),
                sl=jnp.zeros((batch, ls, h, d, dv), jnp.float32),
                zl=jnp.zeros((batch, ls, h, d), jnp.float32),
                cl=jnp.zeros((batch, ls, h), jnp.float32),
                pos=jnp.zeros((batch,), jnp.int32),
                alpha=jnp.ones((batch, h), jnp.float32),
                beta=jnp.ones((batch, h), jnp.float32),
                log_scale=jnp.zeros((batch, h), jnp.float32))
        blk = self.spec.diag_block
        return AttentionState(
            s=jnp.zeros((batch, h, d, dv), jnp.float32),
            z=jnp.zeros((batch, h, d), jnp.float32),
            c_k=jnp.zeros((batch, 1, h, 1), jnp.float32),
            tail_k=jnp.zeros((batch, blk, g, d), self.state_dtype),
            tail_v=jnp.zeros((batch, blk, g, dv), self.state_dtype),
            pos=jnp.zeros((batch,), jnp.int32),
            alpha=jnp.ones((batch, h), jnp.float32),
            beta=jnp.ones((batch, h), jnp.float32),
            log_scale=jnp.zeros((batch, h), jnp.float32))

    def calibrate(self, q, k, n: Optional[int] = None):
        """Moment-matched (alpha, beta) per ``spec.calibration`` —
        ``batch`` pools statistics (training semantics), ``per_row``
        measures each row alone ((B, H)/(B, G); admission semantics).
        ``n`` (static) selects length-aware (a, b) constants when the
        beta(n) schedule is on; ignored otherwise."""
        return batch_alpha_beta(q, k, self.spec,
                                per_row=self.spec.calibration == "per_row",
                                n=n)

    def _length_gain(self, n):
        """beta(n) schedule gain for a depth ``n`` (static int or traced
        per-row (B,) positions); None when the schedule is off."""
        if self.spec.beta_n <= 0.0 or self.spec.impl == "softmax":
            return None
        return mm.length_gain(n, self.spec.beta_n, self.spec.calib_len)

    def attention(self, q, k, v, *, mask=None, alpha=None, beta=None,
                  prefix_len: int = 0):
        """Stateless full-sequence attention (training / scoring).
        q: (B,N,H,D); k/v: (B,N,G,D[v]).  Softmax ``backend='ref'`` is the
        naive quadratic; other softmax backends run flash."""
        spec = self.spec
        if spec.impl == "softmax":
            if spec.backend == "ref":
                return ca.naive_softmax(q, k, v, causal=spec.causal,
                                        mask=mask, prefix_len=prefix_len)
            return ca.flash_softmax(q, k, v, causal=spec.causal,
                                    chunk=min(spec.softmax_chunk,
                                              k.shape[1]),
                                    mask=mask, prefix_len=prefix_len)
        if alpha is None or beta is None:
            # Calibrate HERE so spec.calibration="per_row" applies to the
            # full-sequence forward too (multi_head_attention's internal
            # batch_alpha_beta only knows the batch-pooled mode).
            alpha, beta = self.calibrate(q, k, n=q.shape[1])
            gain = self._length_gain(q.shape[1])
            if gain is not None:
                alpha = jnp.asarray(alpha, jnp.float32) * gain
                beta = jnp.asarray(beta, jnp.float32) * gain
        acfg = ca.AttnConfig(
            impl=spec.impl, causal=spec.causal, diag_block=spec.diag_block,
            lln_chunk=spec.lln_chunk, softmax_chunk=spec.softmax_chunk,
            use_kernel=spec.backend != "ref",
            backend=None if spec.backend == "auto" else spec.backend,
            fixed_ab=spec.fixed_ab, mm_a=spec.mm_a, mm_b=spec.mm_b,
            num_scales=spec.num_scales, scale_decay=spec.scale_decay)
        return ca.multi_head_attention(q, k, v, acfg, mask=mask,
                                       alpha=alpha, beta=beta,
                                       prefix_len=prefix_len)

    def prefill(self, q, k, v, *, max_len: int, prefix_len: int = 0,
                alpha=None, beta=None):
        """Causal forward over the prompt; returns ``(out, state)``.

        q: (B,N,H,D); k/v: (B,N,G,D[v]).  The softmax KV cache is padded to
        ``max_len`` so decode appends in place; LLN gets outputs AND the
        O(d^2) state from one pass (``kernels/ops.py:lln_prefill`` under
        ``spec.backend``) plus the diag tail at the G kv heads.
        ``alpha``/``beta`` override the moment-matching calibration.
        """
        b, n, h, _ = q.shape
        g = k.shape[2]
        spec = self.spec
        if spec.impl == "softmax":
            if spec.backend == "ref":     # independent quadratic oracle
                out = ca.naive_softmax(q, k, v, causal=spec.causal,
                                       prefix_len=prefix_len)
            else:
                out = ca.flash_softmax(q, k, v, causal=spec.causal,
                                       chunk=min(spec.softmax_chunk, n),
                                       prefix_len=prefix_len)
            pad = ((0, 0), (0, max_len - n), (0, 0), (0, 0))
            return out, AttentionState(
                k=jnp.pad(k.astype(self.state_dtype), pad),
                v=jnp.pad(v.astype(self.state_dtype), pad),
                len=jnp.full((b,), n, jnp.int32))
        if alpha is None or beta is None:
            alpha, beta = self.calibrate(q, k, n=n)
        # beta(n) schedule: the prefill forward runs at the prompt-length
        # temperature, but the state stores the BASE calibration — decode
        # re-derives each row's effective temperature from its own pos, so
        # the gain is never baked in twice.
        gain = self._length_gain(n)
        use_alpha, use_beta = alpha, beta
        if gain is not None:
            use_alpha = jnp.asarray(alpha, jnp.float32) * gain
            use_beta = jnp.asarray(beta, jnp.float32) * gain
        if spec.impl == "log_linear":
            out, s, z, c_k, sl, zl, cl = kreg.loglin_prefill(
                spec, q, k, v, use_alpha, use_beta)
            beta_h = jnp.asarray(beta, jnp.float32)
            if beta_h.shape[-1] == g and g != h:
                beta_h = jnp.repeat(beta_h, h // g, axis=-1)
            state = AttentionState(
                s=s, z=z, c_k=c_k, sl=sl, zl=zl, cl=cl,
                pos=jnp.full((b,), n, jnp.int32),
                alpha=jnp.broadcast_to(jnp.asarray(alpha, jnp.float32),
                                       (b, h)).astype(jnp.float32),
                beta=jnp.broadcast_to(beta_h, (b, h)).astype(jnp.float32),
                log_scale=jnp.zeros((b, h), jnp.float32))
            return out, state
        lln_out, s, z, c_k = kreg.prefill(spec, q, k, v, use_alpha,
                                          use_beta)
        if spec.impl == "lln_diag":
            diag_out = kreg.diag_fwd(spec, q, k, v)
            out = (0.5 * (lln_out.astype(jnp.float32)
                          + diag_out.astype(jnp.float32))).astype(v.dtype)
        else:
            out = lln_out
        blk = spec.diag_block
        beta_h = jnp.asarray(beta, jnp.float32)
        if beta_h.shape[-1] == g and g != h:
            beta_h = jnp.repeat(beta_h, h // g, axis=-1)
        state = AttentionState(
            s=s, z=z, c_k=c_k,
            tail_k=_tail_of(k, n, blk).astype(self.state_dtype),
            tail_v=_tail_of(v, n, blk).astype(self.state_dtype),
            pos=jnp.full((b,), n, jnp.int32),
            alpha=jnp.broadcast_to(jnp.asarray(alpha, jnp.float32),
                                   (b, h)).astype(jnp.float32),
            beta=jnp.broadcast_to(beta_h, (b, h)).astype(jnp.float32),
            log_scale=jnp.zeros((b, h), jnp.float32))
        return out, state

    def decode(self, state: AttentionState, q, k, v, *,
               row_mask: Optional[jnp.ndarray] = None,
               commit_len: Optional[jnp.ndarray] = None):
        """Advance ``state`` over T >= 1 new tokens; returns
        ``(out (B,T,H,Dv), new state)``.

        Positions come from the state itself (``len``/``pos`` are per-row
        (B,)).  ``row_mask`` (B,) bool: masked rows advance NOTHING and
        their outputs must be discarded (the continuous-batching
        contract).  ``commit_len`` (B,) int32 in [0, T]: the speculative
        partial-commit contract — all T positions are scored, but only
        the accepted prefix folds into the state (see :meth:`verify`).
        """
        spec = self.spec
        if spec.impl == "softmax":
            out, kv2 = ca.decode_softmax(
                KVCache(k=state.k, v=state.v, length=state.len),
                q, k, v, chunk=spec.softmax_chunk, row_mask=row_mask,
                commit_len=commit_len)
            return out, state.replace(k=kv2.k, v=kv2.v, len=kv2.length)
        # beta(n) schedule: each row's effective calibration keys off its
        # OWN depth (state.pos) — a 400k-context row and a 2k row in the
        # same pool decode at different temperatures.  The stored
        # alpha/beta stay base; the gain is recomputed every chunk.
        alpha_d, beta_d = state.alpha, state.beta
        gain = self._length_gain(state.pos)
        if gain is not None:
            gain = gain[..., None] if gain.ndim else gain    # (B,1) / ()
            alpha_d = state.alpha * gain
            beta_d = state.beta * gain
        if spec.impl == "log_linear":
            st = LogLinState(s=state.s, z=state.z, c_k=state.c_k,
                             sl=state.sl, zl=state.zl, cl=state.cl,
                             log_scale=state.log_scale)
            out, st2 = kreg.decode_chunk(spec, st, q, k, v, alpha_d,
                                         beta_d, row_mask=row_mask,
                                         commit_len=commit_len,
                                         pos=state.pos)
            t = q.shape[1]
            adv = commit_lengths(
                commit_len if commit_len is not None
                else jnp.full((q.shape[0],), t, jnp.int32), row_mask, t)
            return out, state.replace(
                s=st2.s, z=st2.z, c_k=st2.c_k, sl=st2.sl, zl=st2.zl,
                cl=st2.cl, log_scale=st2.log_scale, pos=state.pos + adv)
        st = LLNDecodeState(
            lln=LLNState(s=state.s, z=state.z, c_k=state.c_k,
                         log_scale=state.log_scale),
            tail_k=state.tail_k, tail_v=state.tail_v, pos=state.pos)
        out, st2 = ca.decode_lln_chunk(st, q, k, v, alpha_d, beta_d,
                                       impl=spec.impl, row_mask=row_mask,
                                       backend=spec.backend,
                                       commit_len=commit_len,
                                       renorm=spec.renorm or None)
        return out, state.replace(
            s=st2.lln.s, z=st2.lln.z, c_k=st2.lln.c_k,
            log_scale=st2.lln.log_scale,
            tail_k=st2.tail_k, tail_v=st2.tail_v, pos=st2.pos)

    def verify(self, state: AttentionState, q, k, v, *, commit_len,
               row_mask: Optional[jnp.ndarray] = None,
               return_residuals: bool = False):
        """Speculative verify: score a T-token draft chunk, commit only the
        accepted prefix.

        Identical to :meth:`decode` except ``commit_len`` (B,) int32 is
        required: outputs cover ALL T draft positions (each position
        attends exactly the keys a sequential decode would have seen), but
        the state — LLN ``(s, z, c_k)``, diag tails, softmax KV rows,
        ``pos``/``len`` — folds only tokens ``j < commit_len[b]``.
        ``commit_len=0`` rows behave exactly like ``row_mask=False`` rows;
        ``commit_len=T`` is a plain decode.  A rejected draft token is
        therefore never popped — it simply never enters the running sums.

        ``return_residuals=True`` additionally returns the layer's commit
        residuals ``{"k", "v"}`` — the post-RoPE (B,T,G,D[v]) chunk keys
        and values — as a third element.  A ``commit_len=0`` score pass
        leaves the state bitwise unchanged, so the single-pass verify flow
        is: score once with ``commit_len=0`` + ``return_residuals=True``,
        run the acceptance rule on the logits, then fold the accepted
        prefix with the cheap O(T d^2) :meth:`commit` — no second full
        pass over the model.
        """
        if commit_len is None:
            raise ValueError("verify requires commit_len; use decode for "
                             "an unconditional advance")
        out, st = self.decode(state, q, k, v, row_mask=row_mask,
                              commit_len=commit_len)
        if return_residuals:
            return out, st, {"k": k, "v": v}
        return out, st

    def commit(self, state: AttentionState, residual: dict, *, commit_len,
               row_mask: Optional[jnp.ndarray] = None) -> AttentionState:
        """Fold a scored chunk's accepted prefix into ``state`` — the
        cheap second half of single-pass speculative verify.

        ``residual``: the ``{"k", "v"}`` dict a ``commit_len=0``
        :meth:`verify` returned (post-RoPE, (B,T,G,D[v])).  ``state`` must
        be the state that verify pass ran against (a ``commit_len=0``
        score leaves it bitwise unchanged).  Per backend this is
        bit-identical to re-running :meth:`verify` with the final
        ``commit_len`` — O(T d^2) per layer instead of a full transformer
        pass.  The beta(n) gain is re-derived from ``state.pos`` exactly
        as the score pass derived it (``pos`` did not advance).
        """
        k, v = residual["k"], residual["v"]
        spec = self.spec
        if spec.impl == "softmax":
            kv2 = ca.commit_softmax(
                KVCache(k=state.k, v=state.v, length=state.len), k, v,
                commit_len=commit_len, row_mask=row_mask)
            return state.replace(k=kv2.k, v=kv2.v, len=kv2.length)
        beta_d = state.beta
        gain = self._length_gain(state.pos)
        if gain is not None:
            gain = gain[..., None] if gain.ndim else gain
            beta_d = state.beta * gain
        if spec.impl == "log_linear":
            st = LogLinState(s=state.s, z=state.z, c_k=state.c_k,
                             sl=state.sl, zl=state.zl, cl=state.cl,
                             log_scale=state.log_scale)
            st2 = kreg.commit_chunk(spec, st, k, v, beta_d,
                                    row_mask=row_mask,
                                    commit_len=commit_len, pos=state.pos)
            t = k.shape[1]
            adv = commit_lengths(
                commit_len if commit_len is not None
                else jnp.full((k.shape[0],), t, jnp.int32), row_mask, t)
            return state.replace(
                s=st2.s, z=st2.z, c_k=st2.c_k, sl=st2.sl, zl=st2.zl,
                cl=st2.cl, log_scale=st2.log_scale, pos=state.pos + adv)
        st = LLNDecodeState(
            lln=LLNState(s=state.s, z=state.z, c_k=state.c_k,
                         log_scale=state.log_scale),
            tail_k=state.tail_k, tail_v=state.tail_v, pos=state.pos)
        st2 = ca.commit_lln_chunk(st, k, v, beta_d, impl=spec.impl,
                                  commit_len=commit_len, row_mask=row_mask,
                                  backend=spec.backend,
                                  renorm=spec.renorm or None)
        return state.replace(
            s=st2.lln.s, z=st2.lln.z, c_k=st2.lln.c_k,
            log_scale=st2.lln.log_scale,
            tail_k=st2.tail_k, tail_v=st2.tail_v, pos=st2.pos)

    def check_health(self, state: AttentionState, *,
                     config: Optional["health_mod.HealthConfig"] = None
                     ) -> dict:
        """Per-row state-health flags (the serving sentinel hook).

        Returns ``{"unhealthy", "nonfinite", "magnitude", "calib"}``,
        each a (B,) bool over the state's row axis: non-finite or
        magnitude-exploding ``(s, z, c_k)``/KV/tail leaves, and per-row
        ``alpha``/``beta`` outside the calibration bounds
        (``core/health.py:HealthConfig``).  Pure jnp — callers fold it
        into their own jitted step (``PoolSetup.segment_fn`` runs it on
        the post-segment pool caches in the same dispatch).  A freshly
        evicted row (zeros, alpha/beta = 1) is healthy by construction.
        """
        cfg = config if config is not None else health_mod.HealthConfig()
        return health_mod.row_health(state, row_axis=0, config=cfg)

    def evict(self, state: AttentionState, rows) -> AttentionState:
        """Reset the given rows (freed slots) of every state leaf to their
        ``init_state`` values.

        ``rows``: (k,) int32 slot indices, or a (B,) bool mask of rows to
        clear.  Every leaf resets to zero EXCEPT the per-row calibration
        ``alpha``/``beta``, which reset to ones (their init value) — a
        previous request's moment-matching constants must never leak into
        the next request admitted to that slot.  Semantically eviction is
        belt-and-braces — admission overwrites a slot's rows wholesale —
        but resetting freed slots keeps stale request state from outliving
        its request (and makes the lifecycle testable).
        """
        rows = jnp.asarray(rows)
        if rows.dtype == jnp.bool_:
            def clear(path, leaf):
                name = getattr(path[-1], "key", None)
                fill = (jnp.ones((), leaf.dtype)
                        if name in ("alpha", "beta")
                        else jnp.zeros((), leaf.dtype))
                keep = ~rows.reshape((-1,) + (1,) * (leaf.ndim - 1))
                return jnp.where(keep, leaf, fill)
        else:
            def clear(path, leaf):
                name = getattr(path[-1], "key", None)
                fill = (jnp.ones((), leaf.dtype)
                        if name in ("alpha", "beta")
                        else jnp.zeros((), leaf.dtype))
                return leaf.at[rows].set(fill)
        return jax.tree_util.tree_map_with_path(clear, state)


__all__ = ["AttentionState", "AttentionEngine", "AttnSpec"]
