"""Moment matching between LLN and Softmax attention (paper Appendix A.7).

Prop. 4.1 (broad regime): Var[ln P^(LLN)] ~= a * sigma_tilde^2 + b, with
sigma_tilde^2 = alpha^2 sigma_q^2 + beta^2 sigma_k^2.  The softmax attention
matrix has Var[ln P^(SM)] = sigma_q^2 sigma_k^2 (+ C_cross) (Prop. 3.1).

Matching the variances (eq. 34) and splitting symmetrically
(alpha^2 sigma_q^2 = beta^2 sigma_k^2 = sigma_tilde^2 / 2) gives eq. 10:

    alpha = sigma_tilde / (sqrt(2) * sigma_q)
    beta  = sigma_tilde / (sqrt(2) * sigma_k)
    sigma_tilde = sqrt((sigma_q^2 sigma_k^2 - b) / a)

(a, b) are fit once by linear regression of the *measured* LLN log-variance
against sigma_tilde^2 on synthetic Gaussian inputs (the paper's "linear
interpolation on randomly generated Gaussian samples").  The defaults below
were produced by :func:`fit_lln_constants` with d=64, n=1024 over
sigma_tilde^2 in [1, 36] (the paper's range of interest, App. A.7) and can be
regenerated with ``python -m repro.core.moment_matching``.

Length-aware extension (serving): the fit depends on the sequence length N
the attention matrix is formed over, so :data:`FITTED_CONSTANTS_N` carries
(a, b) on a grid over N as well as d, and :func:`solve_alpha_beta` accepts
``n=`` plus a beta(n) log-length temperature schedule (:func:`length_gain`)
that counteracts the dilution a linear-attention recurrence develops as the
context outgrows the calibration length ("Critical attention scaling" /
"The Devil in Linear Transformer", PAPERS.md).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Broad-regime constants fit on sigma_tilde^2 in [1, 36], N=1024 (regenerate
# via __main__).  Keyed by head_dim; nearest entry is used for other dims.
# Note: with these constants and sigma_q = sigma_k = 1, eq. 10 yields
# alpha = beta ~= 2.1-2.3 — reproducing the paper's observed moment-matching
# range (2, 2.2) in Fig. 9.
FITTED_CONSTANTS: dict[int, Tuple[float, float]] = {
    64: (0.1935, -0.7577),
    128: (0.1706, -0.7442),
}
DEFAULT_A, DEFAULT_B = FITTED_CONSTANTS[64]

# Length-aware fit: (a, b) on a grid over sequence length N as well as head
# dim, produced by ``python -m repro.core.moment_matching --grid`` (seeded,
# num_seeds=4).  Used by length-aware calibration
# (``constants_for_dim(d, n=...)``); plain callers keep the legacy
# FITTED_CONSTANTS defaults above (stable since the seed) so length-unaware
# paths are bit-identical to before the grid existed.
CALIB_LEN = 1024  # reference length n0 the schedules are anchored at
FITTED_CONSTANTS_N: dict[int, dict[int, Tuple[float, float]]] = {
    64: {256: (0.1994, -0.7749), 1024: (0.1873, -0.6735),
         4096: (0.1837, -0.6729)},
    128: {256: (0.1674, -0.7008), 1024: (0.1620, -0.6534),
          4096: (0.1601, -0.6568)},
}


def constants_for_dim(head_dim: int, n: int | None = None,
                      ) -> Tuple[float, float]:
    """Nearest calibrated (a, b) for a head dimension.

    With ``n`` (a static sequence length) ABOVE the calibration length,
    picks the nearest-N entry of the length-aware grid
    :data:`FITTED_CONSTANTS_N` (nearest in log N).  With ``n=None`` or
    ``n <= CALIB_LEN`` returns the legacy defaults unchanged, so
    length-aware calibration reduces exactly to the fixed calibration at
    or below the calibration length.
    """
    best = min(FITTED_CONSTANTS, key=lambda d: abs(d - head_dim))
    if n is None or int(n) <= CALIB_LEN:
        return FITTED_CONSTANTS[best]
    grid = FITTED_CONSTANTS_N[best]
    ln = float(np.log(max(int(n), 1)))
    bn = min(grid, key=lambda m: abs(float(np.log(m)) - ln))
    return grid[bn]


# ---------------------------------------------------------------------------
# Attention-matrix constructors on raw Gaussian inputs (analysis-scale only).
# ---------------------------------------------------------------------------

def softmax_attn_matrix(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """P^(SM) (eq. 6) for q,k: (N, d).  Returns (N, N) rows summing to 1."""
    scores = (q @ k.T) / jnp.sqrt(q.shape[-1]).astype(q.dtype)
    return jax.nn.softmax(scores, axis=-1)


def lln_attn_matrix(q: jnp.ndarray, k: jnp.ndarray, alpha: float,
                    beta: float) -> jnp.ndarray:
    """P^(LLN) (eq. 9) for q,k: (N, d).  Returns (N, N) rows summing to 1."""
    fq = jnp.exp(alpha * q - jnp.max(alpha * q))
    fk = jnp.exp(beta * k - jnp.max(beta * k))
    scores = fq @ fk.T
    return scores / (jnp.sum(scores, axis=-1, keepdims=True) + 1e-30)


def log_variance(p: jnp.ndarray) -> jnp.ndarray:
    """Variance of ln(P) — the log-normal shape parameter estimate."""
    logp = jnp.log(jnp.clip(p, 1e-30, None))
    return jnp.var(logp)


# ---------------------------------------------------------------------------
# (a, b) calibration — paper App. A.7.
# ---------------------------------------------------------------------------

def fit_lln_constants(
    d: int = 64,
    n: int = 1024,
    sigma_tilde_sq: np.ndarray | None = None,
    num_seeds: int = 4,
    seed: int = 0,
) -> Tuple[float, float]:
    """Fit Var[ln P^(LLN)] = a * sigma_tilde^2 + b on Gaussian samples.

    Uses alpha = beta = 1 and sigma_q = sigma_k = sigma_tilde / sqrt(2), so the
    abscissa is exactly sigma_tilde^2 = alpha^2 s_q^2 + beta^2 s_k^2.
    """
    if sigma_tilde_sq is None:
        sigma_tilde_sq = np.linspace(1.0, 36.0, 15)
    xs, ys = [], []
    key = jax.random.PRNGKey(seed)
    for s2 in sigma_tilde_sq:
        sig = float(np.sqrt(s2 / 2.0))
        for _ in range(num_seeds):
            key, kq, kk = jax.random.split(key, 3)
            q = sig * jax.random.normal(kq, (n, d), jnp.float32)
            k = sig * jax.random.normal(kk, (n, d), jnp.float32)
            p = lln_attn_matrix(q, k, 1.0, 1.0)
            xs.append(s2)
            ys.append(float(log_variance(p)))
    a, b = np.polyfit(np.asarray(xs), np.asarray(ys), 1)
    return float(a), float(b)


def fit_lln_constants_grid(
    d: int = 64,
    ns: Tuple[int, ...] = (256, 1024, 4096),
    num_seeds: int = 4,
    seed: int = 0,
) -> dict[int, Tuple[float, float]]:
    """Length-aware fit: (a, b) per sequence length N (FITTED_CONSTANTS_N)."""
    return {n: fit_lln_constants(d=d, n=n, num_seeds=num_seeds, seed=seed)
            for n in ns}


# ---------------------------------------------------------------------------
# beta(n) log-length temperature schedule.
# ---------------------------------------------------------------------------

def length_gain(n, beta_n: float = 0.0, calib_len: int = CALIB_LEN):
    """Multiplicative gain g(n) on (alpha, beta) for a row at depth n.

    g(n) = sqrt(1 + beta_n * ln(n / n0)) for n > n0, and exactly 1 for
    n <= n0 (= ``calib_len``), so the schedule is the identity at or below
    the calibration length.  Scaling both alpha and beta by g inflates the
    matched log-variance sigma_tilde^2 by (1 + beta_n ln(n/n0)) — the
    logit-scale beta ~ log n temperature growth "Critical attention scaling"
    shows attention needs, which counteracts the 1/N dilution of new tokens
    in the linear recurrence.  ``n`` may be a traced per-row (B,) position
    array; the result broadcasts like n.
    """
    if beta_n <= 0.0:
        return jnp.ones_like(jnp.asarray(n, jnp.float32))
    nf = jnp.maximum(jnp.asarray(n, jnp.float32), 1.0)
    ratio = jnp.maximum(nf / float(max(calib_len, 1)), 1.0)
    return jnp.sqrt(1.0 + float(beta_n) * jnp.log(ratio))


def solve_alpha_beta(
    sigma_q: jnp.ndarray,
    sigma_k: jnp.ndarray,
    a: float = DEFAULT_A,
    b: float = DEFAULT_B,
    min_sigma_tilde_sq: float = 1e-4,
    n=None,
    beta_n: float = 0.0,
    calib_len: int = CALIB_LEN,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Eq. 10.  sigma_q/sigma_k: scalars or per-head arrays; gradients blocked
    (moment matching is a calibration, not a learning signal).

    ``n`` (optional) is the sequence length / row depth the calibration is
    for: the solved (alpha, beta) are scaled by the beta(n) schedule
    :func:`length_gain` (identity when ``beta_n=0`` or ``n <= calib_len``).
    Pass a (B,)-shaped ``n`` for per-row length-aware calibration; the gain
    broadcasts against per-head solutions as (B, 1).
    """
    sq = jax.lax.stop_gradient(jnp.asarray(sigma_q, jnp.float32))
    sk = jax.lax.stop_gradient(jnp.asarray(sigma_k, jnp.float32))
    sigma_sm_sq = jnp.square(sq) * jnp.square(sk)
    st = jnp.sqrt(jnp.maximum((sigma_sm_sq - b) / a, min_sigma_tilde_sq))
    alpha = st / (jnp.sqrt(2.0) * jnp.maximum(sq, 1e-4))
    beta = st / (jnp.sqrt(2.0) * jnp.maximum(sk, 1e-4))
    if n is not None and beta_n > 0.0:
        gain = length_gain(n, beta_n, calib_len)
        if gain.ndim and alpha.ndim > gain.ndim:   # (B,) gain vs (B, H) sol
            gain = gain[..., None]
        alpha = alpha * gain
        beta = beta * gain
    return alpha, beta


# ---------------------------------------------------------------------------
# Running input statistics (per-head EMA of sigma_q / sigma_k).
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QKStats:
    """Per-head EMA of query/key standard deviations (batchnorm-style)."""
    sigma_q: jnp.ndarray   # (H,)
    sigma_k: jnp.ndarray   # (H,)

    @staticmethod
    def init(heads: int) -> "QKStats":
        return QKStats(sigma_q=jnp.ones((heads,), jnp.float32),
                       sigma_k=jnp.ones((heads,), jnp.float32))


def _masked_rms(x: jnp.ndarray, mask: jnp.ndarray | None) -> jnp.ndarray:
    """Per-head RMS over (B, N, D) of a (B, N, H, D) tensor, optionally
    excluding padded positions via a (B, N) mask."""
    x2 = jnp.square(x.astype(jnp.float32))
    if mask is None:
        return jnp.sqrt(jnp.mean(x2, axis=(0, 1, 3)))
    m = jnp.asarray(mask, jnp.float32)[:, :, None, None]
    num = jnp.sum(x2 * m, axis=(0, 1, 3))
    den = jnp.maximum(jnp.sum(m) * x.shape[-1], 1.0)
    return jnp.sqrt(num / den)


def update_stats(stats: QKStats, q: jnp.ndarray, k: jnp.ndarray,
                 decay: float = 0.99,
                 mask: jnp.ndarray | None = None) -> QKStats:
    """EMA update from a (B, N, H, D) batch; gradients blocked.

    ``mask`` (optional, (B, N), 1 = real token) excludes padded positions
    from the per-head RMS so ragged batches don't pollute the EMA toward
    zero (padding contributes exact-zero q/k rows).
    """
    sq = jax.lax.stop_gradient(_masked_rms(q, mask))
    sk = jax.lax.stop_gradient(_masked_rms(k, mask))
    return QKStats(sigma_q=decay * stats.sigma_q + (1 - decay) * sq,
                   sigma_k=decay * stats.sigma_k + (1 - decay) * sk)


def matched_alpha_beta(stats: QKStats, a: float = DEFAULT_A,
                       b: float = DEFAULT_B) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return solve_alpha_beta(stats.sigma_q, stats.sigma_k, a, b)


if __name__ == "__main__":
    import sys
    if "--grid" in sys.argv:
        for d in sorted(FITTED_CONSTANTS_N):
            got = fit_lln_constants_grid(d=d)
            print(f"d={d}: " + ", ".join(
                f"n={n}: ({a:.4f}, {b:.4f})" for n, (a, b) in got.items()))
    else:
        a, b = fit_lln_constants()
        print(f"fit: a={a:.4f} b={b:.4f}  "
              f"(defaults: a={DEFAULT_A} b={DEFAULT_B})")
