"""Moment matching between LLN and Softmax attention (paper Appendix A.7).

Prop. 4.1 (broad regime): Var[ln P^(LLN)] ~= a * sigma_tilde^2 + b, with
sigma_tilde^2 = alpha^2 sigma_q^2 + beta^2 sigma_k^2.  The softmax attention
matrix has Var[ln P^(SM)] = sigma_q^2 sigma_k^2 (+ C_cross) (Prop. 3.1).

Matching the variances (eq. 34) and splitting symmetrically
(alpha^2 sigma_q^2 = beta^2 sigma_k^2 = sigma_tilde^2 / 2) gives eq. 10:

    alpha = sigma_tilde / (sqrt(2) * sigma_q)
    beta  = sigma_tilde / (sqrt(2) * sigma_k)
    sigma_tilde = sqrt((sigma_q^2 sigma_k^2 - b) / a)

(a, b) are fit once by linear regression of the *measured* LLN log-variance
against sigma_tilde^2 on synthetic Gaussian inputs (the paper's "linear
interpolation on randomly generated Gaussian samples").  The defaults below
were produced by :func:`fit_lln_constants` with d=64, n=1024 over
sigma_tilde^2 in [1, 4] (the paper's range of interest, App. A.7) and can be
regenerated with ``python -m repro.core.moment_matching``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Broad-regime constants fit on sigma_tilde^2 in [1, 36], N=1024 (regenerate
# via __main__).  Keyed by head_dim; nearest entry is used for other dims.
# Note: with these constants and sigma_q = sigma_k = 1, eq. 10 yields
# alpha = beta ~= 2.1-2.3 — reproducing the paper's observed moment-matching
# range (2, 2.2) in Fig. 9.
FITTED_CONSTANTS: dict[int, Tuple[float, float]] = {
    64: (0.1935, -0.7577),
    128: (0.1706, -0.7442),
}
DEFAULT_A, DEFAULT_B = FITTED_CONSTANTS[64]


def constants_for_dim(head_dim: int) -> Tuple[float, float]:
    """Nearest calibrated (a, b) for a head dimension."""
    best = min(FITTED_CONSTANTS, key=lambda d: abs(d - head_dim))
    return FITTED_CONSTANTS[best]


# ---------------------------------------------------------------------------
# Attention-matrix constructors on raw Gaussian inputs (analysis-scale only).
# ---------------------------------------------------------------------------

def softmax_attn_matrix(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """P^(SM) (eq. 6) for q,k: (N, d).  Returns (N, N) rows summing to 1."""
    scores = (q @ k.T) / jnp.sqrt(q.shape[-1]).astype(q.dtype)
    return jax.nn.softmax(scores, axis=-1)


def lln_attn_matrix(q: jnp.ndarray, k: jnp.ndarray, alpha: float,
                    beta: float) -> jnp.ndarray:
    """P^(LLN) (eq. 9) for q,k: (N, d).  Returns (N, N) rows summing to 1."""
    fq = jnp.exp(alpha * q - jnp.max(alpha * q))
    fk = jnp.exp(beta * k - jnp.max(beta * k))
    scores = fq @ fk.T
    return scores / (jnp.sum(scores, axis=-1, keepdims=True) + 1e-30)


def log_variance(p: jnp.ndarray) -> jnp.ndarray:
    """Variance of ln(P) — the log-normal shape parameter estimate."""
    logp = jnp.log(jnp.clip(p, 1e-30, None))
    return jnp.var(logp)


# ---------------------------------------------------------------------------
# (a, b) calibration — paper App. A.7.
# ---------------------------------------------------------------------------

def fit_lln_constants(
    d: int = 64,
    n: int = 1024,
    sigma_tilde_sq: np.ndarray | None = None,
    num_seeds: int = 4,
    seed: int = 0,
) -> Tuple[float, float]:
    """Fit Var[ln P^(LLN)] = a * sigma_tilde^2 + b on Gaussian samples.

    Uses alpha = beta = 1 and sigma_q = sigma_k = sigma_tilde / sqrt(2), so the
    abscissa is exactly sigma_tilde^2 = alpha^2 s_q^2 + beta^2 s_k^2.
    """
    if sigma_tilde_sq is None:
        sigma_tilde_sq = np.linspace(1.0, 36.0, 15)
    xs, ys = [], []
    key = jax.random.PRNGKey(seed)
    for s2 in sigma_tilde_sq:
        sig = float(np.sqrt(s2 / 2.0))
        for _ in range(num_seeds):
            key, kq, kk = jax.random.split(key, 3)
            q = sig * jax.random.normal(kq, (n, d), jnp.float32)
            k = sig * jax.random.normal(kk, (n, d), jnp.float32)
            p = lln_attn_matrix(q, k, 1.0, 1.0)
            xs.append(s2)
            ys.append(float(log_variance(p)))
    a, b = np.polyfit(np.asarray(xs), np.asarray(ys), 1)
    return float(a), float(b)


def solve_alpha_beta(
    sigma_q: jnp.ndarray,
    sigma_k: jnp.ndarray,
    a: float = DEFAULT_A,
    b: float = DEFAULT_B,
    min_sigma_tilde_sq: float = 1e-4,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Eq. 10.  sigma_q/sigma_k: scalars or per-head arrays; gradients blocked
    (moment matching is a calibration, not a learning signal)."""
    sq = jax.lax.stop_gradient(jnp.asarray(sigma_q, jnp.float32))
    sk = jax.lax.stop_gradient(jnp.asarray(sigma_k, jnp.float32))
    sigma_sm_sq = jnp.square(sq) * jnp.square(sk)
    st = jnp.sqrt(jnp.maximum((sigma_sm_sq - b) / a, min_sigma_tilde_sq))
    alpha = st / (jnp.sqrt(2.0) * jnp.maximum(sq, 1e-4))
    beta = st / (jnp.sqrt(2.0) * jnp.maximum(sk, 1e-4))
    return alpha, beta


# ---------------------------------------------------------------------------
# Running input statistics (per-head EMA of sigma_q / sigma_k).
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QKStats:
    """Per-head EMA of query/key standard deviations (batchnorm-style)."""
    sigma_q: jnp.ndarray   # (H,)
    sigma_k: jnp.ndarray   # (H,)

    @staticmethod
    def init(heads: int) -> "QKStats":
        return QKStats(sigma_q=jnp.ones((heads,), jnp.float32),
                       sigma_k=jnp.ones((heads,), jnp.float32))


def update_stats(stats: QKStats, q: jnp.ndarray, k: jnp.ndarray,
                 decay: float = 0.99) -> QKStats:
    """EMA update from a (B, N, H, D) batch; gradients blocked."""
    sq = jax.lax.stop_gradient(
        jnp.sqrt(jnp.mean(jnp.square(q.astype(jnp.float32)), axis=(0, 1, 3))))
    sk = jax.lax.stop_gradient(
        jnp.sqrt(jnp.mean(jnp.square(k.astype(jnp.float32)), axis=(0, 1, 3))))
    return QKStats(sigma_q=decay * stats.sigma_q + (1 - decay) * sq,
                   sigma_k=decay * stats.sigma_k + (1 - decay) * sk)


def matched_alpha_beta(stats: QKStats, a: float = DEFAULT_A,
                       b: float = DEFAULT_B) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return solve_alpha_beta(stats.sigma_q, stats.sigma_k, a, b)


if __name__ == "__main__":
    a, b = fit_lln_constants()
    print(f"fit: a={a:.4f} b={b:.4f}  (defaults: a={DEFAULT_A} b={DEFAULT_B})")
