"""Unified multi-head attention front-end.

One entry point — :func:`multi_head_attention` — dispatching on
``impl in {"softmax", "lln", "lln_diag"}``:

* ``softmax``  — arch-faithful baseline; flash-style (online-softmax, chunked
  over keys) so 32k-token prefill never materializes an N x N matrix.
* ``lln``      — the paper's Linear Log-Normal attention (eq. 8) with
  moment-matched (alpha, beta) (eq. 10), causal or bidirectional.
* ``lln_diag`` — the paper's §4.2 hybrid: average of LLN and block-diagonal
  softmax attention.

GQA/MQA: k/v may carry fewer heads (G) than q (H); G must divide H.
All inputs are (batch, seq, heads, head_dim).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp

from . import lln as lln_mod
from .numerics import einsum_f32
from .diag import block_diag_attn
from .lln import LLNState, lln_bidir, lln_causal
from .moment_matching import (constants_for_dim, length_gain,
                              solve_alpha_beta)

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    impl: str = "softmax"          # softmax | lln | lln_diag | log_linear
    causal: bool = True
    diag_block: int = 256          # block size of the §4.2 diagonal component
    lln_chunk: int = 128           # chunk of the causal LLN scan (also the
                                   # log_linear bucket granule)
    softmax_chunk: int = 1024      # key-chunk of the flash softmax path
    use_kernel: bool = False       # route through Pallas kernels (kernels/ops)
    backend: Optional[str] = None  # explicit kernel backend (kernels/registry
                                   # auto|pallas|scan|ref); None -> "auto"
    # Moment-matching constants; None -> calibrated defaults for head_dim.
    mm_a: Optional[float] = None
    mm_b: Optional[float] = None
    # Fixed alpha=beta (paper §A.8.4 ablation); 0 = dynamic moment matching.
    fixed_ab: float = 0.0
    # log_linear only: Fenwick pyramid depth and per-level mix decay
    # (core/loglinear.py; num_scales=1 or scale_decay=1 reduce to lln).
    num_scales: int = 4
    scale_decay: float = 0.5


def _repeat_kv(t: jnp.ndarray, h: int) -> jnp.ndarray:
    """Expand (B, N, G, D) kv heads to H = G*R query heads."""
    g = t.shape[2]
    if g == h:
        return t
    return jnp.repeat(t, h // g, axis=2)


def batch_alpha_beta(q: jnp.ndarray, k: jnp.ndarray, cfg: AttnConfig,
                     per_row: bool = False, n: int | None = None
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Moment-matched (alpha, beta) from current-batch statistics.

    Mirrors the artifact: sigma_q/sigma_k are measured on the fly
    (stop-gradient) and eq. 10 is applied — this is what makes alpha/beta
    drift during training as in the paper's Fig. 9.

    GQA: statistics are pooled per kv *group* (the r query heads sharing one
    kv head), so alpha: (H,) and beta: (G,) stay consistent within a group.

    ``per_row=True`` measures each batch row ALONE (statistics over that
    row's sequence and feature dims only) and returns alpha: (B, H) and
    beta: (B, G).  This is the continuous-batching admission setting: a
    batched slot prefill then yields exactly the calibration each request
    would get prefilled solo, so grouped admission stays per-request exact
    even under dynamic moment matching.  ``cfg`` may be any object with
    ``fixed_ab`` / ``mm_a`` / ``mm_b`` attributes (``AttnConfig`` or
    ``kernels.registry.AttnSpec``).

    ``n`` (optional, static int) is the sequence length the calibration is
    for.  When the config carries a beta(n) schedule (``beta_n > 0``,
    ``AttnSpec`` from a config with ``lln_beta_n`` set) the (a, b)
    constants come from the length-aware grid (``constants_for_dim(d, n)``
    — the legacy fit at or below the calibration length, the nearest-N
    fit beyond it); with the schedule off (the default) ``n`` is ignored
    and the result is bit-identical to the legacy calibration.  The
    beta(n) *gain* itself is a use-time modifier applied by the engine
    (prefill at the prompt length, decode per row from ``state.pos``),
    never baked into the calibration this returns.
    """
    bsz, h, g = q.shape[0], q.shape[2], k.shape[2]
    length_aware = getattr(cfg, "beta_n", 0.0) > 0.0 and n is not None
    if cfg.fixed_ab:
        if per_row:
            return (jnp.full((bsz, h), cfg.fixed_ab, jnp.float32),
                    jnp.full((bsz, g), cfg.fixed_ab, jnp.float32))
        return (jnp.full((h,), cfg.fixed_ab, jnp.float32),
                jnp.full((g,), cfg.fixed_ab, jnp.float32))
    a, b = (cfg.mm_a, cfg.mm_b)
    if a is None or b is None:
        a, b = constants_for_dim(q.shape[-1], n=n if length_aware else None)
    r = h // g
    axes = (1, 3) if per_row else (0, 1, 3)   # row-local vs batch-pooled
    sq = jnp.sqrt(jnp.mean(jnp.square(q.astype(jnp.float32)), axis=axes))
    sq_g = jnp.mean(sq.reshape(sq.shape[:-1] + (g, r)), axis=-1)    # (..,G)
    sk_g = jnp.sqrt(jnp.mean(jnp.square(k.astype(jnp.float32)),
                             axis=axes))                            # (..,G)
    alpha_g, beta_g = solve_alpha_beta(sq_g, sk_g, a, b)
    # Per-query-head alpha re-solved against the group's sigma_tilde so each
    # q head is correctly normalized by its own sigma_q (eq. 10).
    sigma_sm_sq = jnp.square(sq_g) * jnp.square(sk_g)
    st = jnp.sqrt(jnp.maximum((sigma_sm_sq - b) / a, 1e-4))         # (..,G)
    alpha = jnp.repeat(st, r, axis=-1) / (jnp.sqrt(2.0)
                                          * jnp.maximum(sq, 1e-4))
    del alpha_g
    return alpha, beta_g


# ---------------------------------------------------------------------------
# Flash-style softmax attention (chunked over keys, online softmax).
# ---------------------------------------------------------------------------

def flash_softmax(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    chunk: int = 1024,
    mask: Optional[jnp.ndarray] = None,
    scale: Optional[float] = None,
    prefix_len: int = 0,
    q_start: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Flash-style (online-softmax) attention, chunked over keys.

    q: (B,Nq,H,D); k/v: (B,Nk,G,D[v]) — G kv heads with G | H (GQA/MQA;
    KV is repeated to H inside).  ``mask``: (B, Nk) key validity.
    Returns (B, Nq, H, Dv) in ``v.dtype``; accumulation is fp32.

    Online-softmax accumulation over key chunks; O(Nq * chunk) live scores.
    Assumes query i attends keys j <= i + (Nk - Nq) when causal (i.e. the
    queries are the *last* Nq positions — the decode/prefill convention).
    ``q_start`` overrides that convention with explicit absolute query
    positions ``q_start + i`` — the multi-token decode case, where queries
    sit mid-buffer in a max_len-sized cache.  It may be a traced scalar or,
    for continuous batching, a per-row ``(B,)`` vector (each batch row sits
    at its own depth in the cache).
    ``prefix_len``: prefix-LM — keys < prefix_len are visible to every query
    (PaliGemma-style bidirectional image prefix).
    """
    from repro.distributed.sharding import constrain

    b, nq, h, d = q.shape
    nk, g = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    scale = (d ** -0.5) if scale is None else scale
    # Flat heads throughout: a (G, R) head split would leave both factors
    # un-shardable by the model axis for GQA archs (e.g. 4 x 8 vs 16), which
    # makes the SPMD partitioner replicate heads and gather batch instead.
    # Repeating KV costs (N * H * D) bf16 transient; sharded it is tiny.
    if g != h:
        k = jnp.repeat(k, h // g, axis=2)
        v = jnp.repeat(v, h // g, axis=2)

    nkc = -(-nk // chunk)
    kpad = nkc * chunk - nk
    if mask is None:
        mask = jnp.ones((b, nk), jnp.bool_)
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, kpad)))

    qchunk = min(chunk, nq)
    nqc = -(-nq // qchunk)
    qpad = nqc * qchunk - nq
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))

    # Arrays stay in their input dtype (bf16 in models) — only the online-
    # softmax statistics and accumulators are fp32 (preferred_element_type
    # on the two matmuls).  Upcasting k/v here would materialize fp32
    # copies of the whole cache.  The stacked scan operands are explicitly
    # constrained (no-op outside a mesh) so the partitioner keeps batch on
    # the data axis and heads on the model axis.
    qg = (q.reshape(b, nqc, qchunk, h, d).transpose(1, 0, 2, 3, 4)
          * jnp.asarray(scale, q.dtype))                     # (nqc,B,Cq,H,D)
    kc = k.reshape(b, nkc, chunk, h, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nkc, chunk, h, dv).transpose(1, 0, 2, 3, 4)
    qg = constrain(qg, None, "act_batch", None, "heads", None)
    kc = constrain(kc, None, "act_batch", None, "heads", None)
    vc = constrain(vc, None, "act_batch", None, "heads", None)
    mc = mask.reshape(b, nkc, chunk).transpose(1, 0, 2)
    key_pos_all = jnp.arange(nkc * chunk).reshape(nkc, chunk)

    q_off = (nk - nq) if q_start is None else q_start
    per_row = q_start is not None and jnp.ndim(q_start) == 1

    def q_block(carry, xs):
        qq, qbase = xs                           # (B,Cq,H,D), scalar
        if per_row:                              # (B, Cq) absolute positions
            q_pos = (qbase + jnp.arange(qchunk))[None, :] + q_off[:, None]
        else:
            q_pos = qbase + jnp.arange(qchunk) + q_off

        def kv_step(inner, ys):
            m, l, acc = inner                    # (B,H,Cq), ..., (...,Dv)
            ck, cv, cm, key_pos = ys
            s = einsum_f32("bqhd,bjhd->bhqj", qq, ck)
            bias = jnp.where(cm[:, None, None, :], 0.0, NEG_INF)
            if causal and per_row:
                allowed = q_pos[:, :, None] >= key_pos[None, None, :]
                if prefix_len:
                    allowed = allowed | (key_pos[None, None, :] < prefix_len)
                bias = bias + jnp.where(allowed[:, None], 0.0, NEG_INF)
            elif causal:
                allowed = q_pos[:, None] >= key_pos[None, :]
                if prefix_len:
                    allowed = allowed | (key_pos[None, :] < prefix_len)
                bias = bias + jnp.where(allowed[None, None], 0.0, NEG_INF)
            s = s + bias
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + einsum_f32(
                "bhqj,bjhv->bhqv", p.astype(v.dtype), cv)
            return (m_new, l, acc), None

        m0 = jnp.full((b, h, qchunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, qchunk), jnp.float32)
        acc0 = jnp.zeros((b, h, qchunk, dv), jnp.float32)
        # remat: the VJP of the scan must recompute each block's p rather
        # than stash (Cq x chunk) probabilities per step (flash backward).
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(kv_step),
                                      (m0, l0, acc0),
                                      (kc, vc, mc, key_pos_all))
        out = acc / jnp.maximum(l[..., None], 1e-20)         # (B,H,Cq,Dv)
        return carry, out.astype(v.dtype)

    qbases = jnp.arange(nqc) * qchunk
    _, blocks = jax.lax.scan(q_block, 0, (qg, qbases))       # (nqc,B,H,Cq,Dv)
    out = blocks.transpose(1, 0, 3, 2, 4).reshape(b, nqc * qchunk, h, dv)
    return out[:, :nq].astype(v.dtype)


def naive_softmax(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
    causal: bool = True, mask: Optional[jnp.ndarray] = None,
    scale: Optional[float] = None, prefix_len: int = 0,
) -> jnp.ndarray:
    """Quadratic reference (small N / tests only)."""
    b, nq, h, d = q.shape
    nk = k.shape[1]
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    scale = (d ** -0.5) if scale is None else scale
    s = jnp.einsum("bqhd,bjhd->bhqj", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if mask is not None:
        s = s + jnp.where(mask[:, None, None, :], 0.0, NEG_INF)
    if causal:
        qp = jnp.arange(nq) + (nk - nq)
        allowed = qp[:, None] >= jnp.arange(nk)[None, :]
        if prefix_len:
            allowed = allowed | (jnp.arange(nk)[None, :] < prefix_len)
        s = s + jnp.where(allowed[None, None], 0.0, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqj,bjhv->bqhv", p, v.astype(jnp.float32)).astype(v.dtype)


# ---------------------------------------------------------------------------
# Unified entry point.
# ---------------------------------------------------------------------------

def multi_head_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: AttnConfig,
    *,
    mask: Optional[jnp.ndarray] = None,
    alpha: Optional[jnp.ndarray] = None,
    beta: Optional[jnp.ndarray] = None,
    prefix_len: int = 0,
) -> jnp.ndarray:
    """Full-sequence attention (training / prefill).  See module docstring."""
    h = q.shape[2]
    if cfg.impl == "softmax":
        return flash_softmax(q, k, v, causal=cfg.causal,
                             chunk=min(cfg.softmax_chunk, k.shape[1]),
                             mask=mask, prefix_len=prefix_len)
    g = k.shape[2]
    if alpha is None or beta is None:
        alpha, beta = batch_alpha_beta(q, k, cfg)
    alpha = jnp.asarray(alpha, jnp.float32)
    beta = jnp.asarray(beta, jnp.float32)
    if alpha.ndim == 0:
        alpha = jnp.broadcast_to(alpha, (h,))
    if beta.ndim == 0:
        beta = jnp.broadcast_to(beta, (g,))
    # Heads live on the LAST axis ((H,) or per-row (B, H)) — pool a
    # per-q-head beta to the kv groups either way.
    if beta.shape[-1] == h and g != h:
        beta = beta.reshape(beta.shape[:-1] + (g, h // g)).mean(axis=-1)

    if cfg.use_kernel:
        # Kernels handle GQA via BlockSpec index maps — no KV repeat; the
        # backend registry owns the pallas/scan/ref dispatch.
        from repro.kernels import registry as kreg
        spec = kreg.AttnSpec(impl=cfg.impl, causal=cfg.causal, r=h // g,
                             backend=cfg.backend or "auto",
                             lln_chunk=cfg.lln_chunk,
                             diag_block=cfg.diag_block,
                             softmax_chunk=cfg.softmax_chunk,
                             fixed_ab=cfg.fixed_ab,
                             num_scales=cfg.num_scales,
                             scale_decay=cfg.scale_decay)
        return kreg.attention(spec, q, k, v, alpha, beta)

    kv_k = _repeat_kv(k, h)
    kv_v = _repeat_kv(v, h)
    beta_h = jnp.repeat(beta, h // g, axis=-1) if g != h else beta
    if cfg.impl == "log_linear":
        if not cfg.causal:
            raise ValueError("log_linear attention is causal-only")
        from . import loglinear as _loglin
        out, _ = _loglin.prefill(q, kv_k, kv_v, alpha, beta_h,
                                 granule=cfg.lln_chunk,
                                 num_scales=cfg.num_scales,
                                 scale_decay=cfg.scale_decay)
        return out.astype(v.dtype)
    if cfg.causal:
        lln_out = lln_causal(q, kv_k, kv_v, alpha, beta_h, chunk=cfg.lln_chunk)
    else:
        lln_out = lln_bidir(q, kv_k, kv_v, alpha, beta_h, mask=mask)
    if cfg.impl == "lln":
        return lln_out
    if cfg.impl == "lln_diag":
        diag_out = block_diag_attn(q, kv_k, kv_v, block=cfg.diag_block,
                                   causal=cfg.causal, mask=mask)
        return (0.5 * (lln_out.astype(jnp.float32)
                       + diag_out.astype(jnp.float32))).astype(v.dtype)
    raise ValueError(f"unknown attention impl: {cfg.impl}")


# ---------------------------------------------------------------------------
# Decode-time state: softmax KV cache / LLN running state (+ diag tail).
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    """Ring-less softmax KV cache: k/v (B, S, G, D[v]) + filled length."""
    k: jnp.ndarray
    v: jnp.ndarray
    length: jnp.ndarray     # scalar int32

    @staticmethod
    def init(batch: int, max_len: int, g: int, d: int, dv: int,
             dtype=jnp.bfloat16) -> "KVCache":
        return KVCache(k=jnp.zeros((batch, max_len, g, d), dtype),
                       v=jnp.zeros((batch, max_len, g, dv), dtype),
                       length=jnp.zeros((), jnp.int32))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LLNDecodeState:
    """LLN decode state + rolling tail buffer for the diagonal component.

    The diag component of §4.2 only ever needs the current block's history,
    so decode keeps a (B, diag_block, G, D) tail instead of the full cache —
    this is what makes long_500k decode O(d^2 + block) per token.  Under GQA
    the tail carries the G kv heads (cache bytes / r); it is repeated to the
    H query heads only inside the tiny tail-softmax.  H-head tails (the seed
    layout, still produced by MLA and the ``use_serve_kernel=False`` path)
    are accepted too — the head count is read off the buffer shape.
    """
    lln: LLNState
    tail_k: jnp.ndarray     # (B, BLK, G, D)
    tail_v: jnp.ndarray     # (B, BLK, G, Dv)
    pos: jnp.ndarray        # absolute next position: scalar or per-row (B,)

    @staticmethod
    def init(batch: int, heads: int, d: int, dv: int, block: int,
             dtype=jnp.bfloat16,
             kv_heads: Optional[int] = None) -> "LLNDecodeState":
        g = kv_heads or heads
        return LLNDecodeState(
            lln=LLNState.init(batch, heads, d, dv),
            tail_k=jnp.zeros((batch, block, g, d), dtype),
            tail_v=jnp.zeros((batch, block, g, dv), dtype),
            pos=jnp.zeros((), jnp.int32))


def decode_softmax(cache: KVCache, q: jnp.ndarray, k_new: jnp.ndarray,
                   v_new: jnp.ndarray, *, scale: Optional[float] = None,
                   chunk: int = 1024,
                   row_mask: Optional[jnp.ndarray] = None,
                   commit_len: Optional[jnp.ndarray] = None
                   ) -> tuple[jnp.ndarray, KVCache]:
    """Softmax decode of T >= 1 tokens against a KV cache.

    q: (B,T,H,D); k/v_new: (B,T,G,D[v]) — new tokens are appended at
    ``cache.length`` and within-chunk causality comes from explicit
    absolute positions (``q_start``), so T > 1 scores a draft chunk in one
    call.  ``cache.length`` may be a scalar (static batch: all rows at the
    same depth) or a per-row ``(B,)`` vector (continuous batching; the
    append is then a vmapped per-row ``dynamic_update_slice``).
    ``row_mask``: optional (B,) bool — rows where it is False do not write
    the cache and do not advance ``length`` (their outputs are garbage and
    must be discarded by the caller); requires per-row ``length``.
    ``commit_len``: optional per-row (B,) int32 in [0, T] — speculative
    partial commit: all T tokens are scored (intra-chunk causality over
    the full draft), but ``length`` advances only by ``commit_len``.
    Keys past the accepted prefix stay in the buffer above ``length``,
    where they are invisible to scoring and overwritten by the next
    commit before ``length`` can ever reach them; ``commit_len=0`` rows
    restore their buffer bitwise (the masked-row contract).  Requires
    per-row ``length``.  Returns (out (B,T,H,Dv), new cache).
    """
    from repro.distributed.sharding import constrain

    per_row = jnp.ndim(cache.length) == 1
    if commit_len is not None and not per_row:
        raise ValueError("decode_softmax: commit_len requires a per-row "
                         "(B,) cache length")
    if per_row:
        upd = lambda c, u, l: jax.lax.dynamic_update_slice_in_dim(
            c, u, l, axis=0)
        kc = jax.vmap(upd)(cache.k, k_new.astype(cache.k.dtype),
                           cache.length)
        vc = jax.vmap(upd)(cache.v, v_new.astype(cache.v.dtype),
                           cache.length)
    else:
        kc = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k_new.astype(cache.k.dtype), cache.length, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v_new.astype(cache.v.dtype), cache.length, axis=1)
    t = q.shape[1]
    ret_k = ret_v = None
    if commit_len is not None:
        cl = lln_mod.commit_lengths(commit_len, row_mask, t)
        # Scoring sees ALL T draft keys on every row (a verify pass with
        # commit_len=0 is a pure score); only the RETURNED cache rolls
        # back — commit_len=0 rows restore their buffer bitwise.
        keep = (cl > 0)[:, None, None, None]
        ret_k = jnp.where(keep, kc, cache.k)
        ret_v = jnp.where(keep, vc, cache.v)
        new_len = cache.length + cl
        score_len = cache.length + t          # all T drafts visible to score
    elif row_mask is not None:
        keep = row_mask[:, None, None, None]
        kc = jnp.where(keep, kc, cache.k)
        vc = jnp.where(keep, vc, cache.v)
        new_len = cache.length + t * row_mask.astype(jnp.int32)
        score_len = new_len
    else:
        new_len = cache.length + t
        score_len = new_len
    kc = constrain(kc, "act_batch", "act_seq_cache", "kv_heads", None)
    vc = constrain(vc, "act_batch", "act_seq_cache", "kv_heads", None)
    lens = score_len if per_row else jnp.broadcast_to(score_len,
                                                      (q.shape[0],))
    valid = jnp.arange(kc.shape[1])[None, :] < lens[:, None]
    out = flash_softmax(q, kc, vc, causal=True,
                        chunk=min(chunk, kc.shape[1]),
                        mask=valid, scale=scale, q_start=cache.length)
    if ret_k is None:
        ret_k, ret_v = kc, vc
    return out, KVCache(k=ret_k, v=ret_v, length=new_len)


def decode_lln_chunk(state: LLNDecodeState, q: jnp.ndarray,
                     k_new: jnp.ndarray, v_new: jnp.ndarray,
                     alpha: jnp.ndarray, beta: jnp.ndarray,
                     *, impl: str = "lln_diag",
                     use_kernel: bool = True,
                     row_mask: Optional[jnp.ndarray] = None,
                     backend: Optional[str] = None,
                     commit_len: Optional[jnp.ndarray] = None,
                     renorm: Optional[float] = None
                     ) -> tuple[jnp.ndarray, LLNDecodeState]:
    """LLN(+Diag) decode of T >= 1 tokens.  q: (B,T,H,D); k/v_new: (B,T,G,D[v]).

    The LLN state advance is vectorized over the chunk (one rescale, one
    intra-chunk causal quadratic — kernels/ops.py:lln_decode_chunk when
    ``use_kernel``; the jnp ``core.lln.decode_chunk`` otherwise).  The diag
    component runs one masked softmax over [tail block ∪ chunk keys] with
    per-token block-diagonal visibility derived from absolute positions, so
    a chunk may straddle a diag-block boundary and still match T sequential
    single-token steps exactly.

    ``state.pos`` may be a scalar (static batch) or a per-row ``(B,)``
    vector (continuous batching: every row sits at its own absolute
    position; the tail slot rotation and the block-diagonal visibility are
    evaluated per row).  ``alpha``/``beta`` may be (H,)/(B, H) —
    per-row calibration for pooled requests prefillled separately.
    ``row_mask``: optional (B,) bool; rows where it is False advance
    NOTHING — lln state, tails and ``pos`` keep their old values (their
    outputs are garbage and must be discarded).  Requires per-row ``pos``.
    ``backend``: explicit registry backend (``auto``/``pallas`` route
    through ``kernels/ops.py``; ``scan``/``ref`` run the jnp twin below);
    None derives it from the legacy ``use_kernel`` flag.
    ``commit_len``: optional per-row (B,) int32 in [0, T] — speculative
    partial commit: all T positions are scored, but only the accepted
    prefix folds into the LLN state, the diag tail and ``pos``
    (``commit_len=0`` ≡ ``row_mask=False``; ``commit_len=T`` ≡ a plain
    decode).  Requires per-row ``pos``.
    ``renorm``: optional drift-renormalization threshold on the carried
    ``z`` magnitude (``core.lln.decode_chunk``); semantics-preserving,
    applied uniformly by every backend.
    """
    b, t, h, d = q.shape
    if backend is None:
        backend = "auto" if use_kernel else "ref"
    if backend not in ("scan", "ref"):
        from repro.kernels import ops as kops
        lln_out, lln_state = kops.lln_decode_chunk(state.lln, q, k_new,
                                                   v_new, alpha, beta,
                                                   row_mask=row_mask,
                                                   backend=backend,
                                                   commit_len=commit_len,
                                                   renorm=renorm)
    else:
        beta_h = jnp.asarray(beta, jnp.float32)
        g = k_new.shape[2]
        if beta_h.ndim and beta_h.shape[-1] == g and g != h:
            beta_h = jnp.repeat(beta_h, h // g, axis=-1)
        lln_out, lln_state = lln_mod.decode_chunk(
            state.lln, q, _repeat_kv(k_new, h), _repeat_kv(v_new, h),
            alpha, beta_h, row_mask=row_mask, commit_len=commit_len,
            renorm=renorm)

    # --- rolling tail update, vectorized: for each slot i the last
    # *committed* chunk token writing it is j_i = j0 + block*((c-1-j0)//block),
    # j0 = (i-pos)%blk, c the per-row committed length (= t for a plain
    # decode).
    block = state.tail_k.shape[1]
    gt = state.tail_k.shape[2]          # tail head count (G, or H for seed)
    k_t = _repeat_kv(k_new, gt) if k_new.shape[2] != gt else k_new
    v_t = _repeat_kv(v_new, gt) if v_new.shape[2] != gt else v_new
    pos = state.pos
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))    # (B,)
    if commit_len is not None:
        cl = lln_mod.commit_lengths(commit_len, row_mask, t)
    elif row_mask is not None:
        cl = t * row_mask.astype(jnp.int32)
    else:
        cl = jnp.full((b,), t, jnp.int32)
    idx = jnp.arange(block)
    j0 = jnp.mod(idx[None, :] - posb[:, None], block)             # (B, BLK)
    j_last = jnp.clip(j0 + block * ((cl[:, None] - 1 - j0) // block),
                      0, t - 1)
    wrote = (j0 < cl[:, None])[:, :, None, None]
    gather = j_last[:, :, None, None]
    tail_k = jnp.where(wrote, jnp.take_along_axis(k_t, gather, axis=1
                                                  ).astype(state.tail_k.dtype),
                       state.tail_k)
    tail_v = jnp.where(wrote, jnp.take_along_axis(v_t, gather, axis=1
                                                  ).astype(state.tail_v.dtype),
                       state.tail_v)
    if commit_len is not None:
        new_pos = posb + cl         # always per-row under partial commit
    elif row_mask is not None:
        new_pos = pos + t * row_mask.astype(jnp.int32)
    else:
        new_pos = pos + t           # scalar pos stays scalar
    new_state = LLNDecodeState(lln=lln_state, tail_k=tail_k, tail_v=tail_v,
                               pos=new_pos)
    if impl == "lln":
        return lln_out, new_state

    # --- diagonal component: one softmax over [tail ∪ chunk] keys.
    # Absolute position of tail slot i (entries from the previous block get
    # positions < the current block start and are masked; never-written
    # slots get negative positions).  All per-row: (B, ...) masks.
    cur_base = (posb // block) * block                            # (B,)
    abs_idx = cur_base[:, None] + idx[None, :]                    # (B, BLK)
    tail_pos = jnp.where(idx[None, :] < (posb - cur_base)[:, None],
                         abs_idx, abs_idx - block)
    q_pos = posb[:, None] + jnp.arange(t)[None, :]                # (B, T)
    q_base = (q_pos // block) * block                 # block start per query
    m_tail = (tail_pos[:, None, :] >= q_base[:, :, None]) \
        & (tail_pos[:, None, :] >= 0)                             # (B, T, BLK)
    m_chunk = (jnp.arange(t)[None, None, :] <= jnp.arange(t)[None, :, None]) \
        & (q_base[:, None, :] == q_base[:, :, None])  # (B,T,T): j<=i, same blk
    allowed = jnp.concatenate([m_tail, m_chunk], axis=2)

    keys = jnp.concatenate(
        [state.tail_k, k_t.astype(state.tail_k.dtype)], axis=1)
    vals = jnp.concatenate(
        [state.tail_v, v_t.astype(state.tail_v.dtype)], axis=1)
    # GQA repeat only here, on the (BLK+T)-key tail-softmax operands.
    kf = _repeat_kv(keys, h).astype(jnp.float32)
    vf = _repeat_kv(vals, h).astype(jnp.float32)
    s = jnp.einsum("bihd,bjhd->bhij", q.astype(jnp.float32), kf) * (d ** -0.5)
    s = jnp.where(allowed[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    diag_out = jnp.einsum("bhij,bjhv->bihv", p, vf)
    out = 0.5 * (lln_out.astype(jnp.float32) + diag_out)
    return out.astype(v_new.dtype), new_state


def commit_softmax(cache: KVCache, k_new: jnp.ndarray, v_new: jnp.ndarray,
                   *, commit_len: jnp.ndarray,
                   row_mask: Optional[jnp.ndarray] = None) -> KVCache:
    """Commit half of :func:`decode_softmax` — append the accepted prefix
    of a previously *scored* chunk, no scoring.

    Single-pass speculative verify: a ``commit_len=0`` verify pass scores
    the draft and rolls the cache back bitwise; this re-appends the
    chunk's (k, v) residuals and advances ``length`` by the final
    ``commit_len``, identical to re-running :func:`decode_softmax` with
    it.  Requires per-row ``length``.
    """
    if jnp.ndim(cache.length) != 1:
        raise ValueError("commit_softmax requires a per-row (B,) cache "
                         "length")
    t = k_new.shape[1]
    upd = lambda c, u, l: jax.lax.dynamic_update_slice_in_dim(
        c, u, l, axis=0)
    kc = jax.vmap(upd)(cache.k, k_new.astype(cache.k.dtype), cache.length)
    vc = jax.vmap(upd)(cache.v, v_new.astype(cache.v.dtype), cache.length)
    cl = lln_mod.commit_lengths(commit_len, row_mask, t)
    keep = (cl > 0)[:, None, None, None]
    return KVCache(k=jnp.where(keep, kc, cache.k),
                   v=jnp.where(keep, vc, cache.v),
                   length=cache.length + cl)


def commit_lln_chunk(state: LLNDecodeState, k_new: jnp.ndarray,
                     v_new: jnp.ndarray, beta: jnp.ndarray,
                     *, impl: str = "lln_diag",
                     commit_len: jnp.ndarray,
                     row_mask: Optional[jnp.ndarray] = None,
                     backend: Optional[str] = None,
                     renorm: Optional[float] = None) -> LLNDecodeState:
    """Commit half of :func:`decode_lln_chunk` — fold the accepted prefix
    of a previously scored chunk into the LLN state, the diag tail and
    ``pos``, without scoring.

    k/v_new: (B,T,G,D[v]) — the post-RoPE residuals the verify pass
    returned.  Bit-identical per backend to re-running
    :func:`decode_lln_chunk` with the final ``commit_len`` (the state
    advance of the two paths shares the same per-backend fold).  Requires
    per-row ``pos``.
    """
    b, t = k_new.shape[0], k_new.shape[1]
    if backend is None:
        backend = "auto"
    if backend not in ("scan", "ref"):
        from repro.kernels import ops as kops
        lln_state = kops.lln_commit_chunk(state.lln, k_new, v_new, beta,
                                          row_mask=row_mask,
                                          backend=backend,
                                          commit_len=commit_len,
                                          renorm=renorm)
    else:
        h = state.lln.s.shape[1]
        g = k_new.shape[2]
        beta_h = jnp.asarray(beta, jnp.float32)
        if beta_h.ndim and beta_h.shape[-1] == g and g != h:
            beta_h = jnp.repeat(beta_h, h // g, axis=-1)
        lln_state = lln_mod.commit_chunk(
            state.lln, _repeat_kv(k_new, h), _repeat_kv(v_new, h), beta_h,
            row_mask=row_mask, commit_len=commit_len, renorm=renorm)

    # Rolling diag-tail update — same per-slot last-committed-writer gather
    # as decode_lln_chunk.
    block = state.tail_k.shape[1]
    gt = state.tail_k.shape[2]
    k_t = _repeat_kv(k_new, gt) if k_new.shape[2] != gt else k_new
    v_t = _repeat_kv(v_new, gt) if v_new.shape[2] != gt else v_new
    posb = jnp.broadcast_to(jnp.asarray(state.pos, jnp.int32), (b,))
    cl = lln_mod.commit_lengths(commit_len, row_mask, t)
    idx = jnp.arange(block)
    j0 = jnp.mod(idx[None, :] - posb[:, None], block)             # (B, BLK)
    j_last = jnp.clip(j0 + block * ((cl[:, None] - 1 - j0) // block),
                      0, t - 1)
    wrote = (j0 < cl[:, None])[:, :, None, None]
    gather = j_last[:, :, None, None]
    tail_k = jnp.where(wrote, jnp.take_along_axis(k_t, gather, axis=1
                                                  ).astype(state.tail_k.dtype),
                       state.tail_k)
    tail_v = jnp.where(wrote, jnp.take_along_axis(v_t, gather, axis=1
                                                  ).astype(state.tail_v.dtype),
                       state.tail_v)
    return LLNDecodeState(lln=lln_state, tail_k=tail_k, tail_v=tail_v,
                          pos=posb + cl)


def decode_lln(state: LLNDecodeState, q: jnp.ndarray, k_new: jnp.ndarray,
               v_new: jnp.ndarray, alpha: jnp.ndarray, beta: jnp.ndarray,
               *, impl: str = "lln_diag") -> tuple[jnp.ndarray, LLNDecodeState]:
    """One-token LLN(+Diag) decode (T=1 :func:`decode_lln_chunk`).

    .. deprecated:: use :meth:`repro.core.engine.AttentionEngine.decode`
       (or :func:`decode_lln_chunk` directly) — chunked decode subsumes the
       single-token case.
    """
    from repro.kernels.registry import warn_deprecated
    warn_deprecated("repro.core.attention.decode_lln",
                    "AttentionEngine.decode / decode_lln_chunk")
    return decode_lln_chunk(state, q, k_new, v_new, alpha, beta, impl=impl,
                            use_kernel=False)
