"""Core library: the paper's contribution as composable JAX modules."""
from .attention import (AttnConfig, KVCache, LLNDecodeState, decode_lln,
                        decode_lln_chunk, decode_softmax, flash_softmax,
                        multi_head_attention, naive_softmax)
from .diag import block_diag_attn
from .lln import LLNState, lln_bidir, lln_causal, lln_causal_scan
from .moment_matching import (DEFAULT_A, DEFAULT_B, constants_for_dim,
                              fit_lln_constants, solve_alpha_beta)
from .engine import AttentionEngine, AttentionState

__all__ = [
    "AttentionEngine", "AttentionState",
    "AttnConfig", "KVCache", "LLNDecodeState", "LLNState",
    "multi_head_attention", "flash_softmax", "naive_softmax",
    "decode_lln", "decode_lln_chunk", "decode_softmax", "block_diag_attn",
    "lln_bidir", "lln_causal", "lln_causal_scan",
    "DEFAULT_A", "DEFAULT_B", "constants_for_dim", "fit_lln_constants",
    "solve_alpha_beta",
]
