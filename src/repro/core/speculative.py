"""Draft-then-verify speculative decoding: the acceptance rules.

Speculative decoding (Leviathan et al., *Fast Inference from Transformers
via Speculative Decoding*; Chen et al., *Accelerating LLM Decoding with
Speculative Sampling*) buys tokens/step by letting a cheap draft model
propose ``k`` tokens and the target model score the whole proposal in ONE
chunked verify pass.  The verify chunk is ``[tok, d_1, ..., d_k]`` —
the last committed token followed by the drafts — so the target's logits
at input position ``i`` are its prediction for the token AFTER
``d_i`` (position 0 predicts ``d_1``'s replacement).

This module owns only the *math* of acceptance; the state side (scoring
all T positions while folding only the accepted prefix into the LLN
running sums / KV rows) is the ``commit_len`` partial-commit contract of
:meth:`repro.core.engine.AttentionEngine.verify`, and the loop lives in
``launch/steps.py:SpecSetup``.

Both rules return ``(n_accept, next_token, commit_len)``:

* ``n_accept`` (B,) — accepted drafts per row (0..k);
* ``next_token`` (B,) — the target's correction at the first rejected
  position, or its bonus extension when every draft survived.  The row
  therefore always emits ``n_accept + 1`` tokens per verify
  (``d_1..d_{n}, next_token``);
* ``commit_len`` (B,) = ``n_accept + 1`` — the verify-chunk inputs whose
  keys commit: ``tok`` plus the accepted drafts (``next_token``'s key is
  folded when it is fed as the next chunk's first input).

Greedy acceptance reproduces the target's greedy sequence token for token
(the drafts only change how many sequential target dispatches it costs);
residual resampling preserves the target's sampling distribution exactly.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_TINY = 1e-30


def greedy_verify(draft_tokens: jnp.ndarray, target_logits: jnp.ndarray
                  ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Greedy accept/reject: keep the longest draft prefix that matches the
    target's argmax at every position.

    Args:
      draft_tokens: (B, k) int32 — the draft model's proposals.
      target_logits: (B, k+1, V) — the target's verify-pass logits over the
        chunk ``[tok, d_1..d_k]`` (``target_logits[:, i]`` predicts the
        token after input ``i``).

    Returns ``(n_accept (B,), next_token (B,), commit_len (B,))``.
    """
    k = draft_tokens.shape[1]
    tgt = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)  # (B, k+1)
    match = (draft_tokens == tgt[:, :k]).astype(jnp.int32)
    # Longest matching prefix: cumprod zeroes everything after the first
    # mismatch; its sum is the prefix length.
    n_accept = jnp.sum(jnp.cumprod(match, axis=1), axis=1)      # (B,)
    next_token = jnp.take_along_axis(tgt, n_accept[:, None],
                                     axis=1)[:, 0]
    return n_accept, next_token, n_accept + 1


def residual_verify(draft_tokens: jnp.ndarray, draft_logits: jnp.ndarray,
                    target_logits: jnp.ndarray, key, temperature: float
                    ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Speculative sampling with residual resampling (Chen et al. 2023).

    Draft ``d_i ~ q_i`` is accepted with probability
    ``min(1, p_i(d_i) / q_i(d_i))`` (``p`` the target's distribution at
    that position); at the first rejection the replacement is drawn from
    the residual ``(p_i - q_i)^+`` (renormalized), and on full acceptance
    the bonus token is drawn from ``p_{k+1}``.  This preserves the
    target's sampling distribution exactly.

    Args:
      draft_tokens: (B, k) int32 proposals.
      draft_logits: (B, k, V) — the draft logits each ``d_i`` was sampled
        from.
      target_logits: (B, k+1, V) verify-pass logits.
      key: PRNG key for the accept coins and the resample/bonus draws.
      temperature: shared sampling temperature (> 0; ``greedy_verify`` is
        the temperature-0 rule).

    Returns ``(n_accept (B,), next_token (B,), commit_len (B,))``.
    """
    if temperature <= 0:
        raise ValueError("residual_verify requires temperature > 0; "
                         "use greedy_verify for greedy decoding")
    b, k = draft_tokens.shape
    ka, kr = jax.random.split(key)
    p = jax.nn.softmax(target_logits[:, :k].astype(jnp.float32)
                       / temperature, axis=-1)                  # (B, k, V)
    q = jax.nn.softmax(draft_logits.astype(jnp.float32)
                       / temperature, axis=-1)                  # (B, k, V)
    idx = draft_tokens[:, :, None]
    p_d = jnp.take_along_axis(p, idx, axis=2)[..., 0]           # (B, k)
    q_d = jnp.take_along_axis(q, idx, axis=2)[..., 0]
    u = jax.random.uniform(ka, (b, k))
    accept = (u < jnp.minimum(1.0, p_d / jnp.maximum(q_d, _TINY)))
    n_accept = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=1),
                       axis=1)                                  # (B,)
    # Residual distribution at the first rejected position (row-gathered;
    # clamped to k-1 — unused when every draft survived).
    j = jnp.minimum(n_accept, k - 1)[:, None, None]
    p_j = jnp.take_along_axis(p, j, axis=1)[:, 0]               # (B, V)
    q_j = jnp.take_along_axis(q, j, axis=1)[:, 0]
    residual = jnp.maximum(p_j - q_j, 0.0)
    norm = jnp.sum(residual, axis=-1, keepdims=True)
    # Degenerate residual (p == q): fall back to sampling from p itself.
    residual = jnp.where(norm > _TINY, residual / jnp.maximum(norm, _TINY),
                         p_j)
    resampled = jax.random.categorical(
        kr, jnp.log(residual + _TINY), axis=-1).astype(jnp.int32)
    bonus = jax.random.categorical(
        kr, target_logits[:, k].astype(jnp.float32) / temperature,
        axis=-1).astype(jnp.int32)
    next_token = jnp.where(n_accept == k, bonus, resampled)
    return n_accept, next_token, n_accept + 1


def verify_tokens(draft_tokens: jnp.ndarray, target_logits: jnp.ndarray,
                  temperature: float, key=None,
                  draft_logits: Optional[jnp.ndarray] = None
                  ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """The one acceptance entry point: greedy at ``temperature == 0``,
    residual resampling otherwise (``draft_logits``/``key`` then
    required)."""
    if temperature <= 0:
        return greedy_verify(draft_tokens, target_logits)
    if draft_logits is None or key is None:
        raise ValueError("temperature sampling requires draft_logits and "
                         "a PRNG key")
    return residual_verify(draft_tokens, draft_logits, target_logits, key,
                           temperature)


def emit_tokens(draft_tokens: jnp.ndarray, n_accept: jnp.ndarray,
                next_token: jnp.ndarray) -> jnp.ndarray:
    """Pack one verify step's emitted tokens into a fixed-shape (B, k+1)
    buffer: the accepted drafts, then ``next_token``; slots past
    ``n_accept + 1`` are padding the caller must mask with the emit count.
    """
    b, k = draft_tokens.shape
    slots = jnp.arange(k + 1)[None, :]
    padded = jnp.concatenate(
        [draft_tokens, jnp.zeros((b, 1), draft_tokens.dtype)], axis=1)
    out = jnp.where(slots < n_accept[:, None], padded, 0)
    return jnp.where(slots == n_accept[:, None], next_token[:, None], out)


__all__ = ["greedy_verify", "residual_verify", "verify_tokens",
           "emit_tokens"]
