"""Linear Log-Normal (LLN) attention — the paper's core contribution (eq. 8-9).

Feature maps Phi_Q(q) = exp(alpha * q), Phi_K(k) = exp(beta * k) turn Gaussian
q/k into log-normal features; the induced attention matrix is approximately
log-normal (Prop. 4.1) and, with moment-matched (alpha, beta) (eq. 10), emulates
the distribution and concentration behaviour of softmax attention.

Shapes follow the framework convention:  (batch, seq, heads, head_dim) for
q/k, (batch, seq, heads, v_dim) for v.  All functions are pure and jit-safe.

Numerical stabilization
-----------------------
exp(alpha*q) can overflow.  The normalized LLN form (eq. 8) is *exactly*
invariant to subtracting a global (per batch*head) constant from alpha*q and
from beta*k: both numerator and denominator scale by exp(-c_q - c_k).  We use
stop-gradient global maxima as those constants.  For decode, the running state
carries its own reference constant and is rescaled when the constant moves
(see :func:`decode_step`).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .numerics import einsum_f32

EPS = 1e-6


def _stab_const(x: jnp.ndarray, axes: tuple[int, ...]) -> jnp.ndarray:
    """Global stabilization constant (stop-gradient max over seq & feature)."""
    c = jax.lax.stop_gradient(jnp.max(x, axis=axes, keepdims=True))
    # Guard fully-masked/empty inputs.
    return jnp.where(jnp.isfinite(c), c, 0.0)


def feature_map_q(q: jnp.ndarray, alpha: jnp.ndarray) -> jnp.ndarray:
    """Phi_Q(q) = exp(alpha*q - c_q);  q: (B, N, H, D), alpha scalar or (H,)."""
    aq = q * _bcast(alpha, q)
    return jnp.exp(aq - _stab_const(aq, (1, 3)))


def feature_map_k(k: jnp.ndarray, beta: jnp.ndarray) -> jnp.ndarray:
    """Phi_K(k) = exp(beta*k - c_k);  k: (B, N, H, D), beta scalar or (H,)."""
    bk = k * _bcast(beta, k)
    return jnp.exp(bk - _stab_const(bk, (1, 3)))


def _bcast(p: jnp.ndarray, like: jnp.ndarray) -> jnp.ndarray:
    """Broadcast a scalar, per-head (H,) or per-row-per-head (B, H)
    parameter over (B, N, H, D)."""
    p = jnp.asarray(p, like.dtype)
    if p.ndim == 0:
        return p
    if p.ndim == 2:                       # (B, H): per-row calibration
        return p[:, None, :, None]
    return p.reshape((1, 1, -1, 1))


# ---------------------------------------------------------------------------
# Bidirectional (encoder) LLN attention — the paper's published setting.
# ---------------------------------------------------------------------------

def lln_bidir(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
    *,
    mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Non-causal LLN attention, O(N d^2) time, O(d^2) state.

    out_i = Phi(q_i) @ S / (Phi(q_i) . z),  S = sum_j Phi(k_j) v_j^T,
    z = sum_j Phi(k_j).   `mask`: optional (B, N) 1/0 key validity.
    """
    fq = feature_map_q(q, alpha).astype(q.dtype)
    fk = feature_map_k(k, beta).astype(k.dtype)
    vf = v
    if mask is not None:
        fk = fk * mask[:, :, None, None].astype(fk.dtype)
    s = einsum_f32("bnhd,bnhv->bhdv", fk, vf)            # (B, H, D, Dv)
    z = jnp.sum(fk.astype(jnp.float32), axis=1)          # (B, H, D)
    num = einsum_f32("bnhd,bhdv->bnhv", fq, s.astype(fq.dtype))
    den = einsum_f32("bnhd,bhd->bnh", fq, z.astype(fq.dtype))
    return (num / (den[..., None] + EPS)).astype(v.dtype)


# ---------------------------------------------------------------------------
# Causal (decoder) LLN attention — chunked prefix-state form.
# ---------------------------------------------------------------------------

def lln_causal(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
    *,
    chunk: int = 128,
) -> jnp.ndarray:
    """Causal LLN via chunked scan: intra-chunk masked quadratic + inter-chunk
    state pass.  O(N * (chunk*d + d^2)) compute, O(d^2) carried state.
    """
    return lln_causal_scan(q, k, v, alpha, beta, chunk=chunk)[0]


def lln_causal_scan(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
    *,
    chunk: int = 128,
) -> tuple[jnp.ndarray, "LLNState"]:
    """Causal LLN returning (out, final LLNState) — the state is the scan's
    final ``(s, z)`` carry, which the pass computes anyway; :func:`prefill`
    hands it to decode for free.  Ragged lengths pad the *feature-mapped*
    keys with zeros so padded positions never leak into the carry.
    """
    b, n, h, d = q.shape
    dv = v.shape[-1]

    from repro.distributed.sharding import constrain

    aq = q * _bcast(alpha, q)
    bk = k * _bcast(beta, k)
    c_k = _stab_const(bk, (1, 3))
    fq = jnp.exp(aq - _stab_const(aq, (1, 3))).astype(q.dtype)
    fk = jnp.exp(bk - c_k).astype(k.dtype)
    vf = v
    if n % chunk:
        pad = chunk - n % chunk
        fq = jnp.pad(fq, ((0, 0), (0, pad), (0, 0), (0, 0)))
        fk = jnp.pad(fk, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = fq.shape[1] // chunk

    # (nc, B, C, H, D); constrained so the partitioner keeps batch on the
    # data axis and heads on the model axis (see flash_softmax).
    fq = fq.reshape(b, nc, chunk, h, d).transpose(1, 0, 2, 3, 4)
    fk = fk.reshape(b, nc, chunk, h, d).transpose(1, 0, 2, 3, 4)
    vf = vf.reshape(b, nc, chunk, h, dv).transpose(1, 0, 2, 3, 4)
    fq = constrain(fq, None, "act_batch", None, "heads", None)
    fk = constrain(fk, None, "act_batch", None, "heads", None)
    vf = constrain(vf, None, "act_batch", None, "heads", None)

    causal = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))

    def step(carry, xs):
        s, z = carry                                   # f32 (B,H,D,Dv),(B,H,D)
        cq, ck, cv = xs
        scores = einsum_f32("bihd,bjhd->bhij", cq, ck) \
            * causal[None, None]
        intra = einsum_f32("bhij,bjhv->bihv", scores.astype(cv.dtype), cv)
        intra_z = jnp.sum(scores, axis=-1).transpose(0, 2, 1)   # (B,C,H)
        inter = einsum_f32("bihd,bhdv->bihv", cq, s.astype(cq.dtype))
        inter_z = einsum_f32("bihd,bhd->bih", cq, z.astype(cq.dtype))
        out = (intra + inter) / (intra_z + inter_z + EPS)[..., None]
        s = s + einsum_f32("bjhd,bjhv->bhdv", ck, cv)
        z = z + jnp.sum(ck.astype(jnp.float32), axis=1)
        return (s, z), out

    s0 = jnp.zeros((b, h, d, dv), jnp.float32)
    z0 = jnp.zeros((b, h, d), jnp.float32)
    # remat: recompute intra-chunk scores in the backward instead of
    # stashing (C x C) blocks per step.
    (s, z), out = jax.lax.scan(jax.checkpoint(step), (s0, z0), (fq, fk, vf))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, h, dv)
    state = LLNState(s=s, z=z, c_k=c_k.astype(jnp.float32))
    return out[:, :n].astype(v.dtype), state


# ---------------------------------------------------------------------------
# Analytic gradients — the quadratic-form oracle for the Pallas backward
# kernels (kernels/lln_backward.py implements the same decomposition in
# chunked/linear form; tests compare both against jax.vjp of lln_causal).
# ---------------------------------------------------------------------------

def lln_grads(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
    g: jnp.ndarray,
    *,
    causal: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Analytic (dq, dk, dv) of LLN attention w.r.t. the cotangent ``g``.

    Derivation (quotient rule through out = num/den, den = Phi(q).z + EPS):
    with u_i = g_i/den_i and w_i = (g_i . out_i)/den_i,

        dPhi(q)_i = sum_j M_ij (u_i . v_j - w_i) Phi(k)_j
        dPhi(k)_j = sum_i M_ij (u_i . v_j - w_i) Phi(q)_i
        dv_j      = sum_i M_ij (Phi(q)_i . Phi(k)_j) u_i

    (M the causal mask), then dq = alpha * Phi(q) * dPhi(q) elementwise
    (exp feature map; the stop-gradient stabilization constants drop out),
    and likewise for k.  O(N^2) memory — a test oracle, not a training path.
    All heads are full (repeat KV before calling for GQA).
    """
    fq = feature_map_q(q.astype(jnp.float32), alpha)
    fk = feature_map_k(k.astype(jnp.float32), beta)
    vf = v.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    scores = jnp.einsum("bihd,bjhd->bhij", fq, fk)
    if causal:
        n = q.shape[1]
        scores = scores * jnp.tril(jnp.ones((n, n), jnp.float32))
    den = jnp.sum(scores, axis=-1) + EPS                      # (B, H, N)
    out = jnp.einsum("bhij,bjhv->bihv", scores, vf) \
        / den.transpose(0, 2, 1)[..., None]
    u = gf / den.transpose(0, 2, 1)[..., None]                # (B, N, H, Dv)
    w = jnp.sum(gf * out, axis=-1) / den.transpose(0, 2, 1)   # (B, N, H)
    gmat = jnp.einsum("bihv,bjhv->bhij", u, vf) \
        - w.transpose(0, 2, 1)[..., None]
    if causal:
        gmat = gmat * jnp.tril(jnp.ones((q.shape[1],) * 2, jnp.float32))
    alpha_b = _bcast(jnp.asarray(alpha, jnp.float32), fq)
    beta_b = _bcast(jnp.asarray(beta, jnp.float32), fk)
    dq = alpha_b * fq * jnp.einsum("bhij,bjhd->bihd", gmat, fk)
    dk = beta_b * fk * jnp.einsum("bhij,bihd->bjhd", gmat, fq)
    dv = jnp.einsum("bhij,bihv->bjhv", scores, u)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


# ---------------------------------------------------------------------------
# Decode: O(1)-per-token state ("KV state" replaces the KV cache).
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LLNState:
    """Running LLN decode state for one layer.

    s:  (B, H, D, Dv)  accumulated Phi(k)^T v  (fp32)
    z:  (B, H, D)      accumulated Phi(k)      (fp32)
    c_k: (B, 1, H, 1)  reference stabilization constant the state was built with
    log_scale: (B, H)  accumulated drift-renorm shift — how far c_k has been
        raised ABOVE the pure running max by :func:`decode_chunk`'s renorm
        (bookkeeping for telemetry; None on paths that don't carry it).
        The true key-feature mass is ``z * exp(log_scale)``.
    """
    s: jnp.ndarray
    z: jnp.ndarray
    c_k: jnp.ndarray
    log_scale: Optional[jnp.ndarray] = None

    @staticmethod
    def init(batch: int, heads: int, d: int, dv: int) -> "LLNState":
        return LLNState(
            s=jnp.zeros((batch, heads, d, dv), jnp.float32),
            z=jnp.zeros((batch, heads, d), jnp.float32),
            c_k=jnp.zeros((batch, 1, heads, 1), jnp.float32),
            log_scale=jnp.zeros((batch, heads), jnp.float32),
        )


def prefill(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
    *,
    chunk: int = 128,
) -> tuple[jnp.ndarray, LLNState]:
    """Causal forward over a prompt, returning outputs and the decode state.

    The state is the causal scan's final carry — no second full-key pass
    (the old implementation re-accumulated ``(s, z)`` with an extra einsum
    over every key after the scan already computed them).
    """
    return lln_causal_scan(q, k, v, alpha, beta, chunk=chunk)


def decode_step(
    state: LLNState,
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
) -> tuple[jnp.ndarray, LLNState]:
    """One decode step.  q/k/v: (B, 1, H, D[v]).  Returns (out, new_state).

    If the new key pushes the stabilization constant up, the state is rescaled
    by exp(c_old - c_new) so history and update share one reference constant.
    """
    bk = k * _bcast(beta, k)
    c_new = jnp.maximum(state.c_k, jax.lax.stop_gradient(
        jnp.max(bk, axis=(1, 3), keepdims=True)))
    rescale = jnp.exp(state.c_k - c_new)               # (B,1,H,1) <= 1
    r = rescale[:, 0, :, 0][..., None]                 # (B,H,1)
    fk = jnp.exp(bk - c_new).astype(jnp.float32)[:, 0]           # (B,H,D)
    vt = jnp.swapaxes(v.astype(jnp.float32), 1, 2)[:, :, 0]      # (B,H,Dv)
    # outer product Phi(k) v^T: (B,H,D,1)*(B,H,1,Dv) -> (B,H,D,Dv)
    s = state.s * r[..., None] + fk[..., None] * vt[:, :, None, :]
    z = state.z * r + fk
    aq = q * _bcast(alpha, q)
    fq = jnp.exp(aq - _stab_const(aq, (1, 3))).astype(jnp.float32)[:, 0]  # (B,H,D)
    num = jnp.einsum("bhd,bhdv->bhv", fq, s)
    den = jnp.einsum("bhd,bhd->bh", fq, z)
    out = (num / (den[..., None] + EPS)).astype(v.dtype)[:, None]  # (B,1,H,Dv)
    return out, LLNState(s=s, z=z, c_k=c_new, log_scale=state.log_scale)


def commit_lengths(commit_len: jnp.ndarray,
                   row_mask: Optional[jnp.ndarray], t: int) -> jnp.ndarray:
    """Normalize a partial-commit vector: clip to [0, T] and zero masked
    rows.  The ONE definition of the contract's edge handling — every
    decode path (jnp core, kernels/ops, softmax cache) must agree on it.
    """
    cl = jnp.clip(commit_len.astype(jnp.int32), 0, t)
    if row_mask is not None:
        cl = jnp.where(row_mask, cl, 0)
    return cl


def decode_chunk(
    state: LLNState,
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
    row_mask: Optional[jnp.ndarray] = None,
    commit_len: Optional[jnp.ndarray] = None,
    renorm: Optional[float] = None,
) -> tuple[jnp.ndarray, LLNState]:
    """Advance the state over T new tokens at once.  q/k/v: (B, T, H, D[v]).

    :func:`decode_step` math vectorized over the chunk: one max-rescale of
    the carried state against the chunk's keys, an intra-chunk causal
    quadratic for the new-token interactions, and a per-row normalizer —
    mathematically identical to T sequential :func:`decode_step` calls
    (the normalized form is exactly invariant to the reference constant).

    ``alpha``/``beta``: scalar, (H,), or per-row (B, H) (continuous
    batching, where pooled requests carry their own calibration).
    ``row_mask``: optional (B,) bool — rows where it is False keep their
    old ``(s, z, c_k)`` exactly (no rescale, no accumulation); their
    outputs are garbage and must be discarded by the caller.
    ``commit_len``: optional per-row (B,) int32 in [0, T] — the
    speculative-decode partial-commit contract.  Outputs are still scored
    for ALL T positions, but only tokens ``j < commit_len[b]`` fold into
    ``(s, z, c_k)``: the reference constant advances only over committed
    keys (exactly the constant a sequential commit of that prefix would
    produce), uncommitted keys contribute Phi(k) = 0.  ``commit_len=0``
    is the masked row (state bitwise preserved up to * 1.0 / + 0.0);
    ``commit_len=T`` (or None) is today's full commit.
    ``renorm``: optional drift-renormalization threshold.  After the fold,
    any row whose per-head ``max_d z`` exceeds it has its reference
    constant raised by ``delta = ln(max_d z)`` and ``(s, z)`` scaled by
    ``exp(-delta)`` — the normalized output is exactly invariant to the
    reference constant, so this is semantics-preserving; it only bounds
    the carried magnitudes (``max_d z`` returns to ~1) so state never
    drifts out of fp32 range at long horizon.  The shift accumulates
    into ``state.log_scale`` when carried.  Renorm never fires for rows
    that committed nothing this call (masked rows and ``commit_len=0``
    rows stay bitwise inert).
    """
    b, t, h, d = q.shape
    dv = v.shape[-1]
    bk = k * _bcast(beta, k)
    if commit_len is not None:
        cl = commit_lengths(commit_len, row_mask, t)
        cmask = jnp.arange(t)[None, :] < cl[:, None]             # (B, T)
        bk_c = jnp.where(cmask[:, :, None, None], bk, -jnp.inf)
        # Committed-prefix reference constant; max over an empty commit is
        # -inf, so c_new degrades to the carried c_k exactly.
        c_new = jnp.maximum(state.c_k, jax.lax.stop_gradient(
            jnp.max(bk_c, axis=(1, 3), keepdims=True)))
        # Scores over every draft position need a constant covering ALL
        # chunk keys (no overflow); the normalized output is invariant.
        c_out = jnp.maximum(c_new, jax.lax.stop_gradient(
            jnp.max(bk, axis=(1, 3), keepdims=True)))
    else:
        bk_c = bk
        c_new = jnp.maximum(state.c_k, jax.lax.stop_gradient(
            jnp.max(bk, axis=(1, 3), keepdims=True)))   # (B,1,H,1)
        c_out = c_new
    r_out = jnp.exp(state.c_k - c_out)[:, 0, :, 0]      # (B,H) <= 1
    fk = jnp.exp(bk - c_out).astype(jnp.float32)        # (B,T,H,D)
    vf = v.astype(jnp.float32)
    aq = q * _bcast(alpha, q)
    fq = jnp.exp(aq - _stab_const(aq, (1, 3))).astype(jnp.float32)
    s0 = state.s * r_out[..., None, None]
    z0 = state.z * r_out[..., None]
    causal = jnp.tril(jnp.ones((t, t), jnp.float32))
    scores = jnp.einsum("bihd,bjhd->bhij", fq, fk) * causal[None, None]
    intra = jnp.einsum("bhij,bjhv->bihv", scores, vf)
    intra_z = jnp.sum(scores, axis=-1).transpose(0, 2, 1)        # (B,T,H)
    inter = jnp.einsum("bihd,bhdv->bihv", fq, s0)
    inter_z = jnp.einsum("bihd,bhd->bih", fq, z0)
    out = (intra + inter) / (intra_z + inter_z + EPS)[..., None]
    if commit_len is not None:
        r_c = jnp.exp(state.c_k - c_new)[:, 0, :, 0]
        fk_c = jnp.exp(bk_c - c_new).astype(jnp.float32)  # 0 beyond commit
        s = state.s * r_c[..., None, None] \
            + jnp.einsum("bjhd,bjhv->bhdv", fk_c, vf)
        z = state.z * r_c[..., None] + jnp.sum(fk_c, axis=1)
    else:
        s = s0 + jnp.einsum("bjhd,bjhv->bhdv", fk, vf)
        z = z0 + jnp.sum(fk, axis=1)
    log_scale = state.log_scale
    if renorm is not None and renorm > 0.0:
        # Drift renorm: shifting the reference constant up by delta and
        # scaling (s, z) by exp(-delta) is exactly the max-rescale identity
        # the normalized output is invariant to.  Gate on rows that folded
        # at least one token so frozen/uncommitted rows stay bitwise inert.
        zmax = jax.lax.stop_gradient(jnp.max(z, axis=-1))        # (B, H)
        if commit_len is not None:
            folded = (cl > 0)[:, None]
        elif row_mask is not None:
            folded = row_mask[:, None]
        else:
            folded = jnp.ones((b, 1), bool)
        delta = jnp.where(folded & (zmax > renorm),
                          jnp.log(jnp.maximum(zmax, EPS)), 0.0)
        scale = jnp.exp(-delta)
        s = s * scale[..., None, None]
        z = z * scale[..., None]
        c_new = c_new + delta[:, None, :, None]
        if log_scale is not None:
            log_scale = log_scale + delta
    if row_mask is not None:
        keep = row_mask
        s = jnp.where(keep[:, None, None, None], s, state.s)
        z = jnp.where(keep[:, None, None], z, state.z)
        c_new = jnp.where(keep[:, None, None, None], c_new, state.c_k)
        if log_scale is not None:
            log_scale = jnp.where(keep[:, None], log_scale, state.log_scale)
    return out.astype(v.dtype), LLNState(s=s, z=z, c_k=c_new,
                                         log_scale=log_scale)


def commit_chunk(
    state: LLNState,
    k: jnp.ndarray,
    v: jnp.ndarray,
    beta: jnp.ndarray,
    row_mask: Optional[jnp.ndarray] = None,
    commit_len: Optional[jnp.ndarray] = None,
    renorm: Optional[float] = None,
) -> LLNState:
    """Fold a chunk's accepted prefix into the state WITHOUT scoring.

    The commit half of :func:`decode_chunk` — same (k, v, beta) residuals,
    same ``commit_lengths`` contract, same renorm and ``row_mask`` guards —
    minus the query scoring.  This is the single-pass speculative-verify
    primitive: the verify pass scores the draft chunk with ``commit_len=0``
    (state untouched) and returns the post-RoPE (k, v) residuals; once the
    acceptance counts are known, this O(T d^2) einsum folds exactly the
    accepted prefix, bit-identical to re-running :func:`decode_chunk` with
    the final ``commit_len``.
    """
    b, t = k.shape[0], k.shape[1]
    bk = k * _bcast(beta, k)
    cl = commit_lengths(
        commit_len if commit_len is not None
        else jnp.full((b,), t, jnp.int32), row_mask, t)
    cmask = jnp.arange(t)[None, :] < cl[:, None]                 # (B, T)
    bk_c = jnp.where(cmask[:, :, None, None], bk, -jnp.inf)
    c_new = jnp.maximum(state.c_k, jax.lax.stop_gradient(
        jnp.max(bk_c, axis=(1, 3), keepdims=True)))
    vf = v.astype(jnp.float32)
    r_c = jnp.exp(state.c_k - c_new)[:, 0, :, 0]
    fk_c = jnp.exp(bk_c - c_new).astype(jnp.float32)    # 0 beyond commit
    s = state.s * r_c[..., None, None] \
        + jnp.einsum("bjhd,bjhv->bhdv", fk_c, vf)
    z = state.z * r_c[..., None] + jnp.sum(fk_c, axis=1)
    log_scale = state.log_scale
    if renorm is not None and renorm > 0.0:
        zmax = jax.lax.stop_gradient(jnp.max(z, axis=-1))        # (B, H)
        folded = (cl > 0)[:, None]
        delta = jnp.where(folded & (zmax > renorm),
                          jnp.log(jnp.maximum(zmax, EPS)), 0.0)
        scale = jnp.exp(-delta)
        s = s * scale[..., None, None]
        z = z * scale[..., None]
        c_new = c_new + delta[:, None, :, None]
        if log_scale is not None:
            log_scale = log_scale + delta
    if row_mask is not None:
        keep = row_mask
        s = jnp.where(keep[:, None, None, None], s, state.s)
        z = jnp.where(keep[:, None, None], z, state.z)
        c_new = jnp.where(keep[:, None, None, None], c_new, state.c_k)
        if log_scale is not None:
            log_scale = jnp.where(keep[:, None], log_scale, state.log_scale)
    return LLNState(s=s, z=z, c_k=c_new, log_scale=log_scale)
