"""Attention-concentration instruments (paper §3.2).

* entropy (eq. 7) — *biased* concentration; monotone increasing in the
  temperature (Thm. 3.2);
* spectral gap gamma = 1 - |lambda_2| — *unbiased* concentration (Thm. 3.3:
  lambda_2^2 equals the variance along the major principal component of the
  centered attention matrix);
* temperatures tau_sm (eq. 5) and tau_lln (eq. 11).

These are analysis tools (paper Figs. 1-2): they operate on explicit (N, N)
attention matrices and are intended for small-N probes, not the training path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .moment_matching import DEFAULT_A, DEFAULT_B


def row_entropy(p: jnp.ndarray) -> jnp.ndarray:
    """Mean base-2 row entropy of a stochastic matrix (eq. 7).  (..., N, N)."""
    logp = jnp.log2(jnp.clip(p, 1e-30, None))
    return -jnp.mean(jnp.sum(p * logp, axis=-1), axis=-1)


def spectral_gap(p: np.ndarray) -> float:
    """gamma = 1 - |lambda_2| of a right-stochastic matrix (numpy, analysis)."""
    ev = np.linalg.eigvals(np.asarray(p, np.float64))
    ev = np.sort(np.abs(ev))[::-1]
    lam2 = ev[1] if ev.size > 1 else 0.0
    return float(1.0 - lam2)


def variance_along_pc(p: np.ndarray) -> float:
    """sigma^2 along the major principal component of the centered matrix
    (Thm. 3.3 asserts this equals lambda_2^2)."""
    p = np.asarray(p, np.float64)
    n = p.shape[-1]
    mu = p.mean(axis=0, keepdims=True)
    pbar = p - np.ones((n, 1)) @ mu
    cov = pbar.T @ pbar
    return float(np.max(np.linalg.eigvalsh(cov)))


def temperature_sm(sigma_q: float, sigma_k: float, c_cross: float = 0.0) -> float:
    """tau_sm = 1 / sqrt(sigma_q^2 sigma_k^2 + C_cross)   (eq. 5)."""
    return float(1.0 / np.sqrt(sigma_q ** 2 * sigma_k ** 2 + c_cross))


def temperature_lln(alpha: float, beta: float, sigma_q: float, sigma_k: float,
                    a: float = DEFAULT_A, b: float = DEFAULT_B) -> float:
    """tau_lln = 1 / sqrt(a (alpha^2 s_q^2 + beta^2 s_k^2) + b)   (eq. 11)."""
    s2 = a * (alpha ** 2 * sigma_q ** 2 + beta ** 2 * sigma_k ** 2) + b
    return float(1.0 / np.sqrt(max(s2, 1e-12)))


def attention_log_moments(p: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(mean, var) of ln P — the log-normal parameters (Prop. 3.1 / 4.1)."""
    logp = jnp.log(jnp.clip(p, 1e-30, None))
    return jnp.mean(logp), jnp.var(logp)


def lognormality_score(p: jnp.ndarray, num_q: int = 256) -> float:
    """Quantile-quantile normality check of ln P: Pearson correlation between
    empirical quantiles of ln P and Gaussian quantiles (1.0 = log-normal)."""
    logp = np.asarray(jnp.log(jnp.clip(p, 1e-30, None))).ravel()
    probs = (np.arange(1, num_q + 1) - 0.5) / num_q
    emp = np.quantile(logp, probs)
    theo = _norm_ppf(probs)
    return float(np.corrcoef(emp, theo)[0, 1])


def _norm_ppf(p: np.ndarray) -> np.ndarray:
    """Acklam's inverse-normal-CDF approximation (no scipy dependency)."""
    a = [-3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
         1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00]
    b = [-5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
         6.680131188771972e+01, -1.328068155288572e+01]
    c = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
         -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00]
    d = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
         3.754408661907416e+00]
    p = np.asarray(p, np.float64)
    out = np.empty_like(p)
    plow, phigh = 0.02425, 1 - 0.02425
    lo = p < plow
    hi = p > phigh
    mid = ~(lo | hi)
    if lo.any():
        ql = np.sqrt(-2 * np.log(p[lo]))
        out[lo] = (((((c[0] * ql + c[1]) * ql + c[2]) * ql + c[3]) * ql + c[4]) * ql + c[5]) / \
                  ((((d[0] * ql + d[1]) * ql + d[2]) * ql + d[3]) * ql + 1)
    if hi.any():
        qh = np.sqrt(-2 * np.log(1 - p[hi]))
        out[hi] = -(((((c[0] * qh + c[1]) * qh + c[2]) * qh + c[3]) * qh + c[4]) * qh + c[5]) / \
                   ((((d[0] * qh + d[1]) * qh + d[2]) * qh + d[3]) * qh + 1)
    if mid.any():
        qm = p[mid] - 0.5
        r = qm * qm
        out[mid] = (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * qm / \
                   (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1)
    return out
