"""Attention-concentration instruments (paper §3.2).

* entropy (eq. 7) — *biased* concentration; monotone increasing in the
  temperature (Thm. 3.2);
* spectral gap gamma = 1 - |lambda_2| — *unbiased* concentration (Thm. 3.3:
  lambda_2^2 equals the variance along the major principal component of the
  centered attention matrix);
* temperatures tau_sm (eq. 5) and tau_lln (eq. 11).

These are analysis tools (paper Figs. 1-2): they operate on explicit (N, N)
attention matrices and are intended for small-N probes, not the training path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .moment_matching import DEFAULT_A, DEFAULT_B


def row_entropy(p: jnp.ndarray) -> jnp.ndarray:
    """Mean base-2 row entropy of a stochastic matrix (eq. 7).  (..., N, N)."""
    logp = jnp.log2(jnp.clip(p, 1e-30, None))
    return -jnp.mean(jnp.sum(p * logp, axis=-1), axis=-1)


def spectral_gap(p: np.ndarray) -> float:
    """gamma = 1 - |lambda_2| of a right-stochastic matrix (numpy, analysis)."""
    ev = np.linalg.eigvals(np.asarray(p, np.float64))
    ev = np.sort(np.abs(ev))[::-1]
    lam2 = ev[1] if ev.size > 1 else 0.0
    return float(1.0 - lam2)


def spectral_gap_power(p: np.ndarray, iters: int = 200,
                       seed: int = 0) -> float:
    """gamma = 1 - |lambda_2| via deflated power iteration (O(iters * N^2)).

    The dense :func:`spectral_gap` is O(N^3) eigvals — unusable at analysis
    N >= 8k.  A right-stochastic P has known dominant pair (lambda_1 = 1,
    right eigenvector 1); power-iterate P^T for the stationary left vector
    pi, deflate B = P - 1 pi^T (eigenvalues {0} ∪ {lambda_2, ...}), then
    estimate |lambda_2| from the norm-growth rate of B^m x — robust to a
    complex dominant pair, where a plain Rayleigh quotient oscillates.
    """
    p = np.asarray(p, np.float64)
    n = p.shape[-1]
    rng = np.random.default_rng(seed)
    pi = np.full(n, 1.0 / n)
    for _ in range(iters):
        pi = pi @ p
        pi /= pi.sum()
    x = rng.standard_normal(n)
    x -= np.ones(n) * (pi @ x)          # deflate: remove the lambda_1 mode
    x /= np.linalg.norm(x) + 1e-300
    burn = iters // 2
    log_rates = []
    for i in range(iters):
        x = p @ x - np.ones(n) * (pi @ x)
        nrm = np.linalg.norm(x)
        if nrm < 1e-300:
            return 1.0
        x /= nrm
        if i >= burn:                   # geometric mean of late growth rates
            log_rates.append(np.log(nrm))
    lam = float(np.exp(np.mean(log_rates)))
    return float(1.0 - lam)


def variance_along_pc(p: np.ndarray) -> float:
    """sigma^2 along the major principal component of the centered matrix
    (Thm. 3.3 asserts this equals lambda_2^2)."""
    p = np.asarray(p, np.float64)
    n = p.shape[-1]
    mu = p.mean(axis=0, keepdims=True)
    pbar = p - np.ones((n, 1)) @ mu
    cov = pbar.T @ pbar
    return float(np.max(np.linalg.eigvalsh(cov)))


def temperature_sm(sigma_q: float, sigma_k: float, c_cross: float = 0.0) -> float:
    """tau_sm = 1 / sqrt(sigma_q^2 sigma_k^2 + C_cross)   (eq. 5)."""
    return float(1.0 / np.sqrt(sigma_q ** 2 * sigma_k ** 2 + c_cross))


def temperature_lln(alpha: float, beta: float, sigma_q: float, sigma_k: float,
                    a: float = DEFAULT_A, b: float = DEFAULT_B) -> float:
    """tau_lln = 1 / sqrt(a (alpha^2 s_q^2 + beta^2 s_k^2) + b)   (eq. 11)."""
    s2 = a * (alpha ** 2 * sigma_q ** 2 + beta ** 2 * sigma_k ** 2) + b
    return float(1.0 / np.sqrt(max(s2, 1e-12)))


# ---------------------------------------------------------------------------
# Streaming concentration instruments (serving telemetry).
#
# The analysis tools above need the explicit (N, N) attention matrix; a
# serving row at 500k context never materializes one.  These estimators read
# the carried O(d^2) LLN decode state directly — jnp, jit-safe, O(H d) per
# row — and are fused into the continuous-batching segment next to the
# health sentinel (launch/steps.py).
# ---------------------------------------------------------------------------

def streaming_concentration(z: jnp.ndarray, log_scale=None, c=None,
                            pos=None, a: float = DEFAULT_A,
                            b: float = DEFAULT_B) -> dict:
    """Per-row concentration instruments from the carried LLN state.

    z: (..., B, H, D) accumulated key features Phi(k) = exp(beta k - c_k);
    c: (..., B, H) per-head reference constant ``c_k`` (squeezed);
    log_scale: (..., B, H) accumulated drift-renorm shift (None = zeros);
    pos: (B,) per-row committed depth.  Leading axes (a layer stack) are
    averaged out.  Returns (B,)-shaped instruments:

    * ``log_mass``  — ln sum_d z + c, the reference-free log key mass
      ``ln sum_t exp(beta k_t)``.  Exactly invariant to renormalization
      AND to reference-constant rebinding (both fold their shift into
      ``c_k``), so renorm-on and renorm-off runs agree to rounding.  When
      ``c`` is unavailable, ``log_scale`` (the cumulative renorm shift)
      corrects within-run renorm jumps instead.
    * ``conc_drift`` — log_mass - ln(pos): log mass *per committed token*.
      Flat over horizon ⇔ stationary concentration; a drifting value is
      the dilution / explosion pathology ("The Devil in Linear
      Transformer").  Only with ``pos``.
    * ``log_mass_var`` — Var_d[ln z_d], the across-dim dispersion of key
      log-features — a proxy for the key half of the matched log-variance
      sigma_tilde^2 (Prop. 4.1).
    * ``tau_hat`` — eq.-11-shaped temperature proxy
      1/sqrt(a * 2 * log_mass_var + b): its *flatness* over horizon is the
      health signal (the absolute value is a proxy, not eq. 11 itself).
    """
    lz = jnp.log(jnp.clip(z.astype(jnp.float32), 1e-30, None))
    log_mass = jax.scipy.special.logsumexp(lz, axis=-1)        # (...,B,H)
    if c is not None:
        log_mass = log_mass + c.astype(jnp.float32)
    elif log_scale is not None:
        log_mass = log_mass + log_scale.astype(jnp.float32)
    logvar = jnp.var(lz, axis=-1)                              # (...,B,H)
    # Average heads and any leading (layer) axes; row axis is -2 of z's
    # (..., B, H, D) layout after the D reduction.
    reduce_axes = tuple(i for i in range(log_mass.ndim) if i != log_mass.ndim - 2)
    lm = jnp.mean(log_mass, axis=reduce_axes)                  # (B,)
    lv = jnp.mean(logvar, axis=reduce_axes)                    # (B,)
    # Clamp the eq.-11 argument: small accumulated log-variance can push
    # a * 2 lv + b below zero (b < 0), where the proxy saturates.  The
    # floor bounds tau_hat at 10 — flatness over horizon is the signal,
    # not the absolute level.
    out = {"log_mass": lm, "log_mass_var": lv,
           "tau_hat": 1.0 / jnp.sqrt(jnp.maximum(a * 2.0 * lv + b, 1e-2))}
    if pos is not None:
        npos = jnp.maximum(jnp.asarray(pos, jnp.float32), 1.0)
        out["conc_drift"] = lm - jnp.log(npos)
    return out


def streaming_concentration_tree(tree, *, row_axis: int = 0) -> dict | None:
    """:func:`streaming_concentration` over a whole (possibly layer-stacked)
    decode-state pytree.

    Collects every ``z`` / ``c_k`` / ``log_scale`` / ``pos`` leaf by name
    (the ``AttentionState`` field names the sharding rules and the health
    sentinel already key off), moves ``row_axis`` first and averages
    instruments across layers.  Returns None when the tree carries no LLN
    state (softmax pools have no ``z``).
    """
    from jax.tree_util import tree_leaves_with_path
    from .health import _leaf_name
    zs, cs, lss, poss = [], [], [], []
    for path, leaf in tree_leaves_with_path(tree):
        name = _leaf_name(path)
        if name == "z":
            zs.append(leaf)
        elif name == "c_k":
            cs.append(leaf)
        elif name == "log_scale":
            lss.append(leaf)
        elif name == "pos":
            poss.append(leaf)
    if not zs:
        return None
    rows = zs[0].shape[row_axis]
    if len(cs) != len(zs):
        cs = [None] * len(zs)
    if len(lss) != len(zs):
        lss = [None] * len(zs)

    def _rows_last3(x):
        # (..., B, H, D) layout: move the row axis to -3 (z is (L?, B, H, D)).
        return jnp.moveaxis(x, row_axis, -3)

    def _rows_last2(x):
        return None if x is None else jnp.moveaxis(x, row_axis, -2)

    per_leaf = [streaming_concentration(
        _rows_last3(z),
        c=_rows_last2(None if c is None
                      else jnp.squeeze(c, axis=(-1, -3))),
        log_scale=_rows_last2(ls))
        for z, c, ls in zip(zs, cs, lss)]
    out = {k: sum(d[k] for d in per_leaf) / len(per_leaf)
           for k in per_leaf[0]}
    if poss:
        pos = jnp.moveaxis(poss[0], row_axis, 0).reshape(rows, -1)[:, 0]
        npos = jnp.maximum(pos.astype(jnp.float32), 1.0)
        out["conc_drift"] = out["log_mass"] - jnp.log(npos)
    return out


def attention_log_moments(p: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(mean, var) of ln P — the log-normal parameters (Prop. 3.1 / 4.1)."""
    logp = jnp.log(jnp.clip(p, 1e-30, None))
    return jnp.mean(logp), jnp.var(logp)


def lognormality_score(p: jnp.ndarray, num_q: int = 256) -> float:
    """Quantile-quantile normality check of ln P: Pearson correlation between
    empirical quantiles of ln P and Gaussian quantiles (1.0 = log-normal)."""
    logp = np.asarray(jnp.log(jnp.clip(p, 1e-30, None))).ravel()
    probs = (np.arange(1, num_q + 1) - 0.5) / num_q
    emp = np.quantile(logp, probs)
    theo = _norm_ppf(probs)
    return float(np.corrcoef(emp, theo)[0, 1])


def _norm_ppf(p: np.ndarray) -> np.ndarray:
    """Acklam's inverse-normal-CDF approximation (no scipy dependency)."""
    a = [-3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
         1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00]
    b = [-5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
         6.680131188771972e+01, -1.328068155288572e+01]
    c = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
         -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00]
    d = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
         3.754408661907416e+00]
    p = np.asarray(p, np.float64)
    out = np.empty_like(p)
    plow, phigh = 0.02425, 1 - 0.02425
    lo = p < plow
    hi = p > phigh
    mid = ~(lo | hi)
    if lo.any():
        ql = np.sqrt(-2 * np.log(p[lo]))
        out[lo] = (((((c[0] * ql + c[1]) * ql + c[2]) * ql + c[3]) * ql + c[4]) * ql + c[5]) / \
                  ((((d[0] * ql + d[1]) * ql + d[2]) * ql + d[3]) * ql + 1)
    if hi.any():
        qh = np.sqrt(-2 * np.log(1 - p[hi]))
        out[hi] = -(((((c[0] * qh + c[1]) * qh + c[2]) * qh + c[3]) * qh + c[4]) * qh + c[5]) / \
                   ((((d[0] * qh + d[1]) * qh + d[2]) * qh + d[3]) * qh + 1)
    if mid.any():
        qm = p[mid] - 0.5
        r = qm * qm
        out[mid] = (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * qm / \
                   (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1)
    return out
