"""Sharded, prefetched host data pipeline.

Production behaviours implemented (and unit-tested):
* per-host sharding: each process draws only its slice of the global batch
  (deterministic in (seed, step, host) — restart-safe, no data duplication);
* double-buffered background prefetch so a slow host's input pipeline never
  stalls the collective (straggler mitigation at the input layer);
* device placement with the train step's input shardings (pjit-ready
  global arrays via ``jax.make_array_from_process_local_data``).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax
import numpy as np


class HostShardedSource:
    """Wrap a (seed, step)-deterministic generator factory into a per-host
    sharded source: global batch B -> this host's B/num_hosts rows."""

    def __init__(self, make_gen: Callable[[int, int], Iterator[dict]],
                 global_batch: int, *, process_index: Optional[int] = None,
                 process_count: Optional[int] = None, start_step: int = 0):
        self.pi = jax.process_index() if process_index is None else process_index
        self.pc = jax.process_count() if process_count is None else process_count
        assert global_batch % self.pc == 0, "global batch must split over hosts"
        self.local_batch = global_batch // self.pc
        self.gen = make_gen(self.local_batch, start_step * self.pc + self.pi)
        self.step = start_step

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        batch = next(self.gen)
        self.step += 1
        return batch


class Prefetcher:
    """Background-thread double buffering (depth configurable)."""

    def __init__(self, source: Iterator[dict], depth: int = 2,
                 place: Optional[Callable[[dict], dict]] = None):
        self.source = source
        self.place = place or (lambda x: x)
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        try:
            for item in self.source:
                if self._stop.is_set():
                    return
                self.q.put(self.place(item))
        except Exception as e:  # surface errors to the consumer
            self.q.put(e)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if isinstance(item, Exception):
            raise item
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass


def device_placer(mesh, batch_specs):
    """Returns a callable placing a host-local numpy batch onto the mesh as
    global arrays with the given PartitionSpecs (dict key -> spec)."""
    from jax.sharding import NamedSharding

    def place(batch: dict) -> dict:
        out = {}
        for k, v in batch.items():
            sharding = NamedSharding(mesh, batch_specs[k])
            out[k] = jax.make_array_from_process_local_data(
                sharding, np.asarray(v))
        return out
    return place
