"""Deterministic synthetic corpora.

Two generators:

* :class:`MarkovCorpus` — a fixed random first-order Markov chain over the
  vocabulary (seeded).  Its entropy rate is well below log(V), so models
  *learn* on it and loss curves are meaningful (used by the Fig-8a-style
  convergence benchmark: LLN-vs-SA loss tracking).
* :func:`mlm_batches` — RoBERTa-style masked-LM batches over a corpus
  (15% masking: 80% [MASK], 10% random, 10% kept), matching the paper's
  pre-training objective.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class MarkovCorpus:
    vocab: int
    seed: int = 0
    branching: int = 32          # out-degree of each state (entropy knob)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        nexts = rng.integers(0, self.vocab, size=(self.vocab, self.branching))
        probs = rng.dirichlet(np.ones(self.branching) * 0.5,
                              size=self.vocab)
        self._nexts = nexts
        self._cum = np.cumsum(probs, axis=1)

    def sample(self, rng: np.random.Generator, batch: int,
               seq: int) -> np.ndarray:
        """(batch, seq) int32 token matrix."""
        out = np.empty((batch, seq), np.int32)
        state = rng.integers(0, self.vocab, size=batch)
        for t in range(seq):
            out[:, t] = state
            u = rng.random(batch)
            choice = (self._cum[state] < u[:, None]).sum(axis=1)
            choice = np.minimum(choice, self.branching - 1)
            state = self._nexts[state, choice]
        return out


def lm_batches(vocab: int, batch: int, seq: int, *, seed: int = 0,
               start_step: int = 0) -> Iterator[dict]:
    """Causal-LM batches: inputs/targets shifted by one, full mask."""
    corpus = MarkovCorpus(vocab)
    step = start_step
    while True:
        rng = np.random.default_rng((seed, step))
        toks = corpus.sample(rng, batch, seq + 1)
        yield {"inputs": toks[:, :-1], "targets": toks[:, 1:],
               "mask": np.ones((batch, seq), np.float32)}
        step += 1


def mlm_batches(vocab: int, batch: int, seq: int, *, seed: int = 0,
                mask_rate: float = 0.15, mask_id: Optional[int] = None,
                start_step: int = 0) -> Iterator[dict]:
    """Masked-LM batches (paper §5 objective).  Loss mask = masked positions."""
    corpus = MarkovCorpus(vocab)
    mask_id = vocab - 1 if mask_id is None else mask_id
    step = start_step
    while True:
        rng = np.random.default_rng((seed, step))
        toks = corpus.sample(rng, batch, seq)
        is_masked = rng.random((batch, seq)) < mask_rate
        u = rng.random((batch, seq))
        inputs = toks.copy()
        inputs[is_masked & (u < 0.8)] = mask_id
        rand_pos = is_masked & (u >= 0.8) & (u < 0.9)
        inputs[rand_pos] = rng.integers(0, vocab, size=rand_pos.sum())
        yield {"inputs": inputs, "targets": toks,
               "mask": is_masked.astype(np.float32)}
        step += 1
