"""Model zoo: composable model definitions for all assigned architectures."""
from .model_zoo import (Model, build_model, draft_config, draft_params,
                        synthetic_batch)

__all__ = ["Model", "build_model", "draft_config", "draft_params",
           "synthetic_batch"]
