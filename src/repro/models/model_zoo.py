"""build_model(cfg) -> Model: a uniform functional interface per family.

Batch conventions (all int32 tokens in [0, vocab)):
  lm / moe / ssm / hybrid : {"inputs" (B,N), "targets" (B,N), "mask" (B,N)}
  encdec                  : + {"src" (B,M,frontend_dim) float}
  vlm                     : + {"patches" (B,P,frontend_dim) float}

``loss``  : params, batch -> scalar (chunked xent + router aux).
``prefill``: params, batch -> (last logits (B, Vpad), caches).
``decode`` : params, caches, token (B,) or (B, T), position -> (logits,
             caches).  Dense/MoE decoders additionally accept a per-row
             (B,) ``position`` plus ``row_mask`` against per-row caches
             (``cache_init(..., per_row=True)``) — the continuous-batching
             contract (masked rows advance nothing).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import encdec as ed
from . import hybrid as hy
from . import transformer as tr
from . import vlm as vl
from .layers import chunked_xent


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable
    loss: Callable
    hidden: Callable
    prefill: Callable
    decode: Callable
    cache_init: Callable
    param_count: Callable
    # Single-pass speculative verify (dense/moe decoders only; None
    # elsewhere): ``score`` = logits + per-layer (k, v) residuals without
    # advancing the caches; ``commit`` = params-free O(T d^2) fold of the
    # accepted prefix (transformer.py:lm_score / lm_commit).
    score: Optional[Callable] = None
    commit: Optional[Callable] = None


def _xent_loss(cfg, h, head, batch):
    loss = chunked_xent(h, head, batch["targets"], batch["mask"],
                        vocab=cfg.vocab, dtype=cfg.cdtype,
                        softcap=cfg.logit_softcap)
    return loss


def _count(params) -> int:
    return int(sum(p.size for p in jax.tree_util.tree_leaves(params)))


def build_model(cfg: ArchConfig) -> Model:
    fam = cfg.family

    if fam in ("dense", "moe", "mla_moe"):
        def loss(params, batch):
            h, aux = tr.lm_hidden(params, batch["inputs"], cfg)
            return (_xent_loss(cfg, h, tr.lm_head_of(params), batch)
                    + cfg.router_aux_coef * aux)

        def hidden(params, batch):
            return tr.lm_hidden(params, batch["inputs"], cfg)

        def prefill(params, batch, max_len):
            return tr.lm_prefill(params, batch["inputs"], cfg, max_len)

        return Model(cfg=cfg, init=lambda key: tr.lm_init(key, cfg),
                     loss=loss, hidden=hidden, prefill=prefill,
                     decode=lambda p, c, t, pos, row_mask=None,
                     commit_len=None: tr.lm_decode(
                         p, c, t, cfg, pos, row_mask=row_mask,
                         commit_len=commit_len),
                     cache_init=lambda p, b, n, per_row=False:
                         tr.lm_cache_init(p, cfg, b, n, per_row=per_row),
                     param_count=_count,
                     score=(None if cfg.kv_lora > 0 else
                            lambda p, c, t, pos, row_mask=None:
                            tr.lm_score(p, c, t, cfg, pos,
                                        row_mask=row_mask)),
                     commit=(None if cfg.kv_lora > 0 else
                             lambda c, resid, commit_len, row_mask=None:
                             tr.lm_commit(c, resid, cfg, commit_len,
                                          row_mask=row_mask)))

    if fam in ("ssm", "hybrid"):
        def loss(params, batch):
            h, aux = hy.hybrid_hidden(params, batch["inputs"], cfg)
            head = params.get("lm_head", params["embed"]["table"].T)
            return _xent_loss(cfg, h, head, batch)

        def hidden(params, batch):
            return hy.hybrid_hidden(params, batch["inputs"], cfg)

        def prefill(params, batch, max_len):
            return hy.hybrid_prefill(params, batch["inputs"], cfg, max_len)

        return Model(cfg=cfg, init=lambda key: hy.hybrid_init(key, cfg),
                     loss=loss, hidden=hidden, prefill=prefill,
                     decode=lambda p, c, t, pos, row_mask=None,
                     commit_len=None: hy.hybrid_decode(
                         p, c, t, cfg, pos, row_mask=row_mask,
                         commit_len=commit_len),
                     cache_init=lambda p, b, n, per_row=False:
                         hy.hybrid_cache_init(p, cfg, b, n,
                                              per_row=per_row),
                     param_count=_count)

    if fam == "encdec":
        def loss(params, batch):
            h, aux = ed.encdec_hidden(params, batch["src"], batch["inputs"],
                                      cfg)
            return _xent_loss(cfg, h, params["lm_head"], batch)

        def hidden(params, batch):
            return ed.encdec_hidden(params, batch["src"], batch["inputs"], cfg)

        def prefill(params, batch, max_len):
            return ed.encdec_prefill(params, batch["src"], batch["inputs"],
                                     cfg, max_len)

        def cache_init(p, b, n):
            return ed.encdec_cache_init(p, cfg, b, n, enc_len=n)

        return Model(cfg=cfg, init=lambda key: ed.encdec_init(key, cfg),
                     loss=loss, hidden=hidden, prefill=prefill,
                     decode=lambda p, c, t, pos: ed.encdec_decode(p, c, t, cfg, pos),
                     cache_init=cache_init, param_count=_count)

    if fam == "encoder":
        from . import encoder as enc

        def loss(params, batch):
            h, aux = enc.encoder_hidden(params, batch["inputs"], cfg)
            return _xent_loss(cfg, h, params["lm_head"], batch)

        def hidden(params, batch):
            return enc.encoder_hidden(params, batch["inputs"], cfg)

        def no_serve(*a, **k):
            raise NotImplementedError("encoder-only models have no decode step")

        return Model(cfg=cfg, init=lambda key: enc.encoder_init(key, cfg),
                     loss=loss, hidden=hidden, prefill=no_serve,
                     decode=no_serve, cache_init=no_serve, param_count=_count)

    if fam == "vlm":
        def loss(params, batch):
            h, aux = vl.vlm_hidden(params, batch["patches"], batch["inputs"],
                                   cfg)
            return _xent_loss(cfg, h, tr.lm_head_of(params), batch)

        def hidden(params, batch):
            return vl.vlm_hidden(params, batch["patches"], batch["inputs"],
                                 cfg)

        def prefill(params, batch, max_len):
            return vl.vlm_prefill(params, batch["patches"], batch["inputs"],
                                  cfg, max_len)

        return Model(cfg=cfg, init=lambda key: vl.vlm_init(key, cfg),
                     loss=loss, hidden=hidden, prefill=prefill,
                     decode=lambda p, c, t, pos: vl.vlm_decode(p, c, t, cfg, pos),
                     cache_init=lambda p, b, n: vl.vlm_cache_init(p, cfg, b, n),
                     param_count=_count)

    raise ValueError(f"unknown family: {fam}")


# ---------------------------------------------------------------------------
# Speculative decoding: the tied first-k-layers draft model.
# ---------------------------------------------------------------------------

def draft_config(cfg: ArchConfig, draft_layers: int = 0) -> ArchConfig:
    """The draft model's config: the target truncated to its first
    ``draft_layers`` blocks (embedding, final norm and LM head shared) —
    the standard early-exit draft for draft-then-verify decoding.
    ``draft_layers`` defaults to ``cfg.draft_layers``; equal to
    ``cfg.n_layers`` it is the tied full model (acceptance -> 1, the
    machinery-proving configuration)."""
    k = draft_layers or cfg.draft_layers
    if not 1 <= k <= cfg.n_layers:
        raise ValueError(f"draft_layers must be in [1, {cfg.n_layers}], "
                         f"got {k}")
    if cfg.family not in ("dense", "moe") or cfg.first_dense_layers:
        raise NotImplementedError(
            "first-k-layers draft supports dense/moe decoders without "
            f"first_dense_layers (family={cfg.family})")
    return cfg.replace(name=f"{cfg.name}-draft{k}", n_layers=k)


def draft_params(params, cfg: ArchConfig, draft_layers: int = 0):
    """Slice the target's stacked layer params to the draft's first-k view.

    Zero-copy under jit (a static slice of the stacked (L, ...) leaves);
    everything else (embed / final_norm / lm_head) is shared by reference —
    the draft is TIED to the target, there are no extra weights to train
    or checkpoint."""
    k = draft_layers or cfg.draft_layers
    dcfg = draft_config(cfg, k)            # validates k and the family
    del dcfg
    out = {n: p for n, p in params.items() if n != "layers"}
    out["layers"] = jax.tree_util.tree_map(lambda a: a[:k],
                                           params["layers"])
    return out


def synthetic_batch(cfg: ArchConfig, batch: int, seq: int, key=None,
                    text_seq: Optional[int] = None) -> dict[str, Any]:
    """Deterministic synthetic batch with the family's input signature."""
    key = jax.random.PRNGKey(0) if key is None else key
    k1, k2, k3 = jax.random.split(key, 3)
    n = text_seq if text_seq is not None else seq
    if cfg.family == "vlm":
        n = max(seq - cfg.num_prefix_tokens, 8)
    toks = jax.random.randint(k1, (batch, n + 1), 0, cfg.vocab, jnp.int32)
    out = {"inputs": toks[:, :-1], "targets": toks[:, 1:],
           "mask": jnp.ones((batch, n), jnp.float32)}
    if cfg.family == "encdec":
        out["src"] = jax.random.normal(k2, (batch, seq, cfg.frontend_dim),
                                       jnp.float32)
    if cfg.family == "vlm":
        out["patches"] = jax.random.normal(
            k3, (batch, cfg.num_prefix_tokens, cfg.frontend_dim), jnp.float32)
    return out
