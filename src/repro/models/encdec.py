"""Encoder-decoder transformer (seamless-m4t-medium backbone).

The audio frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, M, frontend_dim); a linear adapter maps
them to d_model.  The encoder is bidirectional — the paper's exact published
setting for LLN attention (RoBERTa-style bidirectional encoder) — so
``attn_impl=lln_diag`` exercises eq. 8 in its native habitat.  Cross
attention stays softmax (N_q x M rectangle; LLN's state trick brings no
asymptotic win there and the paper does not linearize it).

Simplifications vs. the released m4t checkpoints (DESIGN.md): standard RoPE
instead of conformer relative-position machinery.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import attention as ca
from repro.distributed.sharding import constrain
from .attention_block import (attn_apply, attn_init, serve_decode,
                              serve_prefill, serve_state_init)
from .layers import (apply_mlp, apply_norm, dense, dense_init, embed_init,
                     embed_lookup, logits_from_hidden, mlp_init, norm_init,
                     trunc_normal)
from .transformer import _remat


def encdec_init(key, cfg):
    kf, ke, kd, kt, kh = jax.random.split(key, 5)
    p = {"frontend_proj": dense_init(kf, cfg.frontend_dim, cfg.d_model,
                                     cfg.pdtype),
         "embed": embed_init(kt, cfg.padded_vocab, cfg.d_model, cfg.pdtype),
         "enc_final_norm": norm_init(cfg.d_model, cfg.norm, cfg.pdtype),
         "final_norm": norm_init(cfg.d_model, cfg.norm, cfg.pdtype)}

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": norm_init(cfg.d_model, cfg.norm, cfg.pdtype),
                "attn": attn_init(k1, cfg),
                "ln2": norm_init(cfg.d_model, cfg.norm, cfg.pdtype),
                "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.act,
                                cfg.pdtype)}

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": norm_init(cfg.d_model, cfg.norm, cfg.pdtype),
                "attn": attn_init(k1, cfg),
                "ln_x": norm_init(cfg.d_model, cfg.norm, cfg.pdtype),
                "cross": attn_init(k2, cfg),
                "ln2": norm_init(cfg.d_model, cfg.norm, cfg.pdtype),
                "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.act,
                                cfg.pdtype)}

    p["enc_layers"] = jax.vmap(enc_block)(
        jax.random.split(ke, cfg.enc_layers))
    p["layers"] = jax.vmap(dec_block)(jax.random.split(kd, cfg.n_layers))
    p["lm_head"] = trunc_normal(kh, (cfg.d_model, cfg.padded_vocab),
                                cfg.d_model ** -0.5, cfg.pdtype)
    return p


def encode(p, src_embed, cfg):
    """src_embed: (B, M, frontend_dim) stub frame embeddings -> (B, M, D)."""
    x = dense(p["frontend_proj"], src_embed, cfg.cdtype)
    positions = jnp.arange(x.shape[1])

    def body(x, lp):
        h = apply_norm(lp["ln1"], x, cfg.norm)
        x = x + attn_apply(lp["attn"], h, cfg, positions,
                           causal=False).astype(x.dtype)
        h = apply_norm(lp["ln2"], x, cfg.norm)
        x = x + apply_mlp(lp["mlp"], h, cfg.act, cfg.cdtype).astype(x.dtype)
        return x, None

    x, _ = jax.lax.scan(_remat(body, cfg), x, p["enc_layers"],
                        unroll=bool(cfg.scan_unroll))
    return apply_norm(p["enc_final_norm"], x, cfg.norm)


def _dec_block(lp, x, enc_out, cfg, positions):
    h = apply_norm(lp["ln1"], x, cfg.norm)
    x = x + attn_apply(lp["attn"], h, cfg, positions,
                       causal=True).astype(x.dtype)
    h = apply_norm(lp["ln_x"], x, cfg.norm)
    x = x + attn_apply(lp["cross"], h, cfg, positions,
                       kv=enc_out).astype(x.dtype)
    h = apply_norm(lp["ln2"], x, cfg.norm)
    return x + apply_mlp(lp["mlp"], h, cfg.act, cfg.cdtype).astype(x.dtype)


def encdec_hidden(p, src_embed, tgt_tokens, cfg):
    enc_out = encode(p, src_embed, cfg)
    x = embed_lookup(p["embed"], tgt_tokens, cfg.cdtype, cfg.embed_scale)
    positions = jnp.arange(tgt_tokens.shape[1])

    def body(x, lp):
        return _dec_block(lp, x, enc_out, cfg, positions), None

    x, _ = jax.lax.scan(_remat(body, cfg), x, p["layers"],
                        unroll=bool(cfg.scan_unroll))
    x = apply_norm(p["final_norm"], x, cfg.norm)
    return x, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Serving.
# ---------------------------------------------------------------------------

def encdec_cache_init(p, cfg, batch: int, max_len: int, enc_len: int):
    one = serve_state_init(cfg, batch, max_len)
    g, hd = cfg.n_kv_heads, cfg.hd
    cross = {"ck": jnp.zeros((batch, enc_len, g, hd), cfg.cdtype),
             "cv": jnp.zeros((batch, enc_len, g, hd), cfg.cdtype)}
    return {"layers": jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape),
        {"self": one, **cross})}


def encdec_prefill(p, src_embed, tgt_tokens, cfg, max_len: int):
    """Encode source + prefill decoder over the target prefix."""
    enc_out = encode(p, src_embed, cfg)
    x = embed_lookup(p["embed"], tgt_tokens, cfg.cdtype, cfg.embed_scale)
    n = tgt_tokens.shape[1]
    positions = jnp.arange(n)
    b = x.shape[0]
    g, hd = cfg.n_kv_heads, cfg.hd

    def body(x, lp):
        h = apply_norm(lp["ln1"], x, cfg.norm)
        a, self_cache = serve_prefill(lp["attn"], h, cfg, positions,
                                      max_len=max_len)
        x = x + a.astype(x.dtype)
        h = apply_norm(lp["ln_x"], x, cfg.norm)
        m = enc_out.shape[1]
        ck = dense(lp["cross"]["k_w"], enc_out, cfg.cdtype).reshape(b, m, g, hd)
        cv = dense(lp["cross"]["v_w"], enc_out, cfg.cdtype).reshape(b, m, g, hd)
        q = dense(lp["cross"]["q_w"], h, cfg.cdtype).reshape(
            b, n, cfg.n_heads, hd)
        q = constrain(q, "act_batch", "attn_seq", "heads", None)
        ck = constrain(ck, "act_batch", None, "kv_heads", None)
        cv = constrain(cv, "act_batch", None, "kv_heads", None)
        xa = ca.flash_softmax(q, ck, cv, causal=False,
                              chunk=min(cfg.softmax_chunk, m))
        xa = dense(lp["cross"]["o_w"], xa.reshape(b, n, -1), cfg.cdtype)
        x = x + xa.astype(x.dtype)
        h = apply_norm(lp["ln2"], x, cfg.norm)
        x = x + apply_mlp(lp["mlp"], h, cfg.act, cfg.cdtype).astype(x.dtype)
        return x, {"self": self_cache, "ck": ck, "cv": cv}

    x, caches = jax.lax.scan(body, x, p["layers"],
                             unroll=bool(cfg.scan_unroll))
    x = apply_norm(p["final_norm"], x, cfg.norm)
    logits = logits_from_hidden(p["lm_head"], x[:, -1:], cfg.cdtype,
                                cfg.logit_softcap)
    return logits, {"layers": caches}


def encdec_decode(p, caches, token, cfg, position):
    if token.ndim != 1:
        raise NotImplementedError(
            "chunked (B, T) decode is not wired for the encdec family")
    x = embed_lookup(p["embed"], token[:, None], cfg.cdtype, cfg.embed_scale)
    b = x.shape[0]

    def body(x, xs):
        lp, cache = xs
        h = apply_norm(lp["ln1"], x, cfg.norm)
        a, self_cache = serve_decode(lp["attn"], h, cache["self"], cfg,
                                     position)
        x = x + a.astype(x.dtype)
        h = apply_norm(lp["ln_x"], x, cfg.norm)
        q = dense(lp["cross"]["q_w"], h, cfg.cdtype).reshape(
            b, 1, cfg.n_heads, cfg.hd)
        xa = ca.flash_softmax(q, cache["ck"], cache["cv"], causal=False,
                              chunk=min(cfg.softmax_chunk,
                                        cache["ck"].shape[1]))
        xa = dense(lp["cross"]["o_w"], xa.reshape(b, 1, -1), cfg.cdtype)
        x = x + xa.astype(x.dtype)
        h = apply_norm(lp["ln2"], x, cfg.norm)
        x = x + apply_mlp(lp["mlp"], h, cfg.act, cfg.cdtype).astype(x.dtype)
        return x, {"self": self_cache, "ck": cache["ck"], "cv": cache["cv"]}

    x, new_caches = jax.lax.scan(body, x, (p["layers"], caches["layers"]),
                                 unroll=bool(cfg.scan_unroll))
    x = apply_norm(p["final_norm"], x, cfg.norm)
    logits = logits_from_hidden(p["lm_head"], x, cfg.cdtype, cfg.logit_softcap)
    return logits[:, 0], {"layers": new_caches}
