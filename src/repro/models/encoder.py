"""Bidirectional encoder LM (RoBERTa-style) — the paper's §5 setting.

Token embeddings -> N bidirectional transformer blocks -> MLM head.
``attn_impl`` selects softmax / lln / lln_diag, reproducing the paper's
Table 1 comparison rows; with ``lln``/``lln_diag`` the encoder runs the
bidirectional LLN form (eq. 8) — the exact published configuration.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention_block import attn_apply, attn_init
from .layers import (apply_mlp, apply_norm, embed_init, embed_lookup,
                     logits_from_hidden, mlp_init, norm_init, trunc_normal)
from .transformer import _remat


def encoder_init(key, cfg):
    ke, kl, kh = jax.random.split(key, 3)

    def block(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": norm_init(cfg.d_model, cfg.norm, cfg.pdtype),
                "attn": attn_init(k1, cfg),
                "ln2": norm_init(cfg.d_model, cfg.norm, cfg.pdtype),
                "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.act,
                                cfg.pdtype)}

    return {"embed": embed_init(ke, cfg.padded_vocab, cfg.d_model, cfg.pdtype),
            "layers": jax.vmap(block)(jax.random.split(kl, cfg.n_layers)),
            "final_norm": norm_init(cfg.d_model, cfg.norm, cfg.pdtype),
            "lm_head": trunc_normal(kh, (cfg.d_model, cfg.padded_vocab),
                                    cfg.d_model ** -0.5, cfg.pdtype)}


def encoder_hidden(p, tokens, cfg):
    x = embed_lookup(p["embed"], tokens, cfg.cdtype, cfg.embed_scale)
    positions = jnp.arange(tokens.shape[1])

    def body(x, lp):
        h = apply_norm(lp["ln1"], x, cfg.norm)
        x = x + attn_apply(lp["attn"], h, cfg, positions,
                           causal=False).astype(x.dtype)
        h = apply_norm(lp["ln2"], x, cfg.norm)
        x = x + apply_mlp(lp["mlp"], h, cfg.act, cfg.cdtype).astype(x.dtype)
        return x, None

    x, _ = jax.lax.scan(_remat(body, cfg), x, p["layers"],
                        unroll=bool(cfg.scan_unroll))
    x = apply_norm(p["final_norm"], x, cfg.norm)
    return x, jnp.zeros((), jnp.float32)


def encoder_logits(p, tokens, cfg):
    h, aux = encoder_hidden(p, tokens, cfg)
    return logits_from_hidden(p["lm_head"], h, cfg.cdtype,
                              cfg.logit_softcap), aux
