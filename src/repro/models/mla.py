"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Queries/keys/values are compressed through low-rank latents:
  c_q  = RMSNorm(x W_dq)            (q_lora)
  q    = c_q W_uq                   -> per-head [nope | rope] parts
  c_kv = RMSNorm(x W_dkv)           (kv_lora = 512)
  k_nope, v = c_kv W_uk, c_kv W_uv  (decompressed per head)
  k_rope = RoPE(x W_kr)             (single shared rope key per position)

Decode caches only (c_kv, k_rope) — 576 floats/token — and uses the
*absorbed* formulation (W_uk folded into q, W_uv applied after the latent
context) so no per-step decompression of the whole cache is needed.

LLN applicability: the paper's technique applies to the assembled per-head
q/k (dim nope+rope); LLN decode then needs no token cache at all (O(d^2)
state) — the absorbed trick and the LLN state are two different routes to
the same memory goal, recorded separately in the roofline.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import attention as ca
from repro.core.engine import AttentionEngine, AttentionState
from repro.kernels.registry import deprecated_shim
from repro.distributed.sharding import constrain
from .attention_block import attn_cfg_of
from .layers import dense, dense_init, rope


def _dims(cfg):
    return (cfg.q_lora, cfg.kv_lora, cfg.nope_head_dim, cfg.rope_head_dim,
            cfg.v_head_dim, cfg.n_heads)


def mla_init(key, cfg):
    ql, kvl, nd, rd, vd, h = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    p = {"w_dkv": dense_init(ks[0], d, kvl, cfg.pdtype),
         "kv_norm_scale": jnp.ones((kvl,), cfg.pdtype),
         "w_uk": dense_init(ks[1], kvl, h * nd, cfg.pdtype),
         "w_uv": dense_init(ks[2], kvl, h * vd, cfg.pdtype),
         "w_kr": dense_init(ks[3], d, rd, cfg.pdtype),
         "o_w": dense_init(ks[4], h * vd, d, cfg.pdtype)}
    if ql:
        p["w_dq"] = dense_init(ks[5], d, ql, cfg.pdtype)
        p["q_norm_scale"] = jnp.ones((ql,), cfg.pdtype)
        p["w_uq"] = dense_init(ks[6], ql, h * (nd + rd), cfg.pdtype)
    else:
        p["w_q"] = dense_init(ks[7], d, h * (nd + rd), cfg.pdtype)
    return p


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (xf * inv * scale.astype(jnp.float32)).astype(x.dtype)


def _q_proj(p, x, cfg, positions):
    ql, kvl, nd, rd, vd, h = _dims(cfg)
    b, n, _ = x.shape
    if ql:
        cq = _rms(dense(p["w_dq"], x, cfg.cdtype), p["q_norm_scale"])
        q = dense(p["w_uq"], cq, cfg.cdtype).reshape(b, n, h, nd + rd)
    else:
        q = dense(p["w_q"], x, cfg.cdtype).reshape(b, n, h, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _kv_latent(p, x, cfg, positions):
    ckv = _rms(dense(p["w_dkv"], x, cfg.cdtype), p["kv_norm_scale"])
    kr = dense(p["w_kr"], x, cfg.cdtype)[:, :, None, :]      # (B,N,1,rd)
    kr = rope(kr, positions, cfg.rope_theta)
    return ckv, kr


def _decompress(p, ckv, cfg):
    ql, kvl, nd, rd, vd, h = _dims(cfg)
    b, n, _ = ckv.shape
    k_nope = dense(p["w_uk"], ckv, cfg.cdtype).reshape(b, n, h, nd)
    v = dense(p["w_uv"], ckv, cfg.cdtype).reshape(b, n, h, vd)
    return k_nope, v


def _assemble(q_nope, q_rope, k_nope, kr):
    h = q_nope.shape[2]
    k_rope = jnp.broadcast_to(kr, kr.shape[:2] + (h, kr.shape[-1]))
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, k_rope], -1)
    return q, k


def mla_apply(p, x, cfg, positions, *, causal: bool = True):
    """Full-sequence MLA (decompressed form), any attention impl."""
    b, n, _ = x.shape
    q_nope, q_rope = _q_proj(p, x, cfg, positions)
    ckv, kr = _kv_latent(p, x, cfg, positions)
    k_nope, v = _decompress(p, ckv, cfg)
    q, k = _assemble(q_nope, q_rope, k_nope, kr)
    q = constrain(q, "act_batch", "attn_seq", "heads", None)
    k = constrain(k, "act_batch", "attn_seq", "heads", None)
    v = constrain(v, "act_batch", "attn_seq", "heads", None)
    out = ca.multi_head_attention(q, k, v, attn_cfg_of(cfg, causal))
    out = out.reshape(b, n, -1)
    return dense(p["o_w"], out, cfg.cdtype)


# ---------------------------------------------------------------------------
# Serving — through the unified AttentionEngine.
#
# MLA's assembled per-head q/k (dim nope+rope, G == H) route through the
# same engine as standard attention, which is what gives MLA chunked
# multi-token decode and the kernelized LLN prefill/decode for free
# (ROADMAP "MLA serving parity").  Only the absorbed-form softmax decode
# stays MLA-specific: its state is the latent ``(ckv, kr)`` cache — carried
# in the same ``AttentionState`` pytree (``ckv``/``kr``/``len`` fields).
# ---------------------------------------------------------------------------

def mla_engine(cfg, causal: bool = True) -> AttentionEngine:
    """The engine for MLA's assembled q/k/v (full heads: G == H)."""
    ql, kvl, nd, rd, vd, h = _dims(cfg)
    return AttentionEngine.from_cfg(cfg, causal=causal, heads=h, kv_heads=h,
                                    head_dim=nd + rd, v_dim=vd)


def mla_state_init(cfg, batch: int, max_len: int) -> AttentionState:
    """Zeroed MLA decode state (per-row, like every engine state)."""
    ql, kvl, nd, rd, vd, h = _dims(cfg)
    if cfg.attn_impl == "softmax":
        return AttentionState(
            ckv=jnp.zeros((batch, max_len, kvl), cfg.cdtype),
            kr=jnp.zeros((batch, max_len, rd), cfg.cdtype),
            len=jnp.zeros((batch,), jnp.int32))
    return mla_engine(cfg).init_state(batch, max_len)


def mla_prefill(p, x, cfg, positions, *, max_len: int = 0):
    ql, kvl, nd, rd, vd, h = _dims(cfg)
    b, n, _ = x.shape
    q_nope, q_rope = _q_proj(p, x, cfg, positions)
    ckv, kr = _kv_latent(p, x, cfg, positions)
    k_nope, v = _decompress(p, ckv, cfg)
    q, k = _assemble(q_nope, q_rope, k_nope, kr)
    if cfg.attn_impl == "softmax":
        out = ca.multi_head_attention(q, k, v, attn_cfg_of(cfg, True))
        ml = max(max_len, n)
        pad = ((0, 0), (0, ml - n), (0, 0))
        state = AttentionState(
            ckv=jnp.pad(ckv.astype(cfg.cdtype), pad),
            kr=jnp.pad(kr[:, :, 0].astype(cfg.cdtype), pad),
            len=jnp.full((b,), n, jnp.int32))
    else:
        out, state = mla_engine(cfg).prefill(q, k, v, max_len=max(max_len, n))
    out = out.reshape(b, n, -1)
    return dense(p["o_w"], out, cfg.cdtype), state


def _mla_absorbed_decode(p, cfg, state, q_nope, q_rope, ckv_new, kr_new):
    """Absorbed-form softmax decode over T >= 1 tokens: q is folded into
    the latent space (``W_uk``) so the whole cache is scored without
    per-step decompression; within-chunk causality comes from explicit
    absolute positions (``len + i``)."""
    ql, kvl, nd, rd, vd, h = _dims(cfg)
    b, t = q_nope.shape[:2]
    upd = lambda c, u, l: jax.lax.dynamic_update_slice_in_dim(c, u, l, 0)
    ckv = jax.vmap(upd)(state.ckv, ckv_new.astype(state.ckv.dtype),
                        state.len)
    krc = jax.vmap(upd)(state.kr, kr_new[:, :, 0].astype(state.kr.dtype),
                        state.len)
    ckv = constrain(ckv, "act_batch", "act_seq_cache", None)
    new_len = state.len + t
    # Absorbed: q' = q_nope @ W_uk (per head) lives in latent space.
    w_uk = p["w_uk"].reshape(kvl, h, nd)
    q_lat = jnp.einsum("bqhn,khn->bqhk", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    s = jnp.einsum("bqhk,bsk->bhqs", q_lat, ckv.astype(jnp.float32))
    s = s + jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                       krc.astype(jnp.float32))
    s = s * ((nd + rd) ** -0.5)
    # Query i (absolute position len + i) sees keys j <= len + i.
    key_pos = jnp.arange(ckv.shape[1])
    q_pos = state.len[:, None] + jnp.arange(t)[None, :]           # (B, T)
    allowed = key_pos[None, None, None, :] <= q_pos[:, None, :, None]
    s = jnp.where(allowed, s, -1e30)
    attn = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhqs,bsk->bqhk", attn, ckv.astype(jnp.float32))
    w_uv = p["w_uv"].reshape(kvl, h, vd)
    out = jnp.einsum("bqhk,khv->bqhv", ctx, w_uv.astype(jnp.float32))
    return (out.astype(cfg.cdtype),
            state.replace(ckv=ckv, kr=krc, len=new_len))


def mla_decode(p, x, state, cfg, position):
    """MLA decode over T >= 1 tokens (x: (B, T, d)) — the engine's chunked
    decode for LLN impls (``lln_decode_chunk`` with tails), the absorbed
    formulation for softmax.  ``position``: scalar or per-row (B,) index of
    the first new token."""
    ql, kvl, nd, rd, vd, h = _dims(cfg)
    b, n, _ = x.shape
    if jnp.ndim(position) == 0:
        pos = position + jnp.arange(n, dtype=jnp.int32)
    elif jnp.ndim(position) == 1:
        pos = position[:, None] + jnp.arange(n, dtype=jnp.int32)[None, :]
    else:
        pos = position
    q_nope, q_rope = _q_proj(p, x, cfg, pos)
    ckv_new, kr_new = _kv_latent(p, x, cfg, pos)

    if cfg.attn_impl == "softmax":
        out, state = _mla_absorbed_decode(p, cfg, state, q_nope, q_rope,
                                          ckv_new, kr_new)
    else:
        k_nope, v = _decompress(p, ckv_new, cfg)
        q, k = _assemble(q_nope, q_rope, k_nope, kr_new)
        out, state = mla_engine(cfg).decode(state, q, k, v)
    out = out.reshape(b, n, -1)
    return dense(p["o_w"], out, cfg.cdtype), state


@deprecated_shim("models.mla.mla_cache_init", "mla_state_init")
def mla_cache_init(cfg, batch: int, max_len: int):
    """Legacy cache initializer — delegates to :func:`mla_state_init`."""
    return mla_state_init(cfg, batch, max_len)
