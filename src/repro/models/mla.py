"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Queries/keys/values are compressed through low-rank latents:
  c_q  = RMSNorm(x W_dq)            (q_lora)
  q    = c_q W_uq                   -> per-head [nope | rope] parts
  c_kv = RMSNorm(x W_dkv)           (kv_lora = 512)
  k_nope, v = c_kv W_uk, c_kv W_uv  (decompressed per head)
  k_rope = RoPE(x W_kr)             (single shared rope key per position)

Decode caches only (c_kv, k_rope) — 576 floats/token — and uses the
*absorbed* formulation (W_uk folded into q, W_uv applied after the latent
context) so no per-step decompression of the whole cache is needed.

LLN applicability: the paper's technique applies to the assembled per-head
q/k (dim nope+rope); LLN decode then needs no token cache at all (O(d^2)
state) — the absorbed trick and the LLN state are two different routes to
the same memory goal, recorded separately in the roofline.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import attention as ca
from repro.core import lln as core_lln
from repro.distributed.sharding import constrain
from .attention_block import attn_cfg_of
from .layers import dense, dense_init, rope


def _dims(cfg):
    return (cfg.q_lora, cfg.kv_lora, cfg.nope_head_dim, cfg.rope_head_dim,
            cfg.v_head_dim, cfg.n_heads)


def mla_init(key, cfg):
    ql, kvl, nd, rd, vd, h = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    p = {"w_dkv": dense_init(ks[0], d, kvl, cfg.pdtype),
         "kv_norm_scale": jnp.ones((kvl,), cfg.pdtype),
         "w_uk": dense_init(ks[1], kvl, h * nd, cfg.pdtype),
         "w_uv": dense_init(ks[2], kvl, h * vd, cfg.pdtype),
         "w_kr": dense_init(ks[3], d, rd, cfg.pdtype),
         "o_w": dense_init(ks[4], h * vd, d, cfg.pdtype)}
    if ql:
        p["w_dq"] = dense_init(ks[5], d, ql, cfg.pdtype)
        p["q_norm_scale"] = jnp.ones((ql,), cfg.pdtype)
        p["w_uq"] = dense_init(ks[6], ql, h * (nd + rd), cfg.pdtype)
    else:
        p["w_q"] = dense_init(ks[7], d, h * (nd + rd), cfg.pdtype)
    return p


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (xf * inv * scale.astype(jnp.float32)).astype(x.dtype)


def _q_proj(p, x, cfg, positions):
    ql, kvl, nd, rd, vd, h = _dims(cfg)
    b, n, _ = x.shape
    if ql:
        cq = _rms(dense(p["w_dq"], x, cfg.cdtype), p["q_norm_scale"])
        q = dense(p["w_uq"], cq, cfg.cdtype).reshape(b, n, h, nd + rd)
    else:
        q = dense(p["w_q"], x, cfg.cdtype).reshape(b, n, h, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _kv_latent(p, x, cfg, positions):
    ckv = _rms(dense(p["w_dkv"], x, cfg.cdtype), p["kv_norm_scale"])
    kr = dense(p["w_kr"], x, cfg.cdtype)[:, :, None, :]      # (B,N,1,rd)
    kr = rope(kr, positions, cfg.rope_theta)
    return ckv, kr


def _decompress(p, ckv, cfg):
    ql, kvl, nd, rd, vd, h = _dims(cfg)
    b, n, _ = ckv.shape
    k_nope = dense(p["w_uk"], ckv, cfg.cdtype).reshape(b, n, h, nd)
    v = dense(p["w_uv"], ckv, cfg.cdtype).reshape(b, n, h, vd)
    return k_nope, v


def _assemble(q_nope, q_rope, k_nope, kr):
    h = q_nope.shape[2]
    k_rope = jnp.broadcast_to(kr, kr.shape[:2] + (h, kr.shape[-1]))
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, k_rope], -1)
    return q, k


def mla_apply(p, x, cfg, positions, *, causal: bool = True):
    """Full-sequence MLA (decompressed form), any attention impl."""
    b, n, _ = x.shape
    q_nope, q_rope = _q_proj(p, x, cfg, positions)
    ckv, kr = _kv_latent(p, x, cfg, positions)
    k_nope, v = _decompress(p, ckv, cfg)
    q, k = _assemble(q_nope, q_rope, k_nope, kr)
    q = constrain(q, "act_batch", "attn_seq", "heads", None)
    k = constrain(k, "act_batch", "attn_seq", "heads", None)
    v = constrain(v, "act_batch", "attn_seq", "heads", None)
    out = ca.multi_head_attention(q, k, v, attn_cfg_of(cfg, causal))
    out = out.reshape(b, n, -1)
    return dense(p["o_w"], out, cfg.cdtype)


# ---------------------------------------------------------------------------
# Serving.
# ---------------------------------------------------------------------------

def mla_cache_init(cfg, batch: int, max_len: int):
    ql, kvl, nd, rd, vd, h = _dims(cfg)
    if cfg.attn_impl == "softmax":
        return {"ckv": jnp.zeros((batch, max_len, kvl), cfg.cdtype),
                "kr": jnp.zeros((batch, max_len, rd), cfg.cdtype),
                "len": jnp.zeros((), jnp.int32)}
    d = nd + rd
    return {"s": jnp.zeros((batch, h, d, vd), jnp.float32),
            "z": jnp.zeros((batch, h, d), jnp.float32),
            "c_k": jnp.zeros((batch, 1, h, 1), jnp.float32),
            "tail_k": jnp.zeros((batch, cfg.diag_block, h, d), cfg.cdtype),
            "tail_v": jnp.zeros((batch, cfg.diag_block, h, vd), cfg.cdtype),
            "pos": jnp.zeros((), jnp.int32),
            "alpha": jnp.ones((h,), jnp.float32),
            "beta": jnp.ones((h,), jnp.float32)}


def mla_prefill(p, x, cfg, positions, *, max_len: int = 0):
    ql, kvl, nd, rd, vd, h = _dims(cfg)
    b, n, _ = x.shape
    q_nope, q_rope = _q_proj(p, x, cfg, positions)
    ckv, kr = _kv_latent(p, x, cfg, positions)
    k_nope, v = _decompress(p, ckv, cfg)
    q, k = _assemble(q_nope, q_rope, k_nope, kr)
    acfg = attn_cfg_of(cfg, True)
    if cfg.attn_impl == "softmax":
        out = ca.multi_head_attention(q, k, v, acfg)
        ml = max(max_len, n)
        pad = ((0, 0), (0, ml - n), (0, 0))
        cache = {"ckv": jnp.pad(ckv.astype(cfg.cdtype), pad),
                 "kr": jnp.pad(kr[:, :, 0].astype(cfg.cdtype), pad),
                 "len": jnp.asarray(n, jnp.int32)}
    else:
        alpha, beta = ca.batch_alpha_beta(q, k, acfg)
        lln_out, st = core_lln.prefill(q, k, v, alpha, beta,
                                       chunk=cfg.lln_chunk)
        if cfg.attn_impl == "lln_diag":
            from repro.core.diag import block_diag_attn
            diag_out = block_diag_attn(q, k, v, block=cfg.diag_block,
                                       causal=True)
            out = (0.5 * (lln_out.astype(jnp.float32)
                          + diag_out.astype(jnp.float32))).astype(v.dtype)
        else:
            out = lln_out
        blk = cfg.diag_block
        nb = -(-n // blk)
        last = (nb - 1) * blk
        pad = nb * blk - n
        tail_k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))[:, last:]
        tail_v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))[:, last:]
        cache = {"s": st.s, "z": st.z, "c_k": st.c_k,
                 "tail_k": tail_k.astype(cfg.cdtype),
                 "tail_v": tail_v.astype(cfg.cdtype),
                 "pos": jnp.asarray(n, jnp.int32),
                 "alpha": alpha.astype(jnp.float32),
                 "beta": beta.astype(jnp.float32)}
    out = out.reshape(b, n, -1)
    return dense(p["o_w"], out, cfg.cdtype), cache


def mla_decode(p, x, cache, cfg, position):
    """One-token MLA decode.  Softmax path uses the absorbed formulation."""
    ql, kvl, nd, rd, vd, h = _dims(cfg)
    b, n, _ = x.shape
    pos = jnp.full((1,), position, jnp.int32)
    q_nope, q_rope = _q_proj(p, x, cfg, pos)
    ckv_new, kr_new = _kv_latent(p, x, cfg, pos)

    if cfg.attn_impl == "softmax":
        ckv = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv_new.astype(cache["ckv"].dtype), cache["len"], 1)
        krc = jax.lax.dynamic_update_slice_in_dim(
            cache["kr"], kr_new[:, :, 0].astype(cache["kr"].dtype),
            cache["len"], 1)
        ckv = constrain(ckv, "act_batch", "act_seq_cache", None)
        new_len = cache["len"] + 1
        # Absorbed: q' = q_nope @ W_uk (per head) lives in latent space.
        w_uk = p["w_uk"].reshape(kvl, h, nd)
        q_lat = jnp.einsum("bqhn,khn->bqhk", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))
        s = jnp.einsum("bqhk,bsk->bhqs", q_lat,
                       ckv.astype(jnp.float32))
        s = s + jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                           krc.astype(jnp.float32))
        s = s * ((nd + rd) ** -0.5)
        valid = jnp.arange(ckv.shape[1])[None, None, None, :] < new_len
        s = jnp.where(valid, s, -1e30)
        attn = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhqs,bsk->bqhk", attn, ckv.astype(jnp.float32))
        w_uv = p["w_uv"].reshape(kvl, h, vd)
        out = jnp.einsum("bqhk,khv->bqhv", ctx, w_uv.astype(jnp.float32))
        out = out.astype(cfg.cdtype)
        new_cache = {"ckv": ckv, "kr": krc, "len": new_len}
    else:
        k_nope, v = _decompress(p, ckv_new, cfg)
        q, k = _assemble(q_nope, q_rope, k_nope, kr_new)
        st = ca.LLNDecodeState(
            lln=core_lln.LLNState(s=cache["s"], z=cache["z"],
                                  c_k=cache["c_k"]),
            tail_k=cache["tail_k"], tail_v=cache["tail_v"], pos=cache["pos"])
        out, st = ca.decode_lln(st, q, k, v, cache["alpha"], cache["beta"],
                                impl=cfg.attn_impl)
        new_cache = {"s": st.lln.s, "z": st.lln.z, "c_k": st.lln.c_k,
                     "tail_k": st.tail_k, "tail_v": st.tail_v, "pos": st.pos,
                     "alpha": cache["alpha"], "beta": cache["beta"]}
    out = out.reshape(b, n, -1)
    return dense(p["o_w"], out, cfg.cdtype), new_cache
