"""Mamba2 — State Space Duality (SSD) blocks (arXiv:2405.21060).

The SSD recurrence per head (state N = ssm_state, head dim P):

    h_t = exp(dt_t * A) h_{t-1} + B_t (dt_t x_t)^T      h: (N, P)
    y_t = C_t^T h_t + D x_t

computed in chunks (the dual quadratic form within a chunk + a state pass
between chunks) — the same chunk/state-pass structure as the causal LLN
kernel, which is why the two families share a roofline column in
EXPERIMENTS.md.  All state math in fp32; log-space decay for stability.

Note (DESIGN.md §Arch-applicability): this family is attention-free — the
paper's LLN technique does not apply here; the arch runs without it.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.numerics import einsum_f32
from repro.distributed.sharding import constrain
from .layers import apply_norm, dense, dense_init, norm_init, trunc_normal


def _dims(cfg):
    di = cfg.ssm_expand * cfg.d_model
    h = di // cfg.ssm_head_dim
    return di, h, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups


def ssm_init(key, cfg):
    di, h, p_dim, s, g = _dims(cfg)
    d = cfg.d_model
    conv_dim = di + 2 * g * s
    ks = jax.random.split(key, 8)
    return {
        "w_z": dense_init(ks[0], d, di, cfg.pdtype),
        "w_x": dense_init(ks[1], d, di, cfg.pdtype),
        "w_B": dense_init(ks[2], d, g * s, cfg.pdtype),
        "w_C": dense_init(ks[3], d, g * s, cfg.pdtype),
        "w_dt": dense_init(ks[4], d, h, cfg.pdtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "conv_w": trunc_normal(ks[5], (cfg.conv_width, conv_dim),
                               conv_dim ** -0.5, cfg.pdtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.pdtype),
        "norm": norm_init(di, "rmsnorm", cfg.pdtype),
        "out_w": dense_init(ks[6], di, d, cfg.pdtype),
    }


def _causal_conv(x, w, b, dtype):
    """Depthwise causal conv, width W: y_t = sum_j x_{t-W+1+j} w_j."""
    wdt = w.shape[0]
    xf = x.astype(dtype)
    out = jnp.zeros_like(xf)
    for j in range(wdt):
        shift = wdt - 1 - j
        shifted = jnp.pad(xf, ((0, 0), (shift, 0), (0, 0)))[:, :xf.shape[1]]
        out = out + shifted * w[j].astype(dtype)[None, None, :]
    return jax.nn.silu(out + b.astype(dtype)[None, None, :])


def ssd_chunked(xbar, b_in, c_in, log_a, *, chunk: int,
                state0: Optional[jnp.ndarray] = None):
    """Chunked SSD scan.

    xbar: (B, L, H, P) dt-scaled inputs; b_in/c_in: (B, L, H, S) (already
    group-broadcast); log_a: (B, L, H) per-step log decay (<= 0).
    Returns (y (B, L, H, P), final_state (B, H, S, P)).
    """
    bsz, l, h, p = xbar.shape
    s = b_in.shape[-1]
    c = min(chunk, l)
    pad = (-l) % c
    if pad:
        xbar = jnp.pad(xbar, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
    nc = xbar.shape[1] // c

    def resh(t, last):
        return t.reshape((bsz, nc, c) + last).transpose(
            (1, 0, 2) + tuple(range(3, 3 + len(last))))
    # Stacks keep their input dtype (fp32 accumulation happens in the
    # einsums); constrained like the LLN/flash stacks so the partitioner
    # keeps batch on data and heads on model.
    xc = resh(xbar, (h, p))
    bc = resh(b_in, (h, s))
    cc = resh(c_in, (h, s))
    lc = resh(log_a.astype(jnp.float32), (h,))
    xc = constrain(xc, None, "act_batch", None, "heads", None)
    bc = constrain(bc, None, "act_batch", None, "heads", None)
    cc = constrain(cc, None, "act_batch", None, "heads", None)

    tri = jnp.tril(jnp.ones((c, c), jnp.float32))
    if state0 is None:
        state0 = jnp.zeros((bsz, h, s, p), jnp.float32)

    def step(state, xs):
        xb, bb, cb, la = xs                       # (B,C,H,*)
        lcum = jnp.cumsum(la, axis=1)             # (B,C,H)
        # intra-chunk: score_ij = (C_i . B_j) exp(lcum_i - lcum_j), j <= i
        dot = einsum_f32("bihs,bjhs->bhij", cb, bb)
        dec = jnp.exp(jnp.clip(lcum[:, :, None] - lcum[:, None, :],
                               -60.0, 0.0)).transpose(0, 3, 1, 2)  # (B,H,i,j)
        scores = dot * dec * tri[None, None]
        y_intra = einsum_f32("bhij,bjhp->bihp", scores.astype(xb.dtype),
                             xb)
        # inter-chunk: y_i += exp(lcum_i) C_i . state
        ein = jnp.exp(jnp.clip(lcum, -60.0, 0.0))
        y_inter = einsum_f32("bihs,bhsp->bihp", cb,
                             state.astype(cb.dtype)) * \
            ein[..., None]
        # state pass: state = exp(l_last) state + sum_j exp(l_last - l_j) B_j xbar_j
        l_last = lcum[:, -1]                      # (B,H)
        carry_dec = jnp.exp(jnp.clip(l_last[:, None] - lcum, -60.0, 0.0))
        state = state * jnp.exp(jnp.clip(l_last, -60.0, 0.0))[:, :, None, None] \
            + jnp.einsum("bjhs,bjh,bjhp->bhsp", bb.astype(jnp.float32),
                         carry_dec, xb.astype(jnp.float32))
        return state, y_intra + y_inter

    # remat: recompute intra-chunk scores in backward (see core/lln.py).
    state, yc = jax.lax.scan(jax.checkpoint(step), state0, (xc, bc, cc, lc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(bsz, nc * c, h, p)
    return y[:, :l], state


def ssm_apply(p, x, cfg, *, state0=None, return_state: bool = False,
              conv_tail: Optional[jnp.ndarray] = None):
    """Full-sequence Mamba2 block.  x: (B, L, D) -> (B, L, D)."""
    di, h, p_dim, s, g = _dims(cfg)
    bsz, l, _ = x.shape
    dtype = cfg.cdtype
    z = dense(p["w_z"], x, dtype)
    xs = dense(p["w_x"], x, dtype)
    b_proj = dense(p["w_B"], x, dtype)
    c_proj = dense(p["w_C"], x, dtype)
    dt = dense(p["w_dt"], x, dtype).astype(jnp.float32)

    # Depthwise conv applied per piece: concatenating the (model-sharded) x
    # stream with the (replicated) B/C streams would force a gather/reshard
    # of the whole activation; channel-wise the pieces are independent.
    gs = g * s
    xs_raw, b_raw, c_raw = xs, b_proj, c_proj
    xs = _causal_conv(xs, p["conv_w"][:, :di], p["conv_b"][:di], dtype)
    b_proj = _causal_conv(b_proj, p["conv_w"][:, di:di + gs],
                          p["conv_b"][di:di + gs], dtype)
    c_proj = _causal_conv(c_proj, p["conv_w"][:, di + gs:],
                          p["conv_b"][di + gs:], dtype)

    dt = jax.nn.softplus(dt + p["dt_bias"][None, None])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))            # (H,) < 0
    log_a = dt * a[None, None]                               # (B,L,H)

    xh = xs.reshape(bsz, l, h, p_dim)
    xh = constrain(xh, "act_batch", None, "heads", None)
    xbar = xh.astype(jnp.float32) * dt[..., None]
    rep = h // g
    if cfg.use_kernel and state0 is None and not return_state \
            and l % cfg.ssm_chunk == 0:
        # Pallas SSD kernel (training fwd; groups via index maps, no repeat).
        from repro.kernels import ssd_scan
        y = ssd_scan(xbar, b_proj.reshape(bsz, l, g, s),
                     c_proj.reshape(bsz, l, g, s), log_a, cfg.ssm_chunk)
        state = None
    else:
        b_in = jnp.repeat(b_proj.reshape(bsz, l, g, s), rep, axis=2)
        c_in = jnp.repeat(c_proj.reshape(bsz, l, g, s), rep, axis=2)
        y, state = ssd_chunked(xbar, b_in, c_in, log_a, chunk=cfg.ssm_chunk,
                               state0=state0)
    y = y + xh.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(bsz, l, di).astype(dtype)
    y = y * jax.nn.silu(z)
    y = apply_norm(p["norm"], y, "rmsnorm")
    out = dense(p["out_w"], y, dtype)
    if return_state:
        tail = jnp.concatenate([xs_raw, b_raw, c_raw],
                               -1)[:, -(cfg.conv_width - 1):]
        return out, {"state": state, "conv": tail.astype(dtype)}
    return out


def ssm_cache_init(cfg, batch: int):
    di, h, p_dim, s, g = _dims(cfg)
    conv_dim = di + 2 * g * s
    return {"state": jnp.zeros((batch, h, s, p_dim), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim),
                              cfg.cdtype)}


def ssm_decode_chunk(p, x, cache, cfg, *, row_mask=None, commit_len=None):
    """Chunked T-token SSD decode under the serving contract.

    x: (B, T, D).  All T positions are scored (each sees exactly the
    tokens a sequential decode would have seen: the carried ``state`` /
    conv window plus the in-chunk prefix), but the cache folds only the
    accepted prefix: ``commit_len`` (B,) int32 in [0, T] selects how many
    tokens enter the recurrent state and the conv window per row
    (speculative partial commit), and ``row_mask`` (B,) bool freezes
    masked rows bitwise (their outputs are garbage and must be
    discarded) — the same contract as ``AttentionEngine.decode``.
    Returns (out (B, T, D), new cache).
    """
    from repro.core.lln import commit_lengths
    di, h, p_dim, s, g = _dims(cfg)
    bsz, t, _ = x.shape
    dtype = cfg.cdtype
    wdt = cfg.conv_width
    z = dense(p["w_z"], x, dtype)
    xs = dense(p["w_x"], x, dtype)
    b_proj = dense(p["w_B"], x, dtype)
    c_proj = dense(p["w_C"], x, dtype)
    dt = dense(p["w_dt"], x, dtype).astype(jnp.float32)

    # Causal conv over [cached window | chunk]: position t sees rows
    # t .. t+W-1 of the concatenation — the exact sliding window a
    # sequential one-token loop would assemble.
    conv_in = jnp.concatenate([xs, b_proj, c_proj], -1)       # (B,T,Cd)
    window = jnp.concatenate([cache["conv"].astype(dtype), conv_in], 1)
    conv_out = jnp.zeros((bsz, t, window.shape[-1]), dtype)
    for j in range(wdt):
        conv_out = conv_out + window[:, j:j + t] * \
            p["conv_w"][j].astype(dtype)[None, None, :]
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(dtype)[None, None])
    xs = conv_out[..., :di]
    b_proj = conv_out[..., di:di + g * s]
    c_proj = conv_out[..., di + g * s:]

    dt = jax.nn.softplus(dt + p["dt_bias"][None, None])       # (B,T,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    log_a = dt * a[None, None]                                # (B,T,H)

    xh = xs.reshape(bsz, t, h, p_dim).astype(jnp.float32)
    xbar = xh * dt[..., None]
    rep = h // g
    b_in = jnp.repeat(b_proj.reshape(bsz, t, g, s), rep,
                      axis=2).astype(jnp.float32)
    c_in = jnp.repeat(c_proj.reshape(bsz, t, g, s), rep,
                      axis=2).astype(jnp.float32)

    # Score all T positions against the carried state (the intra-chunk
    # quadratic dual + the inter-chunk state term of ssd_chunked).
    lcum = jnp.cumsum(log_a, axis=1)                          # (B,T,H)
    dot = einsum_f32("bihs,bjhs->bhij", c_in, b_in)
    dec = jnp.exp(jnp.clip(lcum[:, :, None] - lcum[:, None, :],
                           -60.0, 0.0)).transpose(0, 3, 1, 2)
    tri = jnp.tril(jnp.ones((t, t), jnp.float32))
    scores = dot * dec * tri[None, None]
    y_intra = einsum_f32("bhij,bjhp->bihp", scores, xbar)
    ein = jnp.exp(jnp.clip(lcum, -60.0, 0.0))
    y_inter = einsum_f32("bihs,bhsp->bihp", c_in,
                         cache["state"]) * ein[..., None]
    y = y_intra + y_inter

    # Partial commit: only tokens j < commit_len[b] enter the recurrence.
    cl = commit_lengths(commit_len, row_mask, t) if commit_len is not None \
        else commit_lengths(jnp.full((bsz,), t, jnp.int32), row_mask, t)
    lcum0 = jnp.concatenate([jnp.zeros((bsz, 1, h), jnp.float32), lcum], 1)
    l_tot = jnp.take_along_axis(lcum0, cl[:, None, None].repeat(h, 2),
                                axis=1)[:, 0]                 # (B,H)
    take = (jnp.arange(t)[None, :] < cl[:, None])             # (B,T)
    carry_dec = jnp.where(take[..., None],
                          jnp.exp(jnp.clip(l_tot[:, None] - lcum,
                                           -60.0, 0.0)), 0.0)
    state = cache["state"] * \
        jnp.exp(jnp.clip(l_tot, -60.0, 0.0))[:, :, None, None] + \
        jnp.einsum("bjhs,bjh,bjhp->bhsp", b_in, carry_dec, xbar)
    # Conv window commit: rows cl .. cl+W-2 of the concatenation are the
    # last W-1 inputs a sequential decode of the accepted prefix saw.
    idx = cl[:, None] + jnp.arange(wdt - 1)[None, :]          # (B,W-1)
    conv_cache = jnp.take_along_axis(
        window, idx[:, :, None].astype(jnp.int32), axis=1)
    if row_mask is not None:
        keep = row_mask[:, None, None]
        state = jnp.where(keep[..., None], state, cache["state"])
        conv_cache = jnp.where(keep, conv_cache,
                               cache["conv"].astype(dtype))

    y = y + xh * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(bsz, t, di).astype(dtype)
    y = y * jax.nn.silu(z)
    y = apply_norm(p["norm"], y, "rmsnorm")
    out = dense(p["out_w"], y, dtype)
    new_cache = {"state": state, "conv": conv_cache.astype(cfg.cdtype)}
    return out, new_cache


def ssm_decode(p, x, cache, cfg):
    """One-token step.  x: (B, 1, D)."""
    di, h, p_dim, s, g = _dims(cfg)
    bsz = x.shape[0]
    dtype = cfg.cdtype
    z = dense(p["w_z"], x, dtype)
    xs = dense(p["w_x"], x, dtype)
    b_proj = dense(p["w_B"], x, dtype)
    c_proj = dense(p["w_C"], x, dtype)
    dt = dense(p["w_dt"], x, dtype).astype(jnp.float32)

    conv_in = jnp.concatenate([xs, b_proj, c_proj], -1)      # (B,1,Cd)
    window = jnp.concatenate([cache["conv"].astype(dtype), conv_in], 1)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(dtype),
                          p["conv_w"].astype(dtype)) + p["conv_b"].astype(dtype)
    conv_out = jax.nn.silu(conv_out)[:, None]
    xs = conv_out[..., :di]
    b_proj = conv_out[..., di:di + g * s]
    c_proj = conv_out[..., di + g * s:]

    dt = jax.nn.softplus(dt + p["dt_bias"][None, None])[:, 0]       # (B,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a[None])                                    # (B,H)

    xh = xs.reshape(bsz, h, p_dim).astype(jnp.float32)
    xbar = xh * dt[..., None]
    rep = h // g
    b_in = jnp.repeat(b_proj.reshape(bsz, g, s), rep, axis=1).astype(jnp.float32)
    c_in = jnp.repeat(c_proj.reshape(bsz, g, s), rep, axis=1).astype(jnp.float32)

    state = cache["state"] * decay[..., None, None] + \
        jnp.einsum("bhs,bhp->bhsp", b_in, xbar)
    y = jnp.einsum("bhs,bhsp->bhp", c_in, state)
    y = y + xh * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(bsz, 1, di).astype(dtype)
    y = y * jax.nn.silu(z)
    y = apply_norm(p["norm"], y, "rmsnorm")
    out = dense(p["out_w"], y, dtype)
    new_cache = {"state": state, "conv": window[:, 1:].astype(cfg.cdtype)}
    return out, new_cache
