"""SSM language models: pure Mamba2 (mamba2-130m) and Zamba2-style hybrid.

Zamba2 (arXiv:2411.15242): a Mamba2 backbone with a single *shared*
transformer block (attention + MLP, one set of weights) applied every
``shared_attn_period`` layers; its input is the concatenation of the
residual stream with the initial embeddings, linearly projected back to
d_model.  ``shared_attn_period = 0`` disables the shared block (pure
Mamba2 LM).  The paper's LLN technique applies to the shared attention
block only (the SSM blocks are attention-free).

Simplifications vs. the released checkpoints (recorded in DESIGN.md):
no per-application LoRA deltas on the shared block; a single shared block
rather than two alternating ones.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .attention_block import (attn_apply, attn_init, serve_decode,
                              serve_prefill, serve_state_init)
from .layers import (apply_mlp, apply_norm, dense, dense_init, embed_init,
                     embed_lookup, logits_from_hidden, mlp_init, norm_init,
                     trunc_normal)
from .ssm import (ssm_apply, ssm_cache_init, ssm_decode, ssm_decode_chunk,
                  ssm_init)
from .transformer import _remat


def _groups(cfg):
    per = cfg.shared_attn_period
    if per <= 0:
        return 0, 0, cfg.n_layers
    g = cfg.n_layers // per
    return g, per, cfg.n_layers - g * per


def hybrid_init(key, cfg):
    ke, kl, ks, kh = jax.random.split(key, 4)
    p = {"embed": embed_init(ke, cfg.padded_vocab, cfg.d_model, cfg.pdtype),
         "final_norm": norm_init(cfg.d_model, "rmsnorm", cfg.pdtype)}
    keys = jax.random.split(kl, cfg.n_layers)
    p["layers"] = jax.vmap(lambda k: {
        "ln": norm_init(cfg.d_model, "rmsnorm", cfg.pdtype),
        "ssm": ssm_init(k, cfg)})(keys)
    g, per, tail = _groups(cfg)
    if g:
        k1, k2, k3, k4 = jax.random.split(ks, 4)
        p["shared"] = {
            "in_proj": dense_init(k1, 2 * cfg.d_model, cfg.d_model,
                                  cfg.pdtype),
            "ln1": norm_init(cfg.d_model, "rmsnorm", cfg.pdtype),
            "attn": attn_init(k2, cfg),
            "ln2": norm_init(cfg.d_model, "rmsnorm", cfg.pdtype),
            "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.act, cfg.pdtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = trunc_normal(kh, (cfg.d_model, cfg.padded_vocab),
                                    cfg.d_model ** -0.5, cfg.pdtype)
    return p


def _split_layers(p, cfg):
    g, per, tail = _groups(cfg)
    layers = p["layers"]
    if g == 0:
        return None, layers, g, per
    grouped = jax.tree_util.tree_map(
        lambda a: a[:g * per].reshape((g, per) + a.shape[1:]), layers)
    tail_p = jax.tree_util.tree_map(lambda a: a[g * per:], layers)
    return grouped, tail_p, g, per


def _mamba_block(lp, x, cfg):
    return x + ssm_apply(lp["ssm"], apply_norm(lp["ln"], x, "rmsnorm"),
                         cfg).astype(x.dtype)


def _shared_block(sp, x, x0, cfg, positions):
    h = dense(sp["in_proj"], jnp.concatenate([x, x0], -1), cfg.cdtype)
    a = attn_apply(sp["attn"], apply_norm(sp["ln1"], h, "rmsnorm"), cfg,
                   positions, causal=True)
    h = h + a.astype(h.dtype)
    m = apply_mlp(sp["mlp"], apply_norm(sp["ln2"], h, "rmsnorm"), cfg.act,
                  cfg.cdtype)
    return x + (h + m.astype(h.dtype)).astype(x.dtype)


def hybrid_hidden(p, tokens, cfg):
    x = embed_lookup(p["embed"], tokens, cfg.cdtype, cfg.embed_scale)
    x0 = x
    positions = jnp.arange(tokens.shape[1])
    grouped, tail_p, g, per = _split_layers(p, cfg)

    mamba_scan = _remat(lambda x, lp: (_mamba_block(lp, x, cfg), None), cfg)

    if g:
        # remat granularity: per mamba layer (mamba_scan) and per shared-
        # block application — NOT around the whole group, which would nest
        # checkpoints and recompute the recompute (see EXPERIMENTS.md §Perf).
        shared_fn = _remat(
            lambda x, _: (_shared_block(p["shared"], x, x0, cfg, positions),
                          None), cfg)

        def group_body(x, glp):
            x, _ = jax.lax.scan(mamba_scan, x, glp,
                                unroll=bool(cfg.scan_unroll))
            x, _ = shared_fn(x, None)
            return x, None
        x, _ = jax.lax.scan(group_body, x, grouped,
                            unroll=bool(cfg.scan_unroll))
    x, _ = jax.lax.scan(mamba_scan, x, tail_p,
                        unroll=bool(cfg.scan_unroll))
    x = apply_norm(p["final_norm"], x, "rmsnorm")
    return x, jnp.zeros((), jnp.float32)


def hybrid_logits(p, tokens, cfg):
    h, aux = hybrid_hidden(p, tokens, cfg)
    head = p["lm_head"] if "lm_head" in p else p["embed"]["table"].T
    return logits_from_hidden(head, h, cfg.cdtype, cfg.logit_softcap), aux


# ---------------------------------------------------------------------------
# Serving.
# ---------------------------------------------------------------------------

def hybrid_cache_init(p, cfg, batch: int, max_len: int,
                      per_row: bool = False):
    """``per_row`` is accepted for pool-signature compatibility: the SSM
    caches carry no position counters and the shared attention state is
    per-row by construction (``serve_state_init``), so the layout is the
    same either way."""
    del per_row
    g, per, tail = _groups(cfg)
    one = ssm_cache_init(cfg, batch)
    caches = {"layers": jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one)}
    if g:
        sa = serve_state_init(cfg, batch, max_len)
        caches["shared"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (g,) + a.shape), sa)
    return caches


def hybrid_prefill(p, tokens, cfg, max_len: int):
    """Sequential (non-scan) prefill over layers — prefill happens once, and
    the per-layer cache shapes differ between mamba and shared-attn layers."""
    x = embed_lookup(p["embed"], tokens, cfg.cdtype, cfg.embed_scale)
    x0 = x
    n = tokens.shape[1]
    positions = jnp.arange(n)
    grouped, tail_p, g, per = _split_layers(p, cfg)

    def mamba_prefill(lp, x):
        out, cache = ssm_apply(lp["ssm"], apply_norm(lp["ln"], x, "rmsnorm"),
                               cfg, return_state=True)
        return x + out.astype(x.dtype), cache

    def scan_mamba(x, lps):
        def body(x, lp):
            x, cache = mamba_prefill(lp, x)
            return x, cache
        return jax.lax.scan(body, x, lps, unroll=bool(cfg.scan_unroll))

    caches = {}
    if g:
        def group_body(x, glp):
            x, mc = scan_mamba(x, glp)
            # shared block prefill
            hcat = dense(p["shared"]["in_proj"],
                         jnp.concatenate([x, x0], -1), cfg.cdtype)
            a, sc = serve_prefill(p["shared"]["attn"],
                                  apply_norm(p["shared"]["ln1"], hcat,
                                             "rmsnorm"), cfg, positions,
                                  max_len=max_len)
            hcat = hcat + a.astype(hcat.dtype)
            m = apply_mlp(p["shared"]["mlp"],
                          apply_norm(p["shared"]["ln2"], hcat, "rmsnorm"),
                          cfg.act, cfg.cdtype)
            x = x + (hcat + m.astype(hcat.dtype)).astype(x.dtype)
            return x, (mc, sc)
        x, (mc, sc) = jax.lax.scan(group_body, x, grouped,
                                   unroll=bool(cfg.scan_unroll))
        # mc: (g, per, ...) -> flatten to (g*per, ...)
        mc = jax.tree_util.tree_map(
            lambda a: a.reshape((-1,) + a.shape[2:]), mc)
        x, tail_c = scan_mamba(x, tail_p)
        caches["layers"] = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], 0), mc, tail_c)
        caches["shared"] = sc
    else:
        x, caches["layers"] = scan_mamba(x, tail_p)
    x = apply_norm(p["final_norm"], x, "rmsnorm")
    head = p["lm_head"] if "lm_head" in p else p["embed"]["table"].T
    logits = logits_from_hidden(head, x[:, -1:], cfg.cdtype, cfg.logit_softcap)
    return logits, caches


def hybrid_decode(p, caches, token, cfg, position, *, row_mask=None,
                  commit_len=None):
    """Decode step.  ``token`` (B,) is the single-token generation loop;
    (B, T) is the chunked multi-token path.  ``row_mask``/``commit_len``
    follow the continuous-batching / partial-commit contract of
    ``AttentionEngine.decode`` on EVERY cache: masked rows advance
    neither the SSM recurrent state, the conv windows, nor the shared
    block's attention state, and ``commit_len`` folds only the accepted
    prefix of a scored chunk.  ``position`` may be a scalar or per-row
    (B,) (the shared attention block's RoPE base; the SSM layers are
    position-free).  Returns ``(logits (B, V) | (B, T, V), caches)``.
    """
    chunked = token.ndim == 2
    use_chunk = chunked or row_mask is not None or commit_len is not None
    tokens = token if chunked else token[:, None]
    x = embed_lookup(p["embed"], tokens, cfg.cdtype, cfg.embed_scale)
    x0 = x
    grouped, tail_p, g, per = _groups_params(p, cfg)
    new_caches = {}

    def _ssm_step(lp, xn, cache):
        if use_chunk:
            return ssm_decode_chunk(lp["ssm"], xn, cache, cfg,
                                    row_mask=row_mask,
                                    commit_len=commit_len)
        return ssm_decode(lp["ssm"], xn, cache, cfg)

    def mamba_step(x, lp, cache):
        out, cache = _ssm_step(lp, apply_norm(lp["ln"], x, "rmsnorm"),
                               cache)
        return x + out.astype(x.dtype), cache

    if g:
        mcaches = caches["layers"]
        mc_group = jax.tree_util.tree_map(
            lambda a: a[:g * per].reshape((g, per) + a.shape[1:]), mcaches)
        mc_tail = jax.tree_util.tree_map(lambda a: a[g * per:], mcaches)

        def group_body(x, xs):
            glp, gmc, gsc = xs

            def body(x, ys):
                lp, c = ys
                x, c = mamba_step(x, lp, c)
                return x, c
            x, gmc = jax.lax.scan(body, x, (glp, gmc))
            hcat = dense(p["shared"]["in_proj"],
                         jnp.concatenate([x, x0], -1), cfg.cdtype)
            a, gsc = serve_decode(p["shared"]["attn"],
                                  apply_norm(p["shared"]["ln1"], hcat,
                                             "rmsnorm"), gsc, cfg, position,
                                  row_mask=row_mask, commit_len=commit_len)
            hcat = hcat + a.astype(hcat.dtype)
            m = apply_mlp(p["shared"]["mlp"],
                          apply_norm(p["shared"]["ln2"], hcat, "rmsnorm"),
                          cfg.act, cfg.cdtype)
            x = x + (hcat + m.astype(hcat.dtype)).astype(x.dtype)
            return x, (gmc, gsc)
        x, (gmc, gsc) = jax.lax.scan(group_body, x, (grouped, mc_group,
                                                     caches["shared"]),
                                     unroll=bool(cfg.scan_unroll))
        gmc = jax.tree_util.tree_map(
            lambda a: a.reshape((-1,) + a.shape[2:]), gmc)

        def tail_body(x, ys):
            lp, c = ys
            return mamba_step(x, lp, c)
        x, tc = jax.lax.scan(tail_body, x, (tail_p, mc_tail),
                             unroll=bool(cfg.scan_unroll))
        new_caches["layers"] = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], 0), gmc, tc)
        new_caches["shared"] = gsc
    else:
        def body(x, ys):
            lp, c = ys
            return mamba_step(x, lp, c)
        x, new_caches["layers"] = jax.lax.scan(body, x,
                                               (tail_p, caches["layers"]),
                                               unroll=bool(cfg.scan_unroll))
    x = apply_norm(p["final_norm"], x, "rmsnorm")
    head = p["lm_head"] if "lm_head" in p else p["embed"]["table"].T
    logits = logits_from_hidden(head, x, cfg.cdtype, cfg.logit_softcap)
    return (logits if chunked else logits[:, 0]), new_caches


def _groups_params(p, cfg):
    return _split_layers(p, cfg)
