"""Shared model building blocks (pure-functional, pytree params).

Conventions:
* params are nested dicts of jnp arrays, stored in ``cfg.param_dtype`` and
  cast to ``cfg.compute_dtype`` at use;
* per-layer parameter subtrees are *stacked* along a leading layer axis so
  the forward pass is a single ``lax.scan`` (small HLO, fast compiles, remat
  per layer);
* initializers follow standard transformer practice (truncated-normal
  fan-in scaling).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def trunc_normal(key, shape, std, dtype):
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                              jnp.float32)).astype(dtype)


def dense_init(key, d_in, d_out, dtype, std: Optional[float] = None):
    std = (1.0 / math.sqrt(d_in)) if std is None else std
    return trunc_normal(key, (d_in, d_out), std, dtype)


def dense(w, x, dtype):
    return jnp.einsum("...d,df->...f", x.astype(dtype), w.astype(dtype))


# ---------------------------------------------------------------------------
# Normalization.
# ---------------------------------------------------------------------------

def norm_init(d, kind: str, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_norm(p, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        inv = jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
        return (xf * inv * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


def rms_head_norm(scale, x, eps: float = 1e-6):
    """qk-norm: RMS-normalize the last (head_dim) axis (qwen3-style)."""
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (xf * inv * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (full / partial / half-"2d").
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0,
         rotary_pct: float = 1.0) -> jnp.ndarray:
    """x: (B, N, H, D); positions: (N,) or (B, N).  Rotates the first
    ``rotary_pct`` fraction of D (pairwise interleaved halves)."""
    b, n, h, d = x.shape
    rd = int(d * rotary_pct)
    rd -= rd % 2
    if rd == 0:
        return x
    x_rot, x_pass = x[..., :rd], x[..., rd:]
    half = rd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[:, :, None].astype(jnp.float32) * freqs[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x_rot[..., :half].astype(jnp.float32), x_rot[..., half:].astype(jnp.float32)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return jnp.concatenate([rotated.astype(x.dtype), x_pass], -1)


# ---------------------------------------------------------------------------
# Gated / plain MLP.
# ---------------------------------------------------------------------------

def mlp_init(key, d_model, d_ff, act, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    if act.endswith("_glu"):
        return {"wi_gate": dense_init(k1, d_model, d_ff, dtype),
                "wi_up": dense_init(k2, d_model, d_ff, dtype),
                "wo": dense_init(k3, d_ff, d_model, dtype)}
    return {"wi": dense_init(k1, d_model, d_ff, dtype),
            "wo": dense_init(k3, d_ff, d_model, dtype)}


def apply_mlp(p, x, act, dtype):
    if act.endswith("_glu"):
        g = dense(p["wi_gate"], x, dtype)
        u = dense(p["wi_up"], x, dtype)
        g = jax.nn.silu(g) if act.startswith("silu") else jax.nn.gelu(g)
        return dense(p["wo"], g * u, dtype)
    h = dense(p["wi"], x, dtype)
    h = jax.nn.gelu(h)
    return dense(p["wo"], h, dtype)


# ---------------------------------------------------------------------------
# Embedding + chunked cross-entropy (never materializes (B, N, V) logits).
# ---------------------------------------------------------------------------

def embed_init(key, vocab, d_model, dtype):
    # Fan-in scale keeps tied-embedding logits O(1); embed_scale models
    # (gemma) recover O(1) embeddings via the sqrt(d) lookup multiplier.
    return {"table": trunc_normal(key, (vocab, d_model),
                                  d_model ** -0.5, dtype)}


def embed_lookup(p, tokens, dtype, scale: bool = False):
    x = jnp.take(p["table"], tokens, axis=0).astype(dtype)
    if scale:
        x = x * jnp.asarray(math.sqrt(p["table"].shape[1]), dtype)
    return x


def logits_from_hidden(lm_head, h, dtype, softcap: float = 0.0):
    logits = jnp.einsum("...d,dv->...v", h.astype(dtype),
                        lm_head.astype(dtype)).astype(jnp.float32)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


def chunked_xent(h: jnp.ndarray, lm_head: jnp.ndarray, labels: jnp.ndarray,
                 mask: jnp.ndarray, *, vocab: int, chunk: int = 1024,
                 dtype=jnp.bfloat16, softcap: float = 0.0) -> jnp.ndarray:
    """Mean cross-entropy over valid positions, computed in sequence chunks.

    h: (B, N, D); lm_head: (D, Vpad); labels/mask: (B, N).  Only the chunk's
    (B, C, Vpad) logits are ever live; the scan is remat'd so backward
    recomputes them.  Pad-vocab columns are excluded by masking logits.
    """
    b, n, d = h.shape
    vpad = lm_head.shape[1]
    c = min(chunk, n)
    if n % c:
        pad = c - n % c
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = h.shape[1] // c
    hc = h.reshape(b, nc, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, c).transpose(1, 0, 2)
    mc = mask.reshape(b, nc, c).transpose(1, 0, 2)
    vocab_ok = (jnp.arange(vpad) < vocab)[None, None, :]

    from repro.distributed.sharding import constrain

    def body(carry, xs):
        loss_sum, cnt = carry
        hh, ll, mm = xs
        logits = logits_from_hidden(lm_head, hh, dtype, softcap)
        logits = constrain(logits, "act_batch", None, "vocab")
        logits = jnp.where(vocab_ok, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mm
        return (loss_sum + jnp.sum(nll), cnt + jnp.sum(mm)), None

    body = jax.checkpoint(body)
    (loss_sum, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc, mc.astype(jnp.float32)))
    return loss_sum / jnp.maximum(cnt, 1.0)
