"""Top-k routed Mixture-of-Experts FFN with expert parallelism.

Distribution strategy (see DESIGN.md §4): activations between blocks are
replicated over the 'model' axis (standard TP), so every model shard already
holds all tokens of its data shard.  Each model shard therefore:

  1. routes all local tokens (router is replicated),
  2. gathers the tokens assigned to *its own* expert slice into a
     capacity-bounded (E_loc, C, D) buffer (sort-based dispatch — no
     (T, E, C) one-hot einsum, so dispatch FLOPs stay negligible),
  3. runs its experts, scatters weighted outputs back to (T, D),
  4. psum over 'model' combines the contributions — the same collective
     class a TP-sharded dense MLP would need, so EP costs no extra
     collective; the shared experts join the same psum as a TP-sharded
     dense MLP computing a 'model'-sharded d_ff slice.

Expert weights are sharded (E over 'model') x (D over 'data'); the 'data'
shards are all-gathered just-in-time inside the shard_map (FSDP).

When no mesh is active (smoke tests), the same math runs in a single-device
local path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from .layers import dense_init, trunc_normal


def moe_init(key, cfg):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    ks = jax.random.split(key, 7)
    std = d ** -0.5
    p = {"router_w": trunc_normal(ks[0], (d, e), 0.02, jnp.float32)}
    p["exp_wi_gate"] = trunc_normal(ks[1], (e, d, f), std, cfg.pdtype)
    p["exp_wi_up"] = trunc_normal(ks[2], (e, d, f), std, cfg.pdtype)
    p["exp_wo"] = trunc_normal(ks[3], (e, f, d), f ** -0.5, cfg.pdtype)
    if cfg.n_shared_experts:
        fs = cfg.expert_d_ff * cfg.n_shared_experts
        p["shared_wi_gate"] = dense_init(ks[4], d, fs, cfg.pdtype)
        p["shared_wi_up"] = dense_init(ks[5], d, fs, cfg.pdtype)
        p["shared_wo"] = dense_init(ks[6], fs, d, cfg.pdtype)
    return p


def _route(x, router_w, top_k):
    """x: (T, D) -> (expert_idx (T, K), weights (T, K), aux_loss)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss (scatter-add, no (M, E) one-hot).
    e = router_w.shape[1]
    t = x.shape[0]
    me = jnp.mean(probs, axis=0)
    load = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0) / t
    aux = e * jnp.sum(me * load)
    return idx, w, aux


def _positions_in_expert(flat_e: jnp.ndarray, num_experts: int):
    """Rank of each routed slot within its expert (sort-based, O(M log M))."""
    m = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(num_experts), side="left")
    rank_sorted = jnp.arange(m) - starts[sorted_e]
    pos = jnp.zeros((m,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    return pos


def _expert_ffn(xg, wi_gate, wi_up, wo, act: str, dtype):
    """xg: (E, C, D); weights: (E, D, F)/(E, F, D)."""
    g = jnp.einsum("ecd,edf->ecf", xg.astype(dtype), wi_gate.astype(dtype))
    u = jnp.einsum("ecd,edf->ecf", xg.astype(dtype), wi_up.astype(dtype))
    g = jax.nn.silu(g) if act.startswith("silu") else jax.nn.gelu(g)
    return jnp.einsum("ecf,efd->ecd", g * u, wo.astype(dtype))


def _moe_local(x, p, cfg, e0: int, e_loc: int, dtype):
    """Dispatch + expert compute for experts [e0, e0+e_loc) on tokens x (T,D).

    Returns this shard's *partial* output (T, D) (sum over shards completes
    the token outputs) and the aux loss.
    """
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    idx, w, aux = _route(x, p["router_w"], k)                # (T,K)
    flat_e = idx.reshape(-1)                                  # (M=T*K,)
    pos = _positions_in_expert(flat_e, e)
    cap = max(int(t * k * cfg.capacity_factor / e), 1)

    local = (flat_e >= e0) & (flat_e < e0 + e_loc) & (pos < cap)
    slot = jnp.where(local, (flat_e - e0) * cap + pos, e_loc * cap)
    # Gather tokens into (E_loc*C (+1 dump), D).
    tok_of_slot = jnp.zeros((e_loc * cap + 1,), jnp.int32).at[slot].set(
        jnp.repeat(jnp.arange(t, dtype=jnp.int32), k), mode="drop")
    filled = jnp.zeros((e_loc * cap + 1,), jnp.bool_).at[slot].set(
        local, mode="drop")
    xg = jnp.take(x, tok_of_slot, axis=0) * filled[:, None]
    xg = xg[:e_loc * cap].reshape(e_loc, cap, d)

    # Weights are always the *local* expert slice (shape E_loc, ...); e0 only
    # offsets the routing ids.  The meshless path passes e0=0, E_loc=E.
    assert p["exp_wi_gate"].shape[0] == e_loc, \
        (p["exp_wi_gate"].shape, e_loc)
    y = _expert_ffn(xg, p["exp_wi_gate"], p["exp_wi_up"], p["exp_wo"],
                    cfg.act, dtype)                           # (E_loc, C, D)

    # Scatter back with routing weights.
    y_flat = jnp.concatenate(
        [y.reshape(e_loc * cap, d), jnp.zeros((1, d), y.dtype)], 0)
    y_slots = jnp.take(y_flat, jnp.minimum(slot, e_loc * cap), axis=0)
    wv = (w.reshape(-1) * local.astype(jnp.float32))[:, None]
    contrib = (y_slots.astype(jnp.float32) * wv).reshape(t, k, d).sum(1)
    return contrib.astype(dtype), aux


def moe_apply(p, x, cfg) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, N, D) -> (out, aux_loss).  Mesh-aware (see module docstring)."""
    b, n, d = x.shape
    dtype = cfg.cdtype
    mesh = shd.current_mesh()
    xt = x.reshape(b * n, d)

    if mesh is None or "model" not in mesh.axis_names:
        out, aux = _moe_local(xt, p, cfg, 0, cfg.n_experts, dtype)
        if cfg.n_shared_experts:
            out = out + _shared_ffn(p, xt, cfg, dtype)
        return out.reshape(b, n, d), aux

    ep = mesh.devices.shape[list(mesh.axis_names).index("model")]
    e_loc = cfg.n_experts // ep
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    fsdp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    # Token rows shard over the largest batch-axis prefix that divides T
    # (decode with global_batch=1 replicates the single token row).
    batch_axes = ()
    t_total, used = b * n, 1
    for a in fsdp:
        if t_total % (sizes[a] * used) == 0:
            batch_axes = batch_axes + (a,)
            used *= sizes[a]

    # Combine strategy: when the sequence divides the model axis, the
    # expert-partial sums are reduce-SCATTERED into the sequence-parallel
    # layout (half the bytes of a full all-reduce, and the residual stream
    # is already seq-sharded so no re-shard follows).  Decode (n==1) and
    # odd lengths fall back to a full psum.
    scatter = (n % ep == 0) and n > 1

    def shard_fn(xt, rw, wig, wiu, wog, swg=None, swu=None, swo=None):
        # xt: (T_loc, D) full-D tokens; expert weights sharded E/'model',
        # D/fsdp -> gather the FSDP shards just-in-time.
        pp = {"router_w": rw,
              "exp_wi_gate": jax.lax.all_gather(wig, fsdp, axis=1, tiled=True),
              "exp_wi_up": jax.lax.all_gather(wiu, fsdp, axis=1, tiled=True),
              "exp_wo": jax.lax.all_gather(wog, fsdp, axis=2, tiled=True)}
        midx = jax.lax.axis_index("model")
        out, aux = _moe_local(xt, pp, cfg, midx * e_loc, e_loc, dtype)
        if swg is not None:
            # Shared experts as a TP-sharded dense MLP ('model' shards f).
            sw = {"shared_wi_gate": jax.lax.all_gather(swg, fsdp, axis=0, tiled=True),
                  "shared_wi_up": jax.lax.all_gather(swu, fsdp, axis=0, tiled=True),
                  "shared_wo": jax.lax.all_gather(swo, fsdp, axis=1, tiled=True)}
            out = out + _shared_ffn(sw, xt, cfg, dtype)
        aux = jax.lax.pmean(aux, ("model",) + batch_axes)
        if scatter:
            out = out.reshape(-1, n, d)
            out = jax.lax.psum_scatter(out, "model", scatter_dimension=1,
                                       tiled=True)
            return out, aux
        return jax.lax.psum(out, "model"), aux

    espec = P("model", fsdp, None)
    ospec = P("model", None, fsdp)
    args = [xt, p["router_w"], p["exp_wi_gate"], p["exp_wi_up"], p["exp_wo"]]
    in_specs = [P(batch_axes, None), P(None, None), espec, espec, ospec]
    if cfg.n_shared_experts:
        args += [p["shared_wi_gate"], p["shared_wi_up"], p["shared_wo"]]
        in_specs += [P(fsdp, "model"), P(fsdp, "model"), P("model", fsdp)]
    out_spec = (P(batch_axes, "model", None) if scatter
                else P(batch_axes, None))
    if hasattr(jax, "shard_map"):
        smap = jax.shard_map(
            shard_fn, mesh=mesh, in_specs=tuple(in_specs),
            out_specs=(out_spec, P()), check_vma=False)
    else:                               # older jax: experimental API
        from jax.experimental.shard_map import shard_map as _shard_map
        smap = _shard_map(
            shard_fn, mesh=mesh, in_specs=tuple(in_specs),
            out_specs=(out_spec, P()), check_rep=False)
    out, aux = smap(*args)
    return out.reshape(b, n, d), aux


def _shared_ffn(p, xt, cfg, dtype):
    g = jnp.einsum("td,df->tf", xt.astype(dtype),
                   p["shared_wi_gate"].astype(dtype))
    u = jnp.einsum("td,df->tf", xt.astype(dtype),
                   p["shared_wi_up"].astype(dtype))
    g = jax.nn.silu(g) if cfg.act.startswith("silu") else jax.nn.gelu(g)
    return jnp.einsum("tf,fd->td", g * u, p["shared_wo"].astype(dtype))
