"""PaliGemma-style VLM (SigLIP patch stub + gemma decoder).

The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (B, num_prefix_tokens, frontend_dim); a linear
projector maps them into the decoder's embedding space.  The decoder is the
gemma-family transformer (MQA kv=1, GeGLU, embed scaling) with a prefix-LM
mask: patch positions attend bidirectionally, text is causal.  In LLN mode
the prefix bidirectionality is approximated causally (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense, dense_init, logits_from_hidden
from .transformer import (lm_cache_init, lm_decode, lm_hidden, lm_init,
                          lm_prefill)


def vlm_init(key, cfg):
    kp, kl = jax.random.split(key)
    p = lm_init(kl, cfg)
    p["patch_proj"] = dense_init(kp, cfg.frontend_dim, cfg.d_model,
                                 cfg.pdtype)
    return p


def vlm_hidden(p, patches, tokens, cfg):
    """patches: (B, P, frontend_dim); tokens: (B, N).
    Returns hidden for the *text* positions only (prefix stripped)."""
    prefix = dense(p["patch_proj"], patches, cfg.cdtype)
    h, aux = lm_hidden(p, tokens, cfg, prefix_embed=prefix)
    return h[:, patches.shape[1]:], aux


def vlm_prefill(p, patches, tokens, cfg, max_len: int):
    prefix = dense(p["patch_proj"], patches, cfg.cdtype)
    return lm_prefill(p, tokens, cfg, max_len, prefix_embed=prefix)


vlm_decode = lm_decode
vlm_cache_init = lm_cache_init
