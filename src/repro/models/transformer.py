"""Decoder-only transformer LM (dense and MoE families).

Layers are stacked along a leading axis and executed with ``lax.scan``
(+ configurable remat) so tracing/compile cost is depth-independent — a
94-layer qwen3-moe traces the block exactly once.

Covers: yi-9b, stablelm-1.6b, qwen3-14b, chatglm3-6b (dense), qwen3-moe
(moe), deepseek-v2 (moe + MLA attention via models/mla.py), and serves as
the text decoder for paligemma and the shared-attention block for zamba2.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from . import mla as mla_mod
from .attention_block import (attn_apply, attn_init, serve_commit,
                              serve_decode, serve_prefill, serve_state_init)
from .layers import (apply_mlp, apply_norm, embed_init, embed_lookup,
                     logits_from_hidden, mlp_init, norm_init, trunc_normal)
from .moe import moe_apply, moe_init


def _use_mla(cfg) -> bool:
    return cfg.kv_lora > 0


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# One transformer block.
# ---------------------------------------------------------------------------

def block_init(key, cfg, *, use_moe: bool):
    ka, km = jax.random.split(key)
    p = {"ln1": norm_init(cfg.d_model, cfg.norm, cfg.pdtype),
         "ln2": norm_init(cfg.d_model, cfg.norm, cfg.pdtype)}
    p["attn"] = mla_mod.mla_init(ka, cfg) if _use_mla(cfg) else attn_init(ka, cfg)
    p["moe" if use_moe else "mlp"] = (
        moe_init(km, cfg) if use_moe
        else mlp_init(km, cfg.d_model, cfg.d_ff, cfg.act, cfg.pdtype))
    return p


def block_apply(p, x, cfg, positions, *, use_moe: bool, causal: bool = True,
                prefix_len: int = 0):
    x = constrain(x, "act_batch", "act_seq", "embed")
    h = apply_norm(p["ln1"], x, cfg.norm)
    if _use_mla(cfg):
        attn_out = mla_mod.mla_apply(p["attn"], h, cfg, positions,
                                     causal=causal)
    else:
        attn_out = attn_apply(p["attn"], h, cfg, positions, causal=causal,
                              prefix_len=prefix_len)
    x = x + attn_out.astype(x.dtype)
    h = apply_norm(p["ln2"], x, cfg.norm)
    if use_moe:
        ffn_out, aux = moe_apply(p["moe"], h, cfg)
    else:
        ffn_out, aux = apply_mlp(p["mlp"], h, cfg.act, cfg.cdtype), 0.0
    x = x + ffn_out.astype(x.dtype)
    return constrain(x, "act_batch", "act_seq", "embed"), jnp.asarray(
        aux, jnp.float32)


def block_prefill(p, x, cfg, positions, *, use_moe: bool, prefix_len: int = 0,
                  max_len: int = 0):
    h = apply_norm(p["ln1"], x, cfg.norm)
    if _use_mla(cfg):
        attn_out, cache = mla_mod.mla_prefill(p["attn"], h, cfg, positions,
                                              max_len=max_len)
    else:
        attn_out, cache = serve_prefill(p["attn"], h, cfg, positions,
                                        prefix_len=prefix_len,
                                        max_len=max_len)
    x = x + attn_out.astype(x.dtype)
    h = apply_norm(p["ln2"], x, cfg.norm)
    ffn_out = (moe_apply(p["moe"], h, cfg)[0] if use_moe
               else apply_mlp(p["mlp"], h, cfg.act, cfg.cdtype))
    return x + ffn_out.astype(x.dtype), cache


def block_score(p, x, cache, cfg, position, *, use_moe: bool,
                row_mask=None):
    """Speculative score pass over one block: a ``commit_len=0`` decode
    that leaves ``cache`` bitwise unchanged and returns the attention
    layer's ``{"k", "v"}`` commit residuals alongside the activations."""
    h = apply_norm(p["ln1"], x, cfg.norm)
    if _use_mla(cfg):
        raise NotImplementedError(
            "single-pass speculative verify is not wired for MLA")
    zeros = jnp.zeros((x.shape[0],), jnp.int32)
    attn_out, _, resid = serve_decode(p["attn"], h, cache, cfg, position,
                                      row_mask=row_mask, commit_len=zeros,
                                      return_residuals=True)
    x = x + attn_out.astype(x.dtype)
    h = apply_norm(p["ln2"], x, cfg.norm)
    ffn_out = (moe_apply(p["moe"], h, cfg)[0] if use_moe
               else apply_mlp(p["mlp"], h, cfg.act, cfg.cdtype))
    return x + ffn_out.astype(x.dtype), resid


def block_decode(p, x, cache, cfg, position, *, use_moe: bool,
                 row_mask=None, commit_len=None):
    h = apply_norm(p["ln1"], x, cfg.norm)
    if _use_mla(cfg):
        if row_mask is not None or commit_len is not None:
            raise NotImplementedError(
                "row-masked / partial-commit decode is not wired for MLA")
        attn_out, cache = mla_mod.mla_decode(p["attn"], h, cache, cfg,
                                             position)
    else:
        attn_out, cache = serve_decode(p["attn"], h, cache, cfg, position,
                                       row_mask=row_mask,
                                       commit_len=commit_len)
    x = x + attn_out.astype(x.dtype)
    h = apply_norm(p["ln2"], x, cfg.norm)
    ffn_out = (moe_apply(p["moe"], h, cfg)[0] if use_moe
               else apply_mlp(p["mlp"], h, cfg.act, cfg.cdtype))
    return x + ffn_out.astype(x.dtype), cache


# ---------------------------------------------------------------------------
# Full LM.
# ---------------------------------------------------------------------------

def _layer_groups(cfg):
    """(num_dense_first, num_main, main_is_moe)."""
    is_moe = cfg.n_experts > 0
    first = cfg.first_dense_layers if is_moe else 0
    return first, cfg.n_layers - first, is_moe


def lm_init(key, cfg):
    ke, kf, kl, kh = jax.random.split(key, 4)
    first, n_main, is_moe = _layer_groups(cfg)
    p = {"embed": embed_init(ke, cfg.padded_vocab, cfg.d_model, cfg.pdtype),
         "final_norm": norm_init(cfg.d_model, cfg.norm, cfg.pdtype)}
    if first:
        keys = jax.random.split(kf, first)
        p["first_layers"] = jax.vmap(
            lambda k: block_init(k, cfg, use_moe=False))(keys)
    keys = jax.random.split(kl, n_main)
    p["layers"] = jax.vmap(lambda k: block_init(k, cfg, use_moe=is_moe))(keys)
    if not cfg.tie_embeddings:
        p["lm_head"] = trunc_normal(kh, (cfg.d_model, cfg.padded_vocab),
                                    cfg.d_model ** -0.5, cfg.pdtype)
    return p


def lm_head_of(p):
    return p["lm_head"] if "lm_head" in p else p["embed"]["table"].T


def lm_hidden(p, tokens, cfg, *, prefix_embed: Optional[jnp.ndarray] = None):
    """Token ids (B, N) -> final hidden states (B, N, D), plus MoE aux loss.

    ``prefix_embed``: optional (B, M, D) continuous prefix (vlm patches),
    prepended before the token embeddings; attention then uses a prefix-LM
    mask over those positions.
    """
    first, n_main, is_moe = _layer_groups(cfg)
    x = embed_lookup(p["embed"], tokens, cfg.cdtype, cfg.embed_scale)
    prefix_len = 0
    if prefix_embed is not None:
        prefix_len = prefix_embed.shape[1]
        x = jnp.concatenate([prefix_embed.astype(x.dtype), x], axis=1)
    n = x.shape[1]
    positions = jnp.arange(n)
    aux = jnp.zeros((), jnp.float32)

    def body(use_moe):
        def fn(x, lp):
            x, a = block_apply(lp, x, cfg, positions, use_moe=use_moe,
                               prefix_len=prefix_len)
            return x, a
        return _remat(fn, cfg)

    if first:
        x, auxs = jax.lax.scan(body(False), x, p["first_layers"],
                               unroll=bool(cfg.scan_unroll))
        aux += jnp.sum(auxs)
    x, auxs = jax.lax.scan(body(is_moe), x, p["layers"],
                           unroll=bool(cfg.scan_unroll))
    aux += jnp.sum(auxs)
    x = apply_norm(p["final_norm"], x, cfg.norm)
    return x, aux


def lm_logits(p, tokens, cfg, **kw):
    h, aux = lm_hidden(p, tokens, cfg, **kw)
    return logits_from_hidden(lm_head_of(p), h, cfg.cdtype,
                              cfg.logit_softcap), aux


# ---------------------------------------------------------------------------
# Serving.
# ---------------------------------------------------------------------------

def lm_cache_init(p, cfg, batch: int, max_len: int, per_row: bool = False):
    """Stacked per-layer decode caches (``AttentionState`` per layer).

    The engine state is ALWAYS per-row ((B,) ``len``/``pos``, (B, H)
    alpha/beta) — the static lockstep batch is the degenerate case — so
    ``per_row`` is accepted for backward compatibility and ignored."""
    del per_row
    first, n_main, is_moe = _layer_groups(cfg)
    one = (mla_mod.mla_state_init(cfg, batch, max_len) if _use_mla(cfg)
           else serve_state_init(cfg, batch, max_len))

    def stack(n):
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape), one)
    caches = {"layers": stack(n_main)}
    if first:
        caches["first_layers"] = stack(first)
    return caches


def lm_prefill(p, tokens, cfg, max_len: int,
               prefix_embed: Optional[jnp.ndarray] = None):
    """Prompt forward.  Returns (last-position logits, caches)."""
    first, n_main, is_moe = _layer_groups(cfg)
    x = embed_lookup(p["embed"], tokens, cfg.cdtype, cfg.embed_scale)
    prefix_len = 0
    if prefix_embed is not None:
        prefix_len = prefix_embed.shape[1]
        x = jnp.concatenate([prefix_embed.astype(x.dtype), x], axis=1)
    n = x.shape[1]
    positions = jnp.arange(n)
    caches = {}

    def mk(use_moe):
        def fn(x, lp):
            x, cache = block_prefill(lp, x, cfg, positions, use_moe=use_moe,
                                     prefix_len=prefix_len,
                                     max_len=max_len)
            return x, cache
        return _remat(fn, cfg) if cfg.remat != "none" else fn

    if first:
        x, caches["first_layers"] = jax.lax.scan(mk(False), x,
                                                 p["first_layers"],
                                                 unroll=bool(cfg.scan_unroll))
    x, caches["layers"] = jax.lax.scan(mk(is_moe), x, p["layers"],
                                       unroll=bool(cfg.scan_unroll))
    x = apply_norm(p["final_norm"], x, cfg.norm)
    logits = logits_from_hidden(lm_head_of(p), x[:, -1:], cfg.cdtype,
                                cfg.logit_softcap)
    return logits, caches


# Trace-time full-pass counter: each lm_decode / lm_score TRACE bumps the
# config's entry, so lowering a jitted generation loop (whose lax.scan body
# traces exactly once) counts the full transformer passes per loop
# iteration — benchmarks/bench_spec.py uses it to gate target passes per
# verify iteration.  lm_commit is O(T d^2) per layer and does not count.
DECODE_PASS_COUNTS: dict = {}


def _count_pass(cfg):
    DECODE_PASS_COUNTS[cfg.name] = DECODE_PASS_COUNTS.get(cfg.name, 0) + 1


def lm_decode(p, caches, token, cfg, position, row_mask=None,
              commit_len=None):
    """Decode step.  token: (B,) or (B, T) int32 — T > 1 advances the caches
    over a whole chunk in one dispatch (multi-token/speculative scoring);
    position: scalar int32 index of the first new token, or a per-row (B,)
    vector when the caches were allocated ``per_row`` (continuous
    batching).  ``row_mask``: optional (B,) bool — masked-off rows leave
    every cache leaf untouched and their logits are garbage.
    ``commit_len``: optional per-row (B,) int32 in [0, T] — the
    speculative verify pass: logits cover all T draft positions, every
    layer's cache folds only the accepted prefix (``commit_len=0`` rows
    behave like masked rows).  Returns logits (B, V) for (B,) input,
    (B, T, V) for chunked input."""
    single = token.ndim == 1
    _count_pass(cfg)
    first, n_main, is_moe = _layer_groups(cfg)
    toks = token[:, None] if single else token
    x = embed_lookup(p["embed"], toks, cfg.cdtype, cfg.embed_scale)
    new_caches = {}

    def mk(use_moe):
        def fn(x, xs):
            lp, cache = xs
            x, cache = block_decode(lp, x, cache, cfg, position,
                                    use_moe=use_moe, row_mask=row_mask,
                                    commit_len=commit_len)
            return x, cache
        return fn

    if first:
        x, new_caches["first_layers"] = jax.lax.scan(
            mk(False), x, (p["first_layers"], caches["first_layers"]),
            unroll=bool(cfg.scan_unroll))
    x, new_caches["layers"] = jax.lax.scan(
        mk(is_moe), x, (p["layers"], caches["layers"]),
        unroll=bool(cfg.scan_unroll))
    x = apply_norm(p["final_norm"], x, cfg.norm)
    logits = logits_from_hidden(lm_head_of(p), x, cfg.cdtype,
                                cfg.logit_softcap)
    return (logits[:, 0] if single else logits), new_caches


def lm_score(p, caches, token, cfg, position, row_mask=None):
    """Speculative score pass: logits for a (B, T) draft chunk WITHOUT
    advancing the caches, plus per-layer commit residuals.

    A ``commit_len=0`` decode leaves every cache leaf bitwise unchanged,
    so the caller keeps using ``caches`` as-is; once the acceptance rule
    has produced per-row commit lengths, :func:`lm_commit` folds the
    accepted prefix from the returned residuals with the cheap O(T d^2)
    per-layer einsum — one full transformer pass per verify iteration
    instead of two.  Returns ``(logits (B, T, V), residuals)`` where
    ``residuals`` mirrors the cache dict: stacked per-layer
    ``{"k", "v"}`` (L, B, T, G, D[v]) trees under the same keys.
    """
    _count_pass(cfg)
    first, n_main, is_moe = _layer_groups(cfg)
    x = embed_lookup(p["embed"], token, cfg.cdtype, cfg.embed_scale)
    residuals = {}

    def mk(use_moe):
        def fn(x, xs):
            lp, cache = xs
            x, resid = block_score(lp, x, cache, cfg, position,
                                   use_moe=use_moe, row_mask=row_mask)
            return x, resid
        return fn

    if first:
        x, residuals["first_layers"] = jax.lax.scan(
            mk(False), x, (p["first_layers"], caches["first_layers"]),
            unroll=bool(cfg.scan_unroll))
    x, residuals["layers"] = jax.lax.scan(
        mk(is_moe), x, (p["layers"], caches["layers"]),
        unroll=bool(cfg.scan_unroll))
    x = apply_norm(p["final_norm"], x, cfg.norm)
    logits = logits_from_hidden(lm_head_of(p), x, cfg.cdtype,
                                cfg.logit_softcap)
    return logits, residuals


def lm_commit(caches, residuals, cfg, commit_len, row_mask=None):
    """Fold the accepted prefix of a scored chunk into every layer's cache.

    Params-free: the residuals already carry the post-RoPE (k, v) the
    score pass computed, so the commit is one O(T d^2) einsum per layer
    (``AttentionEngine.commit``) — no projections, no MLP, no logits.
    Bit-identical per backend to re-running :func:`lm_decode` with the
    same ``commit_len``.  Returns the new caches.
    """
    if _use_mla(cfg):
        raise NotImplementedError(
            "single-pass speculative verify is not wired for MLA")
    new_caches = {}

    def fn(carry, xs):
        cache, resid = xs
        return carry, serve_commit(cache, resid, cfg,
                                   commit_len=commit_len,
                                   row_mask=row_mask)

    for name in caches:
        _, new_caches[name] = jax.lax.scan(
            fn, 0, (caches[name], residuals[name]),
            unroll=bool(cfg.scan_unroll))
    return new_caches
