"""Standard attention sub-block: projections + RoPE + unified attention.

Used by the dense/MoE decoder LMs, the seamless encoder/decoder, the
PaliGemma decoder and Zamba2's shared attention block.  Supports the three
attention impls (softmax / lln / lln_diag), GQA/MQA, qk-norm and partial
RoPE.

Serving runs through the unified :class:`repro.core.engine.AttentionEngine`
(one ``AttentionState`` pytree, per-row counters, backend dispatch owned by
``kernels/registry.py``): ``serve_state_init`` / ``serve_prefill`` /
``serve_decode`` are the canonical entry points; the legacy
``attn_cache_init`` / ``attn_prefill`` / ``attn_decode`` names survive as
deprecation shims delegating to them (see ``docs/api.md``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import attention as ca
from repro.core.attention import AttnConfig
from repro.core.engine import AttentionEngine
from repro.kernels.registry import deprecated_shim
from repro.distributed.sharding import constrain
from .layers import dense, dense_init, rms_head_norm, rope


def attn_cfg_of(cfg, causal: bool = True) -> AttnConfig:
    return AttnConfig(impl=cfg.attn_impl, causal=causal,
                      diag_block=cfg.diag_block, lln_chunk=cfg.lln_chunk,
                      softmax_chunk=cfg.softmax_chunk,
                      use_kernel=cfg.use_kernel,
                      fixed_ab=cfg.lln_fixed_ab,
                      num_scales=getattr(cfg, "lln_num_scales", 4),
                      scale_decay=getattr(cfg, "lln_scale_decay", 0.5))


def attn_engine(cfg, causal: bool = True) -> AttentionEngine:
    """The serving engine an ``ArchConfig`` attention layer implies."""
    return AttentionEngine.from_cfg(cfg, causal=causal)


def attn_init(key, cfg, d_in: Optional[int] = None):
    d = d_in or cfg.d_model
    hd, h, g = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {"q_w": dense_init(ks[0], d, h * hd, cfg.pdtype),
         "k_w": dense_init(ks[1], d, g * hd, cfg.pdtype),
         "v_w": dense_init(ks[2], d, g * hd, cfg.pdtype),
         "o_w": dense_init(ks[3], h * hd, cfg.d_model, cfg.pdtype)}
    if cfg.qk_norm:
        p["q_norm_scale"] = jnp.ones((hd,), cfg.pdtype)
        p["k_norm_scale"] = jnp.ones((hd,), cfg.pdtype)
    return p


def _project_qkv(p, x, cfg, positions):
    b, n, _ = x.shape
    hd, h, g = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = dense(p["q_w"], x, cfg.cdtype).reshape(b, n, h, hd)
    k = dense(p["k_w"], x, cfg.cdtype).reshape(b, n, g, hd)
    v = dense(p["v_w"], x, cfg.cdtype).reshape(b, n, g, hd)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm_scale"], q)
        k = rms_head_norm(p["k_norm_scale"], k)
    q = rope(q, positions, cfg.rope_theta, cfg.rotary_pct)
    k = rope(k, positions, cfg.rope_theta, cfg.rotary_pct)
    q = constrain(q, "act_batch", "attn_seq", "heads", None)
    k = constrain(k, "act_batch", None, "kv_heads", None)
    v = constrain(v, "act_batch", None, "kv_heads", None)
    return q, k, v


def attn_apply(p, x, cfg, positions, *, causal: bool = True,
               kv: Optional[jnp.ndarray] = None,
               mask: Optional[jnp.ndarray] = None,
               prefix_len: int = 0) -> jnp.ndarray:
    """Full-sequence attention.  ``kv``: optional cross-attention memory
    (B, M, d) — used by the seamless decoder (always softmax for cross)."""
    b, n, _ = x.shape
    hd, h, g = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    if kv is None:
        q, k, v = _project_qkv(p, x, cfg, positions)
        out = ca.multi_head_attention(q, k, v, attn_cfg_of(cfg, causal),
                                      mask=mask, prefix_len=prefix_len)
    else:
        m = kv.shape[1]
        q = dense(p["q_w"], x, cfg.cdtype).reshape(b, n, h, hd)
        k = dense(p["k_w"], kv, cfg.cdtype).reshape(b, m, g, hd)
        v = dense(p["v_w"], kv, cfg.cdtype).reshape(b, m, g, hd)
        q = constrain(q, "act_batch", "attn_seq", "heads", None)
        k = constrain(k, "act_batch", None, "kv_heads", None)
        v = constrain(v, "act_batch", None, "kv_heads", None)
        out = ca.flash_softmax(q, k, v, causal=False,
                               chunk=min(cfg.softmax_chunk, m), mask=mask)
    out = out.reshape(b, n, h * hd)
    out = constrain(out, "act_batch", "attn_seq", None)
    return dense(p["o_w"], out, cfg.cdtype)


# ---------------------------------------------------------------------------
# Serving: the unified engine lifecycle (init_state -> prefill -> decode).
#
# One ``AttentionState`` pytree for every impl, per-row counters always
# (static lockstep batching is the degenerate case where all rows agree),
# diag tails at the G kv heads, backend dispatch (pallas / scan twin / jnp
# ref) owned by ``kernels/registry.py``.  The legacy seed path that
# ``use_serve_kernel=False`` selected is now ``backend='ref'``
# (``AttnSpec.from_cfg`` does that mapping) — used by
# ``benchmarks/bench_serve.py`` as the baseline.
# ---------------------------------------------------------------------------

def serve_state_init(cfg, batch: int, max_len: int):
    """Zeroed :class:`~repro.core.engine.AttentionState` for one layer.

    Always per-row: ``len``/``pos`` are (B,) and alpha/beta (B, H), so the
    same cache layout serves the static lockstep loop and the
    continuous-batching pool (each slot at its own depth with its own
    prompt calibration).
    """
    return attn_engine(cfg).init_state(batch, max_len)


def serve_prefill(p, x, cfg, positions, *, prefix_len: int = 0,
                  max_len: int = 0):
    """Forward over the prompt; returns ``(out, AttentionState)``.  The
    softmax KV cache is allocated at ``max_len`` (>= n) so decode appends
    in place; LLN emits the O(d^2) state from the same pass."""
    b, n, _ = x.shape
    hd, h = cfg.hd, cfg.n_heads
    q, k, v = _project_qkv(p, x, cfg, positions)
    eng = attn_engine(cfg)
    out, state = eng.prefill(q, k, v, max_len=max(max_len, n),
                             prefix_len=prefix_len)
    out = out.reshape(b, n, h * hd)
    return dense(p["o_w"], out, cfg.cdtype), state


def serve_decode(p, x, state, cfg, position, *, row_mask=None,
                 commit_len=None, return_residuals: bool = False):
    """Decode over T >= 1 new tokens.  x: (B, T, d).

    ``position``: absolute index of the first new token — a scalar (static
    batch: every row at the same depth; T=1 is the generation loop, T>1 the
    chunked multi-token / speculative-scoring path) or a per-row (B,)
    vector (continuous batching: every slot at its own depth).
    ``row_mask``: optional (B,) bool — rows where it is False write nothing
    (KV cache / LLN state / tails / positions all keep their old values);
    their outputs are garbage and must be discarded by the caller.
    ``commit_len``: optional per-row (B,) int32 in [0, T] — speculative
    partial commit: all T positions are scored, only the accepted prefix
    folds into the state (``AttentionEngine.verify``).
    ``return_residuals=True`` (requires ``commit_len``) returns a third
    element — the layer's ``{"k", "v"}`` post-RoPE commit residuals — so a
    ``commit_len=0`` score pass can be folded later by
    :func:`serve_commit` without a second full pass.
    """
    b, n, _ = x.shape
    hd, h, g = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = dense(p["q_w"], x, cfg.cdtype).reshape(b, n, h, hd)
    k = dense(p["k_w"], x, cfg.cdtype).reshape(b, n, g, hd)
    v = dense(p["v_w"], x, cfg.cdtype).reshape(b, n, g, hd)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm_scale"], q)
        k = rms_head_norm(p["k_norm_scale"], k)
    if jnp.ndim(position) == 0:
        pos = position + jnp.arange(n, dtype=jnp.int32)
    elif jnp.ndim(position) == 1:
        # Per-row bases: (B,) -> (B, T) absolute positions.
        pos = position[:, None] + jnp.arange(n, dtype=jnp.int32)[None, :]
    else:
        pos = position
    q = rope(q, pos, cfg.rope_theta, cfg.rotary_pct)
    k = rope(k, pos, cfg.rope_theta, cfg.rotary_pct)
    eng = attn_engine(cfg)
    if return_residuals:
        out, state, resid = eng.verify(state, q, k, v, row_mask=row_mask,
                                       commit_len=commit_len,
                                       return_residuals=True)
        out = out.reshape(b, n, h * hd)
        return dense(p["o_w"], out, cfg.cdtype), state, resid
    out, state = eng.decode(state, q, k, v, row_mask=row_mask,
                            commit_len=commit_len)
    out = out.reshape(b, n, h * hd)
    return dense(p["o_w"], out, cfg.cdtype), state


def serve_commit(state, residual, cfg, *, commit_len, row_mask=None):
    """Params-free commit of a scored chunk's accepted prefix.

    ``residual``: the ``{"k", "v"}`` dict :func:`serve_decode` returned
    under ``return_residuals=True`` (the projections and RoPE already
    happened in the score pass); ``state`` the state that pass ran against
    (bitwise unchanged by a ``commit_len=0`` score).  O(T d^2) per layer —
    :meth:`repro.core.engine.AttentionEngine.commit`.
    """
    return attn_engine(cfg).commit(state, residual, commit_len=commit_len,
                                   row_mask=row_mask)


# --- legacy entry points (deprecation shims over the engine) ---------------

@deprecated_shim("models.attention_block.attn_cache_init",
                 "attn_engine(cfg).init_state / serve_state_init")
def attn_cache_init(cfg, batch: int, max_len: int, per_row: bool = False):
    """Legacy cache initializer.  The engine state is always per-row now,
    so ``per_row`` is accepted and ignored (the scalar layout was the
    degenerate case and has been deleted)."""
    del per_row
    return serve_state_init(cfg, batch, max_len)


@deprecated_shim("models.attention_block.attn_prefill", "serve_prefill")
def attn_prefill(p, x, cfg, positions, *, prefix_len: int = 0,
                 max_len: int = 0):
    """Legacy prefill — delegates to :func:`serve_prefill`."""
    return serve_prefill(p, x, cfg, positions, prefix_len=prefix_len,
                         max_len=max_len)


@deprecated_shim("models.attention_block.attn_decode", "serve_decode")
def attn_decode(p, x, cache, cfg, position, *, row_mask=None):
    """Legacy decode — delegates to :func:`serve_decode`."""
    return serve_decode(p, x, cache, cfg, position, row_mask=row_mask)
