"""Standard attention sub-block: projections + RoPE + unified attention.

Used by the dense/MoE decoder LMs, the seamless encoder/decoder, the
PaliGemma decoder and Zamba2's shared attention block.  Supports the three
attention impls (softmax / lln / lln_diag), GQA/MQA, qk-norm, partial RoPE,
and both cache kinds for decode (KV cache vs. O(d^2) LLN state).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import attention as ca
from repro.core import lln as core_lln
from repro.core.attention import AttnConfig
from repro.distributed.sharding import constrain
from .layers import dense, dense_init, rms_head_norm, rope


def attn_cfg_of(cfg, causal: bool = True) -> AttnConfig:
    return AttnConfig(impl=cfg.attn_impl, causal=causal,
                      diag_block=cfg.diag_block, lln_chunk=cfg.lln_chunk,
                      softmax_chunk=cfg.softmax_chunk,
                      use_kernel=cfg.use_kernel,
                      fixed_ab=cfg.lln_fixed_ab)


def attn_init(key, cfg, d_in: Optional[int] = None):
    d = d_in or cfg.d_model
    hd, h, g = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {"q_w": dense_init(ks[0], d, h * hd, cfg.pdtype),
         "k_w": dense_init(ks[1], d, g * hd, cfg.pdtype),
         "v_w": dense_init(ks[2], d, g * hd, cfg.pdtype),
         "o_w": dense_init(ks[3], h * hd, cfg.d_model, cfg.pdtype)}
    if cfg.qk_norm:
        p["q_norm_scale"] = jnp.ones((hd,), cfg.pdtype)
        p["k_norm_scale"] = jnp.ones((hd,), cfg.pdtype)
    return p


def _project_qkv(p, x, cfg, positions):
    b, n, _ = x.shape
    hd, h, g = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = dense(p["q_w"], x, cfg.cdtype).reshape(b, n, h, hd)
    k = dense(p["k_w"], x, cfg.cdtype).reshape(b, n, g, hd)
    v = dense(p["v_w"], x, cfg.cdtype).reshape(b, n, g, hd)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm_scale"], q)
        k = rms_head_norm(p["k_norm_scale"], k)
    q = rope(q, positions, cfg.rope_theta, cfg.rotary_pct)
    k = rope(k, positions, cfg.rope_theta, cfg.rotary_pct)
    q = constrain(q, "act_batch", "attn_seq", "heads", None)
    k = constrain(k, "act_batch", None, "kv_heads", None)
    v = constrain(v, "act_batch", None, "kv_heads", None)
    return q, k, v


def attn_apply(p, x, cfg, positions, *, causal: bool = True,
               kv: Optional[jnp.ndarray] = None,
               mask: Optional[jnp.ndarray] = None,
               prefix_len: int = 0) -> jnp.ndarray:
    """Full-sequence attention.  ``kv``: optional cross-attention memory
    (B, M, d) — used by the seamless decoder (always softmax for cross)."""
    b, n, _ = x.shape
    hd, h, g = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    if kv is None:
        q, k, v = _project_qkv(p, x, cfg, positions)
        out = ca.multi_head_attention(q, k, v, attn_cfg_of(cfg, causal),
                                      mask=mask, prefix_len=prefix_len)
    else:
        m = kv.shape[1]
        q = dense(p["q_w"], x, cfg.cdtype).reshape(b, n, h, hd)
        k = dense(p["k_w"], kv, cfg.cdtype).reshape(b, m, g, hd)
        v = dense(p["v_w"], kv, cfg.cdtype).reshape(b, m, g, hd)
        q = constrain(q, "act_batch", "attn_seq", "heads", None)
        k = constrain(k, "act_batch", None, "kv_heads", None)
        v = constrain(v, "act_batch", None, "kv_heads", None)
        out = ca.flash_softmax(q, k, v, causal=False,
                               chunk=min(cfg.softmax_chunk, m), mask=mask)
    out = out.reshape(b, n, h * hd)
    out = constrain(out, "act_batch", "attn_seq", None)
    return dense(p["o_w"], out, cfg.cdtype)


# ---------------------------------------------------------------------------
# Serving: prefill + decode with impl-appropriate cache.
#
# The default (``cfg.use_serve_kernel``) LLN path is kernelized end to end:
# * prefill gets outputs AND the O(d^2) decode state from ONE pass over the
#   keys (kernels/ops.py:lln_prefill — state-emitting Pallas kernel / its
#   lax.scan twin on CPU), instead of the seed's jnp scan + second full-key
#   einsum; the lln_diag hybrid routes its diagonal component through the
#   block_diag Pallas kernel;
# * the decode cache stores the diag tail at the G kv heads (bytes / r under
#   GQA) — repeated to H only inside the tiny tail-softmax;
# * decode advances T >= 1 tokens per dispatch (chunked multi-token decode).
# ``use_serve_kernel=False`` keeps the seed two-pass path (H-head tails) as
# an explicit escape, used by benchmarks/bench_serve.py as the baseline.
# ---------------------------------------------------------------------------

def attn_cache_init(cfg, batch: int, max_len: int, per_row: bool = False):
    """Zeroed decode cache for one attention layer.

    ``per_row=False`` (static batch): one scalar ``len``/``pos`` and one
    (H,) alpha/beta shared by every row — all rows advance in lockstep.
    ``per_row=True`` (continuous batching): ``len``/``pos`` are (B,) and
    alpha/beta are (B, H) so every slot carries its own depth and its own
    prompt-derived calibration (requests are prefilled separately and admit
    into a freed slot mid-segment).
    """
    hd, h, g = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ctr = (batch,) if per_row else ()
    if cfg.attn_impl == "softmax":
        return {"k": jnp.zeros((batch, max_len, g, hd), cfg.cdtype),
                "v": jnp.zeros((batch, max_len, g, hd), cfg.cdtype),
                "len": jnp.zeros(ctr, jnp.int32)}
    gt = g if cfg.use_serve_kernel else h     # tail heads: G (kernel) / H (seed)
    ab = (batch, h) if per_row else (h,)
    return {"s": jnp.zeros((batch, h, hd, hd), jnp.float32),
            "z": jnp.zeros((batch, h, hd), jnp.float32),
            "c_k": jnp.zeros((batch, 1, h, 1), jnp.float32),
            "tail_k": jnp.zeros((batch, cfg.diag_block, gt, hd), cfg.cdtype),
            "tail_v": jnp.zeros((batch, cfg.diag_block, gt, hd), cfg.cdtype),
            "pos": jnp.zeros(ctr, jnp.int32),
            "alpha": jnp.ones(ab, jnp.float32),
            "beta": jnp.ones(ab, jnp.float32)}   # expanded to H heads


def _tail_of(t, n: int, blk: int):
    """Contents of the (partially filled) last ``blk``-sized block."""
    nb = -(-n // blk)
    last = (nb - 1) * blk
    pad = nb * blk - n
    return jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))[:, last:]


def attn_prefill(p, x, cfg, positions, *, prefix_len: int = 0,
                 max_len: int = 0):
    """Forward over the prompt; returns (out, cache).  The KV cache is
    allocated at ``max_len`` (>= n) so decode can append in place."""
    b, n, _ = x.shape
    max_len = max(max_len, n)
    hd, h, g = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q, k, v = _project_qkv(p, x, cfg, positions)
    acfg = attn_cfg_of(cfg, True)
    if cfg.attn_impl == "softmax":
        out = ca.multi_head_attention(q, k, v, acfg, prefix_len=prefix_len)
        pad = ((0, 0), (0, max_len - n), (0, 0), (0, 0))
        cache = {"k": jnp.pad(k.astype(cfg.cdtype), pad),
                 "v": jnp.pad(v.astype(cfg.cdtype), pad),
                 "len": jnp.asarray(n, jnp.int32)}
    else:
        alpha, beta = ca.batch_alpha_beta(q, k, acfg)
        beta_h = jnp.repeat(beta, h // g) if g != h else beta
        blk = cfg.diag_block
        if cfg.use_serve_kernel:
            # One pass over the keys: outputs + decode state from the
            # state-emitting kernel; no KV repeat anywhere on this path.
            from repro.kernels import ops as kops
            lln_out, s, z, c_k = kops.lln_prefill(q, k, v, alpha, beta,
                                                  chunk=cfg.lln_chunk)
            if cfg.attn_impl == "lln_diag":
                diag_out = kops.block_diag_fwd(q, k, v, blk, True)
                out = (0.5 * (lln_out.astype(jnp.float32)
                              + diag_out.astype(jnp.float32))).astype(v.dtype)
            else:
                out = lln_out
            tail_k, tail_v = _tail_of(k, n, blk), _tail_of(v, n, blk)
        else:
            # Seed path: jnp causal scan + repeated KV, H-head tails.
            kf = k if g == h else jnp.repeat(k, h // g, axis=2)
            vf = v if g == h else jnp.repeat(v, h // g, axis=2)
            lln_out, st = core_lln.prefill(q, kf, vf, alpha, beta_h,
                                           chunk=cfg.lln_chunk)
            s, z, c_k = st.s, st.z, st.c_k
            if cfg.attn_impl == "lln_diag":
                from repro.core.diag import block_diag_attn
                diag_out = block_diag_attn(q, kf, vf, block=blk, causal=True)
                out = (0.5 * (lln_out.astype(jnp.float32)
                              + diag_out.astype(jnp.float32))).astype(v.dtype)
            else:
                out = lln_out
            tail_k, tail_v = _tail_of(kf, n, blk), _tail_of(vf, n, blk)
        cache = {"s": s, "z": z, "c_k": c_k,
                 "tail_k": tail_k.astype(cfg.cdtype),
                 "tail_v": tail_v.astype(cfg.cdtype),
                 "pos": jnp.asarray(n, jnp.int32),
                 "alpha": alpha.astype(jnp.float32),
                 "beta": beta_h.astype(jnp.float32)}
    out = out.reshape(b, n, h * hd)
    return dense(p["o_w"], out, cfg.cdtype), cache


def attn_decode(p, x, cache, cfg, position, *, row_mask=None):
    """Decode over T >= 1 new tokens.  x: (B, T, d).

    ``position``: absolute index of the first new token — a scalar (static
    batch: every row at the same depth; T=1 is the generation loop, T>1 the
    chunked multi-token / speculative-scoring path) or a per-row (B,)
    vector (continuous batching; requires a ``per_row`` cache, whose
    ``len``/``pos`` leaves are (B,) and alpha/beta (B, H)).
    ``row_mask``: optional (B,) bool — rows where it is False write nothing
    (KV cache / LLN state / tails / positions all keep their old values);
    their outputs are garbage and must be discarded by the caller.
    """
    b, n, _ = x.shape
    hd, h, g = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = dense(p["q_w"], x, cfg.cdtype).reshape(b, n, h, hd)
    k = dense(p["k_w"], x, cfg.cdtype).reshape(b, n, g, hd)
    v = dense(p["v_w"], x, cfg.cdtype).reshape(b, n, g, hd)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm_scale"], q)
        k = rms_head_norm(p["k_norm_scale"], k)
    counter = cache["len" if cfg.attn_impl == "softmax" else "pos"]
    if jnp.ndim(position) == 0:
        pos = position + jnp.arange(n, dtype=jnp.int32)
    elif jnp.ndim(position) == 1 and jnp.ndim(counter) == 1:
        # Per-row bases: (B,) -> (B, T) absolute positions.
        pos = position[:, None] + jnp.arange(n, dtype=jnp.int32)[None, :]
    else:
        pos = position
    q = rope(q, pos, cfg.rope_theta, cfg.rotary_pct)
    k = rope(k, pos, cfg.rope_theta, cfg.rotary_pct)

    if cfg.attn_impl == "softmax":
        out, kv2 = ca.decode_softmax(
            ca.KVCache(k=cache["k"], v=cache["v"], length=cache["len"]),
            q, k, v, chunk=cfg.softmax_chunk, row_mask=row_mask)
        new_cache = {"k": kv2.k, "v": kv2.v, "len": kv2.length}
    else:
        st = ca.LLNDecodeState(
            lln=core_lln.LLNState(s=cache["s"], z=cache["z"], c_k=cache["c_k"]),
            tail_k=cache["tail_k"], tail_v=cache["tail_v"], pos=cache["pos"])
        out, st = ca.decode_lln_chunk(st, q, k, v, cache["alpha"],
                                      cache["beta"], impl=cfg.attn_impl,
                                      use_kernel=cfg.use_serve_kernel,
                                      row_mask=row_mask)
        new_cache = {"s": st.lln.s, "z": st.lln.z, "c_k": st.lln.c_k,
                     "tail_k": st.tail_k, "tail_v": st.tail_v, "pos": st.pos,
                     "alpha": cache["alpha"], "beta": cache["beta"]}
    out = out.reshape(b, n, h * hd)
    return dense(p["o_w"], out, cfg.cdtype), new_cache
