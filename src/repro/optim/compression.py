"""Gradient compression for cross-pod (DCN) reduction.

Two mechanisms, both numerically validated in tests/test_optim.py:

* ``bf16_allreduce_cast`` — cast gradients to bf16 before the cross-pod
  all-reduce (2x collective bytes on the slowest link class); the reduce
  itself accumulates in fp32 on TPU.
* int8 error-feedback compression (1-bit-Adam-style residual carrying):
  q_t = Q(g_t + e_t);  e_{t+1} = (g_t + e_t) - DQ(q_t).
  The residual state makes the quantization error telescope instead of
  accumulate, preserving convergence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bf16_allreduce_cast(grads):
    return jax.tree_util.tree_map(
        lambda g: g.astype(jnp.bfloat16), grads)


def ef_init(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress(grads, residual):
    """Returns (quantized tree of (int8, scale) pairs, new residual tree)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(residual)
    qs, new_e = [], []
    for g, e in zip(flat_g, flat_e):
        x = g.astype(jnp.float32) + e
        q, s = _quantize_int8(x)
        qs.append((q, s))
        new_e.append(x - _dequantize_int8(q, s))
    return treedef.unflatten(qs), treedef.unflatten(new_e)


def ef_decompress(qs):
    return jax.tree_util.tree_map(
        lambda p: _dequantize_int8(*p),
        qs, is_leaf=lambda p: isinstance(p, tuple) and len(p) == 2)
