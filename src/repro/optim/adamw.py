"""AdamW with decoupled weight decay, global-norm clipping and mixed
precision (fp32 master moments regardless of param dtype).

Pure-functional: state is a pytree mirroring params, so pjit shards the
optimizer state exactly like the parameters (ZeRO-1 falls out of the
FSDP param sharding — see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(grads, state, params, lr, cfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(gf)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return m, v, (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_p = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}
