"""Optimizer substrate: AdamW, LR schedules, gradient compression."""
from .adamw import (AdamWConfig, adamw_init, adamw_update,
                    clip_by_global_norm, global_norm)
from .compression import (bf16_allreduce_cast, ef_compress, ef_decompress,
                          ef_init)
from .schedules import warmup_cosine, warmup_linear
