"""Fault-tolerant checkpointing (no external deps: npz shards + JSON index).

Layout:   <dir>/step_<N>/
              index.json          pytree structure, leaf shapes/dtypes, CRCs
              shard_<p>.npz       this process's leaves (host-local data)
              _COMMITTED          sentinel written last (atomic completion)

Guarantees:
* atomicity — writers stage into ``step_<N>.tmp`` and rename; a crash mid-
  write never corrupts the latest checkpoint (restore ignores uncommitted
  dirs);
* integrity — per-leaf CRC32 verified on restore;
* elasticity — leaves are saved as *full* (process-gathered) arrays with
  their logical path; restore re-shards onto any mesh/topology via
  ``jax.device_put`` with the target sharding (tested: save on mesh A,
  restore on mesh B of different shape);
* async — ``AsyncCheckpointer`` runs saves on a writer thread off the
  training critical path, with back-pressure on a single in-flight save.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in kp) for kp, _ in leaves]
    return paths, [leaf for _, leaf in leaves], treedef


def save(directory: str, step: int, tree: Any) -> str:
    """Synchronous checkpoint write (single-process data path)."""
    paths, leaves, treedef = _flatten(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    arrays = {}
    meta = {}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        key = f"leaf_{i}"
        arrays[key] = arr
        meta[key] = {"path": p, "shape": list(arr.shape),
                     "dtype": str(arr.dtype),
                     "crc": zlib.crc32(np.ascontiguousarray(arr).tobytes())}
    np.savez(os.path.join(tmp, "shard_0.npz"), **arrays)
    with open(os.path.join(tmp, "index.json"), "w") as f:
        json.dump({"step": step, "treedef": str(treedef), "leaves": meta}, f)
    with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def committed_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "_COMMITTED")):
                steps.append(int(name.split("_")[1]))
    return sorted(steps)


def restore(directory: str, step: int, target_tree: Any,
            shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``target_tree``; place with
    ``shardings`` (pytree of NamedSharding) when given — this is the
    elastic-reshard path."""
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "index.json")) as f:
        index = json.load(f)
    data = np.load(os.path.join(d, "shard_0.npz"))

    by_path = {}
    for key, m in index["leaves"].items():
        arr = data[key]
        crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
        if crc != m["crc"]:
            raise IOError(f"checkpoint corruption at {m['path']}")
        by_path[m["path"]] = arr

    paths, leaves, treedef = _flatten(target_tree)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for p, leaf, shd in zip(paths, leaves, shard_leaves):
        if p not in by_path:
            raise KeyError(f"checkpoint missing leaf {p}")
        arr = by_path[p].astype(leaf.dtype)
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {p}: "
                             f"{arr.shape} vs {leaf.shape}")
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Single-writer-thread async saves with back-pressure."""

    def __init__(self, directory: str, keep_n: int = 3):
        self.directory = directory
        self.keep_n = keep_n
        self._pending: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, tree: Any):
        self.wait()
        # Materialize on host before handing to the writer thread so the
        # training step can donate/overwrite device buffers immediately.
        host_tree = jax.tree_util.tree_map(
            lambda a: np.asarray(jax.device_get(a)), tree)

        def work():
            try:
                save(self.directory, step, host_tree)
                self._gc()
            except BaseException as e:  # pragma: no cover
                self._error = e
        self._pending = threading.Thread(target=work, daemon=True)
        self._pending.start()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = committed_steps(self.directory)
        for s in steps[:-self.keep_n]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
