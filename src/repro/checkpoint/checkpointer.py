"""Fault-tolerant checkpointing (no external deps: npz shards + JSON index).

Layout:   <dir>/step_<N>/
              index.json          pytree structure, leaf shapes/dtypes, CRCs
              shard_<p>.npz       this process's leaves (host-local data)
              <extra files>       opaque sidecar payloads (e.g. batcher meta)
              _COMMITTED          sentinel written last (atomic completion)

Guarantees:
* atomicity — every file is staged to ``<name>.tmp``, fsynced and
  ``os.replace``d; the whole step dir is staged as ``step_<N>.tmp`` and
  renamed into place only after ``_COMMITTED`` lands and the dir is
  fsynced, so a crash at ANY point never leaves a half-written dir that
  restore would pick up;
* integrity — ``_COMMITTED`` carries a manifest of per-file byte sizes
  (truncation detection without a full read) and ``index.json`` carries
  per-leaf CRC32s verified on restore; :func:`is_valid` checks the
  manifest, :func:`valid_steps` filters to fully-intact steps (a legacy
  ``_COMMITTED`` containing just ``"ok"`` falls back to existence checks);
* elasticity — leaves are saved as *full* (process-gathered) arrays with
  their logical path; restore re-shards onto any mesh/topology via
  ``jax.device_put`` with the target sharding (tested: save on mesh A,
  restore on mesh B of different shape);
* async — ``AsyncCheckpointer`` runs saves on a writer thread off the
  training critical path, with back-pressure on a single in-flight save.
"""
from __future__ import annotations

import io
import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in kp) for kp, _ in leaves]
    return paths, [leaf for _, leaf in leaves], treedef


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _write_atomic(path: str, data: bytes) -> None:
    """tmp + fsync + ``os.replace``: the file is either absent or complete,
    never truncated, even across a crash mid-write."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def save(directory: str, step: int, tree: Any,
         extra: Optional[dict] = None) -> str:
    """Synchronous checkpoint write (single-process data path).

    ``extra`` maps file names to ``str``/``bytes`` sidecar payloads saved
    alongside the shards inside the same atomic commit (read back with
    :func:`read_extra`) — e.g. the serving batcher's queue/metadata JSON.
    """
    paths, leaves, treedef = _flatten(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    arrays = {}
    meta = {}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        key = f"leaf_{i}"
        arrays[key] = arr
        meta[key] = {"path": p, "shape": list(arr.shape),
                     "dtype": str(arr.dtype),
                     "crc": zlib.crc32(np.ascontiguousarray(arr).tobytes())}
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    _write_atomic(os.path.join(tmp, "shard_0.npz"), buf.getvalue())
    index = {"step": step, "treedef": str(treedef), "leaves": meta}
    _write_atomic(os.path.join(tmp, "index.json"),
                  json.dumps(index).encode())
    for name, payload in (extra or {}).items():
        if isinstance(payload, str):
            payload = payload.encode()
        _write_atomic(os.path.join(tmp, name), payload)
    # Manifest of byte sizes goes INTO the commit sentinel: a reader can
    # detect truncation of any file without parsing it.
    manifest = {name: os.path.getsize(os.path.join(tmp, name))
                for name in os.listdir(tmp)}
    _write_atomic(os.path.join(tmp, "_COMMITTED"),
                  json.dumps({"files": manifest}).encode())
    _fsync_dir(tmp)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _fsync_dir(directory)
    return final


def committed_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "_COMMITTED")):
                steps.append(int(name.split("_")[1]))
    return sorted(steps)


def is_valid(directory: str, step: int) -> bool:
    """True iff the committed step dir passes its manifest (every file
    present at its recorded size).  Legacy checkpoints whose sentinel is
    the bare ``"ok"`` string fall back to index/shard existence checks."""
    d = os.path.join(directory, f"step_{step:08d}")
    sentinel = os.path.join(d, "_COMMITTED")
    if not os.path.exists(sentinel):
        return False
    try:
        with open(sentinel, "rb") as f:
            raw = f.read()
        manifest = json.loads(raw).get("files", {})
    except (ValueError, OSError):
        # Legacy "ok" sentinel (or unreadable): existence-only check.
        return (os.path.exists(os.path.join(d, "index.json"))
                and os.path.exists(os.path.join(d, "shard_0.npz")))
    for name, size in manifest.items():
        if name == "_COMMITTED":
            continue
        p = os.path.join(d, name)
        if not os.path.exists(p) or os.path.getsize(p) != size:
            return False
    return True


def valid_steps(directory: str) -> list[int]:
    """Committed steps that also pass :func:`is_valid` (restorable)."""
    return [s for s in committed_steps(directory) if is_valid(directory, s)]


def read_extra(directory: str, step: int, name: str) -> bytes:
    """Read back a sidecar file written via ``save(..., extra=...)``."""
    with open(os.path.join(directory, f"step_{step:08d}", name), "rb") as f:
        return f.read()


def restore(directory: str, step: int, target_tree: Any,
            shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``target_tree``; place with
    ``shardings`` (pytree of NamedSharding) when given — this is the
    elastic-reshard path."""
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "index.json")) as f:
        index = json.load(f)
    data = np.load(os.path.join(d, "shard_0.npz"))

    by_path = {}
    for key, m in index["leaves"].items():
        arr = data[key]
        crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
        if crc != m["crc"]:
            raise IOError(f"checkpoint corruption at {m['path']}")
        if arr.dtype.kind == "V":
            # npz round-trips non-native dtypes (bfloat16/float8) as raw
            # void bytes; the index records the real dtype — view it back.
            arr = arr.view(np.dtype(getattr(ml_dtypes, m["dtype"])))
        by_path[m["path"]] = arr

    paths, leaves, treedef = _flatten(target_tree)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for p, leaf, shd in zip(paths, leaves, shard_leaves):
        if p not in by_path:
            raise KeyError(f"checkpoint missing leaf {p}")
        arr = by_path[p].astype(leaf.dtype)
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {p}: "
                             f"{arr.shape} vs {leaf.shape}")
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Single-writer-thread async saves with back-pressure."""

    def __init__(self, directory: str, keep_n: int = 3):
        self.directory = directory
        self.keep_n = keep_n
        self._pending: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, tree: Any,
                   extra: Optional[dict] = None):
        self.wait()
        # Materialize on host before handing to the writer thread so the
        # training step can donate/overwrite device buffers immediately.
        host_tree = jax.tree_util.tree_map(
            lambda a: np.asarray(jax.device_get(a)), tree)

        def work():
            try:
                save(self.directory, step, host_tree, extra=extra)
                self._gc()
            except BaseException as e:  # pragma: no cover
                self._error = e
        self._pending = threading.Thread(target=work, daemon=True)
        self._pending.start()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = committed_steps(self.directory)
        for s in steps[:-self.keep_n]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
