"""CheckpointManager: resume/restart orchestration on top of checkpointer.

Train loops interact only with this class:
    mgr = CheckpointManager(dir, keep_n=3, interval=100)
    state, start_step = mgr.restore_or_init(init_fn, shardings)
    ...
    mgr.maybe_save(step, state)     # async, interval-gated
    mgr.finalize(step, state)       # sync flush at exit/preemption

The serving stack (``launch/batcher.py`` pool snapshots) uses the sync
``save_now``/``read_extra`` pair: snapshots must be durable before the
segment that follows them, and they carry a JSON sidecar (queue + per-row
metadata) next to the device-state shards.

``latest_step`` only ever returns a checkpoint that passes the integrity
manifest (``checkpointer.is_valid``) — a crash during a save can leave a
committed-but-truncated dir, which is skipped AND garbage-collected here
so it can never shadow an older restorable step.
"""
from __future__ import annotations

import os
import shutil
from typing import Any, Callable, Optional

from .checkpointer import (AsyncCheckpointer, committed_steps, is_valid,
                           read_extra, restore, save)


class CheckpointManager:
    def __init__(self, directory: str, *, keep_n: int = 3,
                 interval: int = 100):
        self.directory = directory
        self.interval = interval
        self.async_ckpt = AsyncCheckpointer(directory, keep_n=keep_n)

    def latest_step(self) -> Optional[int]:
        """Newest *restorable* step: corrupt/truncated committed dirs are
        skipped and removed (they would fail restore anyway)."""
        latest = None
        for step in committed_steps(self.directory):
            if is_valid(self.directory, step):
                latest = step
            else:
                shutil.rmtree(
                    os.path.join(self.directory, f"step_{step:08d}"),
                    ignore_errors=True)
        return latest

    def restore_or_init(self, init_fn: Callable[[], Any],
                        shardings: Any = None) -> tuple[Any, int]:
        """Resume from the latest committed checkpoint, else fresh init.
        Re-sharding onto the *current* mesh happens here (elastic restart)."""
        step = self.latest_step()
        template = init_fn()
        if step is None:
            return template, 0
        state = restore(self.directory, step, template, shardings)
        return state, step

    def maybe_save(self, step: int, state: Any):
        if self.interval and step % self.interval == 0 and step > 0:
            self.async_ckpt.save_async(step, state)

    def save_now(self, step: int, state: Any,
                 extra: Optional[dict] = None) -> str:
        """Synchronous save (serving snapshots: durability before the next
        segment matters more than hiding the write)."""
        self.async_ckpt.wait()
        return save(self.directory, step, state, extra=extra)

    def read_extra(self, step: int, name: str) -> bytes:
        return read_extra(self.directory, step, name)

    def finalize(self, step: int, state: Any):
        self.async_ckpt.wait()
        self.async_ckpt.save_async(step, state)
        self.async_ckpt.wait()
