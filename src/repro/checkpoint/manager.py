"""CheckpointManager: resume/restart orchestration on top of checkpointer.

Train loops interact only with this class:
    mgr = CheckpointManager(dir, keep_n=3, interval=100)
    state, start_step = mgr.restore_or_init(init_fn, shardings)
    ...
    mgr.maybe_save(step, state)     # async, interval-gated
    mgr.finalize(step, state)       # sync flush at exit/preemption
"""
from __future__ import annotations

from typing import Any, Callable, Optional

from .checkpointer import (AsyncCheckpointer, committed_steps, restore)


class CheckpointManager:
    def __init__(self, directory: str, *, keep_n: int = 3,
                 interval: int = 100):
        self.directory = directory
        self.interval = interval
        self.async_ckpt = AsyncCheckpointer(directory, keep_n=keep_n)

    def latest_step(self) -> Optional[int]:
        steps = committed_steps(self.directory)
        return steps[-1] if steps else None

    def restore_or_init(self, init_fn: Callable[[], Any],
                        shardings: Any = None) -> tuple[Any, int]:
        """Resume from the latest committed checkpoint, else fresh init.
        Re-sharding onto the *current* mesh happens here (elastic restart)."""
        step = self.latest_step()
        template = init_fn()
        if step is None:
            return template, 0
        state = restore(self.directory, step, template, shardings)
        return state, step

    def maybe_save(self, step: int, state: Any):
        if self.interval and step % self.interval == 0 and step > 0:
            self.async_ckpt.save_async(step, state)

    def finalize(self, step: int, state: Any):
        self.async_ckpt.wait()
        self.async_ckpt.save_async(step, state)
        self.async_ckpt.wait()
