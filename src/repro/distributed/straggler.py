"""Straggler detection: per-step wall-clock watchdog.

EWMA + k*MAD anomaly detector over step times.  On a fleet, ``on_anomaly``
feeds the launcher's replace-node hook; here it records and logs.  Combined
with the input pipeline's prefetching (data/pipeline.py) and async
checkpointing, the only unmitigated straggler class left is in-collective
hardware slowness, which the launcher handles by re-slicing (out of scope
for a single process).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


@dataclasses.dataclass
class StragglerReport:
    step: int
    duration: float
    expected: float
    ratio: float


class StepWatchdog:
    def __init__(self, *, alpha: float = 0.1, k: float = 5.0,
                 warmup_steps: int = 3,
                 on_anomaly: Optional[Callable[[StragglerReport], None]] = None):
        self.alpha = alpha
        self.k = k
        self.warmup = warmup_steps
        self.on_anomaly = on_anomaly
        self.ewma: Optional[float] = None
        self.mad: float = 0.0
        self.count = 0
        self.anomalies: list[StragglerReport] = []
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.monotonic()

    def stop(self, step: int) -> Optional[StragglerReport]:
        assert self._t0 is not None, "stop() without start()"
        dt = time.monotonic() - self._t0
        self._t0 = None
        self.count += 1
        if self.ewma is None:
            self.ewma, self.mad = dt, dt * 0.1
            return None
        report = None
        threshold = self.ewma + self.k * max(self.mad, 1e-4)
        if self.count > self.warmup and dt > threshold:
            report = StragglerReport(step=step, duration=dt,
                                     expected=self.ewma,
                                     ratio=dt / max(self.ewma, 1e-9))
            self.anomalies.append(report)
            if self.on_anomaly:
                self.on_anomaly(report)
        else:
            # Only track the healthy population so anomalies don't poison
            # the baseline.
            self.mad = (1 - self.alpha) * self.mad + \
                self.alpha * abs(dt - self.ewma)
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return report
