"""Distribution: sharding rules, elasticity, straggler mitigation."""
