"""Logical-axis sharding rules (MaxText-style) for params and activations.

Models never name mesh axes directly — they request *logical* axes
("act_batch", "heads", "ff", ...) via :func:`constrain`, and parameter
sharding is derived from path-based rules in :func:`param_specs`.  The
mapping logical->mesh is installed per run (train/serve/dryrun) with
:func:`logical_rules`; outside any rules context every constraint is a
no-op, so single-device smoke tests run the exact same model code.

Mesh axes: ("pod",) "data", "model".  Policy per arch (cfg.attn_shard):
* tp_heads  — attention heads over 'model' (Megatron TP);
* context   — heads not divisible by the model axis: softmax attention is
  sequence-sharded over 'model', LLN attention is replicated over 'model'
  (linear attention is ~1% of FLOPs, see DESIGN.md §4);
* replicate — model axis unused by attention (tiny models).

Every spec is divisibility-checked against the actual dim size and mesh —
axes that do not divide are dropped (never a sharding error, possibly a
less-sharded layout; the dry-run records what was actually achieved).
"""
from __future__ import annotations

import contextlib
import re
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE: dict | None = None
_MESH: Mesh | None = None


@contextlib.contextmanager
def logical_rules(mesh: Mesh, rules: dict[str, tuple]):
    """Install a logical->mesh axis mapping (and the mesh) for model code."""
    global _ACTIVE, _MESH
    prev, prev_mesh = _ACTIVE, _MESH
    _ACTIVE, _MESH = rules, mesh
    try:
        yield
    finally:
        _ACTIVE, _MESH = prev, prev_mesh


def current_mesh() -> Optional[Mesh]:
    return _MESH


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    size = 1
    for a in axes:
        size *= sizes.get(a, 1)   # absent axes (e.g. 'pod' on 1-pod) drop
    return size


def fit_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop spec axes whose mesh size does not divide the dim size, and
    de-duplicate mesh axes across dims (first occurrence wins)."""
    out = []
    used: set = set()
    for dim, axes in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axes is None:
            out.append(None)
            continue
        cand = axes if isinstance(axes, tuple) else (axes,)
        kept = []
        for a in cand:
            if a in used or a not in mesh.axis_names:
                continue
            sz = _axis_size(mesh, tuple(kept) + (a,))
            if dim % sz == 0:
                kept.append(a)
                used.add(a)
        out.append(tuple(kept) if len(kept) > 1 else
                   (kept[0] if kept else None))
    return P(*out)


def constrain(x: jnp.ndarray, *logical_axes) -> jnp.ndarray:
    """Annotate activation sharding by logical axis names (no-op w/o rules)."""
    if _ACTIVE is None or _MESH is None:
        return x
    axes = tuple(_ACTIVE.get(a) if isinstance(a, str) else a
                 for a in logical_axes)
    spec = fit_spec(P(*axes), x.shape, _MESH)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, spec))


# ---------------------------------------------------------------------------
# Parameter sharding from path rules.
# ---------------------------------------------------------------------------

# (regex on 'a/b/c' path, spec builder).  First match wins.  Specs are
# written for the *unstacked* trailing dims; stacked layer params get a
# leading None automatically (detected by the 'layers' path component).
# FSDP axis is ('pod', 'data'): on the single-pod mesh 'pod' is absent and
# drops out; on the multi-pod mesh params/optimizer shard over both (ZeRO
# over DCN — what makes the 236B MoE fit, see EXPERIMENTS.md §Dry-run).
_FSDP = ("pod", "data")
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed/table$",        ("model", _FSDP)),        # (V, D)
    (r"lm_head$",            (_FSDP, "model")),        # (D, V)
    (r"(router|gate)_w$",    (_FSDP, None)),           # (D, E)
    (r"exp_(wi|wi_gate|wi_up)$", ("model", _FSDP, None)),     # (E, D, F)
    (r"exp_wo$",             ("model", None, _FSDP)),         # (E, F, D)
    (r"(o_w|wo|wo_shared|out_w)$", ("model", _FSDP)),         # (F|HD, D)
    (r"(conv_w)$",           (None, None)),
    (r"(a_log|d_skip|dt_bias)$", (None,)),
    (r"\w*(scale|bias)$",    (None,)),
    (r".*",                  (_FSDP, "model")),        # generic 2D (D, F)
]


def _spec_for_path(path: str, shape: tuple[int, ...]) -> P:
    stacked = path.startswith("layers/") or "/layers/" in path
    ndim = len(shape)
    for pat, axes in _PARAM_RULES:
        if re.search(pat, path):
            base = list(axes)
            break
    # Adjust rank: pad/truncate the trailing spec to the unstacked rank.
    core_rank = ndim - 1 if stacked else ndim
    if len(base) < core_rank:
        base = [None] * (core_rank - len(base)) + base
    base = base[-core_rank:] if core_rank else []
    if stacked:
        base = [None] + base
    return P(*base)


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(params, mesh: Mesh):
    """PartitionSpec pytree for a parameter tree (divisibility-fitted)."""
    def leaf_spec(kp, leaf):
        spec = _spec_for_path(_path_str(kp), leaf.shape)
        return fit_spec(spec, leaf.shape, mesh)
    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def param_shardings(params, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(params, mesh))


# ---------------------------------------------------------------------------
# Per-arch logical rule tables.
# ---------------------------------------------------------------------------

def make_rules(cfg, *, multi_pod: bool, serve: bool = False) -> dict:
    """Logical->mesh mapping for one arch config (see module docstring).

    Key activations axes:
    * act_seq  — the residual stream's sequence axis *between* blocks.
      'model' = Megatron-style sequence parallelism (the remat stash and
      norms are 1/model_size per device; attention/MLP gather as needed).
      Disabled for SSM families whose chunk scan would slice a sharded dim.
    * attn_seq — the sequence axis *inside* attention: 'model' only for
      context-parallel softmax archs; None otherwise (TP archs shard heads,
      and LLN attention is cheap enough to replicate for CP archs).
    * act_seq_cache — decode KV-cache sequence axis: 'model' when kv heads
      cannot use the model axis (flash-decode style cache sharding).
    """
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    rules: dict[str, object] = {
        "act_batch": batch_axes,
        "act_seq": "model",
        "attn_seq": None,
        "act_seq_cache": None,
        "embed": None,
        "ff": "model",
        "vocab": "model",
        "kv_heads": "model",
        "heads": "model",
        "head_dim": None,
        "experts": "model",
        "state_d": None,
    }
    if cfg.attn_shard == "context":
        rules["heads"] = None
        rules["kv_heads"] = None
        rules["act_seq_cache"] = "model"
        if cfg.attn_impl == "softmax":
            rules["attn_seq"] = "model"
    elif cfg.attn_shard == "replicate":
        rules["heads"] = None
        rules["kv_heads"] = None
        # Tiny models: fold the model axis into batch when it divides.
        rules["act_batch"] = batch_axes + ("model",)
        rules["act_seq"] = None
    if cfg.family in ("ssm", "hybrid"):
        rules["act_seq"] = None     # SSD chunk scan must not slice a
        rules["attn_seq"] = None    # 'model'-sharded sequence dim
    return rules
