"""Elastic scaling: mesh reconstruction after node loss + state resharding.

On a real fleet the launcher detects failed hosts (heartbeat timeout),
restarts the job on the surviving set, and this module picks the largest
runnable mesh and reshards the checkpointed state onto it.  In this
container the same code paths are exercised by tests with different
``xla_force_host_platform_device_count`` values in subprocesses.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import Mesh

from .sharding import param_shardings


def viable_mesh_shapes(n_devices: int,
                       prefer_model: int = 16) -> list[tuple[int, int]]:
    """(data, model) candidates for a degraded device count, largest first.

    Keeps the model axis as close to ``prefer_model`` as divisibility
    allows — TP degree changes force weight-gather layout changes, so we
    shrink the data axis first (the cheap direction).
    """
    shapes = []
    model = prefer_model
    while model >= 1:
        if n_devices % model == 0:
            shapes.append((n_devices // model, model))
        model //= 2
    return shapes


def make_degraded_mesh(devices: Optional[Sequence] = None,
                       prefer_model: int = 16) -> Mesh:
    devices = list(jax.devices()) if devices is None else list(devices)
    # Largest power-of-two prefix: collectives want regular topology.
    n = 1
    while n * 2 <= len(devices):
        n *= 2
    data, model = viable_mesh_shapes(n, prefer_model)[0]
    import numpy as np
    dev = np.asarray(devices[:n]).reshape(data, model)
    return Mesh(dev, ("data", "model"))


def reshard_state(state, mesh: Mesh):
    """Re-place a (host-restored or differently-sharded) state pytree onto a
    new mesh using the standard param rules."""
    shardings = param_shardings(state, mesh)
    return jax.tree_util.tree_map(jax.device_put, state, shardings)
