"""Render experiments/roofline.json into the EXPERIMENTS.md §Roofline table."""
from __future__ import annotations

import argparse
import json


def render(rows) -> str:
    out = ["| arch | shape | impl | compute_s | memory_s | collective_s | "
           "bound | MODEL_FLOPS | useful | one-line bottleneck note |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    notes = {
        ("compute", "train"): "matmul-bound; next lever: Pallas-fused attn/xent",
        ("compute", "prefill"): "attention/FFN matmuls; lln_diag halves it where not already used",
        ("compute", "decode"): "tiny per-token matmuls; batching is the lever",
        ("memory", "train"): "activation+weight traffic; bigger microbatching or fused kernels",
        ("memory", "prefill"): "activation streaming; fuse feature maps into matmuls (kernels/)",
        ("memory", "decode"): "cache/state reads dominate; int8 cache or LLN state shrink it",
        ("collective", "train"): "weight gathers + grad reduce; larger per-device batch or pure-FSDP layout",
        ("collective", "prefill"): "EP combine / TP gathers; scatter-combine + overlap hide it",
        ("collective", "decode"): "per-token psums over model axis; wider batching amortizes",
    }
    for r in rows:
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                       f"| — | — | {r['error']} |")
            continue
        kind = ("train" if r["shape"].startswith("train") else
                ("prefill" if "prefill" in r["shape"] else "decode"))
        note = notes.get((r["dominant"], kind), "")
        if r["arch"] == "zamba2-7b" and kind == "train":
            note = "flagged: CPU-partitioner inflation on SSD scan stacks (§Perf cell 3)"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['attn_impl']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | **{r['dominant']}** "
            f"| {r['model_flops']:.3e} | {r['useful_ratio'] or 0:.3f} "
            f"| {note} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--report", default="experiments/roofline.json")
    ap.add_argument("--md", default="EXPERIMENTS.md")
    args = ap.parse_args()
    with open(args.report) as f:
        rows = json.load(f)
    table = render(rows)
    with open(args.md) as f:
        doc = f.read()
    marker = "<!-- ROOFLINE_TABLE -->"
    if marker in doc:
        doc = doc.replace(marker, marker + "\n\n" + table + "\n")
        with open(args.md, "w") as f:
            f.write(doc)
        print("table inserted into", args.md)
    else:
        print(table)


if __name__ == "__main__":
    main()
