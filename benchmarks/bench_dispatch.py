"""Dispatch-overhead microbench: engine indirection vs direct kernel calls.

The AttentionEngine adds a layer of indirection over the kernel entry
points (spec resolution, ``AttentionState`` packing/unpacking).  Under
``jax.jit`` all of that happens at trace time, so the per-step cost of the
engine path must be indistinguishable from calling the kernels directly —
this bench gates exactly that claim:

* ``decode`` — one jitted chunked decode step: the legacy composition
  (``LLNDecodeState`` + ``core/attention.py:decode_lln_chunk``) vs
  ``AttentionEngine.decode`` on the same ``AttentionState``;
* ``prefill`` — the direct ``kernels/ops.py:lln_prefill`` kernel call vs
  ``AttentionEngine.prefill`` (which additionally assembles the state
  pytree: tails, per-row counters, calibration broadcast).

``derived`` is the ratio engine_us / direct_us (interleaved min-of-K on
jitted, pre-compiled callables) — ~1.0 means the indirection is free.
Writes ``BENCH_dispatch.json`` at the repo root (benchmarks/README.md).

Usage:
    PYTHONPATH=src python -m benchmarks.bench_dispatch [--smoke] \
        [--out PATH] [--repeats K]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import attention as ca
from repro.core import lln as core_lln
from repro.core.engine import AttentionEngine
from repro.kernels import ops as kops
from repro.kernels.registry import AttnSpec

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_dispatch.json")


def _qkv(seed, b, n, h, g, d):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(kq, (b, n, h, d)),
            jax.random.normal(kk, (b, n, g, d)),
            jax.random.normal(kv, (b, n, g, d)))


def _time_interleaved(fns, repeats: int):
    """Min-of-``repeats`` per callable, interleaved so drift hits both."""
    for fn in fns:
        jax.block_until_ready(fn())            # warm (compile outside)
    best = [float("inf")] * len(fns)
    for _ in range(repeats):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best[i] = min(best[i], time.perf_counter() - t0)
    return [b * 1e6 for b in best]             # us


def run(out_path: str = DEFAULT_OUT, smoke: bool = False,
        repeats: int = 9, verbose: bool = True):
    b, n, t, g, r, d = (2, 64, 4, 2, 2, 16) if smoke else (4, 256, 8, 2, 4, 64)
    h = g * r
    chunk = 32 if smoke else 128
    spec = AttnSpec(impl="lln_diag", causal=True, r=r, lln_chunk=chunk,
                    diag_block=chunk, fixed_ab=2.1)
    eng = AttentionEngine(spec=spec, heads=h, kv_heads=g, head_dim=d,
                          v_dim=d, cache_dtype=jnp.float32)
    q, k, v = _qkv(0, b, n, h, g, d)
    qn, kn, vn = _qkv(1, b, t, h, g, d)
    alpha = jnp.full((h,), 1.3)
    beta = jnp.full((g,), 1.1)

    rows = []

    # --- prefill: legacy composition (direct kernel calls + hand-rolled
    # state assembly, the pre-engine ``attn_prefill`` body) vs engine ------
    def legacy_prefill(q, k, v):
        lln_out, s, z, c_k = kops.lln_prefill(q, k, v, alpha, beta,
                                              chunk=chunk)
        diag = kops.block_diag_fwd(q, k, v, chunk, True)
        out = (0.5 * (lln_out.astype(jnp.float32)
                      + diag.astype(jnp.float32))).astype(v.dtype)
        tail_k, tail_v = k[:, -chunk:], v[:, -chunk:]
        return out, (s, z, c_k, tail_k, tail_v)

    direct_pf = jax.jit(legacy_prefill)
    engine_pf = jax.jit(lambda q, k, v: eng.prefill(q, k, v, max_len=n + t,
                                                    alpha=alpha, beta=beta))
    us_direct, us_engine = _time_interleaved(
        [lambda: direct_pf(q, k, v), lambda: engine_pf(q, k, v)], repeats)
    rows.append(("dispatch_prefill_direct", us_direct, 1.0))
    rows.append(("dispatch_prefill_engine", us_engine,
                 us_engine / max(us_direct, 1e-9)))

    # --- decode step: legacy composition vs engine ------------------------
    _, state = jax.block_until_ready(engine_pf(q, k, v))

    def legacy_step(state, qn, kn, vn):
        st = ca.LLNDecodeState(
            lln=core_lln.LLNState(s=state.s, z=state.z, c_k=state.c_k),
            tail_k=state.tail_k, tail_v=state.tail_v, pos=state.pos)
        return ca.decode_lln_chunk(st, qn, kn, vn, state.alpha, state.beta,
                                   impl="lln_diag")

    legacy_dec = jax.jit(legacy_step)
    engine_dec = jax.jit(lambda state, qn, kn, vn: eng.decode(state, qn,
                                                              kn, vn))
    us_direct, us_engine = _time_interleaved(
        [lambda: legacy_dec(state, qn, kn, vn),
         lambda: engine_dec(state, qn, kn, vn)], repeats)
    rows.append(("dispatch_decode_direct", us_direct, 1.0))
    rows.append(("dispatch_decode_engine", us_engine,
                 us_engine / max(us_direct, 1e-9)))

    report = {
        "host_backend": jax.default_backend(),
        "shape": {"b": b, "n": n, "t": t, "h": h, "g": g, "d": d,
                  "chunk": chunk},
        "repeats": repeats,
        "rows": [{"name": nm, "us_per_call": us, "ratio_vs_direct": dr}
                 for nm, us, dr in rows],
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2)
    if verbose:
        for nm, us, dr in rows:
            print(f"  {nm:32s} {us:10.1f} us  ratio {dr:.3f}")
    return rows


def run_rows(verbose: bool = True):
    """benchmarks/run.py adapter (no JSON write in the aggregate pass)."""
    return run(out_path="", smoke=True, repeats=3, verbose=verbose)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--repeats", type=int, default=9)
    args = ap.parse_args(argv)
    run(out_path=args.out, smoke=args.smoke, repeats=args.repeats)


if __name__ == "__main__":
    main()
