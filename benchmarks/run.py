"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per the repo convention:

  bench_distribution   Fig. 5 / Fig. 7 / Props. 3.1 & 4.1
  bench_concentration  Fig. 2 (entropy + spectral gap vs temperature)
  bench_convergence    Fig. 8a / Table 1 proxy (+ Fig. 9 alpha tracking)
  bench_scaling        Table 2 (+ LRA Table 4 timing class)
  bench_serve          serving path: kernel prefill + scanned decode
                       (also writes BENCH_serve.json at the repo root)
  bench_batching       continuous vs static batching goodput under skewed
                       request lengths (writes BENCH_batching.json)
  bench_dispatch       AttentionEngine indirection vs direct kernel calls
                       (ratio must stay ~1.0; writes BENCH_dispatch.json
                       when run standalone)
  bench_spec           speculative decode: acceptance rate + tokens per
                       verify step across k x impl x r (writes
                       BENCH_spec.json when run standalone)
  bench_robustness     health-sentinel overhead: serving tok/s with the
                       per-row state-health reduction on vs off, gated at
                       <=2% (writes BENCH_robustness.json)
  bench_longctx        long-horizon soak: 500k-token decode with renorm +
                       beta(n) on, gated on z pinned / fp32-safe state /
                       renorm invariance / flat concentration telemetry
                       (writes BENCH_longctx.json)
  bench_loglinear      log_linear multi-scale state: O(log N * d^2) state
                       bytes, association-recall vs single-state lln, and
                       bounded chunked-decode overhead (writes
                       BENCH_loglinear.json)

Roofline terms (EXPERIMENTS.md §Roofline) are produced separately by
``python -m benchmarks.roofline`` from the dry-run artifacts.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (bench_batching, bench_concentration, bench_convergence,
                   bench_dispatch, bench_distribution, bench_loglinear,
                   bench_longctx, bench_robustness, bench_scaling,
                   bench_serve, bench_spec)

    class _ServeAdapter:
        run = staticmethod(bench_serve.run_rows)

    class _BatchingAdapter:
        run = staticmethod(bench_batching.run_rows)

    class _DispatchAdapter:
        run = staticmethod(bench_dispatch.run_rows)

    class _SpecAdapter:
        run = staticmethod(bench_spec.run_rows)

    class _RobustnessAdapter:
        run = staticmethod(bench_robustness.run_rows)

    class _LongctxAdapter:
        run = staticmethod(bench_longctx.run_rows)

    class _LoglinearAdapter:
        run = staticmethod(bench_loglinear.run_rows)

    modules = [("distribution", bench_distribution),
               ("concentration", bench_concentration),
               ("convergence", bench_convergence),
               ("scaling", bench_scaling),
               ("serve", _ServeAdapter),
               ("batching", _BatchingAdapter),
               ("dispatch", _DispatchAdapter),
               ("spec", _SpecAdapter),
               ("robustness", _RobustnessAdapter),
               ("longctx", _LongctxAdapter),
               ("loglinear", _LoglinearAdapter)]
    all_rows = []
    for name, mod in modules:
        print(f"== {name} ==", file=sys.stderr, flush=True)
        t0 = time.time()
        rows = mod.run(verbose=True)
        print(f"   ({time.time() - t0:.1f}s)", file=sys.stderr)
        all_rows.extend(rows)
    print("name,us_per_call,derived")
    for name, us, derived in all_rows:
        print(f"{name},{us:.1f},{derived:.4f}")


if __name__ == "__main__":
    main()
