"""Serving-path benchmark: kernelized + scanned serve vs the seed path.

Measures, per (GQA ratio r, attention impl), on a small real model driven
through the real serving machinery (``launch/steps.py:make_serve_setup``):

* **prefill latency** — ``seed``: the seed two-pass prefill
  (``use_serve_kernel=False``: jnp causal scan + a second full-key einsum to
  rebuild the decode state, repeated KV, H-head tails) vs ``kernel``: the
  state-emitting one-pass prefill (``kernels/ops.py:lln_prefill`` — Pallas
  kernel on TPU, its chunked ``lax.scan`` twin on the CPU container — plus
  the block-diag kernel for the lln_diag hybrid, G-head tails).  The softmax
  impl has no LLN state to build, so its prefill path is unchanged by
  construction and its ratio is reported as context, not a gate.
* **steady-state decode tok/s** — ``loop``: the seed per-token Python loop
  (one jitted dispatch per generated token) vs ``scan``: the whole segment
  folded into one jitted ``lax.scan`` with donated cache carry
  (``ServeSetup.make_generate``).  Both exclude the compile-bearing first
  step.
* **chunked multi-token decode** — scoring T draft tokens through
  ``model.decode`` in one dispatch (the ``lln_decode_chunk`` path) vs T
  sequential single-token dispatches (speculative-decode building block).

Writes ``BENCH_serve.json`` at the repo root (schema: benchmarks/README.md).
Absolute numbers on the CPU container are only meaningful relative to each
other on the same host.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_serve [--smoke] \
        [--out PATH] [--repeats K]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.launch.mesh import compat_mesh
from repro.launch.steps import make_serve_setup
from repro.models import build_model, synthetic_batch

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_serve.json")

IMPLS = ("softmax", "lln", "lln_diag")


def _cfg(r: int, impl: str, *, blk: int, serve_kernel: bool) -> ArchConfig:
    h = 4
    return ArchConfig(
        name=f"serve-bench-r{r}", family="dense", n_layers=2, d_model=128,
        n_heads=h, n_kv_heads=h // r, d_ff=256, vocab=512, head_dim=32,
        attn_impl=impl, diag_block=blk, lln_chunk=blk, softmax_chunk=2 * blk,
        use_serve_kernel=serve_kernel, compute_dtype="float32",
        param_dtype="float32", remat="none", tie_embeddings=True)


class _Bench:
    """One (r, impl, mode) serving session on a 1x1 mesh."""

    def __init__(self, cfg, batch_size: int, prompt: int, gen: int, mesh):
        self.cfg, self.gen, self.prompt = cfg, gen, prompt
        self.model = build_model(cfg)
        max_len = prompt + gen
        shape = ShapeSpec("bench", max_len, batch_size, "decode")
        self.setup = make_serve_setup(cfg, shape, mesh, multi_pod=False)
        self.params = self.model.init(jax.random.PRNGKey(0))
        self.batch = synthetic_batch(cfg, batch_size, max_len,
                                     text_seq=prompt)
        self.pos0 = jnp.asarray(prompt, jnp.int32)

    def prefill(self):
        logits, caches = self.setup.prefill_fn(self.params, self.batch)
        jax.block_until_ready(logits)
        return logits, caches

    def first_step(self, logits, caches):
        tok = jnp.argmax(logits[:, -1] if logits.ndim == 3 else logits,
                         -1).astype(jnp.int32)
        logits, caches = self.setup.decode_fn(self.params, caches, tok,
                                              self.pos0)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return tok, caches

    def time_loop_decode(self) -> float:
        """Seed decode: one jitted dispatch per token; first step excluded."""
        tok, caches = self.first_step(*self.prefill())
        jax.block_until_ready(tok)
        t0 = time.perf_counter()
        for i in range(self.gen - 1):
            logits, caches = self.setup.decode_fn(
                self.params, caches, tok,
                self.pos0 + jnp.asarray(1 + i, jnp.int32))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(tok)
        return time.perf_counter() - t0

    def time_scan_decode(self, gen_fn) -> float:
        tok, caches = self.first_step(*self.prefill())
        key = jax.random.PRNGKey(1)
        jax.block_until_ready(tok)
        t0 = time.perf_counter()
        toks, _ = gen_fn(self.params, caches, tok, self.pos0 + 1, key)
        jax.block_until_ready(toks)
        return time.perf_counter() - t0

    def time_chunk_decode(self, chunk_t: int):
        """Score chunk_t draft tokens: one chunked dispatch vs chunk_t
        sequential dispatches (compile excluded for both)."""
        draft = jnp.ones((self.batch["inputs"].shape[0], chunk_t), jnp.int32)
        decode_chunk = jax.jit(
            lambda p, c, t, pos: self.model.decode(p, c, t, pos))
        seq_times, chunk_times = [], []
        for it in range(2):                      # it 0 warms the compiles
            _, caches = self.prefill()
            t0 = time.perf_counter()
            lg, caches = decode_chunk(self.params, caches, draft, self.pos0)
            jax.block_until_ready(lg)
            if it:
                chunk_times.append(time.perf_counter() - t0)
            _, caches = self.prefill()
            t0 = time.perf_counter()
            for i in range(chunk_t):
                lg, caches = self.setup.decode_fn(
                    self.params, caches, draft[:, i],
                    self.pos0 + jnp.asarray(i, jnp.int32))
            jax.block_until_ready(lg)
            if it:
                seq_times.append(time.perf_counter() - t0)
        return min(chunk_times), min(seq_times)


def bench_one(r: int, impl: str, *, batch: int, prompt: int, gen: int,
              blk: int, chunk_t: int, repeats: int, mesh) -> dict:
    modes = {}
    for mode, sk in (("seed", False), ("kernel", True)):
        modes[mode] = _Bench(_cfg(r, impl, blk=blk, serve_kernel=sk),
                             batch, prompt, gen, mesh)
    # --- prefill: warm both, then interleave min-of-K (order alternated
    # per round so host-load drift and order bias hit both modes equally).
    for b in modes.values():
        b.prefill()
    pf = {m: [] for m in modes}
    order = list(modes.items())
    for i in range(repeats):
        for m, b in (order if i % 2 == 0 else order[::-1]):
            t0 = time.perf_counter()
            b.prefill()
            pf[m].append(time.perf_counter() - t0)
    prefill_us = {m: min(v) * 1e6 for m, v in pf.items()}

    # --- decode: seed python loop vs scanned segment (interleaved) -------
    kb = modes["kernel"]
    steps = gen - 1
    gen_fn = kb.setup.make_generate(steps, 0.0)
    kb.time_scan_decode(gen_fn)                  # compile
    modes["seed"].time_loop_decode()             # warm the loop's step
    loop_ts, scan_ts = [], []
    for i in range(repeats):
        if i % 2 == 0:
            loop_ts.append(modes["seed"].time_loop_decode())
            scan_ts.append(kb.time_scan_decode(gen_fn))
        else:
            scan_ts.append(kb.time_scan_decode(gen_fn))
            loop_ts.append(modes["seed"].time_loop_decode())
    loop_s, scan_s = min(loop_ts), min(scan_ts)
    n_tok = steps * batch

    # --- chunked multi-token decode --------------------------------------
    chunk_s, seq_s = kb.time_chunk_decode(chunk_t)

    return {
        "name": f"r{r}_{impl}", "r": r, "impl": impl,
        "shape": {"batch": batch, "prompt": prompt, "gen": gen,
                  "heads": 4, "kv_heads": 4 // r, "head_dim": 32,
                  "block": blk, "chunk_t": chunk_t},
        "prefill_us": prefill_us,
        "prefill_speedup": prefill_us["seed"] / prefill_us["kernel"],
        "decode": {
            "seed_loop_tok_s": n_tok / loop_s,
            "scan_tok_s": n_tok / scan_s,
            "speedup": loop_s / scan_s,
        },
        "decode_chunk": {
            "chunk_us": chunk_s * 1e6,
            "sequential_us": seq_s * 1e6,
            "speedup": seq_s / chunk_s,
        },
    }


def run(out_path: str = DEFAULT_OUT, smoke: bool = False,
        repeats: int = 5, verbose: bool = True) -> dict:
    if smoke:
        cells = [(1, "softmax"), (1, "lln_diag")]
        batch, prompt, gen, blk, chunk_t, repeats = 2, 32, 5, 16, 4, 1
    else:
        cells = [(r, impl) for r in (1, 4) for impl in IMPLS]
        batch, prompt, gen, blk, chunk_t = 2, 128, 17, 32, 8
    mesh = compat_mesh((1, 1), ("data", "model"))
    rows = []
    with mesh:
        for r, impl in cells:
            if verbose:
                print(f"== r{r} {impl} ==", flush=True)
            row = bench_one(r, impl, batch=batch, prompt=prompt, gen=gen,
                            blk=blk, chunk_t=chunk_t, repeats=repeats,
                            mesh=mesh)
            rows.append(row)
            if verbose:
                d = row["decode"]
                print(f"  prefill seed {row['prefill_us']['seed']:9.0f}us"
                      f" -> kernel {row['prefill_us']['kernel']:9.0f}us"
                      f" ({row['prefill_speedup']:.2f}x)   decode loop "
                      f"{d['seed_loop_tok_s']:7.0f} -> scan "
                      f"{d['scan_tok_s']:7.0f} tok/s ({d['speedup']:.2f}x)"
                      f"   chunk[{chunk_t}] "
                      f"{row['decode_chunk']['speedup']:.2f}x", flush=True)
    report = {
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() == "cpu",
        "repeats": repeats,
        "modes": {
            "seed": "use_serve_kernel=False prefill (jnp scan + second "
                    "full-key state einsum, repeated KV, H-head tails) + "
                    "per-token Python dispatch loop",
            "kernel": "state-emitting one-pass prefill (Pallas / scan twin) "
                      "+ jitted lax.scan generation segment (donated carry) "
                      "+ G-head tails",
        },
        "gate": "kernel beats seed on steady-state tok/s for every row and "
                "on prefill latency for every LLN row (softmax prefill is "
                "the same code path in both modes; its ratio is context)",
        "results": rows,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    if verbose:
        print(f"wrote {out_path}")
    return report


def run_rows(verbose: bool = True):
    """benchmarks/run.py adapter: (name, us_per_call, derived) CSV rows —
    us = kernel-path prefill latency, derived = steady-state scan tok/s."""
    report = run(verbose=verbose)
    return [(f"serve_{row['name']}", row["prefill_us"]["kernel"],
             row["decode"]["scan_tok_s"]) for row in report["results"]]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--smoke", action="store_true",
                    help="two tiny cells (CI)")
    args = ap.parse_args()
    run(args.out, smoke=args.smoke, repeats=args.repeats)


if __name__ == "__main__":
    main()
