"""Log-linear multi-scale state: memory, recall, and decode-cost gates.

The ``log_linear`` impl trades the single O(d^2) LLN summary for a
Fenwick pyramid of ``num_scales`` bucket states (``core/loglinear.py``).
This benchmark checks the three claims that justify the extra state:

* **state bytes are O(log N * d^2)** — the decode state for a 32k-token
  row (pyramid deep enough that the saturating top level is actually
  exercised) stays under 2x the ideal ``ceil(log2 N) * d * dv`` fp32
  bucket budget, and is hundreds of times smaller than the KV cache a
  softmax row of the same depth would carry;
* **multi-scale recall** — on a synthetic association-recall stream
  (key/value pairs written recently, a long distractor prefix behind
  them), down-weighting the old mass by ``scale_decay**level`` recovers
  the stored values where the single-state LLN's uniform running sum
  drowns them: top-1 retrieval accuracy and the correct-vs-confuser
  cosine margin must both beat plain ``lln``;
* **bounded decode cost** — chunked ``loglin_decode_chunk`` wall clock
  stays within ``GATE_DECODE_RATIO``x of ``lln_decode_chunk`` at serving
  shapes (the pyramid fold is O(L) adds + two-view scoring; it must not
  regress the token loop asymptotically).

Writes ``BENCH_loglinear.json`` at the repo root (schema:
benchmarks/README.md).  Wall-clock gates are informational in ``--smoke``
mode (same policy as bench_robustness / bench_longctx); the memory and
recall gates are deterministic and always count.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_loglinear [--smoke] \
        [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lln as core_lln
from repro.core import loglinear as core_loglin
from repro.kernels import ops as kops

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_loglinear.json")

GATE_STATE_RATIO = 2.0       # state bytes / ideal log2(N) bucket budget
GATE_DECODE_RATIO = 3.0      # loglin decode wall clock / lln decode
GATE_RECALL_ACC = 0.85       # multi-scale top-1 retrieval accuracy

STATE_N = 32_768             # horizon the state-bytes cell is sized for
STATE_D = 64
STATE_GRANULE = 128


def state_bytes_cell(verbose: bool) -> dict:
    """Decode-state footprint for one 32k-token row, vs the ideal
    log-depth bucket budget and the equivalent softmax KV cache."""
    n, d, dv, g = STATE_N, STATE_D, STATE_D, STATE_GRANULE
    # pyramid deep enough that 32k tokens overflow into the top level
    ls = max(1, int(math.ceil(math.log2(n // g))))
    st = core_loglin.LogLinState.init(1, 1, d, dv, ls)
    actual = sum(int(np.asarray(leaf).nbytes)
                 for leaf in jax.tree_util.tree_leaves(st))
    ideal = int(math.ceil(math.log2(n))) * d * dv * 4       # fp32 buckets
    kv = 2 * n * d * 4                                      # softmax row
    row = {"name": "state_bytes", "tokens": n, "head_dim": d,
           "granule": g, "num_scales": ls,
           "state_bytes_per_head": actual,
           "ideal_log2n_bytes": ideal, "kv_cache_bytes": kv,
           "ratio_vs_ideal": actual / ideal,
           "compression_vs_kv": kv / actual,
           "gate_ratio": GATE_STATE_RATIO,
           "pass": bool(actual <= GATE_STATE_RATIO * ideal)}
    if verbose:
        print(f"  state {actual / 1024:.0f} KiB/head vs ideal "
              f"{ideal / 1024:.0f} KiB (x{row['ratio_vs_ideal']:.2f}, "
              f"gate {GATE_STATE_RATIO}x) — {row['compression_vs_kv']:.0f}x "
              f"smaller than the 32k KV cache "
              f"({'PASS' if row['pass'] else 'FAIL'})", flush=True)
    return row


def _recall_stream(n: int, granule: int, pairs: int, d: int, seed: int):
    """Distractor prefix + ``pairs`` associations written in the last few
    granules + one probe query per pair in the open granule.

    Under the elementwise-exp LLN feature map, dense random keys barely
    discriminate (``phi(q) . phi(k)`` is a sum of per-dim log-normals, so
    a matched pair only beats a random cross by ``e^(scale^2/d)`` per
    dim).  The associations therefore use SPARSE disjoint-support keys —
    pair ``j`` puts weight ``s`` on its own ``d // pairs`` dims — giving
    a matched score of ``(d/pairs) e^(2s)`` vs ``~e^s`` cross terms:
    retrievable when old mass is down-weighted, drowned by a uniform sum
    over the full distractor prefix.
    """
    rng = np.random.default_rng(seed)
    s = 2.0
    sup = d // pairs

    def unit(shape):
        x = rng.normal(size=shape)
        return x / np.linalg.norm(x, axis=-1, keepdims=True)

    keys = np.zeros((pairs, d))
    for j in range(pairs):
        keys[j, j * sup:(j + 1) * sup] = s
    vals = unit((pairs, d))
    n_store = 2 * granule            # associations: the last two granules
    n_probe = pairs                  # probes: the open (ragged) tail
    n_dis = n - n_store - n_probe
    k = np.concatenate([
        rng.normal(size=(n_dis, d)),
        np.repeat(keys, n_store // pairs, axis=0)[:n_store],
        np.zeros((n_probe, d))])                       # probes: inert keys
    v = np.concatenate([
        unit((n_dis, d)),
        np.repeat(vals, n_store // pairs, axis=0)[:n_store],
        np.zeros((n_probe, d))])
    q = np.concatenate([rng.normal(size=(n - n_probe, d)), keys])
    return (jnp.asarray(q, jnp.float32)[None, :, None, :],
            jnp.asarray(k, jnp.float32)[None, :, None, :],
            jnp.asarray(v, jnp.float32)[None, :, None, :],
            np.asarray(vals, np.float32))


def _recall_score(out, vals, pairs: int):
    """Top-1 accuracy + mean correct-vs-best-confuser cosine margin of the
    last ``pairs`` outputs against the stored value dictionary."""
    probes = np.asarray(out)[0, -pairs:, 0]            # (P, d)
    probes = probes / (np.linalg.norm(probes, axis=-1, keepdims=True)
                       + 1e-30)
    cos = probes @ vals.T                              # (P, P)
    acc = float(np.mean(np.argmax(cos, axis=-1) == np.arange(pairs)))
    own = cos[np.arange(pairs), np.arange(pairs)]
    confuser = np.max(cos - 2.0 * np.eye(pairs), axis=-1)
    return acc, float(np.mean(own - confuser))


def recall_cell(smoke: bool, verbose: bool) -> dict:
    """Association recall: multi-scale pyramid vs single-state LLN,
    averaged over 3 deterministic stream seeds."""
    n, granule, pairs, d = (1024, 32, 8, 32) if smoke else (4096, 32, 8, 32)
    ls, decay, seeds = 6, 0.5, (0, 1, 2)
    alpha = jnp.ones((1,), jnp.float32)
    beta = jnp.ones((1,), jnp.float32)
    accs = {"log_linear": [], "lln": []}
    margins = {"log_linear": [], "lln": []}
    for seed in seeds:
        q, k, v, vals = _recall_stream(n, granule, pairs, d, seed=seed)
        out_ml = kops.loglin_attention(q, k, v, alpha, beta, True, granule,
                                       num_scales=ls, scale_decay=decay,
                                       backend="scan")
        out_ll = kops.lln_attention(q, k, v, alpha, beta, True, granule,
                                    backend="scan")
        for name, out in (("log_linear", out_ml), ("lln", out_ll)):
            acc, margin = _recall_score(out, vals, pairs)
            accs[name].append(acc)
            margins[name].append(margin)
    acc_ml = float(np.mean(accs["log_linear"]))
    acc_ll = float(np.mean(accs["lln"]))
    margin_ml = float(np.mean(margins["log_linear"]))
    margin_ll = float(np.mean(margins["lln"]))
    row = {"name": "recall",
           "stream": {"tokens": n, "granule": granule, "pairs": pairs,
                      "head_dim": d, "num_scales": ls,
                      "scale_decay": decay, "seeds": list(seeds)},
           "log_linear": {"top1_acc": acc_ml, "cos_margin": margin_ml},
           "lln": {"top1_acc": acc_ll, "cos_margin": margin_ll},
           "gate_acc": GATE_RECALL_ACC,
           "pass": bool(acc_ml >= GATE_RECALL_ACC and acc_ml >= acc_ll
                        and margin_ml > margin_ll)}
    if verbose:
        print(f"  recall@{n}: log_linear acc {acc_ml:.2f} margin "
              f"{margin_ml:+.3f}  vs  lln acc {acc_ll:.2f} margin "
              f"{margin_ll:+.3f}  ({'PASS' if row['pass'] else 'FAIL'})",
              flush=True)
    return row


def decode_cost_cell(smoke: bool, verbose: bool) -> dict:
    """Chunked decode wall clock: loglin_decode_chunk vs lln_decode_chunk
    at serving shapes, min-of-repeats, jitted."""
    b, h, d, dv, t = 4, 4, 64, 64, 16
    granule, ls, decay = 16, 4, 0.5
    steps, repeats = (8, 2) if smoke else (64, 5)
    key = jax.random.PRNGKey(0)
    alpha = jnp.full((b, h), 0.9, jnp.float32)
    beta = jnp.full((b, h), 0.9, jnp.float32)

    ll_st = core_lln.LLNState.init(b, h, d, dv)
    ml_st = core_loglin.LogLinState.init(b, h, d, dv, ls)
    pos0 = jnp.zeros((b,), jnp.int32)

    @jax.jit
    def step_ll(state, q, k, v):
        return kops.lln_decode_chunk(state, q, k, v, alpha, beta)

    @jax.jit
    def step_ml(state, pos, q, k, v):
        out, st = kops.loglin_decode_chunk(
            state, q, k, v, alpha, beta, pos=pos, granule=granule,
            num_scales=ls, scale_decay=decay)
        return out, st, pos + t

    def loop_ll():
        st = ll_st
        for i in range(steps):
            kk = jax.random.fold_in(key, i)
            q, k, v = (jax.random.normal(jax.random.fold_in(kk, j),
                                         (b, t, h, d)) for j in range(3))
            out, st = step_ll(st, q, k, v)
        return out.block_until_ready()

    def loop_ml():
        st, pos = ml_st, pos0
        for i in range(steps):
            kk = jax.random.fold_in(key, i)
            q, k, v = (jax.random.normal(jax.random.fold_in(kk, j),
                                         (b, t, h, d)) for j in range(3))
            out, st, pos = step_ml(st, pos, q, k, v)
        return out.block_until_ready()

    loop_ll(), loop_ml()                               # compile
    walls = {"lln": [], "log_linear": []}
    for it in range(repeats):
        order = (("lln", loop_ll), ("log_linear", loop_ml)) if it % 2 == 0 \
            else (("log_linear", loop_ml), ("lln", loop_ll))
        for name, fn in order:
            t0 = time.perf_counter()
            fn()
            walls[name].append(time.perf_counter() - t0)
    s_ll, s_ml = min(walls["lln"]), min(walls["log_linear"])
    toks = b * t * steps
    ratio = s_ml / s_ll
    row = {"name": "decode_cost",
           "shapes": {"batch": b, "heads": h, "head_dim": d, "chunk": t,
                      "granule": granule, "num_scales": ls,
                      "steps": steps},
           "tok_s": {"lln": toks / s_ll, "log_linear": toks / s_ml},
           "wall_s": {"lln": s_ll, "log_linear": s_ml},
           "overhead_ratio": ratio, "gate_ratio": GATE_DECODE_RATIO,
           "pass": bool(ratio <= GATE_DECODE_RATIO)}
    if verbose:
        print(f"  decode lln {toks / s_ll:8.0f} tok/s -> log_linear "
              f"{toks / s_ml:8.0f} tok/s  x{ratio:.2f} "
              f"({'PASS' if row['pass'] else 'FAIL'} <= "
              f"{GATE_DECODE_RATIO}x)", flush=True)
    return row


def run(out_path: str = DEFAULT_OUT, smoke: bool = False,
        verbose: bool = True) -> dict:
    if verbose:
        print(f"== log-linear state: bytes / recall / decode cost "
              f"({'smoke' if smoke else 'full'}) ==", flush=True)
    rows = [state_bytes_cell(verbose), recall_cell(smoke, verbose),
            decode_cost_cell(smoke, verbose)]
    report = {
        "backend": jax.default_backend(),
        "gates": {
            "state_bytes": f"32k-row decode state <= {GATE_STATE_RATIO}x "
                           "the ideal ceil(log2 N) * d * dv fp32 bucket "
                           "budget",
            "recall": f"multi-scale top-1 retrieval accuracy >= "
                      f"{GATE_RECALL_ACC} AND >= single-state lln, with a "
                      "strictly larger correct-vs-confuser cosine margin",
            "decode_cost": f"chunked loglin decode wall clock <= "
                           f"{GATE_DECODE_RATIO}x lln decode (smoke runs "
                           "are informational)",
        },
        "results": rows,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    if verbose:
        print(f"wrote {out_path}")
    return report


def run_rows(verbose: bool = True):
    """benchmarks/run.py adapter: (name, us_per_call, derived) CSV rows —
    us = log_linear decode wall clock, derived = pass fraction."""
    report = run(verbose=verbose)
    rows = report["results"]
    cost = next(r for r in rows if r["name"] == "decode_cost")
    passed = sum(1 for r in rows if r["pass"]) / len(rows)
    return [("loglinear_state", cost["wall_s"]["log_linear"] * 1e6, passed)]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--smoke", action="store_true",
                    help="small recall stream + short decode loop (CI)")
    args = ap.parse_args()
    report = run(args.out, smoke=args.smoke)
    # Smoke-scale wall clocks are too noisy to hard-gate (policy of
    # bench_robustness/bench_longctx); memory + recall always count.
    gated = [r for r in report["results"]
             if not (args.smoke and r["name"] == "decode_cost")]
    if not all(r["pass"] for r in gated):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
