"""Continuous vs static batching: goodput under skewed request lengths.

Serves the SAME mixed-length request stream two ways through the real
serving machinery and compares **goodput** — completed (requested) tokens
per second of wall clock, compiles excluded:

* **static** — FCFS waves of ``slots`` requests through
  ``launch/steps.py:make_serve_setup``: one batched prefill per wave, then
  ``ServeSetup.make_generate`` runs until the LONGEST request of the wave
  finishes.  Rows that asked for fewer tokens idle in lockstep (their
  surplus tokens are generated but not counted — that is the goodput gap).
* **continuous** — the slotted pool (``launch/batcher.py``): per-row
  positions and masks let a freed slot admit the next queued request
  mid-stream, so short requests stop paying for the straggler.

Each cell additionally serves the same stream through the **pooled
speculative** engine (``make_pool_setup(spec_k=..., draft_layers=...)``:
paired target+draft row states, draft-k/verify/accept per segment step,
single-pass verify) and reports its goodput plus acceptance and committed
tokens per verify iteration — the sequential-dependency win on top of
continuous admission.

Traffic is deterministic and skewed (most requests want a few tokens, a
minority want many — the shape that hurts static batching in production).
Both engines serve identical Request streams and both are warmed first.

Writes ``BENCH_batching.json`` at the repo root (schema:
benchmarks/README.md).  CPU-container numbers are only meaningful relative
to each other on the same host.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_batching [--smoke] \
        [--out PATH] [--repeats K]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec
from repro.launch.batcher import ContinuousBatcher, synthetic_traffic
from repro.launch.mesh import compat_mesh
from repro.launch.steps import make_pool_setup, make_serve_setup

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_batching.json")


def _cfg(r: int, impl: str, *, blk: int) -> ArchConfig:
    # Fixed alpha/beta (the pooled-serving convention): per-request
    # calibration is then prompt-batch independent, which lets the engine
    # admit same-length prompts as one batched prefill (launch/batcher.py).
    h = 4
    return ArchConfig(
        name=f"batching-bench-r{r}", family="dense", n_layers=2,
        d_model=128, n_heads=h, n_kv_heads=h // r, d_ff=256, vocab=512,
        head_dim=32, attn_impl=impl, diag_block=blk, lln_chunk=blk,
        softmax_chunk=2 * blk,
        lln_fixed_ab=2.1 if impl != "softmax" else 0.0,
        compute_dtype="float32", param_dtype="float32", remat="none",
        tie_embeddings=True)


class _StaticWaves:
    """FCFS static batching: waves of ``slots`` through make_generate."""

    def __init__(self, cfg, mesh, params, *, slots, prompt_len, max_len):
        from repro.models import build_model
        self.model = build_model(cfg)
        self.params, self.slots, self.mesh = params, slots, mesh
        shape = ShapeSpec("static", max_len, slots, "decode")
        self.setup = make_serve_setup(cfg, shape, mesh, multi_pod=False)
        self.prompt_len = prompt_len
        self._gen_fns: dict = {}

    def _gen_fn(self, steps: int):
        if steps not in self._gen_fns:
            self._gen_fns[steps] = self.setup.make_generate(steps, 0.0)
        return self._gen_fns[steps]

    def serve(self, reqs) -> dict:
        """Serve all requests; returns rid -> generated tokens."""
        outputs = {}
        for i in range(0, len(reqs), self.slots):
            wave = reqs[i:i + self.slots]
            # Pad the last wave by repeating its tail request; the pad
            # rows' tokens are generated but never counted.
            rows = wave + [wave[-1]] * (self.slots - len(wave))
            prompts = jnp.asarray(np.stack([r.prompt for r in rows]))
            batch = {"inputs": prompts, "targets": prompts,
                     "mask": jnp.ones(prompts.shape, jnp.float32)}
            logits, caches = self.setup.prefill_fn(self.params, batch)
            last = logits[:, -1] if logits.ndim == 3 else logits
            tok0 = jnp.argmax(last, -1).astype(jnp.int32)
            toks = [np.asarray(tok0)]
            steps = max(r.gen_len for r in wave) - 1
            if steps > 0:
                out, _ = self._gen_fn(steps)(
                    self.params, caches, tok0,
                    jnp.asarray(self.prompt_len, jnp.int32),
                    jax.random.PRNGKey(0))
                toks.append(np.asarray(out).T)
            all_toks = np.concatenate([t.reshape(-1, self.slots) for t in
                                       toks], axis=0)      # (1+steps, B)
            for j, r in enumerate(wave):
                outputs[r.rid] = all_toks[:r.gen_len, j]
        return outputs

    def wave_steps(self, reqs) -> int:
        """Decode row-steps dispatched (slot-occupancy denominator)."""
        total = 0
        for i in range(0, len(reqs), self.slots):
            wave = reqs[i:i + self.slots]
            total += (max(r.gen_len for r in wave) - 1) * self.slots
        return total


def bench_one(r: int, impl: str, *, slots, n_requests, prompt_len,
              gen_lens, segment, blk, repeats, mesh, verbose,
              spec_k=2, draft_layers=1) -> dict:
    from repro.models import build_model
    cfg = _cfg(r, impl, blk=blk)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len = prompt_len + max(gen_lens) + 1 + spec_k
    reqs = synthetic_traffic(n_requests, cfg.vocab, [prompt_len], gen_lens,
                             seed=r)
    useful = sum(rq.gen_len for rq in reqs)

    static = _StaticWaves(cfg, mesh, params, slots=slots,
                          prompt_len=prompt_len, max_len=max_len)
    pool = make_pool_setup(cfg, mesh, slots=slots, max_len=max_len,
                           segment=segment)
    eng = ContinuousBatcher(pool, params)
    spec_pool = make_pool_setup(cfg, mesh, slots=slots, max_len=max_len,
                                segment=segment, spec_k=spec_k,
                                draft_layers=draft_layers)
    spec_eng = ContinuousBatcher(spec_pool, params)

    # Warm every compile: static prefill + each distinct wave length, and
    # each pool's prefill/admit/segment.
    static.serve(reqs)
    eng.warmup([prompt_len])
    eng.run(reqs)
    spec_eng.warmup([prompt_len])
    spec_eng.run(reqs)

    st_ts, ct_ts, sp_ts, ct_steps = [], [], [], 0
    spec_stats = None
    for it in range(repeats):
        order = (("static", "cont", "spec") if it % 2 == 0
                 else ("spec", "cont", "static"))
        for mode in order:
            if mode == "static":
                t0 = time.perf_counter()
                static.serve(reqs)
                st_ts.append(time.perf_counter() - t0)
            elif mode == "cont":
                stats = eng.run(reqs)
                assert stats.completed_tokens == useful
                ct_ts.append(stats.wall_s)
                ct_steps = stats.decode_steps
            else:
                spec_stats = spec_eng.run(reqs)
                assert spec_stats.completed_tokens == useful
                sp_ts.append(spec_stats.wall_s)
    st_s, ct_s, sp_s = min(st_ts), min(ct_ts), min(sp_ts)
    row = {
        "name": f"r{r}_{impl}", "r": r, "impl": impl,
        "traffic": {"requests": n_requests, "slots": slots,
                    "prompt_len": prompt_len, "gen_lens": gen_lens,
                    "segment": segment, "useful_tokens": useful},
        "goodput_tok_s": {"static": useful / st_s,
                          "continuous": useful / ct_s,
                          "continuous_spec": useful / sp_s},
        "wall_s": {"static": st_s, "continuous": ct_s,
                   "continuous_spec": sp_s},
        "speedup": st_s / ct_s,
        "continuous_spec": {
            "spec_k": spec_k, "draft_layers": draft_layers,
            "acceptance_rate": spec_stats.acceptance_rate,
            "goodput_tokens_per_iter":
                spec_stats.goodput_tokens_per_iter,
            "verify_iters": spec_stats.verify_iters,
        },
        "slot_utilization": {
            "static": useful / max(static.wave_steps(reqs) + n_requests, 1),
            "continuous": useful / max(ct_steps * slots + n_requests, 1),
        },
    }
    if verbose:
        g = row["goodput_tok_s"]
        u = row["slot_utilization"]
        sp = row["continuous_spec"]
        print(f"  static {g['static']:7.1f} tok/s (util {u['static']:.2f})"
              f" -> continuous {g['continuous']:7.1f} tok/s "
              f"(util {u['continuous']:.2f})  speedup {row['speedup']:.2f}x"
              f"  | spec {g['continuous_spec']:7.1f} tok/s "
              f"(acc {sp['acceptance_rate']:.2f}, "
              f"{sp['goodput_tokens_per_iter']:.2f} tok/iter)",
              flush=True)
    return row


def run(out_path: str = DEFAULT_OUT, smoke: bool = False,
        repeats: int = 3, verbose: bool = True) -> dict:
    if smoke:
        cells = [(1, "lln_diag")]
        slots, n_requests, prompt_len, segment, blk = 2, 5, 16, 4, 16
        gen_lens = [3, 3, 9]
        repeats = 1
    else:
        cells = [(r, impl) for r in (1, 4) for impl in ("softmax",
                                                        "lln_diag")]
        slots, n_requests, prompt_len, segment, blk = 4, 16, 16, 8, 16
        # Skewed: 3/4 of requests want 9 tokens, 1/4 want 129 — the
        # long-tail shape that makes lockstep waves idle short rows.
        gen_lens = [9, 9, 9, 129]
    mesh = compat_mesh((1, 1), ("data", "model"))
    rows = []
    with mesh:
        for r, impl in cells:
            if verbose:
                print(f"== r{r} {impl} ==", flush=True)
            rows.append(bench_one(r, impl, slots=slots,
                                  n_requests=n_requests,
                                  prompt_len=prompt_len, gen_lens=gen_lens,
                                  segment=segment, blk=blk,
                                  repeats=repeats, mesh=mesh,
                                  verbose=verbose))
    report = {
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() == "cpu",
        "repeats": repeats,
        "modes": {
            "static": "FCFS waves of `slots` requests: batched prefill + "
                      "one make_generate segment per wave, run until the "
                      "wave's longest request finishes (surplus tokens "
                      "discarded)",
            "continuous": "slotted pool (launch/batcher.py): per-row "
                          "positions + masked rows; freed slots admit the "
                          "next queued request mid-stream via "
                          "dynamic-slice state writes",
            "continuous_spec": "the same slotted pool with speculative "
                               "rows (make_pool_setup spec_k/draft_layers):"
                               " paired target+draft states, one "
                               "draft-k/verify/accept iteration per "
                               "segment step, single-pass verify",
        },
        "gate": "continuous goodput >= 1.3x static on at least one cell "
                "under the skewed traffic",
        "results": rows,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    if verbose:
        print(f"wrote {out_path}")
    return report


def run_rows(verbose: bool = True):
    """benchmarks/run.py adapter: (name, us_per_call, derived) CSV rows —
    us = continuous-engine wall time for the stream, derived = goodput
    speedup over static waves."""
    report = run(verbose=verbose)
    return [(f"batching_{row['name']}", row["wall_s"]["continuous"] * 1e6,
             row["speedup"]) for row in report["results"]]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--smoke", action="store_true", help="one tiny cell (CI)")
    args = ap.parse_args()
    run(args.out, smoke=args.smoke, repeats=args.repeats)


if __name__ == "__main__":
    main()
