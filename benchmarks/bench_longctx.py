"""Long-horizon decode soak: drift-free state + flat telemetry to 500k tokens.

PR 7's length-robustness layer makes three promises this benchmark checks
end to end on a synthetic 500k-token decode stream (CPU-sized state,
``core/lln.py:decode_chunk`` — the same math the serving pool scans):

* **drift-free state** — with renormalization on (``renorm`` threshold),
  every state leaf stays finite and inside the fp32-safe magnitude bound
  (the health sentinel's ``max_abs``) over the whole horizon, and ``z``
  stays pinned near the threshold while the baseline's ``z`` grows
  without bound (the running-sum pathology);
* **semantics-preserving renorm** — the renormalized run's decode outputs
  match the baseline token-for-token (the normalized LLN form is exactly
  invariant to the reference constant), and its drift-corrected
  ``log_mass`` (``z`` + ``log_scale``) matches the baseline's raw log
  mass — telemetry is renorm-invariant;
* **flat telemetry** — on a stationary stream the streaming concentration
  drift (``core/metrics.py:streaming_concentration``) is flat from 4k to
  500k (a drifting value is the dilution/explosion pathology), with the
  beta(n) length schedule on.

A fourth cell measures the SERVING cost of the telemetry: the same
deterministic request stream through ``ContinuousBatcher`` with
``make_pool_setup(telemetry=True)`` vs ``telemetry=False`` — the fused
reduction must cost <= 2% wall clock (same gate as the health sentinel,
``bench_robustness``).

Writes ``BENCH_longctx.json`` at the repo root (schema:
benchmarks/README.md).

Usage:
    PYTHONPATH=src python -m benchmarks.bench_longctx [--smoke] \
        [--out PATH] [--tokens N]
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lln
from repro.core import moment_matching as mm
from repro.core.metrics import streaming_concentration

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_longctx.json")

GATE_OVERHEAD_PCT = 2.0      # telemetry on-vs-off serving wall clock
GATE_FLAT = 0.5              # max-min of conc_drift over the back half
GATE_FP32_SAFE = 1e6         # health sentinel max_abs: every robust leaf
GATE_GROWTH_RATIO = 4.0      # renorm z_final / z_anchor must stay under
GROWTH_FRACTION = 0.4        # baseline must grow >= this fraction of the
                             # token ratio (z is a running sum: ~linear)

B, H, D, DV = 2, 2, 16, 16
RENORM = 64.0
BETA_N = 0.5
CALIB_LEN = 1024


def _chunk_fn(renorm, beta_n):
    """One jitted soak step: fold T tokens, return state + telemetry."""

    @jax.jit
    def step(state, q, k, v, alpha, beta, pos):
        gain = mm.length_gain(pos.astype(jnp.float32), beta_n=beta_n,
                              calib_len=CALIB_LEN)
        out, state = lln.decode_chunk(state, q, k, v, alpha * gain,
                                      beta, renorm=renorm)
        conc = streaming_concentration(
            state.z, c=jnp.squeeze(state.c_k, axis=(-1, -3)),
            log_scale=state.log_scale, pos=pos[None].repeat(B))
        zmax = jnp.max(state.z)
        leafmax = jnp.maximum(jnp.max(jnp.abs(state.s)),
                              jnp.maximum(zmax,
                                          jnp.max(jnp.abs(state.c_k))))
        return state, out, conc, zmax, leafmax

    return step


def soak(total_tokens: int, chunk: int, *, renorm, beta_n, seed=0) -> dict:
    """Decode ``total_tokens`` synthetic tokens in ``chunk``-sized folds,
    recording telemetry at every fold.  Stationary stream: any drift in
    the instruments is the estimator's, not the data's."""
    steps = total_tokens // chunk
    key = jax.random.PRNGKey(seed)
    alpha = jnp.full((B, H), 0.4, jnp.float32)
    beta = jnp.full((B, H), 0.4, jnp.float32)
    state = lln.LLNState.init(B, H, D, DV)
    step = _chunk_fn(renorm if renorm > 0 else None, beta_n)

    trace = {"pos": [], "conc_drift": [], "log_mass": [], "tau_hat": [],
             "z_max": [], "leaf_max": []}
    out_probe = None
    for i in range(steps):
        kk = jax.random.fold_in(key, i)
        kq, kkk, kv = jax.random.split(kk, 3)
        q = jax.random.normal(kq, (B, chunk, H, D), jnp.float32)
        k = jax.random.normal(kkk, (B, chunk, H, D), jnp.float32)
        v = jax.random.normal(kv, (B, chunk, H, DV), jnp.float32)
        pos = jnp.asarray(i * chunk, jnp.int32)
        state, out, conc, zmax, leafmax = step(state, q, k, v, alpha,
                                               beta, pos)
        if i == 0:
            out_probe = np.asarray(out)      # first-chunk outputs: parity
        trace["pos"].append((i + 1) * chunk)
        trace["conc_drift"].append(float(conc["conc_drift"][0]))
        trace["log_mass"].append(float(conc["log_mass"][0]))
        trace["tau_hat"].append(float(conc["tau_hat"][0]))
        trace["z_max"].append(float(zmax))
        trace["leaf_max"].append(float(leafmax))
    trace["out_probe"] = out_probe
    trace["final_out"] = np.asarray(out)
    return trace


def soak_cells(total_tokens: int, chunk: int, verbose: bool) -> list[dict]:
    """baseline (renorm off) vs renorm (on, beta off) vs robust (renorm +
    beta(n)).  The baseline/renorm pair shares the token stream, so renorm
    invariance is a bitwise-comparable claim."""
    base = soak(total_tokens, chunk, renorm=0.0, beta_n=0.0)
    ren = soak(total_tokens, chunk, renorm=RENORM, beta_n=0.0)
    rob = soak(total_tokens, chunk, renorm=RENORM, beta_n=BETA_N)

    anchor = min(4096, total_tokens // 8)
    k4 = max(0, min(len(base["pos"]) - 2,
                    int(np.searchsorted(base["pos"], anchor))))
    token_ratio = base["pos"][-1] / base["pos"][k4]
    rows = []

    def growth(tr):
        return tr["z_max"][-1] / max(tr["z_max"][k4], 1e-30)

    # 1) baseline grows without bound (a running sum: ~linearly in the
    # token ratio); renorm pins z at the threshold — once pinned it stays
    # flat, so the back half of the renorm trace must not grow.
    g_base = growth(base)
    min_base = GROWTH_FRACTION * token_ratio
    ren_back = ren["z_max"][len(ren["z_max"]) // 2:]
    g_ren_back = max(ren_back) / max(min(ren_back), 1e-30)
    rows.append({
        "name": "z_growth", "anchor_tokens": int(base["pos"][k4]),
        "final_tokens": int(base["pos"][-1]),
        "baseline_ratio": g_base, "baseline_min": min_base,
        "renorm_back_half_ratio": g_ren_back,
        "renorm_z_max": max(ren["z_max"]),
        "pass": bool(g_base >= min_base
                     and g_ren_back <= GATE_GROWTH_RATIO
                     and max(ren["z_max"]) <= RENORM * (1.0 + 1e-3)),
    })
    # 2) every robust leaf finite + fp32-safe over the whole horizon.
    leaf_max = max(rob["leaf_max"])
    rows.append({
        "name": "fp32_safe", "robust_leaf_max": leaf_max,
        "bound": GATE_FP32_SAFE,
        "pass": bool(np.isfinite(leaf_max) and leaf_max <= GATE_FP32_SAFE),
    })
    # 3) renorm-invariant outputs AND telemetry (same stream, renorm
    # on/off): log_mass agrees because log_scale repays the shift exactly.
    lm_err = float(np.max(np.abs(np.asarray(ren["log_mass"])
                                 - np.asarray(base["log_mass"]))))
    out_err = float(np.max(np.abs(ren["final_out"] - base["final_out"])))
    rows.append({
        "name": "renorm_invariance", "log_mass_err": lm_err,
        "final_out_err": out_err,
        "pass": bool(lm_err <= 1e-3 and out_err <= 1e-3),
    })
    # 4) flat concentration drift over the back half, beta(n) on.
    back = np.asarray(rob["conc_drift"][len(rob["conc_drift"]) // 2:])
    spread = float(back.max() - back.min())
    rows.append({
        "name": "telemetry_flat", "drift_spread_back_half": spread,
        "gate": GATE_FLAT, "tau_hat_final": rob["tau_hat"][-1],
        "pass": bool(spread <= GATE_FLAT
                     and np.isfinite(rob["tau_hat"][-1])),
    })
    if verbose:
        for r in rows:
            print(f"  {r['name']}: {'PASS' if r['pass'] else 'FAIL'} "
                  + json.dumps({k: v for k, v in r.items()
                                if k not in ('name', 'pass')}), flush=True)
    return rows


def overhead_cell(repeats: int, smoke: bool, verbose: bool) -> dict:
    """Serving cost of the fused telemetry: telemetry=True vs False
    through the real ContinuousBatcher, min-of-repeats wall clock."""
    from repro.configs.base import ArchConfig
    from repro.launch.batcher import ContinuousBatcher, synthetic_traffic
    from repro.launch.mesh import compat_mesh
    from repro.launch.steps import make_pool_setup
    from repro.models import build_model

    h = 4
    cfg = ArchConfig(
        name="longctx-bench", family="dense", n_layers=2, d_model=128,
        n_heads=h, n_kv_heads=h, d_ff=256, vocab=512, head_dim=32,
        attn_impl="lln_diag", diag_block=16, lln_chunk=16,
        softmax_chunk=32, lln_fixed_ab=2.1, compute_dtype="float32",
        param_dtype="float32", remat="none", tie_embeddings=True)
    slots, n_req, plen, seg = (2, 4, 16, 4) if smoke else (4, 12, 16, 8)
    gen_lens = [3, 3, 9] if smoke else [9, 9, 33]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = synthetic_traffic(n_req, cfg.vocab, [plen], gen_lens, seed=3)
    useful = sum(rq.gen_len for rq in reqs)
    mesh = compat_mesh((1, 1), ("data", "model"))
    with mesh:
        engines = {}
        for mode, tele in (("telemetry_off", False), ("telemetry_on", True)):
            pool = make_pool_setup(cfg, mesh, slots=slots,
                                   max_len=plen + max(gen_lens) + 1,
                                   segment=seg, telemetry=tele)
            eng = ContinuousBatcher(pool, params)
            eng.warmup([plen])
            eng.run(reqs)
            engines[mode] = eng
        walls = {m: [] for m in engines}
        for it in range(repeats):
            order = (("telemetry_off", "telemetry_on") if it % 2 == 0
                     else ("telemetry_on", "telemetry_off"))
            for mode in order:
                stats = engines[mode].run(reqs)
                assert stats.completed_tokens == useful
                walls[mode].append(stats.wall_s)
    off_s, on_s = min(walls["telemetry_off"]), min(walls["telemetry_on"])
    overhead_pct = (on_s - off_s) / off_s * 100.0
    row = {"name": "telemetry_overhead",
           "traffic": {"requests": n_req, "slots": slots,
                       "prompt_len": plen, "gen_lens": gen_lens,
                       "segment": seg, "useful_tokens": useful},
           "tok_s": {"telemetry_off": useful / off_s,
                     "telemetry_on": useful / on_s},
           "wall_s": {"telemetry_off": off_s, "telemetry_on": on_s},
           "overhead_pct": overhead_pct, "gate_pct": GATE_OVERHEAD_PCT,
           "pass": overhead_pct <= GATE_OVERHEAD_PCT}
    if verbose:
        t = row["tok_s"]
        print(f"  telemetry off {t['telemetry_off']:7.1f} tok/s -> on "
              f"{t['telemetry_on']:7.1f} tok/s  overhead "
              f"{overhead_pct:+.2f}% "
              f"({'PASS' if row['pass'] else 'FAIL'} "
              f"<= {GATE_OVERHEAD_PCT}%)", flush=True)
    return row


def run(out_path: str = DEFAULT_OUT, smoke: bool = False,
        tokens: int = 500_000, repeats: int = 3,
        verbose: bool = True) -> dict:
    if smoke:
        tokens, chunk, repeats = 8_000, 200, 1
    else:
        chunk = 500
    if verbose:
        print(f"== soak: {tokens} tokens, chunk {chunk}, B={B} H={H} "
              f"D={D} ==", flush=True)
    rows = soak_cells(tokens, chunk, verbose)
    if verbose:
        print("== serving telemetry overhead ==", flush=True)
    rows.append(overhead_cell(repeats, smoke, verbose))
    report = {
        "backend": jax.default_backend(),
        "soak": {"tokens": tokens, "chunk": chunk, "batch": B, "heads": H,
                 "head_dim": D, "renorm": RENORM, "beta_n": BETA_N,
                 "calib_len": CALIB_LEN},
        "modes": {
            "baseline": "renorm off, beta(n) off — the unguarded "
                        "running-sum recurrence",
            "renorm": "renorm threshold on (drift-free state), beta(n) "
                      "off — output/telemetry parity cell vs baseline",
            "robust": "renorm + beta(n) length schedule — the serving "
                      "long-horizon configuration",
        },
        "gates": {
            "z_growth": f"baseline z grows >= {GROWTH_FRACTION} x the "
                        f"token ratio from the 4k anchor while the "
                        f"renorm trace's back half is flat "
                        f"(<= {GATE_GROWTH_RATIO}x) and under the "
                        f"threshold",
            "fp32_safe": f"every robust state leaf finite and |x| <= "
                         f"{GATE_FP32_SAFE:g} over the whole horizon",
            "renorm_invariance": "outputs and log_mass match baseline "
                                 "to 1e-3 (renorm is semantics-preserving)",
            "telemetry_flat": f"conc_drift spread over the back half <= "
                              f"{GATE_FLAT}",
            "telemetry_overhead": f"fused telemetry costs <= "
                                  f"{GATE_OVERHEAD_PCT}% serving wall "
                                  "clock",
        },
        "results": rows,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    if verbose:
        print(f"wrote {out_path}")
    return report


def run_rows(verbose: bool = True):
    """benchmarks/run.py adapter: (name, us_per_call, derived) CSV rows —
    us = telemetry-on serving wall clock, derived = pass fraction of the
    soak gates."""
    report = run(verbose=verbose)
    rows = report["results"]
    over = next(r for r in rows if r["name"] == "telemetry_overhead")
    passed = sum(1 for r in rows if r["pass"]) / len(rows)
    return [("longctx_soak", over["wall_s"]["telemetry_on"] * 1e6, passed)]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--tokens", type=int, default=500_000)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="8k-token soak + tiny serving cell (CI)")
    args = ap.parse_args()
    report = run(args.out, smoke=args.smoke, tokens=args.tokens,
                 repeats=args.repeats)
    # Smoke-scale wall clocks are too noisy to hard-gate (same policy as
    # bench_robustness); the deterministic soak gates always count.
    gated = [r for r in report["results"]
             if not (args.smoke and r["name"] == "telemetry_overhead")]
    if not all(r["pass"] for r in gated):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
